"""Docs-sync check: the README's ``python`` blocks must RUN as written.

Extracts every fenced ```python block from README.md (in document
order), concatenates them into one script, and executes it — the blocks
are written as one continuous session, so later blocks may use names
earlier blocks define.  Any API drift (renamed function, changed
signature, stale example) fails here instead of rotting in the docs.

Usage:
    PYTHONPATH=src python tools/check_docs.py [--print] [FILE ...]

``--print`` dumps the assembled script instead of running it.  Extra
FILE arguments are checked the same way (default: README.md only —
DESIGN.md's fences are illustrative fragments, not sessions).

CI runs this (plus examples/quickstart.py) in the docs-sync job;
tests/test_docs.py runs the extraction logic so the block count is
pinned in tier-1 too.
"""
from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FENCE = re.compile(r"^```python[ \t]*$(.*?)^```[ \t]*$",
                    re.MULTILINE | re.DOTALL)


def extract_blocks(md_text: str) -> list[str]:
    """All ```python fenced blocks, in order (bash/other fences skipped)."""
    return [m.group(1).strip("\n") for m in _FENCE.finditer(md_text)]


def assemble(path: str) -> tuple[str, int]:
    """(assembled script, number of blocks) for the markdown file."""
    with open(path) as f:
        blocks = extract_blocks(f.read())
    if not blocks:
        raise SystemExit(f"{path}: no ```python blocks found — "
                         f"is the file fenced correctly?")
    rel = os.path.relpath(path, REPO)
    out = [f"# assembled from {rel} by tools/check_docs.py\n"]
    for i, b in enumerate(blocks):
        out.append(f"# --- {rel} block {i + 1} ---\n{b}\n")
    return "\n".join(out), len(blocks)


def main() -> None:
    ap = argparse.ArgumentParser(prog="check_docs")
    ap.add_argument("files", nargs="*",
                    default=[os.path.join(REPO, "README.md")])
    ap.add_argument("--print", action="store_true", dest="show",
                    help="dump the assembled script, don't run it")
    args = ap.parse_args()
    sys.path.insert(0, os.path.join(REPO, "src"))
    for path in args.files:
        script, n = assemble(path)
        if args.show:
            print(script)
            continue
        print(f"[check_docs] {os.path.relpath(path, REPO)}: "
              f"executing {n} python block(s)", flush=True)
        # one namespace per FILE: blocks are a continuous session
        exec(compile(script, f"<{os.path.relpath(path, REPO)}>", "exec"),
             {"__name__": "__docs__"})
        print(f"[check_docs] {os.path.relpath(path, REPO)}: OK",
              flush=True)


if __name__ == "__main__":
    main()
