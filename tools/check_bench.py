#!/usr/bin/env python
"""Gate the pinned benchmark trajectories (ISSUE 6 / ISSUE 9).

    python tools/check_bench.py BENCH_kernels.json bench-kernels-ci.json
    python tools/check_bench.py BENCH_serve.json   bench-serve-ci.json

Compares a freshly-measured ``--bench-json`` artifact against the
committed baseline:

  * ratio fields (``speedup`` — legacy us / new us for kernel records,
    p99 bucket/continuous for serve records — and, where present,
    ``goodput_ratio``) may not regress by more than 20% for any record —
    ratios of two measurements on the SAME machine in the SAME mode are
    machine-independent, so this gate works on any CI runner even though
    absolute microseconds do not transfer (the serve-load ratios are
    computed on a deterministic virtual clock and reproduce exactly);
  * ``hbm_bytes`` (and the epilogue activation-bytes model), when the
    record carries them, must match EXACTLY — these are derived from
    shapes, not measured, so any drift means the benchmarked problem
    changed out from under the baseline.  Serve records have no byte
    model and simply omit the field;
  * every baseline record must still be present (same kind + name).

Exit status 1 on any failure, with a per-record report either way.
"""
from __future__ import annotations

import json
import sys

TOLERANCE = 0.20  # max allowed relative ratio regression

#: gated ratio fields, checked when present in the baseline record
RATIO_FIELDS = ("speedup", "goodput_ratio")


def _key(rec):
    return (rec["kind"], rec["name"])


def check(base_doc: dict, new_doc: dict) -> list:
    failures = []
    if base_doc.get("schema") != new_doc.get("schema"):
        failures.append(f"schema mismatch: {base_doc.get('schema')} vs "
                        f"{new_doc.get('schema')}")
        return failures
    if base_doc.get("mode") != new_doc.get("mode"):
        failures.append(
            f"mode mismatch ({base_doc.get('mode')} baseline vs "
            f"{new_doc.get('mode')} candidate): smoke-mode ratios are not "
            f"comparable to full-mode ones")
        return failures
    new_by_key = {_key(r): r for r in new_doc.get("records", [])}
    for b in base_doc.get("records", []):
        k = _key(b)
        n = new_by_key.get(k)
        tag = f"{k[0]}/{k[1]}"
        if n is None:
            failures.append(f"{tag}: record missing from candidate")
            continue
        if "hbm_bytes" in b and b["hbm_bytes"] != n.get("hbm_bytes"):
            failures.append(f"{tag}: hbm_bytes changed "
                            f"{b['hbm_bytes']} -> {n.get('hbm_bytes')} "
                            f"(benchmarked problem drifted)")
        if "epilogue" in b:
            for f in ("act_bytes_f32", "act_bytes_wire"):
                if b["epilogue"][f] != n.get("epilogue", {}).get(f):
                    failures.append(f"{tag}: epilogue {f} changed")
        for field in RATIO_FIELDS:
            if field not in b:
                continue
            got = n.get(field)
            if got is None:
                failures.append(f"{tag}: {field} missing from candidate")
                continue
            floor = b[field] * (1 - TOLERANCE)
            status = "ok" if got >= floor else "FAIL"
            print(f"{status:4s} {tag:32s} {field} {b[field]:6.2f}x -> "
                  f"{got:6.2f}x (floor {floor:.2f}x)")
            if status == "FAIL":
                failures.append(
                    f"{tag}: {field} regressed {b[field]:.2f}x -> "
                    f"{got:.2f}x (> {TOLERANCE:.0%} drop)")
    return failures


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        base = json.load(f)
    with open(argv[2]) as f:
        new = json.load(f)
    failures = check(base, new)
    if failures:
        print("\ncheck_bench FAILURES:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\ncheck_bench: all {len(base.get('records', []))} records "
          f"within {TOLERANCE:.0%} of the pinned trajectory")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
