"""Serving driver: batched generation with the BFP inference datapath.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6-3b] [--bfp]

Builds a reduced same-family model, serves a batch of requests through
the continuous-batching engine, and (with --bfp) runs every GEMM through
the paper's 8-bit fixed-point datapath — the deployment the paper's
accelerator targets.  Compares BFP vs float generations.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.policy import PAPER_DEFAULT
from repro.models.lm.model import init_params
from repro.serve.engine import ServeEngine, Request, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--bfp", action="store_true")
    ap.add_argument("--prequant", action="store_true",
                    help="cache pre-quantized int8 weights in the engine "
                         "(quantize once, not per decode step)")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch], n_layers=4, d_model=128, d_ff=256,
                  vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    policy = PAPER_DEFAULT.with_(straight_through=False) if args.bfp else None

    print(f"serving {cfg.name} bfp={args.bfp} prequant={args.prequant} "
          f"slots={args.slots}")
    # --prequant without --bfp is still meaningful: weights live as
    # int8+scale (4x smaller) and the float path dequantizes on the fly.
    prequant = (PAPER_DEFAULT.with_(straight_through=False)
                if args.prequant else None)
    eng = ServeEngine(params, cfg, slots=args.slots, max_len=128,
                      policy=policy, prequant=prequant)
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=[1 + i, 7, 3, 2], max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    for r in done:
        print(f"req {r.rid}: {r.out}")
    print(f"\n{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s, CPU, continuous batching)")

    # float-vs-BFP agreement on greedy decode (paper: accuracy preserved)
    prompt = jnp.asarray([[1, 7, 3, 2]], jnp.int32)
    t_f = generate(params, cfg, prompt, max_new=args.max_new)
    t_q = generate(params, cfg, prompt, max_new=args.max_new,
                   policy=PAPER_DEFAULT.with_(straight_through=False))
    agree = float(jnp.mean(t_f == t_q))
    print(f"greedy-token agreement float vs BFP-8: {agree * 100:.0f}%")


if __name__ == "__main__":
    main()
