"""End-to-end training driver: ~100M-param LM for a few hundred steps.

Run:  PYTHONPATH=src python examples/train_lm.py \
          [--arch tinyllama-1.1b] [--steps 300] [--params-m 100] \
          [--bfp] [--compress-grads] [--ckpt-dir /tmp/ckpt]

Uses the real stack end to end: config registry -> scaled-down same-family
model (~100M params by default) -> synthetic deterministic data pipeline ->
AdamW + cosine -> fault-tolerant loop (async checkpoints, resume,
straggler watchdog).  ``--bfp`` trains with the BFP forward datapath
(straight-through gradients, beyond-paper QAT); ``--compress-grads``
enables the BFP gradient-compression hook (DESIGN.md §5).
"""
import argparse

import jax

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.policy import PAPER_DEFAULT
from repro.data.pipeline import LMBatchSpec
from repro.dist.compress import make_compressor
from repro.optim import optimizers as opt
from repro.train.loop import LoopConfig, run_training
from repro.train.step import init_state, make_train_step
from repro.models.lm.model import param_count


def scaled_config(name: str, params_m: int):
    """Same-family config scaled to ~params_m million parameters."""
    base = ARCHS[name]
    d = {50: 384, 100: 512, 200: 768}.get(params_m, 512)
    return reduced(base, n_layers=8, d_model=d, d_ff=4 * d, vocab=8192)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--params-m", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--bfp", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = scaled_config(args.arch, args.params_m)
    state = init_state(cfg, jax.random.PRNGKey(0))
    n = param_count(state.params)
    print(f"arch={cfg.name} params={n / 1e6:.1f}M bfp={args.bfp}")

    grad_transform = None
    if args.compress_grads:
        init_fn, transform = make_compressor(bits=8)
        residual = [init_fn(state.params)]

        def grad_transform(grads):
            q, residual[0] = transform(grads, residual[0])
            return q

    policy = PAPER_DEFAULT if args.bfp else None
    step = make_train_step(
        cfg, opt.cosine_schedule(3e-4, 20, args.steps),
        policy=policy, grad_transform=grad_transform)
    if grad_transform is None:
        step = jax.jit(step)

    spec = LMBatchSpec(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)
    out = run_training(
        state, step, spec,
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=50, log_every=10),
        log_fn=lambda s, m: print(
            f"step {s:4d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.2f} "
            f"lr {m['lr']:.2e}"))
    h = out["history"]
    print(f"\nloss: {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} over "
          f"{len(h)} steps; median step {out['median_step_s'] * 1e3:.0f} ms; "
          f"stragglers flagged: {out['stragglers_flagged']}")


if __name__ == "__main__":
    main()
