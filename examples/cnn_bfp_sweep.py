"""Paper protocol end-to-end: train a CNN in float, deploy it in BFP.

Run:  PYTHONPATH=src python examples/cnn_bfp_sweep.py [--kind mnist|cifar]

Trains LeNet on the synthetic 'mnist' task, then—WITHOUT retraining—
evaluates the same weights under BFP across mantissa widths (paper
Table 3) and across partition schemes (paper Table 2), and checks the
paper's headline claim: 8-bit mantissas cost < 0.3% accuracy.
"""
import argparse

from repro.core.bfp import Rounding, Scheme
from repro.core.policy import BFPPolicy
from benchmarks.cnn_train import accuracy, train_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="mnist", choices=["mnist", "cifar"])
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()

    print(f"training {args.kind} CNN in float32 ({args.steps} steps)...")
    params, apply_fn, ev = train_model(args.kind, steps=args.steps)
    acc_f = accuracy(params, apply_fn, ev, None)
    print(f"float accuracy: {acc_f:.4f}\n")

    print("=== Table 3 analog: accuracy drop vs mantissa width ===")
    print(f"{'L_W/L_I':>8s} {'acc':>8s} {'drop':>8s}")
    for bits in (4, 5, 6, 7, 8):
        pol = BFPPolicy(l_w=bits, l_i=bits, straight_through=False)
        acc = accuracy(params, apply_fn, ev, pol)
        print(f"{bits:>8d} {acc:8.4f} {acc_f - acc:+8.4f}")

    print("\n=== Table 2 analog: partition scheme at 8 bits ===")
    for scheme in (Scheme.EQ2, Scheme.EQ4, Scheme.TILED):
        pol = BFPPolicy(scheme=scheme, block_k=32, straight_through=False)
        acc = accuracy(params, apply_fn, ev, pol)
        print(f"{scheme.value:>8s} {acc:8.4f} {acc_f - acc:+8.4f}")

    print("\n=== §3.1: rounding vs truncation at 6 bits ===")
    for rnd in (Rounding.ROUND, Rounding.TRUNCATE):
        pol = BFPPolicy(l_w=6, l_i=6, rounding=rnd, straight_through=False)
        acc = accuracy(params, apply_fn, ev, pol)
        print(f"{rnd.value:>9s} {acc:8.4f} {acc_f - acc:+8.4f}")

    pol8 = BFPPolicy(straight_through=False)
    drop = acc_f - accuracy(params, apply_fn, ev, pol8)
    print(f"\npaper headline check: 8-bit drop = {drop:+.4f} "
          f"({'PASS' if drop < 0.003 else 'above 0.3% on this task'})")


if __name__ == "__main__":
    main()
