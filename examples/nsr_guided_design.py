"""The paper's intended USE of the NSR model: pick hardware bit-widths
analytically before building the accelerator.

Run:  PYTHONPATH=src python examples/nsr_guided_design.py

Given a target end-to-end SNR budget for an N-layer network, invert the
paper's error model (eq. 8, 18, 20) to find the cheapest (L_W, L_I)
meeting it, then verify the pick empirically on a GEMM chain.  This is
the "promising guidance for BFP based CNN engine design" of the abstract,
turned into a function.
"""
import jax
import jax.numpy as jnp

from repro.core.nsr import (analyze_gemm_chain, nsr_from_snr_db,
                            predict_matrix_snr, snr_db_from_nsr)
from repro.core.policy import BFPPolicy


def predict_final_snr(x, ws, l_w, l_i):
    """Chain eq. 18 + eq. 20 analytically over a layer stack."""
    pol = BFPPolicy(l_w=l_w, l_i=l_i)
    eta = 0.0
    for w in ws:
        eta_i = float(nsr_from_snr_db(predict_matrix_snr(x, l_i, "i", pol)))
        eta_w = float(nsr_from_snr_db(predict_matrix_snr(w, l_w, "w", pol)))
        eta = eta + eta_i + eta * eta_i + eta_w      # eq. 20 then eq. 17
        x = jax.nn.relu(x @ w)                        # advance signal stats
    return float(snr_db_from_nsr(jnp.asarray(eta)))


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 256))
    ws = [jax.random.normal(jax.random.PRNGKey(i), (256, 256)) * 0.08
          for i in range(6)]
    target_db = 20.0

    print(f"target: end-to-end SNR >= {target_db} dB over {len(ws)} layers\n")
    print(f"{'L_W':>4s} {'L_I':>4s} {'pred dB':>9s} {'mult bits':>10s}")
    best = None
    for l in range(4, 12):
        pred = predict_final_snr(x, ws, l, l)
        cost = 2 * l  # multiplier input bits ~ area proxy (paper Fig. 2)
        print(f"{l:>4d} {l:>4d} {pred:9.2f} {cost:10d}")
        if pred >= target_db and best is None:
            best = l
    print(f"\nanalytical pick: L_W = L_I = {best}")

    rep = analyze_gemm_chain(x, ws, BFPPolicy(l_w=best, l_i=best,
                                              straight_through=False))[-1]
    print(f"empirical final SNR at {best} bits: "
          f"{rep.snr_output_measured:.2f} dB "
          f"({'meets' if rep.snr_output_measured >= target_db else 'misses'}"
          f" the {target_db} dB target)")


if __name__ == "__main__":
    main()
