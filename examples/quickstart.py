"""Quickstart: the paper's BFP datapath in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py

1. block-format a tensor (paper eq. 1) and inspect the error,
2. run a BFP GEMM on the integer datapath (paper Fig. 2),
3. predict its output SNR with the paper's analytical model (eq. 18)
   and compare with measurement,
4. do the same through a conv layer (paper §3.2 matrix form).
"""
import jax
import jax.numpy as jnp

from repro.core import (BFPPolicy, PAPER_DEFAULT, TPU_TILED, Scheme,
                        bfp_dot, quantize)
from repro.core.nsr import (analyze_gemm_chain, predict_matrix_snr, snr_db)
from repro.models.cnn import layers as L

key = jax.random.PRNGKey(0)

# --- 1. block formatting ---------------------------------------------------
x = jax.random.normal(key, (4, 512)) * 3.0
blk = quantize(x, bits=8, axes=(1,))          # one exponent per row
print("block exponents:", blk.exponent.ravel()[:4])
print("mantissa dtype :", blk.mantissa.dtype)  # int8 -> 4x smaller than f32
print("round-trip SNR :", float(snr_db(x, blk.dequantize())), "dB")

# --- 2. BFP GEMM (integer datapath) -----------------------------------------
w = jax.random.normal(jax.random.PRNGKey(1), (512, 256)) * 0.05
y_float = x @ w
y_paper = bfp_dot(x, w, PAPER_DEFAULT)        # paper's eq. (4) scheme
y_tiled = bfp_dot(x, w, TPU_TILED)            # TPU K-tile blocks (ours)
print("\npaper eq.4 GEMM SNR:", float(snr_db(y_float, y_paper)), "dB")
print("TPU tiled GEMM SNR :", float(snr_db(y_float, y_tiled)), "dB")

# --- 3. analytical NSR model ------------------------------------------------
rep = analyze_gemm_chain(x, [w], PAPER_DEFAULT.with_(straight_through=False))[0]
print("\npredicted output SNR (eq. 18):", rep.snr_output_single, "dB")
print("measured  output SNR          :", rep.snr_output_measured, "dB")

# --- 4. a BFP convolution (paper's matrix form) -----------------------------
img = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 16, 3))
conv = L.conv2d_init(jax.random.PRNGKey(3), 3, 8, 3, 3)
out_f = L.conv2d(conv, img, policy=None)
out_q = L.conv2d(conv, img, policy=PAPER_DEFAULT.with_(straight_through=False))
print("\nconv output SNR:", float(snr_db(out_f, out_q)), "dB")
print("\nDone — see examples/cnn_bfp_sweep.py for the paper's Table-3 "
      "experiment and examples/train_lm.py for the training stack.")
