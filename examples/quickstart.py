"""Quickstart: the paper's BFP datapath in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py

1. block-format a tensor (paper eq. 1) and inspect the error,
2. run a BFP GEMM on the integer datapath (paper Fig. 2),
3. predict its output SNR with the paper's analytical model (eq. 18)
   and compare with measurement,
4. deploy a CNN with engine.bind: policies resolved, backends selected,
   weights pre-quantized ONCE — then just run (DESIGN.md §7.1),
5. watch the real datapath with engine taps (DESIGN.md §7.2),
6. save a bit-packed BFP checkpoint and serve from it — the paper's
   Table-1 storage cut measured in real bytes (DESIGN.md §10).
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import PAPER_DEFAULT, TPU_TILED, bfp_dot, quantize
from repro.core.nsr import analyze_gemm_chain, snr_db
from repro.models.cnn import small

key = jax.random.PRNGKey(0)

# --- 1. block formatting ---------------------------------------------------
x = jax.random.normal(key, (4, 512)) * 3.0
blk = quantize(x, bits=8, axes=(1,))          # one exponent per row
print("block exponents:", blk.exponent.ravel()[:4])
print("mantissa dtype :", blk.mantissa.dtype)  # int8 -> 4x smaller than f32
print("round-trip SNR :", float(snr_db(x, blk.dequantize())), "dB")

# --- 2. BFP GEMM (integer datapath) -----------------------------------------
w = jax.random.normal(jax.random.PRNGKey(1), (512, 256)) * 0.05
y_float = x @ w
y_paper = bfp_dot(x, w, PAPER_DEFAULT)        # paper's eq. (4) scheme
y_tiled = bfp_dot(x, w, TPU_TILED)            # TPU K-tile blocks (ours)
print("\npaper eq.4 GEMM SNR:", float(snr_db(y_float, y_paper)), "dB")
print("TPU tiled GEMM SNR :", float(snr_db(y_float, y_tiled)), "dB")

# --- 3. analytical NSR model ------------------------------------------------
rep = analyze_gemm_chain(x, [w], PAPER_DEFAULT.with_(straight_through=False))[0]
print("\npredicted output SNR (eq. 18):", rep.snr_output_single, "dB")
print("measured  output SNR          :", rep.snr_output_measured, "dB")

# --- 4. bind once, then run (the deployment mode) ---------------------------
# engine.bind walks the params ONCE: per-layer policy rules resolved,
# backends validated + selected (strict=True would refuse fallbacks),
# weights pre-quantized to the int8+scale wire format.
pol = PAPER_DEFAULT.with_(straight_through=False)
params = small.lenet_init(jax.random.PRNGKey(4))
imgs = jax.random.normal(jax.random.PRNGKey(5), (2, 28, 28, 1))
pmap = engine.PolicyMap.of(("^c1$", None),              # stem stays float
                           default=pol)
plan = engine.bind(params, pmap)
print("\nbound plan:\n" + plan.describe())
out_bound = small.lenet_apply(plan.params, imgs, plan)   # plan rides `policy`
print("bound forward:", out_bound.shape)

# legacy shim: the per-call path still works — same engine, same bits,
# policies re-resolved and weights re-quantized every forward.
out_legacy = small.lenet_apply(params, imgs, pmap)
print("legacy per-call matches bound plan:",
      bool(jnp.all(out_bound == out_legacy)))

# --- 5. engine taps: observe the real datapath ------------------------------
with engine.taps(lambda ev: print(f"  tap {ev.path:<4} {ev.kind:<4} "
                                  f"-> {ev.backend}, SNR "
                                  f"{float(snr_db(ev.y_float, ev.y)):.1f} dB"),
                 want_float=True):
    small.lenet_apply(params, imgs, pol)

# --- 6. packed BFP checkpoints: Table 1 in real bytes ------------------------
# format="bfp_packed" stores GEMM/conv weights as bit-packed mantissas +
# one int8 exponent per block (core.packed.PackedBFP); restore yields the
# {"m","s"} sidecars directly — serving never materializes float weights.
from repro.checkpoint import store  # noqa: E402

with tempfile.TemporaryDirectory() as ckpt:
    store.save(os.path.join(ckpt, "f32"), 0, params)
    store.save(os.path.join(ckpt, "bfp"), 0, params,
               format="bfp_packed", policy=pmap)    # same per-layer map

    def du(d):
        return sum(os.path.getsize(os.path.join(r, f))
                   for r, _, fs in os.walk(d) for f in fs)

    ratio = du(os.path.join(ckpt, "bfp")) / du(os.path.join(ckpt, "f32"))
    print(f"\npacked checkpoint is {ratio:.2f}x the float32 npz (L=8)")
    weights, _ = store.restore(os.path.join(ckpt, "bfp"), params)
    plan_pk = engine.bind(weights, pmap)
    out_pk = small.lenet_apply(plan_pk.params, imgs, plan_pk)
    print("packed restore serves bit-identically:",
          bool(jnp.all(out_pk == out_bound)))

print("\nDone — see examples/cnn_bfp_sweep.py for the paper's Table-3 "
      "experiment, benchmarks/table4_nsr.py for the tap-based SNR "
      "analysis, and examples/train_lm.py for the training stack.")
