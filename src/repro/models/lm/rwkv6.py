"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time mixing with
data-dependent per-channel decay.

Recurrence per head (Dk = Dv = head_dim):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Train/prefill uses the CHUNKED formulation (production linear-attention
style): lax.scan over chunks of 128 carrying S, quadratic within chunk —
O(S * C) memory, compact HLO.  Decode is the single-step recurrence.

The WKV recurrence itself is elementwise/outer-product (no GEMM), so BFP
does not apply there (DESIGN.md §Arch-applicability); all projections
(r,k,v,g,w-lora, output, channel-mix) go through bfp_dot.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.core.policy import BFPPolicy
from repro.dist.sharding import shard
from repro.models.lm.common import linear, linear_init, rmsnorm, rmsnorm_init

Policy = Optional[BFPPolicy]

_CHUNK = 32
_LORA = 64  # decay lora rank (Finch uses 64 for ~3b)
# Per-step log-decay clamp: keeps every exponential in the chunked
# formulation inside fp32 range (chunk 32 x 2.0 = 64 < log(3.4e38) ~ 88).
# w >= e^-2 per step still decays state to ~1.6e-28 within one chunk, so
# the semantic difference from unclamped RWKV-6 is negligible (the
# official CUDA kernels clamp the decay exponent the same way).
_LOGW_MIN = -2.0


def time_mix_init(key, cfg: LMConfig):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.dh
    ks = jax.random.split(key, 9)
    p = {
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),  # shift mix r,k,v,w,g
        "wr": linear_init(ks[1], d, d),
        "wk": linear_init(ks[2], d, d),
        "wv": linear_init(ks[3], d, d),
        "wg": linear_init(ks[4], d, d),
        "wo": linear_init(ks[5], d, d),
        # data-dependent decay lora: w_t = exp(-exp(w0 + tanh(x@A)@B))
        "w0": jnp.zeros((d,), jnp.float32) - 0.5,
        "wA": linear_init(ks[6], d, _LORA),
        "wB": linear_init(ks[7], _LORA, d),
        "u": jax.random.normal(ks[8], (h, dh), jnp.float32) * 0.1,  # bonus
        "ln": rmsnorm_init(d),   # per-head group norm approximated by rmsnorm
    }
    return p


def _token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """shifted sequence: [x_prev, x_0 .. x_{S-2}] (one-step delay line)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _projections(p, cfg: LMConfig, x, x_prev, policy: Policy):
    b, s, d = x.shape
    xs = _token_shift(x, x_prev.astype(x.dtype))
    mu = p["mu"].astype(x.dtype)
    mix = lambda i: x + mu[i] * (xs - x)
    r = linear(p["wr"], mix(0), policy)
    k = linear(p["wk"], mix(1), policy)
    v = linear(p["wv"], mix(2), policy)
    xw = mix(3)
    g = linear(p["wg"], mix(4), policy)
    # data-dependent decay (the Finch feature): low-rank modulation
    logw = p["w0"] + linear(p["wB"], jnp.tanh(linear(p["wA"], xw, policy)),
                            policy)
    w = jnp.exp(-jnp.exp(logw.astype(jnp.float32)))   # in (0, 1)
    h, dh = cfg.n_heads, cfg.dh
    shp = (b, s, h, dh)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp),
            w.reshape(shp), jax.nn.silu(g))


def _wkv_chunked(r, k, v, w, u) -> jax.Array:
    """Chunked WKV.  r,k,v,w: [B,S,H,D]; u: [H,D] -> out [B,S,H,D].

    Within a chunk (length C, fp32):
      P_i   = prod_{j<=i} w_j           (inclusive cumulative decay)
      r~_i  = r_i * P_{i-1},  k~_j = k_j / P_j
      o_i   = r~_i @ S_0 + sum_{j<i} (r~_i . k~_j) v_j + ((r_i*u) . k_i) v_i
      S_C   = diag(P_C) S_0 + sum_j diag(P_C/P_j) k_j^T v_j
    """
    b, s, h, d = r.shape
    c = min(_CHUNK, s)
    assert s % c == 0, f"seq {s} must be a multiple of chunk {c}"
    n = s // c
    f32 = jnp.float32
    rc, kc, vc, wc = (t.astype(f32).reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)
                      for t in (r, k, v, w))   # [n,B,H,C,D]

    logw = jnp.clip(jnp.log(jnp.maximum(wc, 1e-38)), _LOGW_MIN, 0.0)
    logP = jnp.cumsum(logw, axis=3)            # inclusive [n,B,H,C,D]
    P = jnp.exp(logP)
    Pprev = jnp.exp(logP - logw)               # exclusive (P_{i-1})
    r_t = rc * Pprev
    k_t = kc * jnp.exp(-logP)                  # k_j / P_j
    Pend = jnp.exp(logP[:, :, :, -1:, :])      # P_C  [n,B,H,1,D]

    # intra-chunk attention: A[i,j] = (r~_i . k~_j) for j < i; diag uses u
    mask = jnp.tril(jnp.ones((c, c), f32), k=-1)
    A = jnp.einsum("nbhid,nbhjd->nbhij", r_t, k_t) * mask
    diag = jnp.einsum("nbhid,nbhid->nbhi",
                      rc * u.astype(f32)[None, None, :, None, :], kc)
    intra = jnp.einsum("nbhij,nbhjd->nbhid", A, vc) + diag[..., None] * vc

    # state contribution of each chunk: sum_j (P_C/P_j * k_j)^T v_j
    kdec = kc * (Pend * jnp.exp(-logP))
    chunk_state = jnp.einsum("nbhjd,nbhje->nbhde", kdec, vc)  # [n,B,H,D,Dv]

    def step(S, inp):
        r_ti, Pend_i, cs_i = inp
        inter = jnp.einsum("bhid,bhde->bhie", r_ti, S)
        S_new = S * Pend_i.transpose(0, 1, 3, 2) + cs_i  # decay along Dk
        return S_new, inter

    S0 = jnp.zeros((b, h, d, d), f32)
    _, inter = jax.lax.scan(step, S0, (r_t, Pend, chunk_state))
    out = (intra + inter).transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return out.astype(r.dtype)


def time_mix(p, cfg: LMConfig, x: jax.Array, x_prev: jax.Array,
             policy: Policy = None) -> jax.Array:
    """Full-sequence WKV (train/prefill).  x_prev: [B, D] delay-line state."""
    r, k, v, w, g = _projections(p, cfg, x, x_prev, policy)
    o = _wkv_chunked(r, k, v, w, p["u"])
    b, s = x.shape[0], x.shape[1]
    o = rmsnorm(p["ln"], o.reshape(b, s, -1), cfg.norm_eps)
    return linear(p["wo"], o * g, policy)


def time_mix_decode(p, cfg: LMConfig, x: jax.Array, state
                    ) -> Tuple[jax.Array, Tuple]:
    """One-token step.  x: [B, 1, D]; state = (x_prev [B,D], S [B,H,D,D])."""
    x_prev, S = state
    r, k, v, w, g = _projections(p, cfg, x, x_prev, None)
    f32 = jnp.float32
    r1, k1, v1, w1 = (t[:, 0].astype(f32) for t in (r, k, v, w))  # [B,H,D]
    u = p["u"].astype(f32)
    kv = jnp.einsum("bhd,bhe->bhde", k1, v1)
    o = jnp.einsum("bhd,bhde->bhe", r1, S + u[None, :, :, None] * kv)
    w1 = jnp.maximum(w1, jnp.exp(_LOGW_MIN))   # same clamp as the train path
    S = S * w1[..., None] + kv
    b = x.shape[0]
    o = rmsnorm(p["ln"], o.reshape(b, 1, -1).astype(x.dtype), cfg.norm_eps)
    out = linear(p["wo"], o * g, None)
    return out, (x[:, -1], S)


# ---------------------------------------------------------------------------
# Channel mix (RWKV FFN)
# ---------------------------------------------------------------------------

def channel_mix_init(key, cfg: LMConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"mu": jax.random.uniform(ks[0], (2, d), jnp.float32),
            "wk": linear_init(ks[1], d, f),
            "wv": linear_init(ks[2], f, d),
            "wr": linear_init(jax.random.fold_in(key, 7), d, d)}


def channel_mix(p, cfg: LMConfig, x: jax.Array, x_prev: jax.Array,
                policy: Policy = None) -> jax.Array:
    xs = _token_shift(x, x_prev.astype(x.dtype))
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk, policy)))
    k = shard(k, "batch", "seq", "ffn")
    return jax.nn.sigmoid(linear(p["wr"], xr, policy)) * \
        linear(p["wv"], k, policy)


def channel_mix_decode(p, cfg: LMConfig, x: jax.Array, x_prev: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    out = channel_mix(p, cfg, x, x_prev, None)
    return out, x[:, -1]
