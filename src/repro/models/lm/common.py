"""Shared transformer components: RMSNorm, RoPE (+M-RoPE), GQA attention
(full / sliding-window, train + KV-cache decode), SwiGLU FFN.

Every linear routes through ``repro.engine.gemm`` so the paper's BFP
datapath applies uniformly (DESIGN.md §3); ``policy=None`` is float, a
``repro.engine.PolicyMap`` resolves per-component policies against the
layer ``path`` ("attn/wq", "ffn/w1", ...), and a bound
``repro.engine.Plan`` (``engine.bind``) carries the same paths with
resolution + backend selection done once up front (ServeEngine binds at
admission).  Pre-quantized weights (the ``{"m", "s"}`` wire format from
``repro.engine.prequantize``) pass to the engine AS-IS: the int8
mantissas + scale sidecar feed the integer datapath directly instead of
being dequantized and re-quantized per forward.  Activations carry
logical sharding annotations (repro.dist.sharding).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.configs.base import LMConfig
from repro.dist.sharding import shard
from repro.engine import PolicyLike, join_path

__all__ = ["rmsnorm", "rmsnorm_init", "rope", "mrope", "attention_init",
           "attention", "attention_decode", "swiglu_init", "swiglu",
           "linear_init", "linear", "embed_init"]

Policy = PolicyLike


def _init(key, shape, fan_in):
    return (jax.random.normal(key, shape, jnp.float32)
            * jnp.sqrt(1.0 / fan_in)).astype(jnp.float32)


def linear_init(key, d_in: int, d_out: int, bias: bool = False):
    p = {"w": _init(key, (d_in, d_out), d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p, x: jax.Array, policy: Policy = None,
           path: Optional[str] = None) -> jax.Array:
    w = p["w"]
    if not EG.is_prequant(w):
        w = w.astype(x.dtype)        # params fp32, compute in x.dtype
    y = EG.gemm(x, w, policy, path=path)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * p["g"]).astype(x.dtype)


def embed_init(key, vocab: int, d: int):
    return {"e": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(dh: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dh // 2, dtype=jnp.float32)
                            / (dh // 2)))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] int32."""
    freqs = _rope_freqs(x.shape[-1], theta)                    # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs     # [B,S,Dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def mrope(x: jax.Array, positions3: jax.Array, theta: float,
          sections: Tuple[int, int, int]) -> jax.Array:
    """qwen2-vl multimodal RoPE: positions3 [3, B, S] = (t, h, w) ids.

    The Dh/2 rotary frequencies are partitioned into (temporal, height,
    width) sections; each section rotates by its own position stream.
    For text tokens the three streams coincide, reducing to standard RoPE.
    """
    dh = x.shape[-1]
    freqs = _rope_freqs(dh, theta)                                    # [Dh/2]
    ang_3 = positions3[..., None].astype(jnp.float32) * freqs         # [3,B,S,Dh/2]
    import numpy as np
    sec_ids = np.repeat(np.arange(3), np.asarray(sections))[: dh // 2]
    sel = jax.nn.one_hot(jnp.asarray(sec_ids), 3, dtype=jnp.float32)  # [Dh/2,3]
    ang = jnp.einsum("pbsd,dp->bsd", ang_3, sel)                      # [B,S,Dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _apply_rope(cfg: LMConfig, x, positions):
    if cfg.mrope_sections is not None:
        if positions.ndim == 2:  # text-only: all three streams equal
            positions = jnp.broadcast_to(positions[None],
                                         (3,) + positions.shape)
        return mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return rope(x, positions, cfg.rope_theta)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_init(key, cfg: LMConfig, cross: bool = False):
    d, dh, h, hk = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_init(ks[0], d, h * dh, cfg.qkv_bias),
        "wk": linear_init(ks[1], d, hk * dh, cfg.qkv_bias),
        "wv": linear_init(ks[2], d, hk * dh, cfg.qkv_bias),
        "wo": linear_init(ks[3], h * dh, d),
    }


def _qkv(p, cfg: LMConfig, x, xkv, policy: Policy, path=None):
    b, s = x.shape[0], x.shape[1]
    skv = xkv.shape[1]
    q = linear(p["wq"], x, policy,
               join_path(path, "wq")).reshape(b, s, cfg.n_heads, cfg.dh)
    k = linear(p["wk"], xkv, policy,
               join_path(path, "wk")).reshape(b, skv, cfg.n_kv_heads, cfg.dh)
    v = linear(p["wv"], xkv, policy,
               join_path(path, "wv")).reshape(b, skv, cfg.n_kv_heads, cfg.dh)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _sdpa(q, k, v, cfg: LMConfig, mask: Optional[jax.Array]) -> jax.Array:
    """Grouped scaled dot-product attention.  q:[B,S,H,Dh] k,v:[B,T,Hk,Dh]."""
    b, s, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    q = q.reshape(b, s, hk, g, dh)
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k,
                        preferred_element_type=jnp.float32) / jnp.sqrt(dh)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, h, dh)


def _flash_sdpa(q, k, v, cfg: LMConfig, causal: bool,
                chunk: int = 512) -> jax.Array:
    """Memory-efficient attention: lax.scan over KV chunks with an online
    softmax (running max / normalizer) — O(S * chunk) live memory instead
    of O(S^2), the standard production attention shape for long sequences.
    With per-layer remat the backward recomputes chunks (flash-style).
    """
    b, s, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    if cfg.analysis_unroll:
        # keep the unroll at <= ~16 chunk bodies (HLO size) in analysis mode
        chunk = max(512, ((t // 16 + 127) // 128) * 128)
    # operands stay in compute dtype (bf16 on TPU); accumulation is f32 via
    # preferred_element_type — halves score/PV traffic (§Perf iteration A3)
    qg = (q.reshape(b, s, hk, g, dh) / jnp.sqrt(dh).astype(q.dtype))
    nc = -(-t // chunk)
    pad = nc * chunk - t
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q_idx = jnp.arange(s)

    def body(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(kp, i * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(vp, i * chunk, chunk, 1)
        scores = jnp.einsum("bshgd,bthd->bhgst", qg, ks,
                            preferred_element_type=jnp.float32)
        if cfg.attn_logit_softcap:
            c = cfg.attn_logit_softcap
            scores = jnp.tanh(scores / c) * c
        k_idx = i * chunk + jnp.arange(chunk)
        valid = k_idx[None, :] < t
        if causal:
            valid = valid & (k_idx[None, :] <= q_idx[:, None])
        scores = jnp.where(valid[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = alpha * l + jnp.sum(p, axis=-1)
        # p stays f32: casting it to bf16 materializes an extra s x chunk
        # buffer that costs more than the PV read saves (refuted variant,
        # §Perf A3b); converting the small vs chunk up to f32 is cheaper.
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("bhgst,bthd->bhgsd", p,
                                vs.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), jnp.float32)
    a0 = jnp.zeros((b, hk, g, s, dh), jnp.float32)
    if cfg.analysis_unroll:
        carry = (m0, l0, a0)
        for i in range(nc):
            carry, _ = body(carry, jnp.asarray(i))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh).astype(q.dtype)


# Sequence length at/above which the flash path replaces materialized
# S x S scores for full attention.
FLASH_THRESHOLD = 2048


def _causal_mask(s: int, window: Optional[int]) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m &= (i - j) < window
    return m  # [S, S] -> broadcast over [B,Hk,G,S,T]


def attention(p, cfg: LMConfig, x: jax.Array, positions: jax.Array,
              policy: Policy = None,
              xkv: Optional[jax.Array] = None,
              causal: bool = True,
              path: Optional[str] = None) -> jax.Array:
    """Full-sequence attention (training / prefill).

    Sliding-window attention uses chunked computation: queries in chunks of
    W attend to (own + previous) key chunk — O(S*2W) instead of O(S^2).
    Cross-attention (xkv given) is non-causal.
    """
    cross = xkv is not None
    xkv = x if xkv is None else xkv
    q, k, v = _qkv(p, cfg, x, xkv, policy, path)
    if not cross:
        q = _apply_rope(cfg, q, positions)
        k = _apply_rope(cfg, k, positions)

    w = cfg.sliding_window
    s = x.shape[1]
    if (not cross) and w is not None and s > 2 * w and s % w == 0:
        out = _swa_chunked(q, k, v, cfg, w)       # O(S * 2W) local attention
    elif (not cross) and w is None and s >= FLASH_THRESHOLD:
        out = _flash_sdpa(q, k, v, cfg, causal)   # online-softmax long-seq
    else:
        mask = None
        if causal and not cross:
            mask = _causal_mask(s, w)[None, None, None]
        out = _sdpa(q, k, v, cfg, mask)
    out = shard(out, "batch", "seq", "heads", None)
    b = x.shape[0]
    return linear(p["wo"], out.reshape(b, s, -1), policy,
                  join_path(path, "wo"))


def _swa_chunked(q, k, v, cfg: LMConfig, w: int) -> jax.Array:
    """Sliding-window attention in O(S * 2W): chunk queries by window size;
    each chunk attends to its own and the previous key/value chunk."""
    b, s, h, dh = q.shape
    hk = k.shape[2]
    nc = s // w
    qc = q.reshape(b, nc, w, h, dh)
    kc = k.reshape(b, nc, w, hk, dh)
    vc = v.reshape(b, nc, w, hk, dh)
    # previous chunk (zero-padded at the front; masked out anyway)
    kp = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    k2 = jnp.concatenate([kp, kc], axis=2)   # [B,nc,2W,Hk,Dh]
    v2 = jnp.concatenate([vp, vc], axis=2)
    g = h // hk
    qg = qc.reshape(b, nc, w, hk, g, dh)
    scores = jnp.einsum("bcshgd,bcthd->bchgst", qg, k2,
                        preferred_element_type=jnp.float32) / jnp.sqrt(dh)
    i = jnp.arange(w)[:, None]          # query offset in chunk
    j = jnp.arange(2 * w)[None, :]      # key offset in [prev, own]
    rel = (i + w) - j                    # distance >= 0 means j not after i
    mask = (rel >= 0) & (rel < w)        # strictly inside the window
    first = jnp.arange(nc) == 0          # first chunk has no prev
    mask_all = mask[None] & ~(first[:, None, None] & (j < w)[None])
    scores = jnp.where(mask_all[None, :, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bchgst,bcthd->bcshgd", probs, v2)
    return out.reshape(b, s, h, dh)


def attention_decode(p, cfg: LMConfig, x: jax.Array, pos: jax.Array,
                     kcache: jax.Array, vcache: jax.Array,
                     policy: Policy = None,
                     path: Optional[str] = None
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode with KV cache.

    x: [B, 1, D]; kcache/vcache: [B, T, Hk, Dh] (T = max_len, or the
    window size when sliding-window — ring buffer indexed pos % T).
    Returns (out [B,1,D], new kcache, new vcache).
    """
    b = x.shape[0]
    t = kcache.shape[1]
    q = linear(p["wq"], x, policy,
               join_path(path, "wq")).reshape(b, 1, cfg.n_heads, cfg.dh)
    k = linear(p["wk"], x, policy,
               join_path(path, "wk")).reshape(b, 1, cfg.n_kv_heads, cfg.dh)
    v = linear(p["wv"], x, policy,
               join_path(path, "wv")).reshape(b, 1, cfg.n_kv_heads, cfg.dh)
    positions = jnp.broadcast_to(pos[None], (b, 1)) \
        if pos.ndim == 0 else pos.reshape(b, 1)
    q = _apply_rope(cfg, q, positions)
    k = _apply_rope(cfg, k, positions)

    slot = (pos % t).astype(jnp.int32)   # ring buffer for SWA; == pos otherwise
    kcache = jax.lax.dynamic_update_slice_in_dim(kcache, k.astype(kcache.dtype),
                                                 slot, axis=1)
    vcache = jax.lax.dynamic_update_slice_in_dim(vcache, v.astype(vcache.dtype),
                                                 slot, axis=1)
    # valid positions: those already written (<= pos), within window if SWA
    idx = jnp.arange(t)
    written = jnp.where(pos >= t, t, pos + 1)      # ring full once pos >= t
    valid = idx < written
    mask = valid[None, None, None, None, :]        # [1,1,1,1,T]
    out = _sdpa(q, kcache.astype(q.dtype), vcache.astype(q.dtype), cfg, mask)
    return (linear(p["wo"], out.reshape(b, 1, -1), policy,
                   join_path(path, "wo")), kcache, vcache)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, f: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": linear_init(k1, d, f),    # gate
            "w3": linear_init(k2, d, f),    # up
            "w2": linear_init(k3, f, d)}    # down


def swiglu(p, x: jax.Array, policy: Policy = None,
           path: Optional[str] = None) -> jax.Array:
    h = jax.nn.silu(linear(p["w1"], x, policy, join_path(path, "w1"))) \
        * linear(p["w3"], x, policy, join_path(path, "w3"))
    h = shard(h, "batch", "seq", "ffn")
    return linear(p["w2"], h, policy, join_path(path, "w2"))
