"""RG-LRU recurrent block (Griffin / recurrentgemma, arXiv:2402.19427).

Block: x -> [linear -> causal depthwise conv1d(4) -> RG-LRU] * [linear ->
GeLU] -> linear.  RG-LRU per channel:

    r_t = sigmoid(x_t @ Wr + br)          (recurrence gate)
    i_t = sigmoid(x_t @ Wi + bi)          (input gate)
    a_t = exp(-c * softplus(L) * r_t)     (data-dependent decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses jax.lax.associative_scan over the sequence (the
recurrence is a diagonal affine map -> associative composition).  The
recurrence is elementwise (no GEMM) so BFP applies to the surrounding
projections only (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.core.policy import BFPPolicy
from repro.models.lm.common import linear, linear_init

Policy = Optional[BFPPolicy]
_C = 8.0


def rglru_block_init(key, cfg: LMConfig):
    d = cfg.d_model
    lw = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    # Lambda init so decay a in (0.9, 0.999) at r=1 (Griffin appendix)
    lam = jax.random.uniform(ks[0], (lw,), jnp.float32, 0.9, 0.999)
    softplus_inv = jnp.log(jnp.expm1(-jnp.log(lam) / _C))
    return {
        "in_x": linear_init(ks[1], d, lw),
        "in_g": linear_init(ks[2], d, lw),
        "conv_w": jax.random.normal(ks[3], (cfg.conv_width, lw),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((lw,), jnp.float32),
        "wr": linear_init(ks[4], lw, lw),
        "wi": linear_init(ks[5], lw, lw),
        "lam": softplus_inv,
        "out": linear_init(ks[6], lw, d),
    }


def _causal_conv(w, b, x, x_hist=None):
    """Causal depthwise conv1d.  x: [B,S,C]; w: [W,C].

    x_hist: [B, W-1, C] previous inputs for decode continuity (None = zeros).
    """
    width = w.shape[0]
    w = w.astype(x.dtype)
    if x_hist is None:
        x_hist = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([x_hist.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return out + b.astype(x.dtype)


def _rglru(p, x: jax.Array, h0: Optional[jax.Array], policy: Policy
           ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,C] -> (y [B,S,C], h_last [B,C]) via associative scan."""
    r = jax.nn.sigmoid(linear(p["wr"], x, policy).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["wi"], x, policy).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r            # [B,S,C]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * x.astype(jnp.float32))
    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block(p, cfg: LMConfig, x: jax.Array, state=None,
                policy: Policy = None):
    """Full-sequence Griffin recurrent block.

    state: None (train) or (h0 [B,C], conv_hist [B,W-1,C]) for chunked
    prefill continuation.  Returns (y, new_state).
    """
    h0, hist = state if state is not None else (None, None)
    gate = jax.nn.gelu(linear(p["in_g"], x, policy))
    u = linear(p["in_x"], x, policy)
    u_conv = _causal_conv(p["conv_w"], p["conv_b"], u, hist)
    h, h_last = _rglru(p, u_conv, h0, policy)
    y = linear(p["out"], h * gate, policy)
    width = p["conv_w"].shape[0]
    new_hist = u[:, -(width - 1):] if u.shape[1] >= width - 1 else u
    return y, (h_last, new_hist)


def rglru_block_decode(p, cfg: LMConfig, x: jax.Array, state,
                       policy: Policy = None):
    """Single-token step.  x: [B,1,D]; state = (h [B,C], conv_hist)."""
    h_prev, hist = state
    gate = jax.nn.gelu(linear(p["in_g"], x, policy))
    u = linear(p["in_x"], x, policy)                       # [B,1,C]
    u_conv = _causal_conv(p["conv_w"], p["conv_b"], u, hist)
    r = jax.nn.sigmoid(linear(p["wr"], u_conv, policy).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["wi"], u_conv, policy).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)[:, 0]
    drive = (jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) *
             (i * u_conv.astype(jnp.float32)))[:, 0]
    h = a * h_prev.astype(jnp.float32) + drive             # [B,C]
    y = linear(p["out"], h[:, None].astype(x.dtype) * gate, policy)
    new_hist = jnp.concatenate([hist[:, 1:], u], axis=1)
    return y, (h, new_hist)
