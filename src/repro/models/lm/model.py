"""Unified LM: one config-driven model covering every assigned family.

Layer stacks are built with ``jax.vmap`` at init (leaves stacked [L, ...])
and executed with ``jax.lax.scan`` + per-layer ``jax.checkpoint`` — compact
HLO (one traced layer body) and activation remat, which is what makes the
full-size 40-cell dry-run compile quickly.

Families:
  dense / vlm      scan over {attn, swiglu} blocks (M-RoPE when configured)
  moe              scan over {attn, moe} blocks (+ aux loss accumulated)
  ssm (rwkv6)      scan over {time_mix, channel_mix} blocks
  hybrid (griffin) scan over (rec, rec, attn) super-blocks + remainder
  audio (enc-dec)  encoder scan + decoder scan with cross-attention

API:
  init_params(cfg, key)                  -> params pytree
  forward(params, cfg, batch, policy)    -> logits (train / prefill)
  init_cache(cfg, batch, max_len)        -> decode cache
  decode_step(params, cfg, cache, tok, pos, policy) -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.configs.base import LMConfig
from repro.dist.sharding import shard
from repro.models.lm import common as C
from repro.models.lm import griffin as G
from repro.models.lm import moe as M
from repro.models.lm import rwkv6 as R

Policy = EG.PolicyLike


# ---------------------------------------------------------------------------
# Per-layer init / apply for each block kind
# ---------------------------------------------------------------------------

def _attn_block_init(key, cfg: LMConfig, cross: bool = False):
    ks = jax.random.split(key, 5)
    p = {"ln1": C.rmsnorm_init(cfg.d_model),
         "attn": C.attention_init(ks[0], cfg),
         "ln2": C.rmsnorm_init(cfg.d_model),
         "ffn": C.swiglu_init(ks[1], cfg.d_model, cfg.d_ff)}
    if cross:
        p["lnx"] = C.rmsnorm_init(cfg.d_model)
        p["xattn"] = C.attention_init(ks[2], cfg)
    return p


def _attn_block(p, cfg, x, positions, policy, enc=None):
    # Layers run under lax.scan (one trace for the whole stack), so paths
    # name COMPONENTS ("attn/wq", "ffn/w1"), not layer indices — PolicyMap
    # rules act per component class across all layers.
    h = C.attention(p["attn"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps),
                    positions, policy, path="attn")
    x = x + h
    if enc is not None:
        h = C.attention(p["xattn"], cfg, C.rmsnorm(p["lnx"], x, cfg.norm_eps),
                        positions, policy, xkv=enc, path="xattn")
        x = x + h
    x = x + C.swiglu(p["ffn"], C.rmsnorm(p["ln2"], x, cfg.norm_eps), policy,
                     path="ffn")
    return shard(x, "batch", "seq_res", "embed")


def _moe_block_init(key, cfg: LMConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": C.rmsnorm_init(cfg.d_model),
            "attn": C.attention_init(k1, cfg),
            "ln2": C.rmsnorm_init(cfg.d_model),
            "moe": M.moe_init(k2, cfg)}


def _moe_block(p, cfg, x, positions, policy):
    x = x + C.attention(p["attn"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps),
                        positions, policy, path="attn")
    y, aux = M.moe_apply(p["moe"], cfg, C.rmsnorm(p["ln2"], x, cfg.norm_eps),
                         policy)
    return shard(x + y, "batch", "seq_res", "embed"), aux


def _rwkv_block_init(key, cfg: LMConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": C.rmsnorm_init(cfg.d_model),
            "tm": R.time_mix_init(k1, cfg),
            "ln2": C.rmsnorm_init(cfg.d_model),
            "cm": R.channel_mix_init(k2, cfg)}


def _rwkv_block(p, cfg, x, policy):
    b = x.shape[0]
    zero = jnp.zeros((b, x.shape[-1]), x.dtype)
    x = x + R.time_mix(p["tm"], cfg, C.rmsnorm(p["ln1"], x, cfg.norm_eps),
                       zero, policy)
    x = x + R.channel_mix(p["cm"], cfg, C.rmsnorm(p["ln2"], x, cfg.norm_eps),
                          zero, policy)
    return shard(x, "batch", "seq_res", "embed")


def _rec_block_init(key, cfg: LMConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": C.rmsnorm_init(cfg.d_model),
            "rec": G.rglru_block_init(k1, cfg),
            "ln2": C.rmsnorm_init(cfg.d_model),
            "ffn": C.swiglu_init(k2, cfg.d_model, cfg.d_ff)}


def _rec_block(p, cfg, x, policy, state=None):
    y, new_state = G.rglru_block(p["rec"], cfg,
                                 C.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                 state, policy)
    x = x + y
    x = x + C.swiglu(p["ffn"], C.rmsnorm(p["ln2"], x, cfg.norm_eps), policy,
                     path="ffn")
    return shard(x, "batch", "seq_res", "embed"), new_state


# ---------------------------------------------------------------------------
# Hybrid pattern helpers (recurrentgemma)
# ---------------------------------------------------------------------------

def _hybrid_layout(cfg: LMConfig):
    """(n_periods, remainder_kinds): 38 = 12 x (rec,rec,attn) + (rec,rec)."""
    pat = cfg.block_pattern
    n_periods = cfg.n_layers // len(pat)
    rem = cfg.n_layers - n_periods * len(pat)
    return n_periods, pat[:rem]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: LMConfig, key) -> Dict[str, Any]:
    ke, kl, ko = jax.random.split(key, 3)
    params: Dict[str, Any] = {"embed": C.embed_init(ke, cfg.vocab_size,
                                                    cfg.d_model)}
    if cfg.is_encdec:
        k1, k2 = jax.random.split(kl)
        params["enc"] = _stacked(lambda k: _attn_block_init(k, cfg), k1,
                                 cfg.encoder_layers)
        params["dec"] = _stacked(lambda k: _attn_block_init(k, cfg, True),
                                 k2, cfg.n_layers)
        params["enc_ln"] = C.rmsnorm_init(cfg.d_model)
    elif cfg.family == "ssm":
        params["layers"] = _stacked(lambda k: _rwkv_block_init(k, cfg), kl,
                                    cfg.n_layers)
    elif cfg.block_pattern:
        n_periods, rem = _hybrid_layout(cfg)

        def period_init(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"rec1": _rec_block_init(k1, cfg),
                    "rec2": _rec_block_init(k2, cfg),
                    "attn": _attn_block_init(k3, cfg)}

        params["periods"] = _stacked(period_init, kl, n_periods)
        kr = jax.random.split(ko, max(1, len(rem)))
        params["rem"] = [_rec_block_init(kr[i], cfg)
                         for i, kind in enumerate(rem)]
    elif cfg.is_moe:
        params["layers"] = _stacked(lambda k: _moe_block_init(k, cfg), kl,
                                    cfg.n_layers)
    else:
        params["layers"] = _stacked(lambda k: _attn_block_init(k, cfg), kl,
                                    cfg.n_layers)
    params["ln_f"] = C.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = C.linear_init(ko, cfg.d_model, cfg.vocab_size)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def _loop(body, carry, stacked, unroll: bool):
    """scan-over-layers, or an unrolled python loop in analysis mode
    (XLA cost_analysis visits while bodies once; unrolling makes the
    dry-run FLOP/byte counts exact).  body: (carry, lp) -> (carry, None)."""
    if not unroll:
        return jax.lax.scan(jax.checkpoint(body), carry, stacked)[0]
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    for i in range(n):
        lp = jax.tree_util.tree_map(lambda t: t[i], stacked)
        carry, _ = jax.checkpoint(body)(carry, lp)
    return carry


def _loop_ys(body, carry, xs, unroll: bool):
    """Like _loop but collects per-layer outputs (decode cache updates)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree_util.tree_map(lambda t: t[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(params, cfg: LMConfig, tokens: jax.Array) -> jax.Array:
    x = params["embed"]["e"][tokens]
    x = (x * jnp.sqrt(float(cfg.d_model))).astype(cfg.compute_dtype)
    return shard(x, "batch", "seq_res", "embed")


def _unembed(params, cfg: LMConfig, x: jax.Array, policy: Policy):
    x = C.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = EG.gemm(x, params["embed"]["e"].T.astype(x.dtype), policy,
                         path="lm_head")
    else:
        logits = C.linear(params["lm_head"], x, policy, path="lm_head")
    return shard(logits, "batch", "seq", "vocab")


def forward(params, cfg: LMConfig, tokens: jax.Array,
            positions: Optional[jax.Array] = None,
            enc_feats: Optional[jax.Array] = None,
            policy: Policy = None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits [B,S,V], aux_loss scalar).

    enc_feats: [B, S_enc, D] precomputed frame/patch embeddings (audio/vlm
    stub frontends).  For vlm they are prepended positions in the sequence
    are assumed already accounted for in ``positions``.
    """
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    x = _embed(params, cfg, tokens)
    aux = jnp.zeros((), jnp.float32)

    if cfg.is_encdec:
        enc = enc_feats if enc_feats is not None else jnp.zeros(
            (b, cfg.enc_seq_stub, cfg.d_model), x.dtype)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc.shape[1], dtype=jnp.int32)[None], enc.shape[:2])

        def enc_layer(h, lp):
            h = C.attention(lp["attn"], cfg,
                            C.rmsnorm(lp["ln1"], h, cfg.norm_eps),
                            enc_pos, policy, causal=False,
                            path="enc/attn") + h
            h = h + C.swiglu(lp["ffn"], C.rmsnorm(lp["ln2"], h, cfg.norm_eps),
                             policy, path="enc/ffn")
            return shard(h, "batch", "seq_res", "embed"), None

        enc = _loop(enc_layer, enc, params["enc"], cfg.analysis_unroll)
        enc = C.rmsnorm(params["enc_ln"], enc, cfg.norm_eps)

        def dec_layer(h, lp):
            return _attn_block(lp, cfg, h, positions, policy, enc=enc), None

        x = _loop(dec_layer, x, params["dec"], cfg.analysis_unroll)
        return _unembed(params, cfg, x, policy), aux

    if cfg.family == "ssm":
        def layer(h, lp):
            return _rwkv_block(lp, cfg, h, policy), None
        x = _loop(layer, x, params["layers"], cfg.analysis_unroll)
        return _unembed(params, cfg, x, policy), aux

    if cfg.block_pattern:
        def period(h, lp):
            h, _ = _rec_block(lp["rec1"], cfg, h, policy)
            h, _ = _rec_block(lp["rec2"], cfg, h, policy)
            h = _attn_block(lp["attn"], cfg, h, positions, policy)
            return h, None
        x = _loop(period, x, params["periods"], cfg.analysis_unroll)
        for rp in params["rem"]:
            x, _ = _rec_block(rp, cfg, x, policy)
        return _unembed(params, cfg, x, policy), aux

    if cfg.is_moe:
        def layer(carry, lp):
            h, a = carry
            h, aux_l = _moe_block(lp, cfg, h, positions, policy)
            return (h, a + aux_l), None
        x, aux = _loop(layer, (x, aux), params["layers"],
                       cfg.analysis_unroll)
        aux = aux / cfg.n_layers
        return _unembed(params, cfg, x, policy), aux

    def layer(h, lp):
        return _attn_block(lp, cfg, h, positions, policy), None
    x = _loop(layer, x, params["layers"], cfg.analysis_unroll)
    return _unembed(params, cfg, x, policy), aux


# ---------------------------------------------------------------------------
# decode (KV cache / recurrent state)
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Decode cache.  Attention KV buffers are ring buffers of size
    min(max_len, sliding_window) (vLLM-style for SWA); recurrent families
    carry constant-size states."""
    t = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    hk, dh, d = cfg.n_kv_heads, cfg.dh, cfg.d_model
    kv = lambda n: {"k": jnp.zeros((n, batch, t, hk, dh), dtype),
                    "v": jnp.zeros((n, batch, t, hk, dh), dtype)}
    if cfg.is_encdec:
        return {"self": kv(cfg.n_layers), "enc_out": None}  # enc set at prefill
    if cfg.family == "ssm":
        h = cfg.n_heads
        return {"x_att": jnp.zeros((cfg.n_layers, batch, d), jnp.float32),
                "x_ffn": jnp.zeros((cfg.n_layers, batch, d), jnp.float32),
                "S": jnp.zeros((cfg.n_layers, batch, h, dh, dh), jnp.float32)}
    if cfg.block_pattern:
        n_periods, rem = _hybrid_layout(cfg)
        lw = cfg.lru_width or d
        w = cfg.conv_width
        rec = lambda n: {"h": jnp.zeros((n, batch, lw), jnp.float32),
                         "hist": jnp.zeros((n, batch, w - 1, lw), dtype)}
        return {"rec1": rec(n_periods), "rec2": rec(n_periods),
                "attn": kv(n_periods),
                "rem": rec(len(rem))}
    return kv(cfg.n_layers)


def decode_step(params, cfg: LMConfig, cache, tokens: jax.Array,
                pos: jax.Array, policy: Policy = None,
                ) -> Tuple[jax.Array, Any]:
    """One decode step.  tokens: [B, 1]; pos: scalar int32 (current index).

    Returns (logits [B, 1, V], updated cache).
    """
    x = _embed(params, cfg, tokens)
    b = tokens.shape[0]

    if cfg.is_encdec:
        enc = cache["enc_out"]

        def layer(h, xs):
            lp, kc, vc = xs
            y, k2, v2 = C.attention_decode(
                lp["attn"], cfg, C.rmsnorm(lp["ln1"], h, cfg.norm_eps), pos,
                kc, vc, policy, path="attn")
            h = h + y
            h = h + C.attention(lp["xattn"], cfg,
                                C.rmsnorm(lp["lnx"], h, cfg.norm_eps),
                                jnp.full((b, 1), pos, jnp.int32), policy,
                                xkv=enc, path="xattn")
            h = h + C.swiglu(lp["ffn"], C.rmsnorm(lp["ln2"], h, cfg.norm_eps),
                             policy, path="ffn")
            return h, (k2, v2)

        x, (ks, vs) = _loop_ys(
            layer, x, (params["dec"], cache["self"]["k"],
                       cache["self"]["v"]), cfg.analysis_unroll)
        cache = dict(cache, **{"self": {"k": ks, "v": vs}})
        return _unembed(params, cfg, x, policy), cache

    if cfg.family == "ssm":
        def layer(h, xs):
            lp, xa, xf, S = xs
            y, (xa2, S2) = R.time_mix_decode(
                lp["tm"], cfg, C.rmsnorm(lp["ln1"], h, cfg.norm_eps), (xa, S))
            h = h + y
            y, xf2 = R.channel_mix_decode(
                lp["cm"], cfg, C.rmsnorm(lp["ln2"], h, cfg.norm_eps), xf)
            return h + y, (xa2, xf2, S2)

        x, (xa, xf, S) = _loop_ys(
            layer, x, (params["layers"], cache["x_att"], cache["x_ffn"],
                       cache["S"]), cfg.analysis_unroll)
        return _unembed(params, cfg, x, policy), \
            {"x_att": xa, "x_ffn": xf, "S": S}

    if cfg.block_pattern:
        def rec_step(lp, h, st):
            y, st2 = G.rglru_block_decode(
                lp["rec"], cfg, C.rmsnorm(lp["ln1"], h, cfg.norm_eps),
                st, policy)
            h = h + y
            h = h + C.swiglu(lp["ffn"], C.rmsnorm(lp["ln2"], h, cfg.norm_eps),
                             policy, path="ffn")
            return h, st2

        def period(h, xs):
            lp, r1h, r1x, r2h, r2x, kc, vc = xs
            h, (r1h2, r1x2) = rec_step(lp["rec1"], h, (r1h, r1x))
            h, (r2h2, r2x2) = rec_step(lp["rec2"], h, (r2h, r2x))
            y, k2, v2 = C.attention_decode(
                lp["attn"]["attn"], cfg,
                C.rmsnorm(lp["attn"]["ln1"], h, cfg.norm_eps), pos, kc, vc,
                policy, path="attn")
            h = h + y
            h = h + C.swiglu(lp["attn"]["ffn"],
                             C.rmsnorm(lp["attn"]["ln2"], h, cfg.norm_eps),
                             policy, path="ffn")
            return h, (r1h2, r1x2, r2h2, r2x2, k2, v2)

        x, (r1h, r1x, r2h, r2x, ks, vs) = _loop_ys(
            period, x,
            (params["periods"], cache["rec1"]["h"], cache["rec1"]["hist"],
             cache["rec2"]["h"], cache["rec2"]["hist"],
             cache["attn"]["k"], cache["attn"]["v"]), cfg.analysis_unroll)
        rem_h, rem_hist = [], []
        for i, rp in enumerate(params["rem"]):
            x, (h2, hist2) = rec_step(
                rp, x, (cache["rem"]["h"][i], cache["rem"]["hist"][i]))
            rem_h.append(h2)
            rem_hist.append(hist2)
        new_cache = {"rec1": {"h": r1h, "hist": r1x},
                     "rec2": {"h": r2h, "hist": r2x},
                     "attn": {"k": ks, "v": vs},
                     "rem": {"h": jnp.stack(rem_h) if rem_h else cache["rem"]["h"],
                             "hist": jnp.stack(rem_hist) if rem_hist else cache["rem"]["hist"]}}
        return _unembed(params, cfg, x, policy), new_cache

    # dense / vlm / moe
    def layer(h, xs):
        lp, kc, vc = xs
        y, k2, v2 = C.attention_decode(
            lp["attn"], cfg, C.rmsnorm(lp["ln1"], h, cfg.norm_eps), pos,
            kc, vc, policy, path="attn")
        h = h + y
        if cfg.is_moe:
            y, _ = M.moe_apply(lp["moe"], cfg,
                               C.rmsnorm(lp["ln2"], h, cfg.norm_eps), policy)
        else:
            y = C.swiglu(lp["ffn"], C.rmsnorm(lp["ln2"], h, cfg.norm_eps),
                         policy, path="ffn")
        return h + y, (k2, v2)

    x, (ks, vs) = _loop_ys(layer, x,
                           (params["layers"], cache["k"], cache["v"]),
                           cfg.analysis_unroll)
    return _unembed(params, cfg, x, policy), {"k": ks, "v": vs}


def prefill_encoder(params, cfg: LMConfig, enc_feats: jax.Array,
                    policy: Policy = None) -> jax.Array:
    """Run the encoder once (enc-dec serving); result goes into the cache."""
    enc_pos = jnp.broadcast_to(
        jnp.arange(enc_feats.shape[1], dtype=jnp.int32)[None],
        enc_feats.shape[:2])

    def enc_layer(h, lp):
        h = C.attention(lp["attn"], cfg, C.rmsnorm(lp["ln1"], h, cfg.norm_eps),
                        enc_pos, policy, causal=False, path="enc/attn") + h
        h = h + C.swiglu(lp["ffn"], C.rmsnorm(lp["ln2"], h, cfg.norm_eps),
                         policy, path="enc/ffn")
        return h, None

    enc, _ = jax.lax.scan(enc_layer, enc_feats, params["enc"])
    return C.rmsnorm(params["enc_ln"], enc, cfg.norm_eps)
