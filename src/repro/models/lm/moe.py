"""Mixture-of-Experts layer (mixtral 8e top-2, olmoe 64e top-8).

Sort-based capacity dispatch (production pjit MoE):

  1. router top-k per token,
  2. sort (token, k) slots by expert id, position-in-expert by running
     offset, drop beyond capacity C = ceil(T*K/E * capacity_factor),
  3. gather into [E, C, D], batched expert GEMMs (einsum 'ecd,edf->ecf' —
     shardable over E = expert parallelism, or over f = TP inside experts),
  4. weighted combine back to [T, D].

Expert GEMMs route through bfp_dot semantics: the per-expert weights are
BFP-formatted per (column, K-tile) exactly like dense layers (each expert
is its own weight matrix -> its own row exponents, DESIGN.md §4).
Returns the load-balancing auxiliary loss alongside the output.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.configs.base import LMConfig
from repro.core.prequant import dequantize_prequant, is_prequant
from repro.dist.sharding import shard
from repro.models.lm.common import linear_init

Policy = EG.PolicyLike


def moe_init(key, cfg: LMConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = jnp.sqrt(1.0 / d)
    return {
        "router": linear_init(ks[0], d, e),
        "w1": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        "w3": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
        "w2": jax.random.normal(ks[3], (e, f, d), jnp.float32) * jnp.sqrt(1.0 / f),
    }


def _expert_gemm(xe: jax.Array, we, policy) -> jax.Array:
    """[E, C, d_in] x [E, d_in, d_out] -> [E, C, d_out], BFP per expert.

    ``policy`` is a concrete BFPPolicy or None here (moe_apply resolves
    PolicyMaps first).  ``we`` may be the prequant wire format with a
    leading expert dim ({"m": [E, d_in, d_out], "s": [E, d_in/bk,
    d_out]}); the vmapped emulated datapath consumes the sidecar directly.
    """
    if policy is None:
        if is_prequant(we):
            return jnp.einsum("ecd,edf->ecf", xe,
                              dequantize_prequant(we, xe.dtype))
        return jnp.einsum("ecd,edf->ecf", xe, we.astype(xe.dtype))
    # vmap the BFP GEMM over experts: each expert's matrix gets its own
    # block exponents (same contract as a dense layer).
    from repro.core.bfp_dot import bfp_matmul_2d, bfp_matmul_2d_prequant
    if is_prequant(we):
        return jax.vmap(
            lambda a, m, s: bfp_matmul_2d_prequant(a, m, s, policy)
        )(xe, we["m"], we["s"])
    return jax.vmap(lambda a, w: bfp_matmul_2d(a, w, policy))(xe, we)


def moe_apply(p, cfg: LMConfig, x: jax.Array, policy: Policy = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    # Resolve per-layer maps once for the expert GEMMs (path "moe"); the
    # router always runs in float regardless of policy.
    policy = EG.resolve_policy(policy, "moe")
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = EG.gemm(xt, p["router"]["w"], None)        # router in float
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # [T, E]
    gate_vals, expert_ids = jax.lax.top_k(probs, k)               # [T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # load-balance aux loss (Switch): E * sum_e fraction_e * prob_e
    density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], e), axis=0)
    prob_mean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * prob_mean)

    cap = int(t * k / e * cfg.capacity_factor + 1)

    # ---- sort-based dispatch ------------------------------------------------
    flat_expert = expert_ids.reshape(-1)                 # [T*K]
    flat_token = jnp.repeat(jnp.arange(t), k)            # [T*K]
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)                     # stable
    sorted_e = flat_expert[order]
    sorted_tok = flat_token[order]
    sorted_gate = flat_gate[order]
    # position within expert segment
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # drop bucket

    # gather tokens into expert buffers [E*C+1, D] (last row = drop bucket)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(xt[sorted_tok])
    xe = buf[:-1].reshape(e, cap, d)
    xe = shard(xe, "experts", None, None)

    # ---- expert FFN (SwiGLU) -------------------------------------------------
    h = jax.nn.silu(_expert_gemm(xe, p["w1"], policy)) * \
        _expert_gemm(xe, p["w3"], policy)
    h = shard(h, "experts", None, "ffn")
    ye = _expert_gemm(h, p["w2"], policy)                # [E, C, D]

    # ---- combine ---------------------------------------------------------------
    yflat = ye.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None], yflat[jnp.minimum(slot, e * cap - 1)],
                        0.0) * sorted_gate[:, None]
    out = jnp.zeros((t, d), x.dtype).at[sorted_tok].add(
        contrib.astype(x.dtype))
    return out.reshape(b, s, d), aux
