"""Model zoo: paper-faithful CNNs + the 10 assigned LM architectures."""
