"""VGG-16 (Simonyan & Zisserman 2014) — the paper's main analysis vehicle.

Declarative sequential spec so the NSR analysis driver (paper Table 4) can
walk layer-by-layer.  ``width_mult``/``input_hw`` let tests run a reduced
config of the same family.  Convs execute through ``engine.conv2d`` —
the fused implicit-im2col Pallas kernel on the pallas backend (no
materialized patch matrix; benchmarks/conv_bench.py models the HBM cut
on exactly these layer shapes).
"""
from __future__ import annotations

from typing import List, Tuple

import jax

from repro.engine import PolicyLike
from repro.models.cnn import layers as L

# (type, *args): ("conv", name, out_ch) stride-1 SAME 3x3 / ("pool",) 2x2
# max / ("dense", name, out_dim) / ("flatten",) — ReLU after every conv and
# the first two dense layers, exactly VGG-16.
VGG16_CONV_PLAN: List[Tuple[str, int]] = [
    ("conv1_1", 64), ("conv1_2", 64), ("pool", 0),
    ("conv2_1", 128), ("conv2_2", 128), ("pool", 0),
    ("conv3_1", 256), ("conv3_2", 256), ("conv3_3", 256), ("pool", 0),
    ("conv4_1", 512), ("conv4_2", 512), ("conv4_3", 512), ("pool", 0),
    ("conv5_1", 512), ("conv5_2", 512), ("conv5_3", 512), ("pool", 0),
]


def init(key, num_classes: int = 1000, in_ch: int = 3,
         width_mult: float = 1.0, input_hw: int = 224,
         fc_dim: int = 4096):
    params = {}
    ch = in_ch
    hw = input_hw
    for name, out in VGG16_CONV_PLAN:
        if name == "pool":
            hw //= 2
            continue
        out = max(8, int(out * width_mult))
        key, sub = jax.random.split(key)
        params[name] = L.conv2d_init(sub, ch, out, 3, 3)
        ch = out
    flat = ch * hw * hw
    key, k1, k2, k3 = jax.random.split(key, 4)
    params["fc6"] = L.dense_init(k1, flat, fc_dim)
    params["fc7"] = L.dense_init(k2, fc_dim, fc_dim)
    params["fc8"] = L.dense_init(k3, fc_dim, num_classes)
    return params


def apply(params, x: jax.Array, policy: PolicyLike = None) -> jax.Array:
    """Layer paths are the plan names ("conv1_1" ... "fc8"), so a
    PolicyMap rule like ("^conv1_1$", None) pins the first conv to float
    (paper Table-3 layer-wise experiments); ``engine.bind(params, pm)``
    binds the same paths once and rides this argument as a Plan."""
    for name, _ in VGG16_CONV_PLAN:
        if name == "pool":
            x = L.max_pool(x)
        else:
            x = L.relu(L.conv2d(params[name], x, 1, "SAME", policy,
                                path=name))
    x = x.reshape(x.shape[0], -1)
    x = L.relu(L.dense(params["fc6"], x, policy, path="fc6"))
    x = L.relu(L.dense(params["fc7"], x, policy, path="fc7"))
    return L.dense(params["fc8"], x, policy, path="fc8")


def conv_names() -> List[str]:
    return [n for n, _ in VGG16_CONV_PLAN if n != "pool"]
