"""CNN layers with the BFP datapath (paper §3.2-3.4).

Convolution is the paper's matrix form ``O = I @ W`` (Fig. 1), executed
by :func:`repro.engine.conv2d`: on the pallas backend that is the fused
implicit-im2col kernel — receptive-field rows are formed in VMEM, the
patch matrix never hits HBM — and on every other backend/scheme the
engine falls back to materialized :func:`im2col` + ``engine.gemm``
(identical numerics; tests assert the two routes agree bit-exactly for
Scheme.TILED).  ``policy=None`` gives the float reference path; a
``repro.engine.PolicyMap`` resolves a per-layer policy against the
layer's ``path`` (paper Table-3 layer-wise assignments); a bound
``repro.engine.Plan`` (from ``engine.bind(params, policy)``) rides the
same argument with resolution + backend selection already done — apply
the model to ``plan.params`` and pass the plan as ``policy``.  Weights
may be pre-quantized to the ``{"m", "s"}`` wire format
(``repro.engine.prequantize_cnn``, or ``bind`` does it): every backend —
including the sidecar-consuming fused conv kernel — consumes it
directly, so inference skips per-forward weight re-quantization.

Parameters are plain pytrees (dicts); every layer is a pure function.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.core.conv_utils import im2col  # re-export: the shared helper
from repro.engine import PolicyLike

__all__ = ["conv2d_init", "conv2d", "im2col", "dense_init", "dense",
           "batchnorm_init", "batchnorm", "max_pool", "avg_pool",
           "global_avg_pool", "relu"]


def _he_init(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------------
# Convolution as matrix multiplication (paper Fig. 1)
# ---------------------------------------------------------------------------

def conv2d_init(key, in_ch: int, out_ch: int, kh: int, kw: int):
    """Weights stored HWIO [kh, kw, in_ch, out_ch]; the GEMM view (paper
    W^T: each column is one filter == one paper W row) is taken inside
    conv2d.  Shape info lives in the array shape (jit-static)."""
    k = kh * kw * in_ch
    wkey, bkey = jax.random.split(key)
    return {
        "w": _he_init(wkey, (kh, kw, in_ch, out_ch), k),
        "b": jnp.zeros((out_ch,), jnp.float32),
    }


def conv2d(params, x: jax.Array, stride: int = 1, padding: str = "SAME",
           policy: PolicyLike = None,
           path: Optional[str] = None) -> jax.Array:
    """BFP convolution through :func:`repro.engine.conv2d`.  x: NHWC.

    ``params["w"]`` is an HWIO float kernel or its prequant form (int8
    HWIO mantissa + GEMM-view scale sidecar); the engine picks the fused
    implicit-im2col kernel or the materialized-im2col GEMM route per
    backend/policy.
    """
    return EG.conv2d(x, params["w"], policy, stride=stride,
                     padding=padding, path=path) + params["b"]


# ---------------------------------------------------------------------------
# Dense / norm / pooling
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int):
    wkey, _ = jax.random.split(key)
    return {"w": _he_init(wkey, (in_dim, out_dim), in_dim),
            "b": jnp.zeros((out_dim,), jnp.float32)}


def dense(params, x: jax.Array, policy: PolicyLike = None,
          path: Optional[str] = None) -> jax.Array:
    return EG.gemm(x, params["w"], policy, path=path) + params["b"]


def batchnorm_init(ch: int):
    return {"gamma": jnp.ones((ch,), jnp.float32),
            "beta": jnp.zeros((ch,), jnp.float32),
            "mean": jnp.zeros((ch,), jnp.float32),
            "var": jnp.ones((ch,), jnp.float32)}


def batchnorm(params, x: jax.Array, training: bool = False,
              eps: float = 1e-5):
    """Inference-mode BN (paper setting: deployed models, no retraining).

    In training mode uses batch statistics (no running-average state
    threading — the small CNNs trained in-repo use this path).
    """
    if training:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axes)
        var = jnp.var(x, axes)
    else:
        mean, var = params["mean"], params["var"]
    inv = jax.lax.rsqrt(var + eps) * params["gamma"]
    return x * inv + (params["beta"] - mean * inv)


def max_pool(x: jax.Array, window: int = 2, stride: int = 2,
             padding: str = "VALID") -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), padding)


def avg_pool(x: jax.Array, window: int, stride: int,
             padding: str = "VALID") -> jax.Array:
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1),
        (1, stride, stride, 1), padding)
    return s / (window * window)


def global_avg_pool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


relu = jax.nn.relu
