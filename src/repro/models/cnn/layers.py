"""CNN layers with the BFP datapath (paper §3.2-3.4).

Convolution is expressed as the paper's matrix form: im2col expands
receptive fields into rows of an input matrix I, the kernels form W, and
``O = I @ W`` runs through :func:`repro.engine.gemm` — block formatting +
fixed-point MAC, exactly the paper's Fig. 2 pipeline.  ``policy=None``
gives the float reference path; a ``repro.engine.PolicyMap`` resolves a
per-layer policy against the layer's ``path`` (paper Table-3 layer-wise
assignments).  Weights may be pre-quantized to the ``{"m", "s"}`` wire
format (``repro.engine.prequantize_cnn``): the engine consumes it on
every backend, so inference skips per-forward weight re-quantization.

Parameters are plain pytrees (dicts); every layer is a pure function.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.engine import PolicyLike

__all__ = ["conv2d_init", "conv2d", "im2col", "dense_init", "dense",
           "batchnorm_init", "batchnorm", "max_pool", "avg_pool",
           "global_avg_pool", "relu"]


def _he_init(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


# ---------------------------------------------------------------------------
# Convolution as matrix multiplication (paper Fig. 1)
# ---------------------------------------------------------------------------

def conv2d_init(key, in_ch: int, out_ch: int, kh: int, kw: int):
    """Weights stored HWIO [kh, kw, in_ch, out_ch]; the GEMM view (paper
    W^T: each column is one filter == one paper W row) is taken inside
    conv2d.  Shape info lives in the array shape (jit-static)."""
    k = kh * kw * in_ch
    wkey, bkey = jax.random.split(key)
    return {
        "w": _he_init(wkey, (kh, kw, in_ch, out_ch), k),
        "b": jnp.zeros((out_ch,), jnp.float32),
    }


def im2col(x: jax.Array, kh: int, kw: int, stride: int,
           padding: str) -> Tuple[jax.Array, Tuple[int, int, int]]:
    """NHWC -> patch matrix [B*OH*OW, kh*kw*C] (receptive fields as rows).

    This is the paper's I matrix (transposed to NN orientation): row n is
    the n-th receptive field, matching bfp_dot's per-row activation blocks.
    """
    b = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    oh, ow = patches.shape[1], patches.shape[2]
    # conv_general_dilated_patches yields features ordered as C*kh*kw
    # (channel-major); weight layout below matches it.
    return patches.reshape(b * oh * ow, -1), (b, oh, ow)


def conv2d(params, x: jax.Array, stride: int = 1, padding: str = "SAME",
           policy: PolicyLike = None,
           path: Optional[str] = None) -> jax.Array:
    """BFP convolution via im2col GEMM.  x: NHWC float.

    ``params["w"]`` is an HWIO float kernel or its prequant form (int8
    HWIO mantissa + GEMM-view scale sidecar); for prequant only the cheap
    int8 transpose into the GEMM view runs per forward — the float
    quantization happened once, offline.
    """
    w = params["w"]
    prequant = EG.is_prequant(w)
    kh, kw, in_ch, out_ch = (w["m"] if prequant else w).shape
    cols, (b, oh, ow) = im2col(x, kh, kw, stride, padding)
    # patches come out channel-major (C, kh, kw) -> match weight row order
    if prequant:
        wmat = {"m": jnp.transpose(w["m"], (2, 0, 1, 3)).reshape(
            in_ch * kh * kw, out_ch), "s": w["s"]}
    else:
        wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(
            in_ch * kh * kw, out_ch)
    out = EG.gemm(cols, wmat, policy, path=path) + params["b"]
    return out.reshape(b, oh, ow, out_ch)


# ---------------------------------------------------------------------------
# Dense / norm / pooling
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int):
    wkey, _ = jax.random.split(key)
    return {"w": _he_init(wkey, (in_dim, out_dim), in_dim),
            "b": jnp.zeros((out_dim,), jnp.float32)}


def dense(params, x: jax.Array, policy: PolicyLike = None,
          path: Optional[str] = None) -> jax.Array:
    return EG.gemm(x, params["w"], policy, path=path) + params["b"]


def batchnorm_init(ch: int):
    return {"gamma": jnp.ones((ch,), jnp.float32),
            "beta": jnp.zeros((ch,), jnp.float32),
            "mean": jnp.zeros((ch,), jnp.float32),
            "var": jnp.ones((ch,), jnp.float32)}


def batchnorm(params, x: jax.Array, training: bool = False,
              eps: float = 1e-5):
    """Inference-mode BN (paper setting: deployed models, no retraining).

    In training mode uses batch statistics (no running-average state
    threading — the small CNNs trained in-repo use this path).
    """
    if training:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axes)
        var = jnp.var(x, axes)
    else:
        mean, var = params["mean"], params["var"]
    inv = jax.lax.rsqrt(var + eps) * params["gamma"]
    return x * inv + (params["beta"] - mean * inv)


def max_pool(x: jax.Array, window: int = 2, stride: int = 2,
             padding: str = "VALID") -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), padding)


def avg_pool(x: jax.Array, window: int, stride: int,
             padding: str = "VALID") -> jax.Array:
    s = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1),
        (1, stride, stride, 1), padding)
    return s / (window * window)


def global_avg_pool(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2))


relu = jax.nn.relu
