"""Small trainable CNNs — the paper's "mnist" and "cifar10" columns.

LeNet-5-style for 28x28x1 and CIFAR-quick for 32x32x3; both train to high
accuracy on the in-repo synthetic datasets in seconds on CPU, which is how
the Table-3-style accuracy-drop sweeps are produced without ILSVRC12
(DESIGN.md §8.1).  Layer paths ("c1", "c2", ..., "fc1", "fc2") feed
PolicyMap per-layer rules.  Convs run through ``engine.conv2d`` (fused
implicit-im2col on the pallas backend, im2col+GEMM otherwise)."""
from __future__ import annotations

import jax

from repro.engine import PolicyLike
from repro.models.cnn import layers as L


def lenet_init(key, num_classes: int = 10, in_ch: int = 1):
    k = jax.random.split(key, 4)
    return {"c1": L.conv2d_init(k[0], in_ch, 16, 5, 5),
            "c2": L.conv2d_init(k[1], 16, 32, 5, 5),
            "fc1": L.dense_init(k[2], 32 * 7 * 7, 128),
            "fc2": L.dense_init(k[3], 128, num_classes)}


def lenet_apply(params, x, policy: PolicyLike = None):
    x = L.relu(L.conv2d(params["c1"], x, 1, "SAME", policy, path="c1"))
    x = L.max_pool(x)
    x = L.relu(L.conv2d(params["c2"], x, 1, "SAME", policy, path="c2"))
    x = L.max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = L.relu(L.dense(params["fc1"], x, policy, path="fc1"))
    return L.dense(params["fc2"], x, policy, path="fc2")


def cifarnet_init(key, num_classes: int = 10, in_ch: int = 3):
    k = jax.random.split(key, 5)
    return {"c1": L.conv2d_init(k[0], in_ch, 32, 3, 3),
            "c2": L.conv2d_init(k[1], 32, 64, 3, 3),
            "c3": L.conv2d_init(k[2], 64, 128, 3, 3),
            "fc1": L.dense_init(k[3], 128 * 4 * 4, 256),
            "fc2": L.dense_init(k[4], 256, num_classes)}


def cifarnet_apply(params, x, policy: PolicyLike = None):
    x = L.relu(L.conv2d(params["c1"], x, 1, "SAME", policy, path="c1"))
    x = L.max_pool(x)
    x = L.relu(L.conv2d(params["c2"], x, 1, "SAME", policy, path="c2"))
    x = L.max_pool(x)
    x = L.relu(L.conv2d(params["c3"], x, 1, "SAME", policy, path="c3"))
    x = L.max_pool(x)
    x = x.reshape(x.shape[0], -1)
    x = L.relu(L.dense(params["fc1"], x, policy, path="fc1"))
    return L.dense(params["fc2"], x, policy, path="fc2")
