"""Paper Table 4 — per-layer SNR validation on VGG-style sequential CNNs.

Runs the float reference and the BFP path side by side through the conv
stack, measuring per-layer input/weight/output SNR and comparing against
the single-layer (eq. 18) and multi-layer (eq. 19-20) analytical models.
ReLU and pooling are traversed exactly as the paper does: ReLU is
SNR-neutral, pooling output SNR feeds the next layer.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import nsr
from repro.core.bfp_dot import bfp_matmul_2d
from repro.core.policy import BFPPolicy
from repro.models.cnn import layers as L
from repro.models.cnn import vgg

__all__ = ["LayerRow", "analyze_vgg"]


@dataclasses.dataclass
class LayerRow:
    """One conv layer's row of the paper's Table 4 (SNRs in dB)."""
    name: str
    input_ex: float       # experimental input SNR
    input_single: float   # single-layer model
    input_multi: float    # multi-layer model
    weight_ex: float
    weight_model: float
    output_ex: float
    output_single: float
    output_multi: float
    relu_ex: float        # SNR after ReLU (paper: ~= output SNR)


def _conv_as_matrices(params, x, name):
    from repro.core.conv_utils import conv_weight_matrix
    kh, kw, _, out_ch = params[name]["w"].shape
    cols, (b, oh, ow) = L.im2col(x, kh, kw, 1, "SAME")
    w = conv_weight_matrix(params[name]["w"])
    return cols, w, params[name]["b"], (b, oh, ow, out_ch)


def analyze_vgg(params, x: jax.Array, policy: BFPPolicy,
                max_layers: Optional[int] = None) -> List[LayerRow]:
    """Dual-path (float / BFP) walk over the VGG conv stack."""
    policy = policy.with_(straight_through=False)
    rows: List[LayerRow] = []
    x_f = x.astype(jnp.float32)
    x_q = x.astype(jnp.float32)
    eta_multi = 0.0
    done = 0
    for name, _ in vgg.VGG16_CONV_PLAN:
        if name == "pool":
            x_f, x_q = L.max_pool(x_f), L.max_pool(x_q)
            continue
        if max_layers is not None and done >= max_layers:
            break
        cols_f, w, b, oshape = _conv_as_matrices(params, x_f, name)
        cols_q, _, _, _ = _conv_as_matrices(params, x_q, name)

        # --- input SNRs ----------------------------------------------------
        from repro.core.bfp_dot import quantize_activations
        in_fmt = quantize_activations(cols_q, policy).dequantize()
        input_ex = float(nsr.snr_db(cols_f, in_fmt))
        input_single = float(nsr.predict_matrix_snr(cols_f, policy.l_i, "i",
                                                    policy))
        eta_fresh = float(nsr.nsr_from_snr_db(
            nsr.predict_matrix_snr(cols_q, policy.l_i, "i", policy)))
        eta_in_multi = float(nsr.chain_input_nsr(eta_multi, eta_fresh))
        input_multi = float(nsr.snr_db_from_nsr(jnp.asarray(eta_in_multi)))

        # --- weight SNRs ---------------------------------------------------
        weight_ex = float(nsr.measure_matrix_snr(w, policy.l_w, "w", policy))
        weight_model = float(nsr.predict_matrix_snr(w, policy.l_w, "w",
                                                    policy))
        eta_w = float(nsr.nsr_from_snr_db(weight_model))

        # --- conv outputs ----------------------------------------------------
        y_f = (cols_f @ w + b).reshape(oshape)
        y_q = (bfp_matmul_2d(cols_q, w, policy) + b).reshape(oshape)
        output_ex = float(nsr.snr_db(y_f, y_q))
        output_single = float(nsr.single_layer_output_snr(
            jnp.asarray(input_single), jnp.asarray(weight_model)))
        eta_out_multi = eta_in_multi + eta_w
        output_multi = float(nsr.snr_db_from_nsr(jnp.asarray(eta_out_multi)))

        # --- ReLU (paper: SNR-neutral check) --------------------------------
        r_f, r_q = L.relu(y_f), L.relu(y_q)
        relu_ex = float(nsr.snr_db(r_f, r_q))

        rows.append(LayerRow(name, input_ex, input_single, input_multi,
                             weight_ex, weight_model, output_ex,
                             output_single, output_multi, relu_ex))
        x_f, x_q = r_f, r_q
        eta_multi = eta_out_multi
        done += 1
    return rows
