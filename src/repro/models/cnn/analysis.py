"""Paper Table 4 — per-layer SNR validation over the REAL datapath.

:func:`analyze_model` runs any model twice — float reference and BFP —
with ``engine.taps`` observing every GEMM/conv site the engine actually
executes, then compares measured input/weight/output SNRs against the
paper's single-layer (eq. 18) and multi-layer (eq. 19-20) analytical
models.  Because the sites come from taps rather than a hand-rolled
walker, this traverses ANY topology the engine runs: sequential VGG,
ResNet residual blocks (projection shortcuts included), GoogLeNet
inception branches and aux heads — the four networks the paper
validates on.

Two inheritance modes for the multi-layer model's eta_1 (inherited NSR):

  * ``"analytic"``  — chain predictions site-by-site in execution order
    (eq. 19-20 exactly as the paper applies it to a sequential CNN;
    :func:`analyze_vgg` uses this and reproduces the pre-tap driver's
    rows bit-for-bit on zero-bias trees);
  * ``"measured"``  — measure eta_1 directly at each site's input from
    the dual runs (the float path and the BFP path are both available,
    so the carried error is observable).  This generalizes eq. 19-20 to
    branch/merge topologies where "the previous layer" is ill-defined:
    residual adds and concats mix inherited NSRs, and the measurement
    captures the mix exactly.

ReLU and pooling are traversed exactly as the paper does, because the
MODEL traverses them: ReLU is SNR-neutral (checked per row), pooling
feeds the next site through the real forward pass.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.core import nsr
from repro.core.bfp_dot import quantize_activations
from repro.core.conv_utils import conv_weight_matrix, im2col
from repro.core.policy import BFPPolicy
from repro.engine import PolicyMap
from repro.models.cnn import vgg

__all__ = ["LayerRow", "SiteRow", "analyze_model", "analyze_vgg"]


@dataclasses.dataclass
class SiteRow:
    """One engine site's row of the paper's Table 4 (SNRs in dB)."""
    path: str
    kind: str             # "gemm" | "conv"
    input_ex: float       # experimental input SNR
    input_single: float   # single-layer model
    input_multi: float    # multi-layer model
    weight_ex: float
    weight_model: float
    output_ex: float
    output_single: float
    output_multi: float
    relu_ex: float        # SNR after ReLU (paper: ~= output SNR)


@dataclasses.dataclass
class LayerRow:
    """Legacy row shape kept for the VGG driver's consumers."""
    name: str
    input_ex: float
    input_single: float
    input_multi: float
    weight_ex: float
    weight_model: float
    output_ex: float
    output_single: float
    output_multi: float
    relu_ex: float


def _no_ste(policy):
    """The analysis measures the inference datapath: no STE grads."""
    if isinstance(policy, BFPPolicy):
        return policy.with_(straight_through=False)
    if isinstance(policy, PolicyMap):
        off = lambda p: None if p is None else p.with_(straight_through=False)
        return PolicyMap(
            rules=tuple((pat, off(p)) for pat, p in policy.rules),
            default=off(policy.default))
    return policy


def _site_matrices(ev: EG.TapEvent):
    """A tapped site in GEMM view: (x2d [rows, K], w [K, N]).

    Conv sites are lowered with the SAME im2col/weight-matrix helpers
    the engine's im2col route uses, so the matrices are bit-identical
    to what the datapath multiplied.
    """
    w = ev.w
    if EG.is_prequant(w):
        raise ValueError(
            "analyze_model needs float weights (the weight-SNR rows "
            "compare quantized vs unquantized); pass the original param "
            "tree, not plan.params / a prequantized tree")
    if ev.kind == "conv":
        kh, kw, _, _ = w.shape
        cols, _ = im2col(ev.x, kh, kw, ev.stride, ev.padding)
        return cols, conv_weight_matrix(w)
    return ev.x.reshape(-1, ev.x.shape[-1]), w


def analyze_model(apply_fn: Callable[[Any, jax.Array, Any], Any],
                  params: Any, x: jax.Array, policy,
                  *, inheritance: str = "measured",
                  max_sites: Optional[int] = None,
                  bias_fn: Optional[Callable[[str],
                                             Optional[jax.Array]]] = None
                  ) -> List[SiteRow]:
    """Dual-run (float / BFP) tap analysis of ``apply_fn``'s datapath.

    ``apply_fn(params, x, policy)`` must execute the model through the
    engine (every in-repo model does); its return value is ignored —
    the engine taps supply the per-site operands.  ``policy`` is a
    BFPPolicy (uniform) or PolicyMap (sites a rule pins to float are
    skipped: there is no quantization to analyze there).  Rows appear
    in execution order.

    ``inheritance`` picks the multi-layer model's eta_1 source:
    "analytic" chains predictions in execution order (sequential
    models, the paper's Table-4 procedure), "measured" reads the
    carried error off the dual runs (any topology).

    Taps fire inside the engine, BEFORE the layer adds its bias, so by
    default output/ReLU SNRs are measured on pre-bias activations
    (identical to post-bias on the zero-bias He-init trees the
    analyses use).  For trained models pass ``bias_fn(path) -> b`` (or
    None for pre-bias sites) and the paper's exact procedure —
    ``snr(y_f + b, y_q + b)``, ReLU on the real activations — is
    restored; :func:`analyze_vgg` does this automatically.
    """
    if inheritance not in ("analytic", "measured"):
        raise ValueError(f"inheritance must be 'analytic' or 'measured', "
                         f"got {inheritance!r}")
    policy = _no_ste(policy)
    ev_f: List[EG.TapEvent] = []
    ev_q: List[EG.TapEvent] = []
    with EG.taps(ev_f.append):
        apply_fn(params, x, None)
    with EG.taps(ev_q.append):
        apply_fn(params, x, policy)
    if len(ev_f) != len(ev_q):
        raise RuntimeError(
            f"float/BFP runs executed different site counts "
            f"({len(ev_f)} vs {len(ev_q)}) — apply_fn must traverse the "
            f"same sites for both policies")

    rows: List[SiteRow] = []
    eta_multi = 0.0  # analytic mode: inherited NSR chained across sites
    for f, q in zip(ev_f, ev_q):
        if f.path != q.path:
            raise RuntimeError(f"site order diverged: {f.path} vs {q.path}")
        pol = q.policy
        if pol is None:
            continue  # float-pinned site: nothing to analyze
        if max_sites is not None and len(rows) >= max_sites:
            break
        cols_f, wmat = _site_matrices(f)
        cols_q, _ = _site_matrices(q)

        # --- input SNRs: measured + single/multi-layer models -------------
        in_fmt = quantize_activations(cols_q, pol).dequantize()
        input_ex = float(nsr.snr_db(cols_f, in_fmt))
        input_single = float(nsr.predict_matrix_snr(cols_f, pol.l_i, "i",
                                                    pol))
        eta_fresh = float(nsr.nsr_from_snr_db(
            nsr.predict_matrix_snr(cols_q, pol.l_i, "i", pol)))
        eta_inherited = (eta_multi if inheritance == "analytic" else
                         float(nsr.nsr_from_snr_db(
                             nsr.snr_db(cols_f, cols_q))))
        eta_in_multi = float(nsr.chain_input_nsr(eta_inherited, eta_fresh))
        input_multi = float(nsr.snr_db_from_nsr(jnp.asarray(eta_in_multi)))

        # --- weight SNRs ---------------------------------------------------
        weight_ex = float(nsr.measure_matrix_snr(wmat, pol.l_w, "w", pol))
        weight_model = float(nsr.predict_matrix_snr(wmat, pol.l_w, "w",
                                                    pol))
        eta_w = float(nsr.nsr_from_snr_db(weight_model))

        # --- outputs: the datapath's own y vs the float run's ------------
        b = bias_fn(f.path) if bias_fn is not None else None
        y_f = f.y if b is None else f.y + b
        y_q = q.y if b is None else q.y + b
        output_ex = float(nsr.snr_db(y_f, y_q))
        output_single = float(nsr.single_layer_output_snr(
            jnp.asarray(input_single), jnp.asarray(weight_model)))
        eta_out_multi = eta_in_multi + eta_w
        output_multi = float(nsr.snr_db_from_nsr(jnp.asarray(eta_out_multi)))

        # --- ReLU (paper §4.4: SNR-neutral check) --------------------------
        relu_ex = float(nsr.snr_db(jax.nn.relu(y_f), jax.nn.relu(y_q)))

        rows.append(SiteRow(f.path or "?", f.kind, input_ex, input_single,
                            input_multi, weight_ex, weight_model, output_ex,
                            output_single, output_multi, relu_ex))
        eta_multi = eta_out_multi
    return rows


def analyze_vgg(params, x: jax.Array, policy: BFPPolicy,
                max_layers: Optional[int] = None) -> List[LayerRow]:
    """The original Table-4 VGG driver, as a thin wrapper over
    :func:`analyze_model` (analytic inheritance, conv rows only, biases
    restored per site — reproducing the pre-tap sequential walker's
    rows exactly, trained or He-init trees alike)."""
    # VGG's conv sites strictly precede its fc sites, so max_sites=
    # max_layers truncates the per-site analysis exactly where the old
    # walker stopped (the forward itself still runs in full — taps
    # can't abort it — but the expensive per-site math does not).
    rows = [r for r in analyze_model(
                vgg.apply, params, x, policy, inheritance="analytic",
                max_sites=max_layers,
                bias_fn=lambda p: params[p]["b"] if p in params else None)
            if r.kind == "conv"]
    return [LayerRow(r.path, r.input_ex, r.input_single, r.input_multi,
                     r.weight_ex, r.weight_model, r.output_ex,
                     r.output_single, r.output_multi, r.relu_ex)
            for r in rows]
