"""CNN zoo with the BFP conv datapath (paper-faithful models).

``MODELS`` registers the paper's four test models (VGG16, ResNet-18/50,
GoogLeNet) plus the small in-repo trainable ones behind a uniform
:class:`CnnSpec` (init / apply / input geometry), so the serving stack
(``serve.cnn`` / ``launch.serve_cnn``) and the benchmarks enumerate them
by name instead of hand-wiring each module.  ``reduced=True`` builds the
tier-1-sized configuration of the same family (identical code paths,
shrunk widths), exactly the shapes the test suite exercises.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

from repro.models.cnn import googlenet as _googlenet
from repro.models.cnn import resnet as _resnet
from repro.models.cnn import small as _small
from repro.models.cnn import vgg as _vgg

__all__ = ["CnnSpec", "MODELS", "head_logits"]


def head_logits(out):
    """Classifier logits from an ``apply()`` output.

    Models with auxiliary heads (GoogLeNet) return a tuple; head 0 is
    the classifier by :class:`CnnSpec` convention.  Single-head models
    return the logits array directly.  Training and evaluation code
    (``repro.train.cnn``, the serve engines) go through this one helper
    so every registered model trains with the same loss plumbing.
    """
    return out[0] if isinstance(out, tuple) else out


@dataclasses.dataclass(frozen=True)
class CnnSpec:
    """One registered CNN: how to build it and what it eats.

    ``apply(params, x, policy)`` may return a tuple of heads (GoogLeNet's
    loss3/loss1/loss2) — consumers take head 0 as the classifier output.
    """

    name: str
    init: Callable[..., Any]        #: init(key, *, reduced: bool) -> params
    apply: Callable[..., Any]       #: apply(params, x, policy) -> logits
    full_hw: int                    #: full-scale input H == W
    reduced_hw: int                 #: tier-1 / smoke input H == W
    in_ch: int = 3

    def input_shape(self, *, reduced: bool = True) -> Tuple[int, int, int]:
        hw = self.reduced_hw if reduced else self.full_hw
        return (hw, hw, self.in_ch)


def _vgg16_init(key, *, reduced: bool = True, num_classes: int = 10):
    if reduced:
        return _vgg.init(key, num_classes, width_mult=0.125, input_hw=32,
                         fc_dim=64)
    return _vgg.init(key, 1000)


def _resnet18_init(key, *, reduced: bool = True, num_classes: int = 10):
    if reduced:
        return _resnet.init(key, 18, num_classes, width_mult=0.25,
                            stage_depths=(1, 1, 1, 1))
    return _resnet.init(key, 18, 1000)


def _resnet50_init(key, *, reduced: bool = True, num_classes: int = 10):
    if reduced:
        return _resnet.init(key, 50, num_classes, width_mult=0.125,
                            stage_depths=(1, 1, 1, 1))
    return _resnet.init(key, 50, 1000)


def _googlenet_init(key, *, reduced: bool = True, num_classes: int = 10):
    if reduced:
        return _googlenet.init(key, num_classes, width_mult=0.125)
    return _googlenet.init(key, 1000)


def _lenet_init(key, *, reduced: bool = True, num_classes: int = 10):
    return _small.lenet_init(key, num_classes)


def _cifarnet_init(key, *, reduced: bool = True, num_classes: int = 10):
    return _small.cifarnet_init(key, num_classes)


MODELS: Dict[str, CnnSpec] = {
    "vgg16": CnnSpec("vgg16", _vgg16_init, _vgg.apply, 224, 32),
    "resnet18": CnnSpec("resnet18", _resnet18_init, _resnet.apply,
                        224, 32),
    "resnet50": CnnSpec("resnet50", _resnet50_init, _resnet.apply,
                        224, 32),
    "googlenet": CnnSpec("googlenet", _googlenet_init, _googlenet.apply,
                         224, 64),
    "lenet": CnnSpec("lenet", _lenet_init, _small.lenet_apply,
                     28, 28, in_ch=1),
    "cifarnet": CnnSpec("cifarnet", _cifarnet_init, _small.cifarnet_apply,
                        32, 32),
}
