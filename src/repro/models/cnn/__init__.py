"""CNN zoo with the BFP conv datapath (paper-faithful models)."""
