"""GoogLeNet (Szegedy et al. 2015) with the three classifier heads the
paper reports (loss1/loss2/loss3 columns of Table 3).  Inception branch
convs (1x1 / 3x3 / 5x5, mixed per-branch shapes) all route through
``engine.conv2d`` — fused implicit-im2col on the pallas backend."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.engine import PolicyLike, join_path
from repro.models.cnn import layers as L

# (name, out_1x1, red_3x3, out_3x3, red_5x5, out_5x5, pool_proj)
_INCEPTION = [
    ("3a", 64, 96, 128, 16, 32, 32),
    ("3b", 128, 128, 192, 32, 96, 64),
    ("pool", 0, 0, 0, 0, 0, 0),
    ("4a", 192, 96, 208, 16, 48, 64),
    ("4b", 160, 112, 224, 24, 64, 64),
    ("4c", 128, 128, 256, 24, 64, 64),
    ("4d", 112, 144, 288, 32, 64, 64),
    ("4e", 256, 160, 320, 32, 128, 128),
    ("pool", 0, 0, 0, 0, 0, 0),
    ("5a", 256, 160, 320, 32, 128, 128),
    ("5b", 384, 192, 384, 48, 128, 128),
]
_AUX_AFTER = {"4a": "loss1", "4d": "loss2"}


def _inception_init(key, in_ch, cfg, width_mult):
    _, o1, r3, o3, r5, o5, pp = cfg
    scale = lambda c: max(4, int(c * width_mult))
    k = jax.random.split(key, 6)
    return {
        "b1": L.conv2d_init(k[0], in_ch, scale(o1), 1, 1),
        "b3r": L.conv2d_init(k[1], in_ch, scale(r3), 1, 1),
        "b3": L.conv2d_init(k[2], scale(r3), scale(o3), 3, 3),
        "b5r": L.conv2d_init(k[3], in_ch, scale(r5), 1, 1),
        "b5": L.conv2d_init(k[4], scale(r5), scale(o5), 5, 5),
        "bp": L.conv2d_init(k[5], in_ch, scale(pp), 1, 1),
    }, scale(o1) + scale(o3) + scale(o5) + scale(pp)


def _inception(p, x, policy, path=None):
    cv = lambda name, inp: L.relu(L.conv2d(p[name], inp, 1, "SAME", policy,
                                           path=join_path(path, name)))
    b1 = cv("b1", x)
    b3 = cv("b3", cv("b3r", x))
    b5 = cv("b5", cv("b5r", x))
    bp = cv("bp", L.max_pool(x, 3, 1, "SAME"))
    return jnp.concatenate([b1, b3, b5, bp], axis=-1)


def _aux_init(key, in_ch, num_classes, width_mult):
    k1, k2, k3 = jax.random.split(key, 3)
    mid = max(16, int(128 * width_mult))
    fc = max(32, int(1024 * width_mult))
    return {"conv": L.conv2d_init(k1, in_ch, mid, 1, 1),
            "fc1_in": mid * 16, "mid": mid,
            "fc1": L.dense_init(k2, mid * 16, fc),
            "fc2": L.dense_init(k3, fc, num_classes)}


def _aux(p, x, policy, path=None):
    # adaptive 4x4 average pool
    h, w = x.shape[1], x.shape[2]
    x = L.avg_pool(x, h // 4, h // 4) if h >= 4 else x
    x = L.relu(L.conv2d(p["conv"], x, 1, "SAME", policy,
                        path=join_path(path, "conv")))
    x = x.reshape(x.shape[0], -1)[:, :p["fc1_in"]]
    x = L.relu(L.dense(p["fc1"], x, policy, path=join_path(path, "fc1")))
    return L.dense(p["fc2"], x, policy, path=join_path(path, "fc2"))


def init(key, num_classes: int = 1000, in_ch: int = 3,
         width_mult: float = 1.0):
    scale = lambda c: max(8, int(c * width_mult))
    key, k1, k2, k3 = jax.random.split(key, 4)
    params = {"stem1": L.conv2d_init(k1, in_ch, scale(64), 7, 7),
              "stem2r": L.conv2d_init(k2, scale(64), scale(64), 1, 1),
              "stem2": L.conv2d_init(k3, scale(64), scale(192), 3, 3)}
    ch = scale(192)
    for cfg in _INCEPTION:
        if cfg[0] == "pool":
            continue
        key, sub = jax.random.split(key)
        params[f"inc{cfg[0]}"], ch_out = _inception_init(sub, ch, cfg,
                                                         width_mult)
        if cfg[0] in _AUX_AFTER:
            key, sub = jax.random.split(key)
            params[_AUX_AFTER[cfg[0]]] = _aux_init(sub, ch_out, num_classes,
                                                   width_mult)
        ch = ch_out
    key, sub = jax.random.split(key)
    params["fc"] = L.dense_init(sub, ch, num_classes)
    return params


def apply(params, x: jax.Array, policy: PolicyLike = None,
          with_aux: bool = True):
    """Returns (loss3_logits, loss1_logits, loss2_logits) — the paper's
    three GoogLeNet columns.  Layer paths: "stem1|stem2r|stem2",
    "inc<name>/b1|b3r|b3|b5r|b5|bp", "loss1|loss2/conv|fc1|fc2", "fc";
    ``policy`` is a PolicyLike (incl. a bound ``engine.Plan``)."""
    x = L.relu(L.conv2d(params["stem1"], x, 2, "SAME", policy,
                        path="stem1"))
    x = L.max_pool(x, 3, 2, "SAME")
    x = L.relu(L.conv2d(params["stem2r"], x, 1, "SAME", policy,
                        path="stem2r"))
    x = L.relu(L.conv2d(params["stem2"], x, 1, "SAME", policy,
                        path="stem2"))
    x = L.max_pool(x, 3, 2, "SAME")
    aux1 = aux2 = None
    for cfg in _INCEPTION:
        if cfg[0] == "pool":
            x = L.max_pool(x, 3, 2, "SAME")
            continue
        x = _inception(params[f"inc{cfg[0]}"], x, policy,
                       path=f"inc{cfg[0]}")
        if with_aux and cfg[0] in _AUX_AFTER:
            a = _aux(params[_AUX_AFTER[cfg[0]]], x, policy,
                     path=_AUX_AFTER[cfg[0]])
            if cfg[0] == "4a":
                aux1 = a
            else:
                aux2 = a
    x = L.global_avg_pool(x)
    main = L.dense(params["fc"], x, policy, path="fc")
    return (main, aux1, aux2) if with_aux else main
