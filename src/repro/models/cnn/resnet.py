"""ResNet-18 / ResNet-50 (He et al. 2016) with the BFP conv datapath.

Inference-mode batch norm (the paper deploys trained models without
retraining); ``width_mult``/``stage_depths`` allow reduced smoke configs.
Convs (incl. the strided stem and projection shortcuts) run through
``engine.conv2d`` — fused implicit-im2col on the pallas backend.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.engine import PolicyLike, join_path
from repro.models.cnn import layers as L


def _conv_bn_init(key, in_ch, out_ch, k):
    return {"conv": L.conv2d_init(key, in_ch, out_ch, k, k),
            "bn": L.batchnorm_init(out_ch)}


def _conv_bn(p, x, stride, policy, training, act=True, path=None):
    x = L.conv2d(p["conv"], x, stride, "SAME", policy, path=path)
    x = L.batchnorm(p["bn"], x, training)
    return L.relu(x) if act else x


def _basic_block_init(key, in_ch, out_ch, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"c1": _conv_bn_init(k1, in_ch, out_ch, 3),
         "c2": _conv_bn_init(k2, out_ch, out_ch, 3)}
    if stride != 1 or in_ch != out_ch:
        p["proj"] = _conv_bn_init(k3, in_ch, out_ch, 1)
    return p


def _basic_block(p, x, stride, policy, training, path=None):
    h = _conv_bn(p["c1"], x, stride, policy, training,
                 path=join_path(path, "c1"))
    h = _conv_bn(p["c2"], h, 1, policy, training, act=False,
                 path=join_path(path, "c2"))
    sc = _conv_bn(p["proj"], x, stride, policy, training, act=False,
                  path=join_path(path, "proj")) if "proj" in p else x
    return L.relu(h + sc)


def _bottleneck_init(key, in_ch, mid_ch, stride):
    out_ch = mid_ch * 4
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"c1": _conv_bn_init(k1, in_ch, mid_ch, 1),
         "c2": _conv_bn_init(k2, mid_ch, mid_ch, 3),
         "c3": _conv_bn_init(k3, mid_ch, out_ch, 1)}
    if stride != 1 or in_ch != out_ch:
        p["proj"] = _conv_bn_init(k4, in_ch, out_ch, 1)
    return p


def _bottleneck(p, x, stride, policy, training, path=None):
    h = _conv_bn(p["c1"], x, 1, policy, training,
                 path=join_path(path, "c1"))
    h = _conv_bn(p["c2"], h, stride, policy, training,
                 path=join_path(path, "c2"))
    h = _conv_bn(p["c3"], h, 1, policy, training, act=False,
                 path=join_path(path, "c3"))
    sc = _conv_bn(p["proj"], x, stride, policy, training, act=False,
                  path=join_path(path, "proj")) if "proj" in p else x
    return L.relu(h + sc)


_DEPTHS = {18: (2, 2, 2, 2), 50: (3, 4, 6, 3)}


def init(key, depth: int = 18, num_classes: int = 1000, in_ch: int = 3,
         width_mult: float = 1.0,
         stage_depths: Optional[Sequence[int]] = None):
    stage_depths = stage_depths or _DEPTHS[depth]
    bottleneck = depth >= 50
    base = max(8, int(64 * width_mult))
    key, sub = jax.random.split(key)
    params = {"stem": _conv_bn_init(sub, in_ch, base, 7)}
    ch = base
    blocks = []
    for si, nblocks in enumerate(stage_depths):
        out = base * (2 ** si)
        for bi in range(nblocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            key, sub = jax.random.split(key)
            if bottleneck:
                blocks.append(_bottleneck_init(sub, ch, out, stride))
                ch = out * 4
            else:
                blocks.append(_basic_block_init(sub, ch, out, stride))
                ch = out
    params["blocks"] = blocks
    key, sub = jax.random.split(key)
    params["fc"] = L.dense_init(sub, ch, num_classes)
    params["meta"] = (depth, tuple(stage_depths), bottleneck)
    return params


def apply(params, x: jax.Array, policy: PolicyLike = None,
          training: bool = False) -> jax.Array:
    """Layer paths: "stem", "blocks/<i>/c1|c2|c3|proj", "fc" — e.g.
    PolicyMap.of(("^stem", None), default=BFPPolicy(l_w=8, l_i=8)) is the
    paper's first-layer-in-float mixed assignment; ``policy`` also takes
    a bound ``engine.Plan`` over the same paths."""
    depth, stage_depths, bottleneck = params["meta"]
    x = _conv_bn(params["stem"], x, 2, policy, training, path="stem")
    x = L.max_pool(x, 3, 2, "SAME")
    bi = 0
    for si, nblocks in enumerate(stage_depths):
        for b in range(nblocks):
            stride = 2 if (b == 0 and si > 0) else 1
            blk = params["blocks"][bi]
            bpath = f"blocks/{bi}"
            x = (_bottleneck(blk, x, stride, policy, training, path=bpath)
                 if bottleneck
                 else _basic_block(blk, x, stride, policy, training,
                                   path=bpath))
            bi += 1
    x = L.global_avg_pool(x)
    return L.dense(params["fc"], x, policy, path="fc")
