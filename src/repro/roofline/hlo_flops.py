"""Per-instruction FLOP attribution from optimized HLO text.

The aggregate ``cost_analysis()`` says WHAT the program costs; this module
says WHERE — it parses every ``dot`` instruction (shapes are printed
inline post-optimization), computes 2*M*N*K FLOPs, and buckets by shape
signature.  This is the "profile" of the dry-run methodology (§Perf):
no wall-clock exists on CPU, so the lowered IR is the profile.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

__all__ = ["dot_flops", "top_dots", "summarize"]

_DOT_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?\bdot\(\s*"
    r"(\w+)\[([\d,]*)\][^,]*,\s*"
    r"(\w+)\[([\d,]*)\]")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]+)\}")


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d]


def dot_flops(hlo_text: str) -> List[Tuple[int, str, int]]:
    """[(flops, 'lhs_shape x rhs_shape -> out_shape', count)] per signature.

    flops = 2 * prod(out) * prod(contracting dims of lhs).
    """
    buckets: Dict[str, List[int]] = defaultdict(lambda: [0, 0])
    for line in hlo_text.splitlines():
        m = _DOT_RE.search(line)
        if not m:
            continue
        out_dims = _dims(m.group(2))
        lhs_dims = _dims(m.group(4))
        rhs_dims = _dims(m.group(6))
        c = _DIMS_RE.search(line)
        if c:
            k = 1
            for ci in _dims(c.group(1)):
                if ci < len(lhs_dims):
                    k *= lhs_dims[ci]
        else:
            k = lhs_dims[-1] if lhs_dims else 1
        out = 1
        for d in out_dims:
            out *= d
        fl = 2 * out * k
        sig = (f"{m.group(3)}[{m.group(4)}] x {m.group(5)}[{m.group(6)}] "
               f"-> [{m.group(2)}]")
        buckets[sig][0] += fl
        buckets[sig][1] += 1
    return sorted(((v[0], sig, v[1]) for sig, v in buckets.items()),
                  reverse=True)


def top_dots(hlo_text: str, n: int = 15) -> str:
    rows = dot_flops(hlo_text)
    total = sum(r[0] for r in rows)
    lines = [f"total dot flops (per device): {total:.4g}"]
    for fl, sig, cnt in rows[:n]:
        lines.append(f"  {fl:12.4g} ({100*fl/max(total,1):5.1f}%) x{cnt:<4d} {sig}")
    return "\n".join(lines)


def summarize(hlo_text: str) -> Dict[str, float]:
    rows = dot_flops(hlo_text)
    return {"dot_flops": float(sum(r[0] for r in rows)),
            "n_dot_signatures": len(rows)}
