"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs.

Usage:  PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
Prints a markdown table per mesh: the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, per-device memory, and a one-line
"what would move the dominant term" note per row.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

_SUGGEST = {
    ("memory", "train"): "bf16 master-grad + fused optimizer; BFP-8 "
        "weight streaming halves HBM reads (paper's traffic argument)",
    ("memory", "prefill"): "KV/activation in bf16 + BFP-8 weights; larger "
        "flash chunks raise arithmetic intensity",
    ("memory", "decode"): "decode is weight-streaming bound: BFP-8 "
        "mantissa weights (+exp sidecar) cut HBM bytes ~4x vs f32",
    ("compute", "train"): "int8 BFP MXU path doubles MACs/s vs bf16; "
        "drop causal-masked flash waste (2x upper-triangle)",
    ("compute", "prefill"): "int8 BFP MXU path; skip fully-masked "
        "flash chunks (causal upper triangle)",
    ("collective", "train"): "BFP-8 gradient compression on the "
        "all-reduce (4x wire bytes); overlap via async collective start",
    ("collective", "decode"): "replicate small KV shards to kill "
        "all-gathers; batch-shard only",
    ("collective", "prefill"): "reduce-scatter + all-gather decomposition "
        "overlapped with per-layer compute",
}


def load(dir_: str, mesh: str, mode: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, mesh,
                                              f"*.{mode}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}"


def render(dir_: str = "results/dryrun"):
    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        comp = {(r["arch"], r["shape"]): r
                for r in load(dir_, mesh, "compile")}
        roof = {(r["arch"], r["shape"]): r
                for r in load(dir_, mesh, "roofline")}
        if not comp:
            continue
        print(f"\n### Mesh {mesh} ({next(iter(comp.values()))['n_devices']}"
              f" devices)\n")
        if roof:
            print("| arch | shape | t_compute s | t_memory s | t_coll s |"
                  " dominant | useful ratio | temp GB/dev | note |")
            print("|---|---|---|---|---|---|---|---|---|")
        else:
            print("| arch | shape | compile_s | temp GB/dev |")
            print("|---|---|---|---|")
        for key in sorted(comp):
            c = comp[key]
            mem = c.get("memory_analysis") or {}
            temp = fmt_bytes(mem.get("temp_bytes"))
            r = roof.get(key)
            if r:
                t = r["roofline"]
                kind = ("train" if key[1].startswith("train") else
                        "decode" if "decode" in key[1] or "long" in key[1]
                        else "prefill")
                note = _SUGGEST.get((t["dominant"], kind), "")
                print(f"| {key[0]} | {key[1]} | {t['t_compute']:.4f} |"
                      f" {t['t_memory']:.4f} | {t['t_collective']:.4f} |"
                      f" {t['dominant']} | {r['useful_flop_ratio']:.3f} |"
                      f" {temp} | {note} |")
            else:
                print(f"| {key[0]} | {key[1]} | {c['compile_s']} | {temp} |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    render(args.dir)
