"""Roofline-term extraction from compiled dry-run artifacts (TPU v5e).

    compute term    = HLO_FLOPs  / (chips * 197e12 FLOP/s)
    memory term     = HLO_bytes  / (chips * 819e9 B/s)
    collective term = coll_bytes / (chips * 2 * 50e9 B/s-ish per link class)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``.  Collective bytes
are NOT in cost_analysis: we parse the post-SPMD optimized HLO text and sum
the operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, weighted by the algorithmic traffic factor
of each collective (ring: all-gather and reduce-scatter move (n-1)/n of the
full payload per chip; all-reduce moves 2x that; all-to-all (n-1)/n).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "collective_bytes", "roofline_terms", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (system-prompt hardware spec)."""
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # B/s
    ici_bw: float = 50e9             # B/s per link
    chips: int = 256


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# matches e.g. "bf16[16,4096,5120]" (possibly with layout "{2,1,0}")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO.

    Uses the RESULT shape on the lhs of each collective instruction (for
    tuples, all elements).  Done / -done ops are skipped (the -start op
    carries the shape).
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        # result shape sits between '=' and the op name:  %x = bf16[..] op(
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1))
    return out


def _wire_bytes(coll: Dict[str, int], n_chips: int) -> float:
    """Per-chip wire traffic with ring-algorithm factors."""
    f = (n_chips - 1) / max(n_chips, 1)
    total = 0.0
    total += coll.get("all-gather", 0) * f
    total += coll.get("reduce-scatter", 0) * f
    total += coll.get("all-reduce", 0) * 2 * f
    total += coll.get("all-to-all", 0) * f
    total += coll.get("collective-permute", 0)
    return total


def roofline_terms(cost: Dict[str, float], coll: Dict[str, int],
                   hw: HW = HW(), n_links: int = 4) -> Dict[str, float]:
    """The three per-step roofline terms, in seconds.

    cost: compiled.cost_analysis() dict (flops/bytes are PER CHIP under
    SPMD — XLA reports the per-device program).  n_links: ICI links per
    chip participating (v5e 2D torus: 4).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / hw.peak_flops
    t_memory = bytes_hbm / hw.hbm_bw
    t_coll = _wire_bytes(coll, hw.chips) / (hw.ici_bw * n_links)
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "dominant": dominant,
            "hlo_flops": flops, "hlo_bytes": bytes_hbm,
            "collective_wire_bytes": _wire_bytes(coll, hw.chips)}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    terms: Dict[str, float]
    collectives: Dict[str, int]
    memory_per_device: Optional[float]
    model_flops: float               # 6*N*D (dense) or 6*N_active*D
    useful_ratio: float              # model_flops / (chips * hlo_flops)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)
