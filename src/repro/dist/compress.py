"""BFP gradient compression with error feedback (beyond-paper E9).

The paper's off-chip-traffic argument (§1, §3.1) applied to the training
interconnect: gradients are block-formatted before the cross-pod
all-reduce, cutting wire bytes ~4x at 8 bits.  Plain quantization of
gradients is biased step-to-step; the standard fix is ERROR FEEDBACK
(Seide et al. 2014; Karimireddy et al. 2019): the residual of each
quantization is carried and added back before the next one, so the
compressed sum converges to the true sum.

``quantize_leaf`` is the wire model (round-trip through the BFP format);
``make_compressor`` packages init + transform for
``train.step.make_train_step(grad_transform=...)``.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp

__all__ = ["quantize_leaf", "make_compressor"]

#: Elements per shared exponent on the wire (one int32 exponent per block;
#: 512 matches the paper's Table-1 storage sweet spot: +8/512 bits/elem).
WIRE_BLOCK = 512


def quantize_leaf(g: jax.Array, bits: int,
                  block: int = WIRE_BLOCK) -> jax.Array:
    """Round-trip one leaf through the BFP wire format (same shape out).

    The leaf is flattened, split into ``block``-element blocks (zero
    padded), block-formatted at ``bits`` (incl. sign), and dequantized —
    exactly the error the int8+exponent wire introduces.
    """
    if not jnp.issubdtype(g.dtype, jnp.floating):
        return g
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    nb = -(-n // block)
    padded = jnp.pad(flat, (0, nb * block - n)).reshape(nb, block)
    q = bfp.quantize(padded, bits, (1,)).dequantize()
    return q.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)


def make_compressor(bits: int = 8, block: int = WIRE_BLOCK
                    ) -> Tuple[Callable[[Any], Any],
                               Callable[[Any, Any], Tuple[Any, Any]]]:
    """Error-feedback BFP compressor for gradient pytrees.

    Returns ``(init_fn, transform)``:

      init_fn(params)            -> zero residual tree
      transform(grads, residual) -> (compressed_grads, new_residual)

    with ``e = g + r;  q = Q(e);  r' = e - q`` per leaf, which keeps the
    accumulated compressed gradient unbiased (test_system asserts the
    50-step sum converges to the true sum).
    """

    def init_fn(params: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def transform(grads: Any, residual: Any) -> Tuple[Any, Any]:
        def one(g, r):
            e = g.astype(jnp.float32) + r
            q = quantize_leaf(e, bits, block)
            return q.astype(g.dtype), e - q

        pairs = jax.tree_util.tree_map(one, grads, residual)
        q = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                   is_leaf=lambda t: isinstance(t, tuple))
        r = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                   is_leaf=lambda t: isinstance(t, tuple))
        return q, r

    return init_fn, transform
