"""BFP gradient compression with error feedback (beyond-paper E9).

The paper's off-chip-traffic argument (§1, §3.1) applied to the training
interconnect: gradients are block-formatted before the cross-pod
all-reduce, cutting wire bytes ~4x at 8 bits.  Plain quantization of
gradients is biased step-to-step; the standard fix is ERROR FEEDBACK
(Seide et al. 2014; Karimireddy et al. 2019): the residual of each
quantization is carried and added back before the next one, so the
compressed sum converges to the true sum.

Two faces of one wire format (pinned bit-exact against each other in
tests/test_packed.py):

  * :func:`quantize_leaf` — the jit-safe in-graph MODEL of the wire
    (round-trip through the BFP format), used inside the training step
    via :func:`make_compressor`;
  * :func:`pack_leaf` / :func:`unpack_leaf` — the ACTUAL bytes: a
    bit-packed :class:`~repro.core.packed.PackedBFP` container (one int8
    exponent per block, mantissas at exactly ``bits`` wide), whose
    dequantized round trip equals ``quantize_leaf`` exactly.  This is
    what crosses a real host boundary, and what :func:`wire_report`
    measures.

Byte accounting is HONEST: the last block of a leaf is zero-padded to
``block`` elements, and those padding bits travel — ``leaf_wire_bytes``
and ``wire_report`` count them (the old analytic ratio silently ignored
the remainder block).  ``block`` geometry is validated up front,
including alignment with a ``Scheme.TILED`` ``tile_k`` when the wire
shares buffers with the tiled execution datapath.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bfp
from repro.core import packed as PK

__all__ = ["quantize_leaf", "make_compressor", "pack_leaf", "unpack_leaf",
           "leaf_wire_bytes", "wire_report", "validate_wire_block",
           "packed_allreduce"]

#: Elements per shared exponent on the wire (one int8 exponent per block;
#: 512 matches the paper's Table-1 storage sweet spot: +8/512 bits/elem).
WIRE_BLOCK = 512


def validate_wire_block(block: int, tile_k: Optional[int] = None) -> None:
    """Reject unusable wire-block geometry up front.

    ``block`` must be a positive int; when ``tile_k`` is given (the
    ``Scheme.TILED`` K-tile the execution datapath blocks on), ``block``
    must be a multiple of it, so wire blocks land on tile boundaries and
    a wire-quantized tensor re-blocks into whole execution tiles.  This
    used to be unchecked: a ``WIRE_BLOCK`` that straddled TILED tiles
    silently mixed exponent groups.
    """
    if not isinstance(block, int) or isinstance(block, bool) or block < 1:
        raise ValueError(f"wire block must be a positive int, got {block!r}")
    if tile_k is not None:
        if not isinstance(tile_k, int) or isinstance(tile_k, bool) \
                or tile_k < 1:
            raise ValueError(f"tile_k must be a positive int, got {tile_k!r}")
        if block % tile_k:
            raise ValueError(
                f"wire block {block} is not a multiple of the TILED "
                f"tile_k {tile_k} — wire blocks would straddle execution "
                f"tiles and mix exponent groups")


def quantize_leaf(g: jax.Array, bits: int, block: int = WIRE_BLOCK,
                  tile_k: Optional[int] = None) -> jax.Array:
    """Round-trip one leaf through the BFP wire format (same shape out).

    The leaf is flattened, split into ``block``-element blocks (zero
    padded), block-formatted at ``bits`` (incl. sign), and dequantized —
    exactly the error the packed int-mantissa+exponent wire
    (:func:`pack_leaf`) introduces; the two are pinned bit-exact in
    tests.  jit-safe (this is the in-graph model the train step runs).
    """
    validate_wire_block(block, tile_k)
    if not jnp.issubdtype(g.dtype, jnp.floating):
        return g
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    nb = -(-n // block)
    padded = jnp.pad(flat, (0, nb * block - n)).reshape(nb, block)
    q = bfp.quantize(padded, bits, (1,)).dequantize()
    return q.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)


# ---------------------------------------------------------------------------
# The actual wire bytes
# ---------------------------------------------------------------------------

def pack_leaf(g: jax.Array, bits: int, block: int = WIRE_BLOCK,
              tile_k: Optional[int] = None,
              variable: bool = False) -> PK.PackedBFP:
    """Block-format one leaf and serialize the REAL wire payload.

    Returns a :class:`PackedBFP` whose ``nbytes`` is exactly what a
    transfer moves: header + one int8 exponent per block + mantissas
    bit-packed at ``bits`` — including the zero-padding of the remainder
    block (honest accounting; the padding travels).  Host-side, not
    jit-safe.  ``unpack_leaf(pack_leaf(g, ...))`` equals
    ``quantize_leaf(g, ...)`` bit-exactly.

    ``variable=True`` writes a v3 variable-width container: each wire
    block travels at its effective occupied width, so sparse gradients
    (near-zero error-feedback residuals, frozen layers) shrink below
    ``bits`` bits/element while the dequantized round trip stays
    bit-identical — ``quantize_leaf`` remains the in-graph model for
    both encodings.
    """
    validate_wire_block(block, tile_k)
    arr = np.asarray(g)
    if not np.issubdtype(arr.dtype, np.floating):
        raise ValueError(f"pack_leaf needs a float leaf, got {arr.dtype}")
    flat = jnp.asarray(arr, jnp.float32).reshape(-1)
    n = int(flat.shape[0])
    nb = -(-n // block)
    padded = jnp.pad(flat, (0, nb * block - n)).reshape(nb, block)
    blk = bfp.quantize(padded, bits, (1,))
    return PK.pack_block(blk, variable=variable, kind="wire",
                         orig_shape=list(arr.shape), orig_size=n,
                         block=block)


def unpack_leaf(p) -> jax.Array:
    """Wire container -> dequantized float32 leaf in its original shape.

    Accepts a :class:`PackedBFP` or the raw serialized ``bytes`` exactly
    as they arrived off the wire.  Either way the container's CRC32 is
    verified first: a corrupted wire block raises the typed
    :class:`repro.core.packed.IntegrityError` instead of dequantizing
    garbage into a gradient all-reduce (the receiver can then re-request
    the block or drop the contribution).
    """
    if isinstance(p, (bytes, bytearray, memoryview)):
        p = PK.PackedBFP.from_bytes(p)        # verifies CRC (v2 wire)
    else:
        p.verify()
    if p.meta.get("kind") != "wire":
        raise ValueError(f"not a wire container (kind="
                         f"{p.meta.get('kind')!r})")
    deq = PK.unpack_block(p).dequantize()
    n = int(p.meta["orig_size"])
    return deq.reshape(-1)[:n].reshape(tuple(p.meta["orig_shape"]))


def leaf_wire_bytes(n_elems: int, bits: int, block: int = WIRE_BLOCK) -> int:
    """Analytic wire bytes for an ``n_elems`` leaf — padding INCLUDED.

    ``ceil(n/block)`` blocks travel ``block`` mantissas each (the
    remainder block is zero-padded to full size and its padding bits are
    on the wire) plus one int8 exponent per block.  Container header
    excluded (constant ~50 bytes/leaf); ``pack_leaf(...).nbytes`` is the
    header-exact number.
    """
    validate_wire_block(block)
    nb = -(-n_elems // block)
    return -(-nb * block * bits // 8) + nb


def wire_report(tree: Any, bits: int, block: int = WIRE_BLOCK,
                tile_k: Optional[int] = None,
                variable: bool = False) -> Dict[str, Any]:
    """Measure REAL wire bytes for a gradient/param pytree.

    Packs every float leaf through :func:`pack_leaf` and sums actual
    serialized container sizes (headers, exponent planes, padded
    mantissa bitstreams).  Non-float leaves transfer uncompressed and are
    counted at their raw ``nbytes``.  ``variable=True`` measures the
    variable-width (v3) wire instead.  Returns::

        {"wire_bytes", "float_bytes", "ratio", "n_leaves",
         "n_uncompressed", "per_leaf": [(shape, wire, raw), ...]}
    """
    validate_wire_block(block, tile_k)
    wire = raw = 0
    per_leaf = []
    n_unc = 0
    leaves = jax.tree_util.tree_leaves(tree)
    for leaf in leaves:
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            p = pack_leaf(arr, bits, block, tile_k, variable)
            w = p.nbytes
        else:
            w = arr.nbytes
            n_unc += 1
        wire += w
        raw += arr.nbytes
        per_leaf.append((tuple(arr.shape), w, arr.nbytes))
    return {"wire_bytes": wire, "float_bytes": raw,
            "ratio": wire / raw if raw else 0.0, "n_leaves": len(leaves),
            "n_uncompressed": n_unc, "per_leaf": per_leaf}


def packed_allreduce(grads: Any, residual: Any, bits: int = 8,
                     block: int = WIRE_BLOCK,
                     tile_k: Optional[int] = None,
                     variable: bool = False
                     ) -> Tuple[Any, Any, int]:
    """Error-feedback all-reduce over the REAL packed wire (host-side).

    ``grads`` / ``residual`` are pytrees whose float leaves are stacked
    per-worker ``[W, ...]`` (the data-parallel trainer's layout,
    ``repro.train.cnn``).  Per worker and leaf the error-feedback input
    ``e = g + r`` is serialized with :func:`pack_leaf`, the container
    bytes cross the "wire" (``to_bytes`` -> CRC-verified
    :func:`unpack_leaf` round trip — exactly what a host boundary
    moves), and the dequantized contributions are averaged.  Returns
    ``(mean_grads, new_residual, wire_bytes)`` with ``wire_bytes`` the
    actual serialized byte total across workers and leaves (headers,
    exponent planes, padded mantissa bitstreams — honest accounting).

    Pinned bit-exact against ``make_compressor``'s jit-safe in-graph
    model in tests/test_dist.py: same residual carry, same mean, so the
    fast jitted training step IS the wire protocol, and this function is
    how a step's bytes are measured (or a real multi-host exchange
    staged).  Non-float leaves pass through unaveraged.

    ``variable=True`` ships v3 variable-width containers — same
    dequantized contributions bit-exactly (so the in-graph model still
    holds), fewer bytes whenever gradient blocks under-occupy ``bits``.
    """
    validate_wire_block(block, tile_k)
    n_bytes = 0

    def one(g, r):
        nonlocal n_bytes
        if not jnp.issubdtype(jnp.asarray(g).dtype, jnp.floating):
            return g, r
        workers = g.shape[0]
        qs, rs = [], []
        for wi in range(workers):
            e = jnp.asarray(g[wi], jnp.float32) + r[wi]
            p = pack_leaf(e, bits, block, tile_k, variable)
            wire = p.to_bytes()
            n_bytes += len(wire)
            q = unpack_leaf(wire)
            qs.append(q)
            rs.append(e - q)
        mean = jnp.mean(jnp.stack(qs), axis=0)
        return mean, jnp.stack(rs)

    pairs = jax.tree_util.tree_map(one, grads, residual)
    is_pair = lambda t: isinstance(t, tuple)
    mean = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_pair)
    res = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_pair)
    return mean, res, n_bytes


def make_compressor(bits: int = 8, block: int = WIRE_BLOCK,
                    tile_k: Optional[int] = None
                    ) -> Tuple[Callable[[Any], Any],
                               Callable[[Any, Any], Tuple[Any, Any]]]:
    """Error-feedback BFP compressor for gradient pytrees.

    Returns ``(init_fn, transform)``:

      init_fn(params)            -> zero residual tree
      transform(grads, residual) -> (compressed_grads, new_residual)

    with ``e = g + r;  q = Q(e);  r' = e - q`` per leaf, which keeps the
    accumulated compressed gradient unbiased (test_system asserts the
    50-step sum converges to the true sum).  ``block`` geometry
    (positivity; ``tile_k`` alignment for TILED-shared buffers) is
    validated HERE, once, not on the jitted per-step path.
    """
    validate_wire_block(block, tile_k)

    def init_fn(params: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def transform(grads: Any, residual: Any) -> Tuple[Any, Any]:
        def one(g, r):
            e = g.astype(jnp.float32) + r
            q = quantize_leaf(e, bits, block)
            return q.astype(g.dtype), e - q

        pairs = jax.tree_util.tree_map(one, grads, residual)
        q = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                   is_leaf=lambda t: isinstance(t, tuple))
        r = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                   is_leaf=lambda t: isinstance(t, tuple))
        return q, r

    return init_fn, transform
