"""Logical-axis sharding annotations (DESIGN.md §5).

Model code names the MEANING of each tensor dimension; the launcher names
the HARDWARE.  ``axis_rules`` installs a (rules, mesh) binding for the
current thread; inside it, ``shard`` lowers logical names to
``jax.lax.with_sharding_constraint`` with a :class:`NamedSharding`.
Outside any binding ``shard`` is the identity, which is what lets the
tier-1 test suite exercise the exact production model code on one CPU
device.

Rules values may be a physical axis name (``"model"``), a tuple of axis
names (``("pod", "data")`` — the multi-pod batch axis), or ``None``
(replicate).  A rule whose axis size does not divide the dimension is
dropped to ``None`` instead of failing, so reduced smoke configs never
trip divisibility errors.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DEFAULT_RULES", "axis_rules", "shard", "current_rules"]

Axis = Union[None, str, Tuple[str, ...]]

#: Logical -> physical defaults for the production meshes
#: (launch.mesh: axes ("data", "model") or ("pod", "data", "model")).
#: ``launch.input_specs.cell_rules`` patches these per (arch x shape) cell.
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": "data",        # pure data parallelism
    "seq": None,            # full sequences per shard
    "seq_res": None,        # residual-stream seq axis (Megatron SP opt-in)
    "embed": None,          # d_model stays replicated (activations)
    "heads": "model",       # tensor parallel attention
    "kv_heads": "model",
    "ffn": "model",         # tensor parallel MLP hidden
    "vocab": "model",       # sharded logits / lm_head
    "experts": "model",     # expert parallelism (MoE)
}

_STATE = threading.local()


def current_rules() -> Optional[Tuple[Dict[str, Axis], Mesh]]:
    """The active (rules, mesh) binding, or None outside axis_rules."""
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Axis], mesh: Mesh):
    """Bind logical axis names to physical mesh axes for this thread."""
    prev = current_rules()
    _STATE.ctx = (dict(rules), mesh)
    try:
        yield
    finally:
        _STATE.ctx = prev


def _axis_size(mesh: Mesh, ax: Axis) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(ax, 1)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with one logical axis name (or None) per dimension.

    Identity outside an :func:`axis_rules` context.  Unknown names and
    indivisible dimensions replicate.
    """
    ctx = current_rules()
    if ctx is None:
        return x
    rules, mesh = ctx
    if x.ndim != len(logical_axes):  # defensive: never fail model code
        return x
    phys = []
    for dim, name in zip(x.shape, logical_axes):
        ax = rules.get(name) if isinstance(name, str) else None
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        phys.append(tuple(ax) if isinstance(ax, list) else ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*phys)))
