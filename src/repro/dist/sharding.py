"""Logical-axis sharding annotations (DESIGN.md §5).

Model code names the MEANING of each tensor dimension; the launcher names
the HARDWARE.  ``axis_rules`` installs a (rules, mesh) binding for the
current thread; inside it, ``shard`` lowers logical names to
``jax.lax.with_sharding_constraint`` with a :class:`NamedSharding`.
Outside any binding ``shard`` is the identity, which is what lets the
tier-1 test suite exercise the exact production model code on one CPU
device.

Rules values may be a physical axis name (``"model"``), a tuple of axis
names (``("pod", "data")`` — the multi-pod batch axis), or ``None``
(replicate).  A rule whose axis size does not divide the dimension is
dropped to ``None`` instead of failing, so reduced smoke configs never
trip divisibility errors — but the drop is no longer silent: the first
time a given rule is dropped a :class:`ShardingRuleDropped` warning
fires (once per rule, process-wide), so a production misconfig that
quietly replicates a tensor it was meant to shard is visible in the
serving logs.
"""
from __future__ import annotations

import contextlib
import threading
import warnings
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DEFAULT_RULES", "axis_rules", "shard", "current_rules",
           "resolve_spec", "mesh_axis_sizes", "ShardingRuleDropped"]

Axis = Union[None, str, Tuple[str, ...]]

#: Logical -> physical defaults for the production meshes
#: (launch.mesh: axes ("data", "model") or ("pod", "data", "model")).
#: ``launch.input_specs.cell_rules`` patches these per (arch x shape) cell.
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": "data",        # pure data parallelism
    "seq": None,            # full sequences per shard
    "seq_res": None,        # residual-stream seq axis (Megatron SP opt-in)
    "embed": None,          # d_model stays replicated (activations)
    "heads": "model",       # tensor parallel attention
    "kv_heads": "model",
    "ffn": "model",         # tensor parallel MLP hidden
    "vocab": "model",       # sharded logits / lm_head
    "experts": "model",     # expert parallelism (MoE)
}

_STATE = threading.local()


class ShardingRuleDropped(UserWarning):
    """A logical-axis rule was dropped at lowering time because the mesh
    axis size does not divide the tensor dimension — the dim replicates
    instead of sharding.  Benign in reduced smoke configs; in production
    it means a tensor you meant to shard is fully replicated."""


#: (logical name, physical axis, axis size, dim) drops already warned
#: about — once per rule GEOMETRY, not per call, so a hot serving loop
#: logs one line, not millions, while a later drop of the same rule at a
#: DIFFERENT size/dim (e.g. smoke warm-up then misconfigured production
#: mesh in one process) still surfaces.
_DROP_WARNED: set = set()


def current_rules() -> Optional[Tuple[Dict[str, Axis], Mesh]]:
    """The active (rules, mesh) binding, or None outside axis_rules."""
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Axis], mesh: Mesh):
    """Bind logical axis names to physical mesh axes for this thread."""
    prev = current_rules()
    _STATE.ctx = (dict(rules), mesh)
    try:
        yield
    finally:
        _STATE.ctx = prev


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    """{axis name: size} for a mesh (what divisibility is checked against)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _axis_size(sizes: Dict[str, int], ax: Axis) -> int:
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(ax, 1)


def resolve_spec(rules: Dict[str, Axis], sizes: Dict[str, int],
                 shape: Tuple[int, ...],
                 logical_axes: Tuple[Optional[str], ...]) -> Tuple[Axis, ...]:
    """Lower logical axis names to a physical PartitionSpec tuple.

    Unknown names and ``None`` replicate silently (that is the contract:
    the name has no binding).  A KNOWN rule whose axis size does not
    divide the dimension is dropped to replicated with a once-per-rule
    :class:`ShardingRuleDropped` warning — reduced smoke configs keep
    running, production misconfigs become visible.  Factored out of
    :func:`shard` (which feeds it the active mesh) so the divisibility
    policy is unit-testable without multi-device meshes.
    """
    phys = []
    for dim, name in zip(shape, logical_axes):
        ax = rules.get(name) if isinstance(name, str) else None
        if ax is not None:
            n = _axis_size(sizes, ax)
            if dim % n != 0:
                phys_ax = ax if isinstance(ax, str) else tuple(ax)
                key = (name, phys_ax, n, dim)
                if key not in _DROP_WARNED:
                    _DROP_WARNED.add(key)
                    warnings.warn(
                        f"sharding rule {name!r} -> {phys_ax!r} dropped: "
                        f"mesh axis size {n} does not divide dim {dim}; "
                        f"the dimension replicates instead",
                        ShardingRuleDropped, stacklevel=3)
                ax = None
        phys.append(tuple(ax) if isinstance(ax, list) else ax)
    return tuple(phys)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with one logical axis name (or None) per dimension.

    Identity outside an :func:`axis_rules` context.  Unknown names
    replicate; indivisible dimensions replicate with a once-per-rule
    :class:`ShardingRuleDropped` warning.
    """
    ctx = current_rules()
    if ctx is None:
        return x
    rules, mesh = ctx
    if x.ndim != len(logical_axes):  # defensive: never fail model code
        return x
    phys = resolve_spec(rules, mesh_axis_sizes(mesh), x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*phys)))
