"""PartitionSpec trees for parameters and decode caches (DESIGN.md §5).

Megatron-style tensor parallelism over the ``"model"`` axis plus FSDP
over the data axes:

  * column-parallel linears (wq/wk/wv, w1/w3, gates, lm_head): output dim
    on "model", input dim FSDP-sharded over ("pod", "data");
  * row-parallel linears (wo, w2, out): input dim on "model";
  * embedding: vocab dim on "model" (sharded logits pair with the
    "vocab" activation rule);
  * BFP prequant leaves ({"m", "s"} wire format): the int8 mantissa
    follows its owner's layout; the small scale sidecar shards only its
    output dim (column-parallel owners) and otherwise replicates.

Every assignment is divisibility-guarded — a dim the axis does not divide
replicates instead of failing, so reduced configs lower on any mesh.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["param_specs", "cache_specs"]

#: Immediate-owner names whose GEMM contracts over the "model"-sharded dim
#: (row parallel); everything else 2-D+ is treated column parallel.
_ROW_PARALLEL = ("wo", "w2", "out")


def _axes(mesh: Mesh):
    names = mesh.axis_names
    data: Any = tuple(a for a in ("pod", "data") if a in names)
    if len(data) == 1:
        data = data[0]
    elif not data:
        data = None
    model = "model" if "model" in names else None
    return data, model


def _size(mesh: Mesh, ax) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(ax, 1)


def _fit(mesh: Mesh, dim: int, ax):
    return ax if ax is not None and dim % _size(mesh, ax) == 0 else None


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_specs(cfg, params_sds: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching ``params_sds`` (ShapeDtypeStruct tree)."""
    data, model = _axes(mesh)

    def one(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd < 2:
            return P()
        keys = _path_keys(path)
        name = keys[-1]
        parent = keys[-2] if len(keys) > 1 else ""
        holder = parent if name in ("w", "b", "m", "s") else name
        shape = leaf.shape
        spec = [None] * nd

        if "embed" in keys:  # [vocab, d_model]
            spec[-2] = _fit(mesh, shape[-2], model)
            return P(*spec)

        row = holder in _ROW_PARALLEL
        if name == "s":
            # scale sidecar [.., K//bk, N]: keep the tiny tensor simple —
            # shard only the output dim of column-parallel owners.
            if not row:
                spec[-1] = _fit(mesh, shape[-1], model)
            return P(*spec)
        if row:
            spec[-2] = _fit(mesh, shape[-2], model)
            spec[-1] = _fit(mesh, shape[-1], data)      # FSDP
        else:
            spec[-1] = _fit(mesh, shape[-1], model)
            spec[-2] = _fit(mesh, shape[-2], data)      # FSDP
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params_sds)


def cache_specs(cfg, cache_sds: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree for decode caches (model.init_cache layout).

    KV buffers [L, B, T, Hk, Dh] shard batch over the data axes and KV
    heads over "model"; recurrent states [L, B, ...] shard batch only;
    ``enc_out`` [B, S, D] shards its leading batch dim.
    """
    data, model = _axes(mesh)

    def one(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        if leaf is None or nd == 0:
            return P()
        keys = _path_keys(path)
        shape = leaf.shape
        spec = [None] * nd
        batch_dim = 0 if (keys and keys[-1] == "enc_out") else min(1, nd - 1)
        spec[batch_dim] = _fit(mesh, shape[batch_dim], data)
        if nd == 5:  # [L, B, T, Hk, Dh]
            spec[3] = _fit(mesh, shape[3], model)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        one, cache_sds, is_leaf=lambda x: x is None)
