"""Distributed-execution utilities: logical-axis sharding annotations,
parameter/cache PartitionSpec trees, and BFP gradient compression.

The model code annotates activations with LOGICAL axis names
(``sharding.shard(x, "batch", "seq", "heads", None)``); the launchers bind
logical names to physical mesh axes with ``sharding.axis_rules``.  Outside
an ``axis_rules`` context every annotation is the identity, so the same
model code runs unmodified on a single CPU host (tests) and on the
production meshes (launch.dryrun / launch.train).

``compress`` carries the BFP gradient wire: ``quantize_leaf`` is the
jit-safe in-graph model, ``pack_leaf``/``wire_report`` the actual
bit-packed bytes (``core.packed.PackedBFP``, DESIGN.md §10) — pinned
bit-exact against each other, padding counted honestly.
"""
