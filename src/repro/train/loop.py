"""Fault-tolerant training loop.

Production posture (DESIGN.md §5):
  * checkpoint/restore with atomic steps + checksum validation,
  * resume from the latest valid step after any crash,
  * straggler watchdog: flags steps slower than ``watchdog_factor`` x the
    running median (on real fleets this feeds the controller that evicts
    the slow host; here it logs and counts),
  * failure injection for tests (``fail_at_step`` raises mid-run exactly
    once, proving the resume path),
  * deterministic data: batch = f(seed, step), so restarts don't replay
    or skip data.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import LMBatchSpec, lm_batch
from repro.train.step import TrainState

__all__ = ["LoopConfig", "run_training"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    keep: int = 3
    watchdog_factor: float = 3.0
    fail_at_step: Optional[int] = None     # failure injection (tests)
    log_every: int = 10


class _SimulatedFailure(RuntimeError):
    pass


def run_training(
    state: TrainState,
    train_step: Callable,
    batch_spec: LMBatchSpec,
    loop: LoopConfig,
    log_fn: Callable[[int, Dict[str, float]], None] = None,
) -> Dict[str, Any]:
    """Run (or resume) training.  Returns summary dict with history."""
    ckpt = store.Checkpointer(loop.ckpt_dir, loop.keep) \
        if loop.ckpt_dir else None
    start = 0
    if loop.ckpt_dir:
        restored, step = store.restore(loop.ckpt_dir, state)
        if restored is not None:
            state, start = restored, int(step)

    history: List[Dict[str, float]] = []
    step_times: List[float] = []
    stragglers = 0
    failed = False

    for step in range(start, loop.total_steps):
        t0 = time.perf_counter()
        if loop.fail_at_step is not None and step == loop.fail_at_step:
            raise _SimulatedFailure(f"injected failure at step {step}")
        batch = lm_batch(batch_spec, step)
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        # --- straggler watchdog ---------------------------------------------
        if len(step_times) >= 5:
            med = float(np.median(step_times[-50:]))
            if dt > loop.watchdog_factor * med:
                stragglers += 1
        step_times.append(dt)
        m = {k: float(v) for k, v in metrics.items()}
        history.append(m)
        if log_fn and step % loop.log_every == 0:
            log_fn(step, m)
        if ckpt and (step + 1) % loop.ckpt_every == 0:
            ckpt.save_async(step + 1, state)
    if ckpt:
        ckpt.wait()
        store.save(loop.ckpt_dir, loop.total_steps, state, loop.keep)
    return {"state": state, "history": history,
            "stragglers_flagged": stragglers,
            "median_step_s": float(np.median(step_times)) if step_times else 0.0}
