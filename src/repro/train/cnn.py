"""Data-parallel BFP CNN training with compressed gradient exchange.

The paper's claim — CNNs tolerate BFP computation error — verified in
TRAINING (DESIGN.md §12.5): forward and backward GEMMs both run on the
BFP engine datapath (``repro.grad`` custom VJPs, grad-path policies),
and the data-parallel gradient exchange is block-formatted over the
packed wire format with error feedback (``repro.dist.compress``).

W logical workers on one host: the global batch splits into W
microbatches, ``jax.vmap(value_and_grad)`` produces per-worker
gradients, each worker compresses ``g + residual`` through the BFP wire
(carrying its own residual), and the decompressed contributions are
averaged — semantically an all-reduce over the compressed wire.  Two
interchangeable exchange routes, pinned bit-exact to each other:

  * the jitted in-graph model (``dist.compress.make_compressor``) — the
    fast training step;
  * the REAL packed bytes (``dist.compress.packed_allreduce``) — eager,
    serializes every worker contribution through the CRC-verified
    :class:`~repro.core.packed.PackedBFP` container and reports actual
    wire bytes.

``train_cnn`` drives steps, measures gradient NSR on the live backward
datapath (``repro.grad.measure_gradient_nsr``) on a schedule, evaluates
accuracy, and optionally round-trips the full train state — INCLUDING
the error-feedback residuals — through ``checkpoint.store``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data.pipeline import image_batch
from repro.dist import compress as DC
from repro.engine.policy_map import PolicyLike
from repro.grad.nsr import GradNSRRecord, measure_gradient_nsr
from repro.models.cnn import MODELS, head_logits
from repro.optim import optimizers as opt

__all__ = ["CnnTrainConfig", "CnnTrainState", "init_state", "data_batch",
           "make_cnn_train_step", "packed_exchange_step", "train_cnn"]


@dataclasses.dataclass(frozen=True)
class CnnTrainConfig:
    """Static training configuration (hashable; closed over by jit)."""

    model: str = "cifarnet"
    workers: int = 2             #: logical data-parallel workers
    batch: int = 64              #: GLOBAL batch (split across workers)
    num_classes: int = 10
    lr: float = 2e-3
    weight_decay: float = 1e-4
    max_grad_norm: float = 1.0
    policy: PolicyLike = None    #: forward+backward datapath policy
    grad_bits: Optional[int] = None   #: wire mantissa bits (None = float
                                      #: exchange, no compression)
    wire_block: int = DC.WIRE_BLOCK
    seed: int = 0

    def __post_init__(self):
        if self.batch % self.workers:
            raise ValueError(f"batch={self.batch} must split across "
                             f"workers={self.workers}")
        if self.grad_bits is not None:
            DC.validate_wire_block(self.wire_block)


class CnnTrainState(NamedTuple):
    params: Any
    opt_state: opt.OptState
    residual: Any        #: per-worker EF residuals, leaves [W, ...]
    step: jax.Array


def _spec(cfg: CnnTrainConfig):
    return MODELS[cfg.model]


def init_state(cfg: CnnTrainConfig, key=None) -> CnnTrainState:
    """Fresh params + optimizer + zero per-worker residuals."""
    key = jax.random.PRNGKey(cfg.seed) if key is None else key
    params = _spec(cfg).init(key, reduced=True,
                             num_classes=cfg.num_classes)
    residual = jax.tree_util.tree_map(
        lambda p: jnp.zeros((cfg.workers,) + p.shape, jnp.float32), params)
    return CnnTrainState(params=params, opt_state=opt.adamw_init(params),
                         residual=residual,
                         step=jnp.zeros((), jnp.int32))


def data_batch(cfg: CnnTrainConfig, step: int, templates=None):
    """Deterministic synthetic batch for ``step`` (templates persist)."""
    spec = _spec(cfg)
    hw, _, ch = spec.input_shape(reduced=True)
    if templates is None:
        _, _, templates = image_batch(
            jax.random.PRNGKey(1234 + cfg.seed), cfg.num_classes, 2, hw, ch)
    x, y, _ = image_batch(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step),
        cfg.num_classes, cfg.batch, hw, ch, templates)
    return x, y, templates


def cnn_loss(params, apply_fn, x, y, policy: PolicyLike,
             num_classes: int) -> jax.Array:
    logits = head_logits(apply_fn(params, x, policy))
    onehot = jax.nn.one_hot(y, num_classes, dtype=jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    return -jnp.mean(jnp.sum(logp * onehot, axis=-1))


def _worker_grads(cfg: CnnTrainConfig, apply_fn, params, x, y):
    """Per-worker (loss, grads): leaves [W, ...]."""
    mb = cfg.batch // cfg.workers
    xs = x.reshape(cfg.workers, mb, *x.shape[1:])
    ys = y.reshape(cfg.workers, mb)

    def loss_fn(p, xw, yw):
        return cnn_loss(p, apply_fn, xw, yw, cfg.policy, cfg.num_classes)

    return jax.vmap(jax.value_and_grad(loss_fn),
                    in_axes=(None, 0, 0))(params, xs, ys)


def _apply_update(cfg: CnnTrainConfig, state: CnnTrainState, mean_g,
                  residual, losses) -> Tuple[CnnTrainState, Dict]:
    g, gnorm = opt.clip_by_global_norm(mean_g, cfg.max_grad_norm)
    params, opt_state = opt.adamw_update(
        g, state.opt_state, state.params, cfg.lr,
        weight_decay=cfg.weight_decay)
    new = CnnTrainState(params, opt_state, residual, state.step + 1)
    return new, {"loss": jnp.mean(losses), "grad_norm": gnorm}


def make_cnn_train_step(cfg: CnnTrainConfig, apply_fn=None):
    """Jit-able ``(state, (x, y)) -> (state, metrics)``.

    Gradient exchange uses the in-graph wire model
    (``dist.compress.make_compressor``) vmapped over workers — bit-exact
    to :func:`packed_exchange_step`, which moves the actual bytes.
    """
    apply_fn = apply_fn or _spec(cfg).apply
    if cfg.grad_bits is not None:
        _, transform = DC.make_compressor(cfg.grad_bits, cfg.wire_block)

    def step_fn(state: CnnTrainState, batch):
        x, y = batch
        losses, grads = _worker_grads(cfg, apply_fn, state.params, x, y)
        if cfg.grad_bits is not None:
            q, residual = jax.vmap(transform)(grads, state.residual)
            mean_g = jax.tree_util.tree_map(lambda t: jnp.mean(t, 0), q)
        else:
            residual = state.residual
            mean_g = jax.tree_util.tree_map(lambda t: jnp.mean(t, 0),
                                            grads)
        return _apply_update(cfg, state, mean_g, residual, losses)

    return step_fn


def packed_exchange_step(cfg: CnnTrainConfig, state: CnnTrainState,
                         batch, apply_fn=None
                         ) -> Tuple[CnnTrainState, Dict]:
    """One eager step exchanging gradients over the REAL packed wire.

    Identical arithmetic to :func:`make_cnn_train_step` (pinned in
    tests/test_train_cnn.py) with the compression routed through
    :func:`dist.compress.packed_allreduce`: every worker contribution is
    serialized, CRC-verified, and counted.  ``metrics["wire_bytes"]``
    reports the measured exchange traffic of this step.
    """
    if cfg.grad_bits is None:
        raise ValueError("packed exchange needs grad_bits (a wire format)")
    apply_fn = apply_fn or _spec(cfg).apply
    x, y = batch
    losses, grads = _worker_grads(cfg, apply_fn, state.params, x, y)
    mean_g, residual, n_bytes = DC.packed_allreduce(
        grads, state.residual, cfg.grad_bits, cfg.wire_block)
    new, metrics = _apply_update(cfg, state, mean_g, residual, losses)
    metrics["wire_bytes"] = n_bytes
    return new, metrics


def evaluate(cfg: CnnTrainConfig, params, templates, batch: int = 256
             ) -> float:
    """Top-1 accuracy on a held-out deterministic eval batch."""
    spec = _spec(cfg)
    hw, _, ch = spec.input_shape(reduced=True)
    x, y, _ = image_batch(jax.random.PRNGKey(999), cfg.num_classes, batch,
                          hw, ch, templates)
    logits = head_logits(spec.apply(params, x, cfg.policy))
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def train_cnn(cfg: CnnTrainConfig, steps: int = 60, *,
              eval_every: int = 0, eval_batch: int = 256,
              measure_nsr_every: int = 0,
              packed_wire_steps: int = 0,
              ckpt_dir: Optional[str] = None,
              jit: bool = True) -> Dict[str, Any]:
    """Train ``cfg.model`` for ``steps`` and report curves + wire bytes.

    Args:
      eval_every: evaluate accuracy every N steps (and always at the
        end); 0 = final only.
      measure_nsr_every: every N steps, additionally run ONE eager
        tapped gradient computation on the current batch (state does not
        advance) and record per-backward-GEMM measured NSR vs bound.
      packed_wire_steps: run the FIRST N steps through the real packed
        wire (:func:`packed_exchange_step`) instead of the jitted model
        — measures actual bytes while training identically (the two
        routes are bit-exact).
      ckpt_dir: when set, save the final state (residuals included)
        there and verify a restore round trip.

    Returns a dict with ``history`` (per-step loss/grad_norm),
    ``accuracy``, ``eval_curve``, ``nsr_records``, ``wire_bytes`` (sum
    over packed steps, plus an analytic per-step report), ``state``.
    """
    state = init_state(cfg)
    _, _, templates = data_batch(cfg, 0)
    step_fn = make_cnn_train_step(cfg)
    if jit:
        step_fn = jax.jit(step_fn)

    history: List[Dict[str, float]] = []
    eval_curve: List[Tuple[int, float]] = []
    nsr_records: List[GradNSRRecord] = []
    wire_bytes = 0

    for i in range(steps):
        x, y, _ = data_batch(cfg, i, templates)

        if measure_nsr_every and i % measure_nsr_every == 0:
            params = state.params

            def grad_once():
                def loss_fn(p):
                    return cnn_loss(p, _spec(cfg).apply, x, y, cfg.policy,
                                    cfg.num_classes)
                jax.grad(loss_fn)(params)

            nsr_records.extend(measure_gradient_nsr(grad_once))

        if cfg.grad_bits is not None and i < packed_wire_steps:
            state, metrics = packed_exchange_step(cfg, state, (x, y))
            wire_bytes += metrics.pop("wire_bytes")
        else:
            state, metrics = step_fn(state, (x, y))
        history.append({k: float(v) for k, v in metrics.items()})

        if eval_every and (i + 1) % eval_every == 0 and i + 1 < steps:
            eval_curve.append((i + 1,
                               evaluate(cfg, state.params, templates,
                                        eval_batch)))

    acc = evaluate(cfg, state.params, templates, eval_batch)
    eval_curve.append((steps, acc))

    if ckpt_dir is not None:
        from repro.checkpoint import store
        store.save(ckpt_dir, int(state.step), state)
        restored, rstep = store.restore(ckpt_dir, state)
        assert rstep == int(state.step)
        state = restored

    wire = None
    if cfg.grad_bits is not None:
        # analytic per-step exchange bytes (all workers) + float baseline
        g_like = jax.tree_util.tree_map(lambda p: p, state.params)
        rep = DC.wire_report(g_like, cfg.grad_bits, cfg.wire_block)
        wire = {"measured_bytes": wire_bytes,
                "packed_steps": min(packed_wire_steps, steps),
                "per_step_bytes": rep["wire_bytes"] * cfg.workers,
                "float_per_step_bytes": rep["float_bytes"] * cfg.workers,
                "ratio": rep["ratio"]}

    return {"history": history, "accuracy": acc, "eval_curve": eval_curve,
            "nsr_records": nsr_records, "wire_bytes": wire,
            "state": state}
