"""Training step factory: loss, grad accumulation, remat, optimizer, BFP.

``make_train_step(cfg, ...)`` returns a pure ``(state, batch) -> (state,
metrics)`` suitable for jit/pjit.  Microbatching runs as lax.scan over
gradient-accumulation chunks (constant memory in the number of chunks).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.engine import PolicyLike
from repro.models.lm import model as Mdl
from repro.optim import optimizers as opt

__all__ = ["TrainState", "make_train_step", "lm_loss"]


class TrainState(NamedTuple):
    params: Any
    opt_state: opt.OptState
    step: jax.Array


def lm_loss(params, cfg: LMConfig, tokens, targets, policy=None,
            enc_feats=None, aux_weight: float = 0.01,
            z_weight: float = 1e-4) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy + MoE aux + z-loss."""
    logits, aux = Mdl.forward(params, cfg, tokens, enc_feats=enc_feats,
                              policy=policy)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # one-hot contraction instead of gather: with vocab sharded over the
    # model axis this is a local partial sum + psum (no logits all-gather)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = jnp.mean(logz - ll)
    zloss = jnp.mean(jnp.square(logz))
    loss = nll + aux_weight * aux + z_weight * zloss
    return loss, {"nll": nll, "aux": aux, "zloss": zloss}


def init_state(cfg: LMConfig, key) -> TrainState:
    params = Mdl.init_params(cfg, key)
    return TrainState(params=params, opt_state=opt.adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(
    cfg: LMConfig,
    lr_schedule: Callable = None,
    grad_accum: int = 1,
    max_grad_norm: float = 1.0,
    policy: PolicyLike = None,
    weight_decay: float = 0.1,
    grad_transform: Optional[Callable[[Any], Any]] = None,
) -> Callable[[TrainState, Tuple[jax.Array, jax.Array]],
              Tuple[TrainState, Dict[str, jax.Array]]]:
    """Build the train step.

    policy: None / BFPPolicy / repro.engine.PolicyMap — BFP-QAT with a
    uniform or per-layer datapath assignment.
    grad_transform: optional hook applied to the accumulated grads BEFORE
    the optimizer — used for BFP gradient compression (dist.compress).
    """
    lr_schedule = lr_schedule or opt.constant_schedule(3e-4)

    def loss_fn(params, tokens, targets):
        return lm_loss(params, cfg, tokens, targets, policy=policy)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        tokens, targets = batch
        if grad_accum > 1:
            b = tokens.shape[0]
            mb = b // grad_accum
            tk = tokens.reshape(grad_accum, mb, -1)
            tg = targets.reshape(grad_accum, mb, -1)

            def accum(carry, xs):
                gsum, lsum = carry
                (l, _), g = grad_fn(state.params, xs[0], xs[1])
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(accum, (zeros, 0.0), (tk, tg))
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics: Dict[str, jax.Array] = {}
        else:
            (loss, metrics), grads = grad_fn(state.params, tokens, targets)

        if grad_transform is not None:
            grads = grad_transform(grads)
        grads, gnorm = opt.clip_by_global_norm(grads, max_grad_norm)
        lr = lr_schedule(state.step)
        params, opt_state = opt.adamw_update(
            grads, state.opt_state, state.params, lr,
            weight_decay=weight_decay)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update(metrics)
        return new_state, out

    return train_step
