"""Pure-jnp oracles for the Pallas BFP kernels.

Semantics contract shared by kernel and oracle (DESIGN.md §6):

  * block exponent  e = floor(log2 max|x|) per (row, K-tile) of x and per
    (column, K-tile) of w  (Scheme.TILED with block_k = the kernel K tile)
  * mantissa        m = clip(round(x / 2^(e-(L-2))), -(2^(L-1)-1), ...)
  * product         int32 dot of int8 mantissas per K-tile (exact)
  * rescale         partial * 2^(ex-(L_I-2)) * 2^(ew-(L_W-2)), fp32 accumulate

The oracles are deliberately independent re-implementations (not calls into
repro.core) so kernel, oracle, and core library triangulate each other.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_ZERO_BLOCK_EXP = -126


def pow2(e):
    """Exact float32 2^e for integer e (jnp.exp2 is ~1 ulp off at many
    negative integer exponents): exponent-field construction for the
    normal range, mantissa-bit construction for denormals — shifts +
    bitcast only, TPU-lowerable.  Deliberately independent copy of
    repro.core.bfp.pow2 (the oracle must not call into core)."""
    e = jnp.asarray(e).astype(jnp.int32)
    normal = (jnp.clip(e, -126, 127) + 127) << 23
    subnorm = jnp.int32(1) << jnp.clip(e + 149, 0, 22)
    bits = jnp.where(e >= -126, normal, subnorm)
    bits = jnp.where(e < -149, 0, bits)
    bits = jnp.where(e > 127, 0x7F800000, bits)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _floor_log2(amax: jax.Array) -> jax.Array:
    """floor(log2 x) for x >= 0 via exponent-field extraction (bit-exact)."""
    bits = jax.lax.bitcast_convert_type(amax.astype(jnp.float32), jnp.uint32)
    e = (jnp.right_shift(bits, jnp.uint32(23)) & jnp.uint32(0xFF)).astype(
        jnp.int32) - 127
    return jnp.where(amax > 0, e, _ZERO_BLOCK_EXP)


def quantize_tile(x: jax.Array, bits: int, axis: int) -> Tuple[jax.Array, jax.Array]:
    """Block-format along ``axis`` (whole axis = one block). -> (m, e)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    e = _floor_log2(amax)
    step = pow2(e - (bits - 2))
    lim = float(2 ** (bits - 1) - 1)
    m = jnp.clip(jnp.round(x.astype(jnp.float32) / step), -lim, lim)
    return m.astype(jnp.int8 if bits <= 8 else jnp.int32), e


def bfp_quantize_ref(x: jax.Array, bits: int, block_k: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the standalone quantize kernel.

    x: [M, K] -> mantissa [M, K] (int8), exponents [M, K//block_k] (int32).
    Blocks are per (row, K-tile).
    """
    m_rows, k = x.shape
    assert k % block_k == 0
    xr = x.reshape(m_rows, k // block_k, block_k)
    m, e = quantize_tile(xr, bits, axis=2)
    return m.reshape(m_rows, k), e.reshape(m_rows, k // block_k)


def bfp_conv2d_ref(x: jax.Array, w_hwio: jax.Array, l_i: int, l_w: int,
                   block_k: int, stride: int = 1,
                   padding: str = "SAME") -> jax.Array:
    """Oracle for the fused implicit-im2col conv kernels.

    Materializes the patch matrix the slow, obvious way — explicit
    Python loops over (di, dj) offsets in HWIO-major K-order
    (k = (di*kw + dj)*C + c), zero K-padding to a ``block_k`` multiple —
    then reuses :func:`bfp_matmul_ref`.  Deliberately independent of
    ``core.conv_utils`` / ``lax.conv_general_dilated_patches`` so kernel,
    oracle, and core library triangulate.
    """
    b, h, w_in, c = x.shape
    kh, kw, _, oc = w_hwio.shape
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w_in // stride)
        ph = max((oh - 1) * stride + kh - h, 0)
        pw = max((ow - 1) * stride + kw - w_in, 0)
        pt, plf = ph // 2, pw // 2
        xp = jnp.pad(x, ((0, 0), (pt, ph - pt), (plf, pw - plf), (0, 0)))
    else:
        assert padding == "VALID"
        oh, ow = (h - kh) // stride + 1, (w_in - kw) // stride + 1
        xp = x
    slabs = []
    for di in range(kh):
        for dj in range(kw):
            slabs.append(jax.lax.slice(
                xp, (0, di, dj, 0),
                (b, di + (oh - 1) * stride + 1, dj + (ow - 1) * stride + 1,
                 c), (1, stride, stride, 1)))          # [B, OH, OW, C]
    patches = jnp.stack(slabs, axis=3)                 # [B,OH,OW,kh*kw,C]
    cols = patches.reshape(b * oh * ow, kh * kw * c)
    k = kh * kw * c
    kp = -(-k // block_k) * block_k
    cols = jnp.pad(cols, ((0, 0), (0, kp - k)))
    wmat = jnp.pad(w_hwio.reshape(k, oc), ((0, kp - k), (0, 0)))
    out = bfp_matmul_ref(cols, wmat, l_i, l_w, block_k)
    return out.reshape(b, oh, ow, oc)


def bfp_matmul_ref(x: jax.Array, w: jax.Array, l_i: int, l_w: int,
                   block_k: int) -> jax.Array:
    """Oracle for the fused BFP matmul kernel.

    x: [B, K] fp, w: [K, N] fp -> [B, N] fp32.  Per-(row, K-tile) blocks on
    x, per-(column, K-tile) blocks on w, exact int32 tile dots, fp32
    sequential accumulation over K-tiles (kernel order).
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2 and k % block_k == 0
    t = k // block_k
    out = jnp.zeros((b, n), jnp.float32)
    for ti in range(t):
        xs = x[:, ti * block_k:(ti + 1) * block_k]
        ws = w[ti * block_k:(ti + 1) * block_k, :]
        mx, ex = quantize_tile(xs, l_i, axis=1)          # [B,bk], [B,1]
        mw, ew = quantize_tile(ws, l_w, axis=0)          # [bk,N], [1,N]
        part = jax.lax.dot(mx.astype(jnp.int32), mw.astype(jnp.int32),
                           preferred_element_type=jnp.int32)
        sx = pow2(ex - (l_i - 2))
        sw = pow2(ew - (l_w - 2))
        out = out + part.astype(jnp.float32) * (sx * sw)
    return out
