"""Pure-jnp oracles for the Pallas BFP kernels.

Semantics contract shared by kernel and oracle (DESIGN.md §6):

  * block exponent  e = floor(log2 max|x|) per (row, K-tile) of x and per
    (column, K-tile) of w  (Scheme.TILED with block_k = the kernel K tile)
  * mantissa        m = clip(round(x / 2^(e-(L-2))), -(2^(L-1)-1), ...)
  * product         int32 dot of int8 mantissas per K-tile (exact)
  * rescale         partial * 2^(ex-(L_I-2)) * 2^(ew-(L_W-2)), fp32 accumulate

The oracles are deliberately independent re-implementations (not calls into
repro.core) so kernel, oracle, and core library triangulate each other.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_ZERO_BLOCK_EXP = -126


def _floor_log2(amax: jax.Array) -> jax.Array:
    """floor(log2 x) for x >= 0 via exponent-field extraction (bit-exact)."""
    bits = jax.lax.bitcast_convert_type(amax.astype(jnp.float32), jnp.uint32)
    e = (jnp.right_shift(bits, jnp.uint32(23)) & jnp.uint32(0xFF)).astype(
        jnp.int32) - 127
    return jnp.where(amax > 0, e, _ZERO_BLOCK_EXP)


def quantize_tile(x: jax.Array, bits: int, axis: int) -> Tuple[jax.Array, jax.Array]:
    """Block-format along ``axis`` (whole axis = one block). -> (m, e)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    e = _floor_log2(amax)
    step = jnp.exp2((e - (bits - 2)).astype(jnp.float32))
    lim = float(2 ** (bits - 1) - 1)
    m = jnp.clip(jnp.round(x.astype(jnp.float32) / step), -lim, lim)
    return m.astype(jnp.int8 if bits <= 8 else jnp.int32), e


def bfp_quantize_ref(x: jax.Array, bits: int, block_k: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the standalone quantize kernel.

    x: [M, K] -> mantissa [M, K] (int8), exponents [M, K//block_k] (int32).
    Blocks are per (row, K-tile).
    """
    m_rows, k = x.shape
    assert k % block_k == 0
    xr = x.reshape(m_rows, k // block_k, block_k)
    m, e = quantize_tile(xr, bits, axis=2)
    return m.reshape(m_rows, k), e.reshape(m_rows, k // block_k)


def bfp_matmul_ref(x: jax.Array, w: jax.Array, l_i: int, l_w: int,
                   block_k: int) -> jax.Array:
    """Oracle for the fused BFP matmul kernel.

    x: [B, K] fp, w: [K, N] fp -> [B, N] fp32.  Per-(row, K-tile) blocks on
    x, per-(column, K-tile) blocks on w, exact int32 tile dots, fp32
    sequential accumulation over K-tiles (kernel order).
    """
    b, k = x.shape
    k2, n = w.shape
    assert k == k2 and k % block_k == 0
    t = k // block_k
    out = jnp.zeros((b, n), jnp.float32)
    for ti in range(t):
        xs = x[:, ti * block_k:(ti + 1) * block_k]
        ws = w[ti * block_k:(ti + 1) * block_k, :]
        mx, ex = quantize_tile(xs, l_i, axis=1)          # [B,bk], [B,1]
        mw, ew = quantize_tile(ws, l_w, axis=0)          # [bk,N], [1,N]
        part = jax.lax.dot(mx.astype(jnp.int32), mw.astype(jnp.int32),
                           preferred_element_type=jnp.int32)
        sx = jnp.exp2((ex - (l_i - 2)).astype(jnp.float32))
        sw = jnp.exp2((ew - (l_w - 2)).astype(jnp.float32))
        out = out + part.astype(jnp.float32) * (sx * sw)
    return out
