"""Standalone block-formatting Pallas kernel (paper eq. 1).

Streams an [M, K] float tensor through VMEM in (bm, bk) tiles and emits
int8 mantissas plus one int32 exponent per (row, K-tile) block — the
"block formatting" stage of the paper's accelerator, used when weights are
formatted once offline and streamed to HBM as int8 + exponent sidecar
(4x HBM traffic cut at L=8, the paper's bandwidth argument).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bfp import pow2

_ZERO_BLOCK_EXP = -126



def _bfp_quantize_kernel(x_ref, m_ref, e_ref, *, bits: int):
    tile = x_ref[...]
    amax = jnp.max(jnp.abs(tile), axis=1, keepdims=True)
    fbits = jax.lax.bitcast_convert_type(amax.astype(jnp.float32), jnp.uint32)
    e = (jnp.right_shift(fbits, jnp.uint32(23)) & jnp.uint32(0xFF)).astype(
        jnp.int32) - 127
    e = jnp.where(amax > 0, e, _ZERO_BLOCK_EXP)
    step = pow2(e - (bits - 2))
    lim = float(2 ** (bits - 1) - 1)
    m = jnp.clip(jnp.round(tile.astype(jnp.float32) / step), -lim, lim)
    m_ref[...] = m.astype(jnp.int8)  # quantize kernel is the L<=8 streaming path
    e_ref[...] = e


@functools.partial(jax.jit, static_argnames=("bits", "bm", "bk", "interpret"))
def bfp_quantize_pallas(x: jax.Array, *, bits: int = 8, bm: int = 256,
                        bk: int = 512, interpret: bool = False):
    """[M, K] -> (int8 mantissa [M, K], int32 exponents [M, K//bk]).

    Each (row, bk-tile) is one BFP block.  M % bm == 0 and K % bk == 0
    (ops.py pads).
    """
    m_rows, k = x.shape
    if m_rows % bm or k % bk:
        raise ValueError(f"shape {x.shape} not a multiple of ({bm},{bk})")
    grid = (m_rows // bm, k // bk)
    kernel = functools.partial(_bfp_quantize_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_rows, k), jnp.int8),
            jax.ShapeDtypeStruct((m_rows, k // bk), jnp.int32),
        ],
        interpret=interpret,
    )(x)
