"""Pallas TPU kernels for the BFP datapath (validated with interpret=True)."""
