"""Public jit'd wrappers around the Pallas BFP kernels.

Handles shape padding to tile multiples, CPU-interpret dispatch (this
container has no TPU; ``interpret=True`` runs the kernel body in Python),
and policy plumbing.  The contract is identical to the emulated path in
``repro.core.bfp_dot`` with Scheme.TILED and ``block_k == bk`` — tests
assert all three (kernel, ref oracle, core library) agree.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import BFPPolicy
from repro.kernels.bfp_matmul import bfp_matmul_pallas
from repro.kernels.bfp_quantize import bfp_quantize_pallas

__all__ = ["bfp_matmul", "bfp_quantize", "default_tiles"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: Tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def default_tiles(b: int, k: int, n: int,
                  block_k: Optional[int]) -> Tuple[int, int, int]:
    """Pick MXU-aligned tile sizes.

    bm/bn: 128 (MXU dimension) unless the problem is smaller; bk: the BFP
    block size when given (must be the K tile so block == tile), else 512.
    """
    bm = min(128, max(8, 1 << (b - 1).bit_length())) if b < 128 else 128
    bn = min(128, max(128, 0)) if n >= 128 else max(8, 1 << (n - 1).bit_length())
    bk = block_k or min(512, max(128, 1 << (k - 1).bit_length()) if k < 512 else 512)
    return bm, bn, bk


def bfp_matmul(x2d: jax.Array, w: jax.Array, policy: BFPPolicy,
               interpret: Optional[bool] = None) -> jax.Array:
    """x2d[B,K] @ w[K,N] via the fused Pallas kernel (Scheme.TILED).

    Pads every dim to tile multiples (zero K-padding is exact: zero
    mantissas contribute nothing; padded rows/cols are sliced off).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, k = x2d.shape
    n = w.shape[1]
    bm, bn, bk = default_tiles(b, k, n, policy.block_k)
    xp = _pad_to(x2d.astype(jnp.float32), (bm, bk))
    wp = _pad_to(w.astype(jnp.float32), (bk, bn))
    out = bfp_matmul_pallas(xp, wp, l_i=policy.l_i, l_w=policy.l_w,
                            bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:b, :n]


def bfp_quantize(x: jax.Array, bits: int, block_k: int,
                 interpret: Optional[bool] = None):
    """[M,K] -> (mantissa int8 [M,K], exps int32 [M,ceil(K/bk)]) padded-safe."""
    if interpret is None:
        interpret = not _on_tpu()
    m_rows, k = x.shape
    bm = 256 if m_rows >= 256 else max(8, 1 << (m_rows - 1).bit_length())
    xp = _pad_to(x.astype(jnp.float32), (bm, block_k))
    m, e = bfp_quantize_pallas(xp, bits=bits, bm=bm, bk=block_k,
                               interpret=interpret)
    return m[:m_rows, :k], e[:m_rows, : -(-k // block_k)]
