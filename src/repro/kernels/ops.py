"""Public jit'd wrappers around the Pallas BFP kernels.

Handles shape padding to tile multiples, CPU-interpret dispatch (this
container has no TPU; ``interpret=True`` runs the kernel body in Python),
tile selection (autotune cache -> fallback table), and policy plumbing.
The contract is identical to the emulated path in ``repro.core.bfp_dot``
with Scheme.TILED and ``block_k == bk`` — tests assert all three
(kernel, ref oracle, core library) agree.  Model code reaches these
through ``repro.engine`` (backend "pallas"), never directly.

ISSUE 6 additions, all bit-preserving:

* Tile selection consults the ACTIVE autotune cache
  (``repro.tune.set_cache`` / a Plan's bound cache) before the fallback
  table; explicit ``tiles=`` overrides both (the autotuner's measuring
  hook).
* ``x2d``/``x`` may be an activation-prequant dict ``{"m","s"}``
  (``core.prequant.prequant_act`` wire: int8 mantissa + per-(row,
  K-chunk) steps) — produced by a previous layer's fused epilogue; the
  kernel consumes it without dequantizing.
* ``out_policy=`` requests epilogue requantization: the kernel emits
  the NEXT layer's activation-prequant input straight from the fp32
  accumulator when the blocks line up (``out_policy.block_k`` divides
  both N and the N tile); otherwise the wrapper falls back to the
  bit-identical two-step (store f32, ``prequant_act``) — callers always
  get the same dict either way.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.conv_utils import conv_geometry, conv_weight_matrix
from repro.core.policy import BFPPolicy
from repro.core.prequant import act_block, is_prequant, prequant_act
from repro.kernels.bfp_conv import (bfp_conv2d_pallas,
                                    bfp_conv2d_prequant_pallas,
                                    bfp_conv2d_xprequant_pallas,
                                    bfp_conv2d_xwprequant_pallas)
from repro.kernels.bfp_matmul import (bfp_matmul_pallas,
                                      bfp_matmul_prequant_pallas,
                                      bfp_matmul_xprequant_pallas,
                                      bfp_matmul_xwprequant_pallas)
from repro.kernels.bfp_quantize import bfp_quantize_pallas
from repro.tune import cache as _tune
from repro.tune.tables import aligned_tile, conv_row_tile, fallback_tiles

__all__ = ["bfp_matmul", "bfp_matmul_prequant", "bfp_conv2d",
           "bfp_conv2d_prequant", "bfp_quantize", "default_tiles",
           "aligned_tile"]

ActOrArray = Union[jax.Array, dict]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: Tuple[int, ...],
            values=0.0) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads, constant_values=values)
    return x


def default_tiles(b: int, k: int, n: int, block_k: Optional[int],
                  l_sum: int = 16) -> Tuple[int, int, int]:
    """MXU-aligned default tiles — delegates to THE shared fallback
    table (:func:`repro.tune.tables.fallback_tiles`), the single default
    path for fused and prequant kernels alike (ISSUE 6)."""
    return fallback_tiles(b, k, n, block_k, l_sum)


def _gemm_tiles(b: int, k: int, n: int, policy: BFPPolicy,
                interpret: bool, tiles, bk_pin: Optional[int]):
    """(bm, bn, bk) for a GEMM site: explicit ``tiles`` > active tune
    cache > fallback table.  ``bk_pin`` (a prequant sidecar's block)
    overrides whatever bk the source proposed."""
    if tiles is not None:
        bm, bn, bk = tiles
    else:
        looked = _tune.lookup_tiles("gemm", b, k, n, policy.l_i,
                                    policy.l_w, policy.block_k, interpret)
        bm, bn, bk = looked if looked is not None else fallback_tiles(
            b, k, n, policy.block_k, policy.l_w + policy.l_i)
    if bk_pin is not None:
        if tiles is not None and bk != bk_pin:
            raise ValueError(f"tiles bk={bk} != prequant block {bk_pin}")
        bk = bk_pin
    return bm, bn, bk


def _act_ops(x2d: dict, bm: int, bk: int) -> Tuple[jax.Array, jax.Array]:
    """Pad an activation-prequant dict's pieces for the kernel.  Mantissa
    rows pad with 0 (inert), step rows with 1.0 (finite, inert)."""
    xm = _pad_to(x2d["m"], (bm, bk))
    xs = _pad_to(x2d["s"].astype(jnp.float32), (bm, 1), values=1.0)
    return xm, xs


def _epilogue_cfg(out_policy: Optional[BFPPolicy], n: int, bn: int):
    """(out_bits, out_block) when the kernel can emit the consumer's
    activation blocks directly; None -> two-step fallback in the
    wrapper (bit-identical either way)."""
    if out_policy is None:
        return None
    bq = out_policy.block_k
    if bq and out_policy.l_i <= 8 and n % bq == 0 and bn % bq == 0:
        return (out_policy.l_i, bq)
    return None


def _finish_gemm(out, b: int, n: int, out_policy: Optional[BFPPolicy],
                 fused_q) -> ActOrArray:
    """Slice padding off; requantize two-step when the epilogue wasn't
    fused."""
    if fused_q is not None:
        m, s = out
        return {"m": m[:b, :n], "s": s[:b, :n // fused_q[1]]}
    out = out[:b, :n]
    if out_policy is not None:
        return prequant_act(out, out_policy)
    return out


def bfp_matmul(x2d: ActOrArray, w: jax.Array, policy: BFPPolicy,
               interpret: Optional[bool] = None, *,
               out_policy: Optional[BFPPolicy] = None,
               tiles: Optional[Tuple[int, int, int]] = None,
               dot_impl: str = "auto", pipeline: bool = True) -> ActOrArray:
    """x2d[B,K] @ w[K,N] via the fused Pallas kernel (Scheme.TILED).

    Pads every dim to tile multiples (zero K-padding is exact: zero
    mantissas contribute nothing; padded rows/cols are sliced off).
    ``x2d`` may be an activation-prequant dict (previous layer's
    epilogue output); ``out_policy`` requests requantized {"m","s"}
    output for the NEXT layer.  ``dot_impl``/``pipeline`` pass through
    to the kernel (benchmarks/tests force the legacy ``"int32"`` +
    unpipelined datapath; every combination is bit-identical).
    """
    if interpret is None:
        interpret = not _on_tpu()
    x_pq = is_prequant(x2d)
    if x_pq:
        b, k = x2d["m"].shape
        bk_pin = act_block(x2d)
        if policy.block_k not in (None, bk_pin):
            raise ValueError(f"policy.block_k={policy.block_k} != "
                             f"activation prequant block {bk_pin}")
    else:
        b, k = x2d.shape
        bk_pin = None
    n = w.shape[1]
    bm, bn, bk = _gemm_tiles(b, k, n, policy, interpret, tiles, bk_pin)
    fused_q = _epilogue_cfg(out_policy, n, bn)
    ob, obk = fused_q if fused_q is not None else (None, None)
    wp = _pad_to(w.astype(jnp.float32), (bk, bn))
    if x_pq:
        xm, xs = _act_ops(x2d, bm, bk)
        out = bfp_matmul_xprequant_pallas(
            xm, xs, wp, l_i=policy.l_i, l_w=policy.l_w, bm=bm, bn=bn,
            bk=bk, interpret=interpret, dot_impl=dot_impl,
            pipeline=pipeline, out_bits=ob, out_block=obk)
    else:
        xp = _pad_to(x2d.astype(jnp.float32), (bm, bk))
        out = bfp_matmul_pallas(
            xp, wp, l_i=policy.l_i, l_w=policy.l_w, bm=bm, bn=bn, bk=bk,
            interpret=interpret, dot_impl=dot_impl, pipeline=pipeline,
            out_bits=ob, out_block=obk)
    return _finish_gemm(out, b, n, out_policy, fused_q)


def bfp_matmul_prequant(x2d: ActOrArray, wm: jax.Array, ws: jax.Array,
                        policy: BFPPolicy,
                        interpret: Optional[bool] = None, *,
                        out_policy: Optional[BFPPolicy] = None,
                        tiles: Optional[Tuple[int, int, int]] = None,
                        dot_impl: str = "auto",
                        pipeline: bool = True) -> ActOrArray:
    """x2d[B,K] @ prequant weight via the sidecar-consuming kernel.

    ``wm``: int8 mantissa [K, N]; ``ws``: f32 power-of-two steps
    [K//bk, N] (core.prequant wire format).  The prequant block size IS
    the kernel K tile, so K needs no padding (it is a bk multiple by
    construction); B and N pad to tile multiples.  Scale padding uses 1.0
    — padded mantissas are zero, so the value is inert but stays finite.
    ``x2d`` may be an activation-prequant dict with the SAME block size.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x_pq = is_prequant(x2d)
    b, k = (x2d["m"] if x_pq else x2d).shape
    n = wm.shape[1]
    t = ws.shape[0]
    if t == 0 or k % t:
        raise ValueError(f"sidecar {ws.shape} incompatible with K={k}")
    bk_pin = k // t
    if policy.block_k not in (None, bk_pin):
        # same contract as the emulated path: a sidecar blocked at bk
        # cannot honour a policy asking for different blocks
        raise ValueError(f"policy.block_k={policy.block_k} != prequant "
                         f"block {bk_pin}")
    if x_pq and act_block(x2d) != bk_pin:
        raise ValueError(f"activation prequant block {act_block(x2d)} != "
                         f"weight prequant block {bk_pin}")
    bm, bn, bk = _gemm_tiles(b, k, n, policy, interpret, tiles, bk_pin)
    fused_q = _epilogue_cfg(out_policy, n, bn)
    ob, obk = fused_q if fused_q is not None else (None, None)
    wmp = _pad_to(wm, (bk, bn))
    wsp = _pad_to(ws.astype(jnp.float32), (1, bn), values=1.0)
    if x_pq:
        xm, xs = _act_ops(x2d, bm, bk)
        out = bfp_matmul_xwprequant_pallas(
            xm, xs, wmp, wsp, l_i=policy.l_i, l_w=policy.l_w, bm=bm,
            bn=bn, bk=bk, interpret=interpret, dot_impl=dot_impl,
            pipeline=pipeline, out_bits=ob, out_block=obk)
    else:
        xp = _pad_to(x2d.astype(jnp.float32), (bm, bk))
        out = bfp_matmul_prequant_pallas(
            xp, wmp, wsp, l_i=policy.l_i, l_w=policy.l_w, bm=bm, bn=bn,
            bk=bk, interpret=interpret, dot_impl=dot_impl,
            pipeline=pipeline, out_bits=ob, out_block=obk)
    return _finish_gemm(out, b, n, out_policy, fused_q)


def _conv_plan(b: int, h: int, w_in: int, c: int, kh: int, kw: int,
               oc: int, stride: int, padding: str, bk: int,
               t_oh: Optional[int] = None, bn: Optional[int] = None):
    """Static geometry + tiling for the fused conv kernels.

    Returns (pads for x, (oh, ow, ohp, t_oh, bn, kp)).  The padded input
    covers conv padding PLUS the kernel's alignment contract
    (Hp >= s*OHp + kh - 1, Wp >= s*OW + kw - 1); extra zero rows/cols are
    only read by padded output rows, which callers slice off.  ``t_oh``
    and ``bn`` override the defaults (autotuned or explicit tiles).
    """
    oh, ow, (pt, pb), (plf, pr) = conv_geometry(h, w_in, kh, kw, stride,
                                                padding)
    if t_oh is None:
        t_oh = conv_row_tile(oh, ow)
    t_oh = min(t_oh, oh)
    ohp = -(-oh // t_oh) * t_oh
    hp = max(stride * ohp + kh - 1, pt + h + pb)
    wp = max(stride * ow + kw - 1, plf + w_in + pr)
    if bn is None:
        bn = aligned_tile(oc)
    kp = -(-(kh * kw * c) // bk) * bk
    pads = ((0, 0), (pt, hp - h - pt), (plf, wp - w_in - plf), (0, 0))
    return pads, (oh, ow, ohp, t_oh, bn, kp)


def _conv_tiles(rows: int, k: int, oc: int, policy: BFPPolicy,
                interpret: bool, tiles):
    """(t_oh, bn) overrides for a conv site: explicit ``tiles`` > active
    tune cache > None (plan defaults).  Keys on the im2col GEMM view."""
    if tiles is not None:
        return tiles
    looked = _tune.lookup_tiles("conv", rows, k, oc, policy.l_i,
                                policy.l_w, policy.block_k, interpret)
    return looked if looked is not None else (None, None)


def _conv_epilogue_cfg(out_policy: Optional[BFPPolicy], oc: int, bn: int):
    if out_policy is None:
        return None
    bq = out_policy.block_k
    if bq and out_policy.l_i <= 8 and oc % bq == 0 and bn % bq == 0:
        return (out_policy.l_i, bq)
    return None


def _finish_conv(out, oh: int, oc: int,
                 out_policy: Optional[BFPPolicy], fused_q) -> ActOrArray:
    if fused_q is not None:
        m, s = out
        return {"m": m[:, :oh, :, :oc],
                "s": s[:, :oh, :, :oc // fused_q[1]]}
    out = out[:, :oh, :, :oc]
    if out_policy is not None:
        return prequant_act(out, out_policy)
    return out


def _conv_x_prequant_check(x: dict, c: int, bk: int, policy: BFPPolicy):
    bk_act = act_block(x)
    if policy.block_k not in (None, bk_act):
        raise ValueError(f"policy.block_k={policy.block_k} != activation "
                         f"prequant block {bk_act}")
    if bk_act != bk or c % bk:
        raise ValueError(f"conv activation prequant needs block_k | C "
                         f"(block {bk_act}, C={c})")


def _pad_act_nhwc(x: dict, pads) -> Tuple[jax.Array, jax.Array]:
    """Spatial-pad an NHWC activation-prequant dict: mantissa pads 0
    (inert), steps pad 1.0 (finite, inert — padded pixels' mantissas are
    all zero)."""
    xm = jnp.pad(x["m"], pads)
    xs = jnp.pad(x["s"].astype(jnp.float32), pads, constant_values=1.0)
    return xm, xs


def bfp_conv2d(x: ActOrArray, w_hwio: jax.Array, policy: BFPPolicy,
               stride: int = 1, padding: str = "SAME",
               interpret: Optional[bool] = None, *,
               out_policy: Optional[BFPPolicy] = None,
               tiles: Optional[Tuple[int, int]] = None,
               dot_impl: str = "auto", pipeline: bool = True) -> ActOrArray:
    """NHWC conv through the fused implicit-im2col kernel (Scheme.TILED).

    x: [B, H, W, C] float — or an activation-prequant dict (int8 NHWC
    mantissa + per-(pixel, C-chunk) steps, the conv epilogue wire
    format; requires ``block_k | C``); w_hwio: [kh, kw, C, OC] float.
    The K tile ``policy.block_k`` IS the BFP block (whole-K when None);
    K zero-pads to a tile multiple exactly like ops.bfp_matmul, so the
    result is bit-identical to im2col + the fused GEMM kernel.
    ``out_policy`` requests the epilogue-requantized {"m","s"} output.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x_pq = is_prequant(x)
    b, h, w_in, c = (x["m"] if x_pq else x).shape
    kh, kw, c2, oc = w_hwio.shape
    if c != c2:
        raise ValueError(f"channel mismatch: x "
                         f"{(x['m'] if x_pq else x).shape} vs w "
                         f"{w_hwio.shape}")
    bk = policy.block_k or (act_block(x) if x_pq else kh * kw * c)
    if x_pq:
        _conv_x_prequant_check(x, c, bk, policy)
    t_oh, bn = _conv_tiles(b * h * w_in, kh * kw * c, oc, policy,
                           interpret, tiles)
    pads, (oh, ow, ohp, t_oh, bn, kp) = _conv_plan(
        b, h, w_in, c, kh, kw, oc, stride, padding, bk, t_oh, bn)
    fused_q = _conv_epilogue_cfg(out_policy, oc, bn)
    ob, obk = fused_q if fused_q is not None else (None, None)
    w2d = conv_weight_matrix(w_hwio.astype(jnp.float32))
    w2d = _pad_to(w2d, (kp, bn))
    kwargs = dict(kh=kh, kw=kw, stride=stride, t_oh=t_oh, ohp=ohp, ow=ow,
                  bn=bn, bk=bk, l_i=policy.l_i, l_w=policy.l_w,
                  interpret=interpret, dot_impl=dot_impl,
                  pipeline=pipeline, out_bits=ob, out_block=obk)
    if x_pq:
        xm, xs = _pad_act_nhwc(x, pads)
        out = bfp_conv2d_xprequant_pallas(xm, xs, w2d, **kwargs)
    else:
        xp = jnp.pad(x.astype(jnp.float32), pads)
        out = bfp_conv2d_pallas(xp, w2d, **kwargs)
    return _finish_conv(out, oh, oc, out_policy, fused_q)


def bfp_conv2d_prequant(x: ActOrArray, wm_hwio: jax.Array, ws: jax.Array,
                        policy: BFPPolicy, stride: int = 1,
                        padding: str = "SAME",
                        interpret: Optional[bool] = None, *,
                        out_policy: Optional[BFPPolicy] = None,
                        tiles: Optional[Tuple[int, int]] = None,
                        dot_impl: str = "auto",
                        pipeline: bool = True) -> ActOrArray:
    """NHWC conv with pre-quantized weights (int8 HWIO mantissa + GEMM-view
    step sidecar [K//bk, OC], core.prequant wire format).

    The sidecar block IS the kernel K tile (K is a ``bk`` multiple by the
    wire-format contract), so prequant execution is bit-exact vs
    :func:`bfp_conv2d` with the same policy.  ``x`` may additionally be
    an activation-prequant dict with the SAME block size (requires
    ``bk | C``) — the fully-prequantized conv->conv chain.
    """
    if interpret is None:
        interpret = not _on_tpu()
    x_pq = is_prequant(x)
    b, h, w_in, c = (x["m"] if x_pq else x).shape
    kh, kw, c2, oc = wm_hwio.shape
    if c != c2:
        raise ValueError(f"channel mismatch: x "
                         f"{(x['m'] if x_pq else x).shape} vs w "
                         f"{wm_hwio.shape}")
    k = kh * kw * c
    t = ws.shape[0]
    if t == 0 or k % t:
        raise ValueError(f"sidecar {ws.shape} incompatible with K={k}")
    bk = k // t
    if policy.block_k not in (None, bk):
        raise ValueError(f"policy.block_k={policy.block_k} != prequant "
                         f"block {bk}")
    if x_pq:
        _conv_x_prequant_check(x, c, bk, policy)
    t_oh, bn = _conv_tiles(b * h * w_in, k, oc, policy, interpret, tiles)
    pads, (oh, ow, ohp, t_oh, bn, kp) = _conv_plan(
        b, h, w_in, c, kh, kw, oc, stride, padding, bk, t_oh, bn)
    assert kp == k, "wire-format K is a bk multiple by construction"
    fused_q = _conv_epilogue_cfg(out_policy, oc, bn)
    ob, obk = fused_q if fused_q is not None else (None, None)
    wm2d = _pad_to(conv_weight_matrix(wm_hwio), (bk, bn))
    wsp = _pad_to(ws.astype(jnp.float32), (1, bn), values=1.0)
    kwargs = dict(kh=kh, kw=kw, stride=stride, t_oh=t_oh, ohp=ohp, ow=ow,
                  bn=bn, bk=bk, l_i=policy.l_i, l_w=policy.l_w,
                  interpret=interpret, dot_impl=dot_impl,
                  pipeline=pipeline, out_bits=ob, out_block=obk)
    if x_pq:
        xm, xs = _pad_act_nhwc(x, pads)
        out = bfp_conv2d_xwprequant_pallas(xm, xs, wm2d, wsp, **kwargs)
    else:
        xp = jnp.pad(x.astype(jnp.float32), pads)
        out = bfp_conv2d_prequant_pallas(xp, wm2d, wsp, **kwargs)
    return _finish_conv(out, oh, oc, out_policy, fused_q)


def bfp_quantize(x: jax.Array, bits: int, block_k: int,
                 interpret: Optional[bool] = None):
    """[M,K] -> (mantissa int8 [M,K], exps int32 [M,ceil(K/bk)]) padded-safe."""
    if interpret is None:
        interpret = not _on_tpu()
    m_rows, k = x.shape
    # same aligned floor as default_tiles (one helper, one rationale);
    # the streaming quantizer has no MXU operand so it rides a taller
    # 256-row tile for bandwidth.
    bm = aligned_tile(m_rows, 256)
    xp = _pad_to(x.astype(jnp.float32), (bm, block_k))
    m, e = bfp_quantize_pallas(xp, bits=bits, bm=bm, bk=block_k,
                               interpret=interpret)
    return m[:m_rows, :k], e[:m_rows, : -(-k // block_k)]
