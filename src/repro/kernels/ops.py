"""Public jit'd wrappers around the Pallas BFP kernels.

Handles shape padding to tile multiples, CPU-interpret dispatch (this
container has no TPU; ``interpret=True`` runs the kernel body in Python),
and policy plumbing.  The contract is identical to the emulated path in
``repro.core.bfp_dot`` with Scheme.TILED and ``block_k == bk`` — tests
assert all three (kernel, ref oracle, core library) agree.  Model code
reaches these through ``repro.engine`` (backend "pallas"), never
directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.conv_utils import conv_geometry, conv_weight_matrix
from repro.core.policy import BFPPolicy
from repro.kernels.bfp_conv import (bfp_conv2d_pallas,
                                    bfp_conv2d_prequant_pallas)
from repro.kernels.bfp_matmul import (bfp_matmul_pallas,
                                      bfp_matmul_prequant_pallas)
from repro.kernels.bfp_quantize import bfp_quantize_pallas

__all__ = ["bfp_matmul", "bfp_matmul_prequant", "bfp_conv2d",
           "bfp_conv2d_prequant", "bfp_quantize", "default_tiles",
           "aligned_tile"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: Tuple[int, ...],
            values=0.0) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads, constant_values=values)
    return x


def _pow2_ge(d: int) -> int:
    """Smallest power of two >= d (d >= 1)."""
    return 1 << max(0, d - 1).bit_length()


def aligned_tile(d: int, cap: int = 128) -> int:
    """THE power-of-two-aligned tile floor, shared by every wrapper:
    next power of two >= d, floored at 8 (sublane minimum) and capped at
    ``cap`` (the MXU dimension, or a bandwidth-friendly multiple of it).
    Small/odd problem dims pad to the NEAREST aligned tile, not a full
    cap."""
    return min(cap, max(8, _pow2_ge(d)))


def default_tiles(b: int, k: int, n: int, block_k: Optional[int],
                  l_sum: int = 16) -> Tuple[int, int, int]:
    """Pick MXU-aligned tile sizes for a (b, k) x (k, n) problem.

    bm/bn: 128 (the MXU dimension) capped below at 8 and shrunk to the
    next power of two when the problem dimension is smaller — small or
    odd shapes pad to the NEAREST aligned tile instead of a full 128.
    bk: the BFP block size when given (block == K tile by construction);
    otherwise 512 for deep contractions and 128 for shallow ones, capped
    by the int32 overflow bound 2**(32 - l_sum) (paper Fig. 2 sizing) so
    auto-picked tiles are always accumulation-safe for the policy's
    mantissa widths.
    """
    bm = aligned_tile(b)
    bn = aligned_tile(n)
    if block_k:
        bk = block_k
    else:
        bk = 512 if k >= 512 else aligned_tile(k)
        bk = min(bk, 1 << max(0, 32 - l_sum))   # always accumulation-safe
    return bm, bn, bk


def bfp_matmul(x2d: jax.Array, w: jax.Array, policy: BFPPolicy,
               interpret: Optional[bool] = None) -> jax.Array:
    """x2d[B,K] @ w[K,N] via the fused Pallas kernel (Scheme.TILED).

    Pads every dim to tile multiples (zero K-padding is exact: zero
    mantissas contribute nothing; padded rows/cols are sliced off).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, k = x2d.shape
    n = w.shape[1]
    bm, bn, bk = default_tiles(b, k, n, policy.block_k,
                               policy.l_w + policy.l_i)
    xp = _pad_to(x2d.astype(jnp.float32), (bm, bk))
    wp = _pad_to(w.astype(jnp.float32), (bk, bn))
    out = bfp_matmul_pallas(xp, wp, l_i=policy.l_i, l_w=policy.l_w,
                            bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:b, :n]


def bfp_matmul_prequant(x2d: jax.Array, wm: jax.Array, ws: jax.Array,
                        policy: BFPPolicy,
                        interpret: Optional[bool] = None) -> jax.Array:
    """x2d[B,K] @ prequant weight via the sidecar-consuming kernel.

    ``wm``: int8 mantissa [K, N]; ``ws``: f32 power-of-two steps
    [K//bk, N] (core.prequant wire format).  The prequant block size IS
    the kernel K tile, so K needs no padding (it is a bk multiple by
    construction); B and N pad to tile multiples.  Scale padding uses 1.0
    — padded mantissas are zero, so the value is inert but stays finite.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, k = x2d.shape
    n = wm.shape[1]
    t = ws.shape[0]
    if t == 0 or k % t:
        raise ValueError(f"sidecar {ws.shape} incompatible with K={k}")
    bk = k // t
    if policy.block_k not in (None, bk):
        # same contract as the emulated path: a sidecar blocked at bk
        # cannot honour a policy asking for different blocks
        raise ValueError(f"policy.block_k={policy.block_k} != prequant "
                         f"block {bk}")
    bm, bn, _ = default_tiles(b, k, n, bk, policy.l_w + policy.l_i)
    xp = _pad_to(x2d.astype(jnp.float32), (bm, bk))
    wmp = _pad_to(wm, (bk, bn))
    wsp = _pad_to(ws.astype(jnp.float32), (1, bn), values=1.0)
    out = bfp_matmul_prequant_pallas(xp, wmp, wsp, l_i=policy.l_i,
                                     l_w=policy.l_w, bm=bm, bn=bn, bk=bk,
                                     interpret=interpret)
    return out[:b, :n]


def _conv_plan(b: int, h: int, w_in: int, c: int, kh: int, kw: int,
               oc: int, stride: int, padding: str, bk: int):
    """Static geometry + tiling for the fused conv kernels.

    Returns (pads for x, (oh, ow, ohp, t_oh, bn, kp)).  The padded input
    covers conv padding PLUS the kernel's alignment contract
    (Hp >= s*OHp + kh - 1, Wp >= s*OW + kw - 1); extra zero rows/cols are
    only read by padded output rows, which callers slice off.
    """
    oh, ow, (pt, pb), (plf, pr) = conv_geometry(h, w_in, kh, kw, stride,
                                                padding)
    # enough output rows per program to feed the MXU a >=128-row M tile
    # when OW is small; one row when OW alone is wide enough
    t_oh = max(1, min(oh, 128 // max(1, ow)))
    ohp = -(-oh // t_oh) * t_oh
    hp = max(stride * ohp + kh - 1, pt + h + pb)
    wp = max(stride * ow + kw - 1, plf + w_in + pr)
    bn = aligned_tile(oc)
    kp = -(-(kh * kw * c) // bk) * bk
    pads = ((0, 0), (pt, hp - h - pt), (plf, wp - w_in - plf), (0, 0))
    return pads, (oh, ow, ohp, t_oh, bn, kp)


def bfp_conv2d(x: jax.Array, w_hwio: jax.Array, policy: BFPPolicy,
               stride: int = 1, padding: str = "SAME",
               interpret: Optional[bool] = None) -> jax.Array:
    """NHWC conv through the fused implicit-im2col kernel (Scheme.TILED).

    x: [B, H, W, C] float; w_hwio: [kh, kw, C, OC] float.  The K tile
    ``policy.block_k`` IS the BFP block (whole-K when None); K zero-pads
    to a tile multiple exactly like ops.bfp_matmul, so the result is
    bit-identical to im2col + the fused GEMM kernel.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, h, w_in, c = x.shape
    kh, kw, c2, oc = w_hwio.shape
    if c != c2:
        raise ValueError(f"channel mismatch: x {x.shape} vs w "
                         f"{w_hwio.shape}")
    bk = policy.block_k or kh * kw * c
    pads, (oh, ow, ohp, t_oh, bn, kp) = _conv_plan(
        b, h, w_in, c, kh, kw, oc, stride, padding, bk)
    xp = jnp.pad(x.astype(jnp.float32), pads)
    w2d = conv_weight_matrix(w_hwio.astype(jnp.float32))
    w2d = _pad_to(w2d, (kp, bn))
    out = bfp_conv2d_pallas(xp, w2d, kh=kh, kw=kw, stride=stride,
                            t_oh=t_oh, ohp=ohp, ow=ow, bn=bn, bk=bk,
                            l_i=policy.l_i, l_w=policy.l_w,
                            interpret=interpret)
    return out[:, :oh, :, :oc]


def bfp_conv2d_prequant(x: jax.Array, wm_hwio: jax.Array, ws: jax.Array,
                        policy: BFPPolicy, stride: int = 1,
                        padding: str = "SAME",
                        interpret: Optional[bool] = None) -> jax.Array:
    """NHWC conv with pre-quantized weights (int8 HWIO mantissa + GEMM-view
    step sidecar [K//bk, OC], core.prequant wire format).

    The sidecar block IS the kernel K tile (K is a ``bk`` multiple by the
    wire-format contract), so prequant execution is bit-exact vs
    :func:`bfp_conv2d` with the same policy.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, h, w_in, c = x.shape
    kh, kw, c2, oc = wm_hwio.shape
    if c != c2:
        raise ValueError(f"channel mismatch: x {x.shape} vs w "
                         f"{wm_hwio.shape}")
    k = kh * kw * c
    t = ws.shape[0]
    if t == 0 or k % t:
        raise ValueError(f"sidecar {ws.shape} incompatible with K={k}")
    bk = k // t
    if policy.block_k not in (None, bk):
        raise ValueError(f"policy.block_k={policy.block_k} != prequant "
                         f"block {bk}")
    pads, (oh, ow, ohp, t_oh, bn, kp) = _conv_plan(
        b, h, w_in, c, kh, kw, oc, stride, padding, bk)
    assert kp == k, "wire-format K is a bk multiple by construction"
    xp = jnp.pad(x.astype(jnp.float32), pads)
    wm2d = _pad_to(conv_weight_matrix(wm_hwio), (bk, bn))
    wsp = _pad_to(ws.astype(jnp.float32), (1, bn), values=1.0)
    out = bfp_conv2d_prequant_pallas(xp, wm2d, wsp, kh=kh, kw=kw,
                                     stride=stride, t_oh=t_oh, ohp=ohp,
                                     ow=ow, bn=bn, bk=bk, l_i=policy.l_i,
                                     l_w=policy.l_w, interpret=interpret)
    return out[:, :oh, :, :oc]


def bfp_quantize(x: jax.Array, bits: int, block_k: int,
                 interpret: Optional[bool] = None):
    """[M,K] -> (mantissa int8 [M,K], exps int32 [M,ceil(K/bk)]) padded-safe."""
    if interpret is None:
        interpret = not _on_tpu()
    m_rows, k = x.shape
    # same aligned floor as default_tiles (one helper, one rationale);
    # the streaming quantizer has no MXU operand so it rides a taller
    # 256-row tile for bandwidth.
    bm = aligned_tile(m_rows, 256)
    xp = _pad_to(x.astype(jnp.float32), (bm, block_k))
    m, e = bfp_quantize_pallas(xp, bits=bits, bm=bm, bk=block_k,
                               interpret=interpret)
    return m[:m_rows, :k], e[:m_rows, : -(-k // block_k)]
