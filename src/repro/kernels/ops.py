"""Public jit'd wrappers around the Pallas BFP kernels.

Handles shape padding to tile multiples, CPU-interpret dispatch (this
container has no TPU; ``interpret=True`` runs the kernel body in Python),
and policy plumbing.  The contract is identical to the emulated path in
``repro.core.bfp_dot`` with Scheme.TILED and ``block_k == bk`` — tests
assert all three (kernel, ref oracle, core library) agree.  Model code
reaches these through ``repro.engine`` (backend "pallas"), never
directly.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import BFPPolicy
from repro.kernels.bfp_matmul import (bfp_matmul_pallas,
                                      bfp_matmul_prequant_pallas)
from repro.kernels.bfp_quantize import bfp_quantize_pallas

__all__ = ["bfp_matmul", "bfp_matmul_prequant", "bfp_quantize",
           "default_tiles"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: Tuple[int, ...],
            values=0.0) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads, constant_values=values)
    return x


def _pow2_ge(d: int) -> int:
    """Smallest power of two >= d (d >= 1)."""
    return 1 << max(0, d - 1).bit_length()


def default_tiles(b: int, k: int, n: int, block_k: Optional[int],
                  l_sum: int = 16) -> Tuple[int, int, int]:
    """Pick MXU-aligned tile sizes for a (b, k) x (k, n) problem.

    bm/bn: 128 (the MXU dimension) capped below at 8 and shrunk to the
    next power of two when the problem dimension is smaller — small or
    odd shapes pad to the NEAREST aligned tile instead of a full 128.
    bk: the BFP block size when given (block == K tile by construction);
    otherwise 512 for deep contractions and 128 for shallow ones, capped
    by the int32 overflow bound 2**(32 - l_sum) (paper Fig. 2 sizing) so
    auto-picked tiles are always accumulation-safe for the policy's
    mantissa widths.
    """
    bm = min(128, max(8, _pow2_ge(b)))
    bn = min(128, max(8, _pow2_ge(n)))
    if block_k:
        bk = block_k
    else:
        bk = 512 if k >= 512 else min(128, max(8, _pow2_ge(k)))
        bk = min(bk, 1 << max(0, 32 - l_sum))   # always accumulation-safe
    return bm, bn, bk


def bfp_matmul(x2d: jax.Array, w: jax.Array, policy: BFPPolicy,
               interpret: Optional[bool] = None) -> jax.Array:
    """x2d[B,K] @ w[K,N] via the fused Pallas kernel (Scheme.TILED).

    Pads every dim to tile multiples (zero K-padding is exact: zero
    mantissas contribute nothing; padded rows/cols are sliced off).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, k = x2d.shape
    n = w.shape[1]
    bm, bn, bk = default_tiles(b, k, n, policy.block_k,
                               policy.l_w + policy.l_i)
    xp = _pad_to(x2d.astype(jnp.float32), (bm, bk))
    wp = _pad_to(w.astype(jnp.float32), (bk, bn))
    out = bfp_matmul_pallas(xp, wp, l_i=policy.l_i, l_w=policy.l_w,
                            bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:b, :n]


def bfp_matmul_prequant(x2d: jax.Array, wm: jax.Array, ws: jax.Array,
                        policy: BFPPolicy,
                        interpret: Optional[bool] = None) -> jax.Array:
    """x2d[B,K] @ prequant weight via the sidecar-consuming kernel.

    ``wm``: int8 mantissa [K, N]; ``ws``: f32 power-of-two steps
    [K//bk, N] (core.prequant wire format).  The prequant block size IS
    the kernel K tile, so K needs no padding (it is a bk multiple by
    construction); B and N pad to tile multiples.  Scale padding uses 1.0
    — padded mantissas are zero, so the value is inert but stays finite.
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, k = x2d.shape
    n = wm.shape[1]
    t = ws.shape[0]
    if t == 0 or k % t:
        raise ValueError(f"sidecar {ws.shape} incompatible with K={k}")
    bk = k // t
    if policy.block_k not in (None, bk):
        # same contract as the emulated path: a sidecar blocked at bk
        # cannot honour a policy asking for different blocks
        raise ValueError(f"policy.block_k={policy.block_k} != prequant "
                         f"block {bk}")
    bm, bn, _ = default_tiles(b, k, n, bk, policy.l_w + policy.l_i)
    xp = _pad_to(x2d.astype(jnp.float32), (bm, bk))
    wmp = _pad_to(wm, (bk, bn))
    wsp = _pad_to(ws.astype(jnp.float32), (1, bn), values=1.0)
    out = bfp_matmul_prequant_pallas(xp, wmp, wsp, l_i=policy.l_i,
                                     l_w=policy.l_w, bm=bm, bn=bn, bk=bk,
                                     interpret=interpret)
    return out[:b, :n]


def bfp_quantize(x: jax.Array, bits: int, block_k: int,
                 interpret: Optional[bool] = None):
    """[M,K] -> (mantissa int8 [M,K], exps int32 [M,ceil(K/bk)]) padded-safe."""
    if interpret is None:
        interpret = not _on_tpu()
    m_rows, k = x.shape
    bm = 256 if m_rows >= 256 else max(8, _pow2_ge(m_rows))
    xp = _pad_to(x.astype(jnp.float32), (bm, block_k))
    m, e = bfp_quantize_pallas(xp, bits=bits, bm=bm, bk=block_k,
                               interpret=interpret)
    return m[:m_rows, :k], e[:m_rows, : -(-k // block_k)]
