"""Implicit-im2col fused BFP convolution Pallas kernels.

The paper's traffic argument (§3.1, Table 1) is that BFP cuts off-chip
bytes — yet a materialized im2col inflates activation HBM traffic
kh*kw-fold (9x for 3x3) before the datapath even starts.  These kernels
read the padded NHWC input straight from HBM and form the receptive-field
rows **in VMEM**:

    HBM: x [1, Hp, Wp, C] tile, w GEMM-view [K, bn] stripe --> VMEM
      gather kh*kw strided slabs  -> patch rows [t_oh*OW, K]   (VMEM only)
      per K-tile of size bk:
        block-format patch rows  (per-row exponent over the K-tile)
        block-format w columns   (per-column exponent; or prequant sidecar)
        int8 x int8 -> int32 MXU dot, rescale 2^(e_x-(L_I-2))*2^(e_w-(L_W-2))
        fp32 accumulate (sequential over K-tiles, same order as the GEMM
        kernel -> bit-identical to im2col + bfp_matmul_pallas)
    fp32 out [1, t_oh, OW, bn] tile --> HBM   (or {"m","s"} via epilogue)

Dot modes, software pipelining, prequant activations, and the epilogue
requantizer all follow :mod:`repro.kernels.bfp_matmul` (one shared
``resolve_dot_impl`` / ``_block_format`` / ``_tile_dot``):

* ``dot_impl``: int8 (MXU-native), int32 (L>8 / legacy), f32 (bit-exact
  under the 2^24 bound, the fast interpret path) — all bit-identical.
* ``pipeline=True`` skews the static K loop: the quantize of tile t+1 is
  issued before the dot of tile t, so the VPU block-format and the MXU
  dot have no data dependence and Mosaic can overlap them.  Accumulation
  order is unchanged — results stay bit-identical.
* Activation-prequant input (``xm`` int8 NHWC + ``xs`` per-(pixel,
  C-chunk) steps): requires ``bk | C``, which makes every patch-row
  K-tile exactly one (input pixel, channel-chunk) block — the patch
  gather permutes whole blocks, so consuming the producer's epilogue
  output is bit-identical to quantizing f32 patches inline.
* Epilogue requantize (``out_bits``/``out_block``): emits int8 mantissas
  + steps per (output pixel, out_block-channel-chunk) — exactly the
  activation blocks the NEXT conv (with block_k = out_block) would form,
  so conv->conv chains skip the f32 HBM round-trip bit-identically.

The K-order is the repo-wide HWIO-major conv GEMM view
(core.conv_utils): k = (di*kw + dj)*C + c.  Because C is innermost and
NHWC keeps channels contiguous, every (di, dj) offset contributes one
contiguous channel slab, extractable with *static* slices — the whole
kernel body is static Python over (kh, kw) offsets; only the output-row
program id enters a dynamic slice start.

Strided columns use the reshape trick: slice [dj : dj + stride*OW] then
reshape [OW, stride, C] and keep phase 0 — exact for any static stride.

Grid: (B, OHp/t_oh, OCp/bn).  The K reduction is an in-kernel static
loop (n_k tiles), so no cross-step accumulator scratch is needed.  VMEM
sizing note: each program holds the full [Hp, Wp, C] input plane plus
[t_oh*OW, Kp] patch rows — fine for the interpret-mode CI and for
real CNN tails; very large early layers would want a row-windowed DMA
variant (future work, see DESIGN.md §3).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bfp_matmul import (_block_format, _mantissa_dtype,
                                      _tile_dot, resolve_dot_impl)


def _patch_rows(x_ref, *, kh: int, kw: int, stride: int, t_oh: int,
                ow: int, kp: int) -> jax.Array:
    """Form [t_oh*OW, Kp] receptive-field rows in VMEM for this program's
    output-row tile (program id 1), zero-padding K up to ``kp``."""
    c = x_ref.shape[3]
    oh0 = pl.program_id(1) * t_oh
    pieces = []
    for di in range(kh):
        # output rows oh0..oh0+t_oh-1 need input rows oh0*s+di + s*r:
        # one dynamic-start slice of s*t_oh rows, then keep phase 0.
        rows = pl.load(x_ref, (pl.ds(0, 1), pl.ds(oh0 * stride + di,
                                                  stride * t_oh),
                               slice(None), slice(None)))
        rows = rows.reshape(t_oh, stride, rows.shape[2], c)[:, 0]
        for dj in range(kw):
            # columns dj + s*i, i < OW: static slice + phase-0 reshape
            slab = rows[:, dj:dj + stride * ow, :]
            pieces.append(slab.reshape(t_oh, ow, stride, c)[:, :, 0, :])
    patches = jnp.concatenate(pieces, axis=-1)     # (di, dj, c) = HWIO-major
    patches = patches.reshape(t_oh * ow, kh * kw * c)
    if kp > kh * kw * c:
        patches = jnp.pad(patches, ((0, 0), (0, kp - kh * kw * c)))
    return patches


def _make_conv_kernel(*, kh, kw, stride, t_oh, ow, bk, n_k, l_i, l_w,
                      x_pq: bool, w_pq: bool, mode: str, pipeline: bool,
                      out_q):
    """Build the conv kernel body for one static configuration.

    Ref order: x side (1 or 2 refs), w side (1 or 2), out (1 or 2).
    """
    x_dt = _mantissa_dtype(mode, l_i, x_pq)
    w_dt = _mantissa_dtype(mode, l_w, w_pq)

    def kernel(*refs):
        it = iter(refs)
        if x_pq:
            xm_ref, xs_ref = next(it), next(it)
        else:
            x_ref = next(it)
        if w_pq:
            wm_ref, ws_ref = next(it), next(it)
        else:
            w_ref = next(it)
        if out_q is not None:
            om_ref, os_ref = next(it), next(it)
        else:
            o_ref = next(it)

        if x_pq:
            # bk | C (checked): each patch K-tile is exactly one (input
            # pixel, channel-chunk) block, so the mantissa/step patches
            # line up tile-for-tile with inline quantization.
            pm = _patch_rows(xm_ref, kh=kh, kw=kw, stride=stride,
                             t_oh=t_oh, ow=ow, kp=n_k * bk).astype(x_dt)
            ps = _patch_rows(xs_ref, kh=kh, kw=kw, stride=stride,
                             t_oh=t_oh, ow=ow, kp=n_k)
        else:
            patches = _patch_rows(x_ref, kh=kh, kw=kw, stride=stride,
                                  t_oh=t_oh, ow=ow, kp=n_k * bk)

        def x_tile(t):
            if x_pq:
                return pm[:, t * bk:(t + 1) * bk], ps[:, t:t + 1]
            return _block_format(patches[:, t * bk:(t + 1) * bk], l_i,
                                 axis=1, mdtype=x_dt)

        def w_tile(t):
            if w_pq:
                # ws IS the step the inline quantizer would compute, so
                # the prequant path is bit-exact vs the inline kernel.
                return (wm_ref[t * bk:(t + 1) * bk, :].astype(w_dt),
                        ws_ref[t:t + 1, :])
            return _block_format(w_ref[t * bk:(t + 1) * bk, :], l_w,
                                 axis=0, mdtype=w_dt)

        bn = (wm_ref if w_pq else w_ref).shape[1]
        acc = jnp.zeros((t_oh * ow, bn), jnp.float32)
        if pipeline:
            # Skewed issue order: quantize tile t+1 BEFORE the dot of
            # tile t — the block-format (VPU) and the dot (MXU) have no
            # data dependence, so Mosaic overlaps them.  Accumulation
            # order is unchanged (0..n_k-1): bit-identical results.
            cur = (x_tile(0), w_tile(0))
            for t in range(n_k):
                nxt = (x_tile(t + 1), w_tile(t + 1)) if t + 1 < n_k \
                    else None
                (mx, sx), (mw, sw) = cur
                acc = acc + _tile_dot(mx, mw, mode) * (sx * sw)
                cur = nxt
        else:
            for t in range(n_k):
                mx, sx = x_tile(t)
                mw, sw = w_tile(t)
                acc = acc + _tile_dot(mx, mw, mode) * (sx * sw)

        if out_q is None:
            o_ref[...] = acc.reshape(1, t_oh, ow, -1)
        else:
            # Epilogue: block-format per (output pixel, out_block
            # channel chunk) — identical math, identical accumulator
            # values as the two-step store-f32-then-prequant_act path.
            ob, bq = out_q
            ms, ss = [], []
            for t in range(bn // bq):
                m, step = _block_format(acc[:, t * bq:(t + 1) * bq], ob,
                                        axis=1, mdtype=jnp.int8)
                ms.append(m)
                ss.append(step)
            om_ref[...] = jnp.concatenate(ms, axis=1).reshape(
                1, t_oh, ow, -1)
            os_ref[...] = jnp.concatenate(ss, axis=1).reshape(
                1, t_oh, ow, -1)

    return kernel


def _check_conv(x_shape, kp, ocp, *, kh, kw, stride, t_oh, ohp, ow, bk,
                bn, l_sum, out_q=None):
    b, hp, wp, c = x_shape
    if ohp % t_oh or ocp % bn or kp % bk:
        raise ValueError(f"tiles (t_oh={t_oh}, bn={bn}, bk={bk}) must "
                         f"divide (OHp={ohp}, OCp={ocp}, Kp={kp})")
    if kp < kh * kw * c:
        raise ValueError(f"Kp={kp} smaller than kh*kw*C={kh * kw * c}")
    if hp < stride * ohp + kh - 1 or wp < stride * ow + kw - 1:
        raise ValueError(
            f"padded input {hp}x{wp} too small for OHp={ohp}, OW={ow}, "
            f"k={kh}x{kw}, stride={stride} (need "
            f">= {stride * ohp + kh - 1}x{stride * ow + kw - 1})")
    # Paper Fig. 2 accumulator sizing: int32 must hold bk products.
    if l_sum + math.ceil(math.log2(bk)) > 32:
        raise ValueError(f"bk={bk} overflows int32 for L_I+L_W={l_sum}")
    if out_q is not None:
        out_bits, out_block = out_q
        if not 2 <= out_bits <= 8:
            raise ValueError(f"epilogue out_bits={out_bits} must be 2..8 "
                             f"(int8 mantissa wire format)")
        if bn % out_block:
            raise ValueError(f"epilogue out_block={out_block} must divide "
                             f"bn={bn}")


def _out_q(out_bits, out_block, bn):
    if out_bits is None:
        return None
    return (out_bits, out_block if out_block is not None else bn)


def _conv_call(x_ops, w_ops, *, kh, kw, stride, t_oh, ohp, ow, bn, bk,
               l_i, l_w, interpret, dot_impl, pipeline, out_q):
    """Assemble specs and launch; ``x_ops`` is (x,) or (xm, xs) NHWC,
    ``w_ops`` is (w2d,) or (wm2d, ws) GEMM view."""
    x_pq, w_pq = len(x_ops) == 2, len(w_ops) == 2
    b, hp, wp, c = x_ops[0].shape
    kp, ocp = w_ops[0].shape
    n_k = kp // bk
    _check_conv(x_ops[0].shape, kp, ocp, kh=kh, kw=kw, stride=stride,
                t_oh=t_oh, ohp=ohp, ow=ow, bk=bk, bn=bn, l_sum=l_i + l_w,
                out_q=out_q)
    mode = resolve_dot_impl(dot_impl, l_i=l_i, l_w=l_w, bk=bk,
                            interpret=interpret, x_pq=x_pq, w_pq=w_pq)

    in_specs = [pl.BlockSpec((1, hp, wp, c),
                             lambda bb, i, j: (bb, 0, 0, 0))]
    if x_pq:
        in_specs.append(pl.BlockSpec((1, hp, wp, c // bk),
                                     lambda bb, i, j: (bb, 0, 0, 0)))
    in_specs.append(pl.BlockSpec((kp, bn), lambda bb, i, j: (0, j)))
    if w_pq:
        in_specs.append(pl.BlockSpec((n_k, bn), lambda bb, i, j: (0, j)))

    if out_q is None:
        out_specs = pl.BlockSpec((1, t_oh, ow, bn),
                                 lambda bb, i, j: (bb, i, 0, j))
        out_shape = jax.ShapeDtypeStruct((b, ohp, ow, ocp), jnp.float32)
    else:
        bq = out_q[1]
        out_specs = [
            pl.BlockSpec((1, t_oh, ow, bn), lambda bb, i, j: (bb, i, 0, j)),
            pl.BlockSpec((1, t_oh, ow, bn // bq),
                         lambda bb, i, j: (bb, i, 0, j)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, ohp, ow, ocp), jnp.int8),
            jax.ShapeDtypeStruct((b, ohp, ow, ocp // bq), jnp.float32),
        ]

    kernel = _make_conv_kernel(kh=kh, kw=kw, stride=stride, t_oh=t_oh,
                               ow=ow, bk=bk, n_k=n_k, l_i=l_i, l_w=l_w,
                               x_pq=x_pq, w_pq=w_pq, mode=mode,
                               pipeline=pipeline, out_q=out_q)
    return pl.pallas_call(
        kernel,
        grid=(b, ohp // t_oh, ocp // bn),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*x_ops, *w_ops)


_STATIC = ("kh", "kw", "stride", "t_oh", "ohp", "ow", "bn", "bk", "l_i",
           "l_w", "interpret", "dot_impl", "pipeline", "out_bits",
           "out_block")


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_conv2d_pallas(x: jax.Array, w2d: jax.Array, *, kh: int, kw: int,
                      stride: int, t_oh: int, ohp: int, ow: int, bn: int,
                      bk: int, l_i: int = 8, l_w: int = 8,
                      interpret: bool = False, dot_impl: str = "auto",
                      pipeline: bool = True, out_bits: int | None = None,
                      out_block: int | None = None):
    """Fused implicit-im2col BFP conv.

    x: pre-padded NHWC [B, Hp, Wp, C] (conv padding + alignment, ops.py
    does this); w2d: conv GEMM view [Kp, OCp], K zero-padded to a ``bk``
    multiple and OC to a ``bn`` multiple.  Returns [B, OHp, OW, OCp]
    fp32 (callers slice OH/OC) — or, with ``out_bits`` set, the epilogue
    pair (int8 mantissa NHWC, f32 steps [..., OCp/out_block]).  ``bk`` IS
    the BFP block — Scheme.TILED with block_k = bk, bit-identical to
    im2col + bfp_matmul_pallas (zero K-padding is inert: it changes no
    block amax and adds zero products, exactly as in ops.bfp_matmul's
    padding).
    """
    return _conv_call((x,), (w2d,), kh=kh, kw=kw, stride=stride,
                      t_oh=t_oh, ohp=ohp, ow=ow, bn=bn, bk=bk, l_i=l_i,
                      l_w=l_w, interpret=interpret, dot_impl=dot_impl,
                      pipeline=pipeline,
                      out_q=_out_q(out_bits, out_block, bn))


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_conv2d_prequant_pallas(x: jax.Array, wm2d: jax.Array,
                               ws: jax.Array, *, kh: int, kw: int,
                               stride: int, t_oh: int, ohp: int, ow: int,
                               bn: int, bk: int, l_i: int = 8,
                               l_w: int = 8, interpret: bool = False,
                               dot_impl: str = "auto",
                               pipeline: bool = True,
                               out_bits: int | None = None,
                               out_block: int | None = None):
    """Prequant fused conv: weights arrive as int8 GEMM-view mantissas
    [K, OCp] + power-of-two step sidecar [K//bk, OCp] (K a ``bk``
    multiple by the wire-format contract).  ``l_w`` only sizes the
    overflow check — weight quantization already happened offline."""
    kp, ocp = wm2d.shape
    if wm2d.dtype != jnp.int8:
        raise ValueError(f"prequant conv kernel streams int8 mantissas, "
                         f"got {wm2d.dtype}")
    if ws.shape != (kp // bk, ocp):
        raise ValueError(f"scale sidecar {ws.shape} != {(kp // bk, ocp)} "
                         f"for bk={bk}")
    return _conv_call((x,), (wm2d, ws), kh=kh, kw=kw, stride=stride,
                      t_oh=t_oh, ohp=ohp, ow=ow, bn=bn, bk=bk, l_i=l_i,
                      l_w=l_w, interpret=interpret, dot_impl=dot_impl,
                      pipeline=pipeline,
                      out_q=_out_q(out_bits, out_block, bn))


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_conv2d_xprequant_pallas(xm: jax.Array, xs: jax.Array,
                                w2d: jax.Array, *, kh: int, kw: int,
                                stride: int, t_oh: int, ohp: int, ow: int,
                                bn: int, bk: int, l_i: int = 8,
                                l_w: int = 8, interpret: bool = False,
                                dot_impl: str = "auto",
                                pipeline: bool = True,
                                out_bits: int | None = None,
                                out_block: int | None = None):
    """Prequant ACTIVATIONS: xm int8 NHWC [B,Hp,Wp,C] + xs f32 steps
    [B,Hp,Wp,C/bk] (per input pixel and channel chunk — the conv
    epilogue wire format).  Requires ``bk | C`` so patch K-tiles ==
    activation blocks; ``l_i`` only sizes the overflow check."""
    c = xm.shape[3]
    if xm.dtype != jnp.int8:
        raise ValueError(f"activation-prequant conv kernel streams int8 "
                         f"mantissas, got {xm.dtype}")
    if c % bk:
        raise ValueError(f"activation prequant requires bk | C, got "
                         f"bk={bk}, C={c}")
    if xs.shape != (*xm.shape[:3], c // bk):
        raise ValueError(f"activation sidecar {xs.shape} != "
                         f"{(*xm.shape[:3], c // bk)} for bk={bk}")
    return _conv_call((xm, xs), (w2d,), kh=kh, kw=kw, stride=stride,
                      t_oh=t_oh, ohp=ohp, ow=ow, bn=bn, bk=bk, l_i=l_i,
                      l_w=l_w, interpret=interpret, dot_impl=dot_impl,
                      pipeline=pipeline,
                      out_q=_out_q(out_bits, out_block, bn))


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_conv2d_xwprequant_pallas(xm: jax.Array, xs: jax.Array,
                                 wm2d: jax.Array, ws: jax.Array, *,
                                 kh: int, kw: int, stride: int, t_oh: int,
                                 ohp: int, ow: int, bn: int, bk: int,
                                 l_i: int = 8, l_w: int = 8,
                                 interpret: bool = False,
                                 dot_impl: str = "auto",
                                 pipeline: bool = True,
                                 out_bits: int | None = None,
                                 out_block: int | None = None):
    """Both sides prequantized — the steady state of a conv->conv chain
    on a bound plan: no in-kernel quantization at all."""
    c = xm.shape[3]
    kp, ocp = wm2d.shape
    if xm.dtype != jnp.int8 or wm2d.dtype != jnp.int8:
        raise ValueError(f"prequant kernels stream int8 mantissas, got "
                         f"{xm.dtype} / {wm2d.dtype}")
    if c % bk:
        raise ValueError(f"activation prequant requires bk | C, got "
                         f"bk={bk}, C={c}")
    if xs.shape != (*xm.shape[:3], c // bk):
        raise ValueError(f"activation sidecar {xs.shape} != "
                         f"{(*xm.shape[:3], c // bk)} for bk={bk}")
    if ws.shape != (kp // bk, ocp):
        raise ValueError(f"scale sidecar {ws.shape} != {(kp // bk, ocp)} "
                         f"for bk={bk}")
    return _conv_call((xm, xs), (wm2d, ws), kh=kh, kw=kw, stride=stride,
                      t_oh=t_oh, ohp=ohp, ow=ow, bn=bn, bk=bk, l_i=l_i,
                      l_w=l_w, interpret=interpret, dot_impl=dot_impl,
                      pipeline=pipeline,
                      out_q=_out_q(out_bits, out_block, bn))
