"""Implicit-im2col fused BFP convolution Pallas kernels.

The paper's traffic argument (§3.1, Table 1) is that BFP cuts off-chip
bytes — yet a materialized im2col inflates activation HBM traffic
kh*kw-fold (9x for 3x3) before the datapath even starts.  These kernels
read the padded NHWC input straight from HBM and form the receptive-field
rows **in VMEM**:

    HBM: x [1, Hp, Wp, C] tile, w GEMM-view [K, bn] stripe --> VMEM
      gather kh*kw strided slabs  -> patch rows [t_oh*OW, K]   (VMEM only)
      per K-tile of size bk:
        block-format patch rows  (per-row exponent over the K-tile)
        block-format w columns   (per-column exponent; or prequant sidecar)
        int8 x int8 -> int32 MXU dot, rescale 2^(e_x-(L_I-2))*2^(e_w-(L_W-2))
        fp32 accumulate (sequential over K-tiles, same order as the GEMM
        kernel -> bit-identical to im2col + bfp_matmul_pallas)
    fp32 out [1, t_oh, OW, bn] tile --> HBM

The K-order is the repo-wide HWIO-major conv GEMM view
(core.conv_utils): k = (di*kw + dj)*C + c.  Because C is innermost and
NHWC keeps channels contiguous, every (di, dj) offset contributes one
contiguous channel slab, extractable with *static* slices — the whole
kernel body is static Python over (kh, kw) offsets; only the output-row
program id enters a dynamic slice start.

Strided columns use the reshape trick: slice [dj : dj + stride*OW] then
reshape [OW, stride, C] and keep phase 0 — exact for any static stride.

Grid: (B, OHp/t_oh, OCp/bn).  The K reduction is an in-kernel static
loop (n_k tiles), so no cross-step accumulator scratch is needed.  VMEM
sizing note: each program holds the full [Hp, Wp, C] input plane plus
[t_oh*OW, Kp] patch rows — fine for the interpret-mode CI and for
real CNN tails; very large early layers would want a row-windowed DMA
variant (future work, see DESIGN.md §3).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bfp_matmul import _block_format


def _patch_rows(x_ref, *, kh: int, kw: int, stride: int, t_oh: int,
                ow: int, kp: int) -> jax.Array:
    """Form [t_oh*OW, Kp] receptive-field rows in VMEM for this program's
    output-row tile (program id 1), zero-padding K up to ``kp``."""
    c = x_ref.shape[3]
    oh0 = pl.program_id(1) * t_oh
    pieces = []
    for di in range(kh):
        # output rows oh0..oh0+t_oh-1 need input rows oh0*s+di + s*r:
        # one dynamic-start slice of s*t_oh rows, then keep phase 0.
        rows = pl.load(x_ref, (pl.ds(0, 1), pl.ds(oh0 * stride + di,
                                                  stride * t_oh),
                               slice(None), slice(None)))
        rows = rows.reshape(t_oh, stride, rows.shape[2], c)[:, 0]
        for dj in range(kw):
            # columns dj + s*i, i < OW: static slice + phase-0 reshape
            slab = rows[:, dj:dj + stride * ow, :]
            pieces.append(slab.reshape(t_oh, ow, stride, c)[:, :, 0, :])
    patches = jnp.concatenate(pieces, axis=-1)     # (di, dj, c) = HWIO-major
    patches = patches.reshape(t_oh * ow, kh * kw * c)
    if kp > kh * kw * c:
        patches = jnp.pad(patches, ((0, 0), (0, kp - kh * kw * c)))
    return patches


def _bfp_conv_kernel(x_ref, w_ref, o_ref, *, kh, kw, stride, t_oh, ow,
                     bk, n_k, l_i, l_w):
    """x_ref [1,Hp,Wp,C], w_ref [Kp,bn] float GEMM view -> o_ref
    [1,t_oh,OW,bn].  Both operands quantized in-kernel per K-tile."""
    patches = _patch_rows(x_ref, kh=kh, kw=kw, stride=stride, t_oh=t_oh,
                          ow=ow, kp=n_k * bk)
    acc = jnp.zeros((t_oh * ow, w_ref.shape[1]), jnp.float32)
    for t in range(n_k):
        mx, sx = _block_format(patches[:, t * bk:(t + 1) * bk], l_i, axis=1)
        mw, sw = _block_format(w_ref[t * bk:(t + 1) * bk, :], l_w, axis=0)
        part = jax.lax.dot(mx.astype(jnp.int32), mw.astype(jnp.int32),
                           preferred_element_type=jnp.int32)
        acc = acc + part.astype(jnp.float32) * (sx * sw)
    o_ref[...] = acc.reshape(1, t_oh, ow, -1)


def _bfp_conv_prequant_kernel(x_ref, wm_ref, ws_ref, o_ref, *, kh, kw,
                              stride, t_oh, ow, bk, n_k, l_i):
    """Prequant variant: wm_ref [K,bn] int8 mantissas + ws_ref [n_k,bn]
    power-of-two step rows (the {"m","s"} wire format lowered to the conv
    GEMM view).  Only the activation side quantizes in-kernel; ws IS the
    step the inline quantizer would compute, so this path is bit-exact vs
    the inline kernel."""
    patches = _patch_rows(x_ref, kh=kh, kw=kw, stride=stride, t_oh=t_oh,
                          ow=ow, kp=n_k * bk)
    acc = jnp.zeros((t_oh * ow, wm_ref.shape[1]), jnp.float32)
    for t in range(n_k):
        mx, sx = _block_format(patches[:, t * bk:(t + 1) * bk], l_i, axis=1)
        mw = wm_ref[t * bk:(t + 1) * bk, :].astype(jnp.int32)
        part = jax.lax.dot(mx.astype(jnp.int32), mw,
                           preferred_element_type=jnp.int32)
        acc = acc + part.astype(jnp.float32) * (sx * ws_ref[t:t + 1, :])
    o_ref[...] = acc.reshape(1, t_oh, ow, -1)


def _check_conv(x_shape, kp, ocp, *, kh, kw, stride, t_oh, ohp, ow, bk,
                bn, l_sum):
    b, hp, wp, c = x_shape
    if ohp % t_oh or ocp % bn or kp % bk:
        raise ValueError(f"tiles (t_oh={t_oh}, bn={bn}, bk={bk}) must "
                         f"divide (OHp={ohp}, OCp={ocp}, Kp={kp})")
    if kp < kh * kw * c:
        raise ValueError(f"Kp={kp} smaller than kh*kw*C={kh * kw * c}")
    if hp < stride * ohp + kh - 1 or wp < stride * ow + kw - 1:
        raise ValueError(
            f"padded input {hp}x{wp} too small for OHp={ohp}, OW={ow}, "
            f"k={kh}x{kw}, stride={stride} (need "
            f">= {stride * ohp + kh - 1}x{stride * ow + kw - 1})")
    # Paper Fig. 2 accumulator sizing: int32 must hold bk products.
    if l_sum + math.ceil(math.log2(bk)) > 32:
        raise ValueError(f"bk={bk} overflows int32 for L_I+L_W={l_sum}")


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "t_oh", "ohp", "ow", "bn", "bk", "l_i", "l_w",
    "interpret"))
def bfp_conv2d_pallas(x: jax.Array, w2d: jax.Array, *, kh: int, kw: int,
                      stride: int, t_oh: int, ohp: int, ow: int, bn: int,
                      bk: int, l_i: int = 8, l_w: int = 8,
                      interpret: bool = False) -> jax.Array:
    """Fused implicit-im2col BFP conv.

    x: pre-padded NHWC [B, Hp, Wp, C] (conv padding + alignment, ops.py
    does this); w2d: conv GEMM view [Kp, OCp], K zero-padded to a ``bk``
    multiple and OC to a ``bn`` multiple.  Returns [B, OHp, OW, OCp]
    fp32 (callers slice OH/OC).  ``bk`` IS the BFP block — Scheme.TILED
    with block_k = bk, bit-identical to im2col + bfp_matmul_pallas
    (zero K-padding is inert: it changes no block amax and adds zero
    products, exactly as in ops.bfp_matmul's padding).
    """
    b, hp, wp, c = x.shape
    kp, ocp = w2d.shape
    n_k = kp // bk
    _check_conv(x.shape, kp, ocp, kh=kh, kw=kw, stride=stride, t_oh=t_oh,
                ohp=ohp, ow=ow, bk=bk, bn=bn, l_sum=l_i + l_w)
    kernel = functools.partial(_bfp_conv_kernel, kh=kh, kw=kw,
                               stride=stride, t_oh=t_oh, ow=ow, bk=bk,
                               n_k=n_k, l_i=l_i, l_w=l_w)
    return pl.pallas_call(
        kernel,
        grid=(b, ohp // t_oh, ocp // bn),
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda bb, i, j: (bb, 0, 0, 0)),
            pl.BlockSpec((kp, bn), lambda bb, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, t_oh, ow, bn),
                               lambda bb, i, j: (bb, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, ohp, ow, ocp), jnp.float32),
        interpret=interpret,
    )(x, w2d)


@functools.partial(jax.jit, static_argnames=(
    "kh", "kw", "stride", "t_oh", "ohp", "ow", "bn", "bk", "l_i", "l_w",
    "interpret"))
def bfp_conv2d_prequant_pallas(x: jax.Array, wm2d: jax.Array,
                               ws: jax.Array, *, kh: int, kw: int,
                               stride: int, t_oh: int, ohp: int, ow: int,
                               bn: int, bk: int, l_i: int = 8,
                               l_w: int = 8,
                               interpret: bool = False) -> jax.Array:
    """Prequant fused conv: weights arrive as int8 GEMM-view mantissas
    [K, OCp] + power-of-two step sidecar [K//bk, OCp] (K a ``bk``
    multiple by the wire-format contract).  ``l_w`` only sizes the
    overflow check — weight quantization already happened offline."""
    b, hp, wp, c = x.shape
    kp, ocp = wm2d.shape
    if wm2d.dtype != jnp.int8:
        raise ValueError(f"prequant conv kernel streams int8 mantissas, "
                         f"got {wm2d.dtype}")
    n_k = kp // bk
    if ws.shape != (n_k, ocp):
        raise ValueError(f"scale sidecar {ws.shape} != {(n_k, ocp)} "
                         f"for bk={bk}")
    _check_conv(x.shape, kp, ocp, kh=kh, kw=kw, stride=stride, t_oh=t_oh,
                ohp=ohp, ow=ow, bk=bk, bn=bn, l_sum=l_i + l_w)
    kernel = functools.partial(_bfp_conv_prequant_kernel, kh=kh, kw=kw,
                               stride=stride, t_oh=t_oh, ow=ow, bk=bk,
                               n_k=n_k, l_i=l_i)
    return pl.pallas_call(
        kernel,
        grid=(b, ohp // t_oh, ocp // bn),
        in_specs=[
            pl.BlockSpec((1, hp, wp, c), lambda bb, i, j: (bb, 0, 0, 0)),
            pl.BlockSpec((kp, bn), lambda bb, i, j: (0, j)),
            pl.BlockSpec((n_k, bn), lambda bb, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, t_oh, ow, bn),
                               lambda bb, i, j: (bb, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, ohp, ow, ocp), jnp.float32),
        interpret=interpret,
    )(x, wm2d, ws)
