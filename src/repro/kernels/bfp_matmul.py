"""Fused BFP matmul Pallas kernels — the paper's accelerator datapath on TPU.

One kernel family fuses the paper's whole pipeline (Fig. 2):

    HBM tiles --> VMEM (float tiles, or int8 mantissa + step sidecars)
      block-format x-tile  (per-row exponent over the K-tile)     \
      block-format w-tile  (per-column exponent over the K-tile)   } in VMEM
      int8 x int8 -> int32 systolic matmul on the MXU             /
      power-of-two rescale + fp32 accumulate in VMEM scratch
    fp32 out tile --> HBM   (or requantized {"m","s"} via the epilogue)

This is the TPU adaptation of the paper's FPGA design (DESIGN.md §2): the
block is the K-tile the matmul pipeline stages through VMEM anyway, so
block formatting costs no extra HBM traffic; the fixed-point MAC array is
the MXU's native int8 path.  Accumulation is int32-exact within a tile
(paper's accumulator-width rule: L_W + L_I + log2(block_k) <= 32 is
asserted) and fp32 across tiles.

Dot implementations (``dot_impl``, static):

* ``"int8"`` — mantissas stay int8 and the dot asks for an int32 result
  (``preferred_element_type``): the MXU's native 8-bit systolic path.
  Requires every inline-quantized operand to have L <= 8 (prequant
  mantissas are int8 by wire contract regardless of the stated L).
* ``"int32"`` — operands widened to int32 before the dot.  The only
  legal mode for L > 8; also the pre-ISSUE-6 behavior, kept as the
  like-for-like "legacy" baseline in benchmarks.
* ``"f32"`` — mantissas kept/cast to f32 and dotted in f32.  BIT-exact
  whenever ``bk * (2^(L_I-1)-1) * (2^(L_W-1)-1) <= 2^24``: every product
  and partial sum is an integer of magnitude <= 2^24, all exactly
  representable in f32 (e.g. L=8, bk=512 -> max 8.26e6 < 2^24).  On
  CPU/interpret this routes through BLAS and is ~8x faster than XLA's
  scalar integer dots, so it is the auto choice off-TPU.
* ``"auto"`` — int32 when an inline operand has L > 8; on TPU, int8;
  in interpret mode, f32 when the exactness bound holds, else int32.

All modes produce bit-identical outputs (tests force each mode and
assert equality), so mode choice is purely a speed decision.

Pipelining (``pipeline=True``, static): tiles are staged through a
2-slot VMEM scratch with a one-step skew — grid step k quantizes tile k
into slot k%2 and dots tile k-1 from slot (k-1)%2 (the last step dots
both).  Quantization (VPU) of tile k then has no data dependence on the
dot (MXU) of tile k-1, so Mosaic can overlap them; accumulation order is
unchanged (tile 0, 1, ..., n_k-1), keeping results bit-identical to the
unpipelined kernel.

Epilogue requantization (``out_bits``/``out_block``, static): instead of
storing the fp32 accumulator, the kernel block-formats it per
(row, out_block-column-chunk) and emits int8 mantissas + power-of-two
steps — the activation-prequant wire format the NEXT layer's kernel
consumes directly.  Bit-identical to storing f32 and requantizing
(``core.prequant.prequant_act``) because it runs the same block-format
math on the same accumulator values; saves one f32 HBM round-trip per
layer.

Grid: (B/bm, N/bn, K/bk) with K innermost so each (i, j) output tile is
accumulated across sequential k steps in a VMEM scratch accumulator.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bfp import pow2
from repro.tune.tables import fallback_tiles

_ZERO_BLOCK_EXP = -126

#: f32 holds every integer of magnitude <= 2^24 exactly — the bound for
#: the "f32" dot mode to be bit-identical to integer accumulation.
_F32_EXACT_BOUND = 1 << 24


def _floor_log2(amax: jax.Array) -> jax.Array:
    """floor(log2 x), x >= 0, via float32 exponent-field extraction."""
    bits = jax.lax.bitcast_convert_type(amax.astype(jnp.float32), jnp.uint32)
    e = (jnp.right_shift(bits, jnp.uint32(23)) & jnp.uint32(0xFF)).astype(
        jnp.int32) - 127
    return jnp.where(amax > 0, e, _ZERO_BLOCK_EXP)


def _block_format(tile: jax.Array, bits: int, axis: int, mdtype=None):
    """Block-format ``tile`` along ``axis``; returns (mantissa, scale).

    scale is the dequantization step 2^(e - (bits-2)) as fp32, shaped with
    a keepdims-1 on ``axis``.  ``mdtype`` picks the mantissa storage type;
    by default int8 feeds the MXU's native 8-bit path (L <= 8, the paper's
    headline config) and wider mantissas take int32 (still integer-exact).
    The "f32" dot mode passes float32 — the rounded mantissa is already a
    small exact integer in f32, so the cast is free and exact.
    """
    amax = jnp.max(jnp.abs(tile), axis=axis, keepdims=True)
    e = _floor_log2(amax)
    step = pow2(e - (bits - 2))
    lim = float(2 ** (bits - 1) - 1)
    m = jnp.clip(jnp.round(tile.astype(jnp.float32) / step), -lim, lim)
    # All-zero blocks take the sentinel exponent, whose step can flush
    # to zero (subnormal) under XLA: force the 0/0 -> NaN mantissa to 0
    # explicitly — the int cast used to hide this; f32 mantissas don't.
    m = jnp.where(amax > 0, m, 0.0)
    if mdtype is None:
        mdtype = jnp.int8 if bits <= 8 else jnp.int32
    return m.astype(mdtype), step


def f32_dot_exact(l_i: int, l_w: int, bk: int) -> bool:
    """True when an f32 dot over ``bk``-long int-mantissa products is
    bit-identical to int32 accumulation: every product and partial sum
    is an integer of magnitude <= 2^24."""
    return bk * (2 ** (l_i - 1) - 1) * (2 ** (l_w - 1) - 1) \
        <= _F32_EXACT_BOUND


def resolve_dot_impl(dot_impl: str, *, l_i: int, l_w: int, bk: int,
                     interpret: bool, x_pq: bool = False,
                     w_pq: bool = False) -> str:
    """Resolve ``"auto"`` to a concrete dot mode and validate the choice.

    Prequant operands arrive as int8 mantissas by wire contract, so their
    stated L never forces the int32 path — only inline-quantized sides do.
    """
    li_eff = min(l_i, 8) if x_pq else l_i
    lw_eff = min(l_w, 8) if w_pq else l_w
    if dot_impl == "auto":
        if max(li_eff, lw_eff) > 8:
            return "int32"
        if interpret:
            # XLA:CPU integer dots are scalar loops (no BLAS); use the
            # bit-exact f32 path when the bound holds, else stay exact
            # on int32.
            return "f32" if f32_dot_exact(li_eff, lw_eff, bk) else "int32"
        return "int8"
    if dot_impl == "int8" and max(li_eff, lw_eff) > 8:
        raise ValueError(f"dot_impl='int8' needs inline L <= 8, got "
                         f"L_I={l_i}, L_W={l_w}")
    if dot_impl == "f32" and not f32_dot_exact(li_eff, lw_eff, bk):
        raise ValueError(f"dot_impl='f32' not exact for L_I={l_i}, "
                         f"L_W={l_w}, bk={bk} (bound 2^24)")
    if dot_impl not in ("int8", "int32", "f32"):
        raise ValueError(f"unknown dot_impl {dot_impl!r}")
    return dot_impl


def _mantissa_dtype(mode: str, bits: int, pq: bool):
    """Storage dtype of one operand's mantissa tile under a dot mode."""
    if mode == "f32":
        return jnp.float32
    if pq:
        return jnp.int8           # wire contract
    return jnp.int8 if bits <= 8 else jnp.int32


def _tile_dot(mx: jax.Array, mw: jax.Array, mode: str) -> jax.Array:
    """One K-tile mantissa dot under ``mode``; always returns f32."""
    if mode == "f32":
        return jax.lax.dot(mx, mw, preferred_element_type=jnp.float32)
    if mode == "int32":
        mx, mw = mx.astype(jnp.int32), mw.astype(jnp.int32)
    part = jax.lax.dot(mx, mw, preferred_element_type=jnp.int32)
    return part.astype(jnp.float32)


def _requant_store(acc: jax.Array, om_ref, os_ref, *, out_bits: int,
                   out_block: int) -> None:
    """Epilogue: block-format the fp32 accumulator per (row, out_block
    column chunk) and store int8 mantissas + power-of-two steps — the
    activation-prequant wire format, bit-identical to storing f32 and
    running core.prequant.prequant_act on it."""
    for t in range(acc.shape[1] // out_block):
        chunk = acc[:, t * out_block:(t + 1) * out_block]
        m, step = _block_format(chunk, out_bits, axis=1, mdtype=jnp.int8)
        om_ref[:, t * out_block:(t + 1) * out_block] = m
        os_ref[:, t:t + 1] = step


def _make_matmul_kernel(*, l_i: int, l_w: int, n_k: int, x_pq: bool,
                        w_pq: bool, mode: str, pipeline: bool, out_q):
    """Build the kernel body for one static configuration.

    Ref order: x side (1 or 2 refs), w side (1 or 2), out (1 or 2),
    accumulator scratch, then (pipeline only) the four staging buffers.
    """
    x_dt = _mantissa_dtype(mode, l_i, x_pq)
    w_dt = _mantissa_dtype(mode, l_w, w_pq)

    def kernel(*refs):
        it = iter(refs)
        if x_pq:
            xm_ref, xs_ref = next(it), next(it)
        else:
            x_ref = next(it)
        if w_pq:
            wm_ref, ws_ref = next(it), next(it)
        else:
            w_ref = next(it)
        if out_q is not None:
            om_ref, os_ref = next(it), next(it)
        else:
            o_ref = next(it)
        acc_ref = next(it)
        if pipeline:
            mxb, sxb, mwb, swb = next(it), next(it), next(it), next(it)

        k_step = pl.program_id(2)

        @pl.when(k_step == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        def load_x():
            if x_pq:
                # already block-formatted: int8 mantissas + step sidecar
                return xm_ref[...].astype(x_dt), xs_ref[...]   # [bm,bk],[bm,1]
            return _block_format(x_ref[...], l_i, axis=1, mdtype=x_dt)

        def load_w():
            if w_pq:
                # ws IS the step the in-kernel quantizer would compute,
                # so prequant and inline paths agree bit-exactly.
                return wm_ref[...].astype(w_dt), ws_ref[...]   # [bk,bn],[1,bn]
            return _block_format(w_ref[...], l_w, axis=0, mdtype=w_dt)

        def accum(mx, sx, mw, sw):
            acc_ref[...] += _tile_dot(mx, mw, mode) * (sx * sw)

        def store():
            if out_q is None:
                o_ref[...] = acc_ref[...]
            else:
                _requant_store(acc_ref[...], om_ref, os_ref,
                               out_bits=out_q[0], out_block=out_q[1])

        if not pipeline:
            mx, sx = load_x()
            mw, sw = load_w()
            accum(mx, sx, mw, sw)

            @pl.when(k_step == n_k - 1)
            def _store():
                store()
            return

        # Skewed double buffer: stage tile k into slot k%2, dot tile k-1
        # from the other slot.  Quantize(k) has no dependence on
        # dot(k-1), so the VPU and MXU overlap; the accumulation order
        # (0, 1, ..., n_k-1) — and hence the result — is unchanged.
        slot = jax.lax.rem(k_step, 2)
        mx, sx = load_x()
        mw, sw = load_w()
        mxb[slot], sxb[slot] = mx, sx
        mwb[slot], swb[slot] = mw, sw

        @pl.when(k_step > 0)
        def _dot_prev():
            prev = 1 - slot
            accum(mxb[prev], sxb[prev], mwb[prev], swb[prev])

        @pl.when(k_step == n_k - 1)
        def _drain():
            accum(mxb[slot], sxb[slot], mwb[slot], swb[slot])
            store()

    return kernel


def _check_tiles(b, k, n, bm, bn, bk, l_sum, out_q=None):
    if b % bm or n % bn or k % bk:
        raise ValueError(f"shapes ({b},{k})x({k},{n}) not multiples of "
                         f"tiles ({bm},{bn},{bk})")
    # Paper Fig. 2 accumulator sizing: int32 must hold bk products.
    if l_sum + math.ceil(math.log2(bk)) > 32:
        raise ValueError(f"bk={bk} overflows int32 for L_I+L_W={l_sum}")
    if out_q is not None:
        out_bits, out_block = out_q
        if not 2 <= out_bits <= 8:
            raise ValueError(f"epilogue out_bits={out_bits} must be 2..8 "
                             f"(int8 mantissa wire format)")
        if bn % out_block:
            raise ValueError(f"epilogue out_block={out_block} must divide "
                             f"bn={bn}")


def _resolve_bk(bk, b, k, n, l_sum):
    """Shared default: the autotuner's fallback table (ISSUE 6 — fused
    and prequant kernels used to disagree, bk=512 vs bk=128)."""
    return bk if bk is not None else fallback_tiles(b, k, n, None, l_sum)[2]


def _matmul_call(x_ops, w_ops, *, b, k, n, l_i, l_w, bm, bn, bk, interpret,
                 dot_impl, pipeline, out_q):
    """Assemble specs and launch; ``x_ops``/``w_ops`` are (float,) or
    (mantissa, steps) operand tuples; ``out_q`` is None or
    (out_bits, out_block)."""
    x_pq, w_pq = len(x_ops) == 2, len(w_ops) == 2
    _check_tiles(b, k, n, bm, bn, bk, l_i + l_w, out_q)
    mode = resolve_dot_impl(dot_impl, l_i=l_i, l_w=l_w, bk=bk,
                            interpret=interpret, x_pq=x_pq, w_pq=w_pq)
    n_k = k // bk
    grid = (b // bm, n // bn, n_k)

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))]
    if x_pq:
        in_specs.append(pl.BlockSpec((bm, 1), lambda i, j, kk: (i, kk)))
    in_specs.append(pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)))
    if w_pq:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)))

    if out_q is None:
        out_specs = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
        out_shape = jax.ShapeDtypeStruct((b, n), jnp.float32)
    else:
        bq = out_q[1]
        out_specs = [
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn // bq), lambda i, j, kk: (i, j)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, n), jnp.int8),
            jax.ShapeDtypeStruct((b, n // bq), jnp.float32),
        ]

    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    if pipeline:
        scratch += [
            pltpu.VMEM((2, bm, bk), _mantissa_dtype(mode, l_i, x_pq)),
            pltpu.VMEM((2, bm, 1), jnp.float32),
            pltpu.VMEM((2, bk, bn), _mantissa_dtype(mode, l_w, w_pq)),
            pltpu.VMEM((2, 1, bn), jnp.float32),
        ]

    kernel = _make_matmul_kernel(l_i=l_i, l_w=l_w, n_k=n_k, x_pq=x_pq,
                                 w_pq=w_pq, mode=mode, pipeline=pipeline,
                                 out_q=out_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*x_ops, *w_ops)


def _out_q(out_bits, out_block, bn):
    if out_bits is None:
        return None
    return (out_bits, out_block if out_block is not None else bn)


_STATIC = ("l_i", "l_w", "bm", "bn", "bk", "interpret", "dot_impl",
           "pipeline", "out_bits", "out_block")


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_matmul_pallas(x: jax.Array, w: jax.Array, *, l_i: int = 8,
                      l_w: int = 8, bm: int = 128, bn: int = 128,
                      bk: int | None = None, interpret: bool = False,
                      dot_impl: str = "auto", pipeline: bool = True,
                      out_bits: int | None = None,
                      out_block: int | None = None):
    """x[B,K] @ w[K,N] through the fused BFP datapath.

    Shapes must be multiples of the block sizes (ops.py pads).  The K tile
    ``bk`` IS the BFP block size (Scheme.TILED with block_k = bk);
    ``bk=None`` takes the autotuner's fallback table.  With ``out_bits``
    set, returns (int8 mantissa [B,N], f32 steps [B, N/out_block]) — the
    epilogue-requantized activation wire format — instead of f32.
    """
    b, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    bk = _resolve_bk(bk, b, k, n, l_i + l_w)
    return _matmul_call((x,), (w,), b=b, k=k, n=n, l_i=l_i, l_w=l_w,
                        bm=bm, bn=bn, bk=bk, interpret=interpret,
                        dot_impl=dot_impl, pipeline=pipeline,
                        out_q=_out_q(out_bits, out_block, bn))


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_matmul_prequant_pallas(x: jax.Array, wm: jax.Array, ws: jax.Array,
                               *, l_i: int = 8, l_w: int = 8, bm: int = 128,
                               bn: int = 128, bk: int | None = None,
                               interpret: bool = False,
                               dot_impl: str = "auto", pipeline: bool = True,
                               out_bits: int | None = None,
                               out_block: int | None = None):
    """x[B,K] @ prequant weight (int8 mantissa [K,N] + steps [K//bk,N]).

    ``bk`` must equal the prequant block size (K // ws.shape[0]); the BFP
    block IS the K tile, as in the fused kernel.  ``l_w`` only sizes the
    overflow check — weight quantization already happened offline.
    """
    b, k = x.shape
    k2, n = wm.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {wm.shape}")
    bk = _resolve_bk(bk, b, k, n, l_i + l_w)
    if ws.shape != (k // bk, n):
        raise ValueError(f"scale sidecar {ws.shape} != {(k // bk, n)} "
                         f"for bk={bk}")
    if wm.dtype != jnp.int8:
        raise ValueError(f"prequant kernel streams int8 mantissas, got "
                         f"{wm.dtype}")
    return _matmul_call((x,), (wm, ws), b=b, k=k, n=n, l_i=l_i, l_w=l_w,
                        bm=bm, bn=bn, bk=bk, interpret=interpret,
                        dot_impl=dot_impl, pipeline=pipeline,
                        out_q=_out_q(out_bits, out_block, bn))


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_matmul_xprequant_pallas(xm: jax.Array, xs: jax.Array, w: jax.Array,
                                *, l_i: int = 8, l_w: int = 8, bm: int = 128,
                                bn: int = 128, bk: int | None = None,
                                interpret: bool = False,
                                dot_impl: str = "auto", pipeline: bool = True,
                                out_bits: int | None = None,
                                out_block: int | None = None):
    """Prequant ACTIVATIONS (int8 mantissa [B,K] + steps [B,K//bk]) @
    float w[K,N] — the consumer half of epilogue-requantize chaining.
    ``l_i`` only sizes the overflow check; activation quantization
    already happened in the producing layer's epilogue."""
    b, k = xm.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {xm.shape} @ {w.shape}")
    bk = _resolve_bk(bk, b, k, n, l_i + l_w)
    if xs.shape != (b, k // bk):
        raise ValueError(f"activation sidecar {xs.shape} != "
                         f"{(b, k // bk)} for bk={bk}")
    if xm.dtype != jnp.int8:
        raise ValueError(f"activation-prequant kernel streams int8 "
                         f"mantissas, got {xm.dtype}")
    return _matmul_call((xm, xs), (w,), b=b, k=k, n=n, l_i=l_i, l_w=l_w,
                        bm=bm, bn=bn, bk=bk, interpret=interpret,
                        dot_impl=dot_impl, pipeline=pipeline,
                        out_q=_out_q(out_bits, out_block, bn))


@functools.partial(jax.jit, static_argnames=_STATIC)
def bfp_matmul_xwprequant_pallas(xm: jax.Array, xs: jax.Array,
                                 wm: jax.Array, ws: jax.Array, *,
                                 l_i: int = 8, l_w: int = 8, bm: int = 128,
                                 bn: int = 128, bk: int | None = None,
                                 interpret: bool = False,
                                 dot_impl: str = "auto",
                                 pipeline: bool = True,
                                 out_bits: int | None = None,
                                 out_block: int | None = None):
    """Both sides prequantized — the steady state of a bound plan chain:
    weights offline, activations from the previous layer's epilogue.  No
    in-kernel quantization at all; the datapath is pure int8 dots plus
    power-of-two rescales."""
    b, k = xm.shape
    k2, n = wm.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {xm.shape} @ {wm.shape}")
    bk = _resolve_bk(bk, b, k, n, l_i + l_w)
    if xs.shape != (b, k // bk):
        raise ValueError(f"activation sidecar {xs.shape} != "
                         f"{(b, k // bk)} for bk={bk}")
    if ws.shape != (k // bk, n):
        raise ValueError(f"scale sidecar {ws.shape} != {(k // bk, n)} "
                         f"for bk={bk}")
    if xm.dtype != jnp.int8 or wm.dtype != jnp.int8:
        raise ValueError(f"prequant kernels stream int8 mantissas, got "
                         f"{xm.dtype} / {wm.dtype}")
    return _matmul_call((xm, xs), (wm, ws), b=b, k=k, n=n, l_i=l_i,
                        l_w=l_w, bm=bm, bn=bn, bk=bk, interpret=interpret,
                        dot_impl=dot_impl, pipeline=pipeline,
                        out_q=_out_q(out_bits, out_block, bn))
