"""Fused BFP matmul Pallas kernel — the paper's accelerator datapath on TPU.

One kernel fuses the paper's whole pipeline (Fig. 2):

    HBM float tiles --> VMEM
      block-format x-tile  (per-row exponent over the K-tile)     \
      block-format w-tile  (per-column exponent over the K-tile)   } in VMEM
      int8 x int8 -> int32 systolic matmul on the MXU             /
      power-of-two rescale + fp32 accumulate in VMEM scratch
    fp32 out tile --> HBM

This is the TPU adaptation of the paper's FPGA design (DESIGN.md §2): the
block is the K-tile the matmul pipeline stages through VMEM anyway, so
block formatting costs no extra HBM traffic; the fixed-point MAC array is
the MXU's native int8 path.  Accumulation is int32-exact within a tile
(paper's accumulator-width rule: L_W + L_I + log2(block_k) <= 32 is
asserted) and fp32 across tiles.

Grid: (B/bm, N/bn, K/bk) with K innermost so each (i, j) output tile is
accumulated across sequential k steps in a VMEM scratch accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bfp import pow2

_ZERO_BLOCK_EXP = -126



def _floor_log2(amax: jax.Array) -> jax.Array:
    """floor(log2 x), x >= 0, via float32 exponent-field extraction."""
    bits = jax.lax.bitcast_convert_type(amax.astype(jnp.float32), jnp.uint32)
    e = (jnp.right_shift(bits, jnp.uint32(23)) & jnp.uint32(0xFF)).astype(
        jnp.int32) - 127
    return jnp.where(amax > 0, e, _ZERO_BLOCK_EXP)


def _block_format(tile: jax.Array, bits: int, axis: int):
    """Block-format ``tile`` along ``axis``; returns (int8 mantissa, scale).

    scale is the dequantization step 2^(e - (bits-2)) as fp32, shaped with
    a keepdims-1 on ``axis``.
    """
    amax = jnp.max(jnp.abs(tile), axis=axis, keepdims=True)
    e = _floor_log2(amax)
    step = pow2(e - (bits - 2))
    lim = float(2 ** (bits - 1) - 1)
    m = jnp.clip(jnp.round(tile.astype(jnp.float32) / step), -lim, lim)
    # int8 feeds the MXU's native 8-bit path (L <= 8, the paper's headline
    # config); wider mantissas take the int32 path (still integer-exact).
    return m.astype(jnp.int8 if bits <= 8 else jnp.int32), step


def _bfp_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, l_i: int, l_w: int,
                       n_k: int):
    """One (i, j, k) grid step: quantize both tiles, int matmul, rescale."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mx, sx = _block_format(x_ref[...], l_i, axis=1)   # [bm,bk], [bm,1]
    mw, sw = _block_format(w_ref[...], l_w, axis=0)   # [bk,bn], [1,bn]
    # MXU int8 x int8 -> int32 (exact: block_k bounded by overflow assert).
    part = jax.lax.dot(mx.astype(jnp.int32), mw.astype(jnp.int32),
                       preferred_element_type=jnp.int32)
    acc_ref[...] += part.astype(jnp.float32) * (sx * sw)

    @pl.when(k_step == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


def _check_tiles(b, k, n, bm, bn, bk, l_sum):
    if b % bm or n % bn or k % bk:
        raise ValueError(f"shapes ({b},{k})x({k},{n}) not multiples of "
                         f"tiles ({bm},{bn},{bk})")
    # Paper Fig. 2 accumulator sizing: int32 must hold bk products.
    import math
    if l_sum + math.ceil(math.log2(bk)) > 32:
        raise ValueError(f"bk={bk} overflows int32 for L_I+L_W={l_sum}")


@functools.partial(jax.jit, static_argnames=("l_i", "l_w", "bm", "bn", "bk",
                                             "interpret"))
def bfp_matmul_pallas(x: jax.Array, w: jax.Array, *, l_i: int = 8,
                      l_w: int = 8, bm: int = 128, bn: int = 128,
                      bk: int = 512, interpret: bool = False) -> jax.Array:
    """x[B,K] @ w[K,N] through the fused BFP datapath.

    Shapes must be multiples of the block sizes (ops.py pads).  The K tile
    ``bk`` IS the BFP block size (Scheme.TILED with block_k = bk).
    """
    b, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    _check_tiles(b, k, n, bm, bn, bk, l_i + l_w)

    n_k = k // bk
    grid = (b // bm, n // bn, n_k)
    kernel = functools.partial(_bfp_matmul_kernel, l_i=l_i, l_w=l_w, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _bfp_matmul_prequant_kernel(x_ref, wm_ref, ws_ref, o_ref, acc_ref, *,
                                l_i: int, n_k: int):
    """Prequant variant of one (i, j, k) grid step.

    The weight tile arrives ALREADY block-formatted: int8 mantissas
    (wm_ref) plus this K-tile's power-of-two step row (ws_ref, [1, bn]).
    Only the activation tile is quantized in-kernel — the weight half of
    the paper's block-formatting stage moved offline, which also cuts the
    weight tile's HBM traffic 4x (int8 vs f32).
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mx, sx = _block_format(x_ref[...], l_i, axis=1)   # [bm,bk], [bm,1]
    mw = wm_ref[...].astype(jnp.int32)                # [bk,bn] int8 in HBM
    part = jax.lax.dot(mx.astype(jnp.int32), mw,
                       preferred_element_type=jnp.int32)
    # identical accumulation expression to the fused kernel: ws IS the
    # same power-of-two step the in-kernel weight quantizer would compute,
    # so fused and prequant paths agree bit-exactly.
    acc_ref[...] += part.astype(jnp.float32) * (sx * ws_ref[...])

    @pl.when(k_step == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("l_i", "l_w", "bm", "bn", "bk",
                                             "interpret"))
def bfp_matmul_prequant_pallas(x: jax.Array, wm: jax.Array, ws: jax.Array,
                               *, l_i: int = 8, l_w: int = 8, bm: int = 128,
                               bn: int = 128, bk: int = 128,
                               interpret: bool = False) -> jax.Array:
    """x[B,K] @ prequant weight (int8 mantissa [K,N] + steps [K//bk,N]).

    ``bk`` must equal the prequant block size (K // ws.shape[0]); the BFP
    block IS the K tile, as in the fused kernel.  ``l_w`` only sizes the
    overflow check — weight quantization already happened offline.
    """
    b, k = x.shape
    k2, n = wm.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {x.shape} @ {wm.shape}")
    if ws.shape != (k // bk, n):
        raise ValueError(f"scale sidecar {ws.shape} != {(k // bk, n)} "
                         f"for bk={bk}")
    if wm.dtype != jnp.int8:
        raise ValueError(f"prequant kernel streams int8 mantissas, got "
                         f"{wm.dtype}")
    _check_tiles(b, k, n, bm, bn, bk, l_i + l_w)

    n_k = k // bk
    grid = (b // bm, n // bn, n_k)
    kernel = functools.partial(_bfp_matmul_prequant_kernel, l_i=l_i, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, wm, ws)
