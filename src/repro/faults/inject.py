"""Deterministic, seeded fault injectors for the BFP datapath.

The paper's premise is that CNNs tolerate BFP's computation error; this
module makes the stronger question measurable: how much ADDITIONAL,
un-designed error (single-event upsets in weight memory, corrupted wire
blocks, accumulator glitches) does the same network absorb?  Every
injector is keyed by an explicit seed, so a campaign is reproducible
bit-for-bit: same seed -> same flips -> same logits.

Three fault surfaces, matching where the bits physically live:

  * **Packed weight storage** (:func:`flip_payload_bits`,
    :func:`flip_exponent_bits`): flips land in the
    :class:`~repro.core.packed.PackedBFP` container's mantissa bitstream
    / int8 exponent plane — the SEU/memory model.  A flipped container
    still parses (range validation happens at PACK time, faults happen
    after), so the corrupted weights flow through ``engine.bind`` into
    the real serving datapath.
  * **Wire blocks** (:func:`corrupt_container_bytes`): flips in the
    SERIALIZED byte stream, past the header — what a faulty transfer
    produces.  ``dist.compress.unpack_leaf`` rejects these with
    :class:`~repro.core.packed.IntegrityError` (the integrity layer this
    injector exercises).
  * **Activations** (:func:`perturb_activations`,
    :func:`activation_faults`): flips in the int8 two's-complement
    memory image of a block-formatted activation buffer, delivered onto
    the live datapath through the ``engine.taps`` ``transform=True``
    hook — run the model un-jitted (taps see eager execution only).

Bit indexing convention: ``bit=0`` is the least-significant mantissa
bit (one quantization step), ``bit=L-1`` the most significant bit of
the L-bit field.  ``bit=None`` makes every bit of the field eligible.
``mode="bernoulli"`` flips each eligible bit independently with
probability ``ber``; ``mode="exact"`` flips exactly
``round(ber * n_eligible)`` distinct bits (smooth, zero-variance
campaign curves).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Any, Iterator, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import bfp
from repro.core.packed import PackedBFP

__all__ = [
    "FaultStats", "derive_rng", "flip_payload_bits", "flip_exponent_bits",
    "corrupt_container_bytes", "perturb_activations", "activation_faults",
]

SeedLike = Union[int, np.random.Generator]


def derive_rng(seed: SeedLike, *keys: Union[int, str]) -> np.random.Generator:
    """A reproducible sub-generator from (seed, keys).

    String keys (leaf paths, site names) hash through CRC32, which is
    stable across platforms and Python processes — unlike ``hash()``.
    Passing an existing Generator returns it unchanged (caller already
    derived it).
    """
    if isinstance(seed, np.random.Generator):
        return seed
    import zlib
    ent = [int(seed) & 0xFFFFFFFF]
    for k in keys:
        ent.append(zlib.crc32(k.encode()) if isinstance(k, str)
                   else int(k) & 0xFFFFFFFF)
    return np.random.default_rng(ent)


def _check_args(ber: float, mode: str, bit: Optional[int],
                width: int) -> None:
    if not 0.0 <= ber <= 1.0:
        raise ValueError(f"bit-error rate must be in [0, 1], got {ber}")
    if mode not in ("bernoulli", "exact"):
        raise ValueError(f"mode must be 'bernoulli' or 'exact', got {mode!r}")
    if bit is not None and not 0 <= bit < width:
        raise ValueError(f"bit must be in [0, {width}) for this field, "
                         f"got {bit}")


def _pick(rng: np.random.Generator, n_eligible: int, ber: float,
          mode: str) -> np.ndarray:
    """Indices (into the eligible-bit enumeration) to flip."""
    if n_eligible == 0:
        return np.zeros((0,), np.int64)
    if mode == "exact":
        k = min(n_eligible, int(round(ber * n_eligible)))
        return rng.choice(n_eligible, size=k, replace=False)
    return np.nonzero(rng.random(n_eligible) < ber)[0]


def flip_payload_bits(p: PackedBFP, ber: float, seed: SeedLike, *,
                      bit: Optional[int] = None,
                      mode: str = "bernoulli") -> Tuple[PackedBFP, int]:
    """Flip bits in the mantissa bitstream (weight-memory SEU model).

    Eligible bits are the ``n_elements * L`` DATA bits (the final byte's
    padding never flips — it is not part of any mantissa).  With
    ``bit=j`` only position ``j`` of each element's L-bit field is
    eligible (``j=0`` = LSB = one step, ``j=L-1`` = MSB of the
    offset-binary field = half the field's range — the high-order-bit
    experiment).  Returns ``(corrupted container, n_flips)``; the
    original is untouched.  ``stored_crc`` is preserved, so a container
    that came off disk/wire still FAILS ``verify()`` afterwards — which
    is exactly what an integrity layer should detect.
    """
    L = p.bits
    _check_args(ber, mode, bit, L)
    rng = derive_rng(seed)
    n = p.n_elements
    n_eligible = n * L if bit is None else n
    idx = _pick(rng, n_eligible, ber, mode)
    if bit is None:
        abs_bits = idx                       # dense enumeration IS the stream
    else:
        # element i's field occupies stream bits [i*L, (i+1)*L), MSB first
        abs_bits = idx * L + (L - 1 - bit)
    arr = np.frombuffer(p.payload, np.uint8).copy()
    np.bitwise_xor.at(arr, abs_bits // 8,
                      (np.uint8(1) << (7 - (abs_bits % 8)).astype(np.uint8)))
    return dataclasses.replace(p, payload=arr.tobytes()), int(len(abs_bits))


def flip_exponent_bits(p: PackedBFP, ber: float, seed: SeedLike, *,
                       bit: Optional[int] = None,
                       mode: str = "bernoulli") -> Tuple[PackedBFP, int]:
    """Flip bits in the int8 exponent plane (one byte per block).

    A flipped block exponent rescales EVERY element of its block by a
    power of two — the paper's shared-exponent economy is exactly what
    makes these catastrophic, and the campaign quantifies it.  ``bit``
    indexes the int8 two's-complement byte (0 = LSB, 7 = sign).
    """
    _check_args(ber, mode, bit, 8)
    rng = derive_rng(seed)
    e = np.ascontiguousarray(p.exponents, np.int8).reshape(-1).copy()
    n_eligible = e.size * 8 if bit is None else e.size
    idx = _pick(rng, n_eligible, ber, mode)
    if bit is None:
        elem, pos = idx // 8, idx % 8
    else:
        elem, pos = idx, np.full(idx.shape, bit, np.int64)
    u = e.view(np.uint8)
    np.bitwise_xor.at(u, elem, (np.uint8(1) << pos.astype(np.uint8)))
    return (dataclasses.replace(p, exponents=e.reshape(p.exp_shape)),
            int(len(idx)))


def corrupt_container_bytes(p: Union[PackedBFP, bytes], seed: SeedLike,
                            n_flips: int = 1) -> bytes:
    """Flip ``n_flips`` random bits in a SERIALIZED container's data
    region (exponent plane + bitstream — past the header, so the result
    still parses structurally and the CRC check is what trips).

    This is the wire-corruption model: ``PackedBFP.from_bytes`` /
    ``dist.compress.unpack_leaf`` on the returned bytes raise
    :class:`~repro.core.packed.IntegrityError`.
    """
    if isinstance(p, PackedBFP):
        data_len = p.exponents.size + len(p.payload)
        buf = p.to_bytes()
    else:
        parsed = PackedBFP.from_bytes(p, verify=False)
        data_len = parsed.exponents.size + len(parsed.payload)
        buf = bytes(p)
    rng = derive_rng(seed)
    arr = np.frombuffer(buf, np.uint8).copy()
    start = len(buf) - data_len           # data region is the tail
    bits = rng.choice(data_len * 8, size=min(n_flips, data_len * 8),
                      replace=False)
    np.bitwise_xor.at(arr, start + bits // 8,
                      (np.uint8(1) << (7 - (bits % 8)).astype(np.uint8)))
    return arr.tobytes()


# ---------------------------------------------------------------------------
# Activation faults (the taps-integrated hook)
# ---------------------------------------------------------------------------

def perturb_activations(y: Any, ber: float, seed: SeedLike, *,
                        bits: int = 8, block: int = 256,
                        bit: Optional[int] = None,
                        mode: str = "bernoulli") -> Tuple[jnp.ndarray, int]:
    """Bit-flip an activation tensor's BFP memory image.

    Models an SEU in the activation SRAM: the tensor is block-formatted
    at ``bits`` (flat ``block``-element blocks, the wire geometry), the
    int8 two's-complement mantissa image takes ``ber`` flips on the
    chosen ``bit`` (0..7 of the stored byte; None = all 8), and the
    corrupted image is dequantized back.  Returns ``(perturbed, flips)``
    with the original shape/dtype.  ``bits`` must be <= 8 (the int8
    storage the accelerator uses for activations).
    """
    if not 2 <= bits <= 8:
        raise ValueError(f"activation faults model int8 storage: bits "
                         f"must be in [2, 8], got {bits}")
    _check_args(ber, mode, bit, 8)
    rng = derive_rng(seed)
    arr = np.asarray(y, np.float32)
    n = arr.size
    nb = -(-n // block)
    padded = np.zeros(nb * block, np.float32)
    padded[:n] = arr.reshape(-1)
    blk = bfp.quantize(jnp.asarray(padded.reshape(nb, block)), bits, (1,))
    m = np.asarray(blk.mantissa).astype(np.int8).reshape(-1)
    n_eligible = m.size * 8 if bit is None else m.size
    idx = _pick(rng, n_eligible, ber, mode)
    if bit is None:
        elem, pos = idx // 8, idx % 8
    else:
        elem, pos = idx, np.full(idx.shape, bit, np.int64)
    u = m.view(np.uint8)
    np.bitwise_xor.at(u, elem, (np.uint8(1) << pos.astype(np.uint8)))
    step = np.asarray(bfp.pow2(blk.exponent - (bits - 2)), np.float32)
    deq = m.reshape(nb, block).astype(np.float32) * step
    out = deq.reshape(-1)[:n].reshape(arr.shape)
    return jnp.asarray(out, jnp.asarray(y).dtype), int(len(idx))


@dataclasses.dataclass
class FaultStats:
    """What an :func:`activation_faults` context actually injected."""

    events: int = 0     #: engine sites whose output was perturbed
    flips: int = 0      #: total bit flips across those sites


@contextlib.contextmanager
def activation_faults(ber: float, seed: int, *, bits: int = 8,
                      block: int = 256, bit: Optional[int] = None,
                      paths: Optional[set] = None,
                      mode: str = "bernoulli") -> Iterator[FaultStats]:
    """Perturb every engine GEMM/conv output inside the context.

    Rides the ``engine.taps`` ``transform=True`` hook, so the faults
    land on the REAL datapath output of each site (and downstream layers
    consume the corrupted activations, exactly like a faulty activation
    buffer would feed the next layer).  ``paths`` restricts injection to
    the named sites; every event consumes one deterministic sub-seed in
    execution order, so the flip pattern is a pure function of
    ``(seed, model, input shapes)``.  Taps see eager execution only —
    run the model un-jitted.
    """
    from repro.engine.taps import taps as datapath_taps
    stats = FaultStats()
    counter = itertools.count()

    def xform(ev):
        i = next(counter)                    # consumed even when filtered:
        if paths is not None and ev.path not in paths:   # stable sub-seeds
            return None
        rng = derive_rng(seed, i)
        y2, k = perturb_activations(ev.y, ber, rng, bits=bits, block=block,
                                    bit=bit, mode=mode)
        stats.events += 1
        stats.flips += k
        return y2

    with datapath_taps(xform, transform=True):
        yield stats
