"""Fault injection and endurance campaigns for the BFP datapath.

``repro.faults.inject`` holds the seeded injectors (packed-container
mantissa/exponent bit flips, wire-byte corruption, taps-driven
activation perturbation); ``repro.faults.campaign`` sweeps them over
bit-error rate x mantissa width x target across the CNN registry and
reads out top-1 agreement + logit SNR.  DESIGN.md §11 has the fault
model and the measured hierarchy (exponent >> mantissa MSB >> LSB).
"""
from repro.faults.campaign import (TARGETS, endurance_campaign,
                                   inject_tree, mean_nsr, run_point)
from repro.faults.inject import (FaultStats, activation_faults,
                                 corrupt_container_bytes, derive_rng,
                                 flip_exponent_bits, flip_payload_bits,
                                 perturb_activations)

__all__ = [
    "FaultStats", "activation_faults", "corrupt_container_bytes",
    "derive_rng", "flip_exponent_bits", "flip_payload_bits",
    "perturb_activations",
    "TARGETS", "endurance_campaign", "inject_tree", "mean_nsr",
    "run_point",
]
