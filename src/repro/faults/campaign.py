"""Fault-endurance sweep: accuracy + SNR vs bit-error rate x L x target.

The paper's Table 3/4 measured how much DESIGNED error (BFP
quantization at mantissa width L) the networks absorb; this campaign
measures the UNDESIGNED kind: seeded bit flips injected into the packed
weight containers (``repro.faults.inject``) or the live activation
datapath, swept over bit-error rate, mantissa width, and fault target,
for every model in the CNN registry.

The campaign's top-line finding mirrors the shared-exponent structure:

  * ``exponent`` flips are CATASTROPHIC — one flipped int8 bit rescales
    an entire block by up to 2^128;
  * ``mantissa_msb`` flips (bit L-1) hurt in proportion to the block
    scale — each one moves an element by half the block's range;
  * ``mantissa_lsb`` flips (bit 0) are nearly free — one quantization
    step each, indistinguishable from the rounding error the design
    already absorbs.

so the measured NSR obeys  exponent >> mantissa_msb >> mantissa_lsb  at
equal BER (pinned in tests/test_faults.py and plotted by
``benchmarks/faults_bench.py``).

No labeled dataset ships with the repo, so "accuracy" is the standard
fault-tolerance proxy: top-1 AGREEMENT between the faulty model and its
own clean-BFP predictions on seeded inputs (1.0 = faults changed no
decisions), alongside ``core.nsr`` logit SNR — the same two-axis
readout the serving degradation layer keys off.  Everything is keyed by
one explicit seed; ``mode="exact"`` (the default) flips exactly
``round(ber * n_bits)`` bits, so a campaign row is a pure function of
its arguments: same seed -> same flips -> same logits, bit-for-bit.
"""
from __future__ import annotations

import zlib
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as EG
from repro.core import nsr as NSR
from repro.core import packed as PK
from repro.core.policy import TPU_TILED
from repro.faults import inject as INJ
from repro.models.cnn import MODELS

__all__ = ["TARGETS", "inject_tree", "run_point", "endurance_campaign",
           "mean_nsr"]

#: Fault targets the campaign understands.  "mantissa" flips anywhere in
#: the L-bit field; the _msb/_lsb variants isolate one bit position.
TARGETS = ("exponent", "mantissa", "mantissa_msb", "mantissa_lsb",
           "activation")


def _policy(l: int):
    """Serving-mode policy at mantissa width ``l`` (whole-K tiles so
    every reduced-model K packs; inference numerics)."""
    return TPU_TILED.with_(block_k=None, straight_through=False,
                           l_w=l, l_i=l)


def inject_tree(tree: Any, target: str, ber: float, seed: int, *,
                mode: str = "exact") -> Tuple[Any, int]:
    """Inject ``target`` faults into every packed leaf of a param tree.

    ``tree`` is a ``pack_param_tree`` output (PackedBFP weight leaves,
    everything else untouched).  Each leaf gets its own sub-generator
    derived from ``(seed, crc32(leaf path))``, so the flip pattern is
    independent of tree iteration order and stable across runs.
    Returns ``(faulty tree, total flips)``.
    """
    if target not in TARGETS or target == "activation":
        raise ValueError(f"inject_tree target must be one of "
                         f"{[t for t in TARGETS if t != 'activation']}, "
                         f"got {target!r}")
    total = [0]

    def one(path, leaf):
        if not PK.is_packed(leaf):
            return leaf
        pstr = jax.tree_util.keystr(path)
        rng = INJ.derive_rng(seed, zlib.crc32(pstr.encode()))
        if target == "exponent":
            leaf2, k = INJ.flip_exponent_bits(leaf, ber, rng, mode=mode)
        else:
            bit = {"mantissa": None, "mantissa_msb": leaf.bits - 1,
                   "mantissa_lsb": 0}[target]
            leaf2, k = INJ.flip_payload_bits(leaf, ber, rng, bit=bit,
                                             mode=mode)
        total[0] += k
        return leaf2

    out = jax.tree_util.tree_map_with_path(one, tree,
                                           is_leaf=PK.is_packed)
    return out, total[0]


def _head0(y):
    return y[0] if isinstance(y, tuple) else y


def _logits(spec, tree, policy, imgs) -> np.ndarray:
    """Eagerly run a (possibly packed, possibly corrupted) tree."""
    plan = EG.bind(tree, policy, tree="cnn")
    return np.asarray(_head0(spec.apply(plan.params, imgs, plan)),
                      np.float32)


def run_point(model: str, l: int, target: str, ber: float, seed: int, *,
              n_images: int = 4, reduced: bool = True,
              mode: str = "exact",
              _ctx: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """One campaign point: inject, run, compare against the clean-BFP
    baseline.  Returns a flat record (CSV-friendly)::

        {"model", "l", "target", "ber", "n_flips",
         "top1_agree", "snr_db", "nsr"}

    ``_ctx`` lets :func:`endurance_campaign` reuse the packed tree /
    clean logits across the BER sweep; standalone calls rebuild them.
    """
    spec = MODELS[model]
    policy = _policy(l)
    if _ctx is None:
        key = jax.random.PRNGKey(seed)
        params = spec.init(key, reduced=reduced)
        imgs = jax.random.normal(jax.random.fold_in(key, 1),
                                 (n_images, *spec.input_shape(
                                     reduced=reduced)))
        packed_tree = PK.pack_param_tree(params, policy, kind="cnn")
        clean = _logits(spec, packed_tree, policy, imgs)
    else:
        imgs, packed_tree, clean = (_ctx["imgs"], _ctx["packed"],
                                    _ctx["clean"])

    if target == "activation":
        with INJ.activation_faults(ber, seed, bits=l, mode=mode) as stats:
            faulty = _logits(spec, packed_tree, policy, imgs)
        n_flips = stats.flips
    else:
        tree_f, n_flips = inject_tree(packed_tree, target, ber, seed,
                                      mode=mode)
        faulty = _logits(spec, tree_f, policy, imgs)

    agree = float(np.mean(np.argmax(faulty, -1) == np.argmax(clean, -1)))
    finite = bool(np.all(np.isfinite(faulty)))
    snr = (float(NSR.snr_db(jnp.asarray(clean), jnp.asarray(faulty)))
           if finite else float("-inf"))
    return {"model": model, "l": l, "target": target, "ber": ber,
            "n_flips": int(n_flips), "top1_agree": agree,
            "snr_db": snr, "nsr": float(NSR.nsr_from_snr_db(snr)),
            "finite": finite}


def endurance_campaign(models: Iterable[str] = ("lenet",),
                       l_values: Sequence[int] = (8,),
                       bers: Sequence[float] = (1e-3, 1e-2),
                       targets: Sequence[str] = ("exponent",
                                                 "mantissa_msb",
                                                 "mantissa_lsb"),
                       *, seed: int = 0, n_images: int = 4,
                       reduced: bool = True,
                       mode: str = "exact") -> List[Dict[str, Any]]:
    """Sweep BER x L x target across ``models`` (registry names).

    For each (model, L) the packed tree and clean-baseline logits are
    built ONCE and shared by every (target, ber) cell, so every row of
    a given (model, L) slice is measured against the identical baseline.
    Returns the flat list of :func:`run_point` records, in deterministic
    sweep order.
    """
    for t in targets:
        if t not in TARGETS:
            raise ValueError(f"unknown fault target {t!r}; "
                             f"choose from {TARGETS}")
    rows: List[Dict[str, Any]] = []
    for model in models:
        spec = MODELS[model]
        key = jax.random.PRNGKey(seed)
        params = spec.init(key, reduced=reduced)
        imgs = jax.random.normal(jax.random.fold_in(key, 1),
                                 (n_images, *spec.input_shape(
                                     reduced=reduced)))
        for l in l_values:
            policy = _policy(l)
            packed_tree = PK.pack_param_tree(params, policy, kind="cnn")
            ctx = {"imgs": imgs, "packed": packed_tree,
                   "clean": _logits(spec, packed_tree, policy, imgs)}
            for target in targets:
                for ber in bers:
                    rows.append(run_point(model, l, target, ber, seed,
                                          n_images=n_images,
                                          reduced=reduced, mode=mode,
                                          _ctx=ctx))
    return rows


def mean_nsr(rows: Iterable[Dict[str, Any]], **match: Any) -> float:
    """Mean NSR over the rows whose fields equal ``match`` (non-finite
    rows count as NSR=inf — a crashed network is maximally noisy)."""
    vals = [float("inf") if not r.get("finite", True) else r["nsr"]
            for r in rows
            if all(r.get(k) == v for k, v in match.items())]
    if not vals:
        raise ValueError(f"no campaign rows match {match!r}")
    return float(np.mean(vals))
