"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Backbone only (per assignment the vision frontend is a stub; input_specs
provides precomputed patch embeddings).  28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936.  M-RoPE sections (16, 24, 24) over the 64
rotary-half dims of head_dim=128.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    max_seq_len=32768,
)
