"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.
[arXiv:2308.11596; hf]

Transformer backbone only: 12L encoder + 12L decoder, d_model=1024 16H
(MHA kv=16) d_ff=4096 vocab=256206.  The speech frontend is a stub —
input_specs() provides precomputed frame embeddings [B, S_enc, d_model].
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,           # decoder layers
    encoder_layers=12,
    enc_seq_stub=1024,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    max_seq_len=4096,
)
