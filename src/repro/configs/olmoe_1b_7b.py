"""olmoe-1b-7b [moe] — 64 experts top-8.  [arXiv:2409.02060; hf]

16L d_model=2048 16H (MHA kv=16) d_ff=1024 vocab=50304.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    n_experts=64,
    top_k=8,
    max_seq_len=4096,
)
