"""qwen1.5-4b [dense] — QKV bias.  [hf:Qwen/Qwen1.5-0.5B (family); hf]

40L d_model=2560 20H (MHA kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    max_seq_len=32768,
)
