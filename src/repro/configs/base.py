"""Architecture config schema + input-shape definitions for all assigned
architectures (system-prompt pool) and the paper's CNNs."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["LMConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """One LM-family architecture.  All sizes are the exact public configs
    (see src/repro/configs/<id>.py for sources)."""

    name: str
    family: str                      # dense | hybrid | ssm | vlm | moe | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- attention variants --------------------------------------------------
    sliding_window: Optional[int] = None   # SWA (mixtral) / local attn window
    qkv_bias: bool = False                 # qwen QKV bias
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # --- hybrid / ssm ---------------------------------------------------------
    block_pattern: Optional[Tuple[str, ...]] = None  # e.g. ("rec","rec","attn")
    lru_width: Optional[int] = None                  # RG-LRU state width
    conv_width: int = 4                              # temporal conv (griffin)

    # --- encoder-decoder -----------------------------------------------------
    encoder_layers: int = 0          # >0 => enc-dec (seamless)
    enc_seq_stub: int = 1024         # precomputed frame/patch embeddings length

    # --- misc ------------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    compute_dtype: str = "float32"   # activations dtype (dry-run: bfloat16)
    analysis_unroll: bool = False    # unroll layer/chunk loops so XLA
                                     # cost_analysis counts every trip
                                     # (scan bodies are visited once)
    max_seq_len: int = 131072
    attn_logit_softcap: Optional[float] = None

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §4 shape applicability)."""
        if self.family == "ssm":
            return True
        if self.block_pattern is not None:   # hybrid: local attn + recurrent
            return True
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        dh, h, hk = self.dh, self.n_heads, self.n_kv_heads
        attn = d * (h * dh) + 2 * d * (hk * dh) + (h * dh) * d

        def ffn_params():
            return 3 * d * f  # SwiGLU (gate, up, down)

        per_layer = 0
        n_dec = self.n_layers
        if self.block_pattern:
            pat = self.block_pattern
            reps = -(-self.n_layers // len(pat))
            kinds = (pat * reps)[: self.n_layers]
            total = 0
            lw = self.lru_width or d
            for kind in kinds:
                if kind == "attn":
                    total += attn + ffn_params() + 2 * d
                else:  # recurrent block
                    rec = 2 * d * lw + lw * self.conv_width + 2 * lw + lw * d
                    total += rec + ffn_params() + 2 * d
            body = total
        elif self.family == "ssm":  # rwkv6
            per_layer = 4 * d * d + d * d  # r,k,v,g,o projections (square)
            per_layer += 2 * d * self.d_ff  # channel-mix (k, v)
            body = self.n_layers * per_layer
        elif self.is_moe:
            per_layer = attn + self.n_experts * ffn_params() + d * self.n_experts + 2 * d
            body = self.n_layers * per_layer
        else:
            per_layer = attn + ffn_params() + 2 * d
            body = self.n_layers * per_layer
        if self.is_encdec:
            enc_layer = attn + ffn_params() + 2 * d
            cross = attn
            body = (self.encoder_layers * enc_layer
                    + self.n_layers * (attn + cross + ffn_params() + 3 * d))
        emb = v * d * (1 if self.tie_embeddings else 2)
        return body + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count() - self.n_layers * (self.n_experts - self.top_k) * 3 * d * f
        return dense_like


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: LMConfig, n_layers: int = 2, d_model: int = 64,
            d_ff: int = 128, vocab: int = 256, lru_width: Optional[int] = None
            ) -> LMConfig:
    """Reduced same-family config for CPU smoke tests."""
    heads = max(2, min(4, cfg.n_heads))
    kv = 1 if cfg.n_kv_heads == 1 else max(1, heads // 2) \
        if cfg.n_kv_heads < cfg.n_heads else heads
    kw = dict(
        name=cfg.name + "-smoke", n_layers=n_layers, d_model=d_model,
        n_heads=heads, n_kv_heads=kv, d_ff=d_ff, vocab_size=vocab,
        head_dim=d_model // heads,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        max_seq_len=512,
    )
    if cfg.is_moe:
        kw.update(n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2))
    if cfg.block_pattern:
        kw.update(block_pattern=cfg.block_pattern,
                  lru_width=lru_width or d_model, conv_width=cfg.conv_width)
    if cfg.is_encdec:
        kw.update(encoder_layers=max(1, n_layers // 2), enc_seq_stub=32)
    if cfg.mrope_sections:
        s = (d_model // heads) // 2
        a = s // 3
        kw.update(mrope_sections=(s - 2 * a, a, a))
    return dataclasses.replace(cfg, **kw)
