"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 recurrent:attn
pattern.  [arXiv:2402.19427; unverified]

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, local window 2048.
38 = 12 x (rec, rec, attn) + 2 trailing recurrent blocks.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    sliding_window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    conv_width=4,
    rope_theta=10000.0,
    max_seq_len=524288,   # unbounded in principle (constant-state recurrence)
    tie_embeddings=True,
)
