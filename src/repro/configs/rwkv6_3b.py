"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]

32L d_model=2560 d_ff=8960 vocab=65536; head_size 64 -> 40 WKV heads.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # WKV heads (head_size 64)
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    head_dim=64,
    max_seq_len=1 << 20,  # constant-state recurrence
)
