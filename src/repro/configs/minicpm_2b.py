"""minicpm-2b [dense] — llama-like arch trained with the WSD schedule
(the schedule is implemented in repro.optim).  [arXiv:2404.06395; hf]

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    max_seq_len=4096,
    tie_embeddings=True,
)
