"""Architecture registry: --arch <id> -> LMConfig."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import LMConfig, SHAPES
from repro.configs.recurrentgemma_9b import CONFIG as _recurrentgemma_9b
from repro.configs.mistral_nemo_12b import CONFIG as _mistral_nemo_12b
from repro.configs.minicpm_2b import CONFIG as _minicpm_2b
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama_1_1b
from repro.configs.qwen1_5_4b import CONFIG as _qwen1_5_4b
from repro.configs.rwkv6_3b import CONFIG as _rwkv6_3b
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl_2b
from repro.configs.mixtral_8x7b import CONFIG as _mixtral_8x7b
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe_1b_7b
from repro.configs.seamless_m4t_medium import CONFIG as _seamless_m4t_medium

ARCHS: Dict[str, LMConfig] = {
    c.name: c for c in [
        _recurrentgemma_9b, _mistral_nemo_12b, _minicpm_2b, _tinyllama_1_1b,
        _qwen1_5_4b, _rwkv6_3b, _qwen2_vl_2b, _mixtral_8x7b, _olmoe_1b_7b,
        _seamless_m4t_medium,
    ]
}


def get(name: str) -> LMConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells():
    """All (arch, shape) dry-run cells with applicability filtering
    (DESIGN.md §4): long_500k only for sub-quadratic archs."""
    out = []
    for arch, cfg in ARCHS.items():
        for sname, shp in SHAPES.items():
            if sname == "long_500k" and not cfg.sub_quadratic:
                out.append((arch, sname, "skip: full quadratic attention"))
            else:
                out.append((arch, sname, None))
    return out
