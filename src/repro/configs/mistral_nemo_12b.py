"""mistral-nemo-12b [dense] — 128k-context GQA transformer.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    max_seq_len=131072,
)
