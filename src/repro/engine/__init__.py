"""Unified BFP GEMM execution engine.

One execution layer for the paper's datapath (block-format -> fixed-point
MAC -> power-of-two rescale, Fig. 2) behind every model GEMM:

  * backend registry: float / emulated / pallas (``backends``),
  * per-layer policies: :class:`PolicyMap` resolved on layer paths
    (``policy_map``) — the paper's Table-3 layer-wise sweeps as config,
  * bound execution plans: :func:`bind` resolves policies, selects
    backends, and pre-quantizes weights ONCE; the returned :class:`Plan`
    rides the ``policy`` argument of every model (``plan``),
  * taps: ``with engine.taps(capture):`` observers on the real datapath
    — every GEMM/conv site reports (site, x, w, y[, y_float]), which is
    how the paper's Table-4 analysis generalizes to any topology
    (``taps``),
  * first-class pre-quantized weights on all paths (``prequantize`` /
    ``prequantize_cnn`` + the ``{"m", "s"}`` wire format).

``repro.core.bfp_dot.bfp_dot`` remains as a thin compatibility shim over
:func:`gemm`.
"""
from repro.core.prequant import (act_block, dequantize_act, is_prequant,
                                 prequant_act)
from repro.engine.backends import (BackendFallbackWarning,
                                   BackendUnsupportedError,
                                   available_backends, get_backend,
                                   register_backend, select_backend)
from repro.engine.core import (conv2d, conv2d_im2col, gemm, prequantize,
                               prequantize_cnn)
from repro.engine.plan import Plan, Site, bind, unpack_packed
from repro.engine.policy_map import (PolicyLike, PolicyMap, join_path,
                                     resolve_policy)
from repro.engine.taps import TapEvent, taps

__all__ = [
    "gemm", "conv2d", "conv2d_im2col", "prequantize", "prequantize_cnn",
    "is_prequant", "prequant_act", "dequantize_act", "act_block",
    "bind", "Plan", "Site", "unpack_packed",
    "taps", "TapEvent",
    "PolicyMap", "PolicyLike", "resolve_policy", "join_path",
    "register_backend", "get_backend", "available_backends",
    "select_backend", "BackendFallbackWarning", "BackendUnsupportedError",
]
