"""The unified BFP GEMM execution layer (DESIGN.md §7).

Every model GEMM in the repo — LM linears, MoE expert GEMMs, the tied
lm_head, dense layers — lands on :func:`gemm`; CNN convolutions land on
the conv-aware :func:`conv2d`, which dispatches to a backend's fused
conv (pallas: implicit im2col, no patch matrix in HBM) or falls back to
materialized im2col + :func:`gemm`:

    gemm(x, w, policy, path="fc6")
    conv2d(x, w_hwio, policy, stride=2, padding="SAME", path="stem")

* ``w`` is a float matrix OR the prequant ``{"m", "s"}`` wire format
  (int8 mantissas + power-of-two scale sidecar); pre-quantized weights
  are first-class on every backend, so inference quantizes weights ONCE
  (see ``prequantize`` / ``prequantize_cnn`` and benchmarks/engine_bench).
* ``policy`` is None (float), a BFPPolicy (uniform), a PolicyMap
  (per-layer rules resolved against ``path`` — the paper's Table-3
  layer-wise assignments as config), or a bound ``Plan``
  (``engine.bind``): per-site policy resolution AND backend selection
  done once up front, per-call dispatch is a dict hit.
* the backend registry (float / emulated / pallas) picks the execution,
  folding in the legacy ``use_kernel`` flag and the CPU-interpret
  dispatch that used to be scattered across call sites.

:func:`gemm` / :func:`conv2d` are thin shims: with a Plan they delegate
to the bound site entry; otherwise they resolve per call (an implicit
one-site plan), so every existing call site keeps working.  Both emit
``engine.taps`` events from the real datapath (repro.engine.taps).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bfp import Rounding, Scheme
from repro.core.conv_utils import conv_weight_matrix, im2col
from repro.core.prequant import (act_block, dequantize_act, is_prequant,
                                 prequant_act, quantize_cnn_param_tree,
                                 quantize_param_tree)
from repro.engine import backends as BK
from repro.engine import taps as TAPS
from repro.engine.policy_map import PolicyLike, resolve_policy

__all__ = ["gemm", "conv2d", "conv2d_im2col", "prequantize",
           "prequantize_cnn"]


# ---------------------------------------------------------------------------
# Activation wire format plumbing (ISSUE 6 fused requantize epilogue).
#
# ``out_policy`` asks an execution to emit the CONSUMING layer's
# quantized-input wire format {"m": int8 [.., N], "s": f32 [.., N//bk]}
# instead of dense float — on a backend advertising ``out_quant`` the
# requantization fuses into the kernel epilogue (the f32 activation
# never touches HBM); anywhere else the engine requantizes the float
# output in a second step, bit-identically (core.prequant.prequant_act
# is the pinned reference).  Symmetrically, an execution whose ``x`` is
# already that wire format feeds it straight to an ``act_prequant``
# backend, and is dequantized first (bit-identical by quantization
# idempotence) for every other route.
# ---------------------------------------------------------------------------

def _check_out_policy(out_policy) -> None:
    """Epilogue requantization is defined for exactly the activation wire
    format: TILED blocks along the last axis, round-to-nearest, int8
    mantissas.  (block_k | N and l_i <= 8 are checked where the sizes
    are known: ops epilogue config / prequant_act.)"""
    if out_policy.scheme is not Scheme.TILED or not out_policy.block_k:
        raise ValueError(
            "out_policy must be Scheme.TILED with an explicit block_k "
            f"(activation wire format); got scheme={out_policy.scheme}, "
            f"block_k={out_policy.block_k}")
    if out_policy.rounding is not Rounding.ROUND:
        raise ValueError("out_policy requantization is round-to-nearest "
                         f"only; got {out_policy.rounding}")


def _act_ok_gemm(be: BK.Backend, pol, w, x2d) -> bool:
    """Can ``be`` consume this activation-prequant dict natively?"""
    if not be.act_prequant or pol is None:
        return False
    if x2d["m"].dtype != jnp.int8:
        return False
    bk = act_block(x2d)
    if pol.block_k not in (None, bk):
        return False
    if is_prequant(w):  # weight sidecar block must match the act block
        if w["m"].shape[-2] // w["s"].shape[-2] != bk:
            return False
    return True


def _reshape_out(out, lead, n):
    """Restore leading dims on a dense or wire-format output."""
    if is_prequant(out):
        bq = out["m"].shape[-1] // out["s"].shape[-1]
        return {"m": out["m"].reshape(*lead, n),
                "s": out["s"].reshape(*lead, n // bq)}
    return out.reshape(*lead, n)


def _tap_view(y):
    """Dense float view of an execution output for tap observers (taps
    compare against float references; the wire-format dict is
    dequantized for observation only — the model still sees the dict)."""
    return dequantize_act(y) if is_prequant(y) else y


# ---------------------------------------------------------------------------
# Execution primitives (shared by the per-call shims and bound Plans).
# PolicyMap resolution and tap emission never happen here; backend
# selection (registry + support checks, the per-call path) runs only
# when no pre-selected ``backend`` is passed — bound Plans pass theirs.
# ---------------------------------------------------------------------------

def _gemm_exec(x: Any, w: Any, pol, key=None,
               backend: Optional[BK.Backend] = None,
               strict: bool = False, path: Optional[str] = None,
               warned=None, out_policy=None) -> Tuple[Any, BK.Backend]:
    """Flatten leading dims, run the (given or selected) backend matmul.

    ``x`` may be the activation wire format; ``out_policy`` requests it
    on the output (see the module comment above)."""
    n = (w["m"] if is_prequant(w) else w).shape[-1]
    if out_policy is not None:
        _check_out_policy(out_policy)
    x_pq = is_prequant(x)
    xm = x["m"] if x_pq else x
    lead = xm.shape[:-1]
    if x_pq:
        x2d = {"m": x["m"].reshape(-1, xm.shape[-1]),
               "s": x["s"].reshape(-1, x["s"].shape[-1])}
    else:
        x2d = x.reshape(-1, xm.shape[-1])
    be = backend
    if be is None:
        be = (BK.get_backend("float") if pol is None
              else BK.select_backend(pol, w, strict=strict, path=path,
                                     warned=warned))
    if x_pq and not _act_ok_gemm(be, pol, w, x2d):
        x2d = dequantize_act(x2d)
    if out_policy is not None and be.out_quant and pol is not None:
        out = be.matmul(x2d, w, pol, key, out_policy=out_policy)
    else:
        out = be.matmul(x2d, w, pol, key)
        if out_policy is not None:
            out = prequant_act(out, out_policy)
    return _reshape_out(out, lead, n), be


def _act_ok_conv(be: BK.Backend, pol, w, x) -> bool:
    """Conv counterpart of :func:`_act_ok_gemm` — blocks are per
    (pixel, channel-chunk), so the act block must also match a
    weight-prequant sidecar's HWIO-major K block."""
    if not be.act_prequant or pol is None:
        return False
    if x["m"].dtype != jnp.int8:
        return False
    bk = act_block(x)
    if pol.block_k not in (None, bk):
        return False
    if is_prequant(w):
        kh, kw, c, _ = w["m"].shape
        if (kh * kw * c) // w["s"].shape[-2] != bk:
            return False
    return True


def _conv_exec(x: Any, w: Any, pol, stride: int, padding: str,
               key=None, backend: Optional[BK.Backend] = None,
               strict: bool = False, path: Optional[str] = None,
               warned=None, out_policy=None) -> Tuple[Any, BK.Backend]:
    """Fused conv when the backend has one and can honour (policy,
    geometry); honest materialized-im2col + matmul fallback otherwise.

    With ``backend=None`` the conv slot of the REQUESTED backend is
    consulted (policy None consults the registered "float" backend — the
    same extension point :func:`gemm` documents), and the im2col GEMM
    re-selects with support checks, exactly the legacy per-call
    semantics.  A bound Plan passes its pre-selected ``backend``.
    """
    if out_policy is not None:
        _check_out_policy(out_policy)
    be = backend
    if be is None:
        be = BK.get_backend("float" if pol is None else pol.backend_name)
    fused = be.conv is not None and be.conv_supports(pol, w, stride, padding)
    if is_prequant(x) and not (fused and _act_ok_conv(be, pol, w, x)):
        x = dequantize_act(x)
    if fused:
        if out_policy is not None and be.out_quant and pol is not None:
            return be.conv(x, w, pol, stride, padding, key,
                           out_policy=out_policy), be
        out = be.conv(x, w, pol, stride, padding, key)
        if out_policy is not None:
            out = prequant_act(out, out_policy)
        return out, be
    # backend given (Plan): reuse its matmul for the im2col GEMM;
    # otherwise select per call (pallas-with-paper-scheme lands emulated).
    return _conv_im2col_exec(x, w, pol, stride, padding, key,
                             backend=backend, strict=strict, path=path,
                             warned=warned, out_policy=out_policy)


def _conv_im2col_exec(x, w, pol, stride, padding, key=None, backend=None,
                      strict=False, path=None, warned=None,
                      out_policy=None) -> Tuple[Any, BK.Backend]:
    if is_prequant(x):  # im2col gathers float patches
        x = dequantize_act(x)
    prequant = is_prequant(w)
    kh, kw, c, oc = (w["m"] if prequant else w).shape
    cols, (b, oh, ow) = im2col(x, kh, kw, stride, padding)
    wmat = ({"m": conv_weight_matrix(w["m"]), "s": w["s"]} if prequant
            else conv_weight_matrix(w))
    out, be = _gemm_exec(cols, wmat, pol, key, backend=backend,
                         strict=strict, path=path, warned=warned,
                         out_policy=out_policy)
    if is_prequant(out):
        bq = out["m"].shape[-1] // out["s"].shape[-1]
        return {"m": out["m"].reshape(b, oh, ow, oc),
                "s": out["s"].reshape(b, oh, ow, oc // bq)}, be
    return out.reshape(b, oh, ow, oc), be


# ---------------------------------------------------------------------------
# Execute-then-tap (one implementation shared by the per-call shims and
# the bound Plan entries, so tap events cannot diverge between the two)
# ---------------------------------------------------------------------------

def _adopt_transform(out, view, new, out_policy):
    """Fold a transforming tap's replacement back into the datapath.

    Taps observe the dense float view; when the execution produced the
    activation wire format, the replacement is re-quantized under the
    same ``out_policy`` — i.e. the fault lands on the f32 accumulator
    BEFORE the epilogue requantization, which is where an SEU in an
    accumulator register would physically sit."""
    if new is view:
        return out
    if is_prequant(out):
        return prequant_act(new, out_policy)
    return new


def gemm_and_tap(x, w, pol, key=None, backend=None, strict=False,
                 path=None, warned=None, out_policy=None) -> Any:
    out, be = _gemm_exec(x, w, pol, key, backend=backend, strict=strict,
                         path=path, warned=warned, out_policy=out_policy)
    if TAPS.active():
        # wire-format outputs are dequantized for observation only (taps
        # compare against the float reference); the model sees ``out``
        # unless a transforming tap replaced the observed view
        view = _tap_view(out)
        new = TAPS.emit("gemm", path, pol, be.name, x, w, view,
                        float_fn=lambda: _gemm_exec(x, w, None, None)[0])
        out = _adopt_transform(out, view, new, out_policy)
    return out


def conv_and_tap(x, w, pol, stride, padding, key=None, backend=None,
                 strict=False, path=None, warned=None,
                 out_policy=None) -> Any:
    out, be = _conv_exec(x, w, pol, stride, padding, key, backend=backend,
                         strict=strict, path=path, warned=warned,
                         out_policy=out_policy)
    if TAPS.active():
        view = _tap_view(out)
        new = TAPS.emit("conv", path, pol, be.name, x, w, view,
                        float_fn=lambda: _conv_im2col_exec(
                            x, w, None, stride, padding)[0],
                        stride=stride, padding=padding)
        out = _adopt_transform(out, view, new, out_policy)
    return out


# ---------------------------------------------------------------------------
# Public shims
# ---------------------------------------------------------------------------

#: lazily-cached Plan class — resolves the core<->plan import cycle once
#: instead of paying a sys.modules lookup on every per-call dispatch
_PLAN_CLS = None


def _plan_cls():
    global _PLAN_CLS
    if _PLAN_CLS is None:
        from repro.engine.plan import Plan
        _PLAN_CLS = Plan
    return _PLAN_CLS


#: lazily-cached grad subsystem (same cycle-breaking pattern):
#: repro.grad.vjp builds the custom VJPs ON TOP of gemm_and_tap /
#: conv_and_tap, so it must import this module, not the reverse
_GRAD_VJP = None


def _grad_vjp():
    global _GRAD_VJP
    if _GRAD_VJP is None:
        from repro.grad import vjp
        _GRAD_VJP = vjp
    return _GRAD_VJP


def gemm(x: Any, w: Any, policy: PolicyLike = None, *,
         path: Optional[str] = None,
         key: Optional[jax.Array] = None,
         out_policy: Optional[Any] = None) -> Any:
    """``x[..., K] @ w[K, N]`` through the policy-selected BFP backend.

    ``w``: float [K, N] or prequant ``{"m": [K, N], "s": [K//bk, N]}``.
    Leading dims of ``x`` are flattened for the 2-D backends and restored.
    ``policy`` may be a bound ``engine.Plan`` — the site entry for
    ``path`` then supplies the resolved policy AND backend with no
    per-call registry/regex work.

    ``x`` may also be the activation wire format ``{"m": int8 [.., K],
    "s": [.., K//bk]}`` (a previous layer's ``out_policy`` output);
    ``out_policy=`` (the CONSUMING layer's policy) returns that format
    instead of dense float — fused into the kernel epilogue on backends
    that support it, a bit-identical second requantization step
    elsewhere.
    """
    if isinstance(policy, _plan_cls()):
        return policy.gemm(x, w, path=path, key=key, out_policy=out_policy)
    gv = _grad_vjp()
    if gv.routable(x, w, key, out_policy) and w.ndim == 2:
        # dense float operands: the custom-VJP route — identical forward
        # (it calls gemm_and_tap), backward GEMMs through the backend
        # registry under the grad-path policies (repro.grad, §12)
        return gv.gemm(x, w, policy, path)
    # policy None goes through the registered "float" backend, so
    # re-registering it (instrumented or accelerated variants) also
    # covers policy-None GEMMs
    return gemm_and_tap(x, w, resolve_policy(policy, path), key, path=path,
                        out_policy=out_policy)


def conv2d(x: Any, w: Any, policy: PolicyLike = None, *,
           stride: int = 1, padding: str = "SAME",
           path: Optional[str] = None,
           key: Optional[jax.Array] = None,
           out_policy: Optional[Any] = None) -> Any:
    """NHWC convolution through the policy-selected BFP backend.

    ``x``: [B, H, W, C] float; ``w``: HWIO [kh, kw, C, OC] float or the
    prequant ``{"m": int8 HWIO, "s": [K//bk, OC]}`` wire format.  A
    backend with a faithful fused conv (pallas: the implicit-im2col
    kernel, no materialized patch matrix in HBM) takes it; everything
    else — float, emulated, pallas with a scheme the kernel can't honour
    — falls back honestly to the materialized im2col + :func:`gemm`
    route, which preserves exact GEMM-engine semantics per backend.
    ``policy=None`` consults the registered "float" backend's conv slot
    (same extension point as GEMMs) before taking the im2col route.

    ``x`` may be the NHWC activation wire format (blocks per
    (pixel, channel-chunk)); ``out_policy=`` returns it — see
    :func:`gemm`.  Chained convs on the pallas backend hand ``{"m","s"}``
    activations layer to layer with no dequantized f32 tensor in HBM.
    """
    if isinstance(policy, _plan_cls()):
        return policy.conv2d(x, w, path=path, stride=stride,
                             padding=padding, key=key,
                             out_policy=out_policy)
    gv = _grad_vjp()
    if gv.routable(x, w, key, out_policy) and w.ndim == 4 \
            and padding in ("SAME", "VALID"):
        return gv.conv2d(x, w, policy, stride, padding, path)
    return conv_and_tap(x, w, resolve_policy(policy, path), stride,
                        padding, key, path=path, out_policy=out_policy)


def conv2d_im2col(x: Any, w: Any, pol, stride: int = 1,
                  padding: str = "SAME", key=None,
                  out_policy=None) -> Any:
    """The materialized-im2col route: paper Fig. 1's matrix form, lowered
    through the GEMM engine (so backend selection, prequant handling, and
    fallbacks behave exactly as for any other GEMM).  :func:`conv2d`'s
    fallback; public so A/B comparisons (benchmarks/conv_bench.py) can
    force this route against the fused kernel.  ``pol`` is an
    already-resolved BFPPolicy or None, not a PolicyMap.  Does not emit
    tap events (the :func:`conv2d` entry does, once per conv site)."""
    return _conv_im2col_exec(x, w, pol, stride, padding, key,
                             out_policy=out_policy)[0]


def prequantize(params: Any, policy: PolicyLike) -> Any:
    """Quantize an LM param tree's GEMM weights once (wire format).

    Per-layer maps work: a PolicyMap rule resolving to None keeps that
    leaf float.  The returned tree feeds the same model code — every
    backend consumes the wire format directly.
    """
    return quantize_param_tree(params, policy)


def prequantize_cnn(params: Any, policy: PolicyLike) -> Any:
    """CNN counterpart of :func:`prequantize` (HWIO convs + dense)."""
    return quantize_cnn_param_tree(params, policy)
