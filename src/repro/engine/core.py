"""The unified BFP GEMM execution layer (DESIGN.md §7).

Every model GEMM in the repo — LM linears, MoE expert GEMMs, the tied
lm_head, dense layers — lands on :func:`gemm`; CNN convolutions land on
the conv-aware :func:`conv2d`, which dispatches to a backend's fused
conv (pallas: implicit im2col, no patch matrix in HBM) or falls back to
materialized im2col + :func:`gemm`:

    gemm(x, w, policy, path="fc6")
    conv2d(x, w_hwio, policy, stride=2, padding="SAME", path="stem")

* ``w`` is a float matrix OR the prequant ``{"m", "s"}`` wire format
  (int8 mantissas + power-of-two scale sidecar); pre-quantized weights
  are first-class on every backend, so inference quantizes weights ONCE
  (see ``prequantize`` / ``prequantize_cnn`` and benchmarks/engine_bench).
* ``policy`` is None (float), a BFPPolicy (uniform), or a PolicyMap
  (per-layer rules resolved against ``path`` — the paper's Table-3
  layer-wise assignments as config).
* the backend registry (float / emulated / pallas) picks the execution,
  folding in the legacy ``use_kernel`` flag and the CPU-interpret
  dispatch that used to be scattered across call sites.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.core.conv_utils import conv_weight_matrix, im2col
from repro.core.prequant import (is_prequant, quantize_cnn_param_tree,
                                 quantize_param_tree)
from repro.engine import backends as BK
from repro.engine.policy_map import PolicyLike, resolve_policy

__all__ = ["gemm", "conv2d", "conv2d_im2col", "prequantize",
           "prequantize_cnn"]


def gemm(x: jax.Array, w: Any, policy: PolicyLike = None, *,
         path: Optional[str] = None,
         key: Optional[jax.Array] = None) -> jax.Array:
    """``x[..., K] @ w[K, N]`` through the policy-selected BFP backend.

    ``w``: float [K, N] or prequant ``{"m": [K, N], "s": [K//bk, N]}``.
    Leading dims of ``x`` are flattened for the 2-D backends and restored.
    """
    pol = resolve_policy(policy, path)
    n = (w["m"] if is_prequant(w) else w).shape[-1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    if pol is None:
        # registered "float" backend, so re-registering it (instrumented
        # or accelerated variants) also covers policy-None GEMMs
        out = BK.get_backend("float").matmul(x2d, w, None, key)
    else:
        out = BK.select_backend(pol, w).matmul(x2d, w, pol, key)
    return out.reshape(*lead, n)


def conv2d(x: jax.Array, w: Any, policy: PolicyLike = None, *,
           stride: int = 1, padding: str = "SAME",
           path: Optional[str] = None,
           key: Optional[jax.Array] = None) -> jax.Array:
    """NHWC convolution through the policy-selected BFP backend.

    ``x``: [B, H, W, C] float; ``w``: HWIO [kh, kw, C, OC] float or the
    prequant ``{"m": int8 HWIO, "s": [K//bk, OC]}`` wire format.  A
    backend with a faithful fused conv (pallas: the implicit-im2col
    kernel, no materialized patch matrix in HBM) takes it; everything
    else — float, emulated, pallas with a scheme the kernel can't honour
    — falls back honestly to the materialized im2col + :func:`gemm`
    route, which preserves exact GEMM-engine semantics per backend.
    """
    pol = resolve_policy(policy, path)
    if pol is not None:
        be = BK.get_backend(pol.backend_name)
        if be.conv is not None and be.conv_supports(pol, w, stride,
                                                    padding):
            return be.conv(x, w, pol, stride, padding, key)
    return conv2d_im2col(x, w, pol, stride, padding, key)


def conv2d_im2col(x: jax.Array, w: Any, pol, stride: int = 1,
                  padding: str = "SAME", key=None) -> jax.Array:
    """The materialized-im2col route: paper Fig. 1's matrix form, lowered
    through the GEMM engine (so backend selection, prequant handling, and
    fallbacks behave exactly as for any other GEMM).  :func:`conv2d`'s
    fallback; public so A/B comparisons (benchmarks/conv_bench.py) can
    force this route against the fused kernel.  ``pol`` is an
    already-resolved BFPPolicy or None, not a PolicyMap."""
    prequant = is_prequant(w)
    kh, kw, c, oc = (w["m"] if prequant else w).shape
    cols, (b, oh, ow) = im2col(x, kh, kw, stride, padding)
    wmat = ({"m": conv_weight_matrix(w["m"]), "s": w["s"]} if prequant
            else conv_weight_matrix(w))
    out = gemm(cols, wmat, pol, key=key)
    return out.reshape(b, oh, ow, oc)


def prequantize(params: Any, policy: PolicyLike) -> Any:
    """Quantize an LM param tree's GEMM weights once (wire format).

    Per-layer maps work: a PolicyMap rule resolving to None keeps that
    leaf float.  The returned tree feeds the same model code — every
    backend consumes the wire format directly.
    """
    return quantize_param_tree(params, policy)


def prequantize_cnn(params: Any, policy: PolicyLike) -> Any:
    """CNN counterpart of :func:`prequantize` (HWIO convs + dense)."""
    return quantize_cnn_param_tree(params, policy)
