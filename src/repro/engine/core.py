"""The unified BFP GEMM execution layer (DESIGN.md §7).

Every model GEMM in the repo — CNN convs via im2col, LM linears, MoE
expert GEMMs, the tied lm_head — lands on :func:`gemm`:

    gemm(x, w, policy, path="blocks/3/c1")

* ``w`` is a float matrix OR the prequant ``{"m", "s"}`` wire format
  (int8 mantissas + power-of-two scale sidecar); pre-quantized weights
  are first-class on every backend, so inference quantizes weights ONCE
  (see ``prequantize`` / ``prequantize_cnn`` and benchmarks/engine_bench).
* ``policy`` is None (float), a BFPPolicy (uniform), or a PolicyMap
  (per-layer rules resolved against ``path`` — the paper's Table-3
  layer-wise assignments as config).
* the backend registry (float / emulated / pallas) picks the execution,
  folding in the legacy ``use_kernel`` flag and the CPU-interpret
  dispatch that used to be scattered across call sites.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from repro.core.prequant import (is_prequant, quantize_cnn_param_tree,
                                 quantize_param_tree)
from repro.engine import backends as BK
from repro.engine.policy_map import PolicyLike, resolve_policy

__all__ = ["gemm", "prequantize", "prequantize_cnn"]


def gemm(x: jax.Array, w: Any, policy: PolicyLike = None, *,
         path: Optional[str] = None,
         key: Optional[jax.Array] = None) -> jax.Array:
    """``x[..., K] @ w[K, N]`` through the policy-selected BFP backend.

    ``w``: float [K, N] or prequant ``{"m": [K, N], "s": [K//bk, N]}``.
    Leading dims of ``x`` are flattened for the 2-D backends and restored.
    """
    pol = resolve_policy(policy, path)
    n = (w["m"] if is_prequant(w) else w).shape[-1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    if pol is None:
        # registered "float" backend, so re-registering it (instrumented
        # or accelerated variants) also covers policy-None GEMMs
        out = BK.get_backend("float").matmul(x2d, w, None, key)
    else:
        out = BK.select_backend(pol, w).matmul(x2d, w, pol, key)
    return out.reshape(*lead, n)


def prequantize(params: Any, policy: PolicyLike) -> Any:
    """Quantize an LM param tree's GEMM weights once (wire format).

    Per-layer maps work: a PolicyMap rule resolving to None keeps that
    leaf float.  The returned tree feeds the same model code — every
    backend consumes the wire format directly.
    """
    return quantize_param_tree(params, policy)


def prequantize_cnn(params: Any, policy: PolicyLike) -> Any:
    """CNN counterpart of :func:`prequantize` (HWIO convs + dense)."""
    return quantize_cnn_param_tree(params, policy)
