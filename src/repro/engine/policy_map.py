"""Per-layer BFP policy resolution — paper Table 3 as configuration.

The paper's layer-wise sweep (first/last layers in float, conv layers at
one word width, FC layers at another) becomes a :class:`PolicyMap`: an
ordered list of (regex, policy) rules matched against a LAYER PATH
("conv1_1", "blocks/3/c1", "attn/wq", "fc", ...).  First match wins; a
rule whose policy is ``None`` pins that layer to float; unmatched paths
fall through to ``default``.

Every GEMM-bearing layer accepts ``policy`` as either a plain
:class:`BFPPolicy` (uniform), a :class:`PolicyMap` (per-layer), or
``None`` (float) — ``resolve_policy`` collapses all three.  PolicyMap is
frozen/hashable, so it is safe to close over in jitted functions exactly
like BFPPolicy.
"""
from __future__ import annotations

import dataclasses
import re
from functools import lru_cache
from typing import Any, Dict, Optional, Tuple, Union

from repro.core.bfp import Rounding, Scheme
from repro.core.policy import BFPPolicy

__all__ = ["PolicyMap", "PolicyLike", "resolve_policy", "join_path"]


@lru_cache(maxsize=1024)
def _compiled(pattern: str) -> "re.Pattern[str]":
    return re.compile(pattern)


@dataclasses.dataclass(frozen=True)
class PolicyMap:
    """Ordered (pattern, policy) rules; first ``re.search`` match wins.

    Example — the paper's Table-3 mixed assignment on a CNN ("first conv
    and classifier in float, every other conv at L=8, FC at L=6"):

        PolicyMap.of(
            ("^conv1_1$", None),
            ("^fc8$", None),
            (r"^fc", BFPPolicy(l_w=6, l_i=6)),
            default=BFPPolicy(l_w=8, l_i=8),
        )
    """

    rules: Tuple[Tuple[str, Optional[BFPPolicy]], ...] = ()
    default: Optional[BFPPolicy] = None

    @classmethod
    def of(cls, *pairs: Tuple[str, Optional[BFPPolicy]],
           default: Optional[BFPPolicy] = None) -> "PolicyMap":
        return cls(rules=tuple((str(p), pol) for p, pol in pairs),
                   default=default)

    def resolve(self, path: Optional[str]) -> Optional[BFPPolicy]:
        """Policy for ``path`` (None path -> default)."""
        if path is not None:
            for pattern, pol in self.rules:
                if _compiled(pattern).search(path):
                    return pol
        return self.default

    def with_default(self, default: Optional[BFPPolicy]) -> "PolicyMap":
        return dataclasses.replace(self, default=default)

    # -- config (de)serialization -------------------------------------------

    @classmethod
    def from_dict(cls, cfg: Dict[str, Any]) -> "PolicyMap":
        """Build from plain data, e.g. loaded from JSON:

            {"rules": [{"pattern": "^stem", "policy": null},
                       {"pattern": "fc", "policy": {"l_w": 6, "l_i": 6}}],
             "default": {"l_w": 8, "l_i": 8, "scheme": "tiled",
                         "block_k": 128}}
        """
        def mk(d):
            if d is None:
                return None
            kw = dict(d)
            if "scheme" in kw:
                kw["scheme"] = Scheme(kw["scheme"])
            if "rounding" in kw:
                kw["rounding"] = Rounding(kw["rounding"])
            return BFPPolicy(**kw)

        rules = tuple((r["pattern"], mk(r.get("policy")))
                      for r in cfg.get("rules", ()))
        return cls(rules=rules, default=mk(cfg.get("default")))

    def to_dict(self) -> Dict[str, Any]:
        """Plain-data form, JSON-serializable; the exact inverse of
        :meth:`from_dict` (``PolicyMap.from_dict(pm.to_dict()) == pm``).
        This is how ``repro.tune.precision`` persists a searched map."""
        def dd(p: Optional[BFPPolicy]) -> Optional[Dict[str, Any]]:
            if p is None:
                return None
            d = dataclasses.asdict(p)
            d["scheme"] = p.scheme.value
            d["rounding"] = p.rounding.value
            return d

        return {"rules": [{"pattern": pat, "policy": dd(pol)}
                          for pat, pol in self.rules],
                "default": dd(self.default)}


#: What every GEMM-bearing layer accepts as ``policy``: None (float), a
#: BFPPolicy (uniform), a PolicyMap (per-layer rules), or a bound
#: ``repro.engine.Plan`` (resolution + backend selection done once at
#: ``engine.bind`` time; forward-referenced to avoid an import cycle).
PolicyLike = Union[None, BFPPolicy, PolicyMap, "repro.engine.plan.Plan"]


def resolve_policy(policy: PolicyLike,
                   path: Optional[str] = None) -> Optional[BFPPolicy]:
    """Collapse a PolicyLike to a concrete per-GEMM policy (or None).

    PolicyMap and Plan both implement the ``.resolve(path)`` protocol —
    a Plan answers from its bound site table (falling back to its
    original policy for unseen paths)."""
    if policy is None or isinstance(policy, BFPPolicy):
        return policy
    return policy.resolve(path)


def join_path(*parts: Optional[str]) -> Optional[str]:
    """'/'-join non-empty path components; None if all empty."""
    ps = [p for p in parts if p]
    return "/".join(ps) if ps else None
