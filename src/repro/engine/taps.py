"""Engine tap API — observers on the real BFP datapath (DESIGN.md §7.2).

A *tap* sees every GEMM / conv the engine executes, with the site
identity the plan/policy machinery already carries:

    def capture(ev):                      # ev: TapEvent
        print(ev.path, ev.kind, ev.backend)

    with engine.taps(capture):
        logits = vgg.apply(params, x, policy)

Events fire from the public entry points — ``engine.gemm``,
``engine.conv2d``, and the bound ``Plan`` equivalents — AFTER the
backend has produced the datapath output, so ``ev.y`` is exactly what
the model sees (pre-bias; biases/norms live in the layers, not the
engine).  ``conv2d_im2col``'s internal GEMM does not double-fire: a conv
site emits ONE conv event regardless of the fused-vs-im2col route.

Overhead contract:
  * no taps registered: one truthiness check per engine call — nothing
    else is built or captured;
  * taps registered: events carry references to the live arrays (no
    copies); ``want_float=True`` additionally runs the float reference
    execution of the same site (one extra matmul/conv per event);
  * under ``jax.jit`` tracing the operands are tracers, not values, so
    events are suppressed — taps observe concrete eager execution only
    (the Table-4 analysis mode).  Run the model un-jitted to measure.

This is what rebuilt the paper's Table-4 analysis as a generic
``models.cnn.analysis.analyze_model`` that works on any topology the
engine executes (VGG, ResNet, GoogLeNet, ...), instead of a hand-rolled
sequential walker.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, List, Optional

import jax

__all__ = ["TapEvent", "taps", "active"]


@dataclasses.dataclass
class TapEvent:
    """One engine execution, as observed by a tap.

    ``x``/``w``/``y`` are the live operands/output (GEMM: ``x`` with
    leading dims, ``w`` float [K, N] or prequant dict; conv: NHWC input,
    HWIO kernel, NHWC output).  ``y_float`` is the float-reference
    output of the same site, computed only when a registered tap asked
    for it (``want_float=True``); otherwise None.

    Backward events (``kind`` ending in ``_dx`` / ``_dw``) report the
    backward GEMM as executed: ``x``/``w`` are its 2-D left/right
    operands (already transposed — e.g. ``gemm_dx`` carries the incoming
    gradient and W^T), ``policy`` the FITTED backward policy
    (``repro.grad.fit_grad_policy``), so
    ``core.nsr.gemm_nsr_upper_bound(ev.x, ev.w, ev.policy)`` bounds
    ``ev.y`` directly.  Backward events fire only when the backward pass
    itself runs eagerly (e.g. un-jitted ``jax.grad``), same tracer rule
    as forward events.
    """

    path: Optional[str]     #: layer path ("conv1_1", ...); backward
                            #: events carry the DERIVED grad path
                            #: ("conv1_1#dx" / "conv1_1#dw")
    kind: str               #: forward: "gemm" | "conv"; backward GEMMs
                            #: (repro.grad custom VJPs): "gemm_dx" |
                            #: "gemm_dw" | "conv_dx" | "conv_dw"
    policy: Any             #: resolved BFPPolicy (None = float site)
    backend: str            #: name of the backend that executed
    x: jax.Array
    w: Any
    y: jax.Array
    y_float: Optional[jax.Array] = None
    stride: Optional[int] = None     #: conv only
    padding: Optional[str] = None    #: conv only


@dataclasses.dataclass
class _Tap:
    fn: Callable[[TapEvent], None]
    want_float: bool
    transform: bool = False


_ACTIVE: List[_Tap] = []


def active() -> bool:
    """True when at least one tap is registered (cheap per-call guard)."""
    return bool(_ACTIVE)


@contextlib.contextmanager
def taps(fn: Callable[[TapEvent], None], *, want_float: bool = False,
         transform: bool = False):
    """Register ``fn`` as a datapath observer for the dynamic extent.

    ``want_float=True`` asks the engine to also execute the float
    reference for every observed site and attach it as ``ev.y_float``
    (costs one extra float execution per event — single-run SNR
    monitoring; the dual-run analysis driver leaves it off).

    ``transform=True`` promotes the tap from observer to INTERVENER: a
    non-None return value from ``fn`` REPLACES the site's output on the
    live datapath (the fault-injection hook — ``repro.faults`` perturbs
    activations this way).  Returning None leaves the output untouched,
    so a transforming tap can target a subset of sites.  Like all taps,
    transforms see only concrete eager execution — under jit tracing no
    event fires and the datapath is unchanged, so fault campaigns run
    the model un-jitted.
    """
    t = _Tap(fn, want_float, transform)
    _ACTIVE.append(t)
    try:
        yield t
    finally:
        _ACTIVE.remove(t)


def emit(kind: str, path, policy, backend: str, x, w, y,
         float_fn: Optional[Callable[[], jax.Array]] = None,
         stride=None, padding=None):
    """Deliver one event to every registered tap (engine-internal).

    ``float_fn`` lazily produces the float reference output; it runs at
    most once, and only if some tap requested ``want_float``.  Tracer
    operands (jit tracing) suppress the event entirely.

    Returns the (possibly transformed) output: identical to ``y`` unless
    some ``transform=True`` tap returned a replacement, in which case
    later taps observe the replaced value and the engine call site
    adopts it (``gemm_and_tap`` / ``conv_and_tap``).
    """
    if not _ACTIVE:
        return y
    if isinstance(x, jax.core.Tracer) or isinstance(y, jax.core.Tracer):
        return y  # taps observe concrete values; jit traces pass through
    y_float = None
    if float_fn is not None and any(t.want_float for t in _ACTIVE):
        y_float = float_fn()
    ev = TapEvent(path=path, kind=kind, policy=policy, backend=backend,
                  x=x, w=w, y=y, y_float=y_float, stride=stride,
                  padding=padding)
    out = y
    for t in list(_ACTIVE):
        r = t.fn(ev)
        if t.transform and r is not None:
            out = r
            ev = dataclasses.replace(ev, y=out)
    return out
