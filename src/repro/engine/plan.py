"""Bound execution plans — resolve/select/quantize ONCE, then just run.

``engine.bind(params, policy)`` is the deployment-mode entry point the
paper's accelerator design (and Ristretto / Fixflow-style fixed-point
serving) organizes around: walk the param tree once, resolve each
GEMM/conv site's PolicyMap rule against its layer path, select the
concrete backend execution (or honest emulated fallback) up front,
pre-quantize every eligible weight leaf into the ``{"m", "s"}`` wire
format, and return an immutable :class:`Plan`:

    plan = engine.bind(params, policy)
    logits = vgg.apply(plan.params, x, plan)     # plan rides the policy arg

A :class:`Plan` is a ``PolicyLike``: model code passes it exactly where
it passed a ``BFPPolicy``/``PolicyMap``, and ``engine.gemm`` /
``engine.conv2d`` delegate to the bound per-site entries — per-call
dispatch drops from regex resolution + registry lookup + support checks
to one dict hit.  Results are bit-identical to the per-call path (the
same backend executions run, selected earlier).

What is resolved when:
  * bind time: policy-rule backends exist (unknown names raise the
    ``available_backends`` KeyError HERE, not mid-forward), per-site
    policy resolution, backend support checks against the actual weight
    (downgrades warn once, or raise with ``strict=True``), weight
    pre-quantization;
  * call time: only geometry-dependent conv fusion (stride/padding) and
    the backend execution itself.

Paths the walk cannot see (e.g. the MoE expert runtime path "moe" vs
its per-matrix tree leaves "moe/w1...") fall back to legacy per-call
resolution against the original policy — correct, just not pre-bound;
``strict`` still applies to their backend selection.
"""
from __future__ import annotations

import contextlib
import dataclasses
import types
from typing import Any, Dict, Iterable, Optional, Tuple, Union

import jax

from repro.core.bfp import Rounding, Scheme
from repro.core.packed import is_packed, unpack_prequant
from repro.core.policy import BFPPolicy
from repro.core.prequant import (_path_keys, cnn_rule_path,
                                 detect_tree_kind, is_prequant,
                                 lm_eligible, lm_rule_path,
                                 quantize_cnn_param_tree,
                                 quantize_param_tree)
from repro.engine import backends as BK
from repro.engine.core import _grad_vjp, conv_and_tap, gemm_and_tap
from repro.engine.policy_map import PolicyLike, PolicyMap, resolve_policy
from repro.grad.paths import GradSpec, grad_path, resolve_grad_policy

__all__ = ["Site", "Plan", "bind", "unpack_packed"]


def unpack_packed(params: Any) -> Any:
    """Replace every :class:`~repro.core.packed.PackedBFP` leaf with its
    ``{"m", "s"}`` prequant sidecar — the packed-artifact load path.

    This is how a serving engine consumes a ``format="bfp_packed"`` or
    ``format="bfp_packed_v2"`` checkpoint restored with
    ``packed="keep"``: the ~4x-smaller container unpacks straight into
    the wire format every backend executes, so no float weight is ever
    materialized for a prequant-eligible site.  Fixed- and
    variable-width containers decode through the same call (the
    container self-describes; ``unpack_prequant`` dispatches on its
    width plane), so binding a v3 artifact is exactly binding its fixed
    twin.  Trees without packed leaves pass through untouched (same
    object).
    """
    flat = jax.tree_util.tree_leaves(params, is_leaf=is_packed)
    if not any(is_packed(l) for l in flat):
        return params
    return jax.tree_util.tree_map(
        lambda l: unpack_prequant(l) if is_packed(l) else l,
        params, is_leaf=is_packed)


@dataclasses.dataclass(frozen=True)
class Site:
    """One bound GEMM/conv execution site."""

    path: str
    kind: str                       #: "gemm" | "conv"
    policy: Optional[BFPPolicy]     #: resolved concrete policy (None=float)
    backend: BK.Backend             #: concrete execution, selected at bind
    fallback: bool = False          #: requested backend was downgraded
    prequantized: bool = False      #: weight leaf holds the wire format
    #: backward-GEMM plans (repro.grad, DESIGN.md §12), resolved on the
    #: derived grad paths (``path#dx`` / ``path#dw``) at bind time —
    #: policy AND backend, so strict binds refuse unsupported backward
    #: backends up front.  None (legacy construction) means "resolve per
    #: call against the plan's original policy".
    dx: Optional[GradSpec] = None
    dw: Optional[GradSpec] = None


class Plan:
    """Immutable per-site execution table returned by :func:`bind`.

    ``plan.params`` is the (pre-quantized) tree the model should be
    applied with; the plan itself rides the ``policy`` argument.  Site
    entries are fixed at bind time — re-registering a backend afterwards
    does not change a bound plan (that is the point: serving runs the
    datapath that was admitted).
    """

    def __init__(self, sites: Dict[str, Site], params: Any,
                 policy: PolicyLike, strict: bool = False,
                 tune_cache: Any = None):
        self._sites = dict(sites)
        self.sites = types.MappingProxyType(self._sites)
        self.params = params
        self.policy = policy
        self.strict = strict
        #: TuneCache attached at bind time (``bind(..., tune_cache=)``):
        #: every bound execution runs with it active, so kernels launch
        #: with the autotuned tiles for their (shape, L, target) site
        self.tune_cache = tune_cache
        #: per-plan fallback-warning dedup for unbound-path dispatch, so
        #: one plan's downgrades never mute another's
        self._warned: set = set()
        #: per-plan cache of jitted forwards, keyed by apply function —
        #: every consumer binding the same plan to the same model shares
        #: one traced callable (see :meth:`jit_forward`)
        self._jit_cache: Dict[Any, Any] = {}

    def __repr__(self) -> str:
        n_bfp = sum(1 for s in self._sites.values() if s.policy is not None)
        return (f"Plan({len(self._sites)} sites, {n_bfp} BFP, "
                f"strict={self.strict})")

    def site(self, path: str) -> Site:
        return self._sites[path]

    def resolve(self, path: Optional[str]) -> Optional[BFPPolicy]:
        """Concrete policy for ``path`` (the ``resolve_policy`` protocol,
        so code like the MoE layer that resolves before vmapping works on
        plans too)."""
        s = self._sites.get(path)
        if s is not None:
            return s.policy
        return resolve_policy(self.policy, path)

    # -- bound executions (execute + tap shared with the per-call shims) ----

    def _tuned(self):
        """Context activating this plan's tune cache (no-op when none)."""
        if self.tune_cache is None:
            return contextlib.nullcontext()
        from repro.tune.cache import use_cache
        return use_cache(self.tune_cache)

    def out_policy_for(self, path: Optional[str]) -> Optional[BFPPolicy]:
        """The resolved policy for ``path`` IF its execution would
        quantize its input to the activation wire format — i.e. the
        ``out_policy=`` the PRODUCING layer should pass so the handoff
        skips the dequantized-f32 round-trip.  None when ``path`` is
        float, doesn't quantize inputs, or its input quantization isn't
        the wire format (non-TILED, no block, stochastic, L_I > 8)."""
        pol = self.resolve(path)
        if pol is None or not pol.quantize_inputs:
            return None
        if (pol.scheme is not Scheme.TILED or not pol.block_k
                or pol.rounding is not Rounding.ROUND or pol.l_i > 8):
            return None
        return pol

    def gemm(self, x: Any, w: Any, *, path: Optional[str] = None,
             key: Optional[jax.Array] = None, out_policy=None) -> Any:
        site = self._sites.get(path)
        gv = _grad_vjp()
        with self._tuned():
            if site is not None and site.kind == "gemm":
                if gv.routable(x, w, key, out_policy) and w.ndim == 2:
                    return gv.gemm_bound(x, w, site)
                return gemm_and_tap(x, w, site.policy, key,
                                    backend=site.backend, path=path,
                                    out_policy=out_policy)
            # unbound path: legacy per-call resolution (strict kept)
            if gv.routable(x, w, key, out_policy) and w.ndim == 2:
                return gv.gemm(x, w, self.policy, path, self.strict)
            return gemm_and_tap(x, w, resolve_policy(self.policy, path),
                                key, strict=self.strict, path=path,
                                warned=self._warned, out_policy=out_policy)

    def conv2d(self, x: Any, w: Any, *, path: Optional[str] = None,
               stride: int = 1, padding: str = "SAME",
               key: Optional[jax.Array] = None, out_policy=None) -> Any:
        site = self._sites.get(path)
        gv = _grad_vjp()
        routed = (gv.routable(x, w, key, out_policy) and w.ndim == 4
                  and padding in ("SAME", "VALID"))
        with self._tuned():
            if site is not None and site.kind == "conv":
                if routed:
                    return gv.conv2d_bound(x, w, site, stride, padding)
                return conv_and_tap(x, w, site.policy, stride, padding,
                                    key, backend=site.backend, path=path,
                                    out_policy=out_policy)
            if routed:
                return gv.conv2d(x, w, self.policy, stride, padding,
                                 path, self.strict)
            return conv_and_tap(x, w, resolve_policy(self.policy, path),
                                stride, padding, key, strict=self.strict,
                                path=path, warned=self._warned,
                                out_policy=out_policy)

    def jit_forward(self, apply_fn):
        """A jitted ``apply_fn(plan.params, x, plan)``, cached per
        ``apply_fn`` on this plan.

        This is how a bound plan is REUSED across jit'd callables: N
        serve engines (or batch buckets, or benchmark drivers) bound to
        the same plan get the SAME callable object back, so they share
        one trace-cache — jax retraces per input shape (each batch
        bucket compiles once), never per consumer.  The plan and its
        pre-quantized params ride the closure; extra positional args
        (e.g. a model's ``training`` flag) pass through.
        """
        fn = self._jit_cache.get(apply_fn)
        if fn is None:
            def fwd(x, *args, _apply=apply_fn):
                return _apply(self.params, x, self, *args)
            fn = jax.jit(fwd)
            self._jit_cache[apply_fn] = fn
        return fn

    def describe(self) -> str:
        """Human-readable site table (examples / serving admission logs)."""
        lines = []
        for path in sorted(self._sites):
            s = self._sites[path]
            pol = ("float" if s.policy is None else
                   f"L_W={s.policy.l_w},L_I={s.policy.l_i},"
                   f"{s.policy.scheme.value}")
            extra = (" (fallback)" if s.fallback else "") + \
                    (" [prequant]" if s.prequantized else "")

            def gdesc(spec):
                if spec is None or spec.policy is None:
                    return "float"
                gp = spec.policy
                be = spec.backend.name if spec.backend is not None else "?"
                return f"L{gp.l_w}/{gp.l_i}@{be}"

            grad = f" grad[dx={gdesc(s.dx)},dw={gdesc(s.dw)}]"
            lines.append(f"{path:<24} {s.kind:<5} {pol:<24} "
                         f"-> {s.backend.name}{extra}{grad}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# bind
# ---------------------------------------------------------------------------

def _validate_policy_backends(policy: PolicyLike) -> None:
    """Every backend a policy (or any PolicyMap rule) names must exist —
    raise the available_backends KeyError at BIND time, not mid-forward."""
    pols = []
    if isinstance(policy, PolicyMap):
        pols = [p for _, p in policy.rules] + [policy.default]
    elif isinstance(policy, BFPPolicy):
        pols = [policy]
    for p in pols:
        if p is not None:
            BK.get_backend(p.backend_name)


class _ScopedPolicy:
    """``resolve_policy`` adapter limiting a policy to an explicit site
    set — leaves outside ``wanted`` resolve to None (stay float)."""

    def __init__(self, policy: PolicyLike, wanted):
        self._policy, self._wanted = policy, wanted

    def resolve(self, path):
        if path not in self._wanted:
            return None
        return resolve_policy(self._policy, path)


#: shared with core.packed.pack_param_tree — one detector, one walk
_detect_tree = detect_tree_kind


def _discover_sites(params: Any, tree: str):
    """Yield (runtime_path, kind, weight_leaf) for every GEMM/conv site
    the param walk can see — the same path derivation the prequant
    walkers use, so rules pin and plans bind exactly the layers the
    model apply functions execute."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_prequant)
    for path, leaf in leaves:
        keys = _path_keys(path)
        arr = leaf["m"] if is_prequant(leaf) else leaf
        if not hasattr(arr, "ndim"):
            continue
        if tree == "lm":
            if not lm_eligible(keys) or arr.ndim < 2:
                continue
            yield lm_rule_path(keys), "gemm", leaf
        else:
            rpath = cnn_rule_path(params, keys)
            if rpath is None:
                continue
            if arr.ndim == 4:
                yield rpath, "conv", leaf
            elif arr.ndim == 2:
                yield rpath, "gemm", leaf


def bind(params: Any, policy: PolicyLike,
         model_paths: Optional[Iterable[Union[str, Tuple[str, str]]]] = None,
         *, tree: str = "auto", strict: bool = False,
         prequantize: bool = True, tune_cache: Any = None) -> Plan:
    """Bind ``policy`` to a model's parameters: one walk, one Plan.

    Args:
      params: model param tree (models.cnn or models.lm conventions; an
        already pre-quantized tree is fine — quantization is idempotent —
        and so is a packed artifact: ``PackedBFP`` leaves restored with
        ``checkpoint.store.restore(..., packed="keep")`` unpack directly
        into their ``{"m", "s"}`` sidecars here).
      policy: None / BFPPolicy / PolicyMap — resolved per site, once.
      model_paths: optional explicit site list — strings or (path, kind)
        pairs.  Restricts the discovered sites to these paths and binds
        policy-only entries (no weight checks, no prequant) for paths
        the tree walk cannot see.  Default: every site the walk finds.
      tree: "cnn" | "lm" | "auto" — which path convention the tree uses.
      strict: refuse (raise) backend downgrades instead of the once-per-
        site warning; also applied to unbound-path fallbacks at call time.
      prequantize: convert eligible weight leaves to the ``{"m", "s"}``
        wire format (set False to bind dispatch only, e.g. when the
        caller already pre-quantized under a different policy).
      tune_cache: a :class:`repro.tune.TuneCache` (or a path string —
        loaded here, missing file = empty cache) of autotuned tile
        winners; the plan activates it around every bound execution so
        kernels launch with tuned tiles (``python -m repro.tune`` fills
        one for the canonical layers).

    Raises KeyError for policies naming unknown backends, and
    :class:`repro.engine.backends.BackendUnsupportedError` under
    ``strict`` when a requested backend cannot honour its policy.
    """
    _validate_policy_backends(policy)
    if isinstance(tune_cache, str):
        from repro.tune.cache import TuneCache
        tune_cache = TuneCache.load(tune_cache)
    # packed serving artifacts (checkpoint restore(packed="keep")) unpack
    # straight into {"m", "s"} sidecars here — never through float
    params = unpack_packed(params)
    kind = _detect_tree(params) if tree == "auto" else tree
    if kind not in ("cnn", "lm"):
        raise ValueError(f"tree must be 'cnn', 'lm', or 'auto'; got {kind!r}")

    wanted: Optional[Dict[str, Optional[str]]] = None
    if model_paths is not None:
        wanted = {}
        for mp in model_paths:
            if isinstance(mp, str):
                wanted[mp] = None
            else:
                p, k = mp
                wanted[p] = k

    qparams = params
    if prequantize:
        quantizer = quantize_param_tree if kind == "lm" \
            else quantize_cnn_param_tree
        # a model_paths restriction also scopes prequantization: sites
        # outside it keep their float leaves (they are not bound, so
        # they must not be converted either)
        qpolicy = policy if wanted is None else _ScopedPolicy(policy,
                                                              wanted)
        qparams = quantizer(params, qpolicy)

    warned: set = set()   # fresh per bind: each plan reports its own

    def _bind_grad(path: str, which: str) -> GradSpec:
        # backward plans resolve on the DERIVED grad path; a float
        # backward GEMM needs no backend choice, a BFP one selects (and
        # under strict, refuses) its backend HERE — before any training
        # step runs.  The weight leaf is irrelevant to the backward
        # GEMMs (they contract transposed/gradient operands), so support
        # is checked policy-only; a K-tile fitted at call time
        # (grad.fit_grad_policy) re-selects honestly then.
        gpol = resolve_grad_policy(policy, path, which)
        if gpol is None:
            return GradSpec(None, None)
        gpath = grad_path(path, which)
        if (gpol.backend_name, path) in warned:
            # the forward site already reported this exact downgrade;
            # don't repeat it two more times for #dx/#dw (strict raises
            # regardless — the dedup is warning-only)
            warned.add((gpol.backend_name, gpath))
        be = BK.select_backend(gpol, None, strict=strict, path=gpath,
                               warned=warned)
        return GradSpec(gpol, be)

    sites: Dict[str, Site] = {}
    for path, skind, leaf in _discover_sites(qparams, kind):
        if wanted is not None and path not in wanted:
            continue
        if path in sites:
            continue  # stacked trees can alias a runtime path; first wins
        pol = resolve_policy(policy, path)
        if pol is None:
            be, fb = BK.get_backend("float"), False
        else:
            be = BK.select_backend(pol, leaf, strict=strict, path=path,
                                   warned=warned)
            fb = be.name != pol.backend_name
        sites[path] = Site(path, skind, pol, be, fb,
                           prequantized=is_prequant(leaf),
                           dx=_bind_grad(path, "dx"),
                           dw=_bind_grad(path, "dw"))

    if wanted is not None:  # policy-only entries for undiscovered paths
        for path, k in wanted.items():
            if path in sites:
                continue
            pol = resolve_policy(policy, path)
            if pol is None:
                be, fb = BK.get_backend("float"), False
            else:
                be = BK.select_backend(pol, None, strict=strict, path=path,
                                       warned=warned)
                fb = be.name != pol.backend_name
            sites[path] = Site(path, k or "gemm", pol, be, fb,
                               dx=_bind_grad(path, "dx"),
                               dw=_bind_grad(path, "dw"))

    return Plan(sites, qparams, policy, strict, tune_cache=tune_cache)
