"""Backend registry for the BFP GEMM engine (DESIGN.md §7).

One datapath, three executions:

  float     disabled-quant baseline: plain ``x @ w`` (prequant weights are
            dequantized first) — the paper's floating-point reference.
  emulated  pure-jnp integer datapath (repro.core.bfp_dot): exact
            fixed-point MACs in int32, works for every scheme/rounding,
            differentiable via STE.
  pallas    fused TPU kernel (repro.kernels): Scheme.TILED only, runs
            interpret=True off-TPU.  With prequant weights it dispatches
            the sidecar-consuming kernel variant that skips in-kernel
            weight quantization entirely.

``select_backend`` honours ``policy.backend`` (or the legacy
``use_kernel`` flag) but falls back to ``emulated`` when the requested
backend cannot execute the policy faithfully — e.g. pallas with a paper
scheme, stochastic rounding, or an int16 prequant mantissa.  This folds
the previously scattered ``use_kernel`` / ``interpret=not _on_tpu()``
dispatch decisions into one place.

External backends (future: GPU Triton, int8 XLA dot) register with
:func:`register_backend`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.core.bfp_dot import bfp_matmul_2d, bfp_matmul_2d_prequant
from repro.core.bfp import Rounding, Scheme
from repro.core.policy import BFPPolicy
from repro.core.prequant import dequantize_prequant, is_prequant

__all__ = ["Backend", "register_backend", "get_backend",
           "available_backends", "select_backend",
           "BackendFallbackWarning", "BackendUnsupportedError"]

#: (x2d, w_or_prequant, policy, key) -> out [B, N]
MatmulFn = Callable[[jax.Array, object, Optional[BFPPolicy],
                     Optional[jax.Array]], jax.Array]

#: (x_nhwc, w_hwio_or_prequant, policy, stride, padding, key) -> out NHWC
ConvFn = Callable[..., jax.Array]


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    matmul: MatmulFn
    supports: Callable[[BFPPolicy, object], bool]
    #: optional fused convolution; ``None`` means engine.conv2d routes
    #: this backend through the materialized-im2col + matmul fallback
    conv: Optional[ConvFn] = None
    #: (policy, w, stride, padding) -> can ``conv`` honour this faithfully?
    conv_supports: Callable[..., bool] = lambda pol, w, stride, pad: False
    #: can ``matmul``/``conv`` consume activation-prequant ``{"m", "s"}``
    #: inputs natively (pallas: the x-prequant kernel variants)?  False
    #: means the engine dequantizes the dict first — bit-identical via
    #: quantization idempotence, just one more HBM round-trip.
    act_prequant: bool = False
    #: do ``matmul``/``conv`` accept an ``out_policy=`` kwarg emitting the
    #: activation wire format straight from the accumulator (fused
    #: requantize epilogue)?  False means the engine requantizes the
    #: float output in a second step (bit-identical, slower).
    out_quant: bool = False


_REGISTRY: Dict[str, Backend] = {}


def register_backend(name: str, matmul: MatmulFn,
                     supports: Optional[Callable] = None,
                     conv: Optional[ConvFn] = None,
                     conv_supports: Optional[Callable] = None,
                     act_prequant: bool = False,
                     out_quant: bool = False) -> None:
    _REGISTRY[name] = Backend(
        name, matmul, supports or (lambda pol, w: True), conv,
        conv_supports or (lambda pol, w, stride, pad: conv is not None),
        act_prequant, out_quant)


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown BFP backend {name!r}; available: "
                       f"{available_backends()}") from None


def available_backends():
    return sorted(_REGISTRY)


class BackendFallbackWarning(UserWarning):
    """A requested backend could not honour a policy and was downgraded."""


class BackendUnsupportedError(ValueError):
    """strict mode: the requested backend cannot honour the policy."""


#: (backend, path) pairs already warned about on the bare per-call path —
#: the downgrade is warned ONCE per site, not per forward (eager loops
#: would otherwise spam).  ``engine.bind`` passes its own fresh registry
#: per bind, so every independently-constructed Plan/ServeEngine surfaces
#: its own downgrades instead of being muted by an earlier one's.
_WARNED: Set[Tuple[str, Optional[str]]] = set()


def select_backend(policy: BFPPolicy, w, *, strict: bool = False,
                   path: Optional[str] = None,
                   warned: Optional[Set] = None) -> Backend:
    """Requested backend if it supports (policy, w); else emulated.

    The downgrade is never silent: by default it emits a
    :class:`BackendFallbackWarning`, deduplicated per (backend, site)
    against ``warned`` (callers like ``engine.bind`` pass a fresh set
    per bind; bare per-call dispatch shares a process-wide one); with
    ``strict=True`` (surfaced through ``engine.bind(strict=...)`` for
    serving configs) it raises :class:`BackendUnsupportedError` instead,
    so a deployment that asked for the fused kernel fails loudly rather
    than drifting onto the emulated path.
    """
    be = get_backend(policy.backend_name)
    if not be.supports(policy, w):
        msg = (f"backend {be.name!r} cannot honour policy "
               f"(scheme={policy.scheme}, rounding={policy.rounding}, "
               f"l_w={policy.l_w})"
               + (f" at site {path!r}" if path else ""))
        if strict:
            raise BackendUnsupportedError(
                msg + "; refusing the emulated fallback (strict mode)")
        reg = _WARNED if warned is None else warned
        if (be.name, path) not in reg:
            reg.add((be.name, path))
            warnings.warn(msg + "; falling back to 'emulated'",
                          BackendFallbackWarning, stacklevel=2)
        be = _REGISTRY["emulated"]
    return be


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

def _float_matmul(x2d, w, policy=None, key=None):
    if is_prequant(w):
        w = dequantize_prequant(w, x2d.dtype)
    return x2d @ w


def _emulated_matmul(x2d, w, policy, key=None):
    if is_prequant(w):
        out = bfp_matmul_2d_prequant(x2d, w["m"], w["s"], policy, key)
        return out.astype(x2d.dtype)
    out = bfp_matmul_2d(x2d, w, policy, key)
    return out.astype(jnp.result_type(x2d.dtype, w.dtype))


def _pallas_matmul(x2d, w, policy, key=None, out_policy=None):
    # x2d may be an activation-prequant {"m", "s"} dict (the fused
    # epilogue's output chained into the next layer) — ops dispatches the
    # x-prequant kernel variants; out_policy asks for the fused
    # requantize epilogue (activation wire format straight from VMEM).
    from repro.kernels import ops  # local import: kernels are optional
    if is_prequant(w):
        return ops.bfp_matmul_prequant(x2d, w["m"], w["s"], policy,
                                       out_policy=out_policy)
    return ops.bfp_matmul(x2d, w, policy, out_policy=out_policy)


def _pallas_supports(policy: BFPPolicy, w) -> bool:
    # The fused kernel implements exactly Scheme.TILED with block == K
    # tile, round-to-nearest, both operands quantized.  Anything else is
    # the emulated path's job (silent semantic drift is worse than a
    # fallback; the old use_kernel flag ran TILED math for ANY scheme).
    if policy.scheme is not Scheme.TILED or policy.block_k is None:
        return False
    if policy.rounding is not Rounding.ROUND:
        return False
    if not (policy.quantize_weights and policy.quantize_inputs):
        return False
    if is_prequant(w) and w["m"].dtype != jnp.int8:
        return False  # prequant kernel streams int8 mantissas (L_W <= 8)
    return True


def _pallas_conv(x, w, policy, stride, padding, key=None, out_policy=None):
    from repro.kernels import ops  # local import: kernels are optional
    if is_prequant(w):
        return ops.bfp_conv2d_prequant(x, w["m"], w["s"], policy, stride,
                                       padding, out_policy=out_policy)
    return ops.bfp_conv2d(x, w, policy, stride, padding,
                          out_policy=out_policy)


def _pallas_conv_supports(policy: BFPPolicy, w, stride, padding) -> bool:
    # Same faithfulness contract as the GEMM kernel, plus the implicit
    # kernel's geometry: string SAME/VALID padding and a positive int
    # stride.  Everything else takes the honest im2col fallback.
    if padding not in ("SAME", "VALID"):
        return False
    if not isinstance(stride, int) or stride < 1:
        return False
    return _pallas_supports(policy, w)


register_backend("float", _float_matmul)
register_backend("emulated", _emulated_matmul)
register_backend("pallas", _pallas_matmul, _pallas_supports,
                 conv=_pallas_conv, conv_supports=_pallas_conv_supports,
                 act_prequant=True, out_quant=True)
