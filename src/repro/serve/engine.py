"""Batched serving engine: prefill + decode with KV/recurrent caches.

``generate`` drives the jitted decode_step over N tokens with greedy or
temperature sampling.  ``ServeEngine`` adds continuous-batching-lite: a
slot table where finished sequences are replaced by queued requests
between decode steps (the Python driver swaps rows; the jitted step is
shape-stable), plus BFP weight pre-quantization (``prequant=`` or an
already-converted param tree) — the paper's deployment mode, where
weights live in HBM as int8 mantissas + exponent sidecars, every GEMM
runs the fixed-point datapath, and quantization happens ONCE at engine
construction, not per decode step (benchmarks/engine_bench.py measures
the difference).  ``policy`` may be a per-layer ``repro.engine.PolicyMap``;
at construction it is bound into an ``engine.Plan`` (``self.plan``) so
rule resolution and backend selection also happen once, at admission-time
weight load, and ``strict_backend=True`` rejects configs whose requested
backend cannot honour the policy (DESIGN.md §7.1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.configs.base import LMConfig
from repro.engine import PolicyLike
from repro.models.lm import model as Mdl
from repro.serve.degrade import (DeadlineExceeded, DegradeConfig,
                                 DegradeController, QueueOverloaded,
                                 float_params)
from repro.serve.slots import SlotTable

__all__ = ["prefill", "generate", "ServeEngine", "Request"]


def prefill(params, cfg: LMConfig, tokens: jax.Array, cache,
            policy: PolicyLike = None,
            enc_feats: Optional[jax.Array] = None):
    """Sequential prefill through decode_step (state-correct for every
    family).  tokens: [B, S_prompt].  Returns (cache, last_logits)."""
    if cfg.is_encdec and enc_feats is not None:
        cache = dict(cache,
                     enc_out=Mdl.prefill_encoder(params, cfg, enc_feats,
                                                 policy))

    def body(carry, t):
        cache, _ = carry
        logits, cache = Mdl.decode_step(params, cfg, cache,
                                        tokens[:, t][:, None],
                                        t.astype(jnp.int32), policy)
        return (cache, logits), None

    zero_logits = jnp.zeros((tokens.shape[0], 1, cfg.vocab_size),
                            jnp.float32)
    (cache, logits), _ = jax.lax.scan(body, (cache, zero_logits),
                                      jnp.arange(tokens.shape[1]))
    return cache, logits


def generate(params, cfg: LMConfig, prompt: jax.Array, max_new: int,
             policy: PolicyLike = None, temperature: float = 0.0,
             key: Optional[jax.Array] = None,
             enc_feats: Optional[jax.Array] = None,
             max_len: Optional[int] = None) -> jax.Array:
    """Greedy/temperature generation.  Returns [B, max_new] tokens."""
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    cache = Mdl.init_cache(cfg, b, max_len)
    cache, logits = prefill(params, cfg, prompt, cache, policy, enc_feats)
    key = key if key is not None else jax.random.PRNGKey(0)

    def sample(logits, k):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    def body(carry, i):
        cache, tok, k = carry
        k, ks = jax.random.split(k)
        logits, cache = Mdl.decode_step(params, cfg, cache, tok[:, None],
                                        (s + i).astype(jnp.int32), policy)
        nxt = sample(logits, ks)
        return (cache, nxt, k), nxt

    first = sample(logits, key)
    (_, _, _), toks = jax.lax.scan(body, (cache, first, key),
                                   jnp.arange(1, max_new))
    return jnp.concatenate([first[:, None], toks.T], axis=1)


# ---------------------------------------------------------------------------
# Continuous-batching-lite
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: absolute engine-clock deadline; missing it completes the request
    #: exceptionally (``error`` = DeadlineExceeded) with partial ``out``
    deadline: Optional[float] = None
    error: Optional[BaseException] = None
    #: True when the request was admitted onto the lower-L fallback plan
    degraded: bool = False


class ServeEngine:
    """Slot-table batched server (shape-stable jitted decode step).

    Admission: empty slots take queued requests; their prompts prefill
    into the slot's cache rows.  Each decode step advances every active
    slot one token; finished slots free immediately (continuous batching).
    """

    def __init__(self, params, cfg: LMConfig, slots: int = 4,
                 max_len: int = 512,
                 policy: PolicyLike = None,
                 prequant: PolicyLike = None,
                 strict_backend: bool = False,
                 max_queue: Optional[int] = None,
                 fallback_policy: PolicyLike = None,
                 degrade: Optional[DegradeConfig] = None,
                 float_retry: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        if cfg.is_encdec:
            # decode-only slot engine: no encoder prefill path, and the
            # enc_out cache leaf ([B, S, D]) breaks the slot-axis-at-dim-1
            # contract _merge_rows relies on
            raise ValueError("ServeEngine does not serve encoder-decoder "
                             "configs; use serve.generate with enc_feats")
        # packed weight artifacts (checkpoint.store format="bfp_packed",
        # restored with packed="keep") unpack straight into {"m", "s"}
        # sidecars at admission — the ~4x-smaller load path; float
        # weights are never materialized for those sites
        params = EG.unpack_packed(params)
        if prequant is not None:
            # cached pre-quantized weights: block-format once here, serve
            # the int8+scale wire format on every subsequent GEMM
            params = EG.prequantize(params, prequant)
        # Admission-time bind: resolve every site's PolicyMap rule and
        # select its concrete backend ONCE, at weight load — decode steps
        # dispatch through the bound plan instead of re-resolving per
        # call.  ``strict_backend=True`` makes a serving config that
        # requested a backend the policy can't run on FAIL HERE (raising
        # BackendUnsupportedError) instead of silently drifting onto the
        # emulated path.  Weight quantization stays governed by the
        # ``prequant`` arg above, so numerics are unchanged.
        self.plan = EG.bind(params, policy, tree="lm",
                            strict=strict_backend, prequantize=False)
        self.params, self.cfg, self.policy = params, cfg, self.plan
        self.slots = slots
        self.max_len = max_len
        self.cache = Mdl.init_cache(cfg, slots, max_len)
        #: pristine per-slot state for admission-time row resets
        self._cache0 = self.cache
        #: shared slot-table bookkeeping (serve.slots); ``slot_req`` and
        #: ``queue`` are aliases of the table's lists, so row-level code
        #: below mutates the same state the table reports on
        self.table = SlotTable(slots)
        self.slot_req: List[Optional[Request]] = self.table.req
        self.slot_pos = [0] * slots
        self.queue: List[Request] = self.table.queue
        self._tok = jnp.zeros((slots, 1), jnp.int32)

        plan = self.plan

        def _step(cache, tok, pos):
            return Mdl.decode_step(params, cfg, cache, tok, pos, plan)

        self._step = jax.jit(_step)

        # -- graceful degradation state ---------------------------------
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self._clock = clock
        self._float_retry = float_retry
        self._float_step = None
        #: per-slot plan tag: True = this slot decodes on the fallback
        #: plan for its whole lifetime (a request never switches plans
        #: mid-sequence — its numerics stay internally consistent)
        self.slot_deg: List[bool] = [False] * slots
        if fallback_policy is not None:
            fb_plan = EG.bind(params, fallback_policy, tree="lm",
                              strict=strict_backend, prequantize=False)
            self.fallback_plan = fb_plan

            def _step_fb(cache, tok, pos):
                return Mdl.decode_step(params, cfg, cache, tok, pos,
                                       fb_plan)

            self._step_fb = jax.jit(_step_fb)
            self.controller: Optional[DegradeController] = \
                DegradeController(degrade or DegradeConfig(
                    queue_high=slots))
        else:
            self.fallback_plan = None
            self._step_fb = None
            self.controller = (DegradeController(degrade)
                               if degrade is not None else None)
        self.stats: Dict[str, int] = {"shed": 0, "expired": 0,
                                      "failed": 0, "float_retries": 0,
                                      "degraded_served": 0}

    def submit(self, req: Request):
        if not req.prompt:
            # an empty prompt would leave _admit's prefill loop with no
            # logits to seed the first decode from, wedging the slot
            raise ValueError("request prompt must be non-empty")
        if self.max_queue is not None and \
                len(self.table.queue) >= self.max_queue:
            self.stats["shed"] += 1
            raise QueueOverloaded(
                f"queue depth {len(self.table.queue)} at limit "
                f"{self.max_queue}; request {req.rid} shed", rid=req.rid)
        self.table.submit(req)

    def _merge_rows(self, old, new, rows):
        """Keep only slot ``rows`` of the stepped cache; every other
        slot's rows are restored from ``old``.

        The jitted step is whole-batch and decode_step takes ONE scalar
        position, so any call writes every slot's cache row at that
        position — garbage for slots that are at a different position.
        ``init_cache`` puts the slot axis at dim 1 on every leaf
        ([n_layers, B, ...]) for the families this engine serves
        (encoder-decoder configs are rejected at construction), so the
        mask is structural, not guessed.
        """
        sel = jnp.zeros((self.slots,), bool)
        sel = sel.at[jnp.asarray(rows)].set(True)

        def one(o, n):
            shape = [1] * o.ndim
            shape[1] = self.slots
            return jnp.where(sel.reshape(shape), n, o)

        return jax.tree_util.tree_map(one, old, new)

    def _slot_step(self, s: int):
        """The jitted step serving slot ``s`` (primary or fallback)."""
        return self._step_fb if self.slot_deg[s] else self._step

    def _float_step_fn(self):
        """Lazily built float-reference decode step (retry path)."""
        if self._float_step is None:
            ftree = float_params(self.params)
            cfg = self.cfg

            def _fstep(cache, tok, pos):
                return Mdl.decode_step(ftree, cfg, cache, tok, pos, None)

            self._float_step = jax.jit(_fstep)
        return self._float_step

    def _fail_slots(self, slots: List[int], exc: BaseException) -> None:
        """Complete the requests in ``slots`` exceptionally and free them
        — a raising step must never leak slots."""
        for s in slots:
            req = self.slot_req[s]
            if req is None:
                continue
            req.error = exc
            req.done = True
            self.stats["failed"] += 1
            self.table.free(s)

    def _expire(self) -> None:
        """Fail queued or decoding requests whose deadline passed (their
        partial ``out`` stays — the client sees how far decode got)."""
        now = self._clock()

        def dead(r):
            return r.deadline is not None and now > r.deadline

        expired = [r for r in self.queue if dead(r)]
        if expired:
            self.queue[:] = [r for r in self.queue if not dead(r)]
        for s in self.table.active():
            r = self.slot_req[s]
            if dead(r):
                expired.append(r)
                self.table.free(s)
        for r in expired:
            r.error = DeadlineExceeded(
                f"request {r.rid} missed deadline {r.deadline}", rid=r.rid)
            r.done = True
            self.stats["expired"] += 1

    def _admit(self, degraded: bool = False):
        while (adm := self.table.admit_one()) is not None:
            s, req = adm
            # plan choice is an ADMISSION decision: the slot keeps it for
            # the request's whole decode (prefill included), so degraded
            # requests are end-to-end lower-L — bit-exact vs a direct
            # lower-L bind — rather than a mid-sequence numeric splice
            self.slot_deg[s] = degraded and self._step_fb is not None
            req.degraded = self.slot_deg[s]
            if req.degraded:
                self.stats["degraded_served"] += 1
            # reset slot s to pristine state: recurrent families
            # (ssm/hybrid) READ-modify-write their states h' = f(h, x),
            # so a reused slot must not prefill from the previous
            # occupant's (or a wholesale-stepped garbage) state.  KV
            # rows are position-overwritten anyway, so this costs one
            # merge and buys correctness for every cache family.
            self.cache = self._merge_rows(self.cache, self._cache0, [s])
            others = [r for i, r in enumerate(self.slot_req)
                      if r is not None and i != s]
            # per-slot prefill: the shape-stable step runs the whole
            # batch, but ONLY row s's cache writes are kept — already
            # active slots would otherwise have their rows clobbered
            # at the new request's (wrong) positions.  Batch rows are
            # independent in decode_step, so garbage other rows pick
            # up MID-loop is never read by row s: one merge after the
            # loop is bit-identical and len(prompt)x cheaper; with no
            # other slot active the merge is skipped entirely.
            cache = self.cache
            step_fn = self._slot_step(s)
            try:
                for t, tok in enumerate(req.prompt):
                    toks = self._tok.at[s, 0].set(tok)
                    logits, cache = step_fn(
                        cache, toks, jnp.asarray(t, jnp.int32))
            except Exception as e:               # noqa: BLE001 — a
                self._fail_slots([s], e)         # raising prefill must
                continue                         # not wedge the slot
            self.cache = (self._merge_rows(self.cache, cache, [s])
                          if others else cache)
            self.slot_pos[s] = len(req.prompt)
            req._next = int(jnp.argmax(logits[s, -1]))

    def step(self) -> int:
        """One decode step over all active slots; returns #active.

        Overload handling mirrors ``CnnServeEngine.step``: the
        controller observes the pre-admission queue depth, admissions
        made while DEGRADED decode on the pre-bound lower-L fallback
        plan for their whole lifetime, and expired requests complete
        exceptionally before any jitted step runs.
        """
        degraded = False
        if self.controller is not None:
            state = self.controller.observe(len(self.queue))
            degraded = state == DegradeController.DEGRADED
        self._admit(degraded)
        self._expire()
        active = self.table.active()
        if not active:
            return 0
        toks = self._tok
        for s in active:
            req = self.slot_req[s]
            toks = toks.at[s, 0].set(req._next if not req.out
                                     else req.out[-1])
        # decode_step takes a scalar position, but staggered admissions
        # leave slots at DIFFERENT positions — and mixed admission states
        # leave slots on DIFFERENT plans.  Step each (plan, position)
        # group separately, keeping only that group's rows — one jitted
        # call per distinct group (usually 1; bounded by #slots).  The
        # old max(slot_pos) stepping wrote every slot's KV at the most
        # advanced slot's position.
        by_grp: Dict[Tuple[bool, int], List[int]] = {}
        for s in active:
            by_grp.setdefault((self.slot_deg[s], self.slot_pos[s]),
                              []).append(s)
        next_tok: Dict[int, int] = {}
        for (deg, pos), group in sorted(by_grp.items()):
            step_fn = self._step_fb if deg else self._step
            try:
                logits, stepped = step_fn(self.cache, toks,
                                          jnp.asarray(pos, jnp.int32))
                if self._float_retry and not bool(jnp.all(jnp.isfinite(
                        logits[jnp.asarray(group)]))):
                    # one retry on the float reference of the same
                    # weights: a blown-up BFP step (faulty container,
                    # exponent SEU) degrades to float numerics instead
                    # of feeding NaN logits into sampling
                    self.stats["float_retries"] += 1
                    logits, stepped = self._float_step_fn()(
                        self.cache, toks, jnp.asarray(pos, jnp.int32))
            except Exception as e:               # noqa: BLE001 — slots
                self._fail_slots(group, e)       # must never leak
                continue
            # single group (steady state): every active slot is at this
            # position and inactive rows are rewritten before any read,
            # so the masked merge copy would protect nothing — skip it.
            self.cache = (stepped if len(by_grp) == 1 else
                          self._merge_rows(self.cache, stepped, group))
            for s in group:
                next_tok[s] = int(jnp.argmax(logits[s, -1]))
        for s in active:
            req = self.slot_req[s]
            if s not in next_tok:
                continue                  # group failed; slot already freed
            req.out.append(next_tok[s])
            self.slot_pos[s] += 1
            if len(req.out) >= req.max_new:
                req.done = True
                self.table.free(s)
        return len(active)

    def run(self) -> List[Request]:
        # include requests a prior step() already admitted into slots —
        # snapshotting only the queue would drop them from the result
        all_reqs = [r for r in self.slot_req if r is not None] + \
            list(self.queue)
        while self.table.pending():
            self.step()
        return all_reqs
