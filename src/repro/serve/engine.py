"""Batched serving engine: prefill + decode with KV/recurrent caches.

``generate`` drives the jitted decode_step over N tokens with greedy or
temperature sampling.  ``ServeEngine`` adds iteration-level (continuous)
batching: a slot table where finished sequences are replaced by queued
requests between decode steps (the Python driver swaps rows; the jitted
step is shape-stable), with PREFILL CHUNKED INTO THE STEP LOOP — an
admission consumes at most ``prefill_chunk`` prompt tokens per engine
step, so a long-prompt admission never stalls in-flight decodes behind
``len(prompt)`` jitted calls (``batching="bucket"`` keeps the legacy
blocking-prefill behaviour as the measured baseline for
``benchmarks/serve_load.py``).  BFP weight pre-quantization
(``prequant=`` or an already-converted param tree) is the paper's
deployment mode, where weights live in HBM as int8 mantissas + exponent
sidecars, every GEMM runs the fixed-point datapath, and quantization
happens ONCE at engine construction, not per decode step
(benchmarks/engine_bench.py measures the difference).  ``policy`` may be
a per-layer ``repro.engine.PolicyMap``; at construction it is bound into
an ``engine.Plan`` (``self.plan``) so rule resolution and backend
selection also happen once, at admission-time weight load, and
``strict_backend=True`` rejects configs whose requested backend cannot
honour the policy (DESIGN.md §7.1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import engine as EG
from repro.configs.base import LMConfig
from repro.engine import PolicyLike
from repro.models.lm import model as Mdl
from repro.serve.degrade import (DeadlineExceeded, DegradeConfig,
                                 DegradeController, QueueOverloaded,
                                 RequestTooLarge, float_params)
from repro.serve.slots import SlotTable

__all__ = ["prefill", "generate", "ServeEngine", "Request"]


def prefill(params, cfg: LMConfig, tokens: jax.Array, cache,
            policy: PolicyLike = None,
            enc_feats: Optional[jax.Array] = None):
    """Sequential prefill through decode_step (state-correct for every
    family).  tokens: [B, S_prompt].  Returns (cache, last_logits)."""
    if cfg.is_encdec and enc_feats is not None:
        cache = dict(cache,
                     enc_out=Mdl.prefill_encoder(params, cfg, enc_feats,
                                                 policy))

    def body(carry, t):
        cache, _ = carry
        logits, cache = Mdl.decode_step(params, cfg, cache,
                                        tokens[:, t][:, None],
                                        t.astype(jnp.int32), policy)
        return (cache, logits), None

    zero_logits = jnp.zeros((tokens.shape[0], 1, cfg.vocab_size),
                            jnp.float32)
    (cache, logits), _ = jax.lax.scan(body, (cache, zero_logits),
                                      jnp.arange(tokens.shape[1]))
    return cache, logits


def generate(params, cfg: LMConfig, prompt: jax.Array, max_new: int,
             policy: PolicyLike = None, temperature: float = 0.0,
             key: Optional[jax.Array] = None,
             enc_feats: Optional[jax.Array] = None,
             max_len: Optional[int] = None) -> jax.Array:
    """Greedy/temperature generation.  Returns [B, max_new] tokens."""
    b, s = prompt.shape
    max_len = max_len or (s + max_new)
    cache = Mdl.init_cache(cfg, b, max_len)
    cache, logits = prefill(params, cfg, prompt, cache, policy, enc_feats)
    key = key if key is not None else jax.random.PRNGKey(0)

    def sample(logits, k):
        lg = logits[:, -1].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    def body(carry, i):
        cache, tok, k = carry
        k, ks = jax.random.split(k)
        logits, cache = Mdl.decode_step(params, cfg, cache, tok[:, None],
                                        (s + i).astype(jnp.int32), policy)
        nxt = sample(logits, ks)
        return (cache, nxt, k), nxt

    first = sample(logits, key)
    (_, _, _), toks = jax.lax.scan(body, (cache, first, key),
                                   jnp.arange(1, max_new))
    return jnp.concatenate([first[:, None], toks.T], axis=1)


# ---------------------------------------------------------------------------
# Iteration-level continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: absolute engine-clock deadline; missing it completes the request
    #: exceptionally (``error`` = DeadlineExceeded) with partial ``out``
    deadline: Optional[float] = None
    error: Optional[BaseException] = None
    #: True when the request was admitted onto the lower-L fallback plan
    degraded: bool = False


class ServeEngine:
    """Slot-table batched server (shape-stable jitted decode step).

    Iteration-level batching (``batching="continuous"``, the default):
    every :meth:`step` expires, admits, and advances — free slots take
    queued requests with NO up-front prefill; a prefilling slot consumes
    at most ``prefill_chunk`` prompt tokens per step while already-active
    slots keep decoding one token per step, in the SAME grouped jitted
    calls wherever positions coincide.  Finished slots free immediately
    and are re-admitted the next step, so a slow admission never erects
    a barrier in front of in-flight work.  ``batching="bucket"`` keeps
    the legacy behaviour — admission runs the WHOLE prompt's jitted
    prefill before any active slot decodes — as the bucket-barrier
    baseline the load harness (``serve.load`` /
    ``benchmarks/serve_load.py``) measures continuous batching against.

    Row independence makes both modes bit-identical per request to solo
    serving (pinned by tests/test_system.py + tests/test_serve_continuous
    .py): each slot's cache rows only ever see its own tokens at its own
    positions.
    """

    def __init__(self, params, cfg: LMConfig, slots: int = 4,
                 max_len: int = 512,
                 policy: PolicyLike = None,
                 prequant: PolicyLike = None,
                 strict_backend: bool = False,
                 max_queue: Optional[int] = None,
                 fallback_policy: PolicyLike = None,
                 degrade: Optional[DegradeConfig] = None,
                 float_retry: bool = True,
                 batching: str = "continuous",
                 prefill_chunk: Optional[int] = 8,
                 clock: Callable[[], float] = time.monotonic):
        if cfg.is_encdec:
            # decode-only slot engine: no encoder prefill path, and the
            # enc_out cache leaf ([B, S, D]) breaks the slot-axis-at-dim-1
            # contract _merge_rows relies on
            raise ValueError("ServeEngine does not serve encoder-decoder "
                             "configs; use serve.generate with enc_feats")
        if batching not in ("continuous", "bucket"):
            raise ValueError(f"batching must be 'continuous' or 'bucket', "
                             f"got {batching!r}")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1 or None, got "
                             f"{prefill_chunk}")
        # packed weight artifacts (checkpoint.store format="bfp_packed",
        # restored with packed="keep") unpack straight into {"m", "s"}
        # sidecars at admission — the ~4x-smaller load path; float
        # weights are never materialized for those sites
        params = EG.unpack_packed(params)
        if prequant is not None:
            # cached pre-quantized weights: block-format once here, serve
            # the int8+scale wire format on every subsequent GEMM
            params = EG.prequantize(params, prequant)
        # Admission-time bind: resolve every site's PolicyMap rule and
        # select its concrete backend ONCE, at weight load — decode steps
        # dispatch through the bound plan instead of re-resolving per
        # call.  ``strict_backend=True`` makes a serving config that
        # requested a backend the policy can't run on FAIL HERE (raising
        # BackendUnsupportedError) instead of silently drifting onto the
        # emulated path.  Weight quantization stays governed by the
        # ``prequant`` arg above, so numerics are unchanged.
        self.plan = EG.bind(params, policy, tree="lm",
                            strict=strict_backend, prequantize=False)
        self.params, self.cfg, self.policy = params, cfg, self.plan
        self.slots = slots
        self.max_len = max_len
        self.batching = batching
        self.prefill_chunk = prefill_chunk
        self.cache = Mdl.init_cache(cfg, slots, max_len)
        #: pristine per-slot state for admission-time row resets
        self._cache0 = self.cache
        #: shared slot-table bookkeeping (serve.slots); ``slot_req`` and
        #: ``queue`` are aliases of the table's containers, so row-level
        #: code below mutates the same state the table reports on
        self.table = SlotTable(slots)
        self.slot_req: List[Optional[Request]] = self.table.req
        self.slot_pos = [0] * slots
        #: prompt tokens already consumed by the slot's occupant; a slot
        #: with ``slot_fed < len(prompt)`` is still prefilling
        self.slot_fed = [0] * slots
        self.queue = self.table.queue
        self._tok = jnp.zeros((slots, 1), jnp.int32)

        plan = self.plan

        def _step(cache, tok, pos):
            return Mdl.decode_step(params, cfg, cache, tok, pos, plan)

        self._step = jax.jit(_step)

        # -- graceful degradation state ---------------------------------
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self._clock = clock
        self._float_retry = float_retry
        self._float_step = None
        #: per-slot plan tag: True = this slot decodes on the fallback
        #: plan for its whole lifetime (a request never switches plans
        #: mid-sequence — its numerics stay internally consistent)
        self.slot_deg: List[bool] = [False] * slots
        if fallback_policy is not None:
            fb_plan = EG.bind(params, fallback_policy, tree="lm",
                              strict=strict_backend, prequantize=False)
            self.fallback_plan = fb_plan

            def _step_fb(cache, tok, pos):
                return Mdl.decode_step(params, cfg, cache, tok, pos,
                                       fb_plan)

            self._step_fb = jax.jit(_step_fb)
            self.controller: Optional[DegradeController] = \
                DegradeController(degrade or DegradeConfig(
                    queue_high=slots))
        else:
            self.fallback_plan = None
            self._step_fb = None
            self.controller = (DegradeController(degrade)
                               if degrade is not None else None)
        self.stats: Dict[str, int] = {"shed": 0, "expired": 0,
                                      "failed": 0, "completed": 0,
                                      "float_retries": 0,
                                      "degraded_served": 0}
        #: total jitted decode calls issued (prefill + decode + retries)
        #: — the load harness's machine-independent virtual-time unit
        #: (serve.load ``call_cost``): one whole-batch decode_step is
        #: one unit of accelerator occupancy regardless of host speed
        self.ncalls = 0

    def submit(self, req: Request):
        """Queue a request, validating it against the cache geometry.

        A request that cannot fit the cache is refused with the typed
        :class:`~repro.serve.degrade.RequestTooLarge`: decode positions
        past ``max_len`` would be CLAMPED/DROPPED by JAX's out-of-bounds
        ``.at[].set`` semantics (no error is ever raised in jit), so the
        engine would silently serve logits computed from a corrupt
        cache.  ``max_new < 1`` is refused too — the decode loop always
        emits at least one token, so "zero tokens" is not a request this
        engine can honour.
        """
        if not req.prompt:
            # an empty prompt would leave the prefill loop with no
            # logits to seed the first decode from, wedging the slot
            raise ValueError("request prompt must be non-empty")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new} "
                             f"(the decode loop always emits a token)")
        if len(req.prompt) + req.max_new > self.max_len:
            raise RequestTooLarge(
                f"request {req.rid}: len(prompt)={len(req.prompt)} + "
                f"max_new={req.max_new} exceeds the cache length "
                f"{self.max_len}; out-of-bounds cache writes are silently "
                f"clamped under jit, corrupting the logits", rid=req.rid)
        if self.max_queue is not None and \
                len(self.table.queue) >= self.max_queue:
            self.stats["shed"] += 1
            raise QueueOverloaded(
                f"queue depth {len(self.table.queue)} at limit "
                f"{self.max_queue}; request {req.rid} shed", rid=req.rid)
        self.table.submit(req)

    def _merge_rows(self, old, new, rows):
        """Keep only slot ``rows`` of the stepped cache; every other
        slot's rows are restored from ``old``.

        The jitted step is whole-batch and decode_step takes ONE scalar
        position, so any call writes every slot's cache row at that
        position — garbage for slots that are at a different position.
        ``init_cache`` puts the slot axis at dim 1 on every leaf
        ([n_layers, B, ...]) for the families this engine serves
        (encoder-decoder configs are rejected at construction), so the
        mask is structural, not guessed.
        """
        sel = jnp.zeros((self.slots,), bool)
        sel = sel.at[jnp.asarray(rows)].set(True)

        def one(o, n):
            shape = [1] * o.ndim
            shape[1] = self.slots
            return jnp.where(sel.reshape(shape), n, o)

        return jax.tree_util.tree_map(one, old, new)

    def _slot_step(self, s: int):
        """The jitted step serving slot ``s`` (primary or fallback)."""
        return self._step_fb if self.slot_deg[s] else self._step

    def _float_step_fn(self):
        """Lazily built float-reference decode step (retry path)."""
        if self._float_step is None:
            ftree = float_params(self.params)
            cfg = self.cfg

            def _fstep(cache, tok, pos):
                return Mdl.decode_step(ftree, cfg, cache, tok, pos, None)

            self._float_step = jax.jit(_fstep)
        return self._float_step

    def _fail_slots(self, slots: List[int], exc: BaseException) -> None:
        """Complete the requests in ``slots`` exceptionally and free them
        — a raising step must never leak slots."""
        for s in slots:
            req = self.slot_req[s]
            if req is None:
                continue
            req.error = exc
            req.done = True
            self.stats["failed"] += 1
            self.table.free(s)

    def _expire(self) -> None:
        """Fail queued or decoding requests whose deadline passed (their
        partial ``out`` stays — the client sees how far decode got).

        Runs BEFORE admission in :meth:`step`: an already-dead queued
        request must never be admitted (and, worst, prefilled for
        ``len(prompt)`` jitted calls) only to be failed afterwards.
        """
        now = self._clock()

        def dead(r):
            return r.deadline is not None and now > r.deadline

        expired = self.table.retain(lambda r: not dead(r))
        for s in self.table.active():
            r = self.slot_req[s]
            if dead(r):
                expired.append(r)
                self.table.free(s)
        for r in expired:
            r.error = DeadlineExceeded(
                f"request {r.rid} missed deadline {r.deadline}", rid=r.rid)
            r.done = True
            self.stats["expired"] += 1

    def _reset_slot(self, s: int, req: Request, degraded: bool) -> None:
        """Admission-time slot bookkeeping shared by both batching modes.

        Plan choice is an ADMISSION decision: the slot keeps it for the
        request's whole decode (prefill included), so degraded requests
        are end-to-end lower-L — bit-exact vs a direct lower-L bind —
        rather than a mid-sequence numeric splice.  The cache rows reset
        to pristine state: recurrent families (ssm/hybrid)
        read-modify-write their states h' = f(h, x), so a reused slot
        must not prefill from the previous occupant's (or a
        wholesale-stepped garbage) state.  KV rows are
        position-overwritten anyway, so this costs one merge and buys
        correctness for every cache family.
        """
        self.slot_deg[s] = degraded and self._step_fb is not None
        req.degraded = self.slot_deg[s]
        if req.degraded:
            self.stats["degraded_served"] += 1
        self.cache = self._merge_rows(self.cache, self._cache0, [s])
        self.slot_pos[s] = 0
        self.slot_fed[s] = 0

    def _admit(self, degraded: bool = False):
        """Admit queued requests into free slots.

        Continuous mode: allocation only — prompt tokens are fed by the
        step loop, ``prefill_chunk`` at a time, interleaved with active
        decodes.  Bucket mode (the legacy baseline): the WHOLE prompt
        prefills here, one jitted call per token, before any active slot
        advances — exactly the admission stall the load harness measures.
        """
        while (adm := self.table.admit_one()) is not None:
            s, req = adm
            self._reset_slot(s, req, degraded)
            if self.batching == "continuous":
                continue
            # -- legacy blocking prefill (bucket-barrier baseline) ------
            others = [r for i, r in enumerate(self.slot_req)
                      if r is not None and i != s]
            # per-slot prefill: the shape-stable step runs the whole
            # batch, but ONLY row s's cache writes are kept — already
            # active slots would otherwise have their rows clobbered
            # at the new request's (wrong) positions.  Batch rows are
            # independent in decode_step, so garbage other rows pick
            # up MID-loop is never read by row s: one merge after the
            # loop is bit-identical and len(prompt)x cheaper; with no
            # other slot active the merge is skipped entirely.
            cache = self.cache
            step_fn = self._slot_step(s)
            try:
                for t, tok in enumerate(req.prompt):
                    toks = self._tok.at[s, 0].set(tok)
                    self.ncalls += 1
                    logits, cache = step_fn(
                        cache, toks, jnp.asarray(t, jnp.int32))
            except Exception as e:               # noqa: BLE001 — a
                self._fail_slots([s], e)         # raising prefill must
                continue                         # not wedge the slot
            self.cache = (self._merge_rows(self.cache, cache, [s])
                          if others else cache)
            self.slot_pos[s] = self.slot_fed[s] = len(req.prompt)
            req._next = int(jnp.argmax(logits[s, -1]))

    def _feed_round(self, fed: List[int]) -> None:
        """Advance every slot in ``fed`` one token — its next PROMPT
        token while prefilling, its last sampled token while decoding.

        decode_step takes a scalar position, but staggered admissions
        leave slots at DIFFERENT positions — and mixed admission states
        leave slots on DIFFERENT plans.  Step each (plan, position)
        group separately, keeping only that group's rows — one jitted
        call per distinct group (usually 1; bounded by #slots).  Rows
        are independent, so a prefill token and a decode token sharing
        one grouped call are each bit-identical to solo serving.
        """
        live = self.table.active()
        toks = self._tok
        pos_of: Dict[int, int] = {}
        for s in fed:
            req = self.slot_req[s]
            if self.slot_fed[s] < len(req.prompt):
                tok, pos = req.prompt[self.slot_fed[s]], self.slot_fed[s]
            else:
                tok = req._next if not req.out else req.out[-1]
                pos = self.slot_pos[s]
            toks = toks.at[s, 0].set(tok)
            pos_of[s] = pos
        by_grp: Dict[Tuple[bool, int], List[int]] = {}
        for s in fed:
            by_grp.setdefault((self.slot_deg[s], pos_of[s]), []).append(s)
        logits_of: Dict[int, jax.Array] = {}
        for (deg, pos), group in sorted(by_grp.items()):
            step_fn = self._step_fb if deg else self._step
            try:
                self.ncalls += 1
                logits, stepped = step_fn(self.cache, toks,
                                          jnp.asarray(pos, jnp.int32))
                if self._float_retry and not bool(jnp.all(jnp.isfinite(
                        logits[jnp.asarray(group)]))):
                    # one retry on the float reference of the same
                    # weights: a blown-up BFP step (faulty container,
                    # exponent SEU) degrades to float numerics instead
                    # of feeding NaN logits into sampling
                    self.stats["float_retries"] += 1
                    self.ncalls += 1
                    logits, stepped = self._float_step_fn()(
                        self.cache, toks, jnp.asarray(pos, jnp.int32))
            except Exception as e:               # noqa: BLE001 — slots
                self._fail_slots(group, e)       # must never leak
                continue
            # when ONE group covers every live slot (steady state),
            # inactive rows are rewritten before any read, so the masked
            # merge copy would protect nothing — skip it.
            self.cache = (stepped
                          if len(by_grp) == 1 and len(group) == len(live)
                          else self._merge_rows(self.cache, stepped,
                                                group))
            for s in group:
                logits_of[s] = logits
        for s in fed:
            if s not in logits_of:
                continue              # group failed; slot already freed
            req = self.slot_req[s]
            nxt = int(jnp.argmax(logits_of[s][s, -1]))
            if self.slot_fed[s] < len(req.prompt):
                self.slot_fed[s] += 1
                self.slot_pos[s] = self.slot_fed[s]
                if self.slot_fed[s] == len(req.prompt):
                    req._next = nxt
            else:
                req.out.append(nxt)
                self.slot_pos[s] += 1
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.stats["completed"] += 1
                    self.table.free(s)

    def step(self) -> int:
        """One engine iteration; returns the number of requests still
        queued or in flight AFTER the step (0 == drained) — the unified
        drive-loop contract both serve engines share (DESIGN.md §9):
        ``while eng.step(): ...`` serves to completion.

        Order per step: the overload controller observes the
        pre-admission queue depth, expiry runs BEFORE admission (a dead
        queued request is failed without ever being admitted, let alone
        prefilled), admissions made while DEGRADED decode on the
        pre-bound lower-L fallback plan for their whole lifetime, then
        every active slot advances — decoding slots one token,
        prefilling slots up to ``prefill_chunk`` prompt tokens (plus
        their first decode when the prompt completes within the chunk).
        """
        degraded = False
        if self.controller is not None:
            state = self.controller.observe(len(self.queue))
            degraded = state == DegradeController.DEGRADED
        self._expire()
        self._admit(degraded)
        active = self.table.active()
        if not active:
            return self.table.pending()
        # per-slot feed budget this step: decoders advance 1; prefilling
        # slots advance min(remaining, chunk) prompt tokens, +1 decode
        # when that finishes the prompt (matching the legacy per-step
        # visible behaviour for prompts shorter than the chunk)
        chunk = self.prefill_chunk
        budget: Dict[int, int] = {}
        for s in active:
            req = self.slot_req[s]
            rem = len(req.prompt) - self.slot_fed[s]
            if rem > 0:
                n = rem if chunk is None else min(rem, chunk)
                budget[s] = n + (1 if n == rem else 0)
            else:
                budget[s] = 1
        while True:
            fed = [s for s in self.table.active() if budget.get(s, 0) > 0]
            if not fed:
                break
            self._feed_round(fed)
            for s in fed:
                budget[s] -= 1
        return self.table.pending()

    def run(self) -> List[Request]:
        # include requests a prior step() already admitted into slots —
        # snapshotting only the queue would drop them from the result
        all_reqs = [r for r in self.slot_req if r is not None] + \
            list(self.queue)
        while self.table.pending():
            self.step()
        return all_reqs
