"""Multi-tenant CNN serving: several model zoo entries in one process.

The paper's accelerator serves fixed-point CNN inference; a real
deployment rarely dedicates a process per model.  This module runs
several ``models/cnn.MODELS`` entries as TENANTS of one
:class:`MultiTenantServer` — each tenant an independent
``serve.cnn.CnnServeEngine`` (own slot table, queue, deadlines,
degrade state) over a shared serving substrate:

  * **packed cold start** (:func:`cold_start`): a tenant boots from a
    ``bfp_packed`` checkpoint artifact (``checkpoint.store``
    ``format="bfp_packed"``) WITHOUT ever materializing float weights
    for the prequant-eligible sites — the restore template comes from
    ``jax.eval_shape`` over the spec's ``init`` (structure + shapes
    only, no weight init compute), ``restore(..., packed="keep")``
    hands back :class:`~repro.core.packed.PackedBFP` leaves, and
    ``engine.bind`` unpacks those straight into ``{"m", "s"}``
    int8+scale sidecars.  Cold-start cost is the ~4x-smaller packed
    artifact read plus unpack — no f32 weight tree ever exists;
  * **shared trace caches**: ``add_tenant(..., plan=other.plan)`` binds
    a tenant to an EXISTING :class:`~repro.engine.plan.Plan`; both
    engines then dispatch through ``plan.jit_forward(apply_fn)``, whose
    per-(plan, apply_fn) cache means one jit trace per batch-bucket
    shape serves every tenant on that plan (pinned by
    tests/test_tenants.py);
  * **aggregate accounting**: :meth:`MultiTenantServer.stats` merges the
    per-engine counter taxonomy (completed/expired/failed/shed/
    float_retries/degraded_served — DESIGN.md §9) across tenants.

The server steps tenants round-robin; each engine keeps its own
iteration-level batching, so one tenant's long queue never erects a
barrier in front of another tenant's traffic.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import store as CK
from repro.engine.plan import Plan
from repro.models.cnn import MODELS, CnnSpec
from repro.serve.cnn import CnnServeEngine, ImageRequest

__all__ = ["cold_start", "Tenant", "MultiTenantServer"]


def cold_start(model: str, checkpoint_dir: str, *, reduced: bool = True,
               step: Optional[int] = None,
               num_classes: int = 10) -> Any:
    """Load a tenant's params from a ``bfp_packed`` artifact, float-free.

    The restore template is ``jax.eval_shape`` over the registered
    ``init`` — abstract shapes only, so cold start never runs (or
    allocates) the float weight init, and ``packed="keep"`` returns the
    serialized :class:`PackedBFP` containers as-is for ``engine.bind``
    to unpack into sidecars.  Raises ``FileNotFoundError`` when the
    directory holds no valid checkpoint (a silently re-initialized
    tenant would serve garbage logits with perfect uptime).
    """
    spec = MODELS[model]
    template = jax.eval_shape(
        functools.partial(spec.init, reduced=reduced,
                          num_classes=num_classes),
        jax.random.PRNGKey(0))
    params, got = CK.restore(checkpoint_dir, template, step=step,
                             packed="keep")
    if params is None:
        raise FileNotFoundError(
            f"no valid checkpoint for tenant model {model!r} under "
            f"{checkpoint_dir}")
    return params


@dataclasses.dataclass
class Tenant:
    """One served model: a name, its spec, and its engine.

    ``engine.plan`` is the bound execution plan; tenants constructed
    with ``plan=`` share that object (and therefore its jit trace
    cache) with their donor.
    """

    name: str
    model: str
    spec: CnnSpec
    engine: CnnServeEngine

    @property
    def plan(self) -> Plan:
        return self.engine.plan


class MultiTenantServer:
    """Round-robin host for independent per-tenant serve engines.

    Engine-level args (``mesh``/``rules``/``jit``/``clock`` and any
    ``CnnServeEngine`` kwarg) set server-wide defaults at construction;
    ``add_tenant`` may override per tenant.
    """

    def __init__(self, *, clock: Callable[[], float] = time.monotonic,
                 **engine_defaults: Any):
        self._defaults = dict(engine_defaults)
        self._defaults.setdefault("clock", clock)
        self.tenants: Dict[str, Tenant] = {}

    def __getitem__(self, name: str) -> Tenant:
        return self.tenants[name]

    def add_tenant(self, name: str, model: str, *,
                   checkpoint_dir: Optional[str] = None,
                   params: Any = None,
                   policy: Any = None,
                   plan: Optional[Plan] = None,
                   reduced: bool = True,
                   num_classes: int = 10,
                   **engine_kwargs: Any) -> Tenant:
        """Register a tenant serving ``models/cnn.MODELS[model]``.

        Weight source, exactly one of:
          * ``plan=`` — an already-bound Plan (typically another
            tenant's): the engine reuses its params, backend selection,
            AND ``jit_forward`` trace cache — the multi-tenant
            consolidation shape;
          * ``checkpoint_dir=`` — packed cold start via
            :func:`cold_start` (no float materialization);
          * ``params=`` — an in-memory tree (tests, fresh init).

        ``policy`` (BFPPolicy / PolicyMap) applies to the latter two and
        is bound here, once.
        """
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        spec = MODELS[model]
        kw = dict(self._defaults)
        kw.update(engine_kwargs)
        if plan is not None:
            if params is not None or checkpoint_dir is not None:
                raise ValueError("pass plan= alone: the plan's params "
                                 "serve (bind-once, serve-many)")
            eng = CnnServeEngine(None, spec.apply, plan, **kw)
        else:
            if checkpoint_dir is not None:
                if params is not None:
                    raise ValueError("pass either checkpoint_dir= or "
                                     "params=, not both")
                params = cold_start(model, checkpoint_dir,
                                    reduced=reduced,
                                    num_classes=num_classes)
                # packed leaves carry their quantization; a second
                # prequant pass over them is a no-op but float leaves
                # of a packed artifact must stay float
                kw.setdefault("prequant", False)
            eng = CnnServeEngine(params, spec.apply, policy, **kw)
        t = Tenant(name=name, model=model, spec=spec, engine=eng)
        self.tenants[name] = t
        return t

    # -- serving ------------------------------------------------------------

    def submit(self, tenant: str, req: Any = None, *,
               image: Optional[jax.Array] = None) -> ImageRequest:
        """Queue a request on ``tenant`` (typed rejections propagate)."""
        return self.tenants[tenant].engine.submit(req, image=image)

    def step(self) -> int:
        """One round-robin pass — each tenant's engine steps once;
        returns total requests still queued or in flight across tenants
        (the same drive-loop contract as a single engine)."""
        return sum(t.engine.step() for t in self.tenants.values())

    def run(self) -> List[Any]:
        """Drain every tenant; returns the requests that were in flight
        or queued when called (per-tenant snapshot, tenant order)."""
        out: List[Any] = []
        for t in self.tenants.values():
            out.extend(t.engine.table.req[s]
                       for s in t.engine.table.active())
            out.extend(t.engine.table.queue)
        while self.step():
            pass
        return out

    def pending(self) -> int:
        return sum(t.engine.table.pending() for t in self.tenants.values())

    def stats(self) -> Dict[str, Any]:
        """Per-tenant counters plus a cross-tenant ``total`` roll-up."""
        per = {n: dict(t.engine.stats) for n, t in self.tenants.items()}
        total: Dict[str, int] = {}
        for s in per.values():
            for k, v in s.items():
                total[k] = total.get(k, 0) + v
        return {"tenants": per, "total": total}
