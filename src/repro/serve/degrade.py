"""Graceful degradation for the serve engines (DESIGN.md §11.3).

A BFP serving deployment has a failure axis float serving does not: the
mantissa width L is a QUALITY dial (paper Table 3), so under overload the
engine can keep answering by answering slightly worse — re-admit new
requests onto a pre-bound lower-L fallback :class:`~repro.engine.plan.Plan`
instead of queueing unboundedly, then return to the primary plan when
the queue drains.  This module holds the pieces both engines
(``serve.cnn.CnnServeEngine``, ``serve.engine.ServeEngine``) share:

  * typed rejections / request errors (:class:`ServeRejected` tree) —
    shedding and expiry are API results, not stack traces;
  * the :class:`DegradeController` state machine —
    PRIMARY -> (queue depth >= high watermark for ``trip_steps``
    consecutive steps) -> DEGRADED -> (depth <= low watermark for
    ``recover_steps`` steps) -> PRIMARY.  Hysteresis on both edges so a
    queue hovering at the watermark doesn't flap plans (and recompile
    jitted forwards) every step;
  * :func:`float_params` — the float-retry weight tree: prequant
    ``{"m", "s"}`` sidecars and packed containers dequantize to dense
    float32, so a group whose BFP logits come back non-finite (a faulty
    container, an exponent SEU — see ``repro.faults``) can re-run once
    on the float reference datapath.

Deadlines use an injectable monotonic ``clock`` (default
``time.monotonic``); tests drive a fake clock for determinism.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.core import packed as PK
from repro.core import prequant as PQ

__all__ = ["ServeRejected", "QueueOverloaded", "DeadlineExceeded",
           "RequestTooLarge", "DegradeConfig", "DegradeController",
           "float_params"]


class ServeRejected(RuntimeError):
    """Base of every typed serving rejection.

    Carries the request id (``rid``) so a caller multiplexing many
    requests can attribute the rejection without parsing the message.
    """

    def __init__(self, msg: str, rid: Optional[int] = None):
        super().__init__(msg)
        self.rid = rid


class QueueOverloaded(ServeRejected):
    """Submission shed: the engine queue is at its depth limit.

    Raised by ``submit`` — the request was never enqueued; the client
    owns retry/backoff.  Shedding at the door keeps the queue (and the
    deadline miss rate of ALREADY-accepted requests) bounded.
    """


class DeadlineExceeded(ServeRejected):
    """The request's deadline passed before its logits were produced.

    Delivered as ``req.error`` (the request completes exceptionally,
    freeing its slot) — never raised through the engine's step loop.
    """


class RequestTooLarge(ServeRejected):
    """The request cannot fit the engine's cache geometry.

    Raised by ``submit`` when ``len(prompt) + max_new > max_len``: the
    decode loop would write cache positions past ``max_len``, and JAX
    CLAMPS/DROPS out-of-bounds ``.at[].set`` writes instead of raising —
    the request would silently decode from a corrupt cache.  Rejecting
    at the door is the only honest answer (the request was never
    enqueued; resubmit with a shorter prompt or smaller ``max_new``).
    """


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Watermarks and hysteresis for :class:`DegradeController`.

    Defaults trip after 2 consecutive overloaded steps and recover after
    2 consecutive drained steps; ``queue_high`` must be set per engine
    (a sensible choice is a small multiple of the slot count).
    """

    queue_high: int = 8       #: depth >= this counts as an overloaded step
    queue_low: int = 0        #: depth <= this counts as a drained step
    trip_steps: int = 2       #: consecutive overloaded steps to degrade
    recover_steps: int = 2    #: consecutive drained steps to recover

    def __post_init__(self):
        if self.queue_high < 1:
            raise ValueError(f"queue_high must be >= 1, got "
                             f"{self.queue_high}")
        if not 0 <= self.queue_low < self.queue_high:
            raise ValueError(f"need 0 <= queue_low < queue_high, got "
                             f"{self.queue_low} / {self.queue_high}")
        if self.trip_steps < 1 or self.recover_steps < 1:
            raise ValueError("trip_steps and recover_steps must be >= 1")


class DegradeController:
    """Hysteretic two-state (PRIMARY / DEGRADED) admission controller.

    ``observe(queue_depth)`` is called once per engine step with the
    depth BEFORE admission; it returns the state new admissions should
    use.  Transitions are counted (``trips`` / ``recoveries``) for the
    serving report.
    """

    PRIMARY = "primary"
    DEGRADED = "degraded"

    def __init__(self, cfg: DegradeConfig):
        self.cfg = cfg
        self.state = self.PRIMARY
        self.trips = 0
        self.recoveries = 0
        self._over = 0     # consecutive steps at/above the high watermark
        self._under = 0    # consecutive steps at/below the low watermark

    @property
    def degraded(self) -> bool:
        return self.state == self.DEGRADED

    def observe(self, queue_depth: int) -> str:
        if self.state == self.PRIMARY:
            self._over = self._over + 1 if queue_depth >= \
                self.cfg.queue_high else 0
            if self._over >= self.cfg.trip_steps:
                self.state = self.DEGRADED
                self.trips += 1
                self._over = 0
        else:
            self._under = self._under + 1 if queue_depth <= \
                self.cfg.queue_low else 0
            if self._under >= self.cfg.recover_steps:
                self.state = self.PRIMARY
                self.recoveries += 1
                self._under = 0
        return self.state


def float_params(params: Any) -> Any:
    """Materialize a serving param tree back to dense float weights.

    Prequant ``{"m", "s"}`` sidecars (including conv HWIO mantissas with
    GEMM-view scales) and :class:`~repro.core.packed.PackedBFP` leaves
    dequantize; float leaves pass through.  This is the weight tree the
    non-finite-logits retry runs with ``policy=None`` — the float
    reference of EXACTLY the weights the BFP path was serving (the
    quantized values, not the original checkpoint: the retry isolates
    datapath blow-ups, it does not un-round the weights).
    """
    import jax

    def one(leaf):
        if PK.is_packed(leaf):
            return PK.unpack_dequant(leaf)
        if PQ.is_prequant(leaf):
            m, s = leaf["m"], leaf["s"]
            if m.ndim == 4 and s.ndim == 2:      # conv HWIO mantissa
                kh, kw, c, n = m.shape
                d = PQ.dequantize_prequant({"m": m.reshape(kh * kw * c, n),
                                            "s": s})
                return d.reshape(kh, kw, c, n).astype(jnp.float32)
            return PQ.dequantize_prequant(leaf)
        return leaf

    return jax.tree_util.tree_map(
        one, params,
        is_leaf=lambda x: PK.is_packed(x) or PQ.is_prequant(x))
