"""Open-loop synthetic load for the serve engines (DESIGN.md §9).

Closed-loop drivers (submit, drain, repeat) can never see a bucket
barrier: the offered load adapts to the server, so queueing delay hides
inside the driver.  This module generates OPEN-LOOP traffic — arrivals
fire at their scheduled times whether or not the server kept up, the
standard methodology for tail-latency measurement — and drives an engine
through it on a virtual clock:

  * :func:`poisson_arrivals` — a seeded Poisson process (exponential
    inter-arrival gaps) over a weighted mix of request kinds, each kind
    carrying its own payload shape and relative deadline.  Deterministic
    given ``seed``: the pinned ``BENCH_serve.json`` trajectory replays
    the exact same trace on any machine;
  * :class:`VirtualClock` — the injectable engine clock the driver owns.
    Time advances by ``call_cost`` per jitted engine call
    (``engine.ncalls``, one whole-batch decode_step / batched CNN
    forward = one unit of accelerator occupancy — machine-independent),
    or by measured wall time when ``call_cost=None``.  A step that
    issues NO calls (a bucket-mode deferral, an empty table) idles the
    server: the clock jumps to the next arrival, which is exactly how a
    barrier turns idle hope into tail latency;
  * :func:`run_open_loop` — submits due arrivals, steps the engine,
    collects completions, and folds everything into a :class:`LoadReport`
    (p50/p99/mean latency, goodput, shed/expired/failed counts, degraded
    service) whose :meth:`~LoadReport.row` is the ``BENCH_serve.json``
    record body.

The engine must be constructed with ``clock=<the VirtualClock>`` so
deadline expiry sees the same timeline the driver advances.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.degrade import DeadlineExceeded, ServeRejected

__all__ = ["Arrival", "poisson_arrivals", "VirtualClock", "LoadReport",
           "run_open_loop"]


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: WHEN it fires and WHAT shape it is.

    ``payload`` is the kind's free-form shape description (prompt
    length, image index, ...) consumed by the benchmark's request
    factory; ``deadline`` is RELATIVE to ``t`` (None = no deadline).
    """

    t: float
    rid: int
    kind: str
    payload: Dict[str, Any]
    deadline: Optional[float] = None


def poisson_arrivals(rate: float, n: int,
                     mix: Sequence[Tuple[float, str, Dict[str, Any]]],
                     *, seed: int = 0,
                     start: float = 0.0) -> List[Arrival]:
    """``n`` Poisson arrivals at ``rate`` per unit time over a kind mix.

    ``mix`` rows are ``(weight, kind, payload)``; a payload may carry a
    ``"deadline"`` key (relative seconds) which is lifted onto the
    :class:`Arrival`.  Sampling is ``numpy.random.RandomState(seed)`` —
    fully deterministic, so a pinned benchmark replays bit-identical
    traffic anywhere.
    """
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if not mix:
        raise ValueError("mix must be non-empty")
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    ts = start + np.cumsum(gaps)
    weights = np.asarray([w for w, _, _ in mix], dtype=np.float64)
    weights = weights / weights.sum()
    picks = rng.choice(len(mix), size=n, p=weights)
    out: List[Arrival] = []
    for i in range(n):
        _, kind, payload = mix[int(picks[i])]
        payload = dict(payload)
        deadline = payload.pop("deadline", None)
        out.append(Arrival(t=float(ts[i]), rid=i, kind=kind,
                           payload=payload, deadline=deadline))
    return out


class VirtualClock:
    """A monotonic clock the load driver owns (inject as ``clock=``)."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self.t += dt


@dataclasses.dataclass
class LoadReport:
    """Everything one open-loop run says about a serving configuration.

    ``goodput_rps`` counts only requests that completed SUCCESSFULLY
    (shed, expired, and failed ones all consumed capacity without
    producing an answer — that is the overload story the report exists
    to tell), per unit of virtual time.
    """

    offered: int
    completed: int
    shed: int
    expired: int
    failed: int
    degraded_served: int
    float_retries: int
    p50_ms: float
    p99_ms: float
    mean_ms: float
    duration_s: float
    goodput_rps: float
    steps: int
    calls: int
    #: per-request-kind latency/outcome breakdown — the aggregate p99
    #: of a mixed workload is owned by its slowest kind, so the
    #: scheduling question ("who pays for the barrier?") needs the
    #: split: {"short": {"completed", "expired", "p50_ms", "p99_ms",
    #: "mean_ms"}, ...}
    kinds: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def row(self) -> Dict[str, Any]:
        """JSON-safe dict — the ``BENCH_serve.json`` record body."""
        def clean(v):
            if isinstance(v, float):
                return round(v, 6)
            if isinstance(v, dict):
                return {k: clean(x) for k, x in v.items()}
            return v

        return {k: clean(v) for k, v in
                dataclasses.asdict(self).items()}


def run_open_loop(engine: Any, arrivals: Sequence[Arrival],
                  make_request: Callable[[Arrival], Any],
                  *, clock: VirtualClock,
                  call_cost: Optional[float] = None,
                  timer: Callable[[], float] = time.perf_counter,
                  max_steps: int = 1_000_000) -> LoadReport:
    """Drive ``engine`` through ``arrivals`` open-loop; returns the report.

    ``make_request(arrival)`` builds the engine's request object — it
    must set the ABSOLUTE deadline (``arrival.t + arrival.deadline``)
    itself, and the engine must share this ``clock``.  ``call_cost``
    switches the timeline to deterministic virtual time (seconds per
    ``engine.ncalls`` unit); None measures wall time per step, for
    real-machine numbers.  Works with any engine exposing
    ``submit`` / ``step`` / ``table.pending()`` / ``ncalls`` / ``stats``
    — both serve engines and :class:`~repro.serve.tenants
    .MultiTenantServer` tenants qualify.
    """
    todo = deque(sorted(arrivals, key=lambda a: a.t))
    offered = len(todo)
    inflight: List[Tuple[Arrival, Any]] = []
    lat: List[float] = []
    by_kind: Dict[str, List[float]] = {}
    exp_kind: Dict[str, int] = {}
    shed = expired = failed = steps = 0
    calls0 = engine.ncalls
    t0 = clock.t
    while todo or engine.table.pending():
        while todo and todo[0].t <= clock.t:
            a = todo.popleft()
            try:
                req = make_request(a)
                engine.submit(req)
                inflight.append((a, req))
            except ServeRejected:
                shed += 1
        if not engine.table.pending():
            if not todo:
                break
            # server idle, future arrivals pending: jump to the next one
            clock.t = max(clock.t, todo[0].t)
            continue
        c0 = engine.ncalls
        w0 = timer()
        engine.step()
        steps += 1
        dcalls = engine.ncalls - c0
        if dcalls == 0:
            # no accelerator work issued (bucket-mode deferral): the
            # server sits idle until traffic moves it — model that as a
            # jump to the next arrival, the latency cost of a barrier
            if todo:
                clock.t = max(clock.t, todo[0].t)
        elif call_cost is not None:
            clock.advance(dcalls * call_cost)
        else:
            clock.advance(max(0.0, timer() - w0))
        still: List[Tuple[Arrival, Any]] = []
        for a, r in inflight:
            if not r.done:
                still.append((a, r))
            elif r.error is None:
                lat.append(clock.t - a.t)
                by_kind.setdefault(a.kind, []).append(clock.t - a.t)
            elif isinstance(r.error, DeadlineExceeded):
                expired += 1
                exp_kind[a.kind] = exp_kind.get(a.kind, 0) + 1
            else:
                failed += 1
        inflight = still
        if steps >= max_steps:
            raise RuntimeError(f"load run exceeded {max_steps} steps "
                               f"({len(inflight)} in flight, "
                               f"{len(todo)} arrivals to go)")
    duration = max(clock.t - t0, 1e-9)
    arr = np.asarray(lat) if lat else np.zeros((0,))
    kinds: Dict[str, Dict[str, float]] = {}
    for k in sorted(set(by_kind) | set(exp_kind)):
        ks = np.asarray(by_kind.get(k, []))
        kinds[k] = {
            "completed": int(ks.size),
            "expired": exp_kind.get(k, 0),
            "p50_ms": float(np.percentile(ks, 50) * 1e3) if ks.size
            else 0.0,
            "p99_ms": float(np.percentile(ks, 99) * 1e3) if ks.size
            else 0.0,
            "mean_ms": float(ks.mean() * 1e3) if ks.size else 0.0,
        }
    return LoadReport(
        offered=offered, completed=len(lat), shed=shed, expired=expired,
        failed=failed,
        degraded_served=engine.stats.get("degraded_served", 0),
        float_retries=engine.stats.get("float_retries", 0),
        p50_ms=float(np.percentile(arr, 50) * 1e3) if lat else 0.0,
        p99_ms=float(np.percentile(arr, 99) * 1e3) if lat else 0.0,
        mean_ms=float(arr.mean() * 1e3) if lat else 0.0,
        duration_s=float(duration),
        goodput_rps=len(lat) / duration,
        steps=steps, calls=engine.ncalls - calls0, kinds=kinds)
