"""Shared slot-table machinery for the batched serve engines.

A :class:`SlotTable` is the Python-side bookkeeping of iteration-level
(continuous) batching (DESIGN.md §7.1 / §9): a fixed number of
shape-stable slots, a FIFO queue of submitted requests, admission of
queued requests into free slots, and immediate slot reuse when a request
finishes.  The jitted step functions stay whole-batch and shape-stable;
this table only decides WHICH rows are live.  Both serve engines share
it — ``serve.engine.ServeEngine`` (LM decode, where prefill is chunked
into the step loop) and ``serve.cnn.CnnServeEngine`` (batched CNN
inference, where every admitted request completes in one forward).
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Deque, List, Optional, Tuple

__all__ = ["SlotTable"]


class SlotTable:
    """Fixed-size request staging: ``req[s] is None`` == slot ``s`` free.

    ``req`` (a plain list) and ``queue`` (a :class:`collections.deque` —
    the FIFO drain is O(1) per admission, where ``list.pop(0)`` was O(n)
    and made a deep-queue drain O(n²) under load) are mutable on
    purpose: engines alias them (``self.slot_req = table.req``,
    ``self.queue = table.queue``) so row-level bookkeeping keeps working
    against the shared state.  Code that used to filter the queue with
    slice assignment must use :meth:`retain` (deques don't slice).
    """

    def __init__(self, slots: int):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.slots = slots
        self.req: List[Optional[Any]] = [None] * slots
        self.queue: Deque[Any] = deque()

    def submit(self, req: Any) -> None:
        self.queue.append(req)

    def retain(self, keep: Callable[[Any], bool]) -> List[Any]:
        """Drop queued requests failing ``keep`` (IN PLACE, preserving
        order and the ``queue`` alias); returns the dropped ones."""
        dropped = [r for r in self.queue if not keep(r)]
        if dropped:
            kept = [r for r in self.queue if keep(r)]
            self.queue.clear()
            self.queue.extend(kept)
        return dropped

    def admit_one(self) -> Optional[Tuple[int, Any]]:
        """Admit ONE queued request into the lowest free slot.

        Returns ``(slot, request)`` or None when the queue is empty or
        every slot is occupied.  Engines that do per-admission work (the
        LM engine's cache-row reset) interleave it between ``admit_one``
        calls, preserving admission-order semantics.
        """
        if not self.queue:
            return None
        for s in range(self.slots):
            if self.req[s] is None:
                r = self.queue.popleft()
                self.req[s] = r
                return s, r
        return None

    def admit(self) -> List[int]:
        """Fill every free slot from the queue; newly admitted slot ids."""
        out: List[int] = []
        while (adm := self.admit_one()) is not None:
            out.append(adm[0])
        return out

    def free(self, s: int) -> None:
        self.req[s] = None

    def active(self) -> List[int]:
        return [s for s in range(self.slots) if self.req[s] is not None]

    def pending(self) -> int:
        """Number of queued + in-flight requests (0 == drained; truthy
        while work remains, so ``while table.pending():`` still drives)."""
        return len(self.queue) + sum(r is not None for r in self.req)
