"""Shared slot-table machinery for the batched serve engines.

A :class:`SlotTable` is the Python-side bookkeeping of
continuous-batching-lite (DESIGN.md §7.1 / §9): a fixed number of
shape-stable slots, a FIFO queue of submitted requests, admission of
queued requests into free slots, and immediate slot reuse when a request
finishes.  The jitted step functions stay whole-batch and shape-stable;
this table only decides WHICH rows are live.  Both serve engines share
it — ``serve.engine.ServeEngine`` (LM decode, where admission interleaves
per-slot prefill) and ``serve.cnn.CnnServeEngine`` (batched CNN
inference, where admission is wholesale and every admitted request
completes in one bucketed forward).
"""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

__all__ = ["SlotTable"]


class SlotTable:
    """Fixed-size request staging: ``req[s] is None`` == slot ``s`` free.

    ``req`` and ``queue`` are plain lists on purpose — engines alias them
    (``self.slot_req = table.req``) so existing row-level bookkeeping
    keeps working against the shared state.
    """

    def __init__(self, slots: int):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.slots = slots
        self.req: List[Optional[Any]] = [None] * slots
        self.queue: List[Any] = []

    def submit(self, req: Any) -> None:
        self.queue.append(req)

    def admit_one(self) -> Optional[Tuple[int, Any]]:
        """Admit ONE queued request into the lowest free slot.

        Returns ``(slot, request)`` or None when the queue is empty or
        every slot is occupied.  Engines that do per-admission work (the
        LM engine's masked per-slot prefill) interleave it between
        ``admit_one`` calls, preserving admission-order semantics.
        """
        if not self.queue:
            return None
        for s in range(self.slots):
            if self.req[s] is None:
                r = self.queue.pop(0)
                self.req[s] = r
                return s, r
        return None

    def admit(self) -> List[int]:
        """Fill every free slot from the queue; newly admitted slot ids."""
        out: List[int] = []
        while (adm := self.admit_one()) is not None:
            out.append(adm[0])
        return out

    def free(self, s: int) -> None:
        self.req[s] = None

    def active(self) -> List[int]:
        return [s for s in range(self.slots) if self.req[s] is not None]

    def pending(self) -> bool:
        """True while queued or in-flight work remains."""
        return bool(self.queue) or any(r is not None for r in self.req)
