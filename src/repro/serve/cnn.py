"""Batched CNN inference service on sharded BFP plans (DESIGN.md §9).

The paper's workload is a CNN *accelerator* serving fixed-point
inference; this module gives the four paper models (and anything with
the ``apply(params, x, policy)`` convention) the same deployment path
the LM decode engine has:

  * a shape-stable slot table (``serve.slots.SlotTable``, the
    continuous-batching-lite bookkeeping shared with ``ServeEngine``):
    image requests admit into free slots, finished slots free
    immediately for the next queued request;
  * iteration-level batching over batch buckets: each step stacks
    whatever slots are active into the smallest configured batch bucket
    (padding with duplicates of a live image — logits-neutral for any
    weights), so the jitted forward compiles once per bucket, not once
    per request count, and a partially-filled step RUNS instead of
    waiting behind a bucket barrier (``batching="bucket"`` keeps the
    barrier — defer until ``buckets[-1]`` slots are active or
    ``max_wait`` deferred steps elapse — as the measured baseline for
    ``benchmarks/serve_load.py``);
  * a bind-once ``engine.Plan``: policy resolution, backend selection,
    and weight pre-quantization happen at admission-time construction
    (``strict_backend=True`` rejects undeployable configs HERE);
    ``Plan.jit_forward`` means N engines bound to one plan share one
    traced forward per bucket shape;
  * data-parallel batch sharding through ``dist.sharding.axis_rules``
    + a ``launch.mesh`` mesh: the stacked batch is annotated
    ``("batch", None, None, None)`` before the forward, so the SAME
    code path runs 1-device in tier-1 tests (identity / trivial mesh)
    and N-device in production.

Bit-exactness contract (pinned by tests/test_serve_cnn.py through
``engine.taps`` events): a request served through the engine produces
exactly the logits of a direct ``apply(plan.params, batch, plan)`` on
the same rows.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine as EG
from repro.dist import sharding as DS
from repro.engine import PolicyLike
from repro.engine.backends import BackendUnsupportedError
from repro.engine.plan import Plan
from repro.serve.degrade import (DeadlineExceeded, DegradeConfig,
                                 DegradeController, QueueOverloaded,
                                 float_params)
from repro.serve.slots import SlotTable

__all__ = ["ImageRequest", "CnnServeEngine", "default_buckets"]

#: logical axes of an NHWC image batch — only the batch axis shards
#: (pure data parallelism; DEFAULT_RULES maps "batch" -> "data")
_BATCH_AXES = ("batch", None, None, None)


@dataclasses.dataclass
class ImageRequest:
    """One classification request: an [H, W, C] image in, logits out.

    ``deadline`` is an absolute value of the engine's monotonic clock;
    a request that has not produced logits by then completes
    exceptionally (``error`` = :class:`DeadlineExceeded`).  ``error``
    is set (and ``logits`` stays None) whenever the request failed —
    deadline expiry or a forward that raised.  ``degraded`` reports
    which plan served it (True = the lower-L fallback plan).
    """

    rid: int
    image: jax.Array
    logits: Optional[np.ndarray] = None
    label: Optional[int] = None
    done: bool = False
    deadline: Optional[float] = None
    error: Optional[BaseException] = None
    degraded: bool = False


def default_buckets(slots: int) -> Tuple[int, ...]:
    """Powers of two up to ``slots`` (plus ``slots`` itself): 8 -> (1, 2,
    4, 8), 6 -> (1, 2, 4, 6).  One jit compilation per bucket."""
    out: List[int] = []
    b = 1
    while b < slots:
        out.append(b)
        b *= 2
    out.append(slots)
    return tuple(out)


class CnnServeEngine:
    """Slot-table batched CNN server over a bound execution plan.

    Args:
      params: param tree (``models.cnn`` conventions) — float, already
        pre-quantized ``{"m", "s"}``, or a packed artifact holding
        ``PackedBFP`` leaves (``checkpoint.store.restore(...,
        packed="keep")``): ``engine.bind`` unpacks those straight into
        sidecars, so serving loads the ~4x-smaller checkpoint without
        ever materializing float weights for prequant-eligible sites.
        Ignored when ``policy`` is already a bound :class:`engine.Plan`
        — pass ``None`` and reuse the plan's pre-quantized params (that
        is the multi-engine deployment shape: bind once, serve many).
      apply_fn: ``apply_fn(params, x, policy)`` -> logits, or a tuple of
        heads (GoogLeNet) — head 0 is taken as the classifier output.
      policy: None / BFPPolicy / PolicyMap (bound here via
        ``engine.bind``) or an existing ``Plan`` (reused as-is).
      slots: size of the admission slot table (max requests in flight).
      buckets: ascending batch-bucket sizes; each step pads the active
        group up to the smallest fitting bucket.  Default:
        ``default_buckets(slots)``.
      prequant: pre-quantize eligible weight leaves at bind time (the
        paper's deployment mode).  Ignored when ``policy`` is a Plan.
      strict_backend: refuse (raise) backend downgrades at construction
        instead of warn-once — an undeployable serving config fails at
        admission, not mid-traffic.  With a pre-bound Plan this verifies
        the plan carries no downgraded (fallback) sites.
      mesh / rules: optional ``launch.mesh`` mesh + logical-axis rules
        (default ``dist.sharding.DEFAULT_RULES``); when given, every
        forward runs under ``axis_rules`` with the batch axis sharded.
      jit: jit the bound forward (shared across engines via
        ``Plan.jit_forward``).  ``jit=False`` runs eagerly — slower,
        but ``engine.taps`` observers see every GEMM/conv site (taps
        are suppressed under jit tracing), which is how the
        bit-exactness regression pins this engine to the direct path.
      max_queue: queue depth limit; ``submit`` beyond it raises the
        typed :class:`~repro.serve.degrade.QueueOverloaded` (the request
        is never enqueued).  None = unbounded (the historical behavior).
      fallback_policy: a lower-L policy (or pre-bound Plan) to serve new
        admissions with while overloaded — bound ONCE here, so the
        degraded path never binds mid-traffic.  Requires ``params``
        unless a Plan is passed.  None disables degraded mode.
      degrade: watermarks/hysteresis for the overload state machine
        (default ``DegradeConfig(queue_high=slots)`` when
        ``fallback_policy`` is set).
      float_retry: when a group's logits come back non-finite, re-run
        that group ONCE on the float reference (the serving plan's
        weights dequantized, ``policy=None``) before reporting — a
        blown-up BFP datapath (exponent SEU, corrupted container)
        degrades to float numerics instead of returning NaNs.
      batching: ``"continuous"`` (default) runs partially-filled steps
        immediately — iteration-level batching, no bucket barrier.
        ``"bucket"`` is the barrier baseline: a step with fewer than
        ``buckets[-1]`` active slots defers its forward (up to
        ``max_wait`` consecutive deferred steps, so a trickle of
        requests still completes) hoping more arrivals fill the bucket.
      max_wait: bucket-mode flush bound — after this many consecutive
        deferred steps the partial batch runs anyway.  Ignored in
        continuous mode.
      clock: monotonic clock for deadlines (injectable for tests).
    """

    def __init__(self, params: Any, apply_fn: Callable[..., Any],
                 policy: PolicyLike = None, *, slots: int = 8,
                 buckets: Optional[Sequence[int]] = None,
                 prequant: bool = True, strict_backend: bool = False,
                 mesh=None, rules: Optional[Dict[str, Any]] = None,
                 jit: bool = True, max_queue: Optional[int] = None,
                 fallback_policy: PolicyLike = None,
                 degrade: Optional[DegradeConfig] = None,
                 float_retry: bool = True,
                 batching: str = "continuous", max_wait: int = 4,
                 clock: Callable[[], float] = time.monotonic):
        if batching not in ("continuous", "bucket"):
            raise ValueError(f"batching must be 'continuous' or 'bucket', "
                             f"got {batching!r}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.batching = batching
        self.max_wait = max_wait
        self._waited = 0   # consecutive bucket-mode deferred steps
        if isinstance(policy, Plan):
            # bind-once reuse across engines: the plan's params serve,
            # and its backend selection is already fixed — enforce the
            # documented contract instead of silently ignoring args
            if params is not None:
                raise ValueError("pass params=None when policy is a "
                                 "bound Plan (the plan's params serve)")
            if strict_backend:
                bad = sorted(s.path for s in policy.sites.values()
                             if s.fallback)
                if bad:
                    raise BackendUnsupportedError(
                        f"strict_backend: plan carries downgraded sites "
                        f"{bad}; rebind with engine.bind(..., strict=True)")
            self.plan = policy
        else:
            self.plan = EG.bind(params, policy, tree="cnn",
                                strict=strict_backend,
                                prequantize=prequant)
        self.apply_fn = apply_fn
        self.table = SlotTable(slots)
        self.buckets = (tuple(sorted(buckets)) if buckets
                        else default_buckets(slots))
        if self.buckets[-1] < 1:
            raise ValueError(f"bad buckets {self.buckets}")
        self.mesh = mesh
        self.rules = dict(rules) if rules is not None \
            else dict(DS.DEFAULT_RULES)
        self._jit = jit
        self._fwd = self._make_fwd(self.plan)
        self._shape: Optional[Tuple[int, ...]] = None
        self._next_rid = 0
        # -- graceful degradation state ---------------------------------
        self.max_queue = max_queue
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self._clock = clock
        self._float_retry = float_retry
        self._float_fwds: Dict[bool, Callable[..., Any]] = {}
        if fallback_policy is not None:
            if isinstance(fallback_policy, Plan):
                self.fallback_plan: Optional[Plan] = fallback_policy
            else:
                if params is None:
                    raise ValueError(
                        "fallback_policy needs params to bind against; "
                        "pass a pre-bound Plan when reusing policy=Plan")
                self.fallback_plan = EG.bind(params, fallback_policy,
                                             tree="cnn",
                                             strict=strict_backend,
                                             prequantize=prequant)
            self._fb_fwd = self._make_fwd(self.fallback_plan)
            self.controller: Optional[DegradeController] = \
                DegradeController(degrade or DegradeConfig(
                    queue_high=slots))
        else:
            self.fallback_plan = None
            self._fb_fwd = None
            self.controller = (DegradeController(degrade)
                               if degrade is not None else None)
        #: serving counters — the shared taxonomy (DESIGN.md §9): every
        #: request ends in exactly one of completed/expired/failed
        #: (shed requests were never enqueued); float_retries and
        #: degraded_served tag HOW completions were served
        self.stats: Dict[str, int] = {"shed": 0, "expired": 0,
                                      "failed": 0, "completed": 0,
                                      "float_retries": 0,
                                      "degraded_served": 0}
        #: total batched forwards issued (retries included) — the load
        #: harness's machine-independent virtual-time unit
        #: (serve.load ``call_cost``)
        self.ncalls = 0

    def _make_fwd(self, plan: Plan) -> Callable[..., Any]:
        if self._jit:
            return plan.jit_forward(self.apply_fn)
        return lambda x: self.apply_fn(plan.params, x, plan)

    # -- admission ----------------------------------------------------------

    def submit(self, req: Any = None, *, image: Optional[jax.Array] = None
               ) -> ImageRequest:
        """Queue a request (or wrap a bare ``image=`` into one).

        All images must share one [H, W, C] shape — the slot table is
        shape-stable by construction.  With ``max_queue`` set, a full
        queue sheds the submission with the typed
        :class:`~repro.serve.degrade.QueueOverloaded` instead of
        queueing unboundedly.
        """
        if req is None:
            if image is None:
                raise ValueError("pass a request or image=")
            req = ImageRequest(rid=self._next_rid, image=image)
        if self.max_queue is not None and \
                len(self.table.queue) >= self.max_queue:
            self.stats["shed"] += 1
            raise QueueOverloaded(
                f"queue depth {len(self.table.queue)} at limit "
                f"{self.max_queue}; request {req.rid} shed", rid=req.rid)
        self._next_rid = max(self._next_rid, req.rid) + 1
        img = req.image
        if getattr(img, "ndim", 0) != 3:
            raise ValueError(f"image must be [H, W, C], got "
                             f"{getattr(img, 'shape', None)}")
        if self._shape is None:
            self._shape = tuple(img.shape)
        elif tuple(img.shape) != self._shape:
            raise ValueError(f"image shape {tuple(img.shape)} != engine "
                             f"shape {self._shape} (slot table is "
                             f"shape-stable)")
        self.table.submit(req)
        return req

    # -- serving ------------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _sharding_ctx(self):
        return (DS.axis_rules(self.rules, self.mesh)
                if self.mesh is not None else contextlib.nullcontext())

    def _float_fwd(self, degraded: bool) -> Callable[..., Any]:
        """Lazily built float-reference forward of the serving plan's
        own (quantized) weights — the non-finite-logits retry path."""
        fwd = self._float_fwds.get(degraded)
        if fwd is None:
            plan = self.fallback_plan if degraded else self.plan
            tree = float_params(plan.params)
            fn = self.apply_fn

            def eager(x, _t=tree):
                return fn(_t, x, None)

            fwd = jax.jit(eager) if self._jit else eager
            self._float_fwds[degraded] = fwd
        return fwd

    def _fail_group(self, group: List[int], reqs: List[ImageRequest],
                    exc: BaseException) -> None:
        """Complete every request of a failed group exceptionally and
        free its slot — a raising forward must never leak slots (the
        table would otherwise fill with zombies and admission would
        stall forever)."""
        for s, r in zip(group, reqs):
            r.error = exc
            r.done = True
            self.stats["failed"] += 1
            self.table.free(s)

    def _expire(self) -> None:
        """Fail every queued or admitted request whose deadline passed.

        Runs BEFORE admission in :meth:`step`: a dead queued request
        must never occupy a slot (or pad out a forward) only to be
        failed afterwards.
        """
        now = self._clock()

        def dead(r):
            return r.deadline is not None and now > r.deadline

        expired_q = self.table.retain(lambda r: not dead(r))
        for s in self.table.active():
            r = self.table.req[s]
            if dead(r):
                expired_q.append(r)
                self.table.free(s)
        for r in expired_q:
            r.error = DeadlineExceeded(
                f"request {r.rid} missed deadline {r.deadline}", rid=r.rid)
            r.done = True
            self.stats["expired"] += 1

    def _run_group(self, group: List[int], degraded: bool = False) -> None:
        reqs = [self.table.req[s] for s in group]
        bucket = self._bucket_for(len(reqs))
        imgs = [r.image for r in reqs]
        if len(imgs) < bucket:
            # pad with a DUPLICATE of a live image: rows are processed
            # independently by every conv/GEMM, so a duplicate row's
            # activations equal its original's at every layer and can
            # never raise a shared block max above the live rows' own —
            # logits-neutral for ANY weights.  (A zero image is only
            # neutral while zero rows STAY zero, i.e. zero biases/BN
            # shifts; a trained model's bias pattern could otherwise own
            # an EQ2/EQ4 whole-matrix exponent from layer 2 on.)
            imgs = imgs + [imgs[0]] * (bucket - len(imgs))
        try:
            x = jnp.stack(imgs)
            self.ncalls += 1
            with self._sharding_ctx():
                x = DS.shard(x, *_BATCH_AXES)
                out = (self._fb_fwd if degraded else self._fwd)(x)
            logits = out[0] if isinstance(out, (tuple, list)) else out
            logits = np.asarray(logits)
            if self._float_retry and \
                    not np.all(np.isfinite(logits[:len(reqs)])):
                # one retry on the float reference of the SAME weights:
                # isolates a blown-up BFP datapath (exponent SEU, bad
                # container) from a genuinely divergent model
                self.stats["float_retries"] += 1
                self.ncalls += 1
                with self._sharding_ctx():
                    out = self._float_fwd(degraded)(x)
                logits = out[0] if isinstance(out, (tuple, list)) else out
                logits = np.asarray(logits)
        except Exception as e:                    # noqa: BLE001 — slots
            self._fail_group(group, reqs, e)      # must never leak
            return
        for i, (s, r) in enumerate(zip(group, reqs)):
            r.logits = logits[i]
            r.label = int(np.argmax(logits[i]))
            r.done = True
            r.degraded = degraded
            self.stats["completed"] += 1
            if degraded:
                self.stats["degraded_served"] += 1
            self.table.free(s)

    def step(self) -> int:
        """One engine iteration; returns the number of requests still
        queued or in flight AFTER the step (0 == drained) — the unified
        drive-loop contract both serve engines share (DESIGN.md §9):
        ``while eng.step(): ...`` serves to completion.  Completions are
        counted in ``stats["completed"]``, not the return value.

        Order per step: the controller observes the pre-admission queue
        depth, expiry runs BEFORE admission (a dead queued request is
        failed without ever occupying a slot), then the active slots run
        — immediately in continuous mode (partially-filled steps pad up
        to the smallest fitting bucket), or behind the bucket barrier in
        ``batching="bucket"`` (defer the forward until ``buckets[-1]``
        slots are active or ``max_wait`` consecutive deferred steps
        elapse).  While DEGRADED every admission of this step is tagged
        for (and served by) the pre-bound lower-L fallback plan.
        """
        degraded = False
        if self.controller is not None:
            state = self.controller.observe(len(self.table.queue))
            degraded = (state == DegradeController.DEGRADED and
                        self._fb_fwd is not None)
        self._expire()
        self.table.admit()
        active = self.table.active()
        if not active:
            return self.table.pending()
        cap = self.buckets[-1]
        if self.batching == "bucket" and len(active) < cap and \
                self._waited < self.max_wait:
            # bucket barrier: hold the partial batch hoping arrivals
            # fill it — exactly the p99 stall continuous mode removes
            self._waited += 1
            return self.table.pending()
        self._waited = 0
        for i in range(0, len(active), cap):
            self._run_group(active[i:i + cap], degraded=degraded)
        return self.table.pending()

    def run(self) -> List[Any]:
        """Drain the queue; returns the requests still in flight or
        queued when called.  Requests a prior step() already COMPLETED
        are not re-reported — keep your own list (as launch.serve_cnn
        does) when accounting across manual step() calls."""
        all_reqs = [self.table.req[s] for s in self.table.active()] + \
            list(self.table.queue)
        while self.table.pending():
            self.step()
        return all_reqs
