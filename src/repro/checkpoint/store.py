"""Checkpointing: atomic, resumable, mesh-agnostic, async-capable.

Layout:  <dir>/step_<N>/
           manifest.json       tree structure + shapes/dtypes + status
           arrays.npz          flat leaves (logical, unsharded)

Fault-tolerance properties (DESIGN.md §5):
  * atomic: written to step_<N>.tmp, fsynced, renamed -> a crash never
    leaves a half checkpoint that restore() would pick up;
  * manifest carries a payload checksum -> torn writes are detected and
    the previous step is used instead;
  * mesh-agnostic: leaves are stored unsharded; ``restore(..., mesh,
    sharding_fn)`` re-device_puts onto ANY mesh shape (elastic restart on
    a different pod count re-shards transparently);
  * async: ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes in a background thread so the train loop keeps stepping.

Packed BFP checkpoints (DESIGN.md §10, docs/formats.md): ``save(...,
format="bfp_packed", policy=...)`` stores every prequant-eligible
GEMM/conv weight leaf as a bit-packed :class:`~repro.core.packed
.PackedBFP` container (the same ``core.prequant`` leaf-selection walk a
bound plan uses; norm gains, biases, embeddings, and odd-K leaves stay
float32), cutting the on-disk artifact ~4x at 8-bit mantissas.
``restore`` then rebuilds packed leaves per its ``packed=`` mode:
``"prequant"`` (default: the ``{"m", "s"}`` sidecars a serving engine
binds with no float weights ever materialized), ``"dequant"`` (a plain
float tree), or ``"keep"`` (raw containers — ``engine.bind`` unpacks
them).  The manifest gains ``format`` and ``packed_leaves`` fields; the
atomicity/checksum/GC machinery is format-agnostic.
``format="bfp_packed_v2"`` writes the variable-width (v3) containers —
per-block effective mantissa widths, docs/formats.md §2 — and restores
through the same three ``packed=`` modes; fixed and variable leaves can
share one manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
import zlib
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packed import (IntegrityError, PackedBFP, is_packed,
                               pack_param_tree, unpack_dequant,
                               unpack_prequant)

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer",
           "CheckpointCorruptionWarning"]


class CheckpointCorruptionWarning(UserWarning):
    """A present-but-invalid step (torn write, corrupted bytes, failed
    checksum) was skipped; restore fell back to an older valid step."""


def _flatten(tree, is_leaf=None) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_leaf)
    return [x if is_packed(x) else np.asarray(x) for x in leaves], treedef


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def save(base: str, step: int, tree, keep: int = 3, *,
         format: str = "float32", policy: Any = None,
         tree_kind: str = "auto") -> str:
    """Synchronous atomic save.  Returns the final directory.

    ``format="float32"`` (default) stores every leaf as-is.
    ``format="bfp_packed"`` additionally needs ``policy`` (BFPPolicy or
    ``engine.PolicyMap``): GEMM/conv weight leaves the prequant walk
    selects are stored as serialized :class:`PackedBFP` containers
    (uint8 rows in the same ``arrays.npz``), everything else as float.
    ``format="bfp_packed_v2"`` is the same walk but writes VARIABLE-WIDTH
    (v3) containers — each block stored at its effective occupied width,
    so sparse/low-precision sites shrink below the fixed-L bitstream.
    ``tree_kind`` ("cnn" | "lm" | "auto") picks the path convention, as
    in ``engine.bind``.  A tree that already contains PackedBFP leaves
    is stored packed under any format (no policy needed); fixed and
    variable containers may coexist in one manifest — every container
    is self-describing, so ``restore`` never consults the format field
    to decode a leaf.
    """
    if format not in ("float32", "bfp_packed", "bfp_packed_v2"):
        raise ValueError(f"unknown checkpoint format {format!r}")
    packing = format in ("bfp_packed", "bfp_packed_v2")
    if packing and policy is not None:
        tree = pack_param_tree(tree, policy, tree_kind,
                               variable=(format == "bfp_packed_v2"))
    leaves, treedef = _flatten(tree, is_leaf=is_packed)
    packed_idx = [i for i, l in enumerate(leaves) if is_packed(l)]
    if packing and not packed_idx:
        # the caller explicitly asked for a packed artifact; silently
        # writing a full-size float32 checkpoint would hide a typo'd
        # PolicyMap / wrong tree_kind until the disk budget blows
        raise ValueError(
            f"format={format!r} packed zero leaves — pass policy= (a "
            f"BFPPolicy or PolicyMap whose rules resolve for at least one "
            f"GEMM/conv weight), or check tree_kind" if policy is None else
            f"format={format!r} packed zero leaves: the policy resolved "
            f"no prequant-eligible GEMM/conv weight (typo'd PolicyMap "
            f"rules, or wrong tree_kind?)")
    os.makedirs(base, exist_ok=True)
    final = _step_dir(base, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    payload = {f"leaf_{i}": (np.frombuffer(leaf.to_bytes(), np.uint8)
                             if is_packed(leaf) else leaf)
               for i, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **payload)
    with open(os.path.join(tmp, "arrays.npz"), "rb") as f:
        crc = zlib.crc32(f.read())
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        # packed leaves report their ORIGINAL tensor geometry, so shape
        # validation at restore is format-agnostic
        "shapes": [list(l.shape) for l in leaves],
        # variable-width leaves advertise a "v" suffix so a manifest
        # reader can tell mixed fixed/variable artifacts apart without
        # parsing containers
        "dtypes": [(f"bfp_packed{l.bits}{'v' if l.variable else ''}"
                    if is_packed(l) else str(l.dtype)) for l in leaves],
        "format": (("bfp_packed_v2" if any(leaves[i].variable
                                           for i in packed_idx)
                    else "bfp_packed") if packed_idx else "float32"),
        "packed_leaves": packed_idx,
        "crc32": crc,
        "status": "complete",
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(base, keep)
    return final


def _gc(base: str, keep: int):
    steps = sorted(_list_steps(base))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


def _list_steps(base: str) -> List[int]:
    if not os.path.isdir(base):
        return []
    out = []
    for name in os.listdir(base):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def _valid(base: str, step: int) -> bool:
    d = _step_dir(base, step)
    mpath = os.path.join(d, "manifest.json")
    apath = os.path.join(d, "arrays.npz")
    if not (os.path.exists(mpath) and os.path.exists(apath)):
        return False
    try:
        with open(mpath) as f:
            m = json.load(f)
        if m.get("status") != "complete":
            return False
        with open(apath, "rb") as f:
            return zlib.crc32(f.read()) == m["crc32"]
    except Exception:
        return False


def latest_step(base: str) -> Optional[int]:
    """Most recent VALID step (checksum-verified).

    A step directory that exists but fails validation (missing files,
    incomplete status, payload-CRC mismatch — i.e. a torn write or
    corrupted bytes) is skipped with a
    :class:`CheckpointCorruptionWarning` and the next older step is
    tried: corruption costs one checkpoint interval, never a crash.
    """
    for s in sorted(_list_steps(base), reverse=True):
        if _valid(base, s):
            return s
        warnings.warn(
            f"checkpoint step {s} at {_step_dir(base, s)} is corrupt or "
            f"incomplete — skipping it and falling back to the next "
            f"valid step", CheckpointCorruptionWarning, stacklevel=2)
    return None


def restore(base: str, tree_like, step: Optional[int] = None,
            sharding_fn: Optional[Callable[[Any], Any]] = None,
            packed: str = "prequant"):
    """Restore into the structure of ``tree_like``.

    sharding_fn(leaf_path_index -> sharding) — when given, leaves are
    device_put with it (elastic re-shard onto the current mesh).
    Returns (tree, step) or (None, None) when no valid checkpoint exists.

    For ``format="bfp_packed"`` checkpoints, ``packed`` selects what a
    packed weight leaf restores to:

      * ``"prequant"`` (default): the ``{"m", "s"}`` sidecar dict every
        engine backend consumes — the serving load path; no float weight
        is ever materialized for these leaves;
      * ``"dequant"``: dense float32 (``m * s``), for consumers that
        need a plain float tree (e.g. resuming float training);
      * ``"keep"``: the raw :class:`PackedBFP` containers (smallest host
        footprint; ``engine.bind`` / the serve engines unpack them).

    Float32 checkpoints ignore ``packed``.  Sharded placement via
    ``sharding_fn`` applies to plain array leaves — including
    ``"dequant"``-mode weights, which ARE plain float arrays (elastic
    restarts re-shard them like any other leaf).  ``"prequant"`` /
    ``"keep"`` leaves stay host-side until the bind-time unpack places
    them.
    """
    if packed not in ("prequant", "dequant", "keep"):
        raise ValueError(f"packed must be 'prequant', 'dequant', or "
                         f"'keep'; got {packed!r}")
    if step is None:
        step = latest_step(base)
        if step is None:
            return None, None
    elif not _valid(base, step):
        # an EXPLICITLY requested step must not silently restore corrupt
        # bytes — the caller asked for this step, so failing loudly (with
        # the typed integrity error) beats both a crash deeper in np.load
        # and a silent wrong-weights restore
        raise IntegrityError(
            f"checkpoint step {step} at {_step_dir(base, step)} is "
            f"corrupt, incomplete, or missing (payload checksum / "
            f"manifest validation failed)")
    d = _step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    packed_idx = set(manifest.get("packed_leaves", []))
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves_ref, treedef = jax.tree_util.tree_flatten(tree_like)
    if manifest.get("n_leaves", len(leaves_ref)) != len(leaves_ref):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, model tree has "
            f"{len(leaves_ref)} — architecture mismatch")
    leaves: List[Any] = [data[f"leaf_{i}"] for i in range(len(leaves_ref))]
    for i in packed_idx:
        leaves[i] = PackedBFP.from_bytes(leaves[i].tobytes())
    for i, (new, ref) in enumerate(zip(leaves, leaves_ref)):
        if tuple(new.shape) != tuple(jnp.shape(ref)):
            raise ValueError(
                f"checkpoint leaf {i} shape {tuple(new.shape)} != model "
                f"{jnp.shape(ref)} — architecture mismatch")
    out: List[Any] = []
    for i, leaf in enumerate(leaves):
        if is_packed(leaf):
            if packed == "dequant":
                leaf = unpack_dequant(leaf)      # plain float: place below
            else:
                out.append(leaf if packed == "keep"
                           else unpack_prequant(leaf))
                continue
        if sharding_fn is not None:
            out.append(jax.device_put(leaf, sharding_fn(i)))
        else:
            out.append(jnp.asarray(leaf))
    return jax.tree_util.tree_unflatten(treedef, out), step


class Checkpointer:
    """Async checkpointer: snapshot-to-host sync, write in background.

    ``format``/``policy``/``tree_kind`` are forwarded to :func:`save`,
    so packed checkpoints ride the async path too.
    """

    def __init__(self, base: str, keep: int = 3, *,
                 format: str = "float32", policy: Any = None,
                 tree_kind: str = "auto"):
        self.base = base
        self.keep = keep
        self.format = format
        self.policy = policy
        self.tree_kind = tree_kind
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save_async(self, step: int, tree):
        self.wait()
        # snapshot now; PackedBFP leaves are already host bytes and must
        # NOT go through np.asarray (a 0-d object array would be pickled
        # into arrays.npz and be unreadable at restore)
        host_tree = jax.tree_util.tree_map(
            lambda l: l if is_packed(l) else np.asarray(l), tree,
            is_leaf=is_packed)

        def _run():
            try:
                save(self.base, step, host_tree, self.keep,
                     format=self.format, policy=self.policy,
                     tree_kind=self.tree_kind)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()


def save_async(base: str, step: int, tree, keep: int = 3, *,
               format: str = "float32", policy: Any = None,
               tree_kind: str = "auto") -> Checkpointer:
    ck = Checkpointer(base, keep, format=format, policy=policy,
                      tree_kind=tree_kind)
    ck.save_async(step, tree)
    return ck
