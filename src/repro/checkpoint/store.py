"""Checkpointing: atomic, resumable, mesh-agnostic, async-capable.

Layout:  <dir>/step_<N>/
           manifest.json       tree structure + shapes/dtypes + status
           arrays.npz          flat leaves (logical, unsharded)

Fault-tolerance properties (DESIGN.md §5):
  * atomic: written to step_<N>.tmp, fsynced, renamed -> a crash never
    leaves a half checkpoint that restore() would pick up;
  * manifest carries a payload checksum -> torn writes are detected and
    the previous step is used instead;
  * mesh-agnostic: leaves are stored unsharded; ``restore(..., mesh,
    sharding_fn)`` re-device_puts onto ANY mesh shape (elastic restart on
    a different pod count re-shards transparently);
  * async: ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes in a background thread so the train loop keeps stepping.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer"]


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def save(base: str, step: int, tree, keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final directory."""
    os.makedirs(base, exist_ok=True)
    final = _step_dir(base, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    payload = {f"leaf_{i}": leaf for i, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **payload)
    with open(os.path.join(tmp, "arrays.npz"), "rb") as f:
        crc = zlib.crc32(f.read())
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(l.shape) for l in leaves],
        "dtypes": [str(l.dtype) for l in leaves],
        "crc32": crc,
        "status": "complete",
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(base, keep)
    return final


def _gc(base: str, keep: int):
    steps = sorted(_list_steps(base))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


def _list_steps(base: str) -> List[int]:
    if not os.path.isdir(base):
        return []
    out = []
    for name in os.listdir(base):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def _valid(base: str, step: int) -> bool:
    d = _step_dir(base, step)
    mpath = os.path.join(d, "manifest.json")
    apath = os.path.join(d, "arrays.npz")
    if not (os.path.exists(mpath) and os.path.exists(apath)):
        return False
    try:
        with open(mpath) as f:
            m = json.load(f)
        if m.get("status") != "complete":
            return False
        with open(apath, "rb") as f:
            return zlib.crc32(f.read()) == m["crc32"]
    except Exception:
        return False


def latest_step(base: str) -> Optional[int]:
    """Most recent VALID step (checksum-verified) — torn writes skipped."""
    for s in sorted(_list_steps(base), reverse=True):
        if _valid(base, s):
            return s
    return None


def restore(base: str, tree_like, step: Optional[int] = None,
            sharding_fn: Optional[Callable[[Any], Any]] = None):
    """Restore into the structure of ``tree_like``.

    sharding_fn(leaf_path_index -> sharding) — when given, leaves are
    device_put with it (elastic re-shard onto the current mesh).
    Returns (tree, step) or (None, None) when no valid checkpoint exists.
    """
    step = latest_step(base) if step is None else step
    if step is None:
        return None, None
    d = _step_dir(base, step)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves_ref, treedef = jax.tree_util.tree_flatten(tree_like)
    leaves = [data[f"leaf_{i}"] for i in range(len(leaves_ref))]
    for i, (new, ref) in enumerate(zip(leaves, leaves_ref)):
        if tuple(new.shape) != tuple(jnp.shape(ref)):
            raise ValueError(
                f"checkpoint leaf {i} shape {new.shape} != model "
                f"{jnp.shape(ref)} — architecture mismatch")
    if sharding_fn is not None:
        leaves = [jax.device_put(l, sharding_fn(i))
                  for i, l in enumerate(leaves)]
    else:
        leaves = [jnp.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class Checkpointer:
    """Async checkpointer: snapshot-to-host sync, write in background."""

    def __init__(self, base: str, keep: int = 3):
        self.base = base
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save_async(self, step: int, tree):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def _run():
            try:
                save(self.base, step, host_tree, self.keep)
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()


def save_async(base: str, step: int, tree, keep: int = 3) -> Checkpointer:
    ck = Checkpointer(base, keep)
    ck.save_async(step, tree)
    return ck
