"""BFP policy — how block floating point is applied across a model.

A :class:`BFPPolicy` is threaded through every GEMM-bearing layer.  ``None``
means pure float math (the paper's floating-point reference).  The default
policy reproduces the paper's chosen configuration: scheme eq. (4), 8-bit
mantissas (incl. sign) for both W and I, round-off.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.bfp import Rounding, Scheme

__all__ = ["BFPPolicy", "PAPER_DEFAULT", "TPU_TILED"]


@dataclasses.dataclass(frozen=True)
class BFPPolicy:
    """Static (hashable) configuration for BFP GEMMs.

    Attributes:
      l_w: weight mantissa bits, INCLUDING sign (paper Table 3 convention).
      l_i: input/activation mantissa bits, INCLUDING sign.
      scheme: matrix partition scheme (paper eq. 2-5, or TILED).
      block_k: K-tile size for Scheme.TILED (None = whole K).
      rounding: ROUND (paper's choice), TRUNCATE, or STOCHASTIC.
      exp_bits: stored exponent width (storage accounting only).
      quantize_weights / quantize_inputs: per-operand enable switches.
      straight_through: if True, bfp_dot uses a straight-through estimator
        so gradients flow as if the GEMM were float (BFP-QAT, beyond-paper).
      use_kernel: prefer the Pallas kernel path where available.
    """

    l_w: int = 8
    l_i: int = 8
    scheme: Scheme = Scheme.EQ4
    block_k: Optional[int] = None
    rounding: Rounding = Rounding.ROUND
    exp_bits: int = 8
    quantize_weights: bool = True
    quantize_inputs: bool = True
    straight_through: bool = True
    use_kernel: bool = False

    def __post_init__(self):
        for name, v in (("l_w", self.l_w), ("l_i", self.l_i)):
            if not 2 <= v <= 24:
                raise ValueError(f"{name}={v} out of range [2, 24]")

    def with_(self, **kw) -> "BFPPolicy":
        return dataclasses.replace(self, **kw)


#: The paper's headline configuration: eq. (4), 8-bit mantissas, rounding.
PAPER_DEFAULT = BFPPolicy()

#: TPU-native tiled variant (DESIGN.md §2): K-tiles of 128 matched to the
#: MXU contraction tiling; strictly lower quantization noise than EQ4.
TPU_TILED = BFPPolicy(scheme=Scheme.TILED, block_k=128)
