"""BFP policy — how block floating point is applied across a model.

A :class:`BFPPolicy` is threaded through every GEMM-bearing layer.  ``None``
means pure float math (the paper's floating-point reference).  The default
policy reproduces the paper's chosen configuration: scheme eq. (4), 8-bit
mantissas (incl. sign) for both W and I, round-off.

Per-LAYER policies (paper Table 3's layer-wise sweep) are expressed with
:class:`repro.engine.PolicyMap`, which resolves a layer path to a
``BFPPolicy`` (or ``None`` for float); every layer accepts either.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.bfp import Rounding, Scheme

__all__ = ["BFPPolicy", "PAPER_DEFAULT", "TPU_TILED", "PALLAS_TILED"]


@dataclasses.dataclass(frozen=True)
class BFPPolicy:
    """Static (hashable) configuration for BFP GEMMs.

    Attributes:
      l_w: weight mantissa bits, INCLUDING sign (paper Table 3 convention).
      l_i: input/activation mantissa bits, INCLUDING sign.
      scheme: matrix partition scheme (paper eq. 2-5, or TILED).
      block_k: K-tile size for Scheme.TILED (None = whole K).
      rounding: ROUND (paper's choice), TRUNCATE, or STOCHASTIC.
      exp_bits: stored exponent width (storage accounting only).
      quantize_weights / quantize_inputs: per-operand enable switches.
      straight_through: if True, bfp_dot uses a straight-through estimator
        so gradients flow as if the GEMM were float (BFP-QAT, beyond-paper).
      backend: execution backend name ("float" | "emulated" | "pallas");
        None selects via ``use_kernel`` (compat) and falls back to
        "emulated".  A backend that cannot honour the policy (e.g. pallas
        with a non-TILED scheme) falls back to "emulated" — see
        repro.engine.backends.select_backend / DESIGN.md §7.
      use_kernel: legacy alias for ``backend="pallas"``; kept so existing
        configs keep working.
    """

    l_w: int = 8
    l_i: int = 8
    scheme: Scheme = Scheme.EQ4
    block_k: Optional[int] = None
    rounding: Rounding = Rounding.ROUND
    exp_bits: int = 8
    quantize_weights: bool = True
    quantize_inputs: bool = True
    straight_through: bool = True
    backend: Optional[str] = None
    use_kernel: bool = False

    def __post_init__(self):
        for name, v in (("l_w", self.l_w), ("l_i", self.l_i)):
            if not 2 <= v <= 24:
                raise ValueError(f"{name}={v} out of range [2, 24]")

    @property
    def backend_name(self) -> str:
        """Requested backend, folding in the legacy use_kernel flag."""
        if self.backend is not None:
            return self.backend
        return "pallas" if self.use_kernel else "emulated"

    def with_(self, **kw) -> "BFPPolicy":
        return dataclasses.replace(self, **kw)


#: The paper's headline configuration: eq. (4), 8-bit mantissas, rounding.
PAPER_DEFAULT = BFPPolicy()

#: TPU-native tiled variant (DESIGN.md §2): K-tiles of 128 matched to the
#: MXU contraction tiling; strictly lower quantization noise than EQ4.
TPU_TILED = BFPPolicy(scheme=Scheme.TILED, block_k=128)

#: TPU_TILED executed by the fused Pallas kernel (interpret=True off-TPU).
PALLAS_TILED = TPU_TILED.with_(backend="pallas")
