"""Core BFP library — the paper's contribution as composable JAX modules."""
from repro.core.bfp import (BFPBlock, Rounding, Scheme, quantize, dequantize,
                            bfp_quantize_matrix, block_exponent,
                            average_bits_per_element, num_block_exponents,
                            accumulator_bits, max_safe_k)
from repro.core.bfp_dot import bfp_dot, bfp_matmul_2d
from repro.core.packed import (PackedBFP, is_packed, pack_block, pack_matrix,
                               pack_prequant, unpack_block, unpack_dequant,
                               unpack_prequant)
from repro.core.policy import BFPPolicy, PAPER_DEFAULT, TPU_TILED

__all__ = [
    "BFPBlock", "Rounding", "Scheme", "quantize", "dequantize",
    "bfp_quantize_matrix", "block_exponent", "average_bits_per_element",
    "num_block_exponents", "accumulator_bits", "max_safe_k",
    "bfp_dot", "bfp_matmul_2d", "BFPPolicy", "PAPER_DEFAULT", "TPU_TILED",
    "PackedBFP", "is_packed", "pack_block", "unpack_block", "pack_prequant",
    "unpack_prequant", "unpack_dequant", "pack_matrix",
]
