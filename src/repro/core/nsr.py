"""The paper's three-stage NSR/SNR error-analysis model (paper §4).

Stage 1 — quantization (eq. 6-13): block formatting adds zero-mean noise of
variance step²/12 per block; the matrix SNR aggregates block energies.

Stage 2 — single layer (eq. 14-18): for the inner products of a GEMM with
independently quantized operands, noise-to-signal ratios ADD:

    eta_O = eta_I + eta_W            (eq. 16-17)

Stage 3 — multi-layer (eq. 19-20): with inherited NSR eta_1 from the
previous layer and fresh input-quantization NSR eta_2 measured against
(signal + inherited error):

    eta_total_input = eta_1 + eta_2 + eta_1 * eta_2

ReLU is SNR-neutral (errors distribute evenly over sign, paper §4.4);
pooling output SNR is passed through unchanged.

All functions work in our mantissa convention (DESIGN.md §6), so theory and
measurement are directly comparable — the tests assert agreement far inside
the paper's 8.9 dB worst-case envelope.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.bfp import pow2 as bfp_pow2
from repro.core.bfp_dot import quantize_activations, quantize_weights
from repro.core.policy import BFPPolicy

__all__ = [
    "snr_db", "nsr_from_snr_db", "snr_db_from_nsr",
    "quantization_noise_var", "predict_matrix_snr", "measure_matrix_snr",
    "matrix_nsr_upper_bound", "gemm_nsr_upper_bound",
    "grad_dx_nsr_upper_bound", "grad_dw_nsr_upper_bound",
    "single_layer_output_snr", "chain_input_nsr", "LayerSNRReport",
    "analyze_gemm_chain",
]


def snr_db(signal: jax.Array, noisy: jax.Array) -> jax.Array:
    """Measured SNR: 10 log10(sum(signal^2) / sum((noisy-signal)^2))."""
    s = jnp.sum(jnp.square(signal.astype(jnp.float32)))
    e = jnp.sum(jnp.square((noisy - signal).astype(jnp.float32)))
    return 10.0 * jnp.log10(s / jnp.maximum(e, jnp.finfo(jnp.float32).tiny))


def nsr_from_snr_db(snr: jax.Array) -> jax.Array:
    return 10.0 ** (-snr / 10.0)


def snr_db_from_nsr(nsr: jax.Array) -> jax.Array:
    return -10.0 * jnp.log10(jnp.maximum(nsr, jnp.finfo(jnp.float32).tiny))


def quantization_noise_var(exponent: jax.Array, bits: int) -> jax.Array:
    """Per-block noise variance step^2 / 12 (paper eq. 8, our convention)."""
    step = bfp_pow2(exponent - (bits - 2))
    return jnp.square(step) / 12.0


def _block_sizes_and_exps(x2d: jax.Array, bits: int, operand: str,
                          policy: BFPPolicy) -> Tuple[jax.Array, int]:
    """Block exponents (flattened) and elements-per-block for an operand.

    operand "w": [K, N] weights; operand "i": [B, K] activations — NN
    orientation, mirroring bfp_dot.quantize_weights/quantize_activations.
    """
    if operand == "w":
        blk = quantize_weights(x2d, policy.with_(l_w=bits))
    else:
        blk = quantize_activations(x2d, policy.with_(l_i=bits))
    n_blocks = blk.exponent.size
    return blk.exponent.reshape(-1), x2d.size // n_blocks


def predict_matrix_snr(x2d: jax.Array, bits: int, operand: str,
                       policy: BFPPolicy) -> jax.Array:
    """Theoretical SNR of a block-formatted matrix (paper eq. 9-13).

    Aggregates over blocks as eq. (13): total signal energy over total
    predicted noise energy (= sum over blocks of elems * step^2/12).
    """
    exps, elems = _block_sizes_and_exps(x2d, bits, operand, policy)
    noise_energy = jnp.sum(quantization_noise_var(exps, bits)) * elems
    signal_energy = jnp.sum(jnp.square(x2d.astype(jnp.float32)))
    return 10.0 * jnp.log10(signal_energy /
                            jnp.maximum(noise_energy,
                                        jnp.finfo(jnp.float32).tiny))


def measure_matrix_snr(x2d: jax.Array, bits: int, operand: str,
                       policy: BFPPolicy) -> jax.Array:
    """Empirical SNR of the same block formatting (for model validation).
    Works for every scheme incl. TILED (``BFPBlock.scale`` expands the
    per-tile exponent layout)."""
    if operand == "w":
        blk = quantize_weights(x2d, policy.with_(l_w=bits))
    else:
        blk = quantize_activations(x2d, policy.with_(l_i=bits))
    return snr_db(x2d, blk.dequantize())


# ---------------------------------------------------------------------------
# NSR upper bounds (paper abstract: "the NSR upper bound ... provides the
# promising guidance for BFP based CNN engine design").  Where eq. 8-13
# model the EXPECTED noise (step^2/12 per element), these are hard
# worst-case bounds no measurement can exceed — the property suite
# (tests/test_bfp_properties.py) pins them over generated GEMMs.
# ---------------------------------------------------------------------------

def matrix_nsr_upper_bound(block_elems: int, bits: int) -> float:
    """Hard worst-case NSR of block formatting (never exceeded).

    Per element the format error is < step (round-off contributes at
    most step/2; the clipped block max loses < step), so a block of n
    elements carries noise energy < n*step^2.  Each block's signal
    energy is at least (2^eps)^2 — the defining block max satisfies
    |x_max| >= 2^eps.  With step = 2^(eps - (L-2)) per our convention:

        eta_block < n * 2^(-2(L-2))

    and the matrix aggregate (total noise / total signal) cannot exceed
    the worst per-block ratio.  ~10.8 dB above the step^2/12 + measured-
    signal expectation — the price of a guarantee.
    """
    return float(block_elems) * 2.0 ** (-2 * (bits - 2))


def _format_noise_energy_bound(x2d: jax.Array, bits: int, operand: str,
                               policy: BFPPolicy) -> jax.Array:
    """Worst-case format noise ENERGY: sum over blocks of n * step^2."""
    exps, elems = _block_sizes_and_exps(x2d, bits, operand, policy)
    step = bfp_pow2(exps - (bits - 2))
    return jnp.sum(jnp.square(step)) * elems


def gemm_nsr_upper_bound(x2d: jax.Array, w2d: jax.Array,
                         policy: BFPPolicy) -> jax.Array:
    """Analytic upper bound on the measured output NSR of one BFP GEMM.

    The fixed-point datapath is exact on the formatted operands (paper
    Fig. 2; test_int_datapath_exactness), so the output error is exactly

        E = e_x (W + e_w) + X e_w

    with per-operand error energies bounded from the block geometry
    alone (||e||_F^2 <= sum over blocks n*step^2, the
    :func:`matrix_nsr_upper_bound` derivation).  Frobenius
    submultiplicativity then gives

        ||E||_F <= ||e_x|| (||W|| + ||e_w||) + ||X|| ||e_w||
        eta_O   <= (that)^2 / ||X W||_F^2

    Loose (worst case per element, Frobenius instead of spectral norms)
    but DETERMINISTIC: both sides share the ||X W|| denominator, so the
    comparison is robust even when the output nearly cancels.  ``x2d``
    is [B, K] activations, ``w2d`` [K, N] weights — the NN orientation
    of ``bfp_dot``.
    """
    x = x2d.astype(jnp.float32)
    w = w2d.astype(jnp.float32)
    ex = jnp.sqrt(_format_noise_energy_bound(x, policy.l_i, "i", policy)) \
        if policy.quantize_inputs else jnp.asarray(0.0)
    ew = jnp.sqrt(_format_noise_energy_bound(w, policy.l_w, "w", policy)) \
        if policy.quantize_weights else jnp.asarray(0.0)
    nx, nw = jnp.linalg.norm(x), jnp.linalg.norm(w)
    e_out = ex * (nw + ew) + nx * ew
    sig = jnp.sum(jnp.square(x @ w))
    # guard must be a float32-representable tiny (1e-300 flushes to 0.0
    # with x64 off, making the guard a no-op and a zero signal -> nan)
    return jnp.square(e_out) / jnp.maximum(sig, jnp.finfo(jnp.float32).tiny)


def grad_dx_nsr_upper_bound(g2d: jax.Array, w2d: jax.Array,
                            policy: BFPPolicy) -> jax.Array:
    """Upper bound on the measured NSR of the data-gradient GEMM.

    The backward pass computes ``dL/dx = g[M, N] @ W^T[N, K]`` — the
    same fixed-point GEMM as a forward layer with the incoming gradient
    on the activation side (``l_i`` bits, activation block scheme,
    blocks along the N contraction) and the transposed weight on the
    weight side (``l_w``), so :func:`gemm_nsr_upper_bound` applies
    verbatim to the grad-side geometry.  ``g2d`` is the [M, N] incoming
    gradient, ``w2d`` the FORWARD-orientation [K, N] weight; ``policy``
    must be the policy the backward GEMM actually executes (after any
    ``repro.grad.fit_grad_policy`` K-tile fitting).
    """
    return gemm_nsr_upper_bound(g2d, jnp.swapaxes(w2d, -1, -2), policy)


def grad_dw_nsr_upper_bound(x2d: jax.Array, g2d: jax.Array,
                            policy: BFPPolicy) -> jax.Array:
    """Upper bound on the measured NSR of the weight-gradient GEMM
    ``dL/dw = x^T[K, M] @ g[M, N]``: the saved activations land on the
    activation side, the incoming gradient on the weight side, and the
    contraction runs over the flattened batch M.  ``x2d`` is the [M, K]
    forward activation matrix, ``g2d`` the [M, N] incoming gradient;
    ``policy`` as in :func:`grad_dx_nsr_upper_bound`."""
    return gemm_nsr_upper_bound(jnp.swapaxes(x2d, -1, -2), g2d, policy)


def single_layer_output_snr(snr_i_db: jax.Array,
                            snr_w_db: jax.Array) -> jax.Array:
    """Paper eq. (18): eta_O = eta_I + eta_W in SNR-dB form."""
    eta = nsr_from_snr_db(snr_i_db) + nsr_from_snr_db(snr_w_db)
    return snr_db_from_nsr(eta)


def chain_input_nsr(eta_inherited: jax.Array,
                    eta_quant: jax.Array) -> jax.Array:
    """Paper eq. (19-20): total input NSR given inherited + fresh NSR.

    eta_quant here is measured against the CLEAN signal (our convention);
    the paper's eta_2 is against signal+inherited — the two agree to first
    order and we keep the full cross term: eta = eta_1 + eta_2 + eta_1*eta_2.
    """
    return eta_inherited + eta_quant + eta_inherited * eta_quant


@dataclasses.dataclass
class LayerSNRReport:
    """One row of the paper's Table 4."""
    name: str
    snr_input_measured: float
    snr_input_single: float      # single-layer model (fresh quantization only)
    snr_input_multi: float       # multi-layer model (with inherited error)
    snr_weight_measured: float
    snr_weight_predicted: float
    snr_output_measured: float
    snr_output_single: float
    snr_output_multi: float


def analyze_gemm_chain(
    inputs: jax.Array,
    weights: Sequence[jax.Array],
    policy: BFPPolicy,
    names: Optional[Sequence[str]] = None,
    nonlinearity=jax.nn.relu,
) -> List[LayerSNRReport]:
    """Run a chain of GEMM+ReLU layers in float and in BFP, and compare the
    measured SNRs against the single-layer and multi-layer models.

    ``inputs`` is [B, K0]; ``weights[l]`` is [K_l, K_{l+1}].  This is the
    paper's Table-4 experiment in matrix form; the CNN driver feeds im2col
    matrices through the same function.
    """
    names = names or [f"gemm{l}" for l in range(len(weights))]
    x_f = inputs.astype(jnp.float32)   # float reference path
    x_q = inputs.astype(jnp.float32)   # BFP path (carries accumulated error)
    eta_multi = jnp.asarray(0.0, jnp.float32)  # inherited NSR (model state)
    reports: List[LayerSNRReport] = []

    from repro.core.bfp_dot import bfp_matmul_2d

    for name, w in zip(names, weights):
        # --- input formatting: measured + predicted -----------------------
        bi = quantize_activations(x_q, policy)
        x_q_fmt = bi.dequantize()
        snr_in_meas = snr_db(x_f, x_q_fmt)               # vs clean signal
        snr_in_single = predict_matrix_snr(x_f, policy.l_i, "i", policy)
        eta_fresh = nsr_from_snr_db(
            predict_matrix_snr(x_q, policy.l_i, "i", policy))
        eta_in_multi = chain_input_nsr(eta_multi, eta_fresh)
        snr_in_multi = snr_db_from_nsr(eta_in_multi)

        # --- weight formatting --------------------------------------------
        snr_w_meas = measure_matrix_snr(w, policy.l_w, "w", policy)
        snr_w_pred = predict_matrix_snr(w, policy.l_w, "w", policy)

        # --- GEMM ----------------------------------------------------------
        y_f = x_f @ w
        y_q = bfp_matmul_2d(x_q, w, policy.with_(straight_through=False))
        snr_out_meas = snr_db(y_f, y_q)
        snr_out_single = single_layer_output_snr(snr_in_single, snr_w_pred)
        snr_out_multi = snr_db_from_nsr(
            eta_in_multi + nsr_from_snr_db(snr_w_pred))

        reports.append(LayerSNRReport(
            name=name,
            snr_input_measured=float(snr_in_meas),
            snr_input_single=float(snr_in_single),
            snr_input_multi=float(snr_in_multi),
            snr_weight_measured=float(snr_w_meas),
            snr_weight_predicted=float(snr_w_pred),
            snr_output_measured=float(snr_out_meas),
            snr_output_single=float(snr_out_single),
            snr_output_multi=float(snr_out_multi),
        ))

        # --- advance both paths through the nonlinearity -------------------
        x_f = nonlinearity(y_f)
        x_q = nonlinearity(y_q)
        # ReLU is SNR-neutral (paper §4.4) -> inherited NSR for next layer
        # is this layer's modeled output NSR.
        eta_multi = eta_in_multi + nsr_from_snr_db(snr_w_pred)

    return reports
