"""Offline weight pre-quantization — the paper's deployment mode.

The accelerator stores weights in HBM as int8 mantissas + a small
power-of-two scale sidecar (block exponents), so every weight read moves
~4x fewer bytes than f32 (2x fewer than bf16) and FSDP weight all-gathers
shrink by the same factor — the paper's off-chip-traffic argument
(§1, §3.1) applied to TPU HBM and ICI.

``quantize_param_tree`` converts every >=2-D float leaf into
``{"m": int8 mantissa, "s": f32 per-(K-tile, out-column) scale}``
(Scheme.TILED with block_k, or per-column when block_k is None = paper
eq. 4).  ``models.lm.common.linear`` consumes either representation, so
the same model code serves float or BFP weights.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import bfp
from repro.core.policy import BFPPolicy

__all__ = ["quantize_param_tree", "prequant_leaf", "is_prequant"]


def is_prequant(w: Any) -> bool:
    return isinstance(w, dict) and "m" in w and "s" in w


def prequant_leaf(w: jax.Array, policy: BFPPolicy) -> Any:
    """[.., K, N] float -> {"m": int8 [.., K, N], "s": f32 [.., K/bk, N]}."""
    if w.ndim < 2:
        return w
    lead = w.shape[:-2]
    k, n = w.shape[-2:]
    bk = policy.block_k or k
    if k % bk:
        return w  # odd contraction dim: leave in float
    w2 = w.reshape(-1, k, n)

    def one(mat):
        blk = bfp.bfp_quantize_matrix(mat, policy.l_w, "i", bfp.Scheme.TILED,
                                      bk, policy.rounding)
        return blk.mantissa, jnp.exp2(
            (blk.exponent - (policy.l_w - 2)).astype(jnp.float32))

    m, s = jax.vmap(one)(w2)
    return {"m": m.reshape(*lead, k, n),
            "s": s.reshape(*lead, k // bk, n)}


def _eligible(path_s: str) -> bool:
    # embedding stays float (gather path); every GEMM weight is eligible
    return not path_s.endswith("embed/e")


def quantize_param_tree(params: Any, policy: Optional[BFPPolicy]) -> Any:
    """Walk the param tree; convert GEMM weights to the BFP wire format."""
    if policy is None:
        return params

    def one(path, leaf):
        parts = []
        for kk in path:
            parts.append(str(getattr(kk, "key", getattr(kk, "idx", kk))))
        if not _eligible("/".join(parts)):
            return leaf
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                jnp.issubdtype(leaf.dtype, jnp.floating):
            return prequant_leaf(leaf, policy)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)
