"""Offline weight pre-quantization — the paper's deployment mode.

The accelerator stores weights in HBM as int8 mantissas + a small
power-of-two scale sidecar (block exponents), so every weight read moves
~4x fewer bytes than f32 (2x fewer than bf16) and FSDP weight all-gathers
shrink by the same factor — the paper's off-chip-traffic argument
(§1, §3.1) applied to TPU HBM and ICI.

Wire format (consumed FIRST-CLASS by every repro.engine backend):

    {"m": int mantissa [.., K, N],  "s": f32 scale [.., K//bk, N]}

``s`` holds the quantizer's power-of-two steps ``2^(e - (L_W - 2))``, so
the emulated integer datapath and the Pallas prequant kernel reproduce
BIT-EXACTLY what in-line ``quantize_weights`` would have produced for
Scheme.TILED with the same ``block_k`` (or per-column / eq. 4 blocks when
``block_k`` is None) — but the quantization runs ONCE, not per forward.

``quantize_param_tree`` converts LM-style trees (>=2-D GEMM leaves,
possibly stacked [L, K, N]); ``quantize_cnn_param_tree`` walks CNN trees,
lowering HWIO conv kernels to their GEMM view (``core.conv_utils``
HWIO-major K-order, the fused conv kernel's K-tiling).  Both accept
a single :class:`BFPPolicy` or a per-layer ``repro.engine.PolicyMap``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import bfp
from repro.core.policy import BFPPolicy

__all__ = ["quantize_param_tree", "quantize_cnn_param_tree", "prequant_leaf",
           "prequant_conv_leaf", "dequantize_prequant", "is_prequant",
           "prequant_act", "dequantize_act", "act_block",
           "lm_rule_path", "lm_eligible", "cnn_rule_path",
           "detect_tree_kind"]


def is_prequant(w: Any) -> bool:
    return isinstance(w, dict) and "m" in w and "s" in w


def detect_tree_kind(params: Any) -> str:
    """"lm" or "cnn" — THE param-tree convention detector.

    Single source of truth shared by ``engine.bind`` and
    ``core.packed.pack_param_tree`` (checkpoint ``format="bfp_packed"``),
    so the walk that packs a checkpoint can never classify a tree
    differently from the walk that binds it.
    """
    if isinstance(params, dict) and (
            {"embed", "layers", "dec", "periods"} & set(params)):
        return "lm"
    return "cnn"


def _resolve(policy: Any, path: Optional[str]) -> Optional[BFPPolicy]:
    # Lazy import: engine.policy_map depends on core.policy; importing it
    # at module scope here would cycle through repro.engine.__init__.
    from repro.engine.policy_map import resolve_policy
    return resolve_policy(policy, path)


def prequant_leaf(w: jax.Array, policy: BFPPolicy) -> Any:
    """[.., K, N] float -> {"m": int8 [.., K, N], "s": f32 [.., K/bk, N]}."""
    if w.ndim < 2:
        return w
    lead = w.shape[:-2]
    k, n = w.shape[-2:]
    bk = policy.block_k or k
    if k % bk:
        return w  # odd contraction dim: leave in float
    w2 = w.reshape(-1, k, n)

    def one(mat):
        blk = bfp.bfp_quantize_matrix(mat, policy.l_w, "i", bfp.Scheme.TILED,
                                      bk, policy.rounding)
        return blk.mantissa, bfp.pow2(blk.exponent - (policy.l_w - 2))

    m, s = jax.vmap(one)(w2)
    return {"m": m.reshape(*lead, k, n),
            "s": s.reshape(*lead, k // bk, n)}


def prequant_conv_leaf(w_hwio: jax.Array, policy: BFPPolicy) -> Any:
    """HWIO conv kernel -> prequant dict with the mantissa kept in HWIO.

    Quantization happens in the conv GEMM view ``[kh*kw*C, out]`` — the
    repo-wide HWIO-major K-order (core.conv_utils), which is also exactly
    the K-tiling the fused implicit-im2col Pallas kernel streams — so the
    sidecar blocks ARE the conv kernel's K-tiles and prequant execution is
    bit-exact vs inline quantization on both the fused and im2col routes.
    The mantissa is reshaped back to HWIO so the layer can still read
    (kh, kw, in_ch, out_ch) off the array shape; ``s`` stays in the GEMM
    view [K//bk, N].
    """
    if w_hwio.ndim != 4:
        return w_hwio
    kh, kw, c, n = w_hwio.shape
    d = prequant_leaf(w_hwio.reshape(kh * kw * c, n), policy)
    if not is_prequant(d):
        return w_hwio  # block_k does not divide kh*kw*C
    return {"m": d["m"].reshape(kh, kw, c, n), "s": d["s"]}


def dequantize_prequant(w: Any, dtype=jnp.float32) -> jax.Array:
    """Materialize a prequant dict back to a dense float weight.

    Supports leading batch dims ([.., K, N] mantissa with [.., K//bk, N]
    scales).  4-D HWIO conv mantissas must be lowered to the GEMM view by
    the caller first (conv2d does).
    """
    m, s = w["m"], w["s"]
    bk = m.shape[-2] // s.shape[-2]
    s_full = jnp.repeat(s, bk, axis=-2)
    return (m.astype(dtype) * s_full.astype(dtype))


def prequant_act(x: jax.Array, policy: BFPPolicy) -> Any:
    """Activations [.., K] -> {"m": int8 [.., K], "s": f32 [.., K//bk]}.

    The ACTIVATION wire format: blocks run along the LAST axis, one per
    (row, K-chunk of ``policy.block_k``) — for NHWC conv activations the
    last axis is C, so blocks are per (pixel, channel-chunk), exactly the
    blocks the fused conv kernel forms inline when ``block_k | C``.

    This is the reference two-step requantizer the kernels' fused
    epilogue must match BIT-exactly (ISSUE 6 acceptance): it runs the
    same block-format math (``bfp_quantize_matrix``) the in-kernel
    quantizer is pinned against.  Quantization idempotence (PR 4
    property suite) then makes dequantize-then-requantize consumers
    (emulated/float backends) agree bit-exactly too.

    Requires ``policy.l_i <= 8`` (int8 mantissa wire) and
    ``block_k | K`` — raises ValueError otherwise, mirroring the
    emulated path's block contract.
    """
    k = x.shape[-1]
    bk = policy.block_k or k
    if k % bk:
        raise ValueError(f"activation prequant needs block_k | K, got "
                         f"block_k={bk}, K={k}")
    if policy.l_i > 8:
        raise ValueError(f"activation prequant streams int8 mantissas; "
                         f"L_I={policy.l_i} > 8")
    lead = x.shape[:-1]
    blk = bfp.bfp_quantize_matrix(x.reshape(-1, k), policy.l_i, "w",
                                  bfp.Scheme.TILED, bk, policy.rounding)
    return {"m": blk.mantissa.reshape(*lead, k),
            "s": bfp.pow2(blk.exponent - (policy.l_i - 2)).reshape(
                *lead, k // bk)}


def dequantize_act(x: Any, dtype=jnp.float32) -> jax.Array:
    """Materialize an activation-prequant dict back to dense float.

    Inverse layout of :func:`prequant_act`: blocks along the LAST axis
    ([.., K] mantissa with [.., K//bk] steps) — vs the weight format's
    [-2] axis (:func:`dequantize_prequant`).
    """
    m, s = x["m"], x["s"]
    bk = m.shape[-1] // s.shape[-1]
    return m.astype(dtype) * jnp.repeat(s, bk, axis=-1).astype(dtype)


def act_block(x: Any) -> int:
    """Block size of an activation-prequant dict (K // sidecar columns)."""
    return x["m"].shape[-1] // x["s"].shape[-1]


def _path_keys(path):
    return [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]


#: Leaf names that hold GEMM weights in LM trees: linear_init's "w" and
#: the MoE batched expert matrices.  Everything else (norm gains, biases,
#: recurrence parameters, embeddings — the gather path) stays float.
_GEMM_LEAF_NAMES = ("w", "w1", "w2", "w3")

#: Leading stack-container keys that runtime layer paths do not carry
#: (layers run under lax.scan; linear() sees "attn/wq", not
#: "layers/attn/wq").  "enc" is NOT stripped — encoder paths keep it.
_LM_STACK_PREFIXES = ("layers", "dec", "periods", "rem")


def lm_rule_path(keys) -> str:
    """Pytree path -> the runtime layer path PolicyMap rules see.

    Strips the trailing "/w" leaf name and leading stack-container/index
    segments so "layers/attn/wq/w" resolves as "attn/wq" — the same
    string models.lm.common.linear passes to the engine.  MoE expert
    leaves keep their matrix name ("moe/w1" vs runtime "moe"), so write
    substring rules ("^moe", not "^moe$") to cover both.
    """
    ks = list(keys)
    if ks and ks[-1] == "w":
        ks = ks[:-1]
    while ks and (ks[0] in _LM_STACK_PREFIXES or ks[0].isdigit()):
        ks = ks[1:]
    return "/".join(ks)


def lm_eligible(keys) -> bool:
    if not keys or keys[-1] not in _GEMM_LEAF_NAMES:
        return False
    if len(keys) >= 2 and keys[-2] == "router":
        return False  # MoE router always runs in float (moe_apply contract)
    return "/".join(keys) != "embed/e"


def _conv_bn_nested(params, rule_keys) -> bool:
    # The trailing "conv" segment is stripped ONLY for conv+bn blocks
    # (resnet's {"conv", "bn"} dicts), where the runtime layer path
    # omits it.  A plain conv layer that happens to be KEYED "conv"
    # (googlenet's aux heads: runtime path "loss1/conv") keeps it —
    # checked structurally via the sibling "bn" entry.
    node = params
    for kk in rule_keys[:-1]:
        node = node[int(kk)] if isinstance(node, (list, tuple)) \
            else node[kk]
    return isinstance(node.get(rule_keys[-1]), dict) and "bn" in node


def cnn_rule_path(params, keys) -> Optional[str]:
    """Runtime layer path for the CNN weight leaf at tree path ``keys``.

    Returns None when the leaf is not a GEMM/conv weight (only leaves
    literally named ``w`` count).  This is the single source of truth
    shared by :func:`quantize_cnn_param_tree` and ``engine.bind``'s site
    discovery, so a PolicyMap pins — and a Plan binds — exactly the
    layers the model apply functions execute ("stem", "blocks/3/c1",
    "conv1_1", "loss1/conv", "fc").
    """
    if not keys or keys[-1] != "w":
        return None
    rule_keys = keys[:-1]
    if rule_keys and rule_keys[-1] == "conv" and \
            _conv_bn_nested(params, rule_keys):
        rule_keys = rule_keys[:-1]
    return "/".join(rule_keys)


def quantize_param_tree(params: Any, policy: Any) -> Any:
    """Walk an LM param tree; convert GEMM weights to the BFP wire format.

    ``policy`` may be None (no-op), a BFPPolicy (uniform), or a
    repro.engine.PolicyMap (per-layer; a rule resolving to None keeps
    that leaf in float).  PolicyMap rules are matched against the SAME
    layer paths the runtime GEMMs use ("attn/wq", "ffn/w1", "lm_head"),
    so a per-layer assignment quantizes exactly the weights it executes.
    Stacked-layer leaves ([L, K, N], or [L, E, K, N] MoE experts)
    quantize each trailing [K, N] matrix independently.
    """
    if policy is None:
        return params

    def one(path, leaf):
        keys = _path_keys(path)
        if not lm_eligible(keys):
            return leaf
        pol = _resolve(policy, lm_rule_path(keys))
        if pol is None:
            return leaf
        if hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
                jnp.issubdtype(leaf.dtype, jnp.floating):
            return prequant_leaf(leaf, pol)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)


def quantize_cnn_param_tree(params: Any, policy: Any) -> Any:
    """Walk a CNN param tree (models.cnn conventions) into the wire format.

    Only leaves literally named ``w`` are touched: 4-D HWIO conv kernels
    go through :func:`prequant_conv_leaf`, 2-D dense weights through
    :func:`prequant_leaf`.  Biases / batch-norm / metadata stay as-is.
    The policy is resolved against the leaf's tree path with the
    trailing ``/w`` (and the ``/conv`` nesting of conv+bn blocks)
    stripped, which is exactly the layer path the model apply functions
    pass to the engine ("stem", "blocks/3/c1", "conv1_1", "fc") — a
    PolicyMap quantizes precisely the layers it will execute in BFP.
    """
    if policy is None:
        return params

    def one(path, leaf):
        keys = _path_keys(path)
        if not keys or keys[-1] != "w" or not hasattr(leaf, "ndim"):
            return leaf
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        pol = _resolve(policy, cnn_rule_path(params, keys))
        if pol is None:
            return leaf
        if leaf.ndim == 4:
            return prequant_conv_leaf(leaf, pol)
        if leaf.ndim == 2:
            return prequant_leaf(leaf, pol)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)
