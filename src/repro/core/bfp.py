"""Block floating point (BFP) formatting — the paper's core mechanism.

A block of numbers shares one exponent (the max exponent in the block,
paper eq. 1); mantissas are right-shifted to align and stored as small
signed integers.  Multiply-accumulate between two BFP blocks is then pure
fixed-point arithmetic plus one exponent add.

Conventions (DESIGN.md §6; paper Table-3 convention, mantissa width
``L`` INCLUDES the sign bit):

    eps   = max_i floor(log2 |x_i|)          (block exponent)
    delta = 2 ** (eps - (L - 2))             (quantization step)
    m_i   = clip(round(x_i / delta), -(2**(L-1)-1), 2**(L-1)-1)
    x'_i  = m_i * delta

All functions are pure jnp and jit-safe.  The Pallas kernels in
``repro.kernels`` implement the same contract for the TPU target and are
tested against these functions.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Rounding",
    "Scheme",
    "BFPBlock",
    "pow2",
    "block_exponent",
    "quantize",
    "dequantize",
    "bfp_quantize_matrix",
    "average_bits_per_element",
    "num_block_exponents",
    "accumulator_bits",
    "max_safe_k",
]

# Exponent used for an all-zero block.  Any finite value works (mantissas
# are all zero); a very negative one keeps dequantized zeros exact and the
# step size harmless.
_ZERO_BLOCK_EXP = -126


def pow2(e: jax.Array) -> jax.Array:
    """EXACT float32 2^e for integer ``e`` — the format's scale primitive.

    ``jnp.exp2`` is a polynomial approximation and lands 1 ulp off 2^e
    for many negative integer exponents on CPU/TPU backends.  That is
    enough to break the power-of-two contract the whole datapath leans
    on: with an inexact step, ``m * step / step`` drifts below the
    integer and TRUNCATE re-quantization loses a count (the
    requantization-idempotence property test caught this).  Build the
    float32 directly instead: exponent field for the normal range,
    mantissa bit for the denormal range — shifts + bitcast only, so the
    same code lowers inside Pallas kernels.
    """
    e = jnp.asarray(e).astype(jnp.int32)
    normal = (jnp.clip(e, -126, 127) + 127) << 23
    subnorm = jnp.int32(1) << jnp.clip(e + 149, 0, 22)
    bits = jnp.where(e >= -126, normal, subnorm)
    bits = jnp.where(e < -149, 0, bits)               # underflow -> +0.0
    bits = jnp.where(e > 127, 0x7F800000, bits)       # overflow  -> +inf
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


class Rounding(enum.Enum):
    """How out-shifted mantissa bits are handled (paper §3.1).

    The paper finds ROUND (round-to-nearest) strictly better than TRUNCATE
    because truncation introduces a DC bias that accumulates layer-wise.
    """

    ROUND = "round"
    TRUNCATE = "truncate"
    # Stochastic rounding: beyond-paper option (Gupta et al. 2015 is cited
    # by the paper as the fixed-point SR baseline).
    STOCHASTIC = "stochastic"


class Scheme(enum.Enum):
    """Matrix partition schemes for O = W[M,K] @ I[K,N] (paper eq. 2-5).

    Controls which entries share a block exponent:

    =========  =====================  =====================  ===========
    scheme     W blocks               I blocks               exponents
    =========  =====================  =====================  ===========
    EQ2        whole matrix (1)       whole matrix (1)       2
    EQ3        per row (M)            per column (N)         M + N
    EQ4        per row (M)            whole matrix (1)       M + 1   <- paper's choice
    EQ5        whole matrix (1)       per column (N)         N + 1
    TILED      per (row, K-tile)      per (column, K-tile)   TPU-native
    =========  =====================  =====================  ===========

    TILED is the beyond-paper TPU adaptation (DESIGN.md §2): blocks are
    K-tiles aligned with the MXU matmul pipeline; finer blocks -> lower
    quantization noise at ~1 exponent byte per tile.
    """

    EQ2 = "eq2"
    EQ3 = "eq3"
    EQ4 = "eq4"
    EQ5 = "eq5"
    TILED = "tiled"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BFPBlock:
    """A block-formatted tensor: integer mantissas + per-block exponents.

    ``mantissa`` has the same shape as the source tensor; ``exponent`` is
    broadcastable against it (size-1 axes over dims that share a block).
    ``bits`` includes the sign bit.
    """

    mantissa: jax.Array  # int8 (L<=8) or int16/int32
    exponent: jax.Array  # int32, broadcastable to mantissa.shape
    bits: int = dataclasses.field(metadata=dict(static=True))

    @property
    def scale(self) -> jax.Array:
        """2^(eps - (L-2)) as float32, expanded to broadcast against
        ``mantissa``.  Keepdims layouts (the paper schemes) pass through;
        Scheme.TILED's non-keepdims reshapes ([rows, K/bk] against a
        [rows, K] mantissa) repeat each tile's exponent along its blocked
        axis (tiles are contiguous), so ``dequantize`` works for every
        layout ``bfp_quantize_matrix`` produces."""
        e = self.exponent
        for ax, (se, sm) in enumerate(zip(e.shape, self.mantissa.shape)):
            if se not in (1, sm):
                e = jnp.repeat(e, sm // se, axis=ax)
        return pow2(e - (self.bits - 2))

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return (self.mantissa.astype(jnp.float32) * self.scale).astype(dtype)


def _mantissa_dtype(bits: int):
    if bits <= 8:
        return jnp.int8
    if bits <= 16:
        return jnp.int16
    return jnp.int32


def block_exponent(x: jax.Array, axes: Tuple[int, ...]) -> jax.Array:
    """Per-block exponent: max_i floor(log2 |x_i|) over ``axes`` (keepdims).

    Uses frexp so it is exact for every finite float (no log2 rounding):
    x = f * 2^e with f in [0.5, 1)  =>  floor(log2|x|) = e - 1.
    """
    amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    _, e = jnp.frexp(amax)
    # frexp(0) returns e=0; map all-zero blocks to a harmless tiny exponent.
    return jnp.where(amax > 0, e - 1, _ZERO_BLOCK_EXP).astype(jnp.int32)


def _apply_rounding(v: jax.Array, rounding: Rounding,
                    key: Optional[jax.Array]) -> jax.Array:
    if rounding is Rounding.ROUND:
        return jnp.round(v)  # round-half-to-even; zero-mean error (paper §3.1)
    if rounding is Rounding.TRUNCATE:
        # Hardware truncation of two's-complement right-shift == floor.
        return jnp.floor(v)
    if rounding is Rounding.STOCHASTIC:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        return jnp.floor(v + jax.random.uniform(key, v.shape, v.dtype))
    raise ValueError(rounding)


def quantize(
    x: jax.Array,
    bits: int,
    axes: Tuple[int, ...],
    rounding: Rounding = Rounding.ROUND,
    key: Optional[jax.Array] = None,
) -> BFPBlock:
    """Block-format ``x``: one shared exponent per block spanning ``axes``.

    This is the paper's eq. (1) (align-shift) expressed in float emulation:
    dividing by the block step and rounding is bit-exact to right-shifting
    the aligned mantissa with round-off.
    """
    if not 2 <= bits <= 24:
        raise ValueError(f"bits (incl. sign) must be in [2, 24], got {bits}")
    x = x.astype(jnp.float32)
    eps = block_exponent(x, axes)
    step = pow2(eps - (bits - 2))
    lim = 2 ** (bits - 1) - 1
    m = _apply_rounding(x / step, rounding, key)
    m = jnp.clip(m, -lim, lim).astype(_mantissa_dtype(bits))
    return BFPBlock(mantissa=m, exponent=eps, bits=bits)


def dequantize(b: BFPBlock, dtype=jnp.float32) -> jax.Array:
    return b.dequantize(dtype)


# ---------------------------------------------------------------------------
# Matrix-level block formatting for the GEMM  O = W[M,K] @ I[K,N]
# ---------------------------------------------------------------------------

def _scheme_axes(scheme: Scheme, operand: str) -> Tuple[int, ...]:
    """Axes that SHARE an exponent for a 2-D operand of the GEMM.

    W is [M, K]; I is [K, N].  Returns reduction axes for block_exponent.
    """
    if scheme is Scheme.EQ2:
        return (0, 1)
    if scheme is Scheme.EQ3:
        return (1,) if operand == "w" else (0,)
    if scheme is Scheme.EQ4:
        return (1,) if operand == "w" else (0, 1)
    if scheme is Scheme.EQ5:
        return (0, 1) if operand == "w" else (0,)
    raise ValueError(f"use bfp_quantize_matrix(block_k=...) for {scheme}")


def bfp_quantize_matrix(
    x: jax.Array,
    bits: int,
    operand: str,  # "w" for [M,K] weights, "i" for [K,N] inputs
    scheme: Scheme,
    block_k: Optional[int] = None,
    rounding: Rounding = Rounding.ROUND,
    key: Optional[jax.Array] = None,
) -> BFPBlock:
    """Block-format one GEMM operand under a paper scheme or TILED.

    For TILED, ``block_k`` must divide K; blocks are (row x block_k) for W
    and (block_k x col) for I — every (row/col, K-tile) pair has its own
    exponent.  For the paper schemes ``block_k`` is ignored.
    """
    if x.ndim != 2:
        raise ValueError(f"expected 2-D operand, got shape {x.shape}")
    if operand not in ("w", "i"):
        raise ValueError(operand)
    if scheme is not Scheme.TILED:
        return quantize(x, bits, _scheme_axes(scheme, operand), rounding, key)

    k_axis = 1 if operand == "w" else 0
    k = x.shape[k_axis]
    bk = block_k or k
    if k % bk:
        raise ValueError(f"block_k={bk} must divide K={k}")
    if operand == "w":  # [M, K] -> [M, K//bk, bk], block over last axis
        xr = x.reshape(x.shape[0], k // bk, bk)
        b = quantize(xr, bits, (2,), rounding, key)
        return BFPBlock(b.mantissa.reshape(x.shape),
                        b.exponent.reshape(x.shape[0], k // bk), bits)
    else:  # [K, N] -> [K//bk, bk, N], block over middle axis
        xr = x.reshape(k // bk, bk, x.shape[1])
        b = quantize(xr, bits, (1,), rounding, key)
        return BFPBlock(b.mantissa.reshape(x.shape),
                        b.exponent.reshape(k // bk, x.shape[1]), bits)


# ---------------------------------------------------------------------------
# Storage / datapath accounting (paper Table 1 and Fig. 2)
# ---------------------------------------------------------------------------

def num_block_exponents(scheme: Scheme, m: int, k: int, n: int,
                        block_k: Optional[int] = None) -> int:
    """NBE column of paper Table 1 (number of stored block exponents)."""
    if scheme is Scheme.EQ2:
        return 2
    if scheme is Scheme.EQ3:
        return m + n
    if scheme is Scheme.EQ4:
        return 1 + m
    if scheme is Scheme.EQ5:
        return 1 + n
    bk = block_k or k
    tiles = -(-k // bk)   # ceil: partial K-tiles still carry an exponent
    return (m + n) * tiles


def average_bits_per_element(bits_mantissa_with_sign: int, exp_bits: int,
                             block_elems: int) -> float:
    """Average stored bits per number: 1 + L_m + L_e/n (paper §3.1).

    ``bits_mantissa_with_sign`` follows our convention (includes sign), so
    the formula is L + L_e/n.
    """
    return bits_mantissa_with_sign + exp_bits / block_elems


def accumulator_bits(l_w: int, l_i: int, k: int) -> int:
    """Fixed-point accumulator width needed for a K-deep dot product.

    Paper Fig. 2 / §3.4: product needs L_W + L_I bits (both operands carry
    their sign bit here), accumulation of K terms adds ceil(log2 K) carries.
    """
    return l_w + l_i + int(np.ceil(np.log2(max(k, 2))))


def max_safe_k(l_w: int, l_i: int, acc_bits: int = 32) -> int:
    """Largest K for which int``acc_bits`` accumulation cannot overflow."""
    return 2 ** (acc_bits - l_w - l_i)
