"""Packed BFP container — the storage/wire format the paper promises.

Table 1's storage argument is ~``L`` bits per element plus one shared
exponent per block, but a :class:`~repro.core.bfp.BFPBlock` in memory
still pads mantissas to int8/int16 and exponents to int32.  This module
is the byte-real counterpart: a :class:`PackedBFP` serializes any
BFPBlock (every paper scheme, TILED layouts, prequant ``{"m", "s"}``
sidecars, flat wire blocks) into

  * a small self-describing header (version, mantissa width, mantissa /
    exponent-plane geometry, JSON metadata),
  * an **exponent plane**: one ``int8`` per block,
  * optionally a **width plane** (container version 3): one ``uint8``
    per block giving that block's effective mantissa width
    ``L_eff = min(L, 1 + bit_length(max |mantissa|))`` — blocks that
    occupy fewer bits than the policy's ``L`` store fewer bits
    (an all-zero block stores 1 bit/element), and
  * a **mantissa bitstream**: sign+mantissa packed at exactly the
    configured width — ``L`` everywhere for fixed-width containers, the
    block's ``L_eff`` for variable-width ones (offset-binary, MSB first,
    byte-padded at the very end only) — 6-bit mantissas really take
    6 bits.

Note that for a PROPERLY saturated BFP block the largest |mantissa| is
already >= 2^(L-2), so dense Gaussian weights need all L bits and the
width plane is pure overhead; the wins come from sparse/structured data
(all-zero blocks, gradient residuals, pruned channels) and — the big
one — from pairing variable width with a per-site precision-searched
PolicyMap (``repro.tune.precision``) whose smaller ``l_w`` shrink every
block.  ``benchmarks/pack_bench.py`` measures both honestly.

Round-trips are lossless by construction (integer mantissas and integer
exponents in, the same integers out), which is what lets the checkpoint
store (``checkpoint.store`` ``format="bfp_packed"``), the serving weight
loaders (``engine.bind`` on packed leaves), and the gradient wire
(``dist.compress``) all share this one container.  See DESIGN.md §10 and
docs/formats.md for the byte layout.

Everything here is host-side numpy (checkpoint/wire code), NOT jit-safe;
the in-graph quantizers stay in ``core.bfp``.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bfp
from repro.core.bfp import BFPBlock, Rounding, Scheme

__all__ = [
    "PackedBFP", "IntegrityError", "pack_block", "unpack_block",
    "pack_prequant", "unpack_prequant", "unpack_dequant", "pack_matrix",
    "pack_param_tree", "is_packed", "packed_nbytes",
]

_MAGIC = b"BFPK"
#: container version written by ``to_bytes`` for fixed-width data.  v2
#: adds a CRC32 of the exponent plane + mantissa bitstream to the fixed
#: header; v1 (no checksum) containers remain readable.
_VERSION = 2
#: container version for variable-width data: inserts a per-block uint8
#: width plane between the exponent plane and the bitstream (the CRC
#: covers it).  Fixed-width containers keep writing version 2, so every
#: artifact produced before this feature parses byte-identically.
_VERSION_VAR = 3
_READ_VERSIONS = (1, 2, 3)
#: fixed part of the v2/v3 serialized header (magic, version, bits,
#: ndims, meta length, crc32) — see ``to_bytes``
_FIXED_HEADER = 4 + 1 + 1 + 1 + 1 + 4 + 4
#: v1 fixed header (no crc32 field)
_FIXED_HEADER_V1 = 4 + 1 + 1 + 1 + 1 + 4


class IntegrityError(ValueError):
    """A container's integrity machinery rejected its bytes: the stored
    CRC32 does not match the data (payload / exponent plane / width
    plane corrupted after serialization — bit rot, torn write, wire
    fault), or a v3 width plane is structurally invalid (a block
    declares a width outside ``[1, L]``, or the plane / its bitstream is
    truncated).  Raised by :meth:`PackedBFP.verify` and, by default, by
    :meth:`PackedBFP.from_bytes` on v2/v3 containers; messages name the
    offending byte offset where one exists."""


def _mantissa_dtype(bits: int):
    return jnp.int8 if bits <= 8 else (jnp.int16 if bits <= 16 else jnp.int32)


#: elements per (un)pack chunk — bounds transient host RAM at
#: ~CHUNK*bits bytes (a few tens of MB) regardless of leaf size, so
#: full-size models decode without an n*bits*8-byte intermediate.
#: Must stay a multiple of 8 so every non-final chunk's bitstream ends
#: on a byte boundary.
_CHUNK = 1 << 20


def _pack_bits(m: np.ndarray, bits: int) -> bytes:
    """Bit-pack signed mantissas at exactly ``bits`` wide (MSB first).

    Values are stored offset-binary (``m + 2^(L-1)``), so the legal
    mantissa range ``[-(2^(L-1)-1), 2^(L-1)-1]`` maps into
    ``[1, 2^L - 2]`` — always representable in ``bits`` unsigned bits.
    Chunked: peak transient memory is ~``_CHUNK * bits`` bytes.
    """
    flat = np.asarray(m).reshape(-1)
    lim = (1 << (bits - 1)) - 1
    if flat.size and (flat.min() < -lim or flat.max() > lim):
        raise ValueError(
            f"mantissa outside [-{lim}, {lim}] for L={bits} (got "
            f"[{flat.min()}, {flat.max()}]) — not a {bits}-bit BFP block")
    shifts = np.arange(bits - 1, -1, -1, dtype=np.uint32)
    out = bytearray()
    for start in range(0, flat.size, _CHUNK):
        u = (flat[start:start + _CHUNK].astype(np.int64)
             + (lim + 1)).astype(np.uint32)
        bitplane = ((u[:, None] >> shifts) & 1).astype(np.uint8)
        out += np.packbits(bitplane.reshape(-1)).tobytes()
    return bytes(out)


def _unpack_bits(payload: bytes, n: int, bits: int) -> np.ndarray:
    """Inverse of :func:`_pack_bits` — n int32 mantissas out (chunked)."""
    if n == 0:
        return np.zeros((0,), np.int32)
    need = -(-n * bits // 8)
    if len(payload) < need:
        raise ValueError(f"mantissa bitstream truncated: have "
                         f"{len(payload)} bytes, need {need}")
    buf = np.frombuffer(payload, np.uint8)
    out = np.empty(n, np.int32)
    for start in range(0, n, _CHUNK):
        cnt = min(_CHUNK, n - start)
        bit0 = start * bits                      # byte-aligned: 8 | _CHUNK
        byte0, byte1 = bit0 // 8, -(-(bit0 + cnt * bits) // 8)
        raw = np.unpackbits(buf[byte0:byte1],
                            count=cnt * bits).reshape(cnt, bits)
        acc = np.zeros(cnt, np.int32)
        for b in range(bits):                    # shift-accumulate: no
            acc = (acc << 1) | raw[:, b]         # (n, bits) int64 matmul
        out[start:start + cnt] = acc
    return out - (1 << (bits - 1))


# ---------------------------------------------------------------------------
# Variable-width (v3) plane mapping + codec
# ---------------------------------------------------------------------------

def _gemm_view(m: np.ndarray, exp_shape: Tuple[int, ...]) -> np.ndarray:
    """View the mantissa tensor with one axis per exponent-plane axis.

    Identity for same-rank layouts (paper schemes' keepdims planes,
    TILED's ``[rows, K/bk]``, the wire's ``[nb, 1]``); conv HWIO
    mantissas (4-D ``m`` against the 2-D GEMM-view ``[K/bk, N]``
    sidecar) reshape to ``(kh*kw*c, n)`` — a C-order-preserving view, so
    bitstream element order is unchanged.  Every exponent axis must
    divide its mantissa axis (size-1 axes broadcast, i.e. divide
    trivially).
    """
    if m.ndim == 4 and len(exp_shape) == 2:
        kh, kw, c, n = m.shape
        m = m.reshape(kh * kw * c, n)
    if m.ndim != len(exp_shape):
        raise ValueError(
            f"cannot map exponent plane {exp_shape} onto mantissa shape "
            f"{m.shape} for variable-width packing")
    for sm, se in zip(m.shape, exp_shape):
        if se < 1 or sm % se:
            raise ValueError(
                f"exponent plane {exp_shape} does not tile mantissa "
                f"shape {m.shape} (axis size {sm} vs {se})")
    return m


def _elem_widths(m: np.ndarray) -> np.ndarray:
    """Per-element occupied width: ``1 + bit_length(|m|)`` (sign bit +
    magnitude bits; zero occupies the minimal 1 bit).  Exact for
    |m| < 2^24 (container ``bits`` <= 24) via float64 frexp."""
    a = np.abs(np.asarray(m, np.int64))
    _, e = np.frexp(a.astype(np.float64))     # e == bit_length for a > 0
    return np.where(a > 0, e + 1, 1).astype(np.int64)


def _reduce_max_to(vals: np.ndarray, exp_shape: Tuple[int, ...]
                   ) -> np.ndarray:
    """Max-reduce a per-element plane onto the exponent-plane geometry
    (same-rank view from :func:`_gemm_view`).  Blocked axes are
    CONTIGUOUS groups — the inverse of ``BFPBlock.scale``'s repeat."""
    split, red = [], []
    for i, (sv, se) in enumerate(zip(vals.shape, exp_shape)):
        split += [se, sv // se]
        red.append(2 * i + 1)
    if not split:
        return vals
    return vals.reshape(split).max(axis=tuple(red))


def _expand_plane(plane: np.ndarray, view_shape: Tuple[int, ...]
                  ) -> np.ndarray:
    """Inverse of :func:`_reduce_max_to`: broadcast/repeat a per-block
    plane to per-element over the same-rank mantissa view."""
    out = plane
    for ax, (sv, se) in enumerate(zip(view_shape, plane.shape)):
        if se != sv:
            out = np.repeat(out, sv // se, axis=ax)
    return out


def _width_planes(m: np.ndarray, exp_shape: Tuple[int, ...], bits: int
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Derive the per-block width plane ``L_eff = min(L, 1 +
    bit_length(max |m|))`` and its per-element expansion (flat, C-order
    of the stored mantissa tensor)."""
    view = _gemm_view(np.asarray(m), exp_shape)
    widths = np.minimum(_reduce_max_to(_elem_widths(view), exp_shape),
                        bits)
    wid_elem = _expand_plane(widths, view.shape).reshape(-1)
    return widths.astype(np.uint8).reshape(exp_shape), wid_elem


def _pack_bits_var(m: np.ndarray, wid_elem: np.ndarray) -> bytes:
    """Bit-pack signed mantissas, element ``i`` at exactly
    ``wid_elem[i]`` bits (its block's effective width), MSB first,
    offset-binary ``m + 2^(w-1)``.  Chunked like :func:`_pack_bits`;
    chunk seams are NOT byte-aligned here, so up to 7 leftover bits
    carry into the next chunk's bit buffer.
    """
    flat = np.asarray(m).reshape(-1).astype(np.int64)
    w = np.asarray(wid_elem).reshape(-1).astype(np.int64)
    lim = (1 << (w - 1)) - 1
    bad = np.abs(flat) > lim
    if flat.size and bad.any():
        i = int(np.argmax(bad))
        raise ValueError(
            f"mantissa {flat[i]} at element {i} exceeds its block's "
            f"effective width {w[i]} — width plane does not describe "
            f"this data")
    out = bytearray()
    carry = np.zeros(0, np.uint8)
    for start in range(0, flat.size, _CHUNK):
        f = flat[start:start + _CHUNK]
        ww = w[start:start + _CHUNK]
        u = (f + (1 << (ww - 1))).astype(np.uint64)
        ends = carry.size + np.cumsum(ww)
        bitbuf = np.zeros(int(ends[-1]) if ww.size else carry.size,
                          np.uint8)
        bitbuf[:carry.size] = carry
        starts = ends - ww
        for width in np.unique(ww):
            sel = ww == width
            s0, uu = starts[sel], u[sel]
            for j in range(int(width)):
                bitbuf[s0 + j] = (uu >> int(width - 1 - j)) & 1
        nfull = (bitbuf.size // 8) * 8
        out += np.packbits(bitbuf[:nfull]).tobytes()
        carry = bitbuf[nfull:]
    if carry.size:
        out += np.packbits(carry).tobytes()   # final byte zero-padded
    return bytes(out)


def _unpack_bits_var(payload: bytes, wid_elem: np.ndarray) -> np.ndarray:
    """Inverse of :func:`_pack_bits_var` — int32 mantissas out (chunked,
    bit offsets via cumsum)."""
    w = np.asarray(wid_elem).reshape(-1).astype(np.int64)
    n = w.size
    if n == 0:
        return np.zeros((0,), np.int32)
    ends = np.cumsum(w)
    starts = ends - w
    need = -(-int(ends[-1]) // 8)
    if len(payload) < need:
        raise ValueError(f"mantissa bitstream truncated: have "
                         f"{len(payload)} bytes, need {need}")
    buf = np.frombuffer(payload, np.uint8)
    out = np.empty(n, np.int32)
    for c0 in range(0, n, _CHUNK):
        c1 = min(c0 + _CHUNK, n)
        byte0 = int(starts[c0]) // 8
        byte1 = -(-int(ends[c1 - 1]) // 8)
        bits_c = np.unpackbits(buf[byte0:byte1])
        local = starts[c0:c1] - byte0 * 8
        ww = w[c0:c1]
        acc = np.zeros(c1 - c0, np.int64)
        for width in np.unique(ww):
            sel = ww == width
            s0 = local[sel]
            a = np.zeros(s0.size, np.int64)
            for j in range(int(width)):
                a = (a << 1) | bits_c[s0 + j]
            acc[sel] = a - (1 << int(width - 1))
        out[c0:c1] = acc
    return out


def _var_payload_need(shape: Tuple[int, ...], exp_shape: Tuple[int, ...],
                      widths: np.ndarray) -> int:
    """Exact variable-width bitstream size.  Every block covers the same
    ``n / n_blocks`` elements (blocked axes tile evenly), so the total
    is ``ceil(elems_per_block * sum(widths) / 8)``."""
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    n_exp = int(np.prod(exp_shape, dtype=np.int64)) if exp_shape else 1
    if n_exp < 1 or n % n_exp:
        raise ValueError(f"exponent plane {exp_shape} does not evenly "
                         f"tile shape {shape}")
    total_bits = (n // n_exp) * int(np.sum(widths, dtype=np.int64))
    return -(-total_bits // 8)


def _exp_int8(e: np.ndarray) -> np.ndarray:
    e = np.asarray(e)
    if e.size and (e.min() < -128 or e.max() > 127):
        raise ValueError(
            f"block exponent outside int8 range [-128, 127] (got "
            f"[{e.min()}, {e.max()}]) — cannot store one int8 per block")
    return e.astype(np.int8)


@dataclasses.dataclass(frozen=True)
class PackedBFP:
    """One bit-packed BFP tensor: header + exponent plane + bitstream.

    ``shape`` is the mantissa tensor's shape (== the source tensor's);
    ``exp_shape`` the exponent plane's (one entry per block).  ``meta``
    is small JSON-serializable provenance (scheme, operand, block_k,
    ``kind`` = "block" | "prequant" | "wire", conv HWIO geometry, ...) —
    the restore paths read it, the container does not depend on it.
    """

    bits: int
    shape: Tuple[int, ...]
    exp_shape: Tuple[int, ...]
    exponents: np.ndarray            #: int8, C-order, ``exp_shape``
    payload: bytes                   #: ceil(prod(shape) * bits / 8) bytes
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: CRC32 the container was DESERIALIZED with (v2 headers); None for
    #: freshly built or v1 containers.  ``verify()`` checks data against
    #: it, so corruption introduced after parsing is still detectable
    #: in-memory.  Excluded from equality: two containers with the same
    #: data are the same container.
    stored_crc: Optional[int] = dataclasses.field(default=None,
                                                  compare=False)
    #: variable-width (v3) containers carry one uint8 effective width
    #: per block, same geometry as the exponent plane; ``None`` means
    #: fixed-width (every element at ``bits``).  Equality-relevant: two
    #: containers with different width planes hold different bitstreams.
    widths: Optional[np.ndarray] = None

    def __post_init__(self):
        if not 2 <= self.bits <= 24:
            raise ValueError(f"bits must be in [2, 24], got {self.bits}")
        if tuple(self.exponents.shape) != tuple(self.exp_shape):
            raise ValueError("exponent plane shape mismatch")
        n = int(np.prod(self.shape, dtype=np.int64)) if self.shape else 1
        if self.widths is None:
            need = -(-n * self.bits // 8)
        else:
            if tuple(self.widths.shape) != tuple(self.exp_shape):
                raise ValueError("width plane shape mismatch (must match "
                                 "the exponent plane, one width per block)")
            wmin = int(self.widths.min()) if self.widths.size else 1
            wmax = int(self.widths.max()) if self.widths.size else 1
            if wmin < 1 or wmax > self.bits:
                raise ValueError(
                    f"block widths [{wmin}, {wmax}] outside the legal "
                    f"[1, {self.bits}] for an L={self.bits} container")
            need = _var_payload_need(self.shape, self.exp_shape,
                                     self.widths)
        if len(self.payload) != need:
            raise ValueError(f"payload is {len(self.payload)} bytes; "
                             f"shape {self.shape} at L={self.bits}"
                             f"{' (variable-width)' if self.widths is not None else ''}"
                             f" needs {need}")

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64))

    @property
    def variable(self) -> bool:
        """True when this container stores per-block effective widths."""
        return self.widths is not None

    @property
    def nbytes(self) -> int:
        """Exact serialized size (fixed header + dims + meta + exponent
        plane [+ width plane] + bitstream)."""
        meta_len = len(json.dumps(self.meta).encode())
        return (_FIXED_HEADER + 4 * (len(self.shape) + len(self.exp_shape))
                + meta_len + self.exponents.size
                + (self.exponents.size if self.widths is not None else 0)
                + len(self.payload))

    # -- integrity ----------------------------------------------------------

    def crc32(self) -> int:
        """CRC32 over the exponent plane + (v3) width plane + mantissa
        bitstream — exactly the bytes a bit-flip in storage or on the
        wire would corrupt.  The header (shape/meta) is covered by its
        own structural validation in :meth:`from_bytes`."""
        crc = zlib.crc32(self.exponents.astype(np.int8).tobytes(order="C"))
        if self.widths is not None:
            crc = zlib.crc32(
                self.widths.astype(np.uint8).tobytes(order="C"), crc)
        return zlib.crc32(self.payload, crc) & 0xFFFFFFFF

    def verify(self) -> "PackedBFP":
        """Check data against the deserialized CRC (v2 containers).

        Returns ``self`` on success (or when no stored CRC exists — v1
        containers and freshly built ones have nothing to check
        against); raises :class:`IntegrityError` on mismatch.  The
        checkpoint restore path and the wire unpack path both call this,
        so a flipped payload byte is caught before it reaches a model.
        """
        if self.stored_crc is not None:
            actual = self.crc32()
            if actual != self.stored_crc:
                raise IntegrityError(
                    f"PackedBFP checksum mismatch: stored crc32 "
                    f"{self.stored_crc:#010x} != computed {actual:#010x} "
                    f"(shape {self.shape}, L={self.bits}, "
                    f"kind={self.meta.get('kind')!r}) — payload or "
                    f"exponent plane corrupted after serialization")
        return self

    # -- serialization ------------------------------------------------------

    def to_bytes(self) -> bytes:
        """Serialize (docs/formats.md layout, container version 2 for
        fixed-width data, 3 for variable-width):

        ========  =========================================================
        bytes     field
        ========  =========================================================
        0:4       magic ``b"BFPK"``
        4         version (2 fixed-width | 3 variable-width)
        5         mantissa width L, sign included (v3: the MAXIMUM width;
                  per-block effective widths live in the width plane)
        6, 7      ndim(shape), ndim(exp_shape)
        8:12      meta JSON length (u32 LE)
        12:16     crc32 of exponent [+ width] plane + bitstream (u32 LE)
        ..        shape dims, then exp_shape dims (u32 LE each)
        ..        meta JSON (utf-8)
        ..        exponent plane (int8, C-order, one per block)
        ..        width plane (uint8, C-order, one per block; v3 ONLY)
        ..        mantissa bitstream (offset-binary, MSB first)
        ========  =========================================================

        The CRC is recomputed from the CURRENT data at every
        serialization (checksums certify bytes, not history).
        """
        meta_b = json.dumps(self.meta).encode()
        ver = _VERSION if self.widths is None else _VERSION_VAR
        out = [_MAGIC,
               struct.pack("<BBBBII", ver, self.bits, len(self.shape),
                           len(self.exp_shape), len(meta_b), self.crc32())]
        for d in (*self.shape, *self.exp_shape):
            out.append(struct.pack("<I", d))
        out.append(meta_b)
        out.append(self.exponents.astype(np.int8).tobytes(order="C"))
        if self.widths is not None:
            out.append(self.widths.astype(np.uint8).tobytes(order="C"))
        out.append(self.payload)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, buf: bytes, verify: bool = True) -> "PackedBFP":
        """Parse a serialized container (v1 or v2).

        Every declared length is validated against the actual buffer
        BEFORE slicing, so a truncated or clipped buffer raises a clear
        ``ValueError`` naming the offending offset instead of slicing
        short silently or surfacing a bare ``struct.error``.  v2
        containers additionally verify the stored CRC32 (raise
        :class:`IntegrityError` on mismatch) unless ``verify=False`` —
        fault-injection campaigns parse corrupted containers on purpose.
        """
        buf = bytes(buf)
        if len(buf) < _FIXED_HEADER_V1:
            raise ValueError(
                f"truncated container: {len(buf)} bytes, need at least "
                f"{_FIXED_HEADER_V1} for the fixed header")
        if buf[:4] != _MAGIC:
            raise ValueError(f"not a PackedBFP container (magic "
                             f"{buf[:4]!r} != {_MAGIC!r})")
        ver, bits, nd, ne, meta_len = struct.unpack(
            "<BBBBI", buf[4:_FIXED_HEADER_V1])
        if ver not in _READ_VERSIONS:
            raise ValueError(f"unsupported PackedBFP version {ver}")
        variable = ver >= 3
        stored_crc = None
        off = _FIXED_HEADER_V1
        if ver >= 2:
            if len(buf) < _FIXED_HEADER:
                raise ValueError(
                    f"truncated container: {len(buf)} bytes, need "
                    f"{_FIXED_HEADER} for the v2 fixed header")
            (stored_crc,) = struct.unpack("<I", buf[off:off + 4])
            off += 4
        if len(buf) < off + 4 * (nd + ne):
            raise ValueError(
                f"truncated container: dims region needs "
                f"{4 * (nd + ne)} bytes at offset {off}, buffer has "
                f"{len(buf) - off}")
        dims = struct.unpack(f"<{nd + ne}I", buf[off:off + 4 * (nd + ne)])
        off += 4 * (nd + ne)
        shape, exp_shape = dims[:nd], dims[nd:]
        if len(buf) < off + meta_len:
            raise ValueError(
                f"truncated container: meta region declares {meta_len} "
                f"bytes at offset {off}, buffer has {len(buf) - off}")
        meta = json.loads(buf[off:off + meta_len].decode()) if meta_len \
            else {}
        off += meta_len
        n_exp = int(np.prod(exp_shape, dtype=np.int64)) if ne else 1
        if len(buf) < off + n_exp:
            raise ValueError(
                f"truncated container: exponent plane needs {n_exp} "
                f"bytes at offset {off}, buffer has {len(buf) - off}")
        exps = np.frombuffer(buf[off:off + n_exp],
                             np.int8).reshape(exp_shape)
        off += n_exp
        n = int(np.prod(shape, dtype=np.int64)) if nd else 1
        widths = None
        if variable:
            if len(buf) < off + n_exp:
                raise IntegrityError(
                    f"truncated container: width plane needs {n_exp} "
                    f"bytes at offset {off}, buffer has {len(buf) - off}")
            widths = np.frombuffer(buf[off:off + n_exp],
                                   np.uint8).reshape(exp_shape)
            flatw = widths.reshape(-1)
            bad = (flatw < 1) | (flatw > bits)
            if bad.any():
                i = int(np.argmax(bad))
                raise IntegrityError(
                    f"width plane corrupt: block {i} declares width "
                    f"{flatw[i]} outside [1, {bits}] for an L={bits} "
                    f"container (byte offset {off + i})")
            off += n_exp
            if n_exp and n % n_exp:
                raise IntegrityError(
                    f"width plane geometry invalid: {n_exp} blocks do "
                    f"not evenly tile {n} elements")
            need = _var_payload_need(tuple(shape), tuple(exp_shape),
                                     widths)
            if len(buf) - off < need:
                raise IntegrityError(
                    f"truncated container: variable-width bitstream "
                    f"needs {need} bytes at offset {off}, buffer has "
                    f"{len(buf) - off}")
        else:
            need = -(-n * bits // 8)
        payload = buf[off:off + need]
        if len(payload) != need:
            raise ValueError(f"truncated container: {len(payload)} payload "
                             f"bytes at offset {off}, need {need}")
        p = cls(bits=bits, shape=tuple(shape), exp_shape=tuple(exp_shape),
                exponents=exps, payload=payload, meta=meta,
                stored_crc=stored_crc, widths=widths)
        return p.verify() if verify else p


def is_packed(x: Any) -> bool:
    return isinstance(x, PackedBFP)


def packed_nbytes(shape: Tuple[int, ...], exp_shape: Tuple[int, ...],
                  bits: int, meta_len: int = 2) -> int:
    """Analytic serialized size for a hypothetical container (the Table-1
    accounting, byte-exact): header + one int8 per block + the bitstream."""
    n = int(np.prod(shape, dtype=np.int64))
    n_exp = int(np.prod(exp_shape, dtype=np.int64))
    return (_FIXED_HEADER + 4 * (len(shape) + len(exp_shape)) + meta_len
            + n_exp + -(-n * bits // 8))


# ---------------------------------------------------------------------------
# BFPBlock <-> container
# ---------------------------------------------------------------------------

def _pack_payload(m: np.ndarray, exp_shape: Tuple[int, ...], bits: int,
                  variable: bool
                  ) -> Tuple[bytes, Optional[np.ndarray]]:
    """Build (payload, width plane) — width plane ``None`` when fixed."""
    if not variable:
        return _pack_bits(m, bits), None
    widths, wid_elem = _width_planes(m, exp_shape, bits)
    return _pack_bits_var(m, wid_elem), widths


def _unpack_mantissas(p: PackedBFP) -> np.ndarray:
    """Decode a container's bitstream (fixed or variable width) to int32
    mantissas in the stored tensor shape."""
    if p.widths is None:
        return _unpack_bits(p.payload, p.n_elements, p.bits).reshape(p.shape)
    view = _gemm_view(np.empty(p.shape, np.int8), p.exp_shape)
    wid_elem = _expand_plane(p.widths.astype(np.int64).reshape(p.exp_shape),
                             view.shape).reshape(-1)
    return _unpack_bits_var(p.payload, wid_elem).reshape(p.shape)


def pack_block(blk: BFPBlock, variable: bool = False,
               **meta: Any) -> PackedBFP:
    """Serialize a BFPBlock losslessly (any scheme/axes layout, incl. the
    TILED non-keepdims exponent planes).  ``variable=True`` packs each
    block at its effective width (v3 container)."""
    m = np.asarray(blk.mantissa)
    e = np.asarray(blk.exponent)
    meta.setdefault("kind", "block")
    payload, widths = _pack_payload(m, tuple(e.shape), blk.bits, variable)
    return PackedBFP(bits=blk.bits, shape=tuple(m.shape),
                     exp_shape=tuple(e.shape), exponents=_exp_int8(e),
                     payload=payload, meta=dict(meta), widths=widths)


def unpack_block(p: PackedBFP) -> BFPBlock:
    """Reconstruct the exact BFPBlock (bit-identical mantissas/exponents,
    fixed- or variable-width container alike)."""
    m = _unpack_mantissas(p)
    return BFPBlock(mantissa=jnp.asarray(m.astype(_mantissa_dtype(p.bits))),
                    exponent=jnp.asarray(
                        p.exponents.astype(np.int32)).reshape(p.exp_shape),
                    bits=p.bits)


def pack_matrix(w: jax.Array, bits: int, operand: str, scheme: Scheme,
                block_k: Optional[int] = None,
                rounding: Rounding = Rounding.ROUND,
                variable: bool = False,
                **meta: Any) -> PackedBFP:
    """Quantize one GEMM operand under ``scheme`` and pack it — the
    one-call path benchmarks and tests use to measure real bytes."""
    blk = bfp.bfp_quantize_matrix(w, bits, operand, scheme, block_k,
                                  rounding)
    return pack_block(blk, variable=variable, scheme=scheme.value,
                      operand=operand, block_k=block_k, **meta)


# ---------------------------------------------------------------------------
# Prequant {"m", "s"} sidecars <-> container
# ---------------------------------------------------------------------------

def _steps_to_exponents(s: np.ndarray, bits: int) -> np.ndarray:
    """Recover integer BLOCK exponents from the power-of-two step sidecar:
    s = 2^(eps - (L-2)) exactly, so frexp is exact too."""
    s = np.asarray(s, np.float32)
    if s.size and (not np.all(np.isfinite(s)) or np.any(s <= 0)):
        raise ValueError("prequant scale sidecar must be positive finite")
    frac, e = np.frexp(s.astype(np.float64))
    if s.size and not np.all(frac == 0.5):
        raise ValueError("prequant scales are not exact powers of two — "
                         "refusing a lossy pack")
    return (e - 1 + (bits - 2)).astype(np.int64)


def pack_prequant(d: Dict[str, Any], bits: int, variable: bool = False,
                  **meta: Any) -> PackedBFP:
    """Pack a prequant ``{"m", "s"}`` weight losslessly.

    ``bits`` is the policy's ``l_w`` (the mantissa storage width; int8
    sidecars of an L<=8 policy really shrink to L bits here).  Works for
    2-D, stacked ``[.., K, N]``, and conv-HWIO mantissas (``s`` stays in
    the GEMM view ``[K//bk, N]``): the container records both shapes, so
    :func:`unpack_prequant` reproduces the dict bit-exactly.
    ``variable=True`` additionally stores each block at its effective
    occupied width (v3 container) — still bit-exact on round trip.
    """
    m, s = np.asarray(d["m"]), np.asarray(d["s"])
    eps = _steps_to_exponents(s, bits)
    meta.setdefault("kind", "prequant")
    payload, widths = _pack_payload(m, tuple(s.shape), bits, variable)
    return PackedBFP(bits=bits, shape=tuple(m.shape),
                     exp_shape=tuple(s.shape), exponents=_exp_int8(eps),
                     payload=payload, meta=dict(meta), widths=widths)


def unpack_prequant(p: PackedBFP) -> Dict[str, jax.Array]:
    """Container -> the exact ``{"m", "s"}`` sidecar dict ``pack_prequant``
    consumed — int mantissas and float32 power-of-two steps, no float
    weight ever materialized.  Fixed- and variable-width containers
    decode identically (``m`` dtype follows the container's L, so a
    variable container restores the same dtype its fixed twin would)."""
    m = _unpack_mantissas(p)
    steps = np.ldexp(1.0, p.exponents.astype(np.int64) - (p.bits - 2))
    return {"m": jnp.asarray(m.astype(_mantissa_dtype(p.bits))),
            "s": jnp.asarray(steps.astype(np.float32)).reshape(p.exp_shape)}


def unpack_dequant(p: PackedBFP) -> jax.Array:
    """Container -> dense float32 (``m * s``), for float-tree restores.

    Handles the conv case (HWIO mantissa with a GEMM-view ``[K//bk, N]``
    sidecar) by dequantizing in the GEMM view and reshaping back.
    """
    from repro.core.prequant import dequantize_prequant
    if p.meta.get("kind") == "block":
        return unpack_block(p).dequantize()
    d = unpack_prequant(p)
    m, s = d["m"], d["s"]
    if m.ndim == 4 and s.ndim == 2:          # conv HWIO mantissa
        kh, kw, c, n = m.shape
        flat = dequantize_prequant({"m": m.reshape(kh * kw * c, n), "s": s})
        return flat.reshape(kh, kw, c, n)
    return dequantize_prequant(d)


# ---------------------------------------------------------------------------
# Param-tree packing (the checkpoint walk)
# ---------------------------------------------------------------------------

def pack_param_tree(params: Any, policy: Any, kind: str = "auto",
                    variable: bool = False) -> Any:
    """Replace every prequant-eligible GEMM/conv weight leaf with a
    :class:`PackedBFP`; every other leaf (norm gains, biases, embeddings,
    odd-K weights, rules resolving to None) stays untouched.

    Uses the SAME leaf selection and layer-path derivation as
    ``core.prequant.quantize_param_tree`` / ``quantize_cnn_param_tree``
    (shared walkers), so a packed checkpoint stores exactly the leaves a
    bound plan would pre-quantize — restoring to ``{"m", "s"}`` sidecars
    is bit-identical to binding the float tree under the same policy.
    A tree that ALREADY holds prequant ``{"m", "s"}`` dicts at those
    sites (e.g. ``plan.params`` from ``engine.bind`` — the bind-once,
    checkpoint-the-bound-weights flow) packs them as-is, losslessly.

    ``kind``: "cnn" | "lm" | "auto" (same detection ``engine.bind`` uses).
    ``variable=True`` writes v3 variable-width containers (each block at
    its effective occupied width) — the checkpoint store's
    ``format="bfp_packed_v2"``.
    """
    from repro.core import prequant as PQ
    if policy is None:
        raise ValueError("pack_param_tree needs a BFPPolicy or PolicyMap "
                         "(got None — nothing would be packed)")
    if kind == "auto":
        kind = PQ.detect_tree_kind(params)   # same detector engine.bind uses
    if kind not in ("cnn", "lm"):
        raise ValueError(f"kind must be 'cnn', 'lm', or 'auto'; got {kind!r}")

    def pack_one(leaf, pol, path, conv):
        if PQ.is_prequant(leaf):            # already bound: pack losslessly
            d = leaf
        else:
            d = (PQ.prequant_conv_leaf if conv
                 else PQ.prequant_leaf)(leaf, pol)
            if not PQ.is_prequant(d):
                return leaf                 # odd K etc.: stays float
        return pack_prequant(d, pol.l_w, variable=variable, path=path,
                             conv=conv, block_k=pol.block_k,
                             scheme=pol.scheme.value)

    def one(tree_path, leaf):
        keys = PQ._path_keys(tree_path)
        prequantized = PQ.is_prequant(leaf)
        arr = leaf["m"] if prequantized else leaf
        if not hasattr(arr, "ndim") or (
                not prequantized and
                not jnp.issubdtype(arr.dtype, jnp.floating)):
            return leaf
        if kind == "lm":
            if not PQ.lm_eligible(keys) or arr.ndim < 2:
                return leaf
            path = PQ.lm_rule_path(keys)
            pol = PQ._resolve(policy, path)
            return leaf if pol is None else pack_one(leaf, pol, path, False)
        if not keys or keys[-1] != "w":
            return leaf
        path = PQ.cnn_rule_path(params, keys)
        pol = None if path is None else PQ._resolve(policy, path)
        if pol is None or arr.ndim not in (2, 4):
            return leaf
        return pack_one(leaf, pol, path, arr.ndim == 4)

    return jax.tree_util.tree_map_with_path(one, params,
                                            is_leaf=PQ.is_prequant)
