"""Convolution-as-GEMM geometry and layout, shared repo-wide.

One K-order for the conv GEMM view, everywhere: **HWIO-major** — the
patch-matrix column index is ``k = (di*kw + dj)*C + c`` (spatial offsets
outer, channel innermost), so the weight view is literally
``w_hwio.reshape(kh*kw*C, out_ch)`` with no transpose.  This order is
what makes the implicit-im2col Pallas kernel cheap: a contiguous K range
of the patch row is a contiguous channel slab of the NHWC input, so the
kernel forms BFP blocks from static slices instead of gathers.  The
materialized :func:`im2col` route, ``prequant_conv_leaf`` sidecars, and
the fused kernel all share this order, which is what lets them agree
bit-exactly for Scheme.TILED with a common ``block_k``.

(The pre-engine code used the channel-major order that
``conv_general_dilated_patches`` emits natively; per-column and
whole-matrix schemes are permutation-invariant, so only TILED numerics
shifted — by design, to the kernel-friendly partition.)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["conv_geometry", "im2col", "conv_weight_matrix"]


def conv_geometry(h: int, w: int, kh: int, kw: int, stride: int,
                  padding: str) -> Tuple[int, int, Tuple[int, int],
                                         Tuple[int, int]]:
    """XLA's SAME/VALID geometry: (oh, ow, (pad_top, pad_bot),
    (pad_left, pad_right))."""
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w // stride)
        ph = max((oh - 1) * stride + kh - h, 0)
        pw = max((ow - 1) * stride + kw - w, 0)
        return oh, ow, (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2)
    if padding == "VALID":
        if h < kh or w < kw:
            raise ValueError(f"VALID conv: input {h}x{w} smaller than "
                             f"kernel {kh}x{kw}")
        return (h - kh) // stride + 1, (w - kw) // stride + 1, (0, 0), (0, 0)
    raise ValueError(f"padding must be 'SAME' or 'VALID', got {padding!r}")


def im2col(x: jax.Array, kh: int, kw: int, stride: int,
           padding: str) -> Tuple[jax.Array, Tuple[int, int, int]]:
    """NHWC -> patch matrix [B*OH*OW, kh*kw*C] (receptive fields as rows).

    The paper's I matrix in NN orientation, in the repo's HWIO-major
    K-order.  ``conv_general_dilated_patches`` emits channel-major
    features, so the feature axis is reordered here.
    """
    b, _, _, c = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    oh, ow = patches.shape[1], patches.shape[2]
    p = patches.reshape(b, oh, ow, c, kh, kw)
    p = jnp.transpose(p, (0, 1, 2, 4, 5, 3))       # -> (kh, kw, C) order
    return p.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def conv_weight_matrix(w_hwio: jax.Array) -> jax.Array:
    """HWIO kernel -> its GEMM view [kh*kw*C, out_ch] (HWIO-major K)."""
    kh, kw, c, n = w_hwio.shape
    return w_hwio.reshape(kh * kw * c, n)
