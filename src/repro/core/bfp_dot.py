"""BFP GEMM — the paper's fixed-point convolution datapath, in JAX.

``bfp_dot(x, w, policy)`` computes ``x @ w`` where both operands are first
block-formatted (paper eq. 1) under the policy's partition scheme and the
multiply-accumulate runs in the INTEGER domain (paper Fig. 2), followed by a
single power-of-two rescale per block pair.  With ``policy=None`` it is
exactly ``jnp.dot`` — the floating-point reference the paper compares
against.

Orientation note: the paper writes O = W[M,K] @ I[K,N] with filters as W
*rows* and receptive fields as I *columns*.  Neural-net code computes
``y[B,N] = x[B,K] @ w[K,N]`` — x rows are the paper's I columns and w
columns are the paper's W rows.  The scheme mapping used here:

    =======  ====================  ====================
    scheme   w blocks (paper W)    x blocks (paper I)
    =======  ====================  ====================
    EQ2      whole matrix          whole matrix
    EQ3      per column            per row
    EQ4      per column            whole matrix     <- paper's choice
    EQ5      whole matrix          per row
    TILED    per (column, K-tile)  per (row, K-tile)
    =======  ====================  ====================

Gradients: quantization is piecewise constant, so by default a
straight-through estimator passes gradients through the dequantized
operands (BFP-QAT, beyond-paper; the paper itself is inference-only).

RECONCILIATION with ``repro.grad`` (the BFP autodiff subsystem): the
``_bfp_matmul_ste`` custom_vjp below is the LEGACY float-gradient mode —
it engages only when :func:`bfp_matmul_2d` is called directly (the
emulated backend's internal route) and always returns float gradients
over the dequantized operands.  Every public entry point
(``engine.gemm`` / ``engine.conv2d`` / :func:`bfp_dot`) now wraps the
whole site in the ``repro.grad`` custom VJP FIRST, whose
``straight_through=True`` fallback reproduces exactly this estimator
(``g @ wq.T``, ``xq.T @ g`` — pinned bit-exact in
tests/test_grad.py::test_default_policy_matches_legacy_ste), and whose
grad-path PolicyMap rules / ``straight_through=False`` additionally
quantize the backward GEMMs on the engine datapath.  The inner STE
never fires on the routed path (the outer custom_vjp owns the VJP), so
the two cannot disagree; this shim is kept for direct
``bfp_matmul_2d`` callers.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp
from repro.core.bfp import BFPBlock, Scheme
from repro.core.policy import BFPPolicy

__all__ = ["bfp_dot", "bfp_matmul_2d", "bfp_matmul_2d_prequant",
           "quantize_activations", "quantize_weights"]


def _flatten_leading(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def quantize_weights(w: jax.Array, policy: BFPPolicy) -> BFPBlock:
    """Block-format a [K, N] weight matrix (paper W, transposed)."""
    if policy.scheme is Scheme.EQ2 or policy.scheme is Scheme.EQ5:
        axes: Tuple[int, ...] = (0, 1)          # whole matrix
        return bfp.quantize(w, policy.l_w, axes, policy.rounding)
    if policy.scheme in (Scheme.EQ3, Scheme.EQ4):
        return bfp.quantize(w, policy.l_w, (0,), policy.rounding)  # per col
    # TILED: per (column, K-tile); w is [K, N] == paper W^T, so operand "i"
    # orientation of bfp_quantize_matrix matches (blocks along axis 0).
    return bfp.bfp_quantize_matrix(w, policy.l_w, "i", Scheme.TILED,
                                   policy.block_k, policy.rounding)


def quantize_activations(x2d: jax.Array, policy: BFPPolicy,
                         key: Optional[jax.Array] = None) -> BFPBlock:
    """Block-format a [B, K] activation matrix (paper I, transposed)."""
    if policy.scheme in (Scheme.EQ2, Scheme.EQ4):
        return bfp.quantize(x2d, policy.l_i, (0, 1), policy.rounding, key)
    if policy.scheme in (Scheme.EQ3, Scheme.EQ5):
        return bfp.quantize(x2d, policy.l_i, (1,), policy.rounding, key)
    return bfp.bfp_quantize_matrix(x2d, policy.l_i, "w", Scheme.TILED,
                                   policy.block_k, policy.rounding, key)


def _int_matmul(mx: jax.Array, mw: jax.Array, l_sum: int) -> jax.Array:
    """Exact fixed-point matmul with overflow-safe K-chunking.

    int32 accumulation of L_W+L_I-bit products is exact for
    K <= 2**(32 - l_sum) (paper Fig. 2 sizing).  Larger K is split into
    chunks whose int32 partials are combined in  fp32 space
    (power-of-two scales keep each partial exactly representable).
    """
    k = mx.shape[-1]
    safe_k = bfp.max_safe_k(0, 0, 32 - l_sum)  # == 2 ** (32 - l_sum)
    if k <= safe_k:
        return jax.lax.dot(mx.astype(jnp.int32), mw.astype(jnp.int32),
                           preferred_element_type=jnp.int32).astype(jnp.float32)
    n_chunks = -(-k // safe_k)
    pad = n_chunks * safe_k - k
    mxp = jnp.pad(mx, ((0, 0), (0, pad)))
    mwp = jnp.pad(mw, ((0, pad), (0, 0)))
    mxc = mxp.reshape(mx.shape[0], n_chunks, safe_k)
    mwc = mwp.reshape(n_chunks, safe_k, mw.shape[1])
    part = jnp.einsum("bck,ckn->cbn", mxc.astype(jnp.int32),
                      mwc.astype(jnp.int32),
                      preferred_element_type=jnp.int32)
    return jnp.sum(part.astype(jnp.float32), axis=0)


def _bfp_matmul_2d_impl(x2d: jax.Array, w: jax.Array,
                        policy: BFPPolicy,
                        key: Optional[jax.Array]) -> jax.Array:
    """BFP x2d[B,K] @ w[K,N] with the true integer datapath."""
    bx = (quantize_activations(x2d, policy, key) if policy.quantize_inputs
          else None)
    bw = quantize_weights(w, policy) if policy.quantize_weights else None
    if bx is None and bw is None:
        return x2d @ w
    if bx is None or bw is None:  # one operand float: dequantize the other
        xq = x2d if bx is None else bx.dequantize()
        wq = w if bw is None else bw.dequantize()
        return xq @ wq

    l_sum = policy.l_w + policy.l_i
    if policy.scheme is not Scheme.TILED:
        mo = _int_matmul(bx.mantissa, bw.mantissa, l_sum)
        # scale = 2^(ex - (L_I-2)) * 2^(ew - (L_W-2)), broadcast [B,1]x[1,N]
        sx = bx.scale  # [B,1] or [1,1]
        sw = bw.scale  # [1,N] or [1,1]
        return mo * (sx * sw)

    # TILED: exponents vary along K-tiles -> rescale each tile's partial.
    bk = policy.block_k or x2d.shape[-1]
    b, k = x2d.shape
    n = w.shape[1]
    t = k // bk
    mx = bx.mantissa.reshape(b, t, bk)
    mw = bw.mantissa.reshape(t, bk, n)
    # Exact int32 per-tile partials (bk <= 2**(32-l_sum) asserted by policy
    # use sites; 128 or 512 always safe for l_sum <= 16).
    part = jnp.einsum("btk,tkn->tbn", mx.astype(jnp.int32),
                      mw.astype(jnp.int32),
                      preferred_element_type=jnp.int32).astype(jnp.float32)
    sx = bfp.pow2(bx.exponent - (policy.l_i - 2))  # [B,t]
    sw = bfp.pow2(bw.exponent - (policy.l_w - 2))  # [t,N]
    scaled = part * sx.T[:, :, None] * sw[:, None, :]
    return jnp.sum(scaled, axis=0)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _bfp_matmul_ste(x2d, w, policy):
    return _bfp_matmul_2d_impl(x2d, w, policy, None)


def _ste_fwd(x2d, w, policy):
    bx = quantize_activations(x2d, policy) if policy.quantize_inputs else None
    bw = quantize_weights(w, policy) if policy.quantize_weights else None
    xq = x2d if bx is None else bx.dequantize()
    wq = w if bw is None else bw.dequantize()
    return _bfp_matmul_2d_impl(x2d, w, policy, None), (xq, wq)


def _ste_bwd(policy, res, g):
    xq, wq = res
    # Straight-through: gradients as if the GEMM were float over the
    # DEQUANTIZED operands (standard QAT estimator).
    return g @ wq.T, xq.T @ g


_bfp_matmul_ste.defvjp(_ste_fwd, _ste_bwd)


def bfp_matmul_2d(x2d: jax.Array, w: jax.Array, policy: BFPPolicy,
                  key: Optional[jax.Array] = None) -> jax.Array:
    """2-D BFP matmul.  Differentiable iff policy.straight_through."""
    if policy.scheme is Scheme.TILED:
        bk = policy.block_k or x2d.shape[-1]
        if bk > bfp.max_safe_k(policy.l_w, policy.l_i):
            raise ValueError(
                f"block_k={bk} overflows int32 accumulation for "
                f"L_W+L_I={policy.l_w + policy.l_i} (paper Fig. 2 sizing)")
    if policy.straight_through and key is None:
        return _bfp_matmul_ste(x2d, w, policy)
    return _bfp_matmul_2d_impl(x2d, w, policy, key)


def bfp_matmul_2d_prequant(x2d: jax.Array, wm: jax.Array, ws: jax.Array,
                           policy: BFPPolicy,
                           key: Optional[jax.Array] = None) -> jax.Array:
    """BFP x2d[B,K] @ pre-quantized weight (int mantissa + scale sidecar).

    ``wm`` is the int mantissa [K, N]; ``ws`` the power-of-two steps
    [K//bk, N] produced by :func:`repro.core.prequant.prequant_leaf`.
    The weight-side quantization is SKIPPED (that is the point); the
    activation side follows ``policy``.  For Scheme.TILED with matching
    ``block_k`` — and for eq. (3)/(4) with per-column sidecars (bk == K) —
    this is bit-exact to ``quantize_weights`` + :func:`bfp_matmul_2d`,
    because ``ws`` IS the quantizer's step array.

    Inference path: no straight-through estimator (weights are already
    integers; there is nothing to train through on the weight side).
    """
    b, k = x2d.shape
    kw, n = wm.shape
    t = ws.shape[0]
    if kw != k or t == 0 or k % t:
        raise ValueError(f"prequant shapes x{x2d.shape} m{wm.shape} "
                         f"s{ws.shape} inconsistent")
    bk = k // t
    if policy.block_k not in (None, bk) and policy.scheme is Scheme.TILED:
        raise ValueError(f"policy.block_k={policy.block_k} != prequant "
                         f"block {bk}")
    if not policy.quantize_inputs:
        s_full = jnp.repeat(ws, bk, axis=0)
        return x2d @ (wm.astype(jnp.float32) * s_full)

    l_sum = policy.l_w + policy.l_i
    if t == 1:
        # one weight block per column: same contraction as the paper
        # schemes; _int_matmul handles K beyond the int32-safe bound.
        bx = (quantize_activations(x2d, policy, key)
              if policy.scheme is not Scheme.TILED else
              bfp.bfp_quantize_matrix(x2d, policy.l_i, "w", Scheme.TILED,
                                      bk, policy.rounding, key))
        sx = (bx.scale if policy.scheme is not Scheme.TILED else
              bfp.pow2(bx.exponent - (policy.l_i - 2)))
        mo = _int_matmul(bx.mantissa, wm, l_sum)
        return mo * (sx.reshape(b, 1) if sx.size != 1 else sx) * ws

    if bk > bfp.max_safe_k(policy.l_w, policy.l_i):
        raise ValueError(
            f"prequant block {bk} overflows int32 accumulation for "
            f"L_W+L_I={l_sum} (paper Fig. 2 sizing)")
    if policy.scheme is Scheme.TILED:
        bx = bfp.bfp_quantize_matrix(x2d, policy.l_i, "w", Scheme.TILED,
                                     bk, policy.rounding, key)
        sx_e = bfp.pow2(bx.exponent
                        - (policy.l_i - 2)).T[:, :, None]        # [t,B,1]
    else:
        bx = quantize_activations(x2d, policy, key)
        sx_e = bx.scale[None]                                    # [1,B|1,1]
    mx = bx.mantissa.reshape(b, t, bk)
    mw = wm.reshape(t, bk, n)
    part = jnp.einsum("btk,tkn->tbn", mx.astype(jnp.int32),
                      mw.astype(jnp.int32),
                      preferred_element_type=jnp.int32).astype(jnp.float32)
    scaled = part * sx_e * ws[:, None, :]
    return jnp.sum(scaled, axis=0)


def bfp_dot(x: jax.Array, w, policy=None,
            key: Optional[jax.Array] = None,
            path: Optional[str] = None) -> jax.Array:
    """``x[..., K] @ w[K, N]`` with optional BFP datapath.

    Thin compatibility shim over :func:`repro.engine.gemm` — the single
    execution layer that owns backend selection (float / emulated /
    pallas), per-layer policy resolution (``policy`` may be a
    ``repro.engine.PolicyMap``; ``path`` names the calling layer), and
    first-class pre-quantized weights (``w`` may be the prequant
    ``{"m", "s"}`` wire format).
    """
    from repro import engine  # local import: engine builds on this module
    return engine.gemm(x, w, policy, path=path, key=key)
