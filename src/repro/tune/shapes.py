"""Canonical benchmark layer shapes — VGG-16 / ResNet-18 hot layers.

One table shared by the autotuner CLI (``python -m repro.tune``) and
``benchmarks/kernel_bench.py`` / ``conv_bench.py``, so tuned entries and
pinned BENCH_kernels.json rows key on exactly the same problems.

Conv shapes are (name, C, OC, kh, stride); spatial extent comes from the
benchmark's ``hw`` (32 full / 8 smoke) so VGG's 224x224 layers stay
runnable in interpret mode.  GEMM shapes are the im2col views of three
representative convs plus the VGG classifier tail at batch 64.
"""
from __future__ import annotations

__all__ = ["CONV_LAYERS", "GEMM_LAYERS"]

#: (name, in_ch, out_ch, k, stride) — benchmark picks H=W=hw.
CONV_LAYERS = (
    ("vgg16/conv1_1", 3, 64, 3, 1),
    ("vgg16/conv2_1", 64, 128, 3, 1),
    ("vgg16/conv3_1", 128, 256, 3, 1),
    ("vgg16/conv5_3", 512, 512, 3, 1),
    ("resnet18/stem7x7", 3, 64, 7, 2),
    ("resnet18/block_3x3", 64, 64, 3, 1),
    ("resnet18/down_3x3_s2", 128, 256, 3, 2),
)

#: (name, B, K, N) — im2col GEMM views at hw=32 (B = batch*OH*OW) and
#: the classifier tail.
GEMM_LAYERS = (
    ("vgg16/conv3_1.gemm", 1024, 1152, 256),
    ("vgg16/conv5_3.gemm", 1024, 4608, 512),
    ("resnet18/block.gemm", 1024, 576, 64),
    ("vgg16/fc.gemm", 64, 512, 4096),
)
