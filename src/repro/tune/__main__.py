"""CLI: tune the canonical VGG-16 / ResNet-18 benchmark layers.

    PYTHONPATH=src python -m repro.tune [--out tune_cache.json]
        [--smoke] [--hw 32] [--block-k 128] [--max-steps 12]

Skips sites already in the cache (delete the file to retune), saves
after every site so interrupts lose at most one measurement.
"""
from __future__ import annotations

import argparse

from repro.core.policy import BFPPolicy, Scheme
from repro.tune.autotune import tune_conv, tune_gemm
from repro.tune.cache import TuneCache
from repro.tune.shapes import CONV_LAYERS, GEMM_LAYERS


def main() -> None:
    ap = argparse.ArgumentParser(prog="repro.tune")
    ap.add_argument("--out", default="tune_cache.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny spatial extent + fewer steps (CI)")
    ap.add_argument("--hw", type=int, default=None,
                    help="conv spatial extent (default 32, smoke 8)")
    ap.add_argument("--block-k", type=int, default=128)
    ap.add_argument("--max-steps", type=int, default=None)
    args = ap.parse_args()

    hw = args.hw or (8 if args.smoke else 32)
    steps = args.max_steps or (4 if args.smoke else 12)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=args.block_k,
                    straight_through=False)
    cache = TuneCache.load(args.out)
    print(f"[tune] cache {args.out}: {len(cache)} entries", flush=True)

    for name, b, k, n in GEMM_LAYERS:
        # block_k must divide K for the pinned-block policy; free it
        # (None) where it doesn't so bk is tuned instead.
        p = pol if k % args.block_k == 0 else pol.with_(block_k=None)
        ent = tune_gemm(b, k, n, p, cache=cache, max_steps=steps)
        cache.save()
        print(f"[tune] gemm {name:24s} ({b},{k},{n}) -> "
              f"bm={ent['bm']} bn={ent['bn']} bk={ent['bk']} "
              f"{ent['us']:.0f}us", flush=True)

    for name, c, oc, kk, stride in CONV_LAYERS:
        p = pol if (kk * kk * c) % args.block_k == 0 \
            else pol.with_(block_k=c if c <= args.block_k else None)
        ent = tune_conv(1, hw, hw, c, kk, oc, p, stride=stride,
                        cache=cache, max_steps=steps)
        cache.save()
        print(f"[tune] conv {name:24s} (hw={hw},C={c},OC={oc},k={kk},"
              f"s={stride}) -> t_oh={ent['t_oh']} bn={ent['bn']} "
              f"{ent['us']:.0f}us", flush=True)

    print(f"[tune] done: {cache!r}", flush=True)


if __name__ == "__main__":
    main()
