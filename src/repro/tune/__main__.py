"""CLI: tune the canonical VGG-16 / ResNet-18 benchmark layers.

    PYTHONPATH=src python -m repro.tune [--out tune_cache.json]
        [--smoke] [--hw 32] [--block-k 128] [--max-steps 12]

Skips sites already in the cache (delete the file to retune), saves
after every site so interrupts lose at most one measurement.

Precision mode (ISSUE 10) — per-site mantissa-width search instead of
tile tuning:

    PYTHONPATH=src python -m repro.tune --precision --model vgg16 \\
        [--budget 1e-2] [--top1-tol 0.25] [--l-max 8] [--l-min 2] \\
        [--seed 0] [--batch 8] [--policy-out policy.json] \\
        [--checkpoint-out ckpt_dir]

Emits the winning PolicyMap (+ per-site NSR evidence) as JSON and,
with ``--checkpoint-out``, the ``format="bfp_packed_v2"``
variable-width checkpoint packed under that map.
"""
from __future__ import annotations

import argparse

from repro.core.policy import BFPPolicy, Scheme
from repro.tune.autotune import tune_conv, tune_gemm
from repro.tune.cache import TuneCache
from repro.tune.shapes import CONV_LAYERS, GEMM_LAYERS


def _main_precision(args) -> None:
    import jax

    from repro.checkpoint import store
    from repro.models.cnn import MODELS
    from repro.tune.precision import search_precision

    res = search_precision(args.model, seed=args.seed, batch=args.batch,
                           l_max=args.l_max, l_min=args.l_min,
                           nsr_budget=args.budget,
                           top1_tol=args.top1_tol, verbose=True)
    for s in res.sites:
        print(f"[precision] {s.path:24s} {s.kind:4s} l_w={s.l_w} "
              f"nsr={s.nsr_measured:.3g} (budget {res.nsr_budget:g}) "
              f"fresh={s.nsr_fresh:.3g} <= bound={s.nsr_bound:.3g}",
              flush=True)
    print(f"[precision] top-1 agreement {res.top1_agreement:.3f} "
          f"(tol {res.top1_tol:g}), {res.n_evals} evals", flush=True)
    if args.policy_out:
        res.save(args.policy_out)
        print(f"[precision] PolicyMap + report -> {args.policy_out}",
              flush=True)
    if args.checkpoint_out:
        spec = MODELS[args.model]
        params = spec.init(jax.random.PRNGKey(args.seed))
        path = store.save(args.checkpoint_out, 0, params,
                          format="bfp_packed_v2", policy=res.policy_map,
                          tree_kind="cnn")
        print(f"[precision] bfp_packed_v2 checkpoint -> {path}",
              flush=True)


def main() -> None:
    ap = argparse.ArgumentParser(prog="repro.tune")
    ap.add_argument("--out", default="tune_cache.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny spatial extent + fewer steps (CI)")
    ap.add_argument("--hw", type=int, default=None,
                    help="conv spatial extent (default 32, smoke 8)")
    ap.add_argument("--block-k", type=int, default=128)
    ap.add_argument("--max-steps", type=int, default=None)
    ap.add_argument("--precision", action="store_true",
                    help="per-site mantissa-width search (repro.tune."
                         "precision) instead of tile tuning")
    ap.add_argument("--model", default="lenet",
                    help="precision mode: registry model name")
    ap.add_argument("--budget", type=float, default=1e-2,
                    help="precision mode: max per-site output NSR")
    ap.add_argument("--top1-tol", type=float, default=0.25,
                    help="precision mode: tolerated top-1 disagreement "
                         "fraction vs the global-l_max baseline")
    ap.add_argument("--l-max", type=int, default=8)
    ap.add_argument("--l-min", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--policy-out", default=None,
                    help="precision mode: write PolicyMap JSON here")
    ap.add_argument("--checkpoint-out", default=None,
                    help="precision mode: write the bfp_packed_v2 "
                         "checkpoint here")
    args = ap.parse_args()

    if args.precision:
        _main_precision(args)
        return

    hw = args.hw or (8 if args.smoke else 32)
    steps = args.max_steps or (4 if args.smoke else 12)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=args.block_k,
                    straight_through=False)
    cache = TuneCache.load(args.out)
    print(f"[tune] cache {args.out}: {len(cache)} entries", flush=True)

    for name, b, k, n in GEMM_LAYERS:
        # block_k must divide K for the pinned-block policy; free it
        # (None) where it doesn't so bk is tuned instead.
        p = pol if k % args.block_k == 0 else pol.with_(block_k=None)
        ent = tune_gemm(b, k, n, p, cache=cache, max_steps=steps)
        cache.save()
        print(f"[tune] gemm {name:24s} ({b},{k},{n}) -> "
              f"bm={ent['bm']} bn={ent['bn']} bk={ent['bk']} "
              f"{ent['us']:.0f}us", flush=True)

    for name, c, oc, kk, stride in CONV_LAYERS:
        p = pol if (kk * kk * c) % args.block_k == 0 \
            else pol.with_(block_k=c if c <= args.block_k else None)
        ent = tune_conv(1, hw, hw, c, kk, oc, p, stride=stride,
                        cache=cache, max_steps=steps)
        cache.save()
        print(f"[tune] conv {name:24s} (hw={hw},C={c},OC={oc},k={kk},"
              f"s={stride}) -> t_oh={ent['t_oh']} bn={ent['bn']} "
              f"{ent['us']:.0f}us", flush=True)

    print(f"[tune] done: {cache!r}", flush=True)


if __name__ == "__main__":
    main()
