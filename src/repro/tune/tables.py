"""THE default tile table — one documented fallback path for every kernel.

Before the autotuner existed, the fused and prequant matmul kernels
carried *different* hardcoded defaults (``bk=512`` vs ``bk=128``) in
their signatures, and ``ops.default_tiles`` re-derived a third opinion.
This module is now the single source of truth: the autotune cache
(:mod:`repro.tune.cache`) is consulted first, and when it has no entry
for a site, :func:`fallback_tiles` answers — for BOTH the fused and the
prequant paths, GEMM and conv alike.  ``kernels.ops`` re-exports
:func:`aligned_tile` / delegates ``default_tiles`` here so legacy
imports keep working.

Pure Python (no jax import): the table must be consultable at trace
time and from the autotuner CLI without touching a backend.
"""
from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["aligned_tile", "fallback_tiles", "overflow_cap",
           "conv_row_tile", "MXU_DIM", "DEEP_K_BK"]

#: The MXU systolic array dimension — bm/bn never exceed it by default.
MXU_DIM = 128

#: Default K tile for deep contractions (bandwidth-friendly multiple of
#: the MXU dim).  Shallow contractions take the aligned tile instead.
DEEP_K_BK = 512


def _pow2_ge(d: int) -> int:
    """Smallest power of two >= d (d >= 1)."""
    return 1 << max(0, d - 1).bit_length()


def aligned_tile(d: int, cap: int = MXU_DIM) -> int:
    """THE power-of-two-aligned tile floor, shared by every wrapper:
    next power of two >= d, floored at 8 (sublane minimum) and capped at
    ``cap`` (the MXU dimension, or a bandwidth-friendly multiple of it).
    Small/odd problem dims pad to the NEAREST aligned tile, not a full
    cap."""
    return min(cap, max(8, _pow2_ge(d)))


def overflow_cap(l_sum: int) -> int:
    """Largest K tile whose int32 accumulation cannot overflow (paper
    Fig. 2 sizing): 2^(32 - (L_I + L_W))."""
    return 1 << max(0, 32 - l_sum)


def fallback_tiles(b: int, k: int, n: int, block_k: Optional[int],
                   l_sum: int = 16) -> Tuple[int, int, int]:
    """Default MXU-aligned tiles for a (b, k) x (k, n) problem.

    bm/bn: the MXU dimension capped below at 8 and shrunk to the next
    power of two when the problem dimension is smaller — small or odd
    shapes pad to the NEAREST aligned tile instead of a full 128.
    bk: the BFP block size when given (block == K tile by construction);
    otherwise ``DEEP_K_BK`` for deep contractions and the aligned tile
    for shallow ones, capped by the int32 overflow bound (paper Fig. 2)
    so auto-picked tiles are always accumulation-safe for the policy's
    mantissa widths.
    """
    bm = aligned_tile(b)
    bn = aligned_tile(n)
    if block_k:
        bk = block_k
    else:
        bk = DEEP_K_BK if k >= DEEP_K_BK else aligned_tile(k)
        bk = min(bk, overflow_cap(l_sum))   # always accumulation-safe
    return bm, bn, bk


def conv_row_tile(oh: int, ow: int) -> int:
    """Default output-row tile for the fused conv kernels: enough rows
    per program to feed the MXU a >=128-row M tile when OW is small;
    one row when OW alone is wide enough."""
    return max(1, min(oh, MXU_DIM // max(1, ow)))
