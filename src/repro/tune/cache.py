"""Persistent autotune cache — tuned tile configs keyed by (shape, L, target).

Schema (docs/autotune.md documents this normatively):

    {
      "schema": 1,
      "entries": {
        "<key>": {"bm": 128, "bn": 128, "bk": 512, "t_oh": 4,
                  "us": 812.5, "steps": 9}
      }
    }

Key string (one entry per tuning site):

    <kind>:b<B>k<K>n<N>:L<L_I>.<L_W>:bk<block_k|0>:<target>

* ``kind``   — "gemm" or "conv" (conv keys use the im2col GEMM view:
  B = B*OH*OW rows, K = kh*kw*C, N = OC, plus the conv kind carries
  spatial geometry in ``t_oh``).
* ``B/K/N``  — the UNPADDED problem shape (wrappers pad identically for
  every candidate, so the unpadded shape is the stable identity).
* ``L``      — both mantissa widths; they bound bk via int32 overflow.
* ``bk``     — the policy's block_k (0 = None = tile free to tune).
  When block_k is pinned, the BFP block IS the K tile — semantics, not
  a tuning knob — so only (bm, bn) (or (t_oh, bn) for conv) hillclimb.
* ``target`` — "interpret" or the jax backend ("cpu"/"tpu"/"gpu"):
  timings never transfer across execution targets.

Entry fields: the winning tiles, the measured median microseconds
(``us``), and how many hillclimb evaluations it took (``steps``).

Invalidation: entries are immortal within a schema version — the key
carries every input that changes the optimum (shape, widths, block, and
target), so there is nothing date-like to expire.  Kernel rewrites that
shift the cost model bump ``SCHEMA`` below; ``load`` drops entries from
other schema versions on read.  Delete the JSON file to retune from
scratch.

Runtime plumbing: ``kernels.ops`` consults the process-wide ACTIVE cache
(``set_cache`` / ``use_cache``) at trace time; ``engine.bind(...,
tune_cache=)`` installs a cache on a Plan so every site the plan
launches uses tuned tiles with no call-site changes.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import warnings
from typing import Any, Dict, Optional, Tuple

__all__ = ["TuneCache", "set_cache", "get_cache", "use_cache",
           "lookup_tiles", "SCHEMA"]

SCHEMA = 1


class TuneCache:
    """A dict of tuned tile entries with JSON persistence.

    Thread-safe for the store path (benchmarks may tune from worker
    threads); lookups are plain dict reads.
    """

    def __init__(self, path: Optional[str] = None,
                 entries: Optional[Dict[str, Dict[str, Any]]] = None):
        self.path = path
        self.entries: Dict[str, Dict[str, Any]] = dict(entries or {})
        self.hits = 0
        self.misses = 0
        self._lock = threading.Lock()

    # -- persistence ----------------------------------------------------
    @classmethod
    def load(cls, path: str) -> "TuneCache":
        """Load from ``path``; a missing file is an empty cache (so the
        first tuning run can create it).

        A corrupt or unreadable file is ALSO an empty cache — warned
        once per path, not raised: the tune cache is a performance
        artifact, and a truncated write or stray edit must degrade to
        "retune from scratch" rather than take serving down.  The next
        ``save`` atomically replaces the bad file.
        """
        if not os.path.exists(path):
            return cls(path=path)
        try:
            with open(path) as f:
                doc = json.load(f)
            if not isinstance(doc, dict) or \
                    not isinstance(doc.get("entries", {}), dict):
                raise ValueError(f"unexpected document shape: "
                                 f"{type(doc).__name__}")
        except (OSError, ValueError) as e:   # json errors are ValueError
            cls._warn_corrupt(path, e)
            return cls(path=path)
        if doc.get("schema") != SCHEMA:
            # schema bump = cost model changed: old winners are stale
            return cls(path=path)
        return cls(path=path, entries=doc.get("entries", {}))

    _warned_paths: set = set()

    @classmethod
    def _warn_corrupt(cls, path: str, err: Exception) -> None:
        key = os.path.abspath(path)
        if key in cls._warned_paths:
            return
        cls._warned_paths.add(key)
        warnings.warn(f"tune cache {path} is corrupt or unreadable "
                      f"({err}); treating as empty — delete or re-save "
                      f"to silence", UserWarning, stacklevel=3)

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("TuneCache has no path to save to")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"schema": SCHEMA,
                       "entries": dict(sorted(self.entries.items()))},
                      f, indent=1, sort_keys=False)
            f.write("\n")
        os.replace(tmp, path)
        self.path = path
        return path

    # -- keying ---------------------------------------------------------
    @staticmethod
    def key(kind: str, b: int, k: int, n: int, l_i: int, l_w: int,
            block_k: Optional[int], target: str) -> str:
        return (f"{kind}:b{b}k{k}n{n}:L{l_i}.{l_w}:"
                f"bk{block_k or 0}:{target}")

    @staticmethod
    def target(interpret: bool) -> str:
        if interpret:
            return "interpret"
        import jax
        return jax.default_backend()

    # -- access ---------------------------------------------------------
    def lookup(self, kind: str, b: int, k: int, n: int, l_i: int,
               l_w: int, block_k: Optional[int],
               target: str) -> Optional[Dict[str, Any]]:
        ent = self.entries.get(
            self.key(kind, b, k, n, l_i, l_w, block_k, target))
        if ent is None:
            self.misses += 1
        else:
            self.hits += 1
        return ent

    def store(self, kind: str, b: int, k: int, n: int, l_i: int,
              l_w: int, block_k: Optional[int], target: str,
              entry: Dict[str, Any]) -> None:
        with self._lock:
            self.entries[self.key(kind, b, k, n, l_i, l_w, block_k,
                                  target)] = dict(entry)

    def __len__(self) -> int:
        return len(self.entries)

    def __repr__(self) -> str:
        return (f"TuneCache({len(self.entries)} entries, "
                f"hits={self.hits}, misses={self.misses}, "
                f"path={self.path!r})")


# -- process-wide active cache ------------------------------------------
_ACTIVE: Optional[TuneCache] = None


def set_cache(cache: Optional[TuneCache]) -> Optional[TuneCache]:
    """Install ``cache`` as the process-wide active cache (None clears);
    returns the previous one."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, cache
    return prev


def get_cache() -> Optional[TuneCache]:
    return _ACTIVE


@contextlib.contextmanager
def use_cache(cache: Optional[TuneCache]):
    """Scoped ``set_cache`` — how Plans activate their bound cache around
    each execution."""
    prev = set_cache(cache)
    try:
        yield cache
    finally:
        set_cache(prev)


def lookup_tiles(kind: str, b: int, k: int, n: int, l_i: int, l_w: int,
                 block_k: Optional[int],
                 interpret: bool) -> Optional[Tuple[int, ...]]:
    """Consult the active cache for a tuned tile config.

    Returns (bm, bn, bk) for "gemm", (t_oh, bn) for "conv", or None when
    no cache is active / it has no entry — callers then fall back to
    :func:`repro.tune.tables.fallback_tiles`.
    """
    cache = get_cache()
    if cache is None:
        return None
    ent = cache.lookup(kind, b, k, n, l_i, l_w, block_k,
                       TuneCache.target(interpret))
    if ent is None:
        return None
    if kind == "conv":
        return (ent["t_oh"], ent["bn"])
    return (ent["bm"], ent["bn"], ent["bk"])
