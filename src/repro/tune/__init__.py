"""repro.tune — tile autotuning for the BFP Pallas kernels (ISSUE 6).

Three pieces:

* :mod:`repro.tune.tables` — THE fallback tile table (the single
  default path both fused and prequant kernels share).
* :mod:`repro.tune.cache` — persistent JSON cache of tuned winners,
  keyed by (shape, mantissa widths, block, execution target), plus the
  process-wide active cache ``kernels.ops`` consults at dispatch.
* :mod:`repro.tune.autotune` — the hillclimber that fills the cache
  (``python -m repro.tune`` tunes the canonical benchmark layers).
* :mod:`repro.tune.precision` — the per-site mantissa-width search
  (``python -m repro.tune --precision``): greedy descent of each
  site's ``l_w`` under a measured-NSR + top-1-agreement budget,
  emitting a ``PolicyMap`` for ``bfp_packed_v2`` checkpoints.

Wiring: ``engine.bind(..., tune_cache=cache)`` attaches a cache to a
Plan; every GEMM/conv the plan executes then launches with tuned tiles.
"""
from repro.tune.autotune import time_us, tune_conv, tune_gemm
from repro.tune.cache import (SCHEMA, TuneCache, get_cache, lookup_tiles,
                              set_cache, use_cache)
from repro.tune.precision import (PrecisionResult, PrecisionSearchError,
                                  SiteReport, search_precision)
from repro.tune.tables import (aligned_tile, conv_row_tile, fallback_tiles,
                               overflow_cap)

__all__ = ["TuneCache", "SCHEMA", "set_cache", "get_cache", "use_cache",
           "lookup_tiles", "tune_gemm", "tune_conv", "time_us",
           "aligned_tile", "fallback_tiles", "overflow_cap",
           "conv_row_tile", "search_precision", "PrecisionResult",
           "PrecisionSearchError", "SiteReport"]
