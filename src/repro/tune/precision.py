"""Automated per-layer mantissa-width search (Ristretto-style, ISSUE 10).

The paper's headline answer — "8-bit mantissas cost <0.3% accuracy
without retraining" — is GLOBAL: one L for every layer.  Ristretto
(Gysel 2016) and the FPGA mixed-precision line (Wu et al. 2020, both in
PAPERS.md) show per-layer width selection dominates any single global
width.  :func:`search_precision` automates that answer over the CNN
registry, on the REAL datapath:

  1. a float reference forward and a global-``l_max`` baseline forward
     run under ``engine.taps`` (eager — taps observe concrete values);
  2. per site, the weight width ``l_w`` descends greedily from
     ``l_max`` while (a) the site's measured output NSR against the
     float run stays within ``nsr_budget`` and (b) the batch top-1
     agreement against the global-``l_max`` baseline stays within
     ``top1_tol`` (Ristretto's independent per-layer sweep);
  3. the joint assignment is validated and hill-climb-repaired: while
     any site exceeds its budget or agreement slips, the
     worst-margin site gains a bit back (terminates: every site is
     bounded by ``l_max``, which was validated up front);
  4. the winner is re-run once with ``want_float`` taps so every
     site's FRESH quantization NSR is checked against the analytic
     :func:`repro.core.nsr.gemm_nsr_upper_bound` — the emitted report
     carries measured-vs-bound per site.

The result is a :class:`repro.engine.PolicyMap` (exact-match rule per
site, ``l_max`` default) plus a per-site report; feed the map to
``checkpoint.store.save(format="bfp_packed_v2", policy=map)`` and every
site searched down from ``l_max`` shrinks the variable-width container
below the fixed-L bytes — that pairing is what
``benchmarks/pack_bench.py`` pins.

An unsatisfiable budget raises :class:`PrecisionSearchError` up front
(the global-``l_max`` baseline already violates it) instead of looping.
The search is deterministic: same model/seed/arguments, same PolicyMap.

Activations keep ``l_i = l_max`` — the search targets the storage/wire
width ``l_w`` (what checkpoints and the gradient wire pay for); the NSR
and agreement budgets still measure the full datapath effect of each
narrowed weight.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import engine as EG
from repro.core import nsr
from repro.core.policy import BFPPolicy, TPU_TILED
from repro.engine import PolicyMap
from repro.models.cnn import MODELS
from repro.models.cnn.analysis import _site_matrices

__all__ = ["PrecisionSearchError", "SiteReport", "PrecisionResult",
           "search_precision"]


class PrecisionSearchError(ValueError):
    """The declared budget cannot be met: the global-``l_max`` baseline
    already violates the NSR budget at some site (or the repair loop
    would have to exceed ``l_max``).  Raised instead of descending into
    a search that cannot terminate on a satisfying assignment; the
    message names the offending site and the measured value."""


@dataclasses.dataclass
class SiteReport:
    """One searched site of the emitted PolicyMap."""
    path: str
    kind: str                 #: "gemm" | "conv"
    l_w: int                  #: chosen weight mantissa width (incl. sign)
    nsr_measured: float       #: site output NSR vs the float run
                              #: (inherited + fresh — the budgeted value)
    nsr_fresh: float          #: fresh quantization NSR (same-input float
                              #: reference, ``want_float`` taps)
    nsr_bound: float          #: analytic gemm_nsr_upper_bound at l_w


@dataclasses.dataclass
class PrecisionResult:
    """A winning per-site width assignment and its evidence."""
    model: str
    seed: int
    l_max: int
    l_min: int
    nsr_budget: float
    top1_tol: float
    policy_map: PolicyMap
    sites: List[SiteReport]
    top1_agreement: float     #: final map vs global-l_max baseline
    n_evals: int              #: tapped forwards the search spent

    @property
    def assignment(self) -> Dict[str, int]:
        return {s.path: s.l_w for s in self.sites}

    def to_dict(self) -> Dict[str, Any]:
        return {
            "model": self.model, "seed": self.seed,
            "l_max": self.l_max, "l_min": self.l_min,
            "nsr_budget": self.nsr_budget, "top1_tol": self.top1_tol,
            "top1_agreement": self.top1_agreement,
            "n_evals": self.n_evals,
            "policy_map": self.policy_map.to_dict(),
            "sites": [dataclasses.asdict(s) for s in self.sites],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)


def _logits(out) -> np.ndarray:
    return np.asarray(out[0] if isinstance(out, tuple) else out)


def _site_nsrs(ev_f: List[EG.TapEvent], ev_q: List[EG.TapEvent]
               ) -> Dict[str, float]:
    """Per-path measured output NSR of a candidate run against the float
    run (error/signal energies accumulated over repeated visits)."""
    if len(ev_f) != len(ev_q):
        raise RuntimeError(
            f"float/candidate runs executed different site counts "
            f"({len(ev_f)} vs {len(ev_q)})")
    sig: Dict[str, float] = {}
    err: Dict[str, float] = {}
    for f, q in zip(ev_f, ev_q):
        if f.path != q.path:
            raise RuntimeError(f"site order diverged: {f.path} vs {q.path}")
        if q.policy is None:
            continue
        yf = np.asarray(f.y, np.float64)
        yq = np.asarray(q.y, np.float64)
        p = f.path or "?"
        sig[p] = sig.get(p, 0.0) + float(np.sum(yf * yf))
        err[p] = err.get(p, 0.0) + float(np.sum((yq - yf) ** 2))
    tiny = float(np.finfo(np.float32).tiny)
    return {p: err[p] / max(sig[p], tiny) for p in sig}


def _agreement(logits: np.ndarray, ref_labels: np.ndarray) -> float:
    return float(np.mean(np.argmax(logits, axis=-1) == ref_labels))


def _site_map(base: BFPPolicy, widths: Dict[str, int]) -> PolicyMap:
    """Exact-match rule per site (escaped, anchored), base as default —
    resolvable both by the engine at execution time and by the
    ``core.prequant`` checkpoint walk (same paths, PR 5 pin)."""
    rules = tuple((f"^{re.escape(p)}$", base.with_(l_w=l))
                  for p, l in widths.items())
    return PolicyMap(rules=rules, default=base)


def search_precision(model: str = "lenet", *, seed: int = 0,
                     batch: int = 8, l_max: int = 8, l_min: int = 2,
                     nsr_budget: float = 1e-3, top1_tol: float = 0.0,
                     base_policy: Optional[BFPPolicy] = None,
                     reduced: bool = True,
                     verbose: bool = False) -> PrecisionResult:
    """Greedy per-site ``l_w`` search over one registry CNN.

    ``nsr_budget`` bounds each site's measured output NSR against the
    float forward (linear noise/signal ratio; 1e-3 ~= 30 dB SNR).
    ``top1_tol`` is the tolerated fraction of the eval batch whose top-1
    class may differ from the global-``l_max`` baseline's.  Raises
    :class:`PrecisionSearchError` when the budget is unsatisfiable even
    at ``l_max``.  Runs eagerly (taps observe concrete execution only).
    """
    if model not in MODELS:
        raise ValueError(f"unknown model {model!r} (have "
                         f"{sorted(MODELS)})")
    if not 2 <= l_min <= l_max <= 24:
        raise ValueError(f"need 2 <= l_min <= l_max <= 24, got "
                         f"l_min={l_min}, l_max={l_max}")
    if nsr_budget < 0:
        raise ValueError(f"nsr_budget must be >= 0, got {nsr_budget}")
    spec = MODELS[model]
    params = spec.init(jax.random.PRNGKey(seed), reduced=reduced)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (batch, *spec.input_shape(reduced=reduced)))
    base = (base_policy if base_policy is not None
            else TPU_TILED.with_(block_k=None))
    base = base.with_(l_w=l_max, l_i=l_max, straight_through=False)
    n_evals = 0

    def run(policy, want_float: bool = False
            ) -> Tuple[List[EG.TapEvent], np.ndarray]:
        nonlocal n_evals
        evs: List[EG.TapEvent] = []
        with EG.taps(evs.append, want_float=want_float):
            out = spec.apply(params, x, policy)
        n_evals += 1
        return evs, _logits(out)

    ev_float, _ = run(None)

    # --- global-l_max baseline: the budget's feasibility gate -------------
    ev_base, logits_base = run(base)
    ref_labels = np.argmax(logits_base, axis=-1)
    base_nsr = _site_nsrs(ev_float, ev_base)
    if not base_nsr:
        raise ValueError(f"model {model!r} executed no quantizable sites "
                         f"under the base policy — nothing to search")
    for p, v in base_nsr.items():
        if v > nsr_budget:
            raise PrecisionSearchError(
                f"nsr_budget {nsr_budget:g} is unsatisfiable: site "
                f"{p!r} measures NSR {v:.3g} already at the maximum "
                f"width l_w={l_max} — no narrower assignment can meet "
                f"the budget; raise the budget or l_max")
    order = []
    for ev in ev_base:
        p = ev.path or "?"
        if ev.policy is not None and p not in order:
            order.append(p)

    # --- phase A: independent per-site descent (Ristretto sweep) ----------
    chosen = {p: l_max for p in order}
    for p in order:
        for L in range(l_max - 1, l_min - 1, -1):
            evs, logits = run(_site_map(base, {p: L}))
            ok = (_site_nsrs(ev_float, evs)[p] <= nsr_budget
                  and _agreement(logits, ref_labels) >= 1.0 - top1_tol)
            if not ok:
                break
            chosen[p] = L
        if verbose:
            print(f"[precision] {model}/{p}: l_w {l_max} -> {chosen[p]}",
                  flush=True)

    # --- phase B: joint validation + hillclimb repair ---------------------
    max_repairs = sum(l_max - chosen[p] for p in order)
    for _ in range(max_repairs + 1):
        evs, logits = run(_site_map(base, chosen))
        nsrs = _site_nsrs(ev_float, evs)
        agree = _agreement(logits, ref_labels)
        over = {p: nsrs[p] / max(nsr_budget, np.finfo(np.float32).tiny)
                for p in order if nsrs[p] > nsr_budget}
        if not over and agree >= 1.0 - top1_tol:
            break
        raisable = [p for p in order if chosen[p] < l_max]
        if not raisable:
            raise PrecisionSearchError(
                f"joint repair exhausted: every site is back at "
                f"l_max={l_max} yet the budget is still violated "
                f"(agreement {agree:.3f}, over-budget {sorted(over)})")
        # worst NSR margin first; pure-agreement violations raise the
        # narrowest (noisiest-per-bit) site instead
        over_raisable = [p for p in raisable if p in over]
        target = (max(over_raisable, key=lambda p: over[p])
                  if over_raisable
                  else min(raisable, key=lambda p: chosen[p]))
        chosen[target] += 1
        if verbose:
            print(f"[precision] repair: {target} -> l_w "
                  f"{chosen[target]}", flush=True)

    # --- final evidence: fresh NSR vs the analytic bound ------------------
    final_map = _site_map(base, chosen)
    evs, logits = run(final_map, want_float=True)
    nsrs = _site_nsrs(ev_float, evs)
    agree = _agreement(logits, ref_labels)
    fresh: Dict[str, float] = {}
    bound: Dict[str, float] = {}
    kinds: Dict[str, str] = {}
    for ev in evs:
        if ev.policy is None:
            continue
        p = ev.path or "?"
        if p in fresh:
            continue
        yf = np.asarray(ev.y_float, np.float64)
        e = float(np.sum((np.asarray(ev.y, np.float64) - yf) ** 2))
        s = float(np.sum(yf * yf))
        fresh[p] = e / max(s, float(np.finfo(np.float32).tiny))
        x2d, w2d = _site_matrices(ev)
        bound[p] = float(nsr.gemm_nsr_upper_bound(x2d, w2d, ev.policy))
        kinds[p] = ev.kind
    sites = [SiteReport(path=p, kind=kinds[p], l_w=chosen[p],
                        nsr_measured=float(nsrs[p]),
                        nsr_fresh=float(fresh[p]),
                        nsr_bound=float(bound[p])) for p in order]
    return PrecisionResult(model=model, seed=seed, l_max=l_max,
                           l_min=l_min, nsr_budget=nsr_budget,
                           top1_tol=top1_tol, policy_map=final_map,
                           sites=sites, top1_agreement=agree,
                           n_evals=n_evals)
