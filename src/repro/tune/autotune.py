"""Hillclimb autotuner for BFP kernel tile configs.

The measure-and-cache shape follows ``launch/hillclimb.py``: each named
candidate is measured (median wall-clock over a few calls, after a
warmup that also pays compilation), results land in a persistent cache,
and already-cached sites are skipped.  Here the variants are not
hand-named though — the tuner walks the power-of-two tile lattice
greedily: evaluate the fallback config, then all single-axis x2 / /2
neighbors, move to the best, repeat until no neighbor wins (or
``max_steps`` evaluations).

Constraints baked into the neighborhood (never evaluated, not just
rejected): the int32-overflow bound ``L_I + L_W + ceil(log2 bk) <= 32``
(paper Fig. 2), the 8-sublane floor, and tiles never more than one
power of two beyond the problem dim (padding past that is pure waste).
When ``policy.block_k`` is pinned, the BFP block IS the K tile —
semantics, not a knob — so only (bm, bn) (GEMM) or (t_oh, bn) (conv)
move.

Usage (CLI, writes/updates the JSON cache):

    PYTHONPATH=src python -m repro.tune --out tune_cache.json [--smoke]

Programmatic:

    cache = TuneCache.load("tune_cache.json")
    tune_gemm(b, k, n, policy, cache=cache)   # no-op if already cached
    cache.save()
    plan = engine.bind(params, pm, paths, tune_cache=cache)
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax

from repro.tune.cache import TuneCache
from repro.tune.tables import conv_row_tile, fallback_tiles, overflow_cap

__all__ = ["tune_gemm", "tune_conv", "time_us"]


def time_us(fn: Callable[[], Any], iters: int = 3,
            warmup: int = 1) -> float:
    """Median wall-clock microseconds of ``fn()`` (jax-blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def _pow2_ge(d: int) -> int:
    return 1 << max(0, d - 1).bit_length()


def _axis_neighbors(v: int, lo: int, hi: int) -> Iterable[int]:
    if v * 2 <= hi:
        yield v * 2
    if v // 2 >= lo:
        yield v // 2


def _hillclimb(start: Tuple[int, ...],
               neighbors: Callable[[Tuple[int, ...]],
                                   Iterable[Tuple[int, ...]]],
               evaluate: Callable[[Tuple[int, ...]], float],
               max_steps: int) -> Tuple[Tuple[int, ...], float, int]:
    """Greedy best-neighbor walk; returns (best config, best us, evals)."""
    seen: Dict[Tuple[int, ...], float] = {}

    def ev(cfg):
        if cfg not in seen:
            seen[cfg] = evaluate(cfg)
        return seen[cfg]

    best, best_us = start, ev(start)
    improved = True
    while improved and len(seen) < max_steps:
        improved = False
        for cand in neighbors(best):
            if len(seen) >= max_steps:
                break
            if cand in seen:
                continue
            us = ev(cand)
            if us < best_us:
                best, best_us, improved = cand, us, True
    return best, best_us, len(seen)


def tune_gemm(b: int, k: int, n: int, policy, *, cache: TuneCache,
              interpret: Optional[bool] = None, max_steps: int = 12,
              iters: int = 3, x: Optional[jax.Array] = None,
              w: Optional[jax.Array] = None) -> Dict[str, Any]:
    """Tune (bm, bn, bk) for one GEMM site; returns the cache entry.

    Already-cached sites return immediately (the launch/hillclimb.py
    skip-if-cached shape).  ``bk`` only moves when ``policy.block_k`` is
    None; a pinned block is the BFP block and stays fixed.
    """
    from repro.kernels import ops  # late: ops imports tune.tables

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    target = TuneCache.target(interpret)
    ent = cache.lookup("gemm", b, k, n, policy.l_i, policy.l_w,
                       policy.block_k, target)
    if ent is not None:
        return ent

    if x is None:
        x = jax.random.normal(jax.random.PRNGKey(0), (b, k))
    if w is None:
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.1
    l_sum = policy.l_i + policy.l_w
    start = fallback_tiles(b, k, n, policy.block_k, l_sum)
    bk_free = not policy.block_k
    bm_hi = max(8, _pow2_ge(b))
    bn_hi = max(8, _pow2_ge(n))
    bk_hi = min(max(8, _pow2_ge(k)), overflow_cap(l_sum))

    def neighbors(cfg):
        bm, bn, bk = cfg
        for v in _axis_neighbors(bm, 8, bm_hi):
            yield (v, bn, bk)
        for v in _axis_neighbors(bn, 8, bn_hi):
            yield (bm, v, bk)
        if bk_free:
            for v in _axis_neighbors(bk, 8, bk_hi):
                yield (bm, bn, v)

    def evaluate(cfg):
        return time_us(
            lambda: ops.bfp_matmul(x, w, policy, interpret, tiles=cfg),
            iters=iters)

    best, us, steps = _hillclimb(start, neighbors, evaluate, max_steps)
    entry = {"bm": best[0], "bn": best[1], "bk": best[2],
             "us": round(us, 1), "steps": steps}
    cache.store("gemm", b, k, n, policy.l_i, policy.l_w, policy.block_k,
                target, entry)
    return entry


def tune_conv(b: int, h: int, w_in: int, c: int, kh: int, oc: int,
              policy, *, stride: int = 1, padding: str = "SAME",
              cache: TuneCache, interpret: Optional[bool] = None,
              max_steps: int = 10, iters: int = 3) -> Dict[str, Any]:
    """Tune (t_oh, bn) for one conv site (bk is the policy block —
    pinned); keys on the im2col GEMM view of the problem."""
    from repro.core.conv_utils import conv_geometry
    from repro.kernels import ops  # late: ops imports tune.tables

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    target = TuneCache.target(interpret)
    kk = kh * kh * c
    oh, ow, _, _ = conv_geometry(h, w_in, kh, kh, stride, padding)
    rows = b * oh * ow
    ent = cache.lookup("conv", rows, kk, oc, policy.l_i, policy.l_w,
                       policy.block_k, target)
    if ent is not None:
        return ent

    x = jax.random.normal(jax.random.PRNGKey(0), (b, h, w_in, c))
    wk = jax.random.normal(jax.random.PRNGKey(1), (kh, kh, c, oc)) * 0.1
    start = (conv_row_tile(oh, ow), fallback_tiles(rows, kk, oc, None)[1])
    t_hi = max(1, _pow2_ge(oh))
    bn_hi = max(8, _pow2_ge(oc))

    def neighbors(cfg):
        t_oh, bn = cfg
        for v in _axis_neighbors(t_oh, 1, t_hi):
            yield (v, bn)
        for v in _axis_neighbors(bn, 8, bn_hi):
            yield (t_oh, v)

    def evaluate(cfg):
        return time_us(
            lambda: ops.bfp_conv2d(x, wk, policy, stride, padding,
                                   interpret, tiles=cfg),
            iters=iters)

    best, us, steps = _hillclimb(start, neighbors, evaluate, max_steps)
    entry = {"t_oh": best[0], "bn": best[1], "bk": policy.block_k,
             "us": round(us, 1), "steps": steps}
    cache.store("conv", rows, kk, oc, policy.l_i, policy.l_w,
                policy.block_k, target, entry)
    return entry
