"""Optimizers (pure JAX, optax-style init/update pairs) + LR schedules.

Includes the WSD (warmup-stable-decay) schedule that minicpm-2b trains
with (arXiv:2404.06395), cosine, and linear warmup.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "sgd_init", "sgd_update",
           "clip_by_global_norm", "global_norm",
           "cosine_schedule", "wsd_schedule", "constant_schedule",
           "OptState"]


class OptState(NamedTuple):
    step: jax.Array
    mu: any
    nu: any


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)
              if hasattr(x, "dtype")
              and jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _is_float(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: g * scale if _is_float(g) else g, grads), norm


def adamw_init(params) -> OptState:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32)
        if _is_float(x) else x, p)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                    nu=zeros(params))


def adamw_update(grads, state: OptState, params, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1) -> Tuple[any, OptState]:
    # Non-float leaves (int metadata; float0 grads from allow_int=True)
    # pass through untouched.
    step = state.step + 1
    t = step.astype(jnp.float32)
    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32)
        if _is_float(g) else m, state.mu, grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32))
        if _is_float(g) else v, state.nu, grads)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, m, v):
        if not _is_float(p):
            return p
        mhat = m / bc1
        vhat = v / bc2
        return (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay
                        * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu)


def sgd_init(params) -> OptState:
    mom = jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=mom, nu=None)


def sgd_update(grads, state: OptState, params, lr, momentum: float = 0.9
               ) -> Tuple[any, OptState]:
    mu = jax.tree_util.tree_map(
        lambda m, g: momentum * m + g.astype(jnp.float32), state.mu, grads)
    new_params = jax.tree_util.tree_map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, mu)
    return new_params, OptState(step=state.step + 1, mu=mu, nu=None)


# ---------------------------------------------------------------------------
# Schedules: step -> lr
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return f


def wsd_schedule(peak: float, warmup: int, stable: int, decay: int,
                 floor_frac: float = 0.01) -> Callable:
    """Warmup-Stable-Decay (minicpm): linear warmup, flat plateau, then a
    short exponential-ish (here linear-log) decay to floor."""
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak * jnp.exp(jnp.log(floor_frac) * prog)
        return jnp.where(step < warmup, warm,
                         jnp.where(step < warmup + stable, peak, dec))
    return f
