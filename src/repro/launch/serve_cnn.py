"""CNN serving launcher: batched BFP inference on a bound plan.

The paper-model counterpart of ``repro.launch.serve`` — admits image
requests into the slot-table engine, serves them with iteration-level
batching on the bind-once plan, optionally under a data-parallel mesh,
or as several MULTI-TENANT models in one process:

  PYTHONPATH=src python -m repro.launch.serve_cnn --model vgg16 \
      --requests 32 --slots 8 --bfp --prequant
  PYTHONPATH=src python -m repro.launch.serve_cnn --model resnet18 \
      --scale full --mesh 1x1 --bfp --strict-backend
  PYTHONPATH=src python -m repro.launch.serve_cnn \
      --tenants lenet,cifarnet --requests 12 --bfp
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core.policy import PAPER_DEFAULT
from repro.dist.sharding import DEFAULT_RULES
from repro.launch.mesh import make_mesh
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine, ImageRequest


def _serve_tenants(args, policy):
    """Multi-tenant path: every listed model serves from one process."""
    from repro.serve.tenants import MultiTenantServer

    names = [m.strip() for m in args.tenants.split(",") if m.strip()]
    bad = [m for m in names if m not in MODELS]
    if bad:
        raise SystemExit(f"unknown tenant model(s) {bad}; "
                         f"available: {sorted(MODELS)}")
    srv = MultiTenantServer(slots=args.slots, batching=args.batching,
                            max_wait=args.max_wait,
                            strict_backend=args.strict_backend)
    for m in names:
        srv.add_tenant(m, m, params=MODELS[m].init(jax.random.PRNGKey(0)),
                       policy=policy, prequant=args.prequant)
    keys = jax.random.split(jax.random.PRNGKey(1), args.requests)
    reqs = []
    for i in range(args.requests):
        m = names[i % len(names)]
        shape = MODELS[m].input_shape()
        reqs.append((m, srv.submit(
            m, ImageRequest(rid=i, image=jax.random.normal(keys[i],
                                                           shape)))))
    t0 = time.perf_counter()
    srv.run()
    dt = max(time.perf_counter() - t0, 1e-9)
    for m, r in reqs[:4]:
        print(f"req {r.rid} [{m}]: label={r.label}")
    st = srv.stats()
    for m in names:
        print(f"tenant {m}: {st['tenants'][m]}")
    print(f"{st['total']['completed']} requests across {len(names)} "
          f"tenants in {dt:.2f}s ({st['total']['completed'] / dt:.1f} "
          f"req/s) batching={args.batching}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=sorted(MODELS),
                    help="single-tenant model (or use --tenants)")
    ap.add_argument("--tenants", metavar="M1,M2,...",
                    help="serve several models as tenants of one "
                         "process (round-robin traffic)")
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--bfp", action="store_true",
                    help="BFP-8 activation x weight datapath per site")
    ap.add_argument("--prequant", action="store_true",
                    help="pre-quantize weights at bind (wire format)")
    ap.add_argument("--strict-backend", action="store_true",
                    help="refuse backend downgrades at admission")
    ap.add_argument("--mesh", metavar="DxM",
                    help="data x model mesh, e.g. 1x1 (device count must "
                         "match); shards the request batch axis")
    ap.add_argument("--batching", default="continuous",
                    choices=["continuous", "bucket"],
                    help="run partially-filled steps immediately vs the "
                         "bucket-barrier baseline")
    ap.add_argument("--max-wait", type=int, default=4,
                    help="bucket mode: deferred steps before a partial "
                         "batch runs anyway")
    args = ap.parse_args()

    policy_ = (PAPER_DEFAULT.with_(straight_through=False) if args.bfp
               else None)
    if args.tenants:
        _serve_tenants(args, policy_)
        return
    if not args.model:
        ap.error("pass --model (single tenant) or --tenants")

    spec = MODELS[args.model]
    reduced = args.scale == "smoke"
    params = spec.init(jax.random.PRNGKey(0), reduced=reduced)
    policy = policy_
    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    eng = CnnServeEngine(params, spec.apply, policy, slots=args.slots,
                         prequant=args.prequant,
                         strict_backend=args.strict_backend,
                         batching=args.batching, max_wait=args.max_wait,
                         mesh=mesh, rules=DEFAULT_RULES)
    print(f"bound plan: {eng.plan!r}")
    h, w, c = spec.input_shape(reduced=reduced)
    keys = jax.random.split(jax.random.PRNGKey(1), args.requests)
    reqs = [eng.submit(ImageRequest(
        rid=i, image=jax.random.normal(keys[i], (h, w, c))))
        for i in range(args.requests)]
    # compile EVERY bucket off the clock (a tail batch smaller than the
    # slot count selects a smaller bucket, whose first compile would
    # otherwise land inside the timed window), via a throwaway engine on
    # the same plan — Plan.jit_forward shares the traced callables
    warm = CnnServeEngine(None, spec.apply, eng.plan, slots=args.slots,
                          mesh=mesh, rules=DEFAULT_RULES)
    for b in warm.buckets:
        for _ in range(b):
            warm.submit(image=jax.numpy.zeros((h, w, c)))
        warm.run()
    t0 = time.perf_counter()
    eng.run()
    dt = max(time.perf_counter() - t0, 1e-9)
    served = [r for r in reqs if r.done]
    for r in served[:4]:
        print(f"req {r.rid}: label={r.label}")
    print(f"{len(served)} requests in {dt:.2f}s "
          f"({len(served) / dt:.1f} req/s) model={args.model} "
          f"bfp={args.bfp} prequant={args.prequant} mesh={args.mesh}")


if __name__ == "__main__":
    main()
