"""CNN serving launcher: batched BFP inference on a bound plan.

The paper-model counterpart of ``repro.launch.serve`` — admits image
requests into the slot-table engine, serves them in bucketed batches on
the bind-once plan, optionally under a data-parallel mesh:

  PYTHONPATH=src python -m repro.launch.serve_cnn --model vgg16 \
      --requests 32 --slots 8 --bfp --prequant
  PYTHONPATH=src python -m repro.launch.serve_cnn --model resnet18 \
      --scale full --mesh 1x1 --bfp --strict-backend
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core.policy import PAPER_DEFAULT
from repro.dist.sharding import DEFAULT_RULES
from repro.launch.mesh import make_mesh
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine, ImageRequest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", required=True, choices=sorted(MODELS))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--bfp", action="store_true",
                    help="BFP-8 activation x weight datapath per site")
    ap.add_argument("--prequant", action="store_true",
                    help="pre-quantize weights at bind (wire format)")
    ap.add_argument("--strict-backend", action="store_true",
                    help="refuse backend downgrades at admission")
    ap.add_argument("--mesh", metavar="DxM",
                    help="data x model mesh, e.g. 1x1 (device count must "
                         "match); shards the request batch axis")
    args = ap.parse_args()

    spec = MODELS[args.model]
    reduced = args.scale == "smoke"
    params = spec.init(jax.random.PRNGKey(0), reduced=reduced)
    policy = (PAPER_DEFAULT.with_(straight_through=False) if args.bfp
              else None)
    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_mesh((d, m), ("data", "model"))

    eng = CnnServeEngine(params, spec.apply, policy, slots=args.slots,
                         prequant=args.prequant,
                         strict_backend=args.strict_backend,
                         mesh=mesh, rules=DEFAULT_RULES)
    print(f"bound plan: {eng.plan!r}")
    h, w, c = spec.input_shape(reduced=reduced)
    keys = jax.random.split(jax.random.PRNGKey(1), args.requests)
    reqs = [eng.submit(ImageRequest(
        rid=i, image=jax.random.normal(keys[i], (h, w, c))))
        for i in range(args.requests)]
    # compile EVERY bucket off the clock (a tail batch smaller than the
    # slot count selects a smaller bucket, whose first compile would
    # otherwise land inside the timed window), via a throwaway engine on
    # the same plan — Plan.jit_forward shares the traced callables
    warm = CnnServeEngine(None, spec.apply, eng.plan, slots=args.slots,
                          mesh=mesh, rules=DEFAULT_RULES)
    for b in warm.buckets:
        for _ in range(b):
            warm.submit(image=jax.numpy.zeros((h, w, c)))
        warm.run()
    t0 = time.perf_counter()
    eng.run()
    dt = max(time.perf_counter() - t0, 1e-9)
    served = [r for r in reqs if r.done]
    for r in served[:4]:
        print(f"req {r.rid}: label={r.label}")
    print(f"{len(served)} requests in {dt:.2f}s "
          f"({len(served) / dt:.1f} req/s) model={args.model} "
          f"bfp={args.bfp} prequant={args.prequant} mesh={args.mesh}")


if __name__ == "__main__":
    main()
