"""Per-(arch x shape) input stand-ins and step functions for the dry-run.

``build_cell`` returns everything needed to lower one cell WITHOUT any
device allocation: ShapeDtypeStruct trees for all inputs, matching
PartitionSpec trees, the step callable, and the axis rules.  Modality
frontends are stubs per the assignment: [audio]/[vlm] get precomputed
frame/patch embeddings as ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import LMConfig, ShapeConfig
from repro.dist import specs as SP
from repro.dist.sharding import DEFAULT_RULES
from repro.models.lm import model as Mdl
from repro.optim import optimizers as opt
from repro.train.step import TrainState, make_train_step

__all__ = ["build_cell", "cell_rules", "input_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def cell_rules(cfg: LMConfig, shape: ShapeConfig, mesh: Mesh) -> Dict:
    """Logical->physical rules for this cell (DESIGN.md §5)."""
    rules = dict(DEFAULT_RULES)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a, n in zip(mesh.axis_names, mesh.devices.shape):
        if a in batch_axes:
            dp *= n
    if shape.global_batch % dp != 0:        # e.g. long_500k batch=1
        rules["batch"] = None
    else:
        rules["batch"] = batch_axes if len(batch_axes) > 1 else \
            (batch_axes[0] if batch_axes else None)
    if shape.kind in ("train", "prefill"):
        rules["seq_res"] = "model"          # Megatron-style sequence parallel
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if cfg.is_moe:
        if cfg.n_experts % model_size == 0:
            rules["ffn"] = None             # EP (olmoe): no TP inside experts
        else:
            rules["experts"] = None         # mixtral: TP inside experts
    if cfg.n_kv_heads % model_size != 0:
        rules["kv_heads"] = None            # MQA/GQA kv < chips: replicate
    if cfg.n_heads % model_size != 0:
        rules["heads"] = None
    if cfg.d_ff % model_size != 0:
        rules["ffn"] = None
    return rules


def input_specs(cfg: LMConfig, shape: ShapeConfig,
                mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = _sds((b, s), jnp.int32)
        out["targets"] = _sds((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((b, s), jnp.int32)
    else:  # decode: one new token against a cache of seq_len
        out["tokens"] = _sds((b, 1), jnp.int32)
        out["pos"] = _sds((), jnp.int32)
    if cfg.is_encdec and shape.kind != "decode":
        out["enc_feats"] = _sds((b, cfg.enc_seq_stub, cfg.d_model),
                                jnp.bfloat16)
    return out


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    fn: Callable                    # positional (state-like..., inputs...)
    args: Tuple[Any, ...]           # ShapeDtypeStruct pytrees (positional)
    in_specs: Tuple[Any, ...]       # matching PartitionSpec pytrees
    out_specs: Any
    donate: Tuple[int, ...]
    rules: Dict


def with_layer_units(cfg: LMConfig, units: int) -> LMConfig:
    """Scale the repeated layer stack to ``units`` layer-units, keeping all
    non-repeated structure (embed, head, hybrid remainder) intact.

    Used by the roofline tier (launch.dryrun --mode roofline): compile at
    units=1 and units=2 with unrolled loops, then extrapolate exactly:
    F(L) = F(1) + (L-1) * (F(2) - F(1)) since every unit is identical.
    A layer-unit is one pattern period (hybrid), one (enc+dec) layer pair
    (enc-dec), or one layer (all other families).
    """
    if cfg.block_pattern:
        rem = cfg.n_layers % len(cfg.block_pattern)
        return dataclasses.replace(
            cfg, n_layers=units * len(cfg.block_pattern) + rem)
    if cfg.is_encdec:
        return dataclasses.replace(cfg, n_layers=units,
                                   encoder_layers=units)
    return dataclasses.replace(cfg, n_layers=units)


def layer_units(cfg: LMConfig) -> int:
    """Number of layer-units the full config has (see with_layer_units)."""
    if cfg.block_pattern:
        return cfg.n_layers // len(cfg.block_pattern)
    return cfg.n_layers


def pad_heads_for_tp(cfg: LMConfig, model_size: int) -> LMConfig:
    """Pad attention heads up to a multiple of the TP degree (standard
    Megatron practice): e.g. minicpm 36 heads -> 48 on a 16-way model
    axis.  Zero-padded heads are mathematically inert; here (cost
    analysis) they appear as +33% attention width in exchange for 16x
    sharding instead of full replication — §Perf iteration."""
    def up(n):
        return -(-n // model_size) * model_size
    h = up(cfg.n_heads)
    hk = up(cfg.n_kv_heads) if cfg.n_kv_heads == cfg.n_heads \
        else cfg.n_kv_heads
    return dataclasses.replace(cfg, n_heads=h, n_kv_heads=hk)


def _strip_fsdp(spec_tree):
    """Inference param layout: TP ('model') only, replicated over the data
    axes — kills per-step FSDP weight all-gathers at serving time."""
    def fix(sp):
        return P(*[None if ax in ("data", "pod") else
                   (tuple(a for a in ax if a not in ("data", "pod")) or None
                    if isinstance(ax, tuple) else ax)
                   for ax in sp])
    return jax.tree_util.tree_map(fix, spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: LMConfig, shape: ShapeConfig, mesh: Mesh,
               analysis_unroll: bool = True,
               bfp_weights=None,            # BFPPolicy -> int8 wire format
               inference_no_fsdp: bool = False,
               pad_heads: bool = False) -> Cell:
    cfg = dataclasses.replace(cfg, compute_dtype="bfloat16",
                              analysis_unroll=analysis_unroll)
    if pad_heads:
        model_size = dict(zip(mesh.axis_names,
                              mesh.devices.shape)).get("model", 1)
        cfg = pad_heads_for_tp(cfg, model_size)
    rules = cell_rules(cfg, shape, mesh)
    batch_axes = rules["batch"]
    ins = input_specs(cfg, shape, mesh)

    def _make_params(key):
        p = Mdl.init_params(cfg, key)
        if bfp_weights is not None:
            from repro.core.prequant import quantize_param_tree
            p = quantize_param_tree(p, bfp_weights)
        return p

    params_sds = jax.eval_shape(_make_params, jax.random.PRNGKey(0))
    pspecs = SP.param_specs(cfg, params_sds, mesh)
    if inference_no_fsdp:
        pspecs = _strip_fsdp(pspecs)

    if shape.kind == "train":
        state_sds = TrainState(params=params_sds,
                               opt_state=jax.eval_shape(opt.adamw_init,
                                                        params_sds),
                               step=_sds((), jnp.int32))
        sspecs = TrainState(params=pspecs,
                            opt_state=opt.OptState(step=P(), mu=pspecs,
                                                   nu=pspecs),
                            step=P())
        step_fn = make_train_step(cfg, opt.constant_schedule(1e-4))

        def fn(state, tokens, targets):
            new_state, metrics = step_fn(state, (tokens, targets))
            return new_state, metrics["loss"]

        bspec = P(batch_axes, None)
        return Cell(cfg.name, shape, fn,
                    (state_sds, ins["tokens"], ins["targets"]),
                    (sspecs, bspec, bspec),
                    (sspecs, P()), donate=(0,), rules=rules)

    if shape.kind == "prefill":
        if cfg.is_encdec:
            def fn(params, tokens, enc_feats):
                logits, _ = Mdl.forward(params, cfg, tokens,
                                        enc_feats=enc_feats)
                return logits[:, -1]
            espec = P(batch_axes, None, None)
            return Cell(cfg.name, shape, fn,
                        (params_sds, ins["tokens"], ins["enc_feats"]),
                        (pspecs, P(batch_axes, None), espec),
                        P(batch_axes, None), donate=(), rules=rules)

        def fn(params, tokens):
            logits, _ = Mdl.forward(params, cfg, tokens)
            return logits[:, -1]
        return Cell(cfg.name, shape, fn, (params_sds, ins["tokens"]),
                    (pspecs, P(batch_axes, None)),
                    P(batch_axes, None), donate=(), rules=rules)

    # decode: serve_step with a cache of seq_len tokens
    cache_sds = jax.eval_shape(
        functools.partial(Mdl.init_cache, cfg, shape.global_batch,
                          shape.seq_len))
    if cfg.is_encdec:
        cache_sds = dict(cache_sds, enc_out=_sds(
            (shape.global_batch, cfg.enc_seq_stub, cfg.d_model),
            jnp.bfloat16))
    cspecs = SP.cache_specs(cfg, cache_sds, mesh)
    if rules["batch"] is None:  # long_500k: strip batch sharding from cache
        cspecs = jax.tree_util.tree_map(
            lambda sp: P(*[None if ax in ("pod", "data",
                                          ("pod", "data"), ("data",))
                           else ax for ax in sp]),
            cspecs, is_leaf=lambda x: isinstance(x, P))

    def fn(params, cache, tokens, pos):
        logits, new_cache = Mdl.decode_step(params, cfg, cache, tokens, pos)
        return logits, new_cache

    return Cell(cfg.name, shape, fn,
                (params_sds, cache_sds, ins["tokens"], ins["pos"]),
                (pspecs, cspecs, P(batch_axes, None), P()),
                (P(batch_axes, None, None), cspecs),
                donate=(1,), rules=rules)
