import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes; extract memory / cost / collective analyses.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first init, and the dry-run (and only the
dry-run) needs 512 placeholder host devices to build the 2x16x16 mesh.

Two modes (both resumable via --skip-existing; one JSON per cell):

  --mode compile   (default) full-size model, layer loops as lax.scan —
      fast compile; proves lowering/SPMD-partitioning works and gives the
      true memory analysis.  XLA cost_analysis visits scan bodies once,
      so flops/bytes/collectives from this mode UNDERCOUNT; use roofline
      mode for those.

  --mode roofline  exact per-step cost terms via layer-unit scaling:
      compile UNROLLED models at 1 and 2 layer-units (full width, full
      shapes) and extrapolate F(L) = F1 + (L-1)(F2 - F1) — exact because
      every unit is identical.  Collective byte counts extrapolate the
      same way.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --mode roofline --mesh single
  ... --arch mixtral-8x7b --shape train_4k --out results/dryrun
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.dist.sharding import axis_rules
from repro.launch.input_specs import build_cell, layer_units, with_layer_units
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA


def _compile_cell(cfg, shape, mesh, analysis_unroll):
    cell = build_cell(cfg, shape, mesh, analysis_unroll=analysis_unroll)
    sin = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), cell.in_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    sout = jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), cell.out_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    with axis_rules(cell.rules, mesh):
        jitted = jax.jit(cell.fn, in_shardings=sin, out_shardings=sout,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return compiled


def _extract(compiled):
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        cost = {"error": str(e)}
    coll = {}
    try:
        coll = RA.collective_bytes(compiled.as_text())
    except Exception as e:
        coll = {"error": str(e)}
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {"argument_bytes": getattr(ma, "argument_size_in_bytes", None),
               "output_bytes": getattr(ma, "output_size_in_bytes", None),
               "temp_bytes": getattr(ma, "temp_size_in_bytes", None)}
    except Exception as e:
        mem = {"error": str(e)}
    return cost, coll, mem


def _model_flops(cfg, shape):
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    return factor * n_active * tokens


def run_cell_compile(arch, shape_name, mesh, mesh_name, out_dir):
    cfg, shape = ARCHS[arch], SHAPES[shape_name]
    t0 = time.time()
    compiled = _compile_cell(cfg, shape, mesh, analysis_unroll=False)
    t_compile = time.time() - t0
    cost, coll, mem = _extract(compiled)
    result = {
        "mode": "compile", "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "n_devices": int(mesh.devices.size),
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "cost_analysis_scan_counted_once": cost,
        "collective_bytes_scan_counted_once": coll,
        "params": int(cfg.param_count()),
        "status": "ok",
    }
    _write(out_dir, mesh_name, arch, shape_name, "compile", result)
    return result


def run_cell_roofline(arch, shape_name, mesh, mesh_name, out_dir):
    cfg, shape = ARCHS[arch], SHAPES[shape_name]
    units = layer_units(cfg)
    t0 = time.time()
    res = {}
    for u in (1, 2):
        compiled = _compile_cell(with_layer_units(cfg, u), shape, mesh,
                                 analysis_unroll=True)
        res[u] = _extract(compiled)
    t_compile = time.time() - t0

    def corr(metric_fn):
        f1, f2 = metric_fn(res[1]), metric_fn(res[2])
        return f1 + (units - 1) * (f2 - f1)

    cost1, coll1, _ = res[1]
    flops = corr(lambda r: r[0].get("flops", 0.0))
    bytes_ = corr(lambda r: r[0].get("bytes accessed", 0.0))
    coll_kinds = set(res[1][1]) | set(res[2][1])
    coll = {k: int(corr(lambda r: float(r[1].get(k, 0))))
            for k in coll_kinds if not isinstance(res[1][1].get(k), str)}

    hw = RA.HW(chips=int(mesh.devices.size))
    terms = RA.roofline_terms({"flops": flops, "bytes accessed": bytes_},
                              coll, hw)
    model_flops = _model_flops(cfg, shape)
    hlo_total = flops * mesh.devices.size
    result = {
        "mode": "roofline", "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "n_devices": int(mesh.devices.size),
        "layer_units": units, "compile_s": round(t_compile, 1),
        "cost_analysis": {"flops": flops, "bytes_accessed": bytes_},
        "collective_bytes": coll,
        "roofline": terms,
        "model_flops": model_flops,
        "useful_flop_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "params": int(cfg.param_count()),
        "status": "ok",
    }
    _write(out_dir, mesh_name, arch, shape_name, "roofline", result)
    return result


def _write(out_dir, mesh_name, arch, shape_name, mode, result):
    path = os.path.join(out_dir, mesh_name,
                        f"{arch}__{shape_name}.{mode}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="compile",
                    choices=["compile", "roofline"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    runner = (run_cell_compile if args.mode == "compile"
              else run_cell_roofline)

    failures = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            cfg = ARCHS[arch]
            for shape_name in shapes:
                if shape_name == "long_500k" and not cfg.sub_quadratic:
                    print(f"SKIP  {mesh_name} {arch} {shape_name} "
                          f"(quadratic attn; DESIGN.md §4)", flush=True)
                    continue
                path = os.path.join(args.out, mesh_name,
                                    f"{arch}__{shape_name}.{args.mode}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"CACHED {mesh_name} {arch} {shape_name}",
                          flush=True)
                    continue
                try:
                    r = runner(arch, shape_name, mesh, mesh_name, args.out)
                    extra = ""
                    if args.mode == "roofline":
                        t = r["roofline"]
                        extra = (f" flops={t['hlo_flops']:.3g}"
                                 f" dom={t['dominant']}"
                                 f" useful={r['useful_flop_ratio']:.2f}")
                    print(f"OK    {mesh_name} {arch} {shape_name} "
                          f"compile={r['compile_s']}s{extra}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"FAIL  {mesh_name} {arch} {shape_name}: "
                          f"{type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
