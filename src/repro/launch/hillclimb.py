"""§Perf hillclimbing driver — named variations over the 3 chosen cells.

Each variation re-lowers the cell (roofline methodology: 1- and 2-unit
unrolled compiles, exact extrapolation) and reports the three roofline
terms, so a before/after lands in EXPERIMENTS.md §Perf.

Cells (picked per the assignment):
  A  minicpm-2b prefill_32k      worst useful-FLOP ratio (0.027)
  B  olmoe-1b-7b prefill_32k     most collective-bound runnable cell
  C  mistral-nemo-12b decode_32k most representative of the paper's
                                 technique (weight-streaming bound ->
                                 BFP-8 weights cut HBM+wire bytes)

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [--cell A B C]
Writes results/hillclimb/<cell>__<variant>.json
"""
import os

# The 512-fake-device host platform must be requested before jax
# initializes — but never clobber flags the user already set.
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.core.policy import BFPPolicy
from repro.dist.sharding import axis_rules
from repro.launch import dryrun as DR
from repro.launch.input_specs import build_cell, layer_units, with_layer_units
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA

_BFP8 = BFPPolicy(l_w=8, l_i=8, block_k=128)  # 128 divides every arch dim


def measure(arch, shape_name, mesh, build_kwargs, rules_patch=None):
    cfg, shape = ARCHS[arch], SHAPES[shape_name]
    units = layer_units(cfg)
    res = {}
    t0 = time.time()
    for u in (1, 2):
        cell = build_cell(with_layer_units(cfg, u), shape, mesh,
                          analysis_unroll=True, **build_kwargs)
        if rules_patch:
            cell.rules.update(rules_patch)
        sin = jax.tree_util.tree_map(
            lambda s: jax.NamedSharding(mesh, s), cell.in_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        sout = jax.tree_util.tree_map(
            lambda s: jax.NamedSharding(mesh, s), cell.out_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        with axis_rules(cell.rules, mesh):
            comp = jax.jit(cell.fn, in_shardings=sin, out_shardings=sout,
                           donate_argnums=cell.donate).lower(
                               *cell.args).compile()
        res[u] = DR._extract(comp)

    def corr(fn):
        f1, f2 = fn(res[1]), fn(res[2])
        return f1 + (units - 1) * (f2 - f1)

    flops = corr(lambda r: r[0].get("flops", 0.0))
    bytes_ = corr(lambda r: r[0].get("bytes accessed", 0.0))
    kinds = set(res[1][1]) | set(res[2][1])
    coll = {k: corr(lambda r: float(r[1].get(k, 0)))
            for k in kinds if not isinstance(res[1][1].get(k), str)}
    hw = RA.HW(chips=int(mesh.devices.size))
    terms = RA.roofline_terms({"flops": flops, "bytes accessed": bytes_},
                              {k: int(v) for k, v in coll.items()}, hw)
    terms["compile_s"] = round(time.time() - t0, 1)
    return terms


VARIANTS = {
    "A": ("minicpm-2b", "prefill_32k", [
        ("baseline", {}, None),
        # H: 36 heads % 16 != 0 -> attention replicated over model (16x
        # attn FLOPs/chip).  Pad heads 36->48: +33% width, 16x sharding.
        ("pad_heads", dict(pad_heads=True), None),
        # H: and stream weights as BFP-8 (paper): HBM bytes drop further.
        ("pad_heads+bfp8w", dict(pad_heads=True, bfp_weights=_BFP8), None),
        # H: flash QK/PV operands in bf16 (f32 accumulate) halve the score
        # traffic that dominates prefill bytes.  (Code change in
        # common._flash_sdpa; this re-measures cell A after it.)
        ("pad_heads+bf16_flash", dict(pad_heads=True), None),
    ]),
    "B": ("olmoe-1b-7b", "prefill_32k", [
        ("baseline", {}, None),
        # H: EP dispatch gathers token buffers; sharding experts over
        # (data x model) = 256-way spreads dispatch buffers AND turns the
        # expert all-gather into an all-to-all of 1/16 the payload.
        ("ep_2d", {}, {"experts": ("data", "model")}),
        # H: TP-inside-experts instead of EP (no token redistribution,
        # but replicated expert buffers) — expected to LOSE on memory.
        ("tp_experts", {}, {"experts": None, "ffn": "model"}),
    ]),
    "C": ("mistral-nemo-12b", "decode_32k", [
        ("baseline", {}, None),
        # H: FSDP at decode all-gathers every weight each step; inference
        # layout (TP only, replicated over data) kills those collectives.
        ("no_fsdp", dict(inference_no_fsdp=True), None),
        # H (paper): BFP-8 weight wire format halves HBM bytes vs bf16
        # and cuts any remaining weight traffic 2x; activation cost
        # unchanged.  The paper's off-chip-traffic claim, measured.
        ("no_fsdp+bfp8w", dict(inference_no_fsdp=True,
                               bfp_weights=_BFP8), None),
        ("bfp8w_only", dict(bfp_weights=_BFP8), None),
    ]),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs="*", default=["A", "B", "C"])
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()
    mesh = make_production_mesh()
    os.makedirs(args.out, exist_ok=True)
    for cid in args.cell:
        arch, shape, variants = VARIANTS[cid]
        for name, kwargs, rules_patch in variants:
            path = os.path.join(args.out, f"{cid}__{name}.json")
            if os.path.exists(path):
                print(f"CACHED {cid} {name}", flush=True)
                continue
            try:
                t = measure(arch, shape, mesh, kwargs, rules_patch)
                with open(path, "w") as f:
                    json.dump({"cell": cid, "arch": arch, "shape": shape,
                               "variant": name, **t}, f, indent=1)
                print(f"OK {cid} {name}: comp={t['t_compute']:.3f}s "
                      f"mem={t['t_memory']:.3f}s coll={t['t_collective']:.3f}s "
                      f"dom={t['dominant']}", flush=True)
            except Exception as e:
                print(f"FAIL {cid} {name}: {type(e).__name__}: {e}",
                      flush=True)


if __name__ == "__main__":
    main()
