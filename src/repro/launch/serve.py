"""Serving launcher: --arch <id>, continuous-batching engine, optional
BFP-8 datapath + prequantized weights (the paper's deployment).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
      --requests 8 --max-new 16 --bfp --bfp-weights
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.policy import BFPPolicy, PAPER_DEFAULT
from repro.core.prequant import quantize_param_tree
from repro.models.lm.model import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--bfp", action="store_true",
                    help="BFP-8 activation x weight datapath per GEMM")
    ap.add_argument("--bfp-weights", action="store_true",
                    help="store weights as int8 mantissa + exponent sidecar")
    ap.add_argument("--batching", default="continuous",
                    choices=["continuous", "bucket"],
                    help="iteration-level batching (chunked prefill in "
                         "the step loop) vs the legacy blocking-prefill "
                         "bucket baseline")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens a prefilling slot consumes per "
                         "step in continuous mode (0 = whole prompt)")
    args = ap.parse_args()

    base = ARCHS[args.arch]
    cfg = base if args.scale == "full" else reduced(
        base, n_layers=4, d_model=128, d_ff=256, vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    if args.bfp_weights:
        params = quantize_param_tree(params, BFPPolicy(block_k=32))
    policy = PAPER_DEFAULT.with_(straight_through=False) if args.bfp else None

    eng = ServeEngine(params, cfg, slots=args.slots, max_len=args.max_len,
                      policy=policy, batching=args.batching,
                      prefill_chunk=args.prefill_chunk or None)
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=[1 + i, 7, 3], max_new=args.max_new))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    for r in done[:4]:
        print(f"req {r.rid}: {r.out}")
    print(f"{toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s) "
          f"bfp={args.bfp} bfp_weights={args.bfp_weights}")


if __name__ == "__main__":
    main()
