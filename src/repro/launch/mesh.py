"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS --xla_force_host_platform_device_count=512 before any jax import
and then calls it.

Single pod:  (16, 16)      axes ("data", "model")   — 256 chips (v5e pod)
Multi pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips.
The "pod" axis carries pure data parallelism; gradient reduction across it
is the slow-link collective the multi-pod dry-run proves out.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 2))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
