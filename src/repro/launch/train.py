"""Production training launcher: --arch <id> at full or scaled size.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --scale smoke --steps 100 --ckpt-dir /tmp/ckpt [--bfp] [--compress-grads]

--scale full uses the exact public config (needs a pod: params won't fit
one CPU host); --scale smoke / 100m build reduced same-family configs.
On a real TPU fleet this driver runs under jax.distributed with the mesh
from repro.launch.mesh and the shardings from repro.dist.specs — the
single-host path here exercises the identical step/loop/checkpoint code.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.policy import PAPER_DEFAULT
from repro.data.pipeline import LMBatchSpec
from repro.dist.compress import make_compressor
from repro.optim import optimizers as opt
from repro.train.loop import LoopConfig, run_training
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "100m",
                                                         "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd",
                                                             "const"])
    ap.add_argument("--bfp", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    base = ARCHS[args.arch]
    if args.scale == "full":
        cfg = base
    elif args.scale == "100m":
        cfg = reduced(base, n_layers=8, d_model=512, d_ff=2048, vocab=8192)
    else:
        cfg = reduced(base)

    state = init_state(cfg, jax.random.PRNGKey(0))
    sched = {"cosine": opt.cosine_schedule(args.lr, 20, args.steps),
             "wsd": opt.wsd_schedule(args.lr, 20, int(args.steps * 0.6),
                                     int(args.steps * 0.3)),
             "const": opt.constant_schedule(args.lr)}[args.schedule]

    grad_transform = None
    if args.compress_grads:
        init_fn, transform = make_compressor(bits=8)
        residual = [init_fn(state.params)]

        def grad_transform(grads):
            q, residual[0] = transform(grads, residual[0])
            return q

    step = make_train_step(cfg, sched,
                           policy=PAPER_DEFAULT if args.bfp else None,
                           grad_transform=grad_transform)
    if grad_transform is None:
        step = jax.jit(step)
    spec = LMBatchSpec(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)
    out = run_training(state, step, spec,
                       LoopConfig(total_steps=args.steps,
                                  ckpt_dir=args.ckpt_dir,
                                  ckpt_every=args.ckpt_every),
                       log_fn=lambda s, m: print(
                           f"step {s} loss {m['loss']:.4f}", flush=True))
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}; "
          f"median step {out['median_step_s'] * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
