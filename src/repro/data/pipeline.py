"""Deterministic synthetic data pipelines.

Fault-tolerance contract (DESIGN.md §5): ``step -> batch`` is a PURE
function of (seed, step, shard), so any host can recompute any shard after
a failure or an elastic re-shard — no data-loader state to checkpoint.

LM stream: a learnable second-order pattern (token depends on the two
previous tokens through a fixed random mixing table) so a ~100M model's
loss visibly drops within a few hundred steps (examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["LMBatchSpec", "lm_batch", "image_batch", "host_shard"]


@dataclasses.dataclass(frozen=True)
class LMBatchSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pattern_vocab: int = 512   # active band of the vocab (learnability)


def lm_batch(spec: LMBatchSpec, step: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deterministic (tokens, targets) for a global step.

    t_{i+1} = (a * t_i + b * t_{i-1} + c_i) mod P with sparse noise — a
    structure a transformer learns quickly but not instantly.
    """
    p = min(spec.pattern_vocab, spec.vocab_size)
    key = jax.random.fold_in(jax.random.PRNGKey(spec.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s = spec.global_batch, spec.seq_len
    t0 = jax.random.randint(k1, (b, 2), 0, p)
    noise = (jax.random.uniform(k2, (b, s)) < 0.05)
    noise_tok = jax.random.randint(k3, (b, s), 0, p)

    def step_fn(carry, i):
        t_prev2, t_prev1 = carry
        nxt = (5 * t_prev1 + 3 * t_prev2 + 7) % p
        nxt = jnp.where(noise[:, i], noise_tok[:, i], nxt)
        return (t_prev1, nxt), nxt

    _, toks = jax.lax.scan(step_fn, (t0[:, 0], t0[:, 1]), jnp.arange(s))
    tokens = toks.T.astype(jnp.int32)            # [B, S]
    targets = jnp.roll(tokens, -1, axis=1)
    return tokens, targets


def image_batch(key, num_classes: int, batch: int, hw: int, ch: int,
                templates: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Class-template images + noise (the in-repo 'mnist'/'cifar10').

    Returns (images [B,H,W,C], labels [B], templates) — pass templates back
    in for a consistent dataset across batches.
    """
    kt, kl, kn, ks = jax.random.split(key, 4)
    if templates is None:
        templates = jax.random.normal(kt, (num_classes, hw, hw, ch))
        # smooth the templates a little (structured, image-like)
        templates = (templates
                     + jnp.roll(templates, 1, 1) + jnp.roll(templates, -1, 1)
                     + jnp.roll(templates, 1, 2) + jnp.roll(templates, -1, 2)
                     ) / 5.0
    labels = jax.random.randint(kl, (batch,), 0, num_classes)
    imgs = templates[labels]
    shift = jax.random.randint(ks, (batch, 2), -2, 3)
    imgs = jax.vmap(lambda im, sh: jnp.roll(im, sh, axis=(0, 1)))(imgs, shift)
    imgs = imgs + 0.35 * jax.random.normal(kn, imgs.shape)
    return imgs, labels, templates


def host_shard(global_batch: int, process_index: Optional[int] = None,
               process_count: Optional[int] = None) -> slice:
    """Which rows of the global batch this host materializes."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    per = global_batch // pc
    return slice(pi * per, (pi + 1) * per)
