"""Measured gradient NSR vs the analytic bound, per backward GEMM.

The backward tap events carry EXACTLY the operands the backward GEMM
executed (already transposed, already tile-fitted policy), so the same
:func:`repro.core.nsr.gemm_nsr_upper_bound` that bounds a forward GEMM
bounds a backward one — no separate derivation, just grad-side geometry
(DESIGN.md §12.4).  :func:`measure_gradient_nsr` runs a gradient
computation under a ``want_float`` tap and returns one record per
backward event with both sides of the inequality

    eta_measured  <=  eta_bound        (hard, deterministic)

which tests/test_grad.py and the train-smoke CI gate assert across
L = 4..12.  Taps observe concrete eager execution only, so ``fn`` must
run un-jitted (the Table-4 analysis convention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax.numpy as jnp

from repro.core.nsr import gemm_nsr_upper_bound
from repro.engine import taps as TAPS

__all__ = ["GradNSRRecord", "BACKWARD_KINDS", "measure_gradient_nsr"]

#: Tap kinds emitted by the backward GEMMs (repro.grad.vjp).
BACKWARD_KINDS = ("gemm_dx", "gemm_dw", "conv_dx", "conv_dw")


@dataclasses.dataclass
class GradNSRRecord:
    """One backward GEMM: measured output NSR vs the analytic bound."""

    path: Optional[str]      #: derived grad path ("c1#dx", ...)
    kind: str                #: "gemm_dx" | "gemm_dw" | "conv_dx" | "conv_dw"
    backend: str
    policy: Any              #: the FITTED policy that executed (None=float)
    eta_measured: float
    eta_bound: float         #: inf for float backward GEMMs (no formatting)

    @property
    def within_bound(self) -> bool:
        return self.eta_measured <= self.eta_bound


def measure_gradient_nsr(fn: Callable[[], Any]) -> List[GradNSRRecord]:
    """Run ``fn`` (some eager gradient computation) under a measuring tap.

    Every backward tap event yields one record: ``eta_measured`` is the
    energy ratio ||y - y_float||^2 / ||y_float||^2 of the backward
    GEMM's output against its float reference on the SAME operands
    (``want_float``), ``eta_bound`` the hard worst-case bound from the
    block geometry of those operands.  Float backward GEMMs (STE / float
    sites) measure ~0 and carry an infinite bound.  Returns records in
    execution order; forward events are ignored.
    """
    records: List[GradNSRRecord] = []

    def capture(ev: TAPS.TapEvent):
        if ev.kind not in BACKWARD_KINDS:
            return
        yf = ev.y_float
        sig = float(jnp.sum(jnp.square(yf)))
        err = float(jnp.sum(jnp.square(ev.y - yf)))
        eta = err / max(sig, float(jnp.finfo(jnp.float32).tiny))
        bound = (float("inf") if ev.policy is None else
                 float(gemm_nsr_upper_bound(ev.x, ev.w, ev.policy)))
        records.append(GradNSRRecord(ev.path, ev.kind, ev.backend,
                                     ev.policy, eta, bound))

    with TAPS.taps(capture, want_float=True):
        fn()
    return records
