"""Custom VJPs routing backward GEMMs through the BFP engine (§12.3).

One :func:`jax.custom_vjp` per (site configuration): the primal runs the
unchanged forward datapath (``engine.core.gemm_and_tap`` /
``conv_and_tap`` — forward numerics and forward tap events are
bit-identical to the unrouted engine), and the backward pass lowers the
two gradient contractions onto ``engine.core._gemm_exec``:

    dL/dx = dy[M, N] @ W^T[N, K]       ("gemm_dx" / "conv_dx")
    dL/dw = x^T[K, M] @ dy[M, N]       ("gemm_dw" / "conv_dw")

so each backward GEMM gets real backend selection (float / emulated /
pallas with honest fallback) under its own resolved policy, and emits a
backward tap event carrying exactly the executed operands — which is
what makes measured gradient NSR comparable against
``core.nsr.gemm_nsr_upper_bound`` on the same geometry.

Operand orientation inside a backward GEMM: the LEFT operand is the
activation side of the policy (``l_i`` bits, activation block scheme)
and the RIGHT operand the weight side (``l_w``) — for dL/dx that puts
the incoming gradient on the activation side and W^T on the weight
side; for dL/dw the saved activations are left and the gradient right.

The residuals saved by the forward pass are the RAW operands; the
backward pass re-derives the site's dequantized operands (exactly the
legacy ``core.bfp_dot`` STE linearization point), so with float grad
policies the gradients are bit-identical to the legacy straight-through
estimator, and to plain JAX autodiff when the site itself is float.

Builders are ``lru_cache``d on frozen config dataclasses: a model with
stable (policy, path) sites reuses one ``custom_vjp`` instance per site
across steps, so jit tracing sees a stable callable identity.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.bfp_dot import quantize_activations, quantize_weights
from repro.core.conv_utils import conv_weight_matrix, im2col
from repro.core.policy import BFPPolicy
from repro.engine import core as EC
from repro.engine import taps as TAPS
from repro.engine.policy_map import PolicyLike, resolve_policy
from repro.grad.paths import (GradSpec, fit_grad_policy, grad_path,
                              resolve_grad_policy)

__all__ = ["gemm", "gemm_bound", "conv2d", "conv2d_bound", "routable"]


def routable(x: Any, w: Any, key, out_policy) -> bool:
    """Can this engine call take the custom-VJP route?

    Dense float operands only: prequant ``{"m", "s"}`` weight dicts hold
    integer mantissas (nothing to differentiate), stochastic-rounding
    ``key`` and wire-format ``out_policy`` outputs are inference-side
    features.  Everything refused here keeps the legacy non-custom-VJP
    engine path, unchanged.
    """
    if key is not None or out_policy is not None:
        return False
    for a in (x, w):
        if not (hasattr(a, "ndim") and hasattr(a, "dtype")):
            return False
        if not jnp.issubdtype(a.dtype, jnp.floating):
            return False
    return True


def _linearize(x: jax.Array, w: jax.Array, pol: Optional[BFPPolicy]):
    """The STE linearization point: the site's dequantized operands.

    Float backward GEMMs run over THESE (legacy ``_ste_fwd`` semantics);
    quantized backward GEMMs also start from them — the backward
    arithmetic then adds its own formatting, exactly like a hardware
    datapath whose gradient buffers hold the forward wire values.
    """
    if pol is None:
        return x, w
    xq, wq = x, w
    if pol.quantize_inputs:
        x2d = x.reshape(-1, x.shape[-1])
        xq = quantize_activations(x2d, pol).dequantize().reshape(x.shape)
    if pol.quantize_weights:
        wq = quantize_weights(w, pol).dequantize()
    return xq, wq


def _grad_gemm(a2d: jax.Array, b2d: jax.Array, spec: GradSpec,
               gpath: Optional[str], kind: str, strict: bool) -> jax.Array:
    """One backward GEMM ``a2d[M, K'] @ b2d[K', N']`` through the engine,
    with its backward tap event."""
    pol = fit_grad_policy(spec.policy, a2d.shape[-1])
    # a fitted tile invalidates the bind-time backend choice (pallas
    # support depends on block_k) -> honest re-selection per call
    be = spec.backend if pol == spec.policy else None
    out, used = EC._gemm_exec(a2d, b2d, pol, None, backend=be,
                              strict=strict, path=gpath)
    if TAPS.active():
        out = TAPS.emit(kind, gpath, pol, used.name, a2d, b2d, out,
                        float_fn=lambda: EC._gemm_exec(a2d, b2d,
                                                       None, None)[0])
    return out


@dataclasses.dataclass(frozen=True)
class _GemmCfg:
    pol: Optional[BFPPolicy]
    backend: Any                 #: pre-selected forward Backend or None
    dx: GradSpec
    dw: GradSpec
    path: Optional[str] = None
    strict: bool = False


@lru_cache(maxsize=None)
def _gemm_fn(cfg: _GemmCfg):
    def primal(x, w):
        return EC.gemm_and_tap(x, w, cfg.pol, None, backend=cfg.backend,
                               strict=cfg.strict, path=cfg.path)

    @jax.custom_vjp
    def f(x, w):
        return primal(x, w)

    def fwd(x, w):
        return primal(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        xq, wq = _linearize(x, w, cfg.pol)
        g2d = g.reshape(-1, g.shape[-1])
        x2d = xq.reshape(-1, xq.shape[-1])
        dx = _grad_gemm(g2d, wq.T, cfg.dx, grad_path(cfg.path, "dx"),
                        "gemm_dx", cfg.strict)
        dw = _grad_gemm(x2d.T, g2d, cfg.dw, grad_path(cfg.path, "dw"),
                        "gemm_dw", cfg.strict)
        return dx.reshape(x.shape).astype(x.dtype), dw.astype(w.dtype)

    f.defvjp(fwd, bwd)
    return f


@dataclasses.dataclass(frozen=True)
class _ConvCfg:
    pol: Optional[BFPPolicy]
    backend: Any
    dx: GradSpec
    dw: GradSpec
    stride: int
    padding: str
    path: Optional[str] = None
    strict: bool = False


@lru_cache(maxsize=None)
def _conv_fn(cfg: _ConvCfg):
    def primal(x, w):
        return EC.conv_and_tap(x, w, cfg.pol, cfg.stride, cfg.padding,
                               None, backend=cfg.backend,
                               strict=cfg.strict, path=cfg.path)

    @jax.custom_vjp
    def f(x, w):
        return primal(x, w)

    def fwd(x, w):
        return primal(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        kh, kw, _, oc = w.shape

        def patches(t):
            return im2col(t, kh, kw, cfg.stride, cfg.padding)[0]

        cols = patches(x)
        colsq, wmatq = _linearize(cols, conv_weight_matrix(w), cfg.pol)
        g2d = g.reshape(-1, oc)
        dcols = _grad_gemm(g2d, wmatq.T, cfg.dx,
                           grad_path(cfg.path, "dx"), "conv_dx",
                           cfg.strict)
        # col2im is the (linear) transpose of im2col — scatter-add the
        # patch gradients back onto the input feature map
        _, pull = jax.vjp(patches, x)
        dx, = pull(dcols)
        dwmat = _grad_gemm(colsq.T, g2d, cfg.dw,
                           grad_path(cfg.path, "dw"), "conv_dw",
                           cfg.strict)
        return dx.astype(x.dtype), dwmat.reshape(w.shape).astype(w.dtype)

    f.defvjp(fwd, bwd)
    return f


# ---------------------------------------------------------------------------
# Entry points — per-call (resolve here) and plan-bound (pre-resolved Site)
# ---------------------------------------------------------------------------

def _specs(policy: PolicyLike, path: Optional[str]):
    return (GradSpec(resolve_grad_policy(policy, path, "dx")),
            GradSpec(resolve_grad_policy(policy, path, "dw")))


def _site_spec(site, which: str) -> GradSpec:
    """Grad spec of a bound Site; a legacy hand-built Site (dx/dw None)
    falls back to its own forward policy with the STE default."""
    spec = getattr(site, which)
    if spec is not None:
        return spec
    pol = site.policy
    if pol is None or pol.straight_through:
        return GradSpec(None, None)
    return GradSpec(pol, None)


def gemm(x, w, policy: PolicyLike, path: Optional[str],
         strict: bool = False):
    dx, dw = _specs(policy, path)
    cfg = _GemmCfg(resolve_policy(policy, path), None, dx, dw, path,
                   strict)
    return _gemm_fn(cfg)(x, w)


def gemm_bound(x, w, site):
    """Dispatch for a bound ``engine.plan.Site`` (grad specs resolved and
    backends selected at bind time)."""
    cfg = _GemmCfg(site.policy, site.backend, _site_spec(site, "dx"),
                   _site_spec(site, "dw"), site.path, False)
    return _gemm_fn(cfg)(x, w)


def conv2d(x, w, policy: PolicyLike, stride: int, padding: str,
           path: Optional[str], strict: bool = False):
    dx, dw = _specs(policy, path)
    cfg = _ConvCfg(resolve_policy(policy, path), None, dx, dw, stride,
                   padding, path, strict)
    return _conv_fn(cfg)(x, w)


def conv2d_bound(x, w, site, stride: int, padding: str):
    cfg = _ConvCfg(site.policy, site.backend, _site_spec(site, "dx"),
                   _site_spec(site, "dw"), stride, padding, site.path,
                   False)
    return _conv_fn(cfg)(x, w)
