"""BFP autodiff — quantized backward GEMMs on the engine datapath.

The paper's error analysis stops at inference; this package extends it
to training (DESIGN.md §12).  ``engine.gemm`` / ``engine.conv2d`` route
through the custom VJPs built here, so the two backward GEMMs of every
site —

    dL/dx = dy @ W^T        (the data gradient)
    dL/dw = x^T @ dy        (the weight gradient)

— execute through the same backend registry (float / emulated / pallas,
honest fallback) as the forward pass, under their own policies resolved
on DERIVED GRAD PATHS: a site ``features/conv1`` owns the backward sites
``features/conv1#dx`` and ``features/conv1#dw``.  A :class:`PolicyMap`
rule whose pattern contains ``#`` is a grad rule and wins on grad paths;
without one, the backward precision follows the forward site policy
(``straight_through=True`` keeps the legacy float-STE gradients).

Backward executions emit ``engine.taps`` events
(``kind="gemm_dx" | "gemm_dw" | "conv_dx" | "conv_dw"``) so measured
gradient NSR is observable on the real datapath and comparable against
the ``core.nsr`` gradient bounds (:func:`measure_gradient_nsr`).
"""
from repro.grad.nsr import GradNSRRecord, measure_gradient_nsr
from repro.grad.paths import (GRAD_KINDS, GradSpec, fit_grad_policy,
                              grad_path, resolve_grad_policy)
from repro.grad.vjp import gemm, gemm_bound, conv2d, conv2d_bound

__all__ = [
    "GRAD_KINDS", "GradSpec", "grad_path", "resolve_grad_policy",
    "fit_grad_policy",
    "gemm", "gemm_bound", "conv2d", "conv2d_bound",
    "measure_gradient_nsr", "GradNSRRecord",
]
