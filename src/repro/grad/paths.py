"""Derived grad paths and backward-policy resolution (DESIGN.md §12.2).

Every forward site ``path`` owns two backward GEMM sites, named by
suffixing the forward path:

    features/conv1  ->  features/conv1#dx   (data gradient  dy @ W^T)
                        features/conv1#dw   (weight gradient x^T @ dy)

``#`` never appears in a model layer path (the prequant walkers build
paths from dict keys / indices), so the suffix is unambiguous: a
PolicyMap rule whose PATTERN contains ``#`` is an explicit grad rule and
is only ever consulted for grad paths; forward resolution is untouched
because forward paths contain no ``#`` for such a pattern to match.

Resolution order for a backward GEMM at ``path#dx`` / ``path#dw``:

  1. explicit grad rules (pattern contains ``#``), in rule order, matched
     against the grad path — first match wins and its policy is used
     AS-IS (``None`` pins the backward GEMM to float; the
     ``straight_through`` flag is meaningless on an explicit grad rule
     and ignored — it configures the FORWARD STE, and an explicit rule
     already states the backward arithmetic);
  2. otherwise fall back to the forward site's resolved policy:
     ``None`` -> float backward; ``straight_through=True`` (the default)
     -> float backward over the dequantized operands (exactly the legacy
     ``core.bfp_dot`` STE); ``straight_through=False`` -> the backward
     GEMMs quantize under the site policy itself.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core import bfp
from repro.core.bfp import Scheme
from repro.core.policy import BFPPolicy
from repro.engine.policy_map import (PolicyLike, PolicyMap, _compiled,
                                     resolve_policy)

__all__ = ["GRAD_KINDS", "GradSpec", "grad_path", "resolve_grad_policy",
           "fit_grad_policy"]

#: The two backward GEMMs of a site, in path-suffix form.
GRAD_KINDS = ("dx", "dw")


@dataclasses.dataclass(frozen=True)
class GradSpec:
    """Bound configuration of one backward GEMM (hashable).

    ``policy=None`` is a float backward GEMM (the STE / float-site case).
    ``backend`` is a pre-selected :class:`repro.engine.backends.Backend`
    (``engine.bind`` fills it in); ``None`` re-selects per call — which
    also happens at call time whenever :func:`fit_grad_policy` had to
    shrink the K-tile for the backward contraction depth.
    """

    policy: Optional[BFPPolicy] = None
    backend: Any = None


def grad_path(path: Optional[str], which: str) -> Optional[str]:
    """``path#dx`` / ``path#dw``; anonymous sites stay anonymous."""
    if which not in GRAD_KINDS:
        raise ValueError(f"which must be one of {GRAD_KINDS}, got {which!r}")
    return None if path is None else f"{path}#{which}"


_MISS = object()


def _explicit_grad_rule(policy: PolicyLike, gpath: Optional[str]):
    """First PolicyMap rule with ``#`` in its pattern matching ``gpath``;
    ``_MISS`` when there is none (distinct from a matching None rule,
    which pins the backward GEMM to float)."""
    if isinstance(policy, PolicyMap) and gpath is not None:
        for pattern, pol in policy.rules:
            if "#" in pattern and _compiled(pattern).search(gpath):
                return pol
    return _MISS


def resolve_grad_policy(policy: PolicyLike, path: Optional[str],
                        which: str) -> Optional[BFPPolicy]:
    """Effective policy of one backward GEMM (None = float backward)."""
    hit = _explicit_grad_rule(policy, grad_path(path, which))
    if hit is not _MISS:
        return hit
    pol = resolve_policy(policy, path)
    if pol is None or pol.straight_through:
        return None
    return pol


def fit_grad_policy(pol: Optional[BFPPolicy],
                    k: int) -> Optional[BFPPolicy]:
    """Adapt a TILED policy's K-tile to a backward contraction depth.

    The backward GEMMs contract over dimensions the forward tile was not
    chosen for — dL/dx over N (out features), dL/dw over the flattened
    batch M — which rarely divide a forward ``block_k`` like 128.  The
    largest divisor of ``k`` that fits both the requested tile and the
    int32 accumulation bound (``bfp.max_safe_k``) is used instead; the
    fitted policy is what executes, what the backward tap reports, and
    what the NSR bound must be evaluated against.  Non-TILED schemes
    have no K-tile and pass through unchanged.
    """
    if pol is None or pol.scheme is not Scheme.TILED:
        return pol
    cap = max(1, min(k, bfp.max_safe_k(pol.l_w, pol.l_i)))
    bk = min(pol.block_k or k, cap)
    while k % bk:
        bk -= 1
    return pol if bk == pol.block_k else pol.with_(block_k=bk)
