"""Validation of the paper's 3-stage NSR model (§4) against measurement.

The paper's own bar is <= 8.9 dB worst-case deviation on VGG-16 (Table 4);
since our theory and code share the exact quantization convention, we
assert much tighter bounds on synthetic data.
"""
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: deterministic fallback sampler
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import nsr
from repro.core.policy import BFPPolicy


def _acts(key, shape, spread=1.0):
    k1, k2 = jax.random.split(key)
    return jax.random.normal(k1, shape) * \
        jnp.exp(spread * jax.random.normal(k2, shape))


def test_quantization_snr_prediction():
    """Stage 1 (eq. 8-13): predicted matrix SNR tracks measurement.

    At low bit widths on heavy-tailed data the step^2/12 model
    overestimates noise (elements far below the step quantize to zero with
    error = the element itself, variance << step^2/12), so measurement
    beats prediction by a couple of dB — well inside the paper's 8.9 dB
    Table-4 envelope.  >= 8 bits must agree within 1 dB.
    """
    for bits in (6, 8, 10):
        for op in ("i", "w"):
            x = _acts(jax.random.PRNGKey(bits), (256, 256))
            p = BFPPolicy(l_w=bits, l_i=bits)
            pred = float(nsr.predict_matrix_snr(x, bits, op, p))
            meas = float(nsr.measure_matrix_snr(x, bits, op, p))
            tol = 3.0 if bits <= 6 else 1.0
            assert abs(pred - meas) < tol, (bits, op, pred, meas)


def test_snr_scales_6db_per_bit():
    """Each extra mantissa bit adds ~6.02 dB SNR (eq. 8)."""
    x = _acts(jax.random.PRNGKey(0), (512, 128))
    p = BFPPolicy()
    snrs = [float(nsr.predict_matrix_snr(x, b, "i", p)) for b in (6, 7, 8)]
    d1, d2 = snrs[1] - snrs[0], snrs[2] - snrs[1]
    assert 5.5 < d1 < 6.5 and 5.5 < d2 < 6.5


def test_single_layer_model():
    """Stage 2 (eq. 18): eta_O = eta_I + eta_W within 1.5 dB."""
    x = _acts(jax.random.PRNGKey(1), (512, 384))
    w = jax.random.normal(jax.random.PRNGKey(2), (384, 256)) * 0.05
    p = BFPPolicy(straight_through=False)
    reps = nsr.analyze_gemm_chain(x, [w], p)
    r = reps[0]
    assert abs(r.snr_output_measured - r.snr_output_single) < 1.5


def test_multi_layer_model_tracks_chain():
    """Stage 3 (eq. 19-20): multi-layer prediction tracks a 6-deep chain
    within 3 dB, and beats the single-layer model in later layers."""
    x = _acts(jax.random.PRNGKey(3), (256, 256))
    ws = [jax.random.normal(jax.random.PRNGKey(10 + i), (256, 256)) * 0.08
          for i in range(6)]
    reps = nsr.analyze_gemm_chain(x, ws, BFPPolicy(straight_through=False))
    for r in reps:
        assert abs(r.snr_output_measured - r.snr_output_multi) < 3.0, r
    last = reps[-1]
    err_multi = abs(last.snr_output_measured - last.snr_output_multi)
    err_single = abs(last.snr_output_measured - last.snr_output_single)
    assert err_multi <= err_single + 0.5


def test_multi_layer_within_paper_envelope():
    """Paper's own bar at its headline config (8-bit): <= 8.9 dB deviation
    through a deep chain (Table 4 reports up to 8.9 dB on VGG-16)."""
    x = _acts(jax.random.PRNGKey(4), (128, 128), spread=1.0)
    ws = [jax.random.normal(jax.random.PRNGKey(20 + i), (128, 128)) * 0.1
          for i in range(8)]
    reps = nsr.analyze_gemm_chain(x, ws, BFPPolicy(l_w=8, l_i=8,
                                                   straight_through=False))
    for r in reps:
        assert abs(r.snr_output_measured - r.snr_output_multi) < 8.9


def test_relu_snr_neutral():
    """Paper §4.4: ReLU leaves SNR approximately unchanged."""
    y = _acts(jax.random.PRNGKey(5), (512, 512))
    noise = 0.01 * jax.random.normal(jax.random.PRNGKey(6), y.shape)
    before = float(nsr.snr_db(y, y + noise))
    after = float(nsr.snr_db(jax.nn.relu(y), jax.nn.relu(y + noise)))
    assert abs(before - after) < 1.5


def test_nsr_snr_roundtrip():
    s = jnp.asarray(23.4)
    assert abs(float(nsr.snr_db_from_nsr(nsr.nsr_from_snr_db(s))) - 23.4) \
        < 1e-4


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(bits=st.integers(5, 10), seed=st.integers(0, 2 ** 31 - 1))
def test_eta_additivity_property(bits, seed):
    """eta_O ~= eta_I + eta_W across random bit-widths/data (eq. 16)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _acts(k1, (256, 128))
    w = jax.random.normal(k2, (128, 64)) * 0.1
    p = BFPPolicy(l_w=bits, l_i=bits, straight_through=False)
    r = nsr.analyze_gemm_chain(x, [w], p)[0]
    eta_meas = 10 ** (-r.snr_output_measured / 10)
    eta_pred = 10 ** (-r.snr_output_single / 10)
    assert 0.15 < eta_meas / eta_pred < 6.0  # order-of-magnitude check
