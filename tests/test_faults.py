"""Fault injection + end-to-end data integrity (ISSUE 7).

Covers: seeded injector determinism and bit targeting; the campaign's
bit-reproducibility and its exponent >> mantissa-MSB >> mantissa-LSB
severity hierarchy; container CRC detection at the wire
(``dist.compress.unpack_leaf``) and at checkpoint restore (corrupt
latest step -> warn + fall back to the newest valid step,
bit-identically); v1 (pre-checksum) container compatibility; and the
``PackedBFP.from_bytes`` truncation hardening.
"""
import os
import struct
import tempfile
import warnings

import jax
import numpy as np
import pytest

from repro import engine as EG
from repro.checkpoint import store
from repro.core import bfp, packed
from repro.core.packed import IntegrityError
from repro.core.policy import TPU_TILED
from repro.dist import compress
from repro.faults import (activation_faults, corrupt_container_bytes,
                          endurance_campaign, flip_exponent_bits,
                          flip_payload_bits, inject_tree, mean_nsr,
                          perturb_activations)
from repro.models.cnn import MODELS

KEY = jax.random.PRNGKey(0)
POL = TPU_TILED.with_(block_k=None, straight_through=False)


def _container(bits=8, shape=(4, 64)):
    blk = bfp.quantize(jax.random.normal(KEY, shape), bits, (1,))
    return packed.pack_block(blk)


# ---------------------------------------------------------------------------
# Injectors
# ---------------------------------------------------------------------------

def test_payload_flips_are_seeded_and_counted():
    p = _container()
    a1, k1 = flip_payload_bits(p, 0.01, seed=7)
    a2, k2 = flip_payload_bits(p, 0.01, seed=7)
    b, k3 = flip_payload_bits(p, 0.01, seed=8)
    assert a1.payload == a2.payload and k1 == k2
    assert b.payload != a1.payload
    # exact mode: deterministic flip count
    e, ke = flip_payload_bits(p, 0.01, seed=7, mode="exact")
    assert ke == round(0.01 * p.n_elements * p.bits)
    # original untouched
    assert p.payload != a1.payload


def test_payload_bit_targeting_hits_only_that_bit():
    p = _container(bits=6)
    # flip EVERY element's LSB: dequantized values move by exactly one
    # step of their block
    lsb, k = flip_payload_bits(p, 1.0, seed=0, bit=0, mode="exact")
    assert k == p.n_elements
    m0 = np.asarray(packed.unpack_block(p).mantissa)
    m1 = np.asarray(packed.unpack_block(lsb).mantissa)
    assert np.all(np.abs(m1 - m0) == 1)
    # MSB flips move by half the field's range
    msb, _ = flip_payload_bits(p, 1.0, seed=0, bit=p.bits - 1,
                               mode="exact")
    m2 = np.asarray(packed.unpack_block(msb).mantissa)
    assert np.all(np.abs(m2 - m0) == 2 ** (p.bits - 1))


def test_exponent_flips_rescale_blocks():
    p = _container()
    f, k = flip_exponent_bits(p, 1.0, seed=0, bit=0, mode="exact")
    assert k == p.exponents.size
    e0 = np.asarray(p.exponents, np.int64)
    e1 = np.asarray(f.exponents, np.int64)
    assert np.all(np.abs(e1 - e0) == 1)   # bit 0 of the int8 toggles +-1
    assert f.payload == p.payload          # mantissas untouched


def test_flip_rejects_bad_args():
    p = _container()
    with pytest.raises(ValueError, match="bit-error rate"):
        flip_payload_bits(p, 1.5, seed=0)
    with pytest.raises(ValueError, match="bit must be"):
        flip_payload_bits(p, 0.1, seed=0, bit=p.bits)
    with pytest.raises(ValueError, match="mode"):
        flip_exponent_bits(p, 0.1, seed=0, mode="gauss")


def test_flipped_container_fails_verify_but_parses_unverified():
    p = packed.PackedBFP.from_bytes(_container().to_bytes())
    assert p.stored_crc is not None
    f, k = flip_payload_bits(p, 0.02, seed=1)
    assert k > 0
    with pytest.raises(IntegrityError):
        f.verify()
    # the unverified parse is the campaign's escape hatch
    raw = corrupt_container_bytes(p, seed=2, n_flips=3)
    q = packed.PackedBFP.from_bytes(raw, verify=False)
    assert q.shape == p.shape


def test_activation_perturbation_is_seeded():
    y = jax.random.normal(KEY, (2, 8, 8, 4))
    a, ka = perturb_activations(y, 0.01, seed=3)
    b, kb = perturb_activations(y, 0.01, seed=3)
    c, _ = perturb_activations(y, 0.01, seed=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ka == kb
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_activation_faults_ride_the_taps_transform_hook():
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (2, *spec.input_shape()))
    plan = EG.bind(params, POL, tree="cnn")
    clean = np.asarray(spec.apply(plan.params, imgs, plan))
    with activation_faults(0.01, seed=0) as stats:
        noisy1 = np.asarray(spec.apply(plan.params, imgs, plan))
    with activation_faults(0.01, seed=0) as stats2:
        noisy2 = np.asarray(spec.apply(plan.params, imgs, plan))
    assert stats.events > 0 and stats.flips > 0
    assert stats2.flips == stats.flips
    np.testing.assert_array_equal(noisy1, noisy2)   # same seed, same run
    assert not np.array_equal(noisy1, clean)
    # outside the context the datapath is untouched again
    after = np.asarray(spec.apply(plan.params, imgs, plan))
    np.testing.assert_array_equal(after, clean)


# ---------------------------------------------------------------------------
# Campaign
# ---------------------------------------------------------------------------

def test_campaign_is_bit_reproducible_and_ordered():
    kw = dict(models=("lenet",), l_values=(8,), bers=(1e-2,),
              targets=("exponent", "mantissa_msb", "mantissa_lsb"),
              seed=0, n_images=2)
    rows1 = endurance_campaign(**kw)
    rows2 = endurance_campaign(**kw)
    assert rows1 == rows2                      # same seed -> same logits
    e = mean_nsr(rows1, target="exponent")
    msb = mean_nsr(rows1, target="mantissa_msb")
    lsb = mean_nsr(rows1, target="mantissa_lsb")
    assert e > msb > lsb                       # the severity hierarchy
    for r in rows1:
        assert r["n_flips"] > 0


def test_inject_tree_is_path_keyed():
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    tree = packed.pack_param_tree(params, POL, kind="cnn")
    t1, k1 = inject_tree(tree, "mantissa", 1e-3, seed=5)
    t2, k2 = inject_tree(tree, "mantissa", 1e-3, seed=5)
    assert k1 == k2 > 0
    l1 = [l.payload for l in jax.tree_util.tree_leaves(
        t1, is_leaf=packed.is_packed) if packed.is_packed(l)]
    l2 = [l.payload for l in jax.tree_util.tree_leaves(
        t2, is_leaf=packed.is_packed) if packed.is_packed(l)]
    assert l1 == l2
    with pytest.raises(ValueError, match="target"):
        inject_tree(tree, "activation", 1e-3, seed=5)


# ---------------------------------------------------------------------------
# Wire + container integrity
# ---------------------------------------------------------------------------

def test_wire_unpack_rejects_corrupted_block():
    g = jax.random.normal(KEY, (40, 17))
    p = compress.pack_leaf(g, 8, block=64)
    # clean round trip still pinned against the in-graph model
    np.testing.assert_array_equal(
        np.asarray(compress.unpack_leaf(p.to_bytes())),
        np.asarray(compress.quantize_leaf(g, 8, block=64)))
    # one flipped payload byte -> typed rejection, from bytes or object
    bad = corrupt_container_bytes(p, seed=0, n_flips=1)
    with pytest.raises(IntegrityError):
        compress.unpack_leaf(bad)
    with pytest.raises(IntegrityError):
        compress.unpack_leaf(packed.PackedBFP.from_bytes(bad,
                                                         verify=False))


def test_container_crc_roundtrip_and_v1_compat():
    p = _container()
    buf = p.to_bytes()
    q = packed.PackedBFP.from_bytes(buf)
    assert q.stored_crc == q.crc32() == p.crc32()
    assert q.to_bytes() == buf                      # bit-identical cycle
    # fabricate the v1 (pre-checksum) serialization of the same payload:
    # 12-byte fixed header, no CRC field — must still parse, with
    # integrity checking disabled (stored_crc None)
    import json
    meta_b = json.dumps(p.meta, separators=(",", ":"),
                        sort_keys=True).encode()
    v1 = b"".join([
        b"BFPK", struct.pack("<BBBB", 1, p.bits, len(p.shape),
                                  len(p.exp_shape)),
        struct.pack("<I", len(meta_b)),
        struct.pack(f"<{len(p.shape)}I", *p.shape),
        struct.pack(f"<{len(p.exp_shape)}I", *p.exp_shape),
        meta_b, p.exponents.astype(np.int8).tobytes(order="C"),
        p.payload,
    ])
    old = packed.PackedBFP.from_bytes(v1)
    assert old.stored_crc is None
    old.verify()                                    # no-op, not a raise
    np.testing.assert_array_equal(old.exponents, p.exponents)
    assert old.payload == p.payload


def test_from_bytes_names_offset_on_truncation():
    buf = _container().to_bytes()
    # every truncation point raises a ValueError naming an offset, never
    # IndexError/struct.error garbage
    for cut in (3, 10, 14, 20, len(buf) // 2, len(buf) - 1):
        with pytest.raises(ValueError,
                           match=r"(offset|magic|fixed header)"):
            packed.PackedBFP.from_bytes(buf[:cut])
    # declared meta length beyond the buffer is caught, not sliced short
    hacked = bytearray(buf)
    struct.pack_into("<I", hacked, 8, 2 ** 20)
    with pytest.raises(ValueError, match="offset"):
        packed.PackedBFP.from_bytes(bytes(hacked))


# ---------------------------------------------------------------------------
# Checkpoint fallback
# ---------------------------------------------------------------------------

def test_checkpoint_falls_back_to_newest_valid_step():
    spec = MODELS["lenet"]
    params0 = spec.init(KEY)
    params1 = spec.init(jax.random.PRNGKey(1))
    params2 = spec.init(jax.random.PRNGKey(2))
    with tempfile.TemporaryDirectory() as d:
        for s, p in ((0, params0), (1, params1), (2, params2)):
            store.save(d, s, p, keep=5)
        ref, s_ref = store.restore(d, params0, step=1)
        assert s_ref == 1
        # corrupt the LATEST step's array bytes (flip one payload byte)
        apath = os.path.join(store._step_dir(d, 2), "arrays.npz")
        raw = bytearray(open(apath, "rb").read())
        raw[len(raw) // 2] ^= 0x40
        with open(apath, "wb") as f:
            f.write(raw)
        with pytest.warns(store.CheckpointCorruptionWarning):
            assert store.latest_step(d) == 1
        with pytest.warns(store.CheckpointCorruptionWarning):
            tree, s = store.restore(d, params0)
        assert s == 1                     # fell back past the bad step
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # explicitly asking for the corrupt step is a typed error
        with pytest.raises(IntegrityError):
            store.restore(d, params0, step=2)


def test_checkpoint_packed_leaf_crc_detected_at_restore():
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, params, format="bfp_packed", policy=POL)
        # flip one byte INSIDE a packed container in arrays.npz would be
        # caught by the npz-level CRC first; instead corrupt a container
        # serialized independently, as dist/checkpoint consumers see it
        tree, _ = store.restore(d, params, packed="keep")
        leaf = next(l for l in jax.tree_util.tree_leaves(
            tree, is_leaf=packed.is_packed) if packed.is_packed(l))
        bad = corrupt_container_bytes(leaf.to_bytes(), seed=0, n_flips=1)
        with pytest.raises(IntegrityError):
            packed.PackedBFP.from_bytes(bad)


def test_tune_cache_corrupt_json_degrades_to_empty():
    from repro.tune.cache import TuneCache
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tune_cache.json")
        with open(path, "w") as f:
            f.write('{"schema": 1, "entries": {"x": ')   # garbage JSON
        with pytest.warns(UserWarning, match="corrupt or unreadable"):
            c = TuneCache.load(path)
        assert len(c) == 0 and c.path == path
        # warn-once: the second load of the same path is silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            c2 = TuneCache.load(path)
        assert len(c2) == 0
        # a save repairs the file and load works again
        c2.store("gemm", 1, 2, 3, 8, 8, None, "interpret",
                 {"bm": 8, "bn": 8, "bk": 8, "us": 1.0, "steps": 1})
        c2.save()
        assert len(TuneCache.load(path)) == 1
