"""Autotuner (ISSUE 6): cache round-trip, hillclimb, ops integration.

Contracts:
  * the JSON cache round-trips exactly and invalidates on schema bumps;
  * keys carry everything that changes the optimum (shape, L, block,
    target) — nothing else hits;
  * tuned tiles are PERFORMANCE-ONLY: with block_k pinned they may never
    change a bit of output, so a stale/wrong cache entry can cost speed
    but not correctness;
  * tune_gemm/tune_conv hillclimb within budget, store the winner, and
    skip already-cached sites (the launch/hillclimb.py shape).
"""
import json

import jax
import numpy as np
import pytest

from repro.core import BFPPolicy, Scheme
from repro.kernels import ops
from repro.tune.autotune import time_us, tune_conv, tune_gemm
from repro.tune.cache import (SCHEMA, TuneCache, get_cache, lookup_tiles,
                              use_cache)
from repro.tune.tables import (DEEP_K_BK, aligned_tile, conv_row_tile,
                               fallback_tiles, overflow_cap)

TILED16 = BFPPolicy(scheme=Scheme.TILED, block_k=16,
                    straight_through=False)


# ---------------------------------------------------------------------------
# cache: keying, persistence, schema invalidation
# ---------------------------------------------------------------------------

def test_cache_key_stability():
    """The key format is persisted in committed JSON — it must not move."""
    assert TuneCache.key("gemm", 64, 512, 128, 8, 8, 128, "interpret") == \
        "gemm:b64k512n128:L8.8:bk128:interpret"
    assert TuneCache.key("conv", 1024, 27, 64, 8, 8, None, "cpu") == \
        "conv:b1024k27n64:L8.8:bk0:cpu"


def test_cache_roundtrip(tmp_path):
    p = str(tmp_path / "cache.json")
    ent = {"bm": 8, "bn": 8, "bk": 16, "us": 1.5, "steps": 3}
    c = TuneCache(path=p)
    c.store("gemm", 8, 64, 8, 8, 8, 16, "interpret", ent)
    assert c.save() == p
    c2 = TuneCache.load(p)
    assert len(c2) == 1
    assert c2.lookup("gemm", 8, 64, 8, 8, 8, 16, "interpret") == ent
    assert (c2.hits, c2.misses) == (1, 0)
    # any keyed field changing is a different site: no hit
    assert c2.lookup("gemm", 9, 64, 8, 8, 8, 16, "interpret") is None
    assert c2.lookup("gemm", 8, 64, 8, 6, 8, 16, "interpret") is None
    assert c2.lookup("gemm", 8, 64, 8, 8, 8, None, "interpret") is None
    assert c2.lookup("gemm", 8, 64, 8, 8, 8, 16, "cpu") is None
    assert c2.misses == 4


def test_cache_schema_invalidation(tmp_path):
    p = tmp_path / "cache.json"
    p.write_text(json.dumps({"schema": SCHEMA + 1,
                             "entries": {"k": {"bm": 8}}}))
    assert len(TuneCache.load(str(p))) == 0      # stale schema dropped
    assert len(TuneCache.load(str(tmp_path / "missing.json"))) == 0


def test_lookup_tiles_scoped_by_use_cache():
    c = TuneCache()
    c.store("gemm", 8, 64, 8, 8, 8, None, "interpret",
            {"bm": 8, "bn": 8, "bk": 32, "us": 1.0, "steps": 1})
    c.store("conv", 128, 27, 16, 8, 8, 3, "interpret",
            {"t_oh": 4, "bn": 16, "bk": 3, "us": 1.0, "steps": 1})
    assert lookup_tiles("gemm", 8, 64, 8, 8, 8, None, True) is None
    with use_cache(c):
        assert get_cache() is c
        assert lookup_tiles("gemm", 8, 64, 8, 8, 8, None, True) == (8, 8, 32)
        assert lookup_tiles("conv", 128, 27, 16, 8, 8, 3, True) == (4, 16)
        assert lookup_tiles("gemm", 9, 64, 8, 8, 8, None, True) is None
    assert get_cache() is None and \
        lookup_tiles("gemm", 8, 64, 8, 8, 8, None, True) is None


# ---------------------------------------------------------------------------
# tuned tiles flow into ops and never change output bits
# ---------------------------------------------------------------------------

def test_tuned_tiles_bit_identical():
    x = jax.random.normal(jax.random.PRNGKey(0), (24, 64)) * 2.0
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 24)) * 0.1
    base = ops.bfp_matmul(x, w, TILED16, True)
    c = TuneCache()
    c.store("gemm", 24, 64, 24, 8, 8, 16, "interpret",
            {"bm": 8, "bn": 8, "bk": 16, "us": 1.0, "steps": 1})
    with use_cache(c):
        out = ops.bfp_matmul(x, w, TILED16, True)
    assert c.hits >= 1      # the kernel wrapper consulted the cache
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


def test_tuned_conv_tiles_bit_identical():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 8)) * 2.0
    wk = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 8, 12)) * 0.1
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=24,
                    straight_through=False)
    base = ops.bfp_conv2d(x, wk, pol, 1, "SAME", True)
    c = TuneCache()
    c.store("conv", 2 * 8 * 8, 72, 12, 8, 8, 24, "interpret",
            {"t_oh": 2, "bn": 8, "bk": 24, "us": 1.0, "steps": 1})
    with use_cache(c):
        out = ops.bfp_conv2d(x, wk, pol, 1, "SAME", True)
    assert c.hits >= 1
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


# ---------------------------------------------------------------------------
# the hillclimber itself
# ---------------------------------------------------------------------------

def test_tune_gemm_small_site_and_cache_skip():
    c = TuneCache()
    ent = tune_gemm(16, 32, 16, TILED16, cache=c, interpret=True,
                    max_steps=4, iters=1)
    assert ent["bk"] == 16          # pinned block == K tile, never moves
    assert 1 <= ent["steps"] <= 4 and ent["us"] > 0
    assert len(c) == 1
    hits0 = c.hits
    assert tune_gemm(16, 32, 16, TILED16, cache=c, interpret=True,
                     max_steps=4, iters=1) == ent    # skip-if-cached
    assert c.hits == hits0 + 1 and len(c) == 1


def test_tune_gemm_free_bk_respects_overflow():
    """With block_k=None the K tile is a knob, but the neighborhood must
    stay inside the int32 accumulation bound for wide mantissas."""
    pol = BFPPolicy(l_i=12, l_w=12, scheme=Scheme.TILED, block_k=None,
                    straight_through=False)
    c = TuneCache()
    ent = tune_gemm(8, 1024, 8, pol, cache=c, interpret=True,
                    max_steps=3, iters=1)
    assert ent["bk"] <= overflow_cap(24)


def test_tune_conv_small_site_and_cache_skip():
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=24,
                    straight_through=False)
    c = TuneCache()
    ent = tune_conv(1, 8, 8, 8, 3, 16, pol, cache=c, interpret=True,
                    max_steps=4, iters=1)
    assert set(ent) >= {"t_oh", "bn", "bk", "us", "steps"}
    assert ent["bk"] == 24
    assert tune_conv(1, 8, 8, 8, 3, 16, pol, cache=c, interpret=True,
                     max_steps=4, iters=1) == ent
    assert len(c) == 1


def test_time_us_returns_positive_median():
    assert time_us(lambda: jax.numpy.zeros(4), iters=3, warmup=1) > 0


# ---------------------------------------------------------------------------
# fallback table (the no-cache answer the tuner starts from)
# ---------------------------------------------------------------------------

def test_fallback_tiles_contract():
    assert overflow_cap(16) == 65536
    assert fallback_tiles(100, 2048, 300, None) == (128, 128, DEEP_K_BK)
    assert fallback_tiles(8, 64, 8, None, l_sum=30)[2] == 4   # capped
    assert fallback_tiles(8, 64, 8, 16)[2] == 16              # pinned
    assert aligned_tile(1) == 8 and aligned_tile(300) == 128
    assert conv_row_tile(32, 16) == 8       # 128-row M tile for the MXU
    assert conv_row_tile(8, 200) == 1       # one wide row is enough
