"""repro.dist: logical-axis sharding, spec trees, gradient compression."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, reduced
from repro.configs.registry import ARCHS
from repro.dist import compress, sharding, specs
from repro.launch.mesh import make_mesh

KEY = jax.random.PRNGKey(0)


def test_shard_identity_without_context():
    x = jax.random.normal(KEY, (4, 8, 16))
    y = sharding.shard(x, "batch", "seq", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shard_applies_constraint_inside_rules():
    mesh = make_mesh((1, 1), ("data", "model"))
    x = jax.random.normal(KEY, (4, 16))
    with sharding.axis_rules(sharding.DEFAULT_RULES, mesh):
        y = sharding.shard(x, "batch", "ffn")
        # indivisible dim drops to replicated instead of failing
        z = sharding.shard(jnp.ones((3, 5)), "batch", "ffn")
    assert sharding.current_rules() is None        # context restored
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert z.shape == (3, 5)


def test_indivisible_rule_warns_once_per_rule():
    """ISSUE 4 satellite: dropping a rule on a non-divisible dim is no
    longer silent — one ShardingRuleDropped per rule, not per call, so
    production misconfigs surface without flooding the serving loop.
    (Unit-tested against the lowering helper with synthetic axis sizes:
    real multi-device meshes are not constructible in the 1-CPU tier-1
    environment.)"""
    sizes = {"data": 4, "model": 2}
    rules = {"batch": "data", "ffn": "model", "experts": ("data", "model")}
    sharding._DROP_WARNED.clear()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        p1 = sharding.resolve_spec(rules, sizes, (6, 7), ("batch", "ffn"))
        p2 = sharding.resolve_spec(rules, sizes, (6, 7), ("batch", "ffn"))
        p3 = sharding.resolve_spec(rules, sizes, (9,), ("experts",))
    assert p1 == (None, None) == p2          # dropped -> replicated
    assert p3 == (None,)                     # tuple-axis rule (size 8)
    drops = [r for r in rec
             if issubclass(r.category, sharding.ShardingRuleDropped)]
    assert len(drops) == 3                   # once per RULE, not per call
    assert any("batch" in str(d.message) and "'data'" in str(d.message)
               for d in drops)
    # the dedup is per (rule, geometry): the SAME rule dropped at a
    # DIFFERENT dim (smoke warm-up then misconfigured prod mesh in one
    # process) must warn again, not stay muted
    with warnings.catch_warnings(record=True) as rec_geo:
        warnings.simplefilter("always")
        sharding.resolve_spec(rules, sizes, (1001,), ("batch",))
    assert [r for r in rec_geo
            if issubclass(r.category, sharding.ShardingRuleDropped)]
    # divisible dims still lower to their physical axes
    assert sharding.resolve_spec(rules, sizes, (8, 4),
                                 ("batch", "ffn")) == ("data", "model")
    # unknown / unnamed axes replicate silently (no rule -> no warning)
    with warnings.catch_warnings(record=True) as rec2:
        warnings.simplefilter("always")
        assert sharding.resolve_spec(rules, sizes, (5, 5),
                                     ("nope", None)) == (None, None)
    assert not [r for r in rec2
                if issubclass(r.category, sharding.ShardingRuleDropped)]


def test_param_and_cache_specs_structure():
    from repro.models.lm import model as Mdl
    cfg = reduced(ARCHS["tinyllama-1.1b"])
    mesh = make_mesh((1, 1), ("data", "model"))
    params_sds = jax.eval_shape(lambda: Mdl.init_params(cfg, KEY))
    pspecs = specs.param_specs(cfg, params_sds, mesh)
    assert jax.tree_util.tree_structure(pspecs) == \
        jax.tree_util.tree_structure(params_sds)
    assert all(isinstance(s, P)
               for s in jax.tree_util.tree_leaves(pspecs))
    cache_sds = jax.eval_shape(lambda: Mdl.init_cache(cfg, 4, 64))
    cspecs = specs.cache_specs(cfg, cache_sds, mesh)
    assert jax.tree_util.tree_structure(cspecs) == \
        jax.tree_util.tree_structure(cache_sds)


def test_build_cell_lowers_with_specs():
    """input_specs.build_cell consumes dist.specs without device work."""
    from repro.launch.input_specs import build_cell
    cfg = reduced(ARCHS["tinyllama-1.1b"])
    mesh = make_mesh((1, 1), ("data", "model"))
    cell = build_cell(cfg, SHAPES["train_4k"], mesh)
    assert len(cell.args) == len(cell.in_specs)


def test_quantize_leaf_shapes_and_snr():
    g = jax.random.normal(KEY, (1000,))            # non-multiple of block
    q = compress.quantize_leaf(g, 8)
    assert q.shape == g.shape and q.dtype == g.dtype
    snr = 10 * np.log10(float(jnp.sum(g ** 2) / jnp.sum((q - g) ** 2)))
    assert snr > 30
    ints = jnp.arange(5)
    np.testing.assert_array_equal(np.asarray(compress.quantize_leaf(ints, 8)),
                                  np.asarray(ints))   # non-float passthrough


def test_packed_allreduce_matches_in_graph_model():
    """ISSUE 8 satellite: the REAL packed-bytes all-reduce is BIT-EXACT
    to the jit-safe in-graph compressor model, per worker, residuals
    included — the wire protocol IS the training step's arithmetic."""
    W, bits = 3, 6
    keys = jax.random.split(KEY, 4)
    grads = {"a": jax.random.normal(keys[0], (W, 600)) * 0.1,
             "b": {"c": jax.random.normal(keys[1], (W, 32, 8))},
             "n": jnp.arange(3)}                  # non-float passthrough
    residual = {"a": jax.random.normal(keys[2], (W, 600)) * 0.01,
                "b": {"c": jnp.zeros((W, 32, 8))},
                "n": jnp.arange(3)}

    mean, res, n_bytes = compress.packed_allreduce(grads, residual, bits)

    _, transform = compress.make_compressor(bits)
    q_ref, r_ref = jax.vmap(transform)(
        {"a": grads["a"], "b": grads["b"]},
        {"a": residual["a"], "b": residual["b"]})
    np.testing.assert_array_equal(np.asarray(mean["a"]),
                                  np.asarray(jnp.mean(q_ref["a"], 0)))
    np.testing.assert_array_equal(np.asarray(mean["b"]["c"]),
                                  np.asarray(jnp.mean(q_ref["b"]["c"], 0)))
    np.testing.assert_array_equal(np.asarray(res["a"]),
                                  np.asarray(r_ref["a"]))
    np.testing.assert_array_equal(np.asarray(mean["n"]),
                                  np.asarray(grads["n"]))
    # byte accounting: serialized container sizes, all workers and leaves
    expect = W * (compress.pack_leaf(grads["a"][0], bits).nbytes
                  + compress.pack_leaf(grads["b"]["c"][0], bits).nbytes)
    assert n_bytes == expect


def test_packed_allreduce_error_feedback_converges():
    """compress -> all-reduce -> decompress with residual carry: the
    accumulated compressed mean matches the uncompressed mean within the
    wire quantization bound (EF makes the bias vanish across steps)."""
    W, steps = 2, 30
    g = {"w": jax.random.normal(KEY, (W, 512)) * 0.1}
    residual = jax.tree_util.tree_map(jnp.zeros_like, g)
    true_mean = jnp.mean(g["w"], 0)
    acc = jnp.zeros_like(true_mean)
    for _ in range(steps):
        mean, residual, _ = compress.packed_allreduce(g, residual, bits=4)
        acc = acc + mean["w"]
    rel = float(jnp.linalg.norm(acc - steps * true_mean) /
                jnp.linalg.norm(steps * true_mean))
    assert rel < 0.05, rel
    # single-step contract: each worker's residual is exactly the wire
    # quantization error of its EF input, so a step's deviation from the
    # true mean is bounded by the mean wire quantization error
    r0 = jax.tree_util.tree_map(jnp.zeros_like, g)
    mean1, r1, _ = compress.packed_allreduce(g, r0, bits=4)
    qerr = jnp.stack([g["w"][wi] - compress.quantize_leaf(g["w"][wi], 4)
                      for wi in range(W)])
    np.testing.assert_array_equal(np.asarray(r1["w"]), np.asarray(qerr))
    np.testing.assert_allclose(np.asarray(mean1["w"] - true_mean),
                               np.asarray(-jnp.mean(qerr, 0)), atol=1e-7)


def test_error_feedback_tree():
    init_fn, transform = compress.make_compressor(bits=4)
    tree = {"a": jax.random.normal(KEY, (256,)) * 0.1,
            "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (64, 4))}}
    res = init_fn(tree)
    acc = jax.tree_util.tree_map(jnp.zeros_like, tree)
    for _ in range(30):
        q, res = transform(tree, res)
        acc = jax.tree_util.tree_map(jnp.add, acc, q)
    for leaf, ref in zip(jax.tree_util.tree_leaves(acc),
                         jax.tree_util.tree_leaves(tree)):
        rel = float(jnp.linalg.norm(leaf - 30 * ref) /
                    (jnp.linalg.norm(30 * ref) + 1e-9))
        assert rel < 0.05, rel
