"""Multi-tenant serving (ISSUE 9): packed cold start without float
materialization, shared ``Plan.jit_forward`` trace caches across
engines on one plan, bit-exactness of a shared-process tenant vs a solo
engine, and aggregate accounting.
"""
import numpy as np
import jax
import pytest

from repro.checkpoint import store
from repro.core.packed import is_packed
from repro.core.policy import TPU_TILED
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine
from repro.serve.tenants import MultiTenantServer, cold_start

KEY = jax.random.PRNGKey(0)
POL = TPU_TILED.with_(block_k=None, straight_through=False)


@pytest.fixture(scope="module")
def packed_ckpt(tmp_path_factory):
    """A bfp_packed lenet artifact + the float params that produced it."""
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    base = str(tmp_path_factory.mktemp("tenants") / "lenet")
    store.save(base, 1, params, format="bfp_packed", policy=POL,
               tree_kind="cnn")
    return spec, params, base


def _imgs(spec, n, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (n, *spec.input_shape()))


def test_cold_start_keeps_packed_leaves(packed_ckpt):
    """The restore template is eval_shape-abstract and packed="keep"
    returns PackedBFP containers — no float weight tree is ever built
    for the prequant-eligible sites."""
    spec, _, base = packed_ckpt
    params = cold_start("lenet", base)
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: is_packed(x))
    assert any(is_packed(l) for l in leaves)


def test_cold_start_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="lenet"):
        cold_start("lenet", str(tmp_path / "nope"))


def test_tenant_bit_exact_vs_solo_engine(packed_ckpt):
    """A tenant served from the shared process (packed cold start) must
    produce logits bit-identical to a solo engine bound to the SAME
    plan — consolidation is an ops decision, never a numerics one."""
    spec, _, base = packed_ckpt
    imgs = _imgs(spec, 4)

    srv = MultiTenantServer(jit=True)
    ten = srv.add_tenant("a", "lenet", checkpoint_dir=base, policy=POL,
                         slots=4)
    got = [srv.submit("a", image=imgs[i]) for i in range(4)]
    srv.run()

    solo = CnnServeEngine(None, spec.apply, ten.plan, slots=4)
    want = [solo.submit(image=imgs[i]) for i in range(4)]
    solo.run()
    for g, w in zip(got, want):
        assert g.error is None
        np.testing.assert_array_equal(g.logits, w.logits)
        assert g.label == w.label


def test_tenants_share_trace_cache_on_one_plan(packed_ckpt):
    """add_tenant(plan=) reuses the donor's Plan: both engines dispatch
    through the SAME plan.jit_forward-cached callable, so one jit trace
    per bucket shape serves every tenant on that plan."""
    spec, _, base = packed_ckpt
    srv = MultiTenantServer(jit=True)
    a = srv.add_tenant("a", "lenet", checkpoint_dir=base, policy=POL,
                       slots=2)
    b = srv.add_tenant("b", "lenet", plan=a.plan, slots=2)
    assert b.plan is a.plan
    assert a.engine._fwd is b.engine._fwd
    assert a.engine._fwd is a.plan.jit_forward(spec.apply)
    imgs = _imgs(spec, 2, seed=3)
    ra = srv.submit("a", image=imgs[0])
    rb = srv.submit("b", image=imgs[0])
    srv.run()
    # same plan + same image -> identical logits through either tenant
    np.testing.assert_array_equal(ra.logits, rb.logits)


def test_multi_model_tenants_and_aggregate_stats(packed_ckpt):
    """Two different MODELS entries in one process, independent queues,
    round-robin draining, and the stats roll-up."""
    spec_l, _, base = packed_ckpt
    spec_c = MODELS["cifarnet"]
    srv = MultiTenantServer(jit=False)
    srv.add_tenant("lenet", "lenet", checkpoint_dir=base, policy=POL,
                   slots=2)
    srv.add_tenant("cifar", "cifarnet", params=spec_c.init(KEY),
                   policy=POL, slots=2, max_queue=2)
    rl = [srv.submit("lenet", image=i) for i in _imgs(spec_l, 3)]
    rc = [srv.submit("cifar", image=i) for i in _imgs(spec_c, 2)]
    from repro.serve.degrade import QueueOverloaded
    with pytest.raises(QueueOverloaded):
        srv.submit("cifar", image=_imgs(spec_c, 1)[0])
    assert srv.pending() == 5
    srv.run()
    assert srv.pending() == 0
    assert all(r.error is None for r in rl + rc)
    st = srv.stats()
    assert st["tenants"]["lenet"]["completed"] == 3
    assert st["tenants"]["cifar"]["completed"] == 2
    assert st["tenants"]["cifar"]["shed"] == 1
    assert st["total"]["completed"] == 5 and st["total"]["shed"] == 1


def test_add_tenant_arg_validation(packed_ckpt):
    spec, params, base = packed_ckpt
    srv = MultiTenantServer()
    t = srv.add_tenant("a", "lenet", checkpoint_dir=base, policy=POL)
    with pytest.raises(ValueError, match="already registered"):
        srv.add_tenant("a", "lenet", checkpoint_dir=base)
    with pytest.raises(ValueError, match="plan= alone"):
        srv.add_tenant("b", "lenet", plan=t.plan, checkpoint_dir=base)
    with pytest.raises(ValueError, match="not both"):
        srv.add_tenant("c", "lenet", checkpoint_dir=base,
                       params=spec.init(KEY))
    assert srv["a"] is t


def test_tenant_logits_match_float_free_restore_path(packed_ckpt):
    """End-to-end: packed cold start == restoring dequantized prequant
    sidecars — the wire format is the numerics, the container is not."""
    spec, params, base = packed_ckpt
    img = _imgs(spec, 1, seed=9)[0]
    srv = MultiTenantServer(jit=False)
    srv.add_tenant("a", "lenet", checkpoint_dir=base, policy=POL,
                   slots=1)
    r = srv.submit("a", image=img)
    srv.run()
    # reference: restore the same artifact as prequant sidecars and
    # serve through a fresh engine (no packed containers involved)
    tpl = jax.tree_util.tree_map(lambda x: x, params)
    ref_params, _ = store.restore(base, tpl, packed="prequant")
    ref_eng = CnnServeEngine(ref_params, spec.apply, POL, slots=1,
                             jit=False, prequant=False)
    ref = ref_eng.submit(image=img)
    ref_eng.run()
    np.testing.assert_array_equal(r.logits, ref.logits)
