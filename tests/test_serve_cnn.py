"""ISSUE 4: batched CNN inference service on sharded BFP plans.

Key contracts:
  * bit-exactness: a request served through ``CnnServeEngine`` produces
    EXACTLY the logits of a direct ``apply(plan.params, batch, plan)``
    on the same rows — verified through ``engine.taps`` events on both
    paths (same sites, same backends, same datapath outputs);
  * bucket padding with DUPLICATES of a live image never perturbs real
    rows (a duplicate row cannot raise a shared block max; a zero image
    would only be safe while zero biases keep zero rows zero);
  * plan reuse: engines bound to one plan share one jitted forward
    (``Plan.jit_forward``), and ``strict_backend`` rejects undeployable
    configs at construction;
  * the data-parallel sharding path (``dist.sharding.axis_rules`` +
    ``launch.mesh``) runs the same code 1-device, bit-identically;
  * continuous batching: more requests than slots drain fully, slots
    are reused.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as EG
from repro.core import BFPPolicy
from repro.dist.sharding import DEFAULT_RULES
from repro.engine.backends import BackendUnsupportedError
from repro.launch.mesh import make_mesh
from repro.models.cnn import MODELS, googlenet, small, vgg
from repro.serve.cnn import CnnServeEngine, ImageRequest, default_buckets
from repro.serve.slots import SlotTable

KEY = jax.random.PRNGKey(0)
EQ4 = BFPPolicy(straight_through=False)


def _images(n, shape=(28, 28, 1)):
    return [jax.random.normal(jax.random.PRNGKey(100 + i), shape)
            for i in range(n)]


# ---------------------------------------------------------------------------
# slot table
# ---------------------------------------------------------------------------

def test_slot_table_admission_and_reuse():
    t = SlotTable(2)
    for r in ("a", "b", "c"):
        t.submit(r)
    assert t.admit() == [0, 1]
    assert t.active() == [0, 1] and t.req[0] == "a"
    assert t.admit() == []          # full: "c" stays queued
    t.free(0)
    assert t.admit() == [0] and t.req[0] == "c"
    t.free(0)
    t.free(1)
    assert not t.pending()
    with pytest.raises(ValueError):
        SlotTable(0)


def test_default_buckets():
    assert default_buckets(8) == (1, 2, 4, 8)
    assert default_buckets(6) == (1, 2, 4, 6)
    assert default_buckets(1) == (1,)


# ---------------------------------------------------------------------------
# bit-exactness vs direct apply (the regression the service is pinned by)
# ---------------------------------------------------------------------------

def test_serve_matches_direct_apply_bitexact():
    """Jitted bucketed serving == direct model apply with the same Plan."""
    params = small.lenet_init(KEY)
    plan = EG.bind(params, EQ4)
    imgs = _images(4)
    direct = small.lenet_apply(plan.params, jnp.stack(imgs), plan)
    eng = CnnServeEngine(None, small.lenet_apply, plan, slots=4,
                         buckets=(4,))
    reqs = [eng.submit(ImageRequest(rid=i, image=im))
            for i, im in enumerate(imgs)]
    eng.run()
    for i, r in enumerate(reqs):
        assert r.done and r.rid == i
        np.testing.assert_array_equal(r.logits, np.asarray(direct[i]))
        assert r.label == int(jnp.argmax(direct[i]))


def test_serve_taps_match_direct_path():
    """ISSUE 4 satellite: the engine runs the SAME datapath as a direct
    apply — engine.taps events on both paths agree on site identity,
    backend, and the exact datapath outputs.  (Taps observe eager
    execution, so the engine runs jit=False here.)"""
    params = small.lenet_init(KEY)
    plan = EG.bind(params, EQ4)
    imgs = _images(4)

    direct_evs = []
    with EG.taps(direct_evs.append):
        direct = small.lenet_apply(plan.params, jnp.stack(imgs), plan)

    serve_evs = []
    eng = CnnServeEngine(None, small.lenet_apply, plan, slots=4,
                         buckets=(4,), jit=False)
    reqs = [eng.submit(image=im) for im in imgs]
    with EG.taps(serve_evs.append):
        eng.run()

    assert [(e.path, e.kind, e.backend) for e in serve_evs] == \
           [(e.path, e.kind, e.backend) for e in direct_evs] == \
           [("c1", "conv", "emulated"), ("c2", "conv", "emulated"),
            ("fc1", "gemm", "emulated"), ("fc2", "gemm", "emulated")]
    for se, de in zip(serve_evs, direct_evs):
        np.testing.assert_array_equal(np.asarray(se.y), np.asarray(de.y))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.logits, np.asarray(direct[i]))


def test_bucket_padding_never_perturbs_real_rows():
    """3 requests into a 4-bucket: the pad row (a duplicate of a live
    image) must not change the live rows' quantization.  Duplicate rows
    are processed identically to their original, so they cannot raise a
    shared block max — unlike a zero image, which is only neutral while
    biases/BN shifts keep zero rows zero, the trained-model case below
    stresses exactly that."""
    params = small.lenet_init(KEY)
    # trained-model shape: nonzero biases make any pad row nonzero from
    # layer 2 on, where an EQ4 whole-matrix exponent could be perturbed
    for name in ("c1", "c2", "fc1", "fc2"):
        params[name]["b"] = jax.random.normal(
            jax.random.PRNGKey(len(name)), params[name]["b"].shape) * 0.5
    plan = EG.bind(params, EQ4)
    imgs = _images(3)
    direct = small.lenet_apply(plan.params, jnp.stack(imgs), plan)
    eng = CnnServeEngine(None, small.lenet_apply, plan, slots=4,
                         buckets=(1, 2, 4))
    reqs = [eng.submit(image=im) for im in imgs]
    eng.run()
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.logits, np.asarray(direct[i]))


def test_serve_sharded_mesh_bitexact():
    """The sharded deployment path (axis_rules + mesh, batch axis on
    "data") is the SAME code 1-device: outputs bit-identical."""
    params = small.lenet_init(KEY)
    plan = EG.bind(params, EQ4)
    imgs = _images(4)
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = CnnServeEngine(None, small.lenet_apply, plan, slots=4,
                         buckets=(4,), mesh=mesh, rules=DEFAULT_RULES)
    reqs = [eng.submit(image=im) for im in imgs]
    eng.run()
    direct = small.lenet_apply(plan.params, jnp.stack(imgs), plan)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.logits, np.asarray(direct[i]))


# ---------------------------------------------------------------------------
# continuous batching / slot reuse
# ---------------------------------------------------------------------------

def test_more_requests_than_slots_drain():
    params = small.lenet_init(KEY)
    eng = CnnServeEngine(params, small.lenet_apply, EQ4, slots=2)
    reqs = [eng.submit(image=im) for im in _images(5)]
    done = eng.run()
    assert len(done) == 5
    assert all(r.done and r.logits.shape == (10,) for r in reqs)
    # single-request isolation: same image served alone gives same logits
    solo = CnnServeEngine(params, small.lenet_apply, EQ4, slots=1)
    r0 = solo.submit(image=reqs[0].image)
    solo.run()
    np.testing.assert_array_equal(r0.logits, reqs[0].logits)


def test_submit_validates_shapes():
    params = small.lenet_init(KEY)
    eng = CnnServeEngine(params, small.lenet_apply, EQ4, slots=2)
    eng.submit(image=jnp.zeros((28, 28, 1)))
    with pytest.raises(ValueError, match="shape"):
        eng.submit(image=jnp.zeros((32, 32, 1)))
    with pytest.raises(ValueError, match="image"):
        eng.submit(image=jnp.zeros((28, 28)))


# ---------------------------------------------------------------------------
# plan binding / reuse
# ---------------------------------------------------------------------------

def test_engines_share_jitted_forward_via_plan():
    """Bind once, serve many: two engines on one plan reuse ONE jitted
    callable (Plan.jit_forward cache) — no per-engine retracing."""
    params = small.lenet_init(KEY)
    plan = EG.bind(params, EQ4)
    e1 = CnnServeEngine(None, small.lenet_apply, plan, slots=2)
    e2 = CnnServeEngine(None, small.lenet_apply, plan, slots=8)
    assert e1._fwd is e2._fwd
    assert e1._fwd is plan.jit_forward(small.lenet_apply)
    # a different plan gets its own
    plan2 = EG.bind(params, EQ4)
    assert plan2.jit_forward(small.lenet_apply) is not e1._fwd


def test_strict_backend_rejects_at_admission():
    """An undeployable serving config (pallas backend, paper scheme it
    cannot honour) fails at engine CONSTRUCTION, not mid-traffic —
    whether the engine binds itself or receives a pre-bound plan."""
    import warnings as W
    from repro.engine.backends import BackendFallbackWarning
    params = small.lenet_init(KEY)
    with pytest.raises(BackendUnsupportedError):
        CnnServeEngine(params, small.lenet_apply,
                       EQ4.with_(backend="pallas"), strict_backend=True)
    # a pre-bound plan carrying downgraded sites is rejected too (the
    # Plan branch must not silently skip the strict check)
    with W.catch_warnings():
        W.simplefilter("ignore", BackendFallbackWarning)
        lax_plan = EG.bind(params, EQ4.with_(backend="pallas"))
    with pytest.raises(BackendUnsupportedError, match="downgraded"):
        CnnServeEngine(None, small.lenet_apply, lax_plan,
                       strict_backend=True)
    # a clean plan passes strict, and params alongside a plan is an error
    clean = EG.bind(params, EQ4)
    CnnServeEngine(None, small.lenet_apply, clean, strict_backend=True)
    with pytest.raises(ValueError, match="params=None"):
        CnnServeEngine(params, small.lenet_apply, clean)


def test_prequant_plan_serves_wire_format():
    params = small.lenet_init(KEY)
    eng = CnnServeEngine(params, small.lenet_apply, EQ4, slots=2,
                         prequant=True)
    assert EG.is_prequant(eng.plan.params["c1"]["w"])
    r = eng.submit(image=_images(1)[0])
    eng.run()
    assert r.done and np.isfinite(r.logits).all()


# ---------------------------------------------------------------------------
# model registry / multi-head models
# ---------------------------------------------------------------------------

def test_registry_covers_paper_models():
    assert {"vgg16", "resnet18", "resnet50", "googlenet"} <= set(MODELS)
    assert MODELS["vgg16"].apply is vgg.apply
    assert MODELS["lenet"].input_shape() == (28, 28, 1)


def test_googlenet_multi_head_serves_main_logits():
    """Tuple-returning models (GoogLeNet's three heads) serve head 0."""
    spec = MODELS["googlenet"]
    params = spec.init(KEY)
    x = jax.random.normal(KEY, spec.input_shape())
    eng = CnnServeEngine(params, spec.apply, EQ4, slots=1)
    r = eng.submit(image=x)
    eng.run()
    direct = googlenet.apply(eng.plan.params, x[None], eng.plan)[0]
    np.testing.assert_array_equal(r.logits, np.asarray(direct[0]))


def test_vgg_reduced_through_engine():
    """A paper-model shape end to end through the serving stack."""
    spec = MODELS["vgg16"]
    params = spec.init(KEY)
    eng = CnnServeEngine(params, spec.apply, EQ4, slots=2)
    reqs = [eng.submit(image=jax.random.normal(jax.random.PRNGKey(i),
                                               spec.input_shape()))
            for i in range(3)]
    eng.run()
    assert all(r.done and r.logits.shape == (10,) for r in reqs)
