"""Graceful degradation in both serve engines (ISSUE 7).

Deadlines, queue shedding with typed rejections, the non-finite-logits
float retry, the lower-L degraded admission mode (bit-exact against a
direct lower-L bind) with drain-recovery, and the slot-leak regression:
a raising forward/prefill completes its requests exceptionally and
frees their slots, so the engine keeps serving.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.policy import TPU_TILED
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine, ImageRequest
from repro.serve.degrade import (DeadlineExceeded, DegradeConfig,
                                 DegradeController, QueueOverloaded,
                                 ServeRejected, float_params)
from repro.serve.engine import Request, ServeEngine
from repro.train.step import init_state

KEY = jax.random.PRNGKey(0)
POL = TPU_TILED.with_(block_k=None, straight_through=False)
POL4 = POL.with_(l_w=4, l_i=4)

#: trip after one overloaded step, recover after one drained step —
#: the fastest state machine, so tests drive transitions in few steps
FAST = DegradeConfig(queue_high=4, queue_low=0, trip_steps=1,
                     recover_steps=1)


@pytest.fixture(scope="module")
def lenet():
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (12, *spec.input_shape()))
    return spec, params, imgs


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(ARCHS["tinyllama-1.1b"], n_layers=2, d_model=64,
                  d_ff=128, vocab=256)
    params = init_state(cfg, KEY).params
    return cfg, params


# ---------------------------------------------------------------------------
# Controller
# ---------------------------------------------------------------------------

def test_controller_hysteresis():
    c = DegradeController(DegradeConfig(queue_high=4, queue_low=1,
                                        trip_steps=2, recover_steps=2))
    assert c.observe(5) == "primary"        # 1 of 2 overloaded steps
    assert c.observe(2) == "primary"        # streak broken
    c.observe(5)
    assert c.observe(5) == "degraded" and c.trips == 1
    assert c.observe(1) == "degraded"       # 1 of 2 drained steps
    assert c.observe(3) == "degraded"       # streak broken
    c.observe(0)
    assert c.observe(1) == "primary" and c.recoveries == 1


def test_degrade_config_validation():
    with pytest.raises(ValueError, match="queue_high"):
        DegradeConfig(queue_high=0)
    with pytest.raises(ValueError, match="queue_low"):
        DegradeConfig(queue_high=2, queue_low=2)
    with pytest.raises(ValueError, match="trip_steps"):
        DegradeConfig(trip_steps=0)


# ---------------------------------------------------------------------------
# CNN engine
# ---------------------------------------------------------------------------

def test_cnn_shed_typed_rejection(lenet):
    spec, params, imgs = lenet
    eng = CnnServeEngine(params, spec.apply, POL, slots=2, jit=False,
                         max_queue=2)
    eng.submit(image=imgs[0])
    eng.submit(image=imgs[1])
    with pytest.raises(QueueOverloaded) as ei:
        eng.submit(image=imgs[2])
    assert isinstance(ei.value, ServeRejected)
    assert ei.value.rid is not None
    assert eng.stats["shed"] == 1
    assert len(eng.table.queue) == 2        # the shed request never queued
    done = eng.run()
    assert all(r.error is None for r in done)


def test_cnn_deadline_expiry(lenet):
    spec, params, imgs = lenet
    t = [0.0]
    eng = CnnServeEngine(params, spec.apply, POL, slots=2, jit=False,
                         clock=lambda: t[0])
    late = eng.submit(ImageRequest(rid=0, image=imgs[0], deadline=5.0))
    ok = eng.submit(ImageRequest(rid=1, image=imgs[1], deadline=50.0))
    t[0] = 10.0
    eng.run()
    assert late.done and isinstance(late.error, DeadlineExceeded)
    assert late.logits is None and late.error.rid == 0
    assert ok.error is None and ok.logits is not None
    assert eng.stats["expired"] == 1
    assert not eng.table.pending()


def test_cnn_degraded_mode_bit_exact_and_recovers(lenet):
    spec, params, imgs = lenet
    eng = CnnServeEngine(params, spec.apply, POL, slots=2, jit=False,
                         fallback_policy=POL4, degrade=FAST)
    # light load serves on the primary plan
    first = [eng.submit(image=imgs[i]) for i in range(2)]
    eng.step()
    assert all(r.done and not r.degraded for r in first)
    # flood: queue depth >= high watermark trips admission to fallback
    flood = [eng.submit(image=imgs[2 + i]) for i in range(8)]
    eng.run()
    assert all(r.done and r.error is None for r in flood)
    deg = [r for r in flood if r.degraded]
    assert deg and eng.stats["degraded_served"] == len(deg)
    # degraded logits are BIT-EXACT vs a direct lower-L bind (same
    # engine padding: batch of one request -> bucket 1)
    fb = eng.fallback_plan
    for r in deg[:3]:
        direct = np.asarray(spec.apply(fb.params,
                                       jnp.stack([r.image]), fb))
        np.testing.assert_array_equal(r.logits, direct[0])
    # an idle step observes the drained queue -> recovery
    eng.step()
    assert eng.controller.state == DegradeController.PRIMARY
    assert eng.controller.recoveries == 1
    post = eng.submit(image=imgs[0])
    eng.run()
    assert not post.degraded


def test_cnn_float_retry_on_nonfinite(lenet):
    spec, params, imgs = lenet

    def flaky_apply(p, x, pol):
        y = spec.apply(p, x, pol)
        return y * jnp.nan if pol is not None else y

    eng = CnnServeEngine(params, flaky_apply, POL, slots=2, jit=False)
    r = eng.submit(image=imgs[0])
    eng.run()
    assert eng.stats["float_retries"] == 1
    assert r.error is None and np.all(np.isfinite(r.logits))
    # the retry served the float reference of the plan's own
    # (quantized) weights — bit-exact at the same batch shape
    ft = float_params(eng.plan.params)
    want = np.asarray(spec.apply(ft, jnp.stack([r.image]), None))
    np.testing.assert_array_equal(r.logits, want[0])
    # retry is opt-out
    eng2 = CnnServeEngine(params, flaky_apply, POL, slots=2, jit=False,
                          float_retry=False)
    r2 = eng2.submit(image=imgs[0])
    eng2.run()
    assert eng2.stats["float_retries"] == 0
    assert not np.any(np.isfinite(r2.logits))


def test_cnn_slot_leak_regression(lenet):
    spec, params, imgs = lenet
    calls = [0]

    def bad_apply(p, x, pol):
        calls[0] += 1
        if calls[0] == 1:
            raise RuntimeError("boom")
        return spec.apply(p, x, pol)

    eng = CnnServeEngine(params, bad_apply, POL, slots=2, jit=False,
                         float_retry=False)
    ra = eng.submit(image=imgs[0])
    rb = eng.submit(image=imgs[1])
    eng.run()
    # both requests of the failed group complete exceptionally...
    assert ra.done and isinstance(ra.error, RuntimeError)
    assert rb.done and isinstance(rb.error, RuntimeError)
    assert eng.stats["failed"] == 2
    # ...and their slots were freed, so the engine keeps serving
    assert eng.table.active() == [] and not eng.table.pending()
    rc = eng.submit(image=imgs[2])
    eng.run()
    assert rc.error is None and rc.logits is not None


# ---------------------------------------------------------------------------
# LM engine
# ---------------------------------------------------------------------------

def test_lm_shed_and_deadline(lm):
    cfg, params = lm
    eng = ServeEngine(params, cfg, slots=1, max_len=32, policy=POL,
                      max_queue=1)
    eng.submit(Request(rid=0, prompt=[1], max_new=2))
    with pytest.raises(QueueOverloaded):
        eng.submit(Request(rid=1, prompt=[1], max_new=2))
    assert eng.stats["shed"] == 1

    t = [0.0]
    eng2 = ServeEngine(params, cfg, slots=1, max_len=32, policy=POL,
                       clock=lambda: t[0])
    rd = Request(rid=0, prompt=[1, 2], max_new=10, deadline=5.0)
    eng2.submit(rd)
    eng2.step()                       # decodes while within deadline
    t[0] = 10.0
    eng2.step()                       # expiry: partial output kept
    assert rd.done and isinstance(rd.error, DeadlineExceeded)
    assert len(rd.out) >= 1
    assert not eng2.table.pending()


def test_lm_degraded_mode_bit_exact_and_recovers(lm):
    cfg, params = lm
    eng = ServeEngine(params, cfg, slots=2, max_len=32, policy=POL,
                      fallback_policy=POL4,
                      degrade=DegradeConfig(queue_high=3, queue_low=0,
                                            trip_steps=1,
                                            recover_steps=1))
    rs = [Request(rid=i, prompt=[1, 2, 3], max_new=4) for i in range(6)]
    for r in rs:
        eng.submit(r)
    eng.run()
    assert all(r.done and r.error is None for r in rs)
    deg = [r for r in rs if r.degraded]
    assert deg
    # degraded decode is bit-exact vs an engine bound directly at the
    # lower L: plan choice at admission covers the WHOLE sequence
    eng_fb = ServeEngine(params, cfg, slots=2, max_len=32, policy=POL4)
    for r in deg[:2]:
        r2 = Request(rid=90 + r.rid, prompt=list(r.prompt),
                     max_new=r.max_new)
        eng_fb.submit(r2)
        eng_fb.run()
        assert r2.out == r.out
    eng.step()                        # drained queue -> recovery
    assert eng.controller.state == DegradeController.PRIMARY
    post = Request(rid=50, prompt=[1, 2], max_new=2)
    eng.submit(post)
    eng.run()
    assert post.done and not post.degraded


def test_lm_slot_leak_regression(lm):
    cfg, params = lm
    eng = ServeEngine(params, cfg, slots=2, max_len=32, policy=POL)
    boom = [True]
    orig = eng._step

    def flaky_step(cache, tok, pos):
        if boom[0]:
            boom[0] = False
            raise RuntimeError("step boom")
        return orig(cache, tok, pos)

    ra = Request(rid=0, prompt=[1, 2], max_new=3)
    eng.submit(ra)
    eng._step = flaky_step            # prefill of ra raises once
    eng.run()
    assert ra.done and isinstance(ra.error, RuntimeError)
    assert eng.stats["failed"] == 1
    assert eng.table.active() == [] and not eng.table.pending()
    # the slot is reusable: the next request decodes normally
    rb = Request(rid=1, prompt=[1, 2], max_new=3)
    eng.submit(rb)
    eng.run()
    assert rb.done and rb.error is None and len(rb.out) == 3
