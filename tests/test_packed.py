"""Packed BFP container end-to-end (ISSUE 5): container hygiene, the
checkpoint size acceptance (vgg16-reduced packed <= 0.35x float32 npz at
8-bit mantissas), the save-packed -> restore -> serve bit-exactness
regression against the float-checkpoint path, and the dist wire-bytes
contract (model == wire, padding counted, tile alignment validated).
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as EG
from repro.checkpoint import store
from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core import bfp, packed, prequant
from repro.core.bfp import BFPBlock
from repro.core.policy import TPU_TILED
from repro.dist import compress
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine
from repro.serve.engine import Request, ServeEngine
from repro.train.step import init_state

KEY = jax.random.PRNGKey(0)

#: serving-mode policy: whole-K tiles so every conv/fc K in the reduced
#: models packs; straight_through off (inference numerics)
POL = TPU_TILED.with_(block_k=None, straight_through=False)


def _dir_bytes(d):
    return sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(d) for f in fs)


# ---------------------------------------------------------------------------
# Container hygiene
# ---------------------------------------------------------------------------

def test_container_rejects_garbage_and_truncation():
    blk = bfp.quantize(jax.random.normal(KEY, (4, 16)), 8, (1,))
    p = packed.pack_block(blk)
    buf = p.to_bytes()
    with pytest.raises(ValueError, match="magic"):
        packed.PackedBFP.from_bytes(b"NOPE" + buf[4:])
    with pytest.raises(ValueError, match="version"):
        packed.PackedBFP.from_bytes(buf[:4] + bytes([99]) + buf[5:])
    with pytest.raises(ValueError, match="truncated"):
        packed.PackedBFP.from_bytes(buf[:-3])
    # and the header is self-describing: nbytes == serialized length ==
    # the analytic accounting
    import json
    assert p.nbytes == len(buf)
    assert packed.packed_nbytes(p.shape, p.exp_shape, p.bits,
                                meta_len=len(json.dumps(p.meta))) == len(buf)


def test_bitstream_chunking_crosses_boundaries_bit_exact():
    """The (un)packer processes leaves in _CHUNK-element chunks to bound
    transient memory; a leaf spanning several chunks with an odd mantissa
    width must still round-trip bit-exactly (chunk seams are mid-byte
    free because _CHUNK is a multiple of 8)."""
    n = packed._CHUNK * 2 + 12345            # 3 chunks, ragged tail
    rng = np.random.default_rng(0)
    for bits in (5, 8, 11):
        lim = 2 ** (bits - 1) - 1
        m = rng.integers(-lim, lim + 1, size=n).astype(np.int32)
        payload = packed._pack_bits(m, bits)
        assert len(payload) == -(-n * bits // 8)
        got = packed._unpack_bits(payload, n, bits)
        np.testing.assert_array_equal(m, got)


def test_mantissa_out_of_range_rejected():
    blk = BFPBlock(mantissa=jnp.full((2, 4), 100, jnp.int8),
                   exponent=jnp.zeros((2, 1), jnp.int32), bits=4)
    with pytest.raises(ValueError, match="mantissa outside"):
        packed.pack_block(blk)


def test_exponent_outside_int8_rejected():
    # an exponent below -128 (denormal-range block max) cannot be stored
    # as one int8 per block, and the container refuses a lossy clip
    blk = BFPBlock(mantissa=jnp.zeros((1, 8), jnp.int8),
                   exponent=jnp.full((1, 1), -150, jnp.int32), bits=8)
    with pytest.raises(ValueError, match="int8 range"):
        packed.pack_block(blk)


def test_non_power_of_two_scales_rejected():
    d = {"m": jnp.ones((4, 2), jnp.int8), "s": jnp.full((2, 2), 0.3)}
    with pytest.raises(ValueError, match="powers of two"):
        packed.pack_prequant(d, 8)


def test_pack_param_tree_needs_policy_and_known_kind():
    params = MODELS["lenet"].init(KEY)
    with pytest.raises(ValueError, match="BFPPolicy or PolicyMap"):
        packed.pack_param_tree(params, None)
    with pytest.raises(ValueError, match="kind"):
        packed.pack_param_tree(params, POL, kind="nope")


def test_pack_param_tree_leaves_non_gemm_leaves_alone():
    params = MODELS["resnet18"].init(KEY)
    pk = packed.pack_param_tree(params, POL, "cnn")
    flat_f = jax.tree_util.tree_leaves_with_path(params)
    packed_paths = {jax.tree_util.keystr(p)
                    for p, l in jax.tree_util.tree_leaves_with_path(
                        pk, is_leaf=packed.is_packed)
                    if packed.is_packed(l)}
    assert packed_paths                       # convs + fc got packed
    assert all("'w'" in p for p in packed_paths)
    # bn gains/biases and conv biases survive bit-identical
    for path, leaf in flat_f:
        if jax.tree_util.keystr(path) not in packed_paths:
            sub = pk
            for k in path:
                sub = sub[getattr(k, "key", getattr(k, "idx", None))]
            if hasattr(sub, "shape"):
                np.testing.assert_array_equal(np.asarray(leaf),
                                              np.asarray(sub))


# ---------------------------------------------------------------------------
# Checkpoint: size acceptance + bit-exact serve regression
# ---------------------------------------------------------------------------

def test_vgg16_reduced_packed_checkpoint_small_and_serves_bit_exact():
    """ISSUE 5 acceptance: the packed vgg16-reduced checkpoint is
    <= 0.35x the float32 npz at 8-bit mantissas, and a packed-restore
    serve produces logits BIT-IDENTICAL to the float-checkpoint path."""
    spec = MODELS["vgg16"]
    params = spec.init(KEY)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (2, *spec.input_shape()))
    with tempfile.TemporaryDirectory() as d:
        store.save(os.path.join(d, "f32"), 0, params)
        store.save(os.path.join(d, "bfp"), 0, params,
                   format="bfp_packed", policy=POL)
        f32_dir = os.path.join(d, "f32", "step_00000000")
        bfp_dir = os.path.join(d, "bfp", "step_00000000")
        ratio = _dir_bytes(bfp_dir) / _dir_bytes(f32_dir)
        assert ratio <= 0.35, f"packed checkpoint ratio {ratio:.3f}"

        # float-checkpoint path: restore f32, bind (prequantizes), serve
        p_f, _ = store.restore(os.path.join(d, "f32"), params)
        eng_f = CnnServeEngine(p_f, spec.apply, POL, slots=2, jit=False)
        # packed path: restore straight to {"m","s"} sidecars, serve —
        # no float weights ever materialized for the packed sites
        p_q, step = store.restore(os.path.join(d, "bfp"), params)
        assert step == 0
        eng_q = CnnServeEngine(p_q, spec.apply, POL, slots=2, jit=False)

        r_f = eng_f.submit(image=imgs[0])
        r_q = eng_q.submit(image=imgs[0])
        eng_f.run()
        eng_q.run()
        np.testing.assert_array_equal(r_f.logits, r_q.logits)

        # manifest records the format and which leaves are packed
        import json
        with open(os.path.join(bfp_dir, "manifest.json")) as f:
            man = json.load(f)
        assert man["format"] == "bfp_packed" and man["packed_leaves"]
        assert any(dt.startswith("bfp_packed") for dt in man["dtypes"])


def test_restore_keep_mode_binds_without_float_materialization():
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    imgs = jax.random.normal(jax.random.PRNGKey(2),
                             (1, *spec.input_shape()))
    plan_ref = EG.bind(params, POL, tree="cnn")
    y_ref = spec.apply(plan_ref.params, imgs, plan_ref)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, params, format="bfp_packed", policy=POL)
        kept, _ = store.restore(d, params, packed="keep")
        n_containers = sum(
            packed.is_packed(l) for l in
            jax.tree_util.tree_leaves(kept, is_leaf=packed.is_packed))
        assert n_containers > 0
        plan = EG.bind(kept, POL, tree="cnn")     # unpacks PackedBFP leaves
        y = spec.apply(plan.params, imgs, plan)
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y))
        # dequant mode gives a plain float tree of the original structure
        deq, _ = store.restore(d, params, packed="dequant")
        assert jax.tree_util.tree_structure(deq) == \
            jax.tree_util.tree_structure(params)
        w = deq["c1"]["w"]
        assert jnp.issubdtype(w.dtype, jnp.floating)
        # dequantized values equal the sidecar dequant, not the raw float
        side = prequant.prequant_conv_leaf(params["c1"]["w"], POL)
        kh, kw, c, n = np.asarray(side["m"]).shape
        want = prequant.dequantize_prequant(
            {"m": side["m"].reshape(kh * kw * c, n), "s": side["s"]}
        ).reshape(kh, kw, c, n)
        np.testing.assert_array_equal(np.asarray(w), np.asarray(want))
        # dequant-mode weights are plain float arrays, so sharding_fn
        # places them like any other leaf (elastic-restart contract)
        dev = jax.devices()[0]
        placed, _ = store.restore(d, params, packed="dequant",
                                  sharding_fn=lambda i: dev)
        w_placed = placed["c1"]["w"]
        assert w_placed.devices() == {dev}
        np.testing.assert_array_equal(np.asarray(w_placed), np.asarray(w))


def test_restore_shape_mismatch_still_caught_for_packed_leaves():
    from repro.models.cnn import small
    params = MODELS["lenet"].init(KEY)
    other = small.lenet_init(KEY, num_classes=7)   # same tree, fc2 differs
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, params, format="bfp_packed", policy=POL)
        with pytest.raises(ValueError, match="mismatch"):
            store.restore(d, other)
        # a DIFFERENT tree (fewer leaves) is a diagnosable ValueError,
        # not an IndexError from packed-index bookkeeping
        with pytest.raises(ValueError, match="mismatch"):
            store.restore(d, {"w": params["c1"]["w"]})


def test_pack_param_tree_accepts_bound_plan_params():
    """The bind-once, checkpoint-the-bound-weights flow: plan.params
    already holds {"m","s"} sidecars; packing them is lossless and the
    restore equals the sidecars bit-exactly."""
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    plan = EG.bind(params, POL, tree="cnn")
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, plan.params, format="bfp_packed", policy=POL)
        got, _ = store.restore(d, params)          # prequant sidecars
    w_l = jax.tree_util.tree_leaves_with_path(plan.params)
    g_l = jax.tree_util.tree_leaves_with_path(got)
    assert len(w_l) == len(g_l)
    for (pw, lw), (pg, lg) in zip(w_l, g_l):
        assert jax.tree_util.keystr(pw) == jax.tree_util.keystr(pg)
        np.testing.assert_array_equal(np.asarray(lw), np.asarray(lg))


def test_save_format_validation():
    from repro.engine import PolicyMap
    params = MODELS["lenet"].init(KEY)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="unknown checkpoint format"):
            store.save(d, 0, params, format="int4")
        with pytest.raises(ValueError, match="packed zero leaves"):
            store.save(d, 0, params, format="bfp_packed")
        # a packed request whose policy resolves NOTHING fails loudly
        # instead of silently writing a full-size float32 artifact
        none_map = PolicyMap.of(("^no_such_layer$", POL), default=None)
        with pytest.raises(ValueError, match="packed zero leaves"):
            store.save(d, 0, params, format="bfp_packed", policy=none_map)
        assert store.latest_step(d) is None       # nothing was written
        with pytest.raises(ValueError, match="packed"):
            store.save(d, 0, params, format="bfp_packed", policy=POL)
            store.restore(d, params, packed="nope")
        # a pre-packed tree needs no policy
        pk = packed.pack_param_tree(params, POL, "cnn")
        store.save(d, 1, pk, format="bfp_packed")
        got, step = store.restore(d, params, packed="keep")
        assert step == 1


def test_async_checkpointer_handles_packed_trees():
    """Regression: save_async used to np.asarray PackedBFP leaves into
    pickled 0-d object arrays that restore could not read.  The async
    path now snapshots containers as-is and forwards format/policy."""
    params = MODELS["lenet"].init(KEY)
    with tempfile.TemporaryDirectory() as d:
        ck = store.Checkpointer(d, format="bfp_packed", policy=POL)
        ck.save_async(3, params)
        ck.wait()
        got, step = store.restore(d, params, packed="keep")
        assert step == 3
        assert any(packed.is_packed(l) for l in
                   jax.tree_util.tree_leaves(got, is_leaf=packed.is_packed))
        # and an already-packed tree snapshots through the async path too
        pk = packed.pack_param_tree(params, POL, "cnn")
        ck2 = store.Checkpointer(d)
        ck2.save_async(4, pk)
        ck2.wait()
        got2, step2 = store.restore(d, params)    # prequant sidecars
        assert step2 == 4
        assert any(prequant.is_prequant(l) for l in
                   jax.tree_util.tree_leaves(
                       got2, is_leaf=prequant.is_prequant))


# ---------------------------------------------------------------------------
# LM trees: packed checkpoint == prequantize, and the serve engines load it
# ---------------------------------------------------------------------------

def _lm_cfg():
    return reduced(ARCHS["tinyllama-1.1b"], n_layers=2, d_model=64,
                   d_ff=128, vocab=256)


def test_lm_packed_checkpoint_matches_prequantize():
    cfg = _lm_cfg()
    params = init_state(cfg, KEY).params
    want = EG.prequantize(params, POL)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, params, format="bfp_packed", policy=POL,
                   tree_kind="lm")
        got, _ = store.restore(d, params)          # packed="prequant"
    w_l = jax.tree_util.tree_leaves_with_path(want)
    g_l = jax.tree_util.tree_leaves_with_path(got)
    assert len(w_l) == len(g_l)
    for (pw, lw), (pg, lg) in zip(w_l, g_l):
        assert jax.tree_util.keystr(pw) == jax.tree_util.keystr(pg)
        np.testing.assert_array_equal(np.asarray(lw), np.asarray(lg))


def test_lm_serve_engine_accepts_packed_artifact():
    cfg = _lm_cfg()
    params = init_state(cfg, KEY).params
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, params, format="bfp_packed", policy=POL,
                   tree_kind="lm")
        kept, _ = store.restore(d, params, packed="keep")
        deq, _ = store.restore(d, params, packed="dequant")

    def run(p):
        eng = ServeEngine(p, cfg, slots=2, max_len=64)
        reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.out for r in reqs]

    # the packed artifact decodes exactly like the dequantized tree (the
    # float backend dequantizes {"m","s"} on the fly to the same values)
    assert run(kept) == run(deq)


# ---------------------------------------------------------------------------
# dist wire: real bytes, honest padding, tile alignment
# ---------------------------------------------------------------------------

def test_wire_pack_matches_in_graph_model_bit_exact():
    g = jax.random.normal(KEY, (37, 29))           # 1073 elems: padded tail
    for bits in (4, 6, 8):
        got = compress.unpack_leaf(compress.pack_leaf(g, bits, block=128))
        want = compress.quantize_leaf(g, bits, block=128)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wire_bytes_count_remainder_padding():
    # 513 elements at block=512 travel as TWO full blocks — the padding
    # is on the wire and the accounting says so (the old analytic ratio
    # ignored it)
    assert compress.leaf_wire_bytes(513, 8, 512) == 2 * 512 + 2
    assert compress.leaf_wire_bytes(512, 8, 512) == 512 + 1
    assert compress.leaf_wire_bytes(1, 4, 512) == 256 + 1
    g = jax.random.normal(KEY, (513,))
    p = compress.pack_leaf(g, 8, block=512)
    overhead = p.nbytes - compress.leaf_wire_bytes(513, 8, 512)
    assert 0 < overhead < 120                     # header only


def test_wire_block_tile_alignment_validated():
    g = jax.random.normal(KEY, (64,))
    with pytest.raises(ValueError, match="multiple of the TILED"):
        compress.quantize_leaf(g, 8, block=48, tile_k=32)
    with pytest.raises(ValueError, match="multiple of the TILED"):
        compress.pack_leaf(g, 8, block=48, tile_k=32)
    with pytest.raises(ValueError, match="multiple of the TILED"):
        compress.make_compressor(8, block=48, tile_k=32)
    with pytest.raises(ValueError, match="positive int"):
        compress.quantize_leaf(g, 8, block=0)
    # aligned geometry passes
    compress.quantize_leaf(g, 8, block=64, tile_k=32)


def test_wire_report_measures_real_ratio():
    tree = {"w": jax.random.normal(KEY, (256, 64)),
            "step": jnp.asarray(3, jnp.int32)}
    rep = compress.wire_report(tree, bits=8, block=512)
    assert rep["n_leaves"] == 2 and rep["n_uncompressed"] == 1
    assert rep["wire_bytes"] < rep["float_bytes"]
    # a 16k-element f32 leaf at 8 bits: ~0.25x + exponents + header
    shape, wire, raw = max(rep["per_leaf"], key=lambda t: t[2])
    assert shape == (256, 64)
    assert 0.24 < wire / raw < 0.27


def test_wire_rejects_non_float_and_non_wire_containers():
    with pytest.raises(ValueError, match="float leaf"):
        compress.pack_leaf(jnp.arange(8), 8)
    blk = bfp.quantize(jax.random.normal(KEY, (2, 8)), 8, (1,))
    with pytest.raises(ValueError, match="wire"):
        compress.unpack_leaf(packed.pack_block(blk))
