"""ISSUE 3: bound execution plans, engine taps, strict backend selection.

Key contracts:
  * ``engine.bind`` plans are BIT-IDENTICAL to the legacy per-call path
    on every backend (emulated, pallas, float), for GEMMs and convs;
  * backend downgrades are never silent: warn-once by default, raise
    with ``strict=True`` — surfaced at bind time and via ServeEngine;
  * policy rules naming unknown backends fail at bind time with the
    ``available_backends`` KeyError, not mid-forward;
  * policy-None convs consult the registered "float" backend (the same
    extension point GEMMs document);
  * taps observe the real datapath, are suppressed under jit tracing,
    and cost one list check when unregistered.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as EG
from repro.core import BFPPolicy, Scheme
from repro.engine import PolicyMap
from repro.engine.backends import (BackendFallbackWarning,
                                   BackendUnsupportedError)
from repro.models.cnn import resnet, small

KEY = jax.random.PRNGKey(0)
EQ4 = BFPPolicy(straight_through=False)
TILED = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)


# ---------------------------------------------------------------------------
# bind: bit-identical to the legacy per-call path
# ---------------------------------------------------------------------------

def test_bind_lenet_bitexact_vs_legacy():
    """Full bound pipeline (prequant + per-site dispatch) == legacy
    prequantize_cnn + per-call PolicyMap resolution, bit for bit."""
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    plan = EG.bind(params, EQ4)
    assert set(plan.sites) == {"c1", "c2", "fc1", "fc2"}
    assert plan.site("c1").kind == "conv" and plan.site("c1").prequantized
    assert plan.site("fc1").kind == "gemm"
    out_plan = small.lenet_apply(plan.params, x, plan)
    out_legacy = small.lenet_apply(EG.prequantize_cnn(params, EQ4), x, EQ4)
    np.testing.assert_array_equal(np.asarray(out_plan),
                                  np.asarray(out_legacy))


def test_bind_without_prequant_matches_inline():
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    plan = EG.bind(params, EQ4, prequantize=False)
    assert not plan.site("c1").prequantized
    np.testing.assert_array_equal(
        np.asarray(small.lenet_apply(plan.params, x, plan)),
        np.asarray(small.lenet_apply(params, x, EQ4)))


def test_bind_policymap_resnet_bitexact():
    """Mixed per-layer assignment (stem float, rest BFP) through a bound
    plan == the per-call PolicyMap path, across residual topology."""
    params = resnet.init(KEY, 18, 10, width_mult=0.25)
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    pm = PolicyMap.of(("^stem", None), default=EQ4)
    plan = EG.bind(params, pm)
    assert plan.site("stem").policy is None
    assert not plan.site("stem").prequantized   # rule kept it float
    assert plan.site("blocks/0/c1").policy == EQ4
    out_plan = resnet.apply(plan.params, x, plan)
    out_legacy = resnet.apply(EG.prequantize_cnn(params, pm), x, pm)
    np.testing.assert_array_equal(np.asarray(out_plan),
                                  np.asarray(out_legacy))


def test_bind_gemm_pallas_bitexact():
    """The kernel path through a bound site == legacy pallas dispatch
    (kernel/oracle/core triangulation holds through plans)."""
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 32)) * 0.1
    x = jax.random.normal(KEY, (8, 256))
    pol = TILED.with_(backend="pallas")
    plan = EG.bind({"fc": {"w": w}}, pol)
    assert plan.site("fc").backend.name == "pallas"
    assert not plan.site("fc").fallback
    np.testing.assert_array_equal(
        np.asarray(plan.gemm(x, plan.params["fc"]["w"], path="fc")),
        np.asarray(EG.gemm(x, EG.prequantize_cnn({"fc": {"w": w}},
                                                 pol)["fc"]["w"], pol)))


def test_bind_conv_pallas_fused_bitexact():
    """Bound conv site keeps the fused implicit-im2col kernel."""
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 4, 8, 16)) * 0.1
    x = jax.random.normal(KEY, (1, 6, 6, 8))
    pol = TILED.with_(backend="pallas")   # K = 4*4*8 = 128 = block_k
    plan = EG.bind({"conv1": {"w": w}}, pol)
    site = plan.site("conv1")
    assert site.kind == "conv" and site.backend.name == "pallas"
    out_plan = plan.conv2d(x, plan.params["conv1"]["w"], path="conv1",
                           stride=1, padding="SAME")
    wq = EG.prequantize_cnn({"conv1": {"w": w}}, pol)["conv1"]["w"]
    out_legacy = EG.conv2d(x, wq, pol, stride=1, padding="SAME")
    np.testing.assert_array_equal(np.asarray(out_plan),
                                  np.asarray(out_legacy))


def test_plan_unbound_path_falls_back_per_call():
    """Paths bind never saw resolve against the original policy."""
    params = small.lenet_init(KEY)
    plan = EG.bind(params, PolicyMap.of(("^c1$", None), default=EQ4))
    x = jax.random.normal(KEY, (4, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 8)) * 0.1
    np.testing.assert_array_equal(
        np.asarray(plan.gemm(x, w, path="not/a/site")),
        np.asarray(EG.gemm(x, w, EQ4)))
    assert plan.resolve("c1") is None
    assert plan.resolve("not/a/site") == EQ4    # PolicyMap default


def test_plan_jit_closure_safe():
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    plan = EG.bind(params, EQ4)
    jitted = jax.jit(lambda p, xx: small.lenet_apply(p, xx, plan))
    np.testing.assert_array_equal(
        np.asarray(jitted(plan.params, x)),
        np.asarray(small.lenet_apply(plan.params, x, plan)))


def test_plan_model_paths_restricts_and_extends():
    params = small.lenet_init(KEY)
    plan = EG.bind(params, EQ4, model_paths=["c1", ("extra/site", "gemm")])
    assert set(plan.sites) == {"c1", "extra/site"}
    assert plan.site("extra/site").policy == EQ4   # policy-only entry
    # the restriction scopes prequantization too: unbound sites keep
    # their float leaves
    assert EG.is_prequant(plan.params["c1"]["w"])
    assert not EG.is_prequant(plan.params["c2"]["w"])
    assert not EG.is_prequant(plan.params["fc1"]["w"])


# ---------------------------------------------------------------------------
# strict / warn-once backend selection (satellite 1)
# ---------------------------------------------------------------------------

def test_select_backend_strict_raises():
    w = jax.random.normal(KEY, (64, 8))
    with pytest.raises(BackendUnsupportedError, match="strict"):
        EG.select_backend(EQ4.with_(backend="pallas"), w, strict=True,
                          path="strict/site/a")


def test_select_backend_warns_once_per_site():
    w = jax.random.normal(KEY, (64, 8))
    pol = EQ4.with_(backend="pallas")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        be = EG.select_backend(pol, w, path="warn/site/unique1")
        assert be.name == "emulated"
        EG.select_backend(pol, w, path="warn/site/unique1")
    fallbacks = [r for r in rec
                 if issubclass(r.category, BackendFallbackWarning)]
    assert len(fallbacks) == 1   # once per site, not per call
    assert "pallas" in str(fallbacks[0].message)


def test_each_bind_warns_independently():
    """The warn-once dedup is per bind, not process-global: a later
    independently-constructed plan must surface its own downgrades."""
    params = small.lenet_init(KEY)
    pol = EQ4.with_(backend="pallas")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        EG.bind(params, pol)
        n1 = sum(issubclass(r.category, BackendFallbackWarning)
                 for r in rec)
        EG.bind(params, pol)
        n2 = sum(issubclass(r.category, BackendFallbackWarning)
                 for r in rec)
    assert n1 == 4          # one per site (c1, c2, fc1, fc2)
    assert n2 == 8          # the second bind warns again, not silently


def test_bind_strict_fails_loudly():
    """A serving config that requests a backend its policy can't run on
    must fail at bind, not drift onto the emulated path."""
    params = small.lenet_init(KEY)
    with pytest.raises(BackendUnsupportedError):
        EG.bind(params, EQ4.with_(backend="pallas"), strict=True)
    # non-strict: binds with the fallback recorded on the site
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        plan = EG.bind(params, EQ4.with_(backend="pallas"))
    assert plan.site("c1").fallback
    assert plan.site("c1").backend.name == "emulated"


def test_serve_engine_strict_backend():
    from repro.configs.base import reduced
    from repro.configs.registry import ARCHS
    from repro.models.lm import model as Mdl
    from repro.serve.engine import ServeEngine
    cfg = reduced(ARCHS["tinyllama-1.1b"])
    params = Mdl.init_params(cfg, KEY)
    with pytest.raises(BackendUnsupportedError):
        ServeEngine(params, cfg, slots=1, max_len=32,
                    policy=EQ4.with_(backend="pallas"),
                    strict_backend=True)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        eng = ServeEngine(params, cfg, slots=1, max_len=32, policy=EQ4)
    assert "attn/wq" in eng.plan.sites        # bound at admission time
    assert eng.plan.site("attn/wq").policy == EQ4


# ---------------------------------------------------------------------------
# PolicyMap edge cases + bind-time validation (satellite 3)
# ---------------------------------------------------------------------------

def test_policy_map_first_match_wins_on_overlap():
    p6 = BFPPolicy(l_w=6, l_i=6)
    p8 = BFPPolicy(l_w=8, l_i=8)
    pm = PolicyMap.of(("conv", p6), ("conv1", p8), default=None)
    assert pm.resolve("conv1_1") == p6        # both match; FIRST wins
    pm2 = PolicyMap.of(("conv1", p8), ("conv", p6), default=None)
    assert pm2.resolve("conv1_1") == p8       # order flipped, winner flips


def test_policy_map_none_path_resolution():
    p8 = BFPPolicy(l_w=8, l_i=8)
    pm = PolicyMap.of((".*", None), default=p8)
    # a None path never matches rules (even match-anything ones): default
    assert pm.resolve(None) == p8
    assert EG.resolve_policy(pm, None) == p8


def test_unknown_backend_in_rule_raises_at_bind_not_forward():
    """Even a rule that matches NO site must be validated at bind."""
    params = small.lenet_init(KEY)
    pm = PolicyMap.of(("^never_matches$", EQ4.with_(backend="cuda")),
                      default=EQ4)
    with pytest.raises(KeyError, match="unknown BFP backend"):
        EG.bind(params, pm)


# ---------------------------------------------------------------------------
# conv2d policy-None registry routing (satellite 2)
# ---------------------------------------------------------------------------

def test_conv_policy_none_consults_registered_float_backend():
    """A re-registered float backend with a fused conv must be used for
    policy-None convs (same extension point engine.gemm documents)."""
    x = jax.random.normal(KEY, (1, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5)) * 0.1
    calls = []
    orig = EG.get_backend("float")

    def my_conv(x, w, pol, stride, padding, key=None):
        calls.append((stride, padding))
        return EG.conv2d_im2col(x, w, pol, stride, padding, key)

    EG.register_backend("float", orig.matmul, orig.supports,
                        conv=my_conv,
                        conv_supports=lambda pol, w, s, p: True)
    try:
        out = EG.conv2d(x, w, None, stride=2, padding="VALID")
        assert calls == [(2, "VALID")], \
            "policy=None conv must dispatch via the float backend's conv"
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(EG.conv2d_im2col(x, w, None, 2, "VALID")),
            rtol=1e-6, atol=1e-6)
    finally:
        EG.register_backend("float", orig.matmul, orig.supports,
                            conv=orig.conv,
                            conv_supports=orig.conv_supports)


# ---------------------------------------------------------------------------
# taps
# ---------------------------------------------------------------------------

def test_taps_observe_every_site_in_order():
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    events = []
    with EG.taps(events.append):
        small.lenet_apply(params, x, EQ4)
    assert [(e.path, e.kind) for e in events] == \
        [("c1", "conv"), ("c2", "conv"), ("fc1", "gemm"), ("fc2", "gemm")]
    assert all(e.backend == "emulated" for e in events)
    assert events[0].stride == 1 and events[0].padding == "SAME"
    assert events[0].y.shape == (2, 28, 28, 16)
    assert all(e.y_float is None for e in events)   # not requested
    assert events[0].policy == EQ4


def test_taps_fire_through_bound_plans():
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    plan = EG.bind(params, EQ4, prequantize=False)
    events = []
    with EG.taps(events.append):
        small.lenet_apply(plan.params, x, plan)
    assert [e.path for e in events] == ["c1", "c2", "fc1", "fc2"]


def test_taps_suppressed_under_jit():
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    events = []
    with EG.taps(events.append):
        jax.jit(lambda p, xx: small.lenet_apply(p, xx, EQ4))(params, x)
    assert events == []   # tracers never leak into taps


def test_taps_want_float_reference():
    x = jax.random.normal(KEY, (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16)) * 0.1
    events = []
    with EG.taps(events.append, want_float=True):
        EG.gemm(x, w, EQ4, path="g0")
    (ev,) = events
    np.testing.assert_array_equal(np.asarray(ev.y_float), np.asarray(x @ w))
    assert float(jnp.linalg.norm(ev.y - ev.y_float)) > 0   # BFP y differs


def test_taps_no_double_fire_on_im2col_route():
    """A conv lowered to im2col+GEMM emits ONE conv event, no gemm."""
    x = jax.random.normal(KEY, (1, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5)) * 0.1
    events = []
    with EG.taps(events.append):
        EG.conv2d(x, w, EQ4, path="conv0")   # emulated: im2col route
    assert [(e.path, e.kind) for e in events] == [("conv0", "conv")]
