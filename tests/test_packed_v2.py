"""Variable-width PackedBFP (v3 container) + precision search (ISSUE 10).

Adversarial property suite for the self-describing per-block width
header: lossless round trips across schemes x L 4-12 x odd shapes,
adversarial blocks (all-zero, single max-magnitude element, sign-only
mantissas, exponents at the int8 extremes), exact ``nbytes`` accounting,
and typed :class:`~repro.core.packed.IntegrityError` on width-header
corruption/truncation naming the byte offset.  Back-compat: hand-crafted
v1 bytes and fixed-L v2 containers restore bit-identically under the new
reader, and the ``bfp_packed_v2`` vgg16-reduced checkpoint serves logits
BIT-identical to the float path (extends the PR 5 pin in
tests/test_packed.py).  Plus the ``repro.tune.precision`` search
contract: determinism, per-site measured NSR within budget and fresh NSR
within the analytic bound, and a typed error on unsatisfiable budgets.

Generated sweeps (200+ cases per property) are ``@pytest.mark.slow``;
every point regression stays in the fast profile.
"""
import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: deterministic fallback sampler
    from _hypothesis_stub import given, settings, strategies as st

from repro import engine as EG
from repro.checkpoint import store
from repro.core import bfp, packed
from repro.core.bfp import BFPBlock, Scheme
from repro.core.policy import TPU_TILED
from repro.dist import compress
from repro.engine import PolicyMap
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine
from repro.tune.precision import PrecisionSearchError, search_precision

KEY = jax.random.PRNGKey(0)
POL = TPU_TILED.with_(block_k=None, straight_through=False)

#: ISSUE 10 acceptance: 200+ generated cases per property
N_EXAMPLES = 200


def _same_block(a: BFPBlock, b: BFPBlock) -> None:
    assert a.bits == b.bits
    assert a.mantissa.dtype == b.mantissa.dtype
    np.testing.assert_array_equal(np.asarray(a.mantissa),
                                  np.asarray(b.mantissa))
    np.testing.assert_array_equal(np.asarray(a.exponent),
                                  np.asarray(b.exponent))


def _width_plane_off(p: packed.PackedBFP) -> int:
    """Byte offset of the v3 width plane inside ``p.to_bytes()``."""
    meta_len = len(json.dumps(p.meta).encode())
    return (packed._FIXED_HEADER
            + 4 * (len(p.shape) + len(p.exp_shape))
            + meta_len + p.exponents.size)


# ---------------------------------------------------------------------------
# Adversarial blocks (fast)
# ---------------------------------------------------------------------------

def test_all_zero_leaf_packs_at_minimal_width():
    blk = bfp.quantize(jnp.zeros((4, 32)), 8, (1,))
    p = packed.pack_block(blk, variable=True)
    assert p.variable
    assert p.widths.shape == p.exp_shape
    assert int(p.widths.max()) == 1            # 1 bit/element, not 8
    assert len(p.payload) == -(-4 * 32 * 1 // 8)
    _same_block(blk, packed.unpack_block(
        packed.PackedBFP.from_bytes(p.to_bytes())))


def test_single_max_magnitude_element_widens_only_its_block():
    m = np.zeros((2, 16), np.int8)
    m[0, 3] = 127                              # one saturated element
    blk = BFPBlock(mantissa=jnp.asarray(m),
                   exponent=jnp.zeros((2, 1), jnp.int32), bits=8)
    p = packed.pack_block(blk, variable=True)
    assert p.widths.reshape(-1).tolist() == [8, 1]
    assert len(p.payload) == -(-(16 * 8 + 16 * 1) // 8)
    _same_block(blk, packed.unpack_block(
        packed.PackedBFP.from_bytes(p.to_bytes())))


def test_sign_only_mantissas_take_two_bits():
    m = np.array([[-1, 1, 0, -1], [1, 1, -1, 0]], np.int8)
    blk = BFPBlock(mantissa=jnp.asarray(m),
                   exponent=jnp.zeros((2, 1), jnp.int32), bits=8)
    p = packed.pack_block(blk, variable=True)
    assert int(p.widths.max()) == 2            # sign + 1 magnitude bit
    _same_block(blk, packed.unpack_block(
        packed.PackedBFP.from_bytes(p.to_bytes())))


def test_exponents_at_int8_extremes_roundtrip():
    m = np.array([[3, -7], [100, 1]], np.int8)
    blk = BFPBlock(mantissa=jnp.asarray(m),
                   exponent=jnp.asarray([[-128], [127]], jnp.int32), bits=8)
    p = packed.pack_block(blk, variable=True)
    q = packed.PackedBFP.from_bytes(p.to_bytes())
    assert q.exponents.reshape(-1).tolist() == [-128, 127]
    _same_block(blk, packed.unpack_block(q))
    # the prequant path hits the same extremes through its float32
    # power-of-two step sidecar (2^-134 is a subnormal f32; frexp on
    # float64 recovers the exponent exactly)
    s = np.ldexp(1.0, np.array([[-134], [121]])).astype(np.float32)
    d = {"m": jnp.asarray(m), "s": jnp.asarray(s)}
    pp = packed.pack_prequant(d, 8, variable=True)
    assert pp.exponents.reshape(-1).tolist() == [-128, 127]
    r = packed.unpack_prequant(packed.PackedBFP.from_bytes(pp.to_bytes()))
    assert r["m"].dtype == d["m"].dtype        # dtype follows container L
    np.testing.assert_array_equal(np.asarray(r["m"]), m)
    np.testing.assert_array_equal(np.asarray(r["s"]), s)


def test_nbytes_exactly_matches_byte_stream():
    for variable in (False, True):
        for shape, axes in (((3, 7), (1,)), ((5, 13), (0,)), ((1, 17), (1,))):
            blk = bfp.quantize(jax.random.normal(KEY, shape), 6, axes)
            p = packed.pack_block(blk, variable=variable)
            assert p.nbytes == len(p.to_bytes())
            q = packed.PackedBFP.from_bytes(p.to_bytes())
            assert q.nbytes == p.nbytes


# ---------------------------------------------------------------------------
# Width-header corruption / truncation -> typed IntegrityError (fast)
# ---------------------------------------------------------------------------

def _adversarial_container() -> packed.PackedBFP:
    m = np.zeros((2, 16), np.int8)
    m[0, 3] = 127                              # widths [8, 1]
    blk = BFPBlock(mantissa=jnp.asarray(m),
                   exponent=jnp.zeros((2, 1), jnp.int32), bits=8)
    return packed.pack_block(blk, variable=True)


def test_width_out_of_range_raises_integrity_error_naming_offset():
    p = _adversarial_container()
    off = _width_plane_off(p)
    for bad in (0, 200):                       # below 1 / above L=8
        buf = bytearray(p.to_bytes())
        buf[off + 1] = bad
        with pytest.raises(packed.IntegrityError,
                           match=rf"width plane corrupt: block 1 .*"
                                 rf"byte offset {off + 1}"):
            packed.PackedBFP.from_bytes(bytes(buf))


def test_width_plane_truncation_raises_integrity_error_naming_offset():
    p = _adversarial_container()
    off = _width_plane_off(p)
    with pytest.raises(packed.IntegrityError,
                       match=rf"width plane needs 2 bytes at offset {off}"):
        packed.PackedBFP.from_bytes(p.to_bytes()[:off + 1])


def test_bitstream_truncation_raises_integrity_error():
    p = _adversarial_container()
    with pytest.raises(packed.IntegrityError,
                       match="variable-width bitstream"):
        packed.PackedBFP.from_bytes(p.to_bytes()[:-1])


def test_in_range_width_corruption_caught():
    p = _adversarial_container()
    off = _width_plane_off(p)
    # widening a block's declared width starves the bitstream
    buf = bytearray(p.to_bytes())
    buf[off + 1] = 8
    with pytest.raises(packed.IntegrityError,
                       match="variable-width bitstream"):
        packed.PackedBFP.from_bytes(bytes(buf))
    # narrowing stays structurally plausible — the CRC catches it
    buf = bytearray(p.to_bytes())
    buf[off] = 1
    with pytest.raises(packed.IntegrityError, match="checksum mismatch"):
        packed.PackedBFP.from_bytes(bytes(buf))


def test_widths_validated_at_construction():
    p = _adversarial_container()
    with pytest.raises(ValueError, match="width plane shape"):
        packed.PackedBFP(bits=p.bits, shape=p.shape, exp_shape=p.exp_shape,
                         exponents=p.exponents, payload=p.payload,
                         meta=p.meta, widths=np.ones((3, 1), np.uint8))
    with pytest.raises(ValueError, match=r"outside the legal \[1, 8\]"):
        packed.PackedBFP(bits=p.bits, shape=p.shape, exp_shape=p.exp_shape,
                         exponents=p.exponents, payload=p.payload,
                         meta=p.meta, widths=np.full((2, 1), 9, np.uint8))


# ---------------------------------------------------------------------------
# Back-compat: v1 bytes and fixed-L v2 under the new reader (fast)
# ---------------------------------------------------------------------------

def _v1_bytes(p: packed.PackedBFP) -> bytes:
    """Hand-craft the pre-CRC v1 serialization of a fixed container (no
    v1 writer exists anymore — this is the archived layout)."""
    assert not p.variable
    meta_b = json.dumps(p.meta).encode()
    out = [b"BFPK", struct.pack("<BBBBI", 1, p.bits, len(p.shape),
                                len(p.exp_shape), len(meta_b))]
    for d in (*p.shape, *p.exp_shape):
        out.append(struct.pack("<I", d))
    out.append(meta_b)
    out.append(p.exponents.astype(np.int8).tobytes(order="C"))
    out.append(p.payload)
    return b"".join(out)


def test_v1_container_restores_bit_identically():
    blk = bfp.quantize(jax.random.normal(KEY, (6, 24)), 8, (1,))
    p = packed.pack_block(blk)
    q = packed.PackedBFP.from_bytes(_v1_bytes(p))
    assert q.stored_crc is None and not q.variable
    _same_block(blk, packed.unpack_block(q))


def test_fixed_width_data_still_writes_v2_bytes():
    # pre-existing fixed-L artifacts parse byte-identically because the
    # writer only emits version 3 when a width plane exists
    blk = bfp.quantize(jax.random.normal(KEY, (6, 24)), 8, (1,))
    buf = packed.pack_block(blk).to_bytes()
    assert buf[4] == packed._VERSION            # still version 2
    q = packed.PackedBFP.from_bytes(buf)
    assert not q.variable and q.widths is None
    _same_block(blk, packed.unpack_block(q))
    vbuf = packed.pack_block(blk, variable=True).to_bytes()
    assert vbuf[4] == packed._VERSION_VAR


# ---------------------------------------------------------------------------
# Checkpoint traffic (fast)
# ---------------------------------------------------------------------------

def test_mixed_fixed_and_variable_leaves_in_one_manifest():
    params = MODELS["lenet"].init(KEY)
    # pre-pack c1 as a FIXED container, then save the rest variable
    pre = PolicyMap.of(("^c1$", POL), default=None)
    tree = packed.pack_param_tree(params, pre, "cnn")
    rest = PolicyMap.of(("^c1$", None), default=POL)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, tree, format="bfp_packed_v2", policy=rest,
                   tree_kind="cnn")
        step_dir = os.path.join(d, "step_00000000")
        with open(os.path.join(step_dir, "manifest.json")) as f:
            man = json.load(f)
        assert man["format"] == "bfp_packed_v2"
        packed_dtypes = [man["dtypes"][i] for i in man["packed_leaves"]]
        assert "bfp_packed8" in packed_dtypes          # the fixed leaf
        assert "bfp_packed8v" in packed_dtypes         # variable leaves
        # both kinds restore to the exact sidecars a bind would produce
        got, step = store.restore(d, params)
    assert step == 0
    want = EG.prequantize_cnn(params, POL)
    for a, b in zip(jax.tree_util.tree_leaves(want),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_vgg16_reduced_v2_checkpoint_serves_bit_identical():
    """Extends the PR 5 pin: the VARIABLE-WIDTH checkpoint restores to
    the same sidecars, so served logits stay BIT-identical to the
    float-checkpoint path."""
    spec = MODELS["vgg16"]
    params = spec.init(KEY)
    img = jax.random.normal(jax.random.PRNGKey(1), spec.input_shape())
    with tempfile.TemporaryDirectory() as d:
        store.save(os.path.join(d, "f32"), 0, params)
        store.save(os.path.join(d, "var"), 0, params,
                   format="bfp_packed_v2", policy=POL, tree_kind="cnn")
        with open(os.path.join(d, "var", "step_00000000",
                               "manifest.json")) as f:
            assert json.load(f)["format"] == "bfp_packed_v2"
        p_f, _ = store.restore(os.path.join(d, "f32"), params)
        p_q, _ = store.restore(os.path.join(d, "var"), params)
    eng_f = CnnServeEngine(p_f, spec.apply, POL, slots=2, jit=False)
    eng_q = CnnServeEngine(p_q, spec.apply, POL, slots=2, jit=False)
    r_f = eng_f.submit(image=img)
    r_q = eng_q.submit(image=img)
    eng_f.run()
    eng_q.run()
    np.testing.assert_array_equal(r_f.logits, r_q.logits)


# ---------------------------------------------------------------------------
# Wire traffic (fast)
# ---------------------------------------------------------------------------

def test_wire_variable_container_roundtrips_crc_verified():
    g = jax.random.normal(KEY, (33, 7))
    p = compress.pack_leaf(g, 8, block=16, variable=True)
    assert p.variable
    want = compress.unpack_leaf(compress.pack_leaf(g, 8, block=16))
    got = compress.unpack_leaf(p.to_bytes())   # parse + CRC verify path
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    buf = bytearray(p.to_bytes())
    buf[-1] ^= 0xFF
    with pytest.raises(packed.IntegrityError):
        compress.unpack_leaf(bytes(buf))


def test_packed_allreduce_variable_matches_fixed():
    # same quantize -> mean path, so the reduced mean and residual are
    # identical; only the wire accounting (honest bytes) may differ
    grads = {"w": jax.random.normal(KEY, (4, 16, 8)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (4, 8))}
    zeros = jax.tree_util.tree_map(jnp.zeros_like, grads)
    m_f, r_f, by_f = compress.packed_allreduce(grads, zeros, bits=8,
                                               block=16)
    m_v, r_v, by_v = compress.packed_allreduce(grads, zeros, bits=8,
                                               block=16, variable=True)
    for a, b in zip(jax.tree_util.tree_leaves((m_f, r_f)),
                    jax.tree_util.tree_leaves((m_v, r_v))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert by_v > 0


def test_wire_report_variable_counts_real_bytes():
    tree = {"w": jax.random.normal(KEY, (256, 64))}
    rep_f = compress.wire_report(tree, bits=8, block=512)
    rep_v = compress.wire_report(tree, bits=8, block=512, variable=True)
    # dense Gaussian blocks saturate, so variable pays only the width
    # plane on top (one byte per block) — never more
    n_blocks = 256 * 64 // 512
    assert rep_f["wire_bytes"] < rep_v["wire_bytes"] \
        <= rep_f["wire_bytes"] + n_blocks


# ---------------------------------------------------------------------------
# Precision search (fast)
# ---------------------------------------------------------------------------

def test_precision_search_meets_budget_and_analytic_bounds():
    res = search_precision("lenet", seed=0, batch=4, nsr_budget=5e-3,
                           top1_tol=0.0)
    assert res.sites
    for s in res.sites:
        assert res.l_min <= s.l_w <= res.l_max
        assert s.nsr_measured <= res.nsr_budget
        assert s.nsr_fresh <= s.nsr_bound
        # the emitted map resolves each site to its chosen width
        assert res.policy_map.resolve(s.path).l_w == s.l_w
    assert res.top1_agreement >= 1.0 - res.top1_tol
    # the report round-trips through plain data (the --policy-out file)
    assert PolicyMap.from_dict(res.policy_map.to_dict()) == res.policy_map
    assert json.loads(json.dumps(res.to_dict())) == res.to_dict()


def test_precision_search_deterministic():
    a = search_precision("lenet", seed=0, batch=4, nsr_budget=5e-3)
    b = search_precision("lenet", seed=0, batch=4, nsr_budget=5e-3)
    assert a.assignment == b.assignment
    assert a.policy_map == b.policy_map
    assert a.to_dict() == b.to_dict()


def test_precision_search_unsatisfiable_budget_raises_typed_error():
    with pytest.raises(PrecisionSearchError, match="unsatisfiable"):
        search_precision("lenet", seed=0, batch=2, nsr_budget=0.0)


def test_precision_search_rejects_bad_arguments():
    with pytest.raises(ValueError, match="unknown model"):
        search_precision("nope")
    with pytest.raises(ValueError, match="l_min"):
        search_precision("lenet", l_min=9, l_max=8)
    with pytest.raises(ValueError, match="nsr_budget"):
        search_precision("lenet", nsr_budget=-1.0)


# ---------------------------------------------------------------------------
# Fast-profile collection guard (satellite: CI smoke)
# ---------------------------------------------------------------------------

def test_fast_profile_collects_this_suite():
    """CI's pack-smoke job runs ``-m "not slow"`` on this file; a stray
    module-level slow mark would silently drop every regression above
    (pytest would exit 5 on empty collection — this guards the intent
    in-suite too)."""
    import sys
    mod = sys.modules[__name__]
    marks = getattr(mod, "pytestmark", [])
    marks = marks if isinstance(marks, list) else [marks]
    assert not any(getattr(m, "name", "") == "slow" for m in marks)


# ---------------------------------------------------------------------------
# Generated sweeps (slow profile): 200+ cases per property
# ---------------------------------------------------------------------------

_SHAPES = ((3, 7), (5, 13), (1, 17), (16, 16), (7, 1), (2, 63), (31, 2))
_SCHEMES = (Scheme.EQ2, Scheme.EQ3, Scheme.EQ4, Scheme.EQ5, Scheme.TILED)


@pytest.mark.slow
@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=st.integers(4, 12), si=st.integers(0, len(_SHAPES) - 1),
       ci=st.integers(0, len(_SCHEMES) - 1), seed=st.integers(0, 10_000),
       operand=st.sampled_from(["w", "i"]))
def test_variable_roundtrip_lossless_across_schemes(bits, si, ci, seed,
                                                    operand):
    w = jax.random.normal(jax.random.PRNGKey(seed), _SHAPES[si])
    blk = bfp.bfp_quantize_matrix(w, bits, operand, _SCHEMES[ci])
    p = packed.pack_block(blk, variable=True)
    buf = p.to_bytes()
    assert p.nbytes == len(buf)
    q = packed.PackedBFP.from_bytes(buf)
    assert q.nbytes == len(buf)
    _same_block(blk, packed.unpack_block(q))


@pytest.mark.slow
@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10_000), bits=st.integers(4, 12),
       tenths=st.integers(0, 10))
def test_variable_bytes_bounded_and_sparsity_shrinks(seed, bits, tenths):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((24, 32)).astype(np.float32)
    w[rng.random((24, 32)) < tenths / 10] = 0.0
    blk = bfp.quantize(jnp.asarray(w), bits, (1,))
    pf = packed.pack_block(blk)
    pv = packed.pack_block(blk, variable=True)
    # widths never exceed L, so the only possible overhead is the width
    # plane itself (one byte per block)
    assert len(pv.payload) <= len(pf.payload)
    assert pv.nbytes <= pf.nbytes + pv.exponents.size
    if tenths == 10:
        assert int(pv.widths.max()) == 1
    _same_block(packed.unpack_block(pf), packed.unpack_block(pv))


@pytest.mark.slow
@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10_000), cut=st.integers(0, 1 << 30))
def test_any_truncation_raises(seed, cut):
    w = jax.random.normal(jax.random.PRNGKey(seed), (6, 24))
    p = packed.pack_matrix(w, 8, "w", Scheme.EQ2, variable=True)
    buf = p.to_bytes()
    k = 1 + cut % (len(buf) - 1)               # any strict prefix
    with pytest.raises(ValueError):            # IntegrityError included
        packed.PackedBFP.from_bytes(buf[:k])


@pytest.mark.slow
@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 10_000), pos=st.integers(0, 1 << 30),
       flip=st.integers(1, 255))
def test_any_plane_or_payload_corruption_raises_integrity_error(seed, pos,
                                                                flip):
    w = jax.random.normal(jax.random.PRNGKey(seed), (6, 24))
    p = packed.pack_matrix(w, 8, "w", Scheme.EQ2, variable=True)
    buf = bytearray(p.to_bytes())
    start = _width_plane_off(p) - p.exponents.size  # exponent plane on
    idx = start + pos % (len(buf) - start)
    buf[idx] ^= flip
    with pytest.raises(packed.IntegrityError):
        packed.PackedBFP.from_bytes(bytes(buf))
