"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Sweeps shapes/dtypes/bit-widths and asserts allclose (mostly bit-exact)
against ref.py, and triangulates against the core-library emulated path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: deterministic fallback sampler
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import BFPPolicy, Scheme
from repro.core.bfp_dot import bfp_matmul_2d
from repro.kernels import ops, ref
from repro.kernels.bfp_matmul import bfp_matmul_pallas
from repro.kernels.bfp_quantize import bfp_quantize_pallas


def _rand(key, shape, dtype, scale=1.0):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


@pytest.mark.parametrize("b,k,n", [(8, 128, 8), (128, 256, 128),
                                   (64, 512, 32), (256, 1024, 128)])
@pytest.mark.parametrize("bits", [4, 6, 8])
def test_matmul_kernel_matches_ref(b, k, n, bits):
    x = _rand(jax.random.PRNGKey(0), (b, k), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32, 0.1)
    bk = min(128, k)
    out_k = bfp_matmul_pallas(x, w, l_i=bits, l_w=bits, bm=min(128, b),
                              bn=min(128, n), bk=bk, interpret=True)
    out_r = ref.bfp_matmul_ref(x, w, bits, bits, bk)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_dtypes(dtype):
    x = _rand(jax.random.PRNGKey(2), (128, 256), dtype)
    w = _rand(jax.random.PRNGKey(3), (256, 128), dtype, 0.05)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out_k = ops.bfp_matmul(x, w, pol, interpret=True)
    out_r = ref.bfp_matmul_ref(x.astype(jnp.float32),
                               w.astype(jnp.float32), 8, 8, 128)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_matmul_kernel_matches_core_library():
    x = _rand(jax.random.PRNGKey(4), (128, 512), jnp.float32, 4.0)
    w = _rand(jax.random.PRNGKey(5), (512, 128), jnp.float32, 0.2)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out_k = ops.bfp_matmul(x, w, pol, interpret=True)
    out_c = bfp_matmul_2d(x, w, pol)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_c),
                               rtol=1e-6, atol=1e-6)


def test_matmul_kernel_ragged_padding():
    """Non-multiple shapes go through ops.py padding and stay exact."""
    x = _rand(jax.random.PRNGKey(6), (100, 300), jnp.float32)
    w = _rand(jax.random.PRNGKey(7), (300, 70), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out = ops.bfp_matmul(x, w, pol, interpret=True)
    assert out.shape == (100, 70)
    xp = jnp.pad(x, ((0, 28), (0, 84)))
    wp = jnp.pad(w, ((0, 84), (0, 58)))
    out_r = ref.bfp_matmul_ref(xp, wp, 8, 8, 128)[:100, :70]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_matmul_kernel_accuracy_vs_float():
    """BFP-8 GEMM should be within ~2% relative error of the float GEMM."""
    x = _rand(jax.random.PRNGKey(8), (256, 512), jnp.float32)
    w = _rand(jax.random.PRNGKey(9), (512, 256), jnp.float32, 0.05)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out = ops.bfp_matmul(x, w, pol, interpret=True)
    rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.02, rel


def test_matmul_kernel_overflow_guard():
    x = jnp.ones((128, 65536 * 2), jnp.float32)
    w = jnp.ones((65536 * 2, 128), jnp.float32)
    with pytest.raises(ValueError, match="overflow"):
        bfp_matmul_pallas(x, w, l_i=8, l_w=8, bk=65536 * 2, interpret=True)


@pytest.mark.parametrize("m,k,bk", [(256, 512, 128), (8, 128, 128),
                                    (256, 2048, 512)])
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_kernel_matches_ref(m, k, bk, bits):
    x = _rand(jax.random.PRNGKey(10), (m, k), jnp.float32, 3.0)
    mq, eq = bfp_quantize_pallas(x, bits=bits, bm=min(256, m), bk=bk,
                                 interpret=True)
    mr, er = ref.bfp_quantize_ref(x, bits, bk)
    np.testing.assert_array_equal(np.asarray(mq), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(eq), np.asarray(er))


def test_quantize_kernel_zero_block():
    x = jnp.zeros((8, 128), jnp.float32)
    mq, eq = bfp_quantize_pallas(x, bits=8, bm=8, bk=128, interpret=True)
    assert int(jnp.max(jnp.abs(mq))) == 0


@pytest.mark.parametrize("b,k,n", [(1, 32, 1), (100, 300, 70), (7, 129, 9),
                                   (130, 512, 200)])
def test_default_tiles_align_odd_shapes(b, k, n):
    """Tiles are power-of-two, capped at the MXU dim, and divide the
    padded problem; auto-bk respects the int32 overflow bound."""
    bm, bn, bk = ops.default_tiles(b, k, n, None)
    for tile in (bm, bn, bk):
        assert tile & (tile - 1) == 0 and tile >= 8
    assert bm <= 128 and bn <= 128
    assert (-b % bm) < bm and (-n % bn) < bn     # padding < one tile
    # overflow cap: auto bk must be accumulation-safe for wide mantissas
    _, _, bk24 = ops.default_tiles(b, k, n, None, l_sum=24)
    assert bk24 <= 2 ** (32 - 24)


@pytest.mark.parametrize("b,k,n", [(1, 32, 1), (100, 300, 70), (7, 129, 9)])
def test_matmul_kernel_odd_shapes_match_ref(b, k, n):
    """Odd/padded shapes through ops.bfp_matmul stay exact vs the oracle
    run on the identically padded problem."""
    x = _rand(jax.random.PRNGKey(20), (b, k), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(21), (k, n), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=None,
                    straight_through=False)
    out = ops.bfp_matmul(x, w, pol, interpret=True)
    assert out.shape == (b, n)
    bm, bn, bk = ops.default_tiles(b, k, n, None)
    xp = jnp.pad(x, ((0, -b % bm), (0, -k % bk)))
    wp = jnp.pad(w, ((0, -k % bk), (0, -n % bn)))
    out_r = ref.bfp_matmul_ref(xp, wp, 8, 8, bk)[:b, :n]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b,n", [(100, 70), (8, 8), (1, 200)])
def test_prequant_kernel_matches_fused(b, n):
    """The sidecar-consuming kernel == the fused kernel, bit for bit,
    including B/N padding paths."""
    from repro.core.bfp_dot import bfp_matmul_2d
    from repro.core.prequant import prequant_leaf
    k = 256
    x = _rand(jax.random.PRNGKey(22), (b, k), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(23), (k, n), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    pq = prequant_leaf(w, pol)
    out_pq = ops.bfp_matmul_prequant(x, pq["m"], pq["s"], pol,
                                     interpret=True)
    out_fused = ops.bfp_matmul(x, w, pol, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_pq), np.asarray(out_fused))
    # and both equal the emulated core datapath
    np.testing.assert_allclose(np.asarray(out_pq),
                               np.asarray(bfp_matmul_2d(x, w, pol)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([8, 16, 64]),
    kt=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([8, 32, 128]),
    bits=st.integers(min_value=3, max_value=9),
    scale_pow=st.integers(min_value=-8, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_kernel_property(b, kt, n, bits, scale_pow, seed):
    """Property: kernel == oracle for random shapes/bits/dynamic ranges."""
    bk = 128
    k = kt * bk
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, k)) * (2.0 ** scale_pow)
    w = jax.random.normal(kw, (k, n))
    out_k = bfp_matmul_pallas(x, w, l_i=bits, l_w=bits, bm=min(128, b),
                              bn=min(128, n), bk=bk, interpret=True)
    out_r = ref.bfp_matmul_ref(x, w, bits, bits, bk)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-30)

# ---------------------------------------------------------------------------
# ISSUE 6 — dot-mode datapaths, pipelining, fused requantize epilogue
# ---------------------------------------------------------------------------

from repro.core.prequant import dequantize_act, is_prequant, prequant_act  # noqa: E402
from repro.kernels.bfp_matmul import f32_dot_exact, resolve_dot_impl  # noqa: E402


@pytest.mark.parametrize("dot_impl", ["int8", "int32", "f32"])
@pytest.mark.parametrize("pipeline", [False, True])
def test_matmul_dot_modes_bit_identical(dot_impl, pipeline):
    """Every dot datapath x pipelining matches the oracle AND the legacy
    int32/unpipelined kernel bit for bit (f32 is exact at bk=128, L=8:
    128 * 127 * 127 < 2^24; int8 products widen to int32 in the MXU)."""
    x = _rand(jax.random.PRNGKey(30), (64, 384), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(31), (384, 48), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    out = ops.bfp_matmul(x, w, pol, True, dot_impl=dot_impl,
                         pipeline=pipeline)
    base = ops.bfp_matmul(x, w, pol, True, dot_impl="int32",
                          pipeline=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    out_r = ref.bfp_matmul_ref(x, w, 8, 8, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("li,lw", [(4, 4), (6, 8), (8, 6), (10, 10),
                                   (12, 12)])
def test_matmul_auto_dot_bitwidth_sweep(li, lw):
    """auto mode stays exact across L=4..12 (L > 8 forces the widened
    int32 path; the overflow cap 2^(32-L_I-L_W) still admits bk=128)."""
    x = _rand(jax.random.PRNGKey(32), (32, 256), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(33), (256, 24), jnp.float32, 0.1)
    pol = BFPPolicy(l_i=li, l_w=lw, scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    out = ops.bfp_matmul(x, w, pol, True)
    out_r = ref.bfp_matmul_ref(x, w, li, lw, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_resolve_dot_impl_rules():
    """Mode resolution: auto picks the exact-f32 BLAS path on interpret
    within the 2^24 bound, int32 past it or for wide mantissas, int8 on
    a compiled target; explicit modes validate their preconditions."""
    assert f32_dot_exact(8, 8, 128) and f32_dot_exact(8, 8, 1024)
    assert not f32_dot_exact(8, 8, 2048)
    assert resolve_dot_impl("auto", l_i=8, l_w=8, bk=128,
                            interpret=True) == "f32"
    assert resolve_dot_impl("auto", l_i=8, l_w=8, bk=2048,
                            interpret=True) == "int32"
    assert resolve_dot_impl("auto", l_i=10, l_w=8, bk=128,
                            interpret=True) == "int32"
    assert resolve_dot_impl("auto", l_i=8, l_w=8, bk=128,
                            interpret=False) == "int8"
    # prequant operands are int8 on the wire whatever the stated L
    assert resolve_dot_impl("auto", l_i=12, l_w=12, bk=128,
                            interpret=False, x_pq=True, w_pq=True) == "int8"
    with pytest.raises(ValueError, match="int8"):
        resolve_dot_impl("int8", l_i=10, l_w=8, bk=128, interpret=True)
    with pytest.raises(ValueError, match="not exact"):
        resolve_dot_impl("f32", l_i=12, l_w=12, bk=128, interpret=True)
    with pytest.raises(ValueError, match="unknown"):
        resolve_dot_impl("fp8", l_i=8, l_w=8, bk=128, interpret=True)


@pytest.mark.parametrize("tiles", [(8, 8, 128), (32, 64, 128),
                                   (128, 128, 128)])
def test_matmul_tiles_are_performance_only(tiles):
    """With block_k pinned, (bm, bn) tiling must never change a bit —
    the invariant that makes the autotuner safe to trust blindly."""
    x = _rand(jax.random.PRNGKey(34), (96, 256), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(35), (256, 80), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    base = ops.bfp_matmul(x, w, pol, True)
    out = ops.bfp_matmul(x, w, pol, True, tiles=tiles)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("bq,n", [(8, 64), (16, 48), (8, 72)])
def test_matmul_epilogue_requant_bit_identical(pipeline, bq, n):
    """Fused epilogue requantization == dequantize-then-prequant_act,
    bit for bit, across out-block sizes and an N the default bn does
    not divide (which exercises the two-step fallback inside ops)."""
    x = _rand(jax.random.PRNGKey(36), (64, 256), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(37), (256, n), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    out_pol = pol.with_(block_k=bq)
    fused = ops.bfp_matmul(x, w, pol, True, out_policy=out_pol,
                           pipeline=pipeline)
    two = prequant_act(ops.bfp_matmul(x, w, pol, True, pipeline=pipeline),
                       out_pol)
    assert is_prequant(fused) and fused["m"].dtype == jnp.int8
    assert fused["m"].shape == (64, n)
    assert fused["s"].shape == (64, n // bq)
    np.testing.assert_array_equal(np.asarray(fused["m"]),
                                  np.asarray(two["m"]))
    np.testing.assert_array_equal(np.asarray(fused["s"]),
                                  np.asarray(two["s"]))


def test_matmul_act_dict_input_bit_identical():
    """int8 wire-format activations consumed natively == dequantize +
    inline re-quantization (idempotence on matching blocks) — the
    layer-to-layer handoff contract."""
    x = _rand(jax.random.PRNGKey(38), (48, 256), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(39), (256, 32), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    xq = prequant_act(x, pol)
    assert is_prequant(xq) and xq["m"].dtype == jnp.int8
    out_d = ops.bfp_matmul(xq, w, pol, True)
    out_f = ops.bfp_matmul(dequantize_act(xq), w, pol, True)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_f))


def test_matmul_epilogue_then_consume_chain():
    """gemm -> gemm entirely on the wire format: the fused-epilogue
    output feeds the next kernel directly and lands bit-identical to
    the all-float-activation chain with inline quantization."""
    x = _rand(jax.random.PRNGKey(40), (32, 256), jnp.float32, 2.0)
    w1 = _rand(jax.random.PRNGKey(41), (256, 128), jnp.float32, 0.1)
    w2 = _rand(jax.random.PRNGKey(42), (128, 16), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    y1 = ops.bfp_matmul(x, w1, pol, True, out_policy=pol)
    out = ops.bfp_matmul(y1, w2, pol, True)
    y1_f = ops.bfp_matmul(x, w1, pol, True)
    out_ref = ops.bfp_matmul(y1_f, w2, pol, True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))
