"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Sweeps shapes/dtypes/bit-widths and asserts allclose (mostly bit-exact)
against ref.py, and triangulates against the core-library emulated path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: deterministic fallback sampler
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import BFPPolicy, Scheme
from repro.core.bfp_dot import bfp_matmul_2d
from repro.kernels import ops, ref
from repro.kernels.bfp_matmul import bfp_matmul_pallas
from repro.kernels.bfp_quantize import bfp_quantize_pallas


def _rand(key, shape, dtype, scale=1.0):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


@pytest.mark.parametrize("b,k,n", [(8, 128, 8), (128, 256, 128),
                                   (64, 512, 32), (256, 1024, 128)])
@pytest.mark.parametrize("bits", [4, 6, 8])
def test_matmul_kernel_matches_ref(b, k, n, bits):
    x = _rand(jax.random.PRNGKey(0), (b, k), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(1), (k, n), jnp.float32, 0.1)
    bk = min(128, k)
    out_k = bfp_matmul_pallas(x, w, l_i=bits, l_w=bits, bm=min(128, b),
                              bn=min(128, n), bk=bk, interpret=True)
    out_r = ref.bfp_matmul_ref(x, w, bits, bits, bk)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_dtypes(dtype):
    x = _rand(jax.random.PRNGKey(2), (128, 256), dtype)
    w = _rand(jax.random.PRNGKey(3), (256, 128), dtype, 0.05)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out_k = ops.bfp_matmul(x, w, pol, interpret=True)
    out_r = ref.bfp_matmul_ref(x.astype(jnp.float32),
                               w.astype(jnp.float32), 8, 8, 128)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_matmul_kernel_matches_core_library():
    x = _rand(jax.random.PRNGKey(4), (128, 512), jnp.float32, 4.0)
    w = _rand(jax.random.PRNGKey(5), (512, 128), jnp.float32, 0.2)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out_k = ops.bfp_matmul(x, w, pol, interpret=True)
    out_c = bfp_matmul_2d(x, w, pol)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_c),
                               rtol=1e-6, atol=1e-6)


def test_matmul_kernel_ragged_padding():
    """Non-multiple shapes go through ops.py padding and stay exact."""
    x = _rand(jax.random.PRNGKey(6), (100, 300), jnp.float32)
    w = _rand(jax.random.PRNGKey(7), (300, 70), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out = ops.bfp_matmul(x, w, pol, interpret=True)
    assert out.shape == (100, 70)
    xp = jnp.pad(x, ((0, 28), (0, 84)))
    wp = jnp.pad(w, ((0, 84), (0, 58)))
    out_r = ref.bfp_matmul_ref(xp, wp, 8, 8, 128)[:100, :70]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_matmul_kernel_accuracy_vs_float():
    """BFP-8 GEMM should be within ~2% relative error of the float GEMM."""
    x = _rand(jax.random.PRNGKey(8), (256, 512), jnp.float32)
    w = _rand(jax.random.PRNGKey(9), (512, 256), jnp.float32, 0.05)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
    out = ops.bfp_matmul(x, w, pol, interpret=True)
    rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.02, rel


def test_matmul_kernel_overflow_guard():
    x = jnp.ones((128, 65536 * 2), jnp.float32)
    w = jnp.ones((65536 * 2, 128), jnp.float32)
    with pytest.raises(ValueError, match="overflow"):
        bfp_matmul_pallas(x, w, l_i=8, l_w=8, bk=65536 * 2, interpret=True)


@pytest.mark.parametrize("m,k,bk", [(256, 512, 128), (8, 128, 128),
                                    (256, 2048, 512)])
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_kernel_matches_ref(m, k, bk, bits):
    x = _rand(jax.random.PRNGKey(10), (m, k), jnp.float32, 3.0)
    mq, eq = bfp_quantize_pallas(x, bits=bits, bm=min(256, m), bk=bk,
                                 interpret=True)
    mr, er = ref.bfp_quantize_ref(x, bits, bk)
    np.testing.assert_array_equal(np.asarray(mq), np.asarray(mr))
    np.testing.assert_array_equal(np.asarray(eq), np.asarray(er))


def test_quantize_kernel_zero_block():
    x = jnp.zeros((8, 128), jnp.float32)
    mq, eq = bfp_quantize_pallas(x, bits=8, bm=8, bk=128, interpret=True)
    assert int(jnp.max(jnp.abs(mq))) == 0


@pytest.mark.parametrize("b,k,n", [(1, 32, 1), (100, 300, 70), (7, 129, 9),
                                   (130, 512, 200)])
def test_default_tiles_align_odd_shapes(b, k, n):
    """Tiles are power-of-two, capped at the MXU dim, and divide the
    padded problem; auto-bk respects the int32 overflow bound."""
    bm, bn, bk = ops.default_tiles(b, k, n, None)
    for tile in (bm, bn, bk):
        assert tile & (tile - 1) == 0 and tile >= 8
    assert bm <= 128 and bn <= 128
    assert (-b % bm) < bm and (-n % bn) < bn     # padding < one tile
    # overflow cap: auto bk must be accumulation-safe for wide mantissas
    _, _, bk24 = ops.default_tiles(b, k, n, None, l_sum=24)
    assert bk24 <= 2 ** (32 - 24)


@pytest.mark.parametrize("b,k,n", [(1, 32, 1), (100, 300, 70), (7, 129, 9)])
def test_matmul_kernel_odd_shapes_match_ref(b, k, n):
    """Odd/padded shapes through ops.bfp_matmul stay exact vs the oracle
    run on the identically padded problem."""
    x = _rand(jax.random.PRNGKey(20), (b, k), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(21), (k, n), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=None,
                    straight_through=False)
    out = ops.bfp_matmul(x, w, pol, interpret=True)
    assert out.shape == (b, n)
    bm, bn, bk = ops.default_tiles(b, k, n, None)
    xp = jnp.pad(x, ((0, -b % bm), (0, -k % bk)))
    wp = jnp.pad(w, ((0, -k % bk), (0, -n % bn)))
    out_r = ref.bfp_matmul_ref(xp, wp, 8, 8, bk)[:b, :n]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b,n", [(100, 70), (8, 8), (1, 200)])
def test_prequant_kernel_matches_fused(b, n):
    """The sidecar-consuming kernel == the fused kernel, bit for bit,
    including B/N padding paths."""
    from repro.core.bfp_dot import bfp_matmul_2d
    from repro.core.prequant import prequant_leaf
    k = 256
    x = _rand(jax.random.PRNGKey(22), (b, k), jnp.float32, 2.0)
    w = _rand(jax.random.PRNGKey(23), (k, n), jnp.float32, 0.1)
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=128,
                    straight_through=False)
    pq = prequant_leaf(w, pol)
    out_pq = ops.bfp_matmul_prequant(x, pq["m"], pq["s"], pol,
                                     interpret=True)
    out_fused = ops.bfp_matmul(x, w, pol, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_pq), np.asarray(out_fused))
    # and both equal the emulated core datapath
    np.testing.assert_allclose(np.asarray(out_pq),
                               np.asarray(bfp_matmul_2d(x, w, pol)),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([8, 16, 64]),
    kt=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([8, 32, 128]),
    bits=st.integers(min_value=3, max_value=9),
    scale_pow=st.integers(min_value=-8, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_kernel_property(b, kt, n, bits, scale_pow, seed):
    """Property: kernel == oracle for random shapes/bits/dynamic ranges."""
    bk = 128
    k = kt * bk
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, k)) * (2.0 ** scale_pow)
    w = jax.random.normal(kw, (k, n))
    out_k = bfp_matmul_pallas(x, w, l_i=bits, l_w=bits, bm=min(128, b),
                              bn=min(128, n), bk=bk, interpret=True)
    out_r = ref.bfp_matmul_ref(x, w, bits, bits, bk)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-30)
