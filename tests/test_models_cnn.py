"""CNN zoo: shape/NaN smoke for every paper model + BFP accuracy behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BFPPolicy, PAPER_DEFAULT
from repro.models.cnn import analysis, googlenet, layers as L, resnet, small, vgg


KEY = jax.random.PRNGKey(0)


def test_im2col_matches_conv():
    """im2col + GEMM == lax.conv (the paper's matrix form is exact)."""
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    p = L.conv2d_init(jax.random.PRNGKey(1), 3, 5, 3, 3)
    out = L.conv2d(p, x, 1, "SAME", None)
    w_hwio = p["w"]
    ref = jax.lax.conv_general_dilated(
        x, w_hwio, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("model,init,apply,shape", [
    ("vgg", lambda k: vgg.init(k, 10, width_mult=0.125, input_hw=32,
                               fc_dim=64),
     vgg.apply, (2, 32, 32, 3)),
    ("resnet18", lambda k: resnet.init(k, 18, 10, width_mult=0.25),
     resnet.apply, (2, 32, 32, 3)),
    ("resnet50", lambda k: resnet.init(k, 50, 10, width_mult=0.125,
                                       stage_depths=(1, 1, 1, 1)),
     resnet.apply, (2, 32, 32, 3)),
    ("lenet", small.lenet_init, small.lenet_apply, (2, 28, 28, 1)),
    ("cifarnet", small.cifarnet_init, small.cifarnet_apply, (2, 32, 32, 3)),
])
def test_cnn_smoke(model, init, apply, shape):
    params = init(KEY)
    x = jax.random.normal(KEY, shape)
    for policy in (None, PAPER_DEFAULT.with_(straight_through=False)):
        out = apply(params, x, policy)
        assert out.shape == (2, 10)
        assert bool(jnp.all(jnp.isfinite(out))), (model, policy)


def test_googlenet_three_heads():
    params = googlenet.init(KEY, 10, width_mult=0.125)
    x = jax.random.normal(KEY, (2, 64, 64, 3))
    main, aux1, aux2 = googlenet.apply(params, x, PAPER_DEFAULT.with_(
        straight_through=False))
    for o in (main, aux1, aux2):   # the paper's loss1/loss2/loss3 columns
        assert o.shape == (2, 10) and bool(jnp.all(jnp.isfinite(o)))


def test_bfp_output_close_to_float():
    """8-bit BFP conv output stays within ~2% of float (paper Table 3)."""
    params = small.cifarnet_init(KEY)
    x = jax.random.normal(KEY, (4, 32, 32, 3))
    y_f = small.cifarnet_apply(params, x, None)
    y_q = small.cifarnet_apply(params, x,
                               PAPER_DEFAULT.with_(straight_through=False))
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
    # 6% bound: measured ~5.0% on this seed/jax version; the paper-level
    # claim is "a few percent", not a hard 5.0.
    assert rel < 0.06, rel


def test_vgg_table4_analysis():
    """Table-4 driver: measured output SNR within the paper envelope of the
    multi-layer model on a reduced VGG."""
    params = vgg.init(KEY, 10, width_mult=0.25, input_hw=32, fc_dim=64)
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    rows = analysis.analyze_vgg(params, x, BFPPolicy(), max_layers=6)
    assert len(rows) == 6
    for r in rows:
        assert abs(r.output_ex - r.output_multi) < 8.9, r
        # ReLU SNR-neutrality (paper §4.4, verified in their Table 4)
        assert abs(r.relu_ex - r.output_ex) < 1.5, r


#: analyze_vgg rows captured from the pre-tap sequential walker (ISSUE 3
#: regression pin): vgg.init(key0, 10, width_mult=0.25, input_hw=32,
#: fc_dim=64), x = normal(key0, (2, 32, 32, 3)), BFPPolicy(), 6 layers.
#: (name, input_ex, input_single, input_multi, weight_ex, weight_model,
#:  output_ex, output_single, output_multi, relu_ex)
_VGG_TABLE4_PINNED = [
    ("conv1_1", 40.763931, 40.605503, 40.605499, 42.482407, 42.360992,
     38.472313, 38.384842, 38.384842, 38.527714),
    ("conv1_2", 34.968494, 34.224194, 32.817474, 40.485310, 40.479164,
     34.013855, 33.300964, 32.130684, 34.210258),
    ("conv2_1", 34.818081, 38.807625, 31.283648, 40.169861, 40.205021,
     33.558323, 36.440056, 30.759815, 33.884216),
    ("conv2_2", 31.748373, 32.100552, 28.374634, 39.284000, 39.309986,
     31.136555, 31.344597, 28.037889, 31.770947),
    ("conv3_1", 32.387501, 39.799828, 27.758234, 38.809685, 38.865807,
     30.458771, 36.297459, 27.434103, 31.051081),
    ("conv3_2", 30.834774, 39.334789, 27.161533, 40.338593, 40.383537,
     29.402966, 36.817280, 26.959494, 29.332874),
]


def test_analyze_vgg_regression_pinned():
    """The tap-based analyze_vgg reproduces the pre-refactor walker's
    Table-4 rows (same params/input/policy) to float precision."""
    params = vgg.init(KEY, 10, width_mult=0.25, input_hw=32, fc_dim=64)
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    rows = analysis.analyze_vgg(params, x, BFPPolicy(), max_layers=6)
    assert len(rows) == len(_VGG_TABLE4_PINNED)
    for r, exp in zip(rows, _VGG_TABLE4_PINNED):
        assert r.name == exp[0]
        got = (r.input_ex, r.input_single, r.input_multi, r.weight_ex,
               r.weight_model, r.output_ex, r.output_single,
               r.output_multi, r.relu_ex)
        for g, e in zip(got, exp[1:]):
            assert abs(g - e) < 2e-3, (r.name, g, e)


def test_analyze_model_resnet18_within_envelope():
    """ISSUE 3 acceptance: measured-vs-predicted SNR on ResNet-18
    (residual/projection topology) within the paper's 8.9 dB bar."""
    params = resnet.init(KEY, 18, 10, width_mult=0.25,
                         stage_depths=(1, 1, 1, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    rows = analysis.analyze_model(resnet.apply, params, x, BFPPolicy())
    convs = [r for r in rows if r.kind == "conv"]
    assert len(convs) >= 8   # stem + blocks incl. projection shortcuts
    assert any("proj" in r.path for r in convs)
    for r in rows:
        assert abs(r.output_ex - r.output_multi) < 8.9, r


def test_analyze_model_googlenet_within_envelope():
    """ISSUE 3 acceptance: GoogLeNet inception branches + aux heads."""
    params = googlenet.init(KEY, 10, width_mult=0.125)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64, 3))
    rows = analysis.analyze_model(googlenet.apply, params, x, BFPPolicy())
    paths = {r.path for r in rows}
    # branch convs, aux-head sites, and the classifier all analyzed
    assert {"inc3a/b1", "inc3a/b3", "inc3a/b5", "inc3a/bp",
            "loss1/conv", "loss1/fc1", "fc"} <= paths
    for r in rows:
        assert abs(r.output_ex - r.output_multi) < 8.9, r


def test_analyze_model_policymap_skips_float_sites():
    """Sites a PolicyMap rule pins to float carry no quantization —
    they must not produce rows (and must not crash the traversal)."""
    from repro.engine import PolicyMap
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    pm = PolicyMap.of(("^c1$", None),
                      default=BFPPolicy(straight_through=False))
    rows = analysis.analyze_model(small.lenet_apply, params, x, pm)
    assert [r.path for r in rows] == ["c2", "fc1", "fc2"]


def test_analyze_model_rejects_prequant_params():
    from repro import engine as EG
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    pol = BFPPolicy(straight_through=False)
    pq = EG.prequantize_cnn(params, pol)
    with pytest.raises(ValueError, match="float weights"):
        analysis.analyze_model(small.lenet_apply, pq, x, pol)


def test_bit_width_monotonicity():
    """More mantissa bits -> output closer to float (paper Table 3 trend)."""
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (4, 28, 28, 1))
    y_f = small.lenet_apply(params, x, None)
    errs = []
    for bits in (4, 6, 8, 10):
        pol = BFPPolicy(l_w=bits, l_i=bits, straight_through=False)
        y_q = small.lenet_apply(params, x, pol)
        errs.append(float(jnp.linalg.norm(y_q - y_f)))
    assert errs[0] > errs[1] > errs[2] > errs[3]
