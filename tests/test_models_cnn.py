"""CNN zoo: shape/NaN smoke for every paper model + BFP accuracy behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BFPPolicy, PAPER_DEFAULT
from repro.core.bfp import Scheme
from repro.models.cnn import analysis, googlenet, layers as L, resnet, small, vgg


KEY = jax.random.PRNGKey(0)


def test_im2col_matches_conv():
    """im2col + GEMM == lax.conv (the paper's matrix form is exact)."""
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    p = L.conv2d_init(jax.random.PRNGKey(1), 3, 5, 3, 3)
    out = L.conv2d(p, x, 1, "SAME", None)
    w_hwio = p["w"]
    ref = jax.lax.conv_general_dilated(
        x, w_hwio, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("model,init,apply,shape", [
    ("vgg", lambda k: vgg.init(k, 10, width_mult=0.125, input_hw=32,
                               fc_dim=64),
     vgg.apply, (2, 32, 32, 3)),
    ("resnet18", lambda k: resnet.init(k, 18, 10, width_mult=0.25),
     resnet.apply, (2, 32, 32, 3)),
    ("resnet50", lambda k: resnet.init(k, 50, 10, width_mult=0.125,
                                       stage_depths=(1, 1, 1, 1)),
     resnet.apply, (2, 32, 32, 3)),
    ("lenet", small.lenet_init, small.lenet_apply, (2, 28, 28, 1)),
    ("cifarnet", small.cifarnet_init, small.cifarnet_apply, (2, 32, 32, 3)),
])
def test_cnn_smoke(model, init, apply, shape):
    params = init(KEY)
    x = jax.random.normal(KEY, shape)
    for policy in (None, PAPER_DEFAULT.with_(straight_through=False)):
        out = apply(params, x, policy)
        assert out.shape == (2, 10)
        assert bool(jnp.all(jnp.isfinite(out))), (model, policy)


def test_googlenet_three_heads():
    params = googlenet.init(KEY, 10, width_mult=0.125)
    x = jax.random.normal(KEY, (2, 64, 64, 3))
    main, aux1, aux2 = googlenet.apply(params, x, PAPER_DEFAULT.with_(
        straight_through=False))
    for o in (main, aux1, aux2):   # the paper's loss1/loss2/loss3 columns
        assert o.shape == (2, 10) and bool(jnp.all(jnp.isfinite(o)))


def test_bfp_output_close_to_float():
    """8-bit BFP conv output stays within ~2% of float (paper Table 3)."""
    params = small.cifarnet_init(KEY)
    x = jax.random.normal(KEY, (4, 32, 32, 3))
    y_f = small.cifarnet_apply(params, x, None)
    y_q = small.cifarnet_apply(params, x,
                               PAPER_DEFAULT.with_(straight_through=False))
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
    # 6% bound: measured ~5.0% on this seed/jax version; the paper-level
    # claim is "a few percent", not a hard 5.0.
    assert rel < 0.06, rel


def test_vgg_table4_analysis():
    """Table-4 driver: measured output SNR within the paper envelope of the
    multi-layer model on a reduced VGG."""
    params = vgg.init(KEY, 10, width_mult=0.25, input_hw=32, fc_dim=64)
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    rows = analysis.analyze_vgg(params, x, BFPPolicy(), max_layers=6)
    assert len(rows) == 6
    for r in rows:
        assert abs(r.output_ex - r.output_multi) < 8.9, r
        # ReLU SNR-neutrality (paper §4.4, verified in their Table 4)
        assert abs(r.relu_ex - r.output_ex) < 1.5, r


def test_bit_width_monotonicity():
    """More mantissa bits -> output closer to float (paper Table 3 trend)."""
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (4, 28, 28, 1))
    y_f = small.lenet_apply(params, x, None)
    errs = []
    for bits in (4, 6, 8, 10):
        pol = BFPPolicy(l_w=bits, l_i=bits, straight_through=False)
        y_q = small.lenet_apply(params, x, pol)
        errs.append(float(jnp.linalg.norm(y_q - y_f)))
    assert errs[0] > errs[1] > errs[2] > errs[3]
