"""End-to-end system behaviour: training convergence, fault tolerance
(checkpoint/restart, failure injection), serving, gradient compression.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.data.pipeline import LMBatchSpec, lm_batch, image_batch
from repro.dist.compress import make_compressor, quantize_leaf
from repro.optim import optimizers as opt
from repro.serve.engine import generate, ServeEngine, Request
from repro.train.loop import LoopConfig, run_training, _SimulatedFailure
from repro.train.step import init_state, make_train_step

KEY = jax.random.PRNGKey(0)
CFG = reduced(ARCHS["tinyllama-1.1b"], n_layers=2, d_model=64, d_ff=128,
              vocab=256)


def _spec():
    return LMBatchSpec(vocab_size=CFG.vocab_size, seq_len=64, global_batch=8)


def test_loss_decreases():
    """~40 steps on the synthetic pattern must cut the loss visibly."""
    state = init_state(CFG, KEY)
    step = jax.jit(make_train_step(CFG, opt.cosine_schedule(3e-3, 5, 60)))
    out = run_training(state, step, _spec(), LoopConfig(total_steps=40))
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first - 0.2, (first, last)


def test_data_pipeline_deterministic():
    a1, b1 = lm_batch(_spec(), 7)
    a2, b2 = lm_batch(_spec(), 7)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    a3, _ = lm_batch(_spec(), 8)
    assert not np.array_equal(np.asarray(a1), np.asarray(a3))


def test_checkpoint_roundtrip_and_resume():
    state = init_state(CFG, KEY)
    step = jax.jit(make_train_step(CFG))
    with tempfile.TemporaryDirectory() as d:
        run_training(state, step, _spec(),
                     LoopConfig(total_steps=10, ckpt_dir=d, ckpt_every=5))
        # resume continues from step 10, runs 5 more
        out2 = run_training(state, step, _spec(),
                            LoopConfig(total_steps=15, ckpt_dir=d,
                                       ckpt_every=5))
        assert len(out2["history"]) == 5
        assert store.latest_step(d) == 15


def test_failure_injection_and_recovery():
    """Crash mid-run, then resume from the last checkpoint (deliverable:
    fault tolerance)."""
    state = init_state(CFG, KEY)
    step = jax.jit(make_train_step(CFG))
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(_SimulatedFailure):
            run_training(state, step, _spec(),
                         LoopConfig(total_steps=20, ckpt_dir=d,
                                    ckpt_every=5, fail_at_step=12))
        resumed = store.latest_step(d)
        assert resumed is not None and resumed >= 10  # did not lose work
        out = run_training(state, step, _spec(),
                           LoopConfig(total_steps=20, ckpt_dir=d,
                                      ckpt_every=5))
        assert len(out["history"]) == 20 - resumed


def test_corrupt_checkpoint_skipped():
    state = init_state(CFG, KEY)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 5, state)
        store.save(d, 10, state)
        # corrupt the newest
        with open(os.path.join(d, "step_00000010", "arrays.npz"), "wb") as f:
            f.write(b"garbage")
        assert store.latest_step(d) == 5  # checksum catches it


def test_checkpoint_shape_mismatch_rejected():
    state = init_state(CFG, KEY)
    other = init_state(reduced(ARCHS["tinyllama-1.1b"], d_model=32,
                               vocab=256), KEY)
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 1, state)
        with pytest.raises(ValueError, match="mismatch"):
            store.restore(d, other)


def test_async_checkpointer():
    state = init_state(CFG, KEY)
    with tempfile.TemporaryDirectory() as d:
        ck = store.Checkpointer(d)
        ck.save_async(3, state)
        ck.wait()
        restored, s = store.restore(d, state)
        assert s == 3
        np.testing.assert_array_equal(
            np.asarray(restored.params["embed"]["e"]),
            np.asarray(state.params["embed"]["e"]))


def test_generate_deterministic_greedy():
    state = init_state(CFG, KEY)
    prompt = jnp.ones((2, 4), jnp.int32)
    t1 = generate(state.params, CFG, prompt, max_new=6)
    t2 = generate(state.params, CFG, prompt, max_new=6)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 6)


def test_serve_engine_continuous_batching():
    state = init_state(CFG, KEY)
    eng = ServeEngine(state.params, CFG, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
            for i in range(5)]   # more requests than slots
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(len(r.out) == 4 for r in done)


def test_serve_engine_staggered_requests_match_isolated():
    """Regression: a request admitted mid-flight must not clobber the
    cache rows of already-active slots (per-row-masked prefill), and
    slots at different positions must each decode at their OWN position
    (the old code used max(slot_pos) for everyone).  Greedy decoding, so
    each request's tokens must exactly match the same request served
    alone."""
    state = init_state(CFG, KEY)
    p1, p2 = [1, 2, 3], [7, 8]

    def solo(prompt, max_new):
        eng = ServeEngine(state.params, CFG, slots=1, max_len=64)
        r = Request(rid=0, prompt=prompt, max_new=max_new)
        eng.submit(r)
        eng.run()
        return list(r.out)

    ref1, ref2 = solo(p1, 6), solo(p2, 6)

    eng = ServeEngine(state.params, CFG, slots=2, max_len=64)
    r1 = Request(rid=1, prompt=p1, max_new=6)
    eng.submit(r1)
    eng.step()
    eng.step()                       # r1 is now 2 tokens ahead
    r2 = Request(rid=2, prompt=p2, max_new=6)
    eng.submit(r2)                   # staggered admission
    while eng.step():
        pass
    assert r1.done and r2.done
    assert r1.out == ref1, (r1.out, ref1)
    assert r2.out == ref2, (r2.out, ref2)


def test_serve_engine_slot_reuse_resets_recurrent_state():
    """Regression: recurrent families (ssm) read-modify-write their
    states, so a reused slot must be reset to pristine state at
    admission — otherwise the second request prefils from the first
    request's leftover state and its greedy tokens diverge."""
    cfg = reduced(ARCHS["rwkv6-3b"], n_layers=2, d_model=64, vocab=256)
    state = init_state(cfg, KEY)

    def solo(prompt):
        eng = ServeEngine(state.params, cfg, slots=1, max_len=64)
        r = Request(rid=0, prompt=prompt, max_new=4)
        eng.submit(r)
        eng.run()
        return list(r.out)

    ref2 = solo([5, 6])
    eng = ServeEngine(state.params, cfg, slots=1, max_len=64)
    r1 = Request(rid=1, prompt=[1, 2, 3], max_new=4)
    r2 = Request(rid=2, prompt=[5, 6], max_new=4)
    eng.submit(r1)
    eng.submit(r2)          # runs in the slot r1 vacates
    eng.run()
    assert r2.out == ref2, (r2.out, ref2)


def test_grad_compression_error_feedback():
    """BFP-compressed grads + error feedback: compressed-sum converges to
    the true sum over steps (unbiasedness, beyond-paper E9)."""
    g = jax.random.normal(KEY, (1024,)) * 0.01
    init_fn, transform = make_compressor(bits=4)
    residual = init_fn({"g": g})["g"]
    acc_q = jnp.zeros_like(g)
    for _ in range(50):
        out, res = transform({"g": g}, {"g": residual})
        residual = res["g"]
        acc_q = acc_q + out["g"]
    acc_true = 50 * g
    rel = float(jnp.linalg.norm(acc_q - acc_true) /
                jnp.linalg.norm(acc_true))
    assert rel < 0.02, rel


def test_quantize_leaf_traffic_model():
    """Round-trip error of the wire format ~ 8-bit BFP (4x traffic cut)."""
    g = jax.random.normal(KEY, (4096,))
    q = quantize_leaf(g, 8)
    snr = 10 * np.log10(float(jnp.sum(g ** 2) / jnp.sum((q - g) ** 2)))
    assert snr > 30  # ~6 dB/bit x (8-2) bits, minus block-max penalty


def test_train_with_compression_converges():
    state = init_state(CFG, KEY)
    init_fn, transform = make_compressor(bits=8)
    residual = [init_fn(state.params)]

    def grad_transform(grads):
        q, residual[0] = transform(grads, residual[0])
        return q

    step_c = make_train_step(CFG, opt.cosine_schedule(3e-3, 5, 60),
                             grad_transform=grad_transform)
    out = run_training(state, step_c, _spec(), LoopConfig(total_steps=30))
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first - 0.1


def test_grad_accumulation_equivalence():
    """grad_accum=2 over a 2x batch == single big-batch step (same loss)."""
    state = init_state(CFG, KEY)
    toks, targs = lm_batch(_spec(), 0)
    s1 = jax.jit(make_train_step(CFG))
    s2 = jax.jit(make_train_step(CFG, grad_accum=2))
    st1, m1 = s1(state, (toks, targs))
    st2, m2 = s2(state, (toks, targs))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        st1.params, st2.params)
    assert max(jax.tree_util.tree_leaves(d)) < 2e-3


def test_wsd_schedule_shape():
    f = opt.wsd_schedule(1.0, warmup=10, stable=20, decay=10)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(f(jnp.asarray(25))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(40))) < 0.02


def test_image_pipeline():
    imgs, labels, templates = image_batch(KEY, 10, 16, 28, 1)
    assert imgs.shape == (16, 28, 28, 1) and labels.shape == (16,)
    _, labels2, _ = image_batch(KEY, 10, 16, 28, 1, templates)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(labels2))
