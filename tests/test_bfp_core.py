"""Unit + property tests for the core BFP library (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: deterministic fallback sampler
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import bfp
from repro.core.bfp import Rounding, Scheme
from repro.core.bfp_dot import bfp_dot, bfp_matmul_2d
from repro.core.policy import BFPPolicy, PAPER_DEFAULT


def test_block_exponent_exact():
    x = jnp.asarray([[1.5, -3.0, 0.25, 7.9]])
    e = bfp.block_exponent(x, (1,))
    assert int(e[0, 0]) == 2  # floor(log2 7.9) = 2


def test_zero_block():
    b = bfp.quantize(jnp.zeros((4, 8)), 8, (1,))
    assert int(jnp.max(jnp.abs(b.mantissa))) == 0
    np.testing.assert_allclose(np.asarray(b.dequantize()), 0.0)


def test_quantize_error_bound():
    """|x - q(x)| <= step/2 for every element (round-off, paper eq. 1)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 10
    b = bfp.quantize(x, 8, (0, 1))
    step = float(b.scale.reshape(-1)[0])
    err = np.abs(np.asarray(b.dequantize() - x))
    assert err.max() <= step / 2 + 1e-9


def test_largest_element_representable():
    """The block max must survive quantization without clipping."""
    x = jnp.asarray([[100.0, 0.001]])
    b = bfp.quantize(x, 8, (1,))
    assert abs(float(b.dequantize()[0, 0]) - 100.0) / 100.0 < 0.01


def test_rounding_beats_truncation_bias():
    """Paper §3.1: truncation has a DC bias, rounding is ~zero-mean."""
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    br = bfp.quantize(x, 6, (1,), Rounding.ROUND)
    bt = bfp.quantize(x, 6, (1,), Rounding.TRUNCATE)
    bias_r = abs(float(jnp.mean(br.dequantize() - x)))
    bias_t = abs(float(jnp.mean(bt.dequantize() - x)))
    assert bias_t > 5 * bias_r


def test_stochastic_rounding_unbiased():
    x = jnp.full((1, 512), 0.3)
    keys = jax.random.split(jax.random.PRNGKey(2), 64)
    deq = jnp.stack([bfp.quantize(x, 4, (1,), Rounding.STOCHASTIC, k)
                     .dequantize() for k in keys])
    assert abs(float(jnp.mean(deq)) - 0.3) < 0.01


@pytest.mark.parametrize("scheme", list(Scheme))
def test_scheme_shapes(scheme):
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
    blk = bfp.bfp_quantize_matrix(w, 8, "w", scheme, block_k=16)
    assert blk.mantissa.shape == w.shape
    exp = {Scheme.EQ2: 1, Scheme.EQ3: 64, Scheme.EQ4: 64, Scheme.EQ5: 1,
           Scheme.TILED: 64 * 2}[scheme]  # 64 rows x (K=32)/(bk=16) tiles
    assert blk.exponent.size == exp


def test_scheme_accuracy_ordering():
    """Finer blocks never hurt: TILED >= EQ3 >= EQ4 >= EQ2 output SNR
    (activations with heavy dynamic range; paper Table 2 direction)."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (128, 256)) * \
        jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (128, 256)))
    w = jax.random.normal(jax.random.PRNGKey(6), (256, 64)) * 0.1
    ref = x @ w

    def snr(scheme, bk=None):
        p = BFPPolicy(scheme=scheme, block_k=bk, straight_through=False)
        y = bfp_dot(x, w, p)
        return 10 * np.log10(float(jnp.sum(ref**2) /
                                   jnp.sum((y - ref)**2)))

    s2, s4, s3 = snr(Scheme.EQ2), snr(Scheme.EQ4), snr(Scheme.EQ3)
    st = snr(Scheme.TILED, 32)
    assert s3 >= s4 - 0.5 and s4 >= s2 - 0.5
    assert st >= s3 - 0.5


def test_paper_worked_example():
    """Paper §3.4 numeric example: I block-formatted with eps_I = 2."""
    i_mat = jnp.asarray([[1.25 * 2 ** 0, 1.25 * 2 ** 0],
                         [1.25 * 2 ** 1, 1.25 * 2 ** 2]])
    b = bfp.quantize(i_mat, 4, (0, 1))  # L=4 incl sign ~ paper L_I=3 + sign
    assert int(b.exponent.reshape(-1)[0]) == 2
    # largest value 5.0 must be exact: 5 = 1.01b * 2^2
    assert float(b.dequantize()[1, 1]) == 5.0


def test_storage_accounting():
    # paper Table 1: eq4 stores 1 + M exponents
    assert bfp.num_block_exponents(Scheme.EQ4, m=64, k=9, n=50176) == 65
    assert bfp.num_block_exponents(Scheme.EQ2, m=64, k=9, n=50176) == 2
    assert bfp.num_block_exponents(Scheme.EQ3, m=64, k=9, n=50176) == 50240
    # avg bits: 8-bit mantissa(incl sign) + 8-bit exp over 512-block
    assert bfp.average_bits_per_element(8, 8, 512) == 8 + 8 / 512


def test_accumulator_sizing():
    # paper Fig. 2: L_W + L_I + ceil(log2 K)
    assert bfp.accumulator_bits(8, 8, 4608) == 16 + 13
    assert bfp.max_safe_k(8, 8) == 65536


def test_int_datapath_exactness():
    """The integer path must equal exact math on the dequantized operands
    (the fixed-point MACs add NO error beyond quantization, paper Fig. 2)."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (32, 128)) * 4
    w = jax.random.normal(jax.random.PRNGKey(8), (128, 16))
    p = PAPER_DEFAULT.with_(straight_through=False)
    from repro.core.bfp_dot import quantize_activations, quantize_weights
    xq = quantize_activations(x, p).dequantize().astype(jnp.float64 if False
                                                        else jnp.float32)
    wq = quantize_weights(w, p).dequantize()
    np.testing.assert_allclose(np.asarray(bfp_matmul_2d(x, w, p)),
                               np.asarray(xq) @ np.asarray(wq), rtol=1e-6)


def test_big_k_chunked_accumulation():
    """K beyond the int32-safe bound splits into exact chunks."""
    k = bfp.max_safe_k(8, 8) * 2 + 37
    x = jnp.ones((2, k)) * 0.5
    w = jnp.ones((k, 2)) * 0.5
    p = PAPER_DEFAULT.with_(straight_through=False)
    out = bfp_matmul_2d(x, w, p)
    ref = x @ w
    assert abs(float(out[0, 0] - ref[0, 0])) / float(ref[0, 0]) < 0.01


def test_ste_gradients():
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(10), (64, 8)) * 0.1

    def loss(w):
        return jnp.sum(bfp_dot(x, w, PAPER_DEFAULT) ** 2)

    g = jax.grad(loss)(w)
    assert g.shape == w.shape and bool(jnp.all(jnp.isfinite(g)))
    # STE grad should approximate the float grad
    gf = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(w)
    cos = float(jnp.sum(g * gf) /
                (jnp.linalg.norm(g) * jnp.linalg.norm(gf)))
    assert cos > 0.99


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(bits=st.integers(3, 12), scale_pow=st.integers(-10, 10),
       seed=st.integers(0, 2 ** 31 - 1))
def test_quantize_dequantize_property(bits, scale_pow, seed):
    """Relative matrix error bounded by 2^-(L-2) regardless of scale."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 64)) \
        * (2.0 ** scale_pow)
    b = bfp.quantize(x, bits, (1,))
    err = np.asarray(b.dequantize() - x)
    ref = np.abs(np.asarray(x)).max(axis=1)
    rel = np.abs(err).max(axis=1) / np.maximum(ref, 1e-30)
    assert rel.max() <= 2.0 ** -(bits - 2)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_scale_invariance_property(seed):
    """BFP is scale-invariant across powers of two (shared exponent)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 32))
    b1 = bfp.quantize(x, 8, (1,))
    b2 = bfp.quantize(x * 4.0, 8, (1,))
    np.testing.assert_array_equal(np.asarray(b1.mantissa),
                                  np.asarray(b2.mantissa))
    np.testing.assert_array_equal(np.asarray(b2.exponent),
                                  np.asarray(b1.exponent) + 2)
