"""Deterministic fallback for the optional ``hypothesis`` dependency.

The property tests prefer real hypothesis (``pip install -e .[test]``).
In minimal containers without it, this stub runs each property over a
fixed pseudo-random sample set (seeded, reproducible) so the properties
still execute instead of the whole module failing collection.  It covers
only the tiny strategy surface the suite uses: ``integers`` and
``sampled_from``.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 10
#: sanity ceiling — the property suites ask for 200+ generated cases per
#: property (ISSUE 4 acceptance) and the stub honors that; anything past
#: this cap is a typo, not a coverage request
_MAX_EXAMPLES_CAP = 2000


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


class strategies:  # noqa: N801 - mirrors the hypothesis module name
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 30) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: rng.choice(items))


def settings(**kwargs):
    max_examples = kwargs.get("max_examples", _DEFAULT_EXAMPLES)

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, not
        # the wrapped one (drawn arguments are not fixtures).
        def wrapper():
            # honor the requested max_examples (the property suites need
            # their full generated-case budget under the stub too)
            n = min(getattr(wrapper, "_stub_max_examples",
                            _DEFAULT_EXAMPLES), _MAX_EXAMPLES_CAP)
            rng = random.Random(0)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(**drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
