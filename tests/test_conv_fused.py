"""Fused implicit-im2col conv: kernel vs oracle vs im2col+GEMM (ISSUE 2).

Triangulation contract:
  * kernel == independent oracle (ref.bfp_conv2d_ref) over a
    stride x padding x odd-spatial grid;
  * kernel == materialized im2col + the fused GEMM kernel, BIT-identical
    (same TILED blocks, same K zero-padding, same fp32 accumulation
    order);
  * prequant (int8 HWIO mantissa + sidecar) == inline quantization,
    bit-identical, through both the raw ops and engine.conv2d;
  * engine.conv2d falls back honestly (paper schemes -> emulated im2col
    route) and resolves PolicyMap layer paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as EG
from repro.core import BFPPolicy, Scheme
from repro.core.conv_utils import conv_weight_matrix, im2col
from repro.core.prequant import prequant_conv_leaf
from repro.engine import PolicyMap
from repro.kernels import ops, ref
from repro.models.cnn import small

KEY = jax.random.PRNGKey(0)
EQ4 = BFPPolicy(straight_through=False)


def _case(h, w, c, oc, kh, kw, seed=0, xs=2.0):
    kx, kw_ = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (2, h, w, c)) * xs
    wk = jax.random.normal(kw_, (kh, kw, c, oc)) * 0.1
    return x, wk


def _tiled(bk, backend=None):
    return BFPPolicy(scheme=Scheme.TILED, block_k=bk,
                     straight_through=False, backend=backend)


# ---------------------------------------------------------------------------
# kernel vs oracle: stride x padding x odd-spatial grid (ISSUE 2 satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("h,w", [(8, 8), (7, 9)])
def test_conv_kernel_matches_oracle(stride, padding, h, w):
    x, wk = _case(h, w, 8, 10, 3, 3, seed=h * 10 + stride)
    pol = _tiled(24)          # 24 | 72 = kh*kw*C: no K padding
    out = ops.bfp_conv2d(x, wk, pol, stride, padding, interpret=True)
    out_r = ref.bfp_conv2d_ref(x, wk, 8, 8, 24, stride, padding)
    assert out.shape == out_r.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("kh,kw,bk", [(1, 1, 8), (5, 5, 32), (3, 3, 128)])
def test_conv_kernel_kernel_sizes_and_ragged_k(kh, kw, bk):
    """1x1 / 5x5 kernels and a block_k that does NOT divide K (the last
    block zero-pads, exactly like ops.bfp_matmul)."""
    x, wk = _case(9, 7, 6, 5, kh, kw, seed=kh)
    pol = _tiled(bk)
    out = ops.bfp_conv2d(x, wk, pol, 1, "SAME", interpret=True)
    out_r = ref.bfp_conv2d_ref(x, wk, 8, 8, bk, 1, "SAME")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID"), (2, "VALID")])
def test_fused_bitidentical_to_im2col_gemm(stride, padding):
    """ISSUE 2 acceptance: fused conv == im2col + bfp_matmul_pallas,
    bit for bit (TILED, matching block_k, incl. K/OC padding paths)."""
    x, wk = _case(8, 10, 16, 24, 3, 3, seed=stride * 7)
    pol = _tiled(128)         # K=144 -> pads to 256: partial-block path
    out_f = ops.bfp_conv2d(x, wk, pol, stride, padding, interpret=True)
    cols, (b, oh, ow) = im2col(x, 3, 3, stride, padding)
    out_g = ops.bfp_matmul(cols, conv_weight_matrix(wk), pol,
                           interpret=True).reshape(b, oh, ow, 24)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_g))


def test_conv_kernel_accuracy_vs_float():
    """BFP-8 fused conv stays within ~2% of the float conv."""
    x, wk = _case(8, 8, 16, 16, 3, 3, seed=3, xs=1.0)
    out = ops.bfp_conv2d(x, wk, _tiled(16), 1, "SAME", interpret=True)
    ref_f = jax.lax.conv_general_dilated(
        x, wk, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    rel = float(jnp.linalg.norm(out - ref_f) / jnp.linalg.norm(ref_f))
    assert rel < 0.02, rel


def test_conv_kernel_overflow_guard():
    x, wk = _case(4, 4, 4, 4, 3, 3)
    pol = BFPPolicy(l_w=15, l_i=15, scheme=Scheme.TILED, block_k=36,
                    straight_through=False)
    with pytest.raises(ValueError, match="overflow"):
        ops.bfp_conv2d(x, wk, pol, 1, "SAME", interpret=True)


# ---------------------------------------------------------------------------
# prequant: bit-exact vs inline on the fused path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "VALID")])
def test_prequant_fused_bitexact_inline(stride, padding):
    x, wk = _case(8, 9, 8, 10, 3, 3, seed=11)
    pol = _tiled(24)
    pq = prequant_conv_leaf(wk, pol)
    assert EG.is_prequant(pq) and pq["m"].shape == wk.shape
    out_pq = ops.bfp_conv2d_prequant(x, pq["m"], pq["s"], pol, stride,
                                     padding, interpret=True)
    out_in = ops.bfp_conv2d(x, wk, pol, stride, padding, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_pq), np.asarray(out_in))


def test_prequant_block_mismatch_rejected():
    x, wk = _case(6, 6, 8, 8, 3, 3)
    pq = prequant_conv_leaf(wk, _tiled(24))
    with pytest.raises(ValueError, match="block"):
        ops.bfp_conv2d_prequant(x, pq["m"], pq["s"], _tiled(36), 1, "SAME",
                                interpret=True)


# ---------------------------------------------------------------------------
# engine.conv2d: dispatch, fallback honesty, PolicyMap paths
# ---------------------------------------------------------------------------

def test_engine_conv2d_pallas_equals_emulated_im2col():
    """The fused kernel and the emulated im2col route implement the same
    TILED math: engine.conv2d(backend=pallas) == engine.conv2d(emulated)."""
    x, wk = _case(8, 8, 8, 12, 3, 3, seed=5)
    out_pl = EG.conv2d(x, wk, _tiled(24, backend="pallas"))
    out_em = EG.conv2d(x, wk, _tiled(24))
    np.testing.assert_array_equal(np.asarray(out_pl), np.asarray(out_em))


def test_engine_conv2d_fallback_on_paper_scheme():
    """pallas + a paper scheme must NOT silently run TILED math: it
    falls back to the emulated im2col route."""
    x, wk = _case(7, 7, 4, 6, 3, 3, seed=6)
    out = EG.conv2d(x, wk, EQ4.with_(backend="pallas"))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(EG.conv2d(x, wk, EQ4)))


def test_engine_conv2d_float_matches_lax_conv():
    x, wk = _case(8, 8, 3, 5, 3, 3, seed=7, xs=1.0)
    out = EG.conv2d(x, wk, None, stride=2, padding="SAME")
    ref_f = jax.lax.conv_general_dilated(
        x, wk, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_f),
                               rtol=2e-4, atol=2e-4)


def test_engine_conv2d_policy_map_paths():
    """PolicyMap rules resolve on conv layer paths exactly as for GEMMs."""
    x, wk = _case(6, 6, 4, 6, 3, 3, seed=8)
    pm = PolicyMap.of(("^stem$", None), default=_tiled(12))
    np.testing.assert_array_equal(
        np.asarray(EG.conv2d(x, wk, pm, path="stem")),
        np.asarray(EG.conv2d(x, wk, None)))
    np.testing.assert_array_equal(
        np.asarray(EG.conv2d(x, wk, pm, path="blocks/0/c1")),
        np.asarray(EG.conv2d(x, wk, _tiled(12))))


def test_model_forward_pallas_fused_equals_emulated():
    """Whole-model check: LeNet forward on the fused conv path ==
    emulated backend, bit for bit (convs fused, dense on the GEMM
    kernel), including the prequantize_cnn wire format."""
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    # conv K's (c1: 25, c2: 400) are block_k=5 multiples; the dense K's
    # are not, so the map scopes TILED to the convs (fc layers float) —
    # the emulated route requires block_k | K, and a faithful comparison
    # must execute the SAME math on both backends.
    pm_pl = PolicyMap.of(("^fc", None), default=_tiled(5, backend="pallas"))
    pm_em = PolicyMap.of(("^fc", None), default=_tiled(5))
    out_pl = small.lenet_apply(params, x, pm_pl)
    out_em = small.lenet_apply(params, x, pm_em)
    np.testing.assert_array_equal(np.asarray(out_pl), np.asarray(out_em))

    pq = EG.prequantize_cnn(params, pm_pl)
    assert EG.is_prequant(pq["c1"]["w"])
    assert not EG.is_prequant(pq["fc1"]["w"])
    out_pq = small.lenet_apply(pq, x, pm_pl)
    np.testing.assert_array_equal(np.asarray(out_pq), np.asarray(out_pl))


def test_aligned_tile_shared_floor():
    """ops.bfp_quantize rides the same aligned floor as default_tiles
    (ISSUE 2 satellite: one helper, one rationale)."""
    assert ops.aligned_tile(1) == 8
    assert ops.aligned_tile(100) == 128
    assert ops.aligned_tile(300) == 128
    assert ops.aligned_tile(100, 256) == 128
    assert ops.aligned_tile(300, 256) == 256
    bm, bn, _ = ops.default_tiles(100, 256, 300, None)
    assert (bm, bn) == (ops.aligned_tile(100), ops.aligned_tile(300))
    m, e = ops.bfp_quantize(jax.random.normal(KEY, (100, 256)), 8, 128,
                            interpret=True)
    assert m.shape == (100, 256) and e.shape == (100, 2)

# ---------------------------------------------------------------------------
# ISSUE 6 — dot modes, pipelining, fused requantize epilogue (conv)
# ---------------------------------------------------------------------------

from repro.core.prequant import dequantize_act, prequant_act  # noqa: E402


@pytest.mark.parametrize("dot_impl", ["int8", "int32", "f32"])
@pytest.mark.parametrize("pipeline", [False, True])
def test_conv_dot_modes_bit_identical(dot_impl, pipeline):
    """Every conv dot datapath x pipelining == the legacy
    int32/unpipelined kernel bit for bit, and == the oracle."""
    x, wk = _case(8, 8, 8, 10, 3, 3, seed=21)
    pol = _tiled(24)
    out = ops.bfp_conv2d(x, wk, pol, 1, "SAME", True,
                         dot_impl=dot_impl, pipeline=pipeline)
    base = ops.bfp_conv2d(x, wk, pol, 1, "SAME", True,
                          dot_impl="int32", pipeline=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    out_r = ref.bfp_conv2d_ref(x, wk, 8, 8, 24, 1, "SAME")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("pipeline", [False, True])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv_epilogue_requant_bit_identical(pipeline, stride):
    """Fused conv epilogue == conv-then-prequant_act, bit for bit,
    including the NHWC sidecar shape (blocks along OC per pixel)."""
    x, wk = _case(8, 8, 8, 16, 3, 3, seed=22)
    pol = _tiled(24)
    out_pol = _tiled(8)
    fused = ops.bfp_conv2d(x, wk, pol, stride, "SAME", True,
                           out_policy=out_pol, pipeline=pipeline)
    two = prequant_act(
        ops.bfp_conv2d(x, wk, pol, stride, "SAME", True,
                       pipeline=pipeline), out_pol)
    oh = 8 // stride
    assert EG.is_prequant(fused) and fused["m"].dtype == jnp.int8
    assert fused["m"].shape == (2, oh, oh, 16)
    assert fused["s"].shape == (2, oh, oh, 2)
    np.testing.assert_array_equal(np.asarray(fused["m"]),
                                  np.asarray(two["m"]))
    np.testing.assert_array_equal(np.asarray(fused["s"]),
                                  np.asarray(two["s"]))


def test_conv_act_dict_input_bit_identical():
    """int8 wire-format NHWC activations consumed natively == dequantize
    + inline re-quantization (C blocks align with patch K blocks)."""
    x, wk = _case(8, 8, 16, 12, 3, 3, seed=23)
    pol = _tiled(16)
    xq = prequant_act(x, pol)
    assert EG.is_prequant(xq) and xq["m"].shape == x.shape
    out_d = ops.bfp_conv2d(xq, wk, pol, 1, "SAME", True)
    out_f = ops.bfp_conv2d(dequantize_act(xq), wk, pol, 1, "SAME", True)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_f))


def test_conv_epilogue_then_consume_chain():
    """conv -> conv entirely on the wire format == the all-float-
    activation chain with inline quantization, bit for bit."""
    x, w1 = _case(8, 8, 8, 16, 3, 3, seed=24)
    w2 = jax.random.normal(jax.random.PRNGKey(25), (3, 3, 16, 12)) * 0.1
    pol1, pol2 = _tiled(24), _tiled(16)
    y1 = ops.bfp_conv2d(x, w1, pol1, 1, "SAME", True, out_policy=pol2)
    out = ops.bfp_conv2d(y1, w2, pol2, 1, "SAME", True)
    y1_f = ops.bfp_conv2d(x, w1, pol1, 1, "SAME", True)
    out_ref = ops.bfp_conv2d(y1_f, w2, pol2, 1, "SAME", True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))
