"""LM zoo: per-arch reduced-config smoke tests (deliverable f) +
forward/decode consistency + family-specific invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS, cells
from repro.core.policy import PAPER_DEFAULT
from repro.models.lm import common as C, model as Mdl

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = sorted(ARCHS)


def _setup(name, **kw):
    cfg = reduced(ARCHS[name], **kw)
    params = Mdl.init_params(cfg, KEY)
    return cfg, params


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_and_train_step(name):
    """One forward + one train step on CPU: output shapes + no NaNs."""
    cfg, params = _setup(name)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab_size)
    enc = (jax.random.normal(KEY, (2, cfg.enc_seq_stub, cfg.d_model))
           if cfg.is_encdec else None)
    logits, aux = Mdl.forward(params, cfg, toks, enc_feats=enc)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    from repro.train.step import init_state, make_train_step
    state = init_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg))
    targets = jnp.roll(toks, -1, 1)
    state2, metrics = step(state, (toks, targets))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_matches_forward(name):
    """KV-cache / recurrent-state decode == full forward, token by token."""
    kw = {}
    cfg = reduced(ARCHS[name])
    if cfg.is_moe:   # capacity drops are fwd-only; disable for equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = Mdl.init_params(cfg, KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    enc = (jax.random.normal(KEY, (B, cfg.enc_seq_stub, cfg.d_model))
           if cfg.is_encdec else None)
    logits_f, _ = Mdl.forward(params, cfg, toks, enc_feats=enc)
    cache = Mdl.init_cache(cfg, B, max_len=64, dtype=jnp.float32)
    if cfg.is_encdec:
        cache["enc_out"] = Mdl.prefill_encoder(params, cfg, enc)
    step = jax.jit(lambda c, t, p: Mdl.decode_step(params, cfg, c, t, p))
    outs = []
    for t in range(S):
        lg, cache = step(cache, toks[:, t:t + 1], jnp.asarray(t, jnp.int32))
        outs.append(lg)
    logits_d = jnp.concatenate(outs, axis=1)
    scale = float(jnp.max(jnp.abs(logits_f))) + 1e-9
    assert float(jnp.max(jnp.abs(logits_f - logits_d))) / scale < 1e-4


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_bfp_forward(name):
    """Every arch runs with the paper's BFP datapath in all linears."""
    cfg, params = _setup(name)
    toks = jax.random.randint(KEY, (1, 32), 0, cfg.vocab_size)
    enc = (jax.random.normal(KEY, (1, cfg.enc_seq_stub, cfg.d_model))
           if cfg.is_encdec else None)
    lf, _ = Mdl.forward(params, cfg, toks, enc_feats=enc)
    lq, _ = Mdl.forward(params, cfg, toks, enc_feats=enc,
                        policy=PAPER_DEFAULT.with_(straight_through=False))
    assert bool(jnp.all(jnp.isfinite(lq)))
    rel = float(jnp.linalg.norm(lq - lf) / (jnp.linalg.norm(lf) + 1e-9))
    # 8-bit BFP stays close to float end-to-end.  MoE archs get a looser
    # bound: quantization can flip discrete top-k routing decisions, which
    # perturbs logits beyond the pure datapath error (~0.16 measured).
    bound = 0.2 if cfg.is_moe else 0.15
    assert rel < bound, rel


def test_causality():
    """Changing a future token must not affect past logits (dense arch)."""
    cfg, params = _setup("tinyllama-1.1b")
    toks = jax.random.randint(KEY, (1, 32), 0, cfg.vocab_size)
    l1, _ = Mdl.forward(params, cfg, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % cfg.vocab_size)
    l2, _ = Mdl.forward(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l2[:, :-1]), atol=1e-5)


def test_swa_matches_masked_attention():
    """Chunked sliding-window attention == full attention with band mask."""
    cfg = reduced(ARCHS["mixtral-8x7b"])
    cfg = dataclasses.replace(cfg, sliding_window=32)
    p = C.attention_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 128, cfg.d_model))  # S = 4*W -> chunked
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    out_chunked = C.attention(p, cfg, x, pos, None)
    cfg_small = dataclasses.replace(cfg, sliding_window=32)
    # force the masked-dense path by lying about the threshold
    q, k, v = C._qkv(p, cfg_small, x, x, None)
    q = C._apply_rope(cfg_small, q, pos)
    k = C._apply_rope(cfg_small, k, pos)
    mask = C._causal_mask(128, 32)[None, None, None]
    out_dense = C._sdpa(q, k, v, cfg_small, mask)
    out_dense = C.linear(p["wo"], out_dense.reshape(2, 128, -1), None)
    np.testing.assert_allclose(np.asarray(out_chunked),
                               np.asarray(out_dense), atol=2e-4)


def test_flash_matches_dense():
    cfg = reduced(ARCHS["mistral-nemo-12b"])
    p = C.attention_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))
    q, k, v = C._qkv(p, cfg, x, x, None)
    out_flash = C._flash_sdpa(q, k, v, cfg, causal=True, chunk=16)
    mask = C._causal_mask(64, None)[None, None, None]
    out_dense = C._sdpa(q, k, v, cfg, mask)
    np.testing.assert_allclose(np.asarray(out_flash),
                               np.asarray(out_dense), atol=2e-4)


def test_mrope_text_equals_rope():
    """qwen2-vl M-RoPE with equal (t,h,w) ids == standard RoPE."""
    x = jax.random.normal(KEY, (2, 16, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    r1 = C.rope(x, pos, 10000.0)
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 16))
    r2 = C.mrope(x, pos3, 10000.0, (6, 5, 5))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)


def test_rwkv_state_decay():
    """RWKV-6: with zero input-keys the WKV state must decay toward 0."""
    from repro.models.lm import rwkv6 as R
    cfg = reduced(ARCHS["rwkv6-3b"])
    p = R.time_mix_init(KEY, cfg)
    B = 1
    S0 = jnp.ones((B, cfg.n_heads, cfg.dh, cfg.dh))
    x = jnp.zeros((B, 1, cfg.d_model))
    _, (_, S1) = R.time_mix_decode(p, cfg, x, (jnp.zeros((B, cfg.d_model)),
                                               S0))
    assert float(jnp.max(jnp.abs(S1))) <= float(jnp.max(jnp.abs(S0))) + 1e-3


def test_moe_capacity_drops_counted():
    """Oversubscribed experts drop tokens (capacity factor semantics)."""
    from repro.models.lm import moe as M
    cfg = dataclasses.replace(reduced(ARCHS["olmoe-1b-7b"]),
                              capacity_factor=0.25)
    p = M.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    out, aux = M.moe_apply(p, cfg, x)
    assert out.shape == x.shape and bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.5  # aux loss active


def test_cells_accounting():
    """40 assigned cells: 33 runnable + 7 documented long_500k skips."""
    cs = cells()
    assert len(cs) == 40
    skips = [c for c in cs if c[2] is not None]
    assert len(skips) == 7
    assert all(c[1] == "long_500k" for c in skips)
    runnable_long = [c for c in cs if c[1] == "long_500k" and c[2] is None]
    assert sorted(c[0] for c in runnable_long) == [
        "mixtral-8x7b", "recurrentgemma-9b", "rwkv6-3b"]


def test_param_count_matches_analytic():
    """Analytic 6ND count matches actual leaves within 5% (dense arch)."""
    cfg, params = _setup("tinyllama-1.1b")
    analytic = cfg.param_count()
    actual = Mdl.param_count(params)
    assert abs(analytic - actual) / actual < 0.05
