"""Fused requantize epilogue through the engine (ISSUE 6).

``out_policy=`` asks a layer to emit its output already in the
activation wire format (int8 mantissas + power-of-two steps), so chained
BFP layers hand off quantized activations without a dequantized-f32
round-trip through HBM.  Contracts:

  * on every backend, ``out_policy=`` output == run the layer, then
    ``prequant_act`` — bit for bit (pallas fuses it into the kernel
    epilogue; float/emulated requantize in two steps);
  * a chain running on the wire format == the float-activation chain
    with inline input quantization (quantization idempotence);
  * ``out_policy`` rejects anything that isn't the wire format;
  * ``Plan.out_policy_for`` derives the correct handoff policy from the
    consuming site, and ``bind(tune_cache=)`` scopes tuned tiles to plan
    executions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as EG
from repro.core import BFPPolicy, Scheme
from repro.core.bfp import Rounding
from repro.engine import dequantize_act, is_prequant, prequant_act
from repro.tune.cache import TuneCache

KEY = jax.random.PRNGKey(0)
TILED16 = BFPPolicy(scheme=Scheme.TILED, block_k=16,
                    straight_through=False)


def _xw(b=8, k=32, n=16, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (b, k)) * 2.0
    w = jax.random.normal(kw, (k, n)) * 0.1
    return x, w


# ---------------------------------------------------------------------------
# out_policy == two-step requantization, every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas", "emulated", "float"])
def test_gemm_out_policy_equals_two_step(backend):
    x, w = _xw()
    pol = TILED16.with_(backend=backend)
    y = EG.gemm(x, w, pol, out_policy=TILED16)
    ref_y = prequant_act(EG.gemm(x, w, pol), TILED16)
    assert is_prequant(y) and y["m"].dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(y["m"]), np.asarray(ref_y["m"]))
    np.testing.assert_array_equal(np.asarray(y["s"]), np.asarray(ref_y["s"]))


@pytest.mark.parametrize("backend", ["pallas", "emulated"])
def test_conv_out_policy_equals_two_step(backend):
    x = jax.random.normal(KEY, (2, 8, 8, 8)) * 2.0
    wk = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 16)) * 0.1
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=24,
                    straight_through=False, backend=backend)
    y = EG.conv2d(x, wk, pol, out_policy=TILED16)
    ref_y = prequant_act(EG.conv2d(x, wk, pol), TILED16)
    assert is_prequant(y) and y["m"].shape == (2, 8, 8, 16)
    np.testing.assert_array_equal(np.asarray(y["m"]), np.asarray(ref_y["m"]))
    np.testing.assert_array_equal(np.asarray(y["s"]), np.asarray(ref_y["s"]))


def test_gemm_leading_dims_restored_on_wire_format():
    """x with extra leading dims: the dict output carries them too."""
    x = jax.random.normal(KEY, (2, 3, 32)) * 2.0
    _, w = _xw()
    y = EG.gemm(x, w, TILED16.with_(backend="pallas"), out_policy=TILED16)
    assert y["m"].shape == (2, 3, 16) and y["s"].shape == (2, 3, 1)
    flat = EG.gemm(x.reshape(6, 32), w, TILED16.with_(backend="pallas"),
                   out_policy=TILED16)
    np.testing.assert_array_equal(np.asarray(y["m"]),
                                  np.asarray(flat["m"].reshape(2, 3, 16)))


# ---------------------------------------------------------------------------
# chains on the wire format
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas", "emulated", "float"])
def test_gemm_chain_bit_identical(backend):
    """Wire-format handoff == dequantize-then-consume on every backend
    (non-consuming backends dequantize internally; pallas streams the
    int8 dict straight into the kernel)."""
    x, w1 = _xw(8, 32, 32, seed=1)
    _, w2 = _xw(8, 32, 16, seed=2)
    pol = TILED16.with_(backend=backend)
    y1 = EG.gemm(x, w1, pol, out_policy=TILED16)
    out = EG.gemm(y1, w2, pol)
    out_ref = EG.gemm(dequantize_act(y1), w2, pol)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))


def test_gemm_chain_equals_float_activation_chain():
    """The stronger idempotence claim: consuming the producer's wire
    output == consuming its FLOAT output (the consumer's inline input
    quantization lands on the identical BFP grid)."""
    x, w1 = _xw(8, 32, 32, seed=3)
    _, w2 = _xw(8, 32, 16, seed=4)
    pol = TILED16.with_(backend="pallas")
    y1 = EG.gemm(x, w1, pol, out_policy=TILED16)
    out = EG.gemm(y1, w2, pol)
    out_ref = EG.gemm(EG.gemm(x, w1, pol), w2, pol)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))


def test_conv_chain_bit_identical():
    x = jax.random.normal(KEY, (2, 8, 8, 8)) * 2.0
    w1 = jax.random.normal(jax.random.PRNGKey(5), (3, 3, 8, 16)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 16, 12)) * 0.1
    pol1 = BFPPolicy(scheme=Scheme.TILED, block_k=24,
                     straight_through=False, backend="pallas")
    pol2 = TILED16.with_(backend="pallas")
    y1 = EG.conv2d(x, w1, pol1, out_policy=TILED16)
    out = EG.conv2d(y1, w2, pol2)
    out_ref = EG.conv2d(EG.conv2d(x, w1, pol1), w2, pol2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))


def test_im2col_route_accepts_wire_format():
    """conv2d_im2col (the fallback route) dequantizes dict inputs and
    honours out_policy — fallback never changes semantics."""
    x = jax.random.normal(KEY, (2, 6, 6, 8)) * 2.0
    wk = jax.random.normal(jax.random.PRNGKey(7), (3, 3, 8, 16)) * 0.1
    pol = BFPPolicy(scheme=Scheme.TILED, block_k=24,
                    straight_through=False)
    xq = prequant_act(x, TILED16.with_(block_k=8))
    y = EG.conv2d_im2col(xq, wk, pol, out_policy=TILED16)
    ref_y = prequant_act(EG.conv2d_im2col(dequantize_act(xq), wk, pol),
                         TILED16)
    assert y["m"].shape == (2, 6, 6, 16)
    np.testing.assert_array_equal(np.asarray(y["m"]), np.asarray(ref_y["m"]))


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_out_policy_rejects_non_wire_format():
    x, w = _xw()
    with pytest.raises(ValueError, match="TILED"):
        EG.gemm(x, w, TILED16,
                out_policy=BFPPolicy(straight_through=False))   # no blocks
    with pytest.raises(ValueError, match="round-to-nearest"):
        EG.gemm(x, w, TILED16,
                out_policy=TILED16.with_(rounding=Rounding.STOCHASTIC))


# ---------------------------------------------------------------------------
# plans: out_policy_for + bound tune caches
# ---------------------------------------------------------------------------

def _toy_plan(policy, **kw):
    x, w1 = _xw(8, 32, 32, seed=9)      # fc1: 32 -> 32
    _, w2 = _xw(8, 32, 16, seed=10)     # fc2: 32 -> 16
    params = {"fc1": {"w": w1}, "fc2": {"w": w2}}
    plan = EG.bind(params, policy, [("fc1", "gemm"), ("fc2", "gemm")], **kw)
    return plan, x, (w1, w2)


def test_plan_out_policy_for():
    plan, _, _ = _toy_plan(TILED16)
    assert plan.out_policy_for("fc2") == TILED16
    plan_f, _, _ = _toy_plan(None)
    assert plan_f.out_policy_for("fc2") is None
    plan_w, _, _ = _toy_plan(TILED16.with_(l_i=10))   # not int8 on the wire
    assert plan_w.out_policy_for("fc2") is None
    plan_s, _, _ = _toy_plan(
        TILED16.with_(rounding=Rounding.STOCHASTIC), prequantize=False)
    assert plan_s.out_policy_for("fc2") is None


def test_plan_chain_on_wire_format():
    plan, x, (w1, w2) = _toy_plan(TILED16.with_(backend="pallas"))
    y1 = plan.gemm(x, w1, path="fc1",
                   out_policy=plan.out_policy_for("fc2"))
    assert is_prequant(y1)
    out = plan.gemm(y1, w2, path="fc2")
    out_ref = plan.gemm(plan.gemm(x, w1, path="fc1"), w2, path="fc2")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))


def test_bind_tune_cache_scoped_to_plan():
    cache = TuneCache()
    cache.store("gemm", 8, 32, 32, 8, 8, 16, "interpret",
                {"bm": 8, "bn": 8, "bk": 16, "us": 1.0, "steps": 1})
    plan, x, (w1, _) = _toy_plan(TILED16.with_(backend="pallas"),
                                 tune_cache=cache)
    out = plan.gemm(x, w1, path="fc1")
    assert cache.hits >= 1          # the plan activated its cache
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(EG.gemm(x, w1, TILED16.with_(backend="pallas"))))


def test_bind_tune_cache_accepts_path(tmp_path):
    p = str(tmp_path / "cache.json")
    TuneCache(path=p).save()
    plan, x, (w1, _) = _toy_plan(TILED16, tune_cache=p)
    assert isinstance(plan.tune_cache, TuneCache)
    out = plan.gemm(x, w1, path="fc1")
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(EG.gemm(x, w1, TILED16)))
