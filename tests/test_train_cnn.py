"""Data-parallel BFP CNN training (repro.train.cnn; ISSUE 8).

The training step runs forward AND backward on the BFP engine datapath
and exchanges gradients over the compressed wire with error feedback.
Contracts: loss decreases (float and BFP), the real packed-bytes
exchange is BIT-EXACT to the jitted in-graph model, residuals survive a
checkpoint restore round trip, and training-time gradient NSR stays
under the analytic bound.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import BFPPolicy
from repro.train import cnn as TC

EQ4_HARD = BFPPolicy(l_w=8, l_i=8, straight_through=False)


def _cfg(**kw):
    base = dict(model="lenet", workers=2, batch=16, lr=1e-3, grad_bits=8)
    base.update(kw)
    return TC.CnnTrainConfig(**base)


def _tree_equal(a, b):
    return bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda u, v: jnp.array_equal(u, v), a, b)))


def test_config_validates_split_and_wire_block():
    with pytest.raises(ValueError, match="split"):
        TC.CnnTrainConfig(batch=10, workers=4)
    with pytest.raises(ValueError, match="wire block"):
        TC.CnnTrainConfig(grad_bits=8, wire_block=0)


def test_loss_decreases_float_and_bfp():
    out_f = TC.train_cnn(_cfg(policy=None, grad_bits=None), steps=8,
                         eval_batch=64)
    lf = [h["loss"] for h in out_f["history"]]
    assert lf[-1] < lf[0], lf

    out_q = TC.train_cnn(_cfg(policy=EQ4_HARD), steps=8, eval_batch=64)
    lq = [h["loss"] for h in out_q["history"]]
    assert lq[-1] < lq[0], lq


def test_packed_exchange_bit_exact_to_jit_model():
    cfg = _cfg(policy=EQ4_HARD)
    state = TC.init_state(cfg)
    x, y, _ = TC.data_batch(cfg, 0)
    s_wire, m_wire = TC.packed_exchange_step(cfg, state, (x, y))
    s_model, _ = TC.make_cnn_train_step(cfg)(state, (x, y))
    assert _tree_equal(s_wire.params, s_model.params)
    assert _tree_equal(s_wire.residual, s_model.residual)
    assert m_wire["wire_bytes"] > 0


def test_packed_exchange_requires_wire_format():
    cfg = _cfg(grad_bits=None)
    state = TC.init_state(cfg)
    x, y, _ = TC.data_batch(cfg, 0)
    with pytest.raises(ValueError, match="grad_bits"):
        TC.packed_exchange_step(cfg, state, (x, y))


def test_residuals_nonzero_and_survive_checkpoint(tmp_path):
    from repro.checkpoint import store
    cfg = _cfg(policy=EQ4_HARD)
    out = TC.train_cnn(cfg, steps=2, eval_batch=32,
                       ckpt_dir=str(tmp_path / "ck"))
    state = out["state"]
    # EF residuals carry real quantization error after a compressed step
    rnorm = sum(float(jnp.linalg.norm(r))
                for r in jax.tree_util.tree_leaves(state.residual))
    assert rnorm > 0.0
    # train_cnn already verified one round trip; pin it independently
    restored, step = store.restore(str(tmp_path / "ck"), state)
    assert step == 2
    assert _tree_equal(restored.residual, state.residual)
    assert _tree_equal(restored.params, state.params)


def test_wire_bytes_accounting():
    cfg = _cfg(policy=EQ4_HARD)
    out = TC.train_cnn(cfg, steps=3, packed_wire_steps=2, eval_batch=32)
    wire = out["wire_bytes"]
    assert wire["packed_steps"] == 2
    # per-leaf container headers make measured > analytic payload, but
    # within the same order; and 8-bit wire beats float by ~4x
    assert wire["measured_bytes"] >= 2 * wire["per_step_bytes"] * 0.9
    assert wire["ratio"] < 0.3


def test_training_grad_nsr_within_bound():
    cfg = _cfg(policy=EQ4_HARD)
    out = TC.train_cnn(cfg, steps=2, measure_nsr_every=1, eval_batch=32)
    recs = out["nsr_records"]
    assert recs, "no backward tap events recorded"
    kinds = {r.kind for r in recs}
    assert "conv_dx" in kinds and "gemm_dw" in kinds
    for r in recs:
        assert r.within_bound, (r.path, r.kind, r.eta_measured, r.eta_bound)
