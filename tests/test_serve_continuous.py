"""Iteration-level continuous batching + serve-path correctness fixes
(ISSUE 9).

Pins the three bugfixes — submit validation (cache-geometry rejection,
``max_new >= 1``), expire-BEFORE-admit ordering (zero jitted calls for a
dead request, in both batching modes), O(1) FIFO admission order — and
the continuous-batching invariants: chunked prefill with staggered
admissions is bit-identical to solo serving, bucket mode matches
continuous token-for-token, and ``step()`` returns the unified
pending-after-step count.
"""
import jax
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS
from repro.core.policy import TPU_TILED
from repro.serve.degrade import (DeadlineExceeded, QueueOverloaded,
                                 RequestTooLarge, ServeRejected)
from repro.serve.engine import Request, ServeEngine
from repro.serve.slots import SlotTable
from repro.train.step import init_state

KEY = jax.random.PRNGKey(0)
POL = TPU_TILED.with_(block_k=None, straight_through=False)


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(ARCHS["tinyllama-1.1b"], n_layers=2, d_model=64,
                  d_ff=128, vocab=256)
    params = init_state(cfg, KEY).params
    return cfg, params


# ---------------------------------------------------------------------------
# Satellite 1: submit validation
# ---------------------------------------------------------------------------

def test_submit_rejects_request_too_large(lm):
    """len(prompt) + max_new > max_len would write cache positions JAX
    silently clamps/drops under jit — the request must be refused at the
    door, typed, and never enqueued."""
    cfg, params = lm
    eng = ServeEngine(params, cfg, slots=1, max_len=8, policy=POL)
    with pytest.raises(RequestTooLarge) as ei:
        eng.submit(Request(rid=7, prompt=[1, 2, 3, 4, 5], max_new=4))
    assert isinstance(ei.value, ServeRejected) and ei.value.rid == 7
    assert len(eng.table.queue) == 0
    assert eng.stats["shed"] == 0        # a rejection is not a shed
    # the boundary fits exactly: positions 0..7 for 5 prompt + 3 new
    eng.submit(Request(rid=8, prompt=[1, 2, 3, 4, 5], max_new=3))
    done = eng.run()
    assert done[0].error is None and len(done[0].out) == 3


def test_submit_rejects_nonpositive_max_new(lm):
    cfg, params = lm
    eng = ServeEngine(params, cfg, slots=1, max_len=16, policy=POL)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(rid=0, prompt=[1], max_new=0))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(rid=1, prompt=[1], max_new=-2))
    with pytest.raises(ValueError, match="non-empty"):
        eng.submit(Request(rid=2, prompt=[], max_new=1))
    assert not eng.table.pending()


def test_validation_runs_before_shedding(lm):
    """An oversized request must be rejected as TOO LARGE even when the
    queue is also full — the client's fix is different (shrink vs
    retry), so the type must not depend on load."""
    cfg, params = lm
    eng = ServeEngine(params, cfg, slots=1, max_len=8, policy=POL,
                      max_queue=1)
    eng.submit(Request(rid=0, prompt=[1], max_new=2))
    with pytest.raises(RequestTooLarge):
        eng.submit(Request(rid=1, prompt=[1] * 8, max_new=8))
    with pytest.raises(QueueOverloaded):
        eng.submit(Request(rid=2, prompt=[1], max_new=2))


# ---------------------------------------------------------------------------
# Satellite 2: expiry runs BEFORE admission
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batching", ["continuous", "bucket"])
def test_dead_request_is_never_prefilled(lm, batching):
    """Regression (pre-fix: step() admitted then expired): a request
    whose deadline already passed while queued must fail with ZERO
    jitted calls — in bucket mode the old order burned len(prompt)
    blocking prefill steps on a corpse."""
    cfg, params = lm
    t = [0.0]
    eng = ServeEngine(params, cfg, slots=1, max_len=64, policy=POL,
                      batching=batching, clock=lambda: t[0])
    calls = [0]
    orig = eng._step

    def counting_step(cache, tok, pos):
        calls[0] += 1
        return orig(cache, tok, pos)

    eng._step = counting_step
    dead = Request(rid=0, prompt=list(range(1, 33)), max_new=4,
                   deadline=5.0)
    eng.submit(dead)
    t[0] = 10.0                          # deadline passed while queued
    assert eng.step() == 0
    assert dead.done and isinstance(dead.error, DeadlineExceeded)
    assert calls[0] == 0 and eng.ncalls == 0
    assert eng.stats["expired"] == 1
    assert eng.table.active() == []      # never occupied a slot


def test_live_request_unaffected_by_dead_neighbor(lm):
    cfg, params = lm
    t = [0.0]
    eng = ServeEngine(params, cfg, slots=2, max_len=64, policy=POL,
                      clock=lambda: t[0])
    dead = Request(rid=0, prompt=[1, 2, 3], max_new=4, deadline=5.0)
    live = Request(rid=1, prompt=[1, 2, 3], max_new=4, deadline=500.0)
    eng.submit(dead)
    eng.submit(live)
    t[0] = 10.0
    eng.run()
    assert isinstance(dead.error, DeadlineExceeded) and dead.out == []
    assert live.error is None and len(live.out) == 4


# ---------------------------------------------------------------------------
# Satellite 3: O(1) FIFO preserves admission order + aliasing
# ---------------------------------------------------------------------------

def test_slot_table_fifo_admission_order():
    """admit_one() hands out queued requests strictly in submission
    order (the O(1) deque must still behave as a FIFO), including
    across full-table stalls."""
    tab = SlotTable(2)
    for i in range(5):
        tab.submit(("req", i))
    occupied = [tab.admit_one(), tab.admit_one()]
    assert tab.admit_one() is None       # table full — queue untouched
    admitted = [adm[1] for adm in occupied]
    while tab.queue:                     # drain the backlog one-for-one
        s, _ = occupied.pop(0)           # retire the oldest admission
        tab.free(s)
        adm = tab.admit_one()
        occupied.append(adm)
        admitted.append(adm[1])
    assert [r[1] for r in admitted] == [0, 1, 2, 3, 4]   # strict FIFO


def test_slot_table_retain_preserves_alias_and_order():
    tab = SlotTable(1)
    alias = tab.queue
    for i in range(6):
        tab.submit(i)
    dropped = tab.retain(lambda r: r % 2 == 0)
    assert dropped == [1, 3, 5]
    assert list(tab.queue) == [0, 2, 4]
    assert tab.queue is alias            # engines alias table.queue
    assert tab.retain(lambda r: True) == []
    assert list(alias) == [0, 2, 4]


def test_slot_table_pending_counts():
    tab = SlotTable(2)
    assert tab.pending() == 0 and not tab.pending()
    tab.submit("a")
    tab.submit("b")
    tab.submit("c")
    assert tab.pending() == 3
    tab.admit()
    assert tab.pending() == 3 and len(tab.queue) == 1
    tab.free(0)
    assert tab.pending() == 2


# ---------------------------------------------------------------------------
# Tentpole invariants
# ---------------------------------------------------------------------------

def _solo(params, cfg, prompt, max_new, **kw):
    eng = ServeEngine(params, cfg, slots=1, max_len=64, policy=POL, **kw)
    r = Request(rid=0, prompt=list(prompt), max_new=max_new)
    eng.submit(r)
    eng.run()
    return list(r.out)


def test_chunked_prefill_staggered_admissions_bit_exact(lm):
    """A long prompt admitted mid-flight prefills in chunks interleaved
    with the active request's decodes — and neither request's greedy
    tokens may move vs solo serving."""
    cfg, params = lm
    p_short, p_long = [1, 2, 3], list(range(5, 5 + 24))
    ref_s = _solo(params, cfg, p_short, 8)
    ref_l = _solo(params, cfg, p_long, 8)

    eng = ServeEngine(params, cfg, slots=2, max_len=64, policy=POL,
                      prefill_chunk=2)   # many micro-iterations
    r1 = Request(rid=1, prompt=list(p_short), max_new=8)
    eng.submit(r1)
    eng.step()
    eng.step()                           # r1 is decoding
    mid = len(r1.out)
    r2 = Request(rid=2, prompt=list(p_long), max_new=8)
    eng.submit(r2)                       # 24-token prompt, chunk=2
    eng.step()
    # the admission advanced r1 (no barrier) while r2 only prefilled
    assert len(r1.out) == mid + 1 and r2.out == []
    while eng.step():
        pass
    assert r1.out == ref_s and r2.out == ref_l


def test_bucket_mode_matches_continuous_tokens(lm):
    """The measured baseline is slower, not different: same requests,
    same greedy tokens, either batching mode."""
    cfg, params = lm
    prompts = [[1, 2, 3], [9, 8, 7, 6, 5, 4], [11, 12]]
    outs = {}
    for mode in ("continuous", "bucket"):
        eng = ServeEngine(params, cfg, slots=2, max_len=64, policy=POL,
                          batching=mode, prefill_chunk=3)
        rs = [Request(rid=i, prompt=list(p), max_new=5)
              for i, p in enumerate(prompts)]
        for r in rs:
            eng.submit(r)
        eng.run()
        outs[mode] = [r.out for r in rs]
    assert outs["continuous"] == outs["bucket"]


def test_whole_prompt_chunk_none(lm):
    cfg, params = lm
    ref = _solo(params, cfg, [3, 1, 4, 1, 5], 4)
    assert _solo(params, cfg, [3, 1, 4, 1, 5], 4,
                 prefill_chunk=None) == ref


def test_step_returns_pending_after_step(lm):
    cfg, params = lm
    eng = ServeEngine(params, cfg, slots=1, max_len=64, policy=POL)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new=2))
    eng.submit(Request(rid=1, prompt=[1, 2], max_new=2))
    seen = []
    while True:
        n = eng.step()
        seen.append(n)
        if not n:
            break
    assert seen[-1] == 0 and seen[0] >= 1      # drives `while eng.step()`
    assert eng.stats["completed"] == 2
    assert eng.step() == 0                     # idempotent when drained


def test_engine_rejects_bad_batching_args(lm):
    cfg, params = lm
    with pytest.raises(ValueError, match="batching"):
        ServeEngine(params, cfg, slots=1, policy=POL, batching="magic")
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine(params, cfg, slots=1, policy=POL, prefill_chunk=0)
