"""The BFP autodiff subsystem (ISSUE 8 acceptance).

Key contracts:
  * float grad-policy custom-VJP gradients are BIT-IDENTICAL to plain
    JAX autodiff of the float path (gemm AND conv);
  * the routed default-policy (straight_through=True) gradients equal
    the legacy core.bfp_dot STE bit-exactly — the reconciliation pin the
    bfp_dot module docstring points at;
  * with quantized backward GEMMs the measured gradient NSR (backward
    tap events) never exceeds core.nsr's bound, across L = 4..12;
  * #dx/#dw PolicyMap rules override the site rule, fall back to the
    site policy when absent, an explicit None rule pins float, and
    strict bind raises for an unsupported backward backend;
  * plan-bound gradients equal per-call gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as EG
from repro.core import BFPPolicy, Scheme, bfp
from repro.core.bfp_dot import bfp_matmul_2d
from repro.core.nsr import (gemm_nsr_upper_bound, grad_dx_nsr_upper_bound,
                            grad_dw_nsr_upper_bound)
from repro.engine import PolicyMap
from repro.engine.taps import taps as tap_ctx
from repro.engine.backends import BackendUnsupportedError
from repro.grad import (GRAD_KINDS, fit_grad_policy, grad_path,
                        measure_gradient_nsr, resolve_grad_policy)
from repro.models.cnn import small

KEY = jax.random.PRNGKey(0)
EQ4 = BFPPolicy(straight_through=False)
STE = BFPPolicy()          # straight_through=True (the default)
TILED = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)


def _xw(b=6, k=96, n=16, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (b, k)) * 1.5,
            jax.random.normal(kw, (k, n)) * 0.1)


def _conv_xw(b=2, hw=8, ci=3, co=8, kh=3, seed=0):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(kx, (b, hw, hw, ci)),
            jax.random.normal(kw, (kh, kh, ci, co)) * 0.2)


def _tree_equal(a, b):
    return bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda u, v: jnp.array_equal(u, v), a, b)))


# ---------------------------------------------------------------------------
# grad paths and policy resolution (unit)
# ---------------------------------------------------------------------------

def test_grad_path_suffixes():
    assert grad_path("c1", "dx") == "c1#dx"
    assert grad_path("blk/fc", "dw") == "blk/fc#dw"
    assert grad_path(None, "dx") is None
    with pytest.raises(ValueError):
        grad_path("c1", "dy")
    assert GRAD_KINDS == ("dx", "dw")


def test_resolve_fallback_semantics():
    # None site -> float backward; STE site -> float backward;
    # straight_through=False site -> the site policy itself
    assert resolve_grad_policy(None, "c1", "dx") is None
    assert resolve_grad_policy(STE, "c1", "dx") is None
    assert resolve_grad_policy(EQ4, "c1", "dw") == EQ4


def test_resolve_explicit_rules_precede_site_rule():
    low = BFPPolicy(l_w=4, l_i=4)
    pm = PolicyMap([(r"c1#dx", low), (r"c1", EQ4)])
    assert resolve_grad_policy(pm, "c1", "dx") == low       # explicit hit
    assert resolve_grad_policy(pm, "c1", "dw") == EQ4       # site fallback
    # explicit None PINS float even though the site policy would quantize
    pm2 = PolicyMap([(r"#dw", None), (r"c1", EQ4)])
    assert resolve_grad_policy(pm2, "c1", "dw") is None
    assert resolve_grad_policy(pm2, "c1", "dx") == EQ4


def test_explicit_rule_never_hits_forward_resolution():
    pm = PolicyMap([(r"c1#dx", BFPPolicy(l_w=4, l_i=4)), (r"c1", EQ4)])
    from repro.engine.policy_map import resolve_policy
    assert resolve_policy(pm, "c1") == EQ4


def test_fit_grad_policy_tiles():
    assert fit_grad_policy(None, 48) is None
    assert fit_grad_policy(EQ4, 48) == EQ4                  # non-TILED
    assert fit_grad_policy(TILED, 256).block_k == 128       # divides
    assert fit_grad_policy(TILED, 96).block_k == 96         # shrink to k
    assert fit_grad_policy(TILED, 80).block_k == 80
    assert fit_grad_policy(TILED, 100).block_k == 100
    fitted = fit_grad_policy(TILED, 7)
    assert fitted.block_k == 7
    # never exceeds the int32 accumulation bound
    wide = BFPPolicy(scheme=Scheme.TILED, block_k=1 << 20, l_w=12, l_i=12,
                     straight_through=False)
    k = 1 << 18
    assert fit_grad_policy(wide, k).block_k <= bfp.max_safe_k(12, 12)


# ---------------------------------------------------------------------------
# float bit-identity with plain JAX autodiff
# ---------------------------------------------------------------------------

def test_float_gemm_grads_match_jax_autodiff():
    x, w = _xw()

    def routed(x, w):
        return jnp.sum(jnp.sin(EG.gemm(x, w, None)))

    def plain(x, w):
        return jnp.sum(jnp.sin(x @ w))

    gr = jax.grad(routed, argnums=(0, 1))(x, w)
    gp = jax.grad(plain, argnums=(0, 1))(x, w)
    assert _tree_equal(gr, gp)


def test_float_conv_grads_match_jax_autodiff():
    x, w = _conv_xw()

    def routed(x, w):
        return jnp.sum(jnp.square(EG.conv2d(x, w, None, stride=1,
                                            padding="SAME")))

    def plain(x, w):
        y = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jnp.sum(jnp.square(y))

    # the engine's float conv is materialized im2col + float GEMM; its
    # custom VJP must be bit-identical to autodiff of THAT composition
    def im2col_ref(x, w):
        from repro.core.conv_utils import conv_weight_matrix, im2col
        cols, (oh, ow, _) = im2col(x, 3, 3, 1, "SAME")
        y = cols @ conv_weight_matrix(w)
        return jnp.sum(jnp.square(y.reshape(x.shape[0], oh, ow, -1)))

    gr = jax.grad(routed, argnums=(0, 1))(x, w)
    gi = jax.grad(im2col_ref, argnums=(0, 1))(x, w)
    assert _tree_equal(gr, gi)
    # and numerically equal to the XLA conv autodiff
    gp = jax.grad(plain, argnums=(0, 1))(x, w)
    for a, b in zip(gr, gp):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_forward_values_unchanged_by_routing():
    x, w = _xw()
    assert jnp.array_equal(EG.gemm(x, w, EQ4),
                           bfp_matmul_2d(x, w, EQ4))


# ---------------------------------------------------------------------------
# satellite 1: reconciliation with the legacy core.bfp_dot STE
# ---------------------------------------------------------------------------

def test_default_policy_matches_legacy_ste():
    """The routed default-policy (straight_through=True) backward equals
    the legacy ``bfp_matmul_2d`` straight-through estimator bit-exactly
    (the pin ``core/bfp_dot.py``'s RECONCILIATION docstring points at)."""
    x, w = _xw()

    def routed(x, w):
        return jnp.sum(jnp.tanh(EG.gemm(x, w, STE)))

    def legacy(x, w):
        return jnp.sum(jnp.tanh(bfp_matmul_2d(x, w, STE)))

    assert jnp.array_equal(routed(x, w), legacy(x, w))
    gr = jax.grad(routed, argnums=(0, 1))(x, w)
    gl = jax.grad(legacy, argnums=(0, 1))(x, w)
    assert _tree_equal(gr, gl)


# ---------------------------------------------------------------------------
# backward taps + gradient NSR bound, L = 4..12
# ---------------------------------------------------------------------------

def test_backward_taps_carry_grad_paths():
    x, w = _xw()
    events = []
    with tap_ctx(events.append):
        jax.grad(lambda x: jnp.sum(EG.gemm(x, w, EQ4, path="fc")))(x)
    kinds = [(e.kind, e.path) for e in events]
    assert ("gemm", "fc") in kinds
    assert ("gemm_dx", "fc#dx") in kinds
    assert ("gemm_dw", "fc#dw") in kinds


@pytest.mark.parametrize("L", [4, 6, 8, 10, 12])
def test_gemm_grad_nsr_within_bound(L):
    pol = BFPPolicy(l_w=L, l_i=L, straight_through=False)
    x, w = _xw(seed=L)

    recs = measure_gradient_nsr(lambda: jax.grad(
        lambda x, w: jnp.sum(EG.gemm(x, w, pol, path="fc")),
        argnums=(0, 1))(x, w))
    assert sorted(r.kind for r in recs) == ["gemm_dw", "gemm_dx"]
    for r in recs:
        assert r.eta_bound < float("inf")
        assert r.within_bound, (r.kind, r.eta_measured, r.eta_bound)


@pytest.mark.parametrize("L", [4, 8, 12])
def test_conv_grad_nsr_within_bound(L):
    pol = BFPPolicy(l_w=L, l_i=L, straight_through=False)
    x, w = _conv_xw(seed=L)

    recs = measure_gradient_nsr(lambda: jax.grad(
        lambda x, w: jnp.sum(EG.conv2d(x, w, pol)), argnums=(0, 1))(x, w))
    assert sorted(r.kind for r in recs) == ["conv_dw", "conv_dx"]
    for r in recs:
        assert r.within_bound, (r.kind, r.eta_measured, r.eta_bound)


def test_tiled_backward_fits_tile_and_stays_bounded():
    # dL/dw contracts over M=6, which 128 does not divide: the tap must
    # report the FITTED policy and the bound must hold under it
    x, w = _xw(b=6, k=256, n=32)
    recs = measure_gradient_nsr(lambda: jax.grad(
        lambda x, w: jnp.sum(EG.gemm(x, w, TILED, path="t")),
        argnums=(0, 1))(x, w))
    by_kind = {r.kind: r for r in recs}
    assert by_kind["gemm_dw"].policy.block_k == 6
    assert by_kind["gemm_dx"].policy.block_k == 32    # contracts over N
    for r in recs:
        assert r.within_bound


def test_grad_bound_wrappers_match_forward_geometry():
    x, w = _xw()
    g = jax.random.normal(KEY, (x.shape[0], w.shape[1]))
    assert (grad_dx_nsr_upper_bound(g, w, EQ4)
            == gemm_nsr_upper_bound(g, w.T, EQ4))
    assert (grad_dw_nsr_upper_bound(x, g, EQ4)
            == gemm_nsr_upper_bound(x.T, g, EQ4))


# ---------------------------------------------------------------------------
# satellite 3: grad-path PolicyMap precedence through bind
# ---------------------------------------------------------------------------

def _lenet():
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    return params, x


def test_bind_resolves_grad_specs():
    params, _ = _lenet()
    low = BFPPolicy(l_w=4, l_i=4)
    pm = PolicyMap([(r"fc1#dx", low), (r"#dw", None), (r".", EQ4)])
    plan = EG.bind(params, pm, prequantize=False)
    sites = plan.sites
    assert sites["fc1"].dx.policy == low       # explicit grad rule, as-is
    assert sites["fc1"].dw.policy is None      # explicit None pins float
    assert sites["c1"].dx.policy == EQ4        # site fallback (quantized)
    assert sites["c1"].dw.policy is None       # the "#dw" rule matches all
    d = plan.describe()
    assert "grad[" in d and "#" not in d.split("grad[")[0].split()[-1]


def test_plan_grads_match_per_call_grads():
    params, x = _lenet()
    pm = PolicyMap([(r"fc1#dx", BFPPolicy(l_w=4, l_i=4)), (r".", EQ4)])
    plan = EG.bind(params, pm, prequantize=False)

    def loss_plan(p):
        return jnp.sum(small.lenet_apply(p, x, plan))

    def loss_call(p):
        return jnp.sum(small.lenet_apply(p, x, pm))

    gp = jax.grad(loss_plan)(params)
    gc = jax.grad(loss_call)(params)
    assert _tree_equal(gp, gc)
    # and jit of the plan-bound grad agrees numerically
    gj = jax.jit(jax.grad(loss_plan))(params)
    for a, b in zip(jax.tree_util.tree_leaves(gj),
                    jax.tree_util.tree_leaves(gp)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_strict_bind_raises_for_unsupported_backward_backend():
    params, _ = _lenet()
    # pallas has no EQ4 slot: a strict bind must refuse the #dx rule even
    # though every forward site is serviceable
    pm = PolicyMap([(r"fc1#dx", BFPPolicy(backend="pallas")), (r".", None)])
    with pytest.raises(BackendUnsupportedError, match="fc1#dx"):
        EG.bind(params, pm, strict=True, prequantize=False)


def test_bind_grad_warning_dedup_with_forward():
    params, _ = _lenet()
    # EQ4 downgrades pallas->emulated at every site, forward and backward:
    # one warning per forward site, none extra for #dx/#dw
    pm = PolicyMap([(r".", BFPPolicy(backend="pallas",
                                     straight_through=False))])
    with pytest.warns(EG.BackendFallbackWarning) as rec:
        plan = EG.bind(params, pm, prequantize=False)
    n_sites = len(plan.sites)
    assert len(rec) == n_sites


def test_quantized_backward_differs_from_ste_and_improves_with_l():
    # sanity that straight_through=False actually quantizes the backward:
    # the dx gradient differs from the float/STE one, and the deviation
    # shrinks with more mantissa bits
    x, w = _xw()
    g_ste = jax.grad(lambda x: jnp.sum(EG.gemm(x, w, STE)))(x)
    devs = []
    for L in (4, 12):
        pol = BFPPolicy(l_w=L, l_i=L, straight_through=False)
        g = jax.grad(lambda x: jnp.sum(EG.gemm(x, w, pol)))(x)
        devs.append(float(jnp.linalg.norm(g - g_ste)))
    assert devs[0] > 0.0
    assert devs[1] < devs[0]
