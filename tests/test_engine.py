"""The unified BFP GEMM engine: backend agreement, per-layer PolicyMap
resolution end-to-end, and first-class pre-quantized weights.

Key contracts (ISSUE 1 acceptance):
  * emulated and pallas backends agree (bit-level) for Scheme.TILED;
  * prequant weights through the engine are BIT-EXACT vs quantize_weights
    + the emulated path, and vs the fused Pallas kernel;
  * a PolicyMap reproduces a mixed per-layer assignment (first conv in
    float, rest at L=8) through a ResNet-18 forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as EG
from repro.core import BFPPolicy, Scheme
from repro.core.bfp_dot import bfp_matmul_2d
from repro.core.prequant import dequantize_prequant, prequant_leaf
from repro.engine import PolicyMap
from repro.models.cnn import layers as L, resnet, small

KEY = jax.random.PRNGKey(0)
TILED = BFPPolicy(scheme=Scheme.TILED, block_k=128, straight_through=False)
EQ4 = BFPPolicy(straight_through=False)


def _xw(b=64, k=384, n=48, xs=2.0, wscale=0.1):
    x = jax.random.normal(KEY, (b, k)) * xs
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * wscale
    return x, w


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_backends_registered():
    assert {"float", "emulated", "pallas"} <= set(EG.available_backends())


def test_unknown_backend_rejected():
    x, w = _xw()
    with pytest.raises(KeyError, match="unknown BFP backend"):
        EG.gemm(x, w, TILED.with_(backend="cuda"))


def test_float_backend_is_plain_dot():
    x, w = _xw()
    np.testing.assert_array_equal(np.asarray(EG.gemm(x, w, None)),
                                  np.asarray(x @ w))
    # backend="float" ignores quantization entirely (disabled-quant base)
    np.testing.assert_array_equal(
        np.asarray(EG.gemm(x, w, TILED.with_(backend="float"))),
        np.asarray(x @ w))


def test_emulated_matches_legacy_core():
    x, w = _xw()
    for pol in (EQ4, TILED):
        np.testing.assert_array_equal(
            np.asarray(EG.gemm(x, w, pol)),
            np.asarray(bfp_matmul_2d(x, w, pol)))


def test_pallas_fallback_on_unsupported_scheme():
    """Requesting pallas with a paper scheme must NOT silently run TILED
    math (the old use_kernel behaviour); it falls back to emulated EQ4."""
    x, w = _xw()
    out = EG.gemm(x, w, EQ4.with_(backend="pallas"))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(EG.gemm(x, w, EQ4)))


def test_use_kernel_compat_flag():
    x, w = _xw(128, 256, 128)
    pol = TILED.with_(use_kernel=True)
    np.testing.assert_array_equal(
        np.asarray(EG.gemm(x, w, pol)),
        np.asarray(EG.gemm(x, w, TILED.with_(backend="pallas"))))


# ---------------------------------------------------------------------------
# cross-backend agreement (acceptance: identical outputs for Scheme.TILED)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,n", [(64, 256, 32), (100, 384, 70),
                                   (8, 128, 8)])
def test_emulated_pallas_agree_tiled(b, k, n):
    x, w = _xw(b, k, n)
    out_em = EG.gemm(x, w, TILED)
    out_pl = EG.gemm(x, w, TILED.with_(backend="pallas"))
    np.testing.assert_array_equal(np.asarray(out_em), np.asarray(out_pl))


# ---------------------------------------------------------------------------
# pre-quantized weights: bit-exact on every path
# ---------------------------------------------------------------------------

def test_prequant_emulated_bitexact_tiled():
    x, w = _xw()
    pq = prequant_leaf(w, TILED)
    assert EG.is_prequant(pq) and pq["m"].dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(EG.gemm(x, pq, TILED)),
                                  np.asarray(EG.gemm(x, w, TILED)))


def test_prequant_pallas_bitexact_tiled():
    x, w = _xw(100, 384, 70)
    pq = prequant_leaf(w, TILED)
    np.testing.assert_array_equal(
        np.asarray(EG.gemm(x, pq, TILED.with_(backend="pallas"))),
        np.asarray(EG.gemm(x, w, TILED.with_(backend="pallas"))))


def test_prequant_emulated_bitexact_eq4():
    """block_k=None sidecar == per-column blocks == eq. (4) weights."""
    x, w = _xw()
    pq = prequant_leaf(w, EQ4)
    assert pq["s"].shape == (1, w.shape[1])
    np.testing.assert_array_equal(np.asarray(EG.gemm(x, pq, EQ4)),
                                  np.asarray(EG.gemm(x, w, EQ4)))


def test_prequant_float_path_dequantizes():
    x, w = _xw()
    pq = prequant_leaf(w, TILED)
    np.testing.assert_allclose(
        np.asarray(EG.gemm(x, pq, None)),
        np.asarray(x @ dequantize_prequant(pq)), rtol=1e-6, atol=1e-6)


def test_prequant_block_mismatch_rejected():
    x, w = _xw()
    pq = prequant_leaf(w, TILED)  # bk=128 sidecar
    with pytest.raises(ValueError, match="block"):
        EG.gemm(x, pq, TILED.with_(block_k=64))


def test_prequant_int16_falls_back_to_emulated():
    """L_W > 8 mantissas cannot stream through the int8 kernel; the
    engine must fall back to the (still bit-exact) emulated path."""
    x, w = _xw()
    pol = TILED.with_(l_w=12, l_i=8)
    pq = prequant_leaf(w, pol)
    assert pq["m"].dtype == jnp.int16
    np.testing.assert_array_equal(
        np.asarray(EG.gemm(x, pq, pol.with_(backend="pallas"))),
        np.asarray(EG.gemm(x, w, pol)))


# ---------------------------------------------------------------------------
# PolicyMap: per-layer policies (paper Table 3 as config)
# ---------------------------------------------------------------------------

def test_policy_map_resolution_order():
    p8, p6 = BFPPolicy(l_w=8, l_i=8), BFPPolicy(l_w=6, l_i=6)
    pm = PolicyMap.of(("^stem", None), (r"blocks/\d+/c1", p6), default=p8)
    assert pm.resolve("stem") is None
    assert pm.resolve("stem/conv") is None
    assert pm.resolve("blocks/3/c1") == p6
    assert pm.resolve("blocks/3/c2") == p8
    assert pm.resolve("fc") == p8
    assert pm.resolve(None) == p8          # no path -> default
    assert EG.resolve_policy(pm, "stem") is None
    assert EG.resolve_policy(p6, "anything") == p6
    assert EG.resolve_policy(None, "anything") is None


def test_policy_map_from_dict_roundtrip():
    pm = PolicyMap.from_dict({
        "rules": [{"pattern": "^stem", "policy": None},
                  {"pattern": "fc", "policy": {"l_w": 6, "l_i": 6}}],
        "default": {"l_w": 8, "l_i": 8, "scheme": "tiled", "block_k": 128},
    })
    assert pm.resolve("stem") is None
    assert pm.resolve("fc").l_w == 6
    assert pm.resolve("blocks/0/c1").scheme is Scheme.TILED


def test_policy_map_is_hashable_and_jit_safe():
    pm = PolicyMap.of(("c1", None), default=EQ4)
    hash(pm)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    params = small.lenet_init(KEY)
    jitted = jax.jit(lambda p, x: small.lenet_apply(p, x, pm))
    out = jitted(params, x)
    assert out.shape == (2, 10)


def test_policy_map_all_float_equals_none():
    params = resnet.init(KEY, 18, 10, width_mult=0.25)
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    pm = PolicyMap(default=None)
    np.testing.assert_array_equal(np.asarray(resnet.apply(params, x, pm)),
                                  np.asarray(resnet.apply(params, x, None)))


def test_policy_map_uniform_equals_plain_policy():
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    pm = PolicyMap(default=EQ4)
    np.testing.assert_array_equal(
        np.asarray(small.lenet_apply(params, x, pm)),
        np.asarray(small.lenet_apply(params, x, EQ4)))


def test_policy_map_mixed_lenet_matches_manual_composition():
    """first-conv-float map == manually running c1 float, rest BFP."""
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    pm = PolicyMap.of(("^c1$", None), default=EQ4)
    mixed = small.lenet_apply(params, x, pm)

    h = L.relu(L.conv2d(params["c1"], x, 1, "SAME", None))
    h = L.max_pool(h)
    h = L.relu(L.conv2d(params["c2"], h, 1, "SAME", EQ4))
    h = L.max_pool(h)
    h = h.reshape(h.shape[0], -1)
    h = L.relu(L.dense(params["fc1"], h, EQ4))
    manual = L.dense(params["fc2"], h, EQ4)
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(manual))


def test_resnet18_mixed_policy_end_to_end():
    """Acceptance: first conv float, rest L=8, through ResNet-18."""
    params = resnet.init(KEY, 18, 10, width_mult=0.25)
    x = jax.random.normal(KEY, (2, 32, 32, 3))
    p8 = BFPPolicy(l_w=8, l_i=8, straight_through=False)
    pm = PolicyMap.of(("^stem", None), default=p8)
    out_mixed = resnet.apply(params, x, pm)
    out_float = resnet.apply(params, x, None)
    out_bfp = resnet.apply(params, x, p8)
    assert out_mixed.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(out_mixed)))
    # the map actually took effect: differs from BOTH uniform extremes
    assert float(jnp.max(jnp.abs(out_mixed - out_float))) > 0
    assert float(jnp.max(jnp.abs(out_mixed - out_bfp))) > 0
    # and stays closer to float than the all-BFP forward (stem protected)
    err_mixed = float(jnp.linalg.norm(out_mixed - out_float))
    err_bfp = float(jnp.linalg.norm(out_bfp - out_float))
    assert err_mixed < err_bfp * 1.5


# ---------------------------------------------------------------------------
# pre-quantized param trees through real models
# ---------------------------------------------------------------------------

def test_prequant_cnn_forward_bitexact():
    """prequantize_cnn(EQ4) + float-policy-EQ4 forward == in-line
    quantization forward, bit for bit (conv + dense, HWIO round trip)."""
    params = small.lenet_init(KEY)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    pq = EG.prequantize_cnn(params, EQ4)
    assert EG.is_prequant(pq["c1"]["w"]) and EG.is_prequant(pq["fc1"]["w"])
    out_pq = small.lenet_apply(pq, x, EQ4)
    out_inline = small.lenet_apply(params, x, EQ4)
    np.testing.assert_array_equal(np.asarray(out_pq), np.asarray(out_inline))


def test_prequant_cnn_respects_policy_map():
    params = small.lenet_init(KEY)
    pm = PolicyMap.of(("^c1$", None), default=EQ4)
    pq = EG.prequantize_cnn(params, pm)
    assert not EG.is_prequant(pq["c1"]["w"])   # rule kept it float
    assert EG.is_prequant(pq["c2"]["w"])


def test_prequant_resolves_same_paths_as_runtime():
    """A PolicyMap rule must pin the SAME layers at prequant time as at
    GEMM time — resnet conv+bn nesting and LM stack containers are
    stripped from the rule path."""
    rparams = resnet.init(KEY, 18, 10, width_mult=0.25)
    pm = PolicyMap.of(("^stem", None), default=EQ4)
    pq = EG.prequantize_cnn(rparams, pm)
    assert not EG.is_prequant(pq["stem"]["conv"]["w"])    # pinned float
    assert EG.is_prequant(pq["blocks"][0]["c1"]["conv"]["w"])

    # googlenet aux heads: the runtime path KEEPS the "conv" segment
    # ("loss1/conv" — plain conv layer keyed "conv", no bn sibling), so a
    # rule anchored on it must pin the same layer at prequant time.
    from repro.models.cnn import googlenet
    gparams = googlenet.init(KEY, 10, width_mult=0.125)
    pm_g = PolicyMap.of(("^loss1/conv$", None), default=EQ4)
    pq_g = EG.prequantize_cnn(gparams, pm_g)
    assert not EG.is_prequant(pq_g["loss1"]["conv"]["w"])  # pinned float
    assert EG.is_prequant(pq_g["loss2"]["conv"]["w"])

    from repro.configs.base import reduced
    from repro.configs.registry import ARCHS
    from repro.models.lm import model as Mdl
    cfg = reduced(ARCHS["tinyllama-1.1b"])
    params = Mdl.init_params(cfg, KEY)
    pm_lm = PolicyMap.of(("^attn/", None), default=EQ4)   # runtime path form
    pq_lm = EG.prequantize(params, pm_lm)
    assert not EG.is_prequant(pq_lm["layers"]["attn"]["wq"]["w"])
    assert EG.is_prequant(pq_lm["layers"]["ffn"]["w1"]["w"])


def test_prequant_never_touches_moe_router():
    """moe_apply always runs the router in float; prequant must not
    quantize it even under a uniform policy."""
    from repro.configs.base import reduced
    from repro.configs.registry import ARCHS
    from repro.models.lm import model as Mdl
    cfg = reduced(ARCHS["olmoe-1b-7b"])
    params = Mdl.init_params(cfg, KEY)
    pq = EG.prequantize(params, EQ4)
    assert not EG.is_prequant(pq["layers"]["moe"]["router"]["w"])
    assert EG.is_prequant(pq["layers"]["moe"]["w1"])


def test_prequant_block_mismatch_rejected_on_pallas_too():
    """Emulated and pallas must agree on rejecting a sidecar/policy
    block mismatch (no silent numeric drift between backends)."""
    x, w = _xw(8, 256, 16)
    pq = prequant_leaf(w, TILED.with_(block_k=64))
    with pytest.raises(ValueError, match="block"):
        EG.gemm(x, pq, TILED.with_(backend="pallas"))  # policy bk=128


def test_default_tiles_safe_for_wide_mantissas():
    from repro.kernels import ops
    _, _, bk = ops.default_tiles(8, 256, 16, None, l_sum=30)
    assert bk <= 4      # 2**(32-30); no min-8 floor defeating the cap
    out = ops.bfp_matmul(jax.random.normal(KEY, (8, 64)),
                         jax.random.normal(jax.random.PRNGKey(1), (64, 16)),
                         BFPPolicy(l_w=15, l_i=15, scheme=Scheme.TILED,
                                   straight_through=False),
                         interpret=True)
    assert out.shape == (8, 16)


def test_policy_none_goes_through_registered_float_backend():
    x, w = _xw(8, 32, 8)
    calls = []
    orig = EG.get_backend("float")
    EG.register_backend("float",
                        lambda x2d, w, pol, key: calls.append(1) or
                        orig.matmul(x2d, w, pol, key))
    try:
        EG.gemm(x, w, None)
        assert calls, "policy=None must dispatch via the registry"
    finally:
        EG.register_backend("float", orig.matmul, orig.supports)


def test_prequant_lm_forward_close():
    """LM tree prequant (incl. stacked layers + MoE experts) serves
    through the engine; outputs match the inline-BFP forward closely."""
    from repro.configs.base import reduced
    from repro.configs.registry import ARCHS
    from repro.models.lm import model as Mdl
    cfg = reduced(ARCHS["olmoe-1b-7b"])
    params = Mdl.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    pol = EQ4
    pq = EG.prequantize(params, pol)
    assert EG.is_prequant(pq["layers"]["moe"]["w1"])
    lf, _ = Mdl.forward(params, cfg, toks, policy=pol)
    lq, _ = Mdl.forward(pq, cfg, toks, policy=pol)
    assert bool(jnp.all(jnp.isfinite(lq)))
    rel = float(jnp.linalg.norm(lq - lf) / (jnp.linalg.norm(lf) + 1e-9))
    assert rel < 0.05, rel


def test_bfp_dot_shim_is_engine():
    from repro.core.bfp_dot import bfp_dot
    x, w = _xw()
    np.testing.assert_array_equal(np.asarray(bfp_dot(x, w, TILED)),
                                  np.asarray(EG.gemm(x, w, TILED)))
    pm = PolicyMap.of(("^x$", None), default=TILED)
    np.testing.assert_array_equal(
        np.asarray(bfp_dot(x, w, pm, path="dense1")),
        np.asarray(EG.gemm(x, w, TILED)))
