"""Tier-1 docs-sync guard (ISSUE 5 satellite).

The CI docs-sync job EXECUTES examples/quickstart.py and every fenced
README ```python block (tools/check_docs.py).  This file keeps the
cheap half in tier-1: the extractor finds the blocks, and every block
(plus the assembled session) at least COMPILES — so a syntax-breaking
doc edit or a fence typo fails the local suite immediately, not just in
CI.
"""
import importlib.util
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(REPO, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_readme_blocks_extract_and_compile():
    cd = _check_docs()
    readme = os.path.join(REPO, "README.md")
    with open(readme) as f:
        blocks = cd.extract_blocks(f.read())
    # the README documents at least: core API, plans/taps, packed
    # checkpoints, CNN serving
    assert len(blocks) >= 4, f"README python blocks vanished: {len(blocks)}"
    for i, b in enumerate(blocks):
        compile(b, f"<README block {i + 1}>", "exec")
    script, n = cd.assemble(readme)
    assert n == len(blocks)
    compile(script, "<README assembled>", "exec")


def test_quickstart_compiles():
    path = os.path.join(REPO, "examples", "quickstart.py")
    with open(path) as f:
        compile(f.read(), path, "exec")


def test_extractor_skips_non_python_fences():
    cd = _check_docs()
    md = "```bash\necho no\n```\n```python\nx = 1\n```\n```\nplain\n```\n"
    assert cd.extract_blocks(md) == ["x = 1"]
