"""Open-loop load harness (ISSUE 9): deterministic Poisson traces, the
virtual-clock driver, report accounting, and the continuous-vs-bucket
ordering the pinned BENCH_serve.json trajectory gates.
"""
import jax
import numpy as np
import pytest

from repro.core.policy import TPU_TILED
from repro.models.cnn import MODELS
from repro.serve.cnn import CnnServeEngine, ImageRequest
from repro.serve.load import (Arrival, VirtualClock, poisson_arrivals,
                              run_open_loop)

KEY = jax.random.PRNGKey(0)
POL = TPU_TILED.with_(block_k=None, straight_through=False)
MIX = [(0.5, "a", {}), (0.5, "b", {"deadline": 0.5})]


@pytest.fixture(scope="module")
def lenet():
    spec = MODELS["lenet"]
    params = spec.init(KEY)
    imgs = [jax.random.normal(jax.random.PRNGKey(5 + i),
                              spec.input_shape()) for i in range(4)]
    return spec, params, imgs


def test_poisson_arrivals_deterministic_and_shaped():
    a1 = poisson_arrivals(10.0, 50, MIX, seed=3)
    a2 = poisson_arrivals(10.0, 50, MIX, seed=3)
    assert a1 == a2                      # replayable trace
    assert a1 != poisson_arrivals(10.0, 50, MIX, seed=4)
    assert len(a1) == 50
    ts = [a.t for a in a1]
    assert ts == sorted(ts) and ts[0] > 0
    # mean gap ~ 1/rate (loose: 50 samples)
    assert 0.03 < np.mean(np.diff([0.0] + ts)) < 0.3
    kinds = {a.kind for a in a1}
    assert kinds == {"a", "b"}
    for a in a1:
        # the relative deadline is lifted off the payload
        assert a.deadline == (0.5 if a.kind == "b" else None)
        assert "deadline" not in a.payload
        assert isinstance(a.rid, int)


def test_poisson_arrivals_validation():
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(0.0, 5, MIX)
    with pytest.raises(ValueError, match="n must"):
        poisson_arrivals(1.0, 0, MIX)
    with pytest.raises(ValueError, match="mix"):
        poisson_arrivals(1.0, 5, [])


def test_virtual_clock():
    c = VirtualClock(2.0)
    assert c() == 2.0
    c.advance(0.5)
    assert c() == 2.5
    with pytest.raises(ValueError):
        c.advance(-1.0)


def _drive(lenet_fix, n=10, rate=200.0, seed=1, mix=MIX, **engine_kw):
    spec, params, imgs = lenet_fix
    arrivals = poisson_arrivals(rate, n, mix, seed=seed)
    clock = VirtualClock()
    eng = CnnServeEngine(params, spec.apply, POL, slots=4, jit=False,
                         clock=clock, **engine_kw)

    def mk(a):
        return ImageRequest(
            rid=a.rid, image=imgs[a.rid % len(imgs)],
            deadline=None if a.deadline is None else a.t + a.deadline)

    return run_open_loop(eng, arrivals, mk, clock=clock,
                         call_cost=0.002), eng


def test_open_loop_accounting(lenet):
    rep, eng = _drive(lenet)
    assert rep.offered == 10
    assert rep.completed + rep.shed + rep.expired + rep.failed == 10
    assert rep.completed == eng.stats["completed"] == 10
    assert rep.p99_ms >= rep.p50_ms > 0
    assert rep.mean_ms > 0 and rep.duration_s > 0
    assert rep.goodput_rps == pytest.approx(rep.completed /
                                            rep.duration_s)
    assert rep.calls == eng.ncalls > 0
    row = rep.row()
    assert row["completed"] == 10 and isinstance(row["p99_ms"], float)


def test_virtual_time_is_deterministic(lenet):
    r1, _ = _drive(lenet, n=20, seed=6)
    r2, _ = _drive(lenet, n=20, seed=6)
    assert r1 == r2                      # exact replay, any machine


def test_shedding_counted_once(lenet):
    rep, eng = _drive(lenet, n=30, rate=5000.0, max_queue=2)
    assert rep.shed > 0
    assert rep.shed == eng.stats["shed"]
    assert rep.completed + rep.shed + rep.expired + rep.failed == 30


def test_bucket_barrier_loses_on_p99(lenet):
    """The whole point: on the identical trace, the bucket barrier's
    idle waits turn into tail latency — and, once deadlines bind,
    expiries — that the continuous engine never pays."""
    # 10ms deadline on half the traffic: well above the continuous
    # engine's tail (~3ms here) but inside the bucket barrier's
    # max_wait idling, so only the barrier converts waits into expiry
    tight = [(0.5, "a", {}), (0.5, "b", {"deadline": 0.010})]
    cont, _ = _drive(lenet, n=40, rate=300.0, seed=9, mix=tight,
                     batching="continuous")
    buck, _ = _drive(lenet, n=40, rate=300.0, seed=9, mix=tight,
                     batching="bucket", max_wait=4)
    assert cont.p99_ms < buck.p99_ms
    assert cont.expired < buck.expired   # the barrier's waits expire work
    assert cont.goodput_rps > buck.goodput_rps


def test_idle_server_jumps_to_next_arrival(lenet):
    """Sparse arrivals: the driver must jump the clock across idle gaps
    instead of spinning, and latencies must not include idle time."""
    spec, params, imgs = lenet
    arrivals = [Arrival(t=float(t), rid=i, kind="a", payload={})
                for i, t in enumerate((1.0, 100.0, 200.0))]
    clock = VirtualClock()
    eng = CnnServeEngine(params, spec.apply, POL, slots=4, jit=False,
                         clock=clock)
    rep = run_open_loop(eng, arrivals,
                        lambda a: ImageRequest(rid=a.rid,
                                               image=imgs[0]),
                        clock=clock, call_cost=0.002)
    assert rep.completed == 3
    assert clock.t >= 200.0              # reached the last arrival
    assert rep.p99_ms < 1000.0           # idle gaps are not latency
