"""Hypothesis property suite: core BFP invariants + the paper's NSR bound.

Replaces ad-hoc point checks with generated cases (ISSUE 4): every
property runs 200+ examples (real hypothesis when installed; the
deterministic ``_hypothesis_stub`` honors ``max_examples`` otherwise).

Invariants pinned here are exactly what the CNN serving stack relies on:

  * the shared block exponent IS the block max exponent (paper eq. 1);
  * mantissas saturate at +/-(2^(L-1) - 1) — and the block max actually
    uses the top half of the mantissa range;
  * requantization is idempotent (serving may re-format formatted data:
    prequant weights, cached activations — no drift allowed);
  * all-zero blocks round-trip exactly;
  * measured NSR never exceeds the analytic worst-case bounds from
    ``core.nsr`` (matrix formatting AND full GEMMs), across mantissa
    widths 4-12, block sizes, schemes, and input scales.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal container: deterministic fallback sampler
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import bfp, nsr, packed, prequant
from repro.core.bfp import Rounding, Scheme
from repro.core.bfp_dot import bfp_matmul_2d

# every test here is a generated-example sweep: the whole module is
# the slow profile (deselect with -m 'not slow' for quick iteration)
pytestmark = pytest.mark.slow
from repro.core.policy import BFPPolicy

#: ISSUE 4 acceptance: 200+ generated cases per property
N_EXAMPLES = 200

SEEDS = st.integers(0, 2 ** 31 - 1)
BITS = st.integers(4, 12)
SCALE_POWS = st.integers(-12, 12)


def _block(seed: int, rows: int, cols: int, scale_pow: int) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * \
        (2.0 ** scale_pow)


def test_pow2_exact_everywhere():
    """The scale primitive is EXACTLY 2^e for every representable float32
    exponent, denormals included — ``jnp.exp2`` is not (1 ulp off at many
    negative integer exponents), which the idempotence property below
    caught breaking TRUNCATE requantization."""
    e = np.arange(-160, 140)
    got = np.asarray(bfp.pow2(jnp.asarray(e)))
    with np.errstate(over="ignore"):     # e > 127 overflows to inf — wanted
        want = np.exp2(e.astype(np.float64)).astype(np.float32)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# BFP formatting invariants
# ---------------------------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=SCALE_POWS, seed=SEEDS,
       cols=st.sampled_from([1, 3, 8, 33, 64]))
def test_shared_exponent_is_block_max_exponent(bits, scale_pow, seed, cols):
    """eps = max_i floor(log2 |x_i|) over the block (paper eq. 1)."""
    x = _block(seed, 8, cols, scale_pow)
    b = bfp.quantize(x, bits, (1,))
    amax = np.abs(np.asarray(x)).max(axis=1)
    _, e = np.frexp(amax)                      # amax = f * 2^e, f in [.5, 1)
    np.testing.assert_array_equal(np.asarray(b.exponent).reshape(-1),
                                  (e - 1).astype(np.int32))


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=SCALE_POWS, seed=SEEDS,
       rounding=st.sampled_from([Rounding.ROUND, Rounding.TRUNCATE]))
def test_mantissas_saturate_at_limit(bits, scale_pow, seed, rounding):
    """|m| <= 2^(L-1)-1 always, and the block max lands in the top half
    of the mantissa range [2^(L-2), 2^(L-1)-1] — the format wastes no
    headroom on the element that defines the exponent."""
    x = _block(seed, 4, 32, scale_pow)
    b = bfp.quantize(x, bits, (1,), rounding)
    lim = 2 ** (bits - 1) - 1
    m = np.abs(np.asarray(b.mantissa, dtype=np.int64))
    assert m.max() <= lim
    # per block, the max element's mantissa uses the top half
    assert (m.max(axis=1) >= 2 ** (bits - 2)).all()


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=st.integers(-8, 8), seed=SEEDS)
def test_mantissa_clipping_hits_limit_exactly(bits, scale_pow, seed):
    """An element just under the next power of two rounds past the top
    mantissa and must CLIP to exactly +/-(2^(L-1)-1), not wrap."""
    x = np.array(_block(seed, 1, 16, scale_pow), dtype=np.float32)
    _, e = np.frexp(np.abs(x).max())
    eps = int(e) - 1                    # the block exponent
    x[0, 0] = (2.0 - 2.0 ** -12) * 2.0 ** eps    # 1.111...b * 2^eps
    x[0, 1] = -x[0, 0]                  # eps unchanged: |x00| < 2^(eps+1)
    b = bfp.quantize(jnp.asarray(x), bits, (1,))
    lim = 2 ** (bits - 1) - 1
    m = np.asarray(b.mantissa, dtype=np.int64)
    assert m[0, 0] == lim and m[0, 1] == -lim


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=SCALE_POWS, seed=SEEDS,
       rounding=st.sampled_from([Rounding.ROUND, Rounding.TRUNCATE]))
def test_requantization_idempotent(bits, scale_pow, seed, rounding):
    """quantize(dequantize(quantize(x))) == quantize(x) bit-for-bit:
    already-formatted data (prequant weights, requantized activations)
    never drifts through a second pass."""
    x = _block(seed, 4, 32, scale_pow)
    b1 = bfp.quantize(x, bits, (1,), rounding)
    x1 = b1.dequantize()
    b2 = bfp.quantize(x1, bits, (1,), rounding)
    np.testing.assert_array_equal(np.asarray(b1.mantissa),
                                  np.asarray(b2.mantissa))
    np.testing.assert_array_equal(np.asarray(b1.exponent),
                                  np.asarray(b2.exponent))
    np.testing.assert_array_equal(np.asarray(x1),
                                  np.asarray(b2.dequantize()))


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=SCALE_POWS, seed=SEEDS,
       zero_row=st.integers(0, 3))
def test_all_zero_blocks_round_trip_exactly(bits, scale_pow, seed,
                                            zero_row):
    """A zero block among live blocks dequantizes to EXACT zeros (no
    denormal junk from the sentinel exponent), and its mantissas are 0."""
    x = np.array(_block(seed, 4, 16, scale_pow), dtype=np.float32)
    x[zero_row] = 0.0
    b = bfp.quantize(jnp.asarray(x), bits, (1,))
    m = np.asarray(b.mantissa)
    deq = np.asarray(b.dequantize())
    assert (m[zero_row] == 0).all()
    assert (deq[zero_row] == 0.0).all()
    # and the all-zero matrix round-trips exactly too
    bz = bfp.quantize(jnp.zeros((2, 8)), bits, (0, 1))
    assert (np.asarray(bz.dequantize()) == 0.0).all()


# ---------------------------------------------------------------------------
# The paper's NSR upper bound (core.nsr) — measurement never exceeds it
# ---------------------------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=SCALE_POWS, seed=SEEDS,
       operand=st.sampled_from(["i", "w"]),
       block_k=st.sampled_from([8, 16, 32, None]))
def test_matrix_nsr_never_exceeds_bound(bits, scale_pow, seed, operand,
                                        block_k):
    """Measured formatting NSR <= the hard per-block bound n*2^(-2(L-2)),
    for the paper scheme and TILED at several block sizes."""
    # the contraction axis (axis 0 for "w" weights, axis 1 for "i"
    # activations) must be divisible by every TILED block size
    x = _block(seed, 64, 48, scale_pow) if operand == "w" \
        else _block(seed, 12, 64, scale_pow)
    scheme = Scheme.EQ4 if block_k is None else Scheme.TILED
    pol = BFPPolicy(l_w=bits, l_i=bits, scheme=scheme, block_k=block_k,
                    straight_through=False)
    snr = float(nsr.measure_matrix_snr(x, bits, operand, pol))
    eta = 10.0 ** (-snr / 10.0)
    _, elems = nsr._block_sizes_and_exps(x, bits, operand, pol)
    assert eta <= nsr.matrix_nsr_upper_bound(elems, bits) * (1 + 1e-4), \
        (eta, elems, bits)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=st.integers(-8, 8), seed=SEEDS,
       block_k=st.sampled_from([8, 16, 32, None]),
       w_scale_pow=st.integers(-6, 2))
def test_gemm_nsr_never_exceeds_bound(bits, scale_pow, seed, block_k,
                                      w_scale_pow):
    """ISSUE 4 acceptance: measured NSR of random GEMMs never exceeds the
    analytic bound from core/nsr.py, across mantissa widths 4-12, block
    sizes, and input scales."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (12, 64)) * (2.0 ** scale_pow)
    w = jax.random.normal(k2, (64, 16)) * (2.0 ** w_scale_pow)
    scheme = Scheme.EQ4 if block_k is None else Scheme.TILED
    pol = BFPPolicy(l_w=bits, l_i=bits, scheme=scheme, block_k=block_k,
                    straight_through=False)
    y_f = x @ w
    y_q = bfp_matmul_2d(x, w, pol)
    eta = float(jnp.sum(jnp.square(y_q - y_f)) /
                jnp.maximum(jnp.sum(jnp.square(y_f)),
                            jnp.finfo(jnp.float32).tiny))
    bound = float(nsr.gemm_nsr_upper_bound(x, w, pol))
    assert eta <= bound * (1 + 1e-3), (eta, bound, bits, block_k)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=st.integers(4, 11), seed=SEEDS)
def test_gemm_bound_tightens_with_bits(bits, seed):
    """The bound is guidance, not vacuous: one more mantissa bit cuts it
    4x (6 dB/bit, the paper's design trade-off), tracking the format."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (8, 32))
    w = jax.random.normal(k2, (32, 8)) * 0.1
    pol = BFPPolicy(l_w=bits, l_i=bits, straight_through=False)
    b1 = float(nsr.gemm_nsr_upper_bound(x, w, pol))
    b2 = float(nsr.gemm_nsr_upper_bound(
        x, w, pol.with_(l_w=bits + 1, l_i=bits + 1)))
    assert b2 < b1
    assert b1 / b2 > 2.0     # ~4x in the small-error regime


# ---------------------------------------------------------------------------
# Packed BFP container (ISSUE 5): serialize -> bytes -> deserialize is
# bit-exact for every scheme x mantissa width x odd geometry
# ---------------------------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=SCALE_POWS, seed=SEEDS,
       scheme=st.sampled_from([Scheme.EQ2, Scheme.EQ3, Scheme.EQ4,
                               Scheme.EQ5, Scheme.TILED]),
       operand=st.sampled_from(["w", "i"]),
       rows=st.sampled_from([1, 3, 7, 8, 16]),
       cols=st.sampled_from([1, 4, 12, 33, 64]))
def test_packed_container_round_trip_bit_exact(bits, scale_pow, seed,
                                               scheme, operand, rows, cols):
    """pack -> to_bytes -> from_bytes -> unpack reproduces the EXACT
    BFPBlock (integer mantissas, integer exponents, identical dequant)
    for every scheme, mantissa width 4-12, and odd shapes whose bit
    count does not land on a byte boundary.  The payload is exactly
    ceil(n*L/8) bytes — a 6-bit mantissa really takes 6 bits."""
    x = _block(seed, rows, cols, scale_pow)
    k = x.shape[1] if operand == "w" else x.shape[0]
    block_k = (k if k % 4 else 4) if scheme is Scheme.TILED else None
    blk = bfp.bfp_quantize_matrix(x, bits, operand, scheme, block_k)
    p = packed.pack_block(blk, scheme=scheme.value, operand=operand)
    assert len(p.payload) == -(-x.size * bits // 8)
    assert p.nbytes == len(p.to_bytes())
    p2 = packed.PackedBFP.from_bytes(p.to_bytes())
    assert p2.bits == bits and p2.shape == tuple(x.shape)
    assert p2.meta["scheme"] == scheme.value
    b2 = packed.unpack_block(p2)
    np.testing.assert_array_equal(np.asarray(blk.mantissa),
                                  np.asarray(b2.mantissa))
    np.testing.assert_array_equal(np.asarray(blk.exponent),
                                  np.asarray(b2.exponent))
    np.testing.assert_array_equal(np.asarray(blk.dequantize()),
                                  np.asarray(b2.dequantize()))


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(bits=BITS, scale_pow=SCALE_POWS, seed=SEEDS,
       k=st.sampled_from([4, 6, 12, 16]),
       n=st.sampled_from([1, 5, 10, 33]),
       block_k=st.sampled_from([1, 2, None]))
def test_packed_prequant_round_trip_bit_exact(bits, scale_pow, seed, k, n,
                                              block_k):
    """The prequant {"m", "s"} sidecar survives the packed container
    bit-exactly: integer mantissas AND the float32 power-of-two step
    sidecar (recovered from int8 block exponents) are identical, so a
    packed checkpoint restore is indistinguishable from binding the
    float tree."""
    w = _block(seed, k, n, scale_pow)
    pol = BFPPolicy(l_w=bits, scheme=Scheme.TILED, block_k=block_k,
                    straight_through=False)
    d = prequant.prequant_leaf(w, pol)
    assert prequant.is_prequant(d)
    p = packed.PackedBFP.from_bytes(
        packed.pack_prequant(d, pol.l_w).to_bytes())
    d2 = packed.unpack_prequant(p)
    np.testing.assert_array_equal(np.asarray(d["m"]), np.asarray(d2["m"]))
    np.testing.assert_array_equal(np.asarray(d["s"]), np.asarray(d2["s"]))
    np.testing.assert_array_equal(
        np.asarray(prequant.dequantize_prequant(d)),
        np.asarray(packed.unpack_dequant(p)))
