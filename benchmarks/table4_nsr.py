"""Paper Table 4 — per-layer experimental vs model SNR, all four nets.

Full-architecture VGG-16 (ImageNet-shaped synthetic inputs, He-init
weights): the NSR theory is data-parametric, so this validates the
paper's analytical contribution without ILSVRC12 (DESIGN.md §8.1).
Reduced width keeps CPU runtime sane; --full uses width 1.0.

ResNet-18 and GoogLeNet (the paper's other Table-3/4 networks) run
through the tap-based ``analyze_model`` with measured-inheritance
eq. 19-20 — branch/concat topologies the sequential walker could not
traverse; only the per-model worst deviation is emitted.
"""
from __future__ import annotations

import sys

import jax

from repro.core.policy import BFPPolicy
from repro.models.cnn import analysis, googlenet, resnet, vgg
from benchmarks import common
from benchmarks.common import emit


def run(width: float = 0.25, hw: int = 64, layers: int = 10):
    if common.SMOKE:
        width, hw, layers = 0.125, 32, 3
    key = jax.random.PRNGKey(0)
    params = vgg.init(key, 1000, width_mult=width, input_hw=hw, fc_dim=256)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, hw, hw, 3))
    rows = analysis.analyze_vgg(params, x, BFPPolicy(), max_layers=layers)
    worst = 0.0
    for r in rows:
        dev = abs(r.output_ex - r.output_multi)
        worst = max(worst, dev)
        emit(f"table4/{r.name}", 0.0,
             f"ex={r.output_ex:.2f};single={r.output_single:.2f};"
             f"multi={r.output_multi:.2f};relu={r.relu_ex:.2f};"
             f"dev={dev:.2f}")
    emit("table4/worst_deviation_db", 0.0,
         f"{worst:.2f} (paper reports <= 8.9 dB)")

    # beyond the sequential walker: branch topologies via engine taps
    rw = 0.125 if common.SMOKE else 0.25
    rhw = 24 if common.SMOKE else 32
    rparams = resnet.init(key, 18, 1000, width_mult=rw,
                          stage_depths=(1, 1, 1, 1) if common.SMOKE
                          else None)
    rx = jax.random.normal(jax.random.PRNGKey(2), (2, rhw, rhw, 3))
    cap = 6 if common.SMOKE else None
    rows = analysis.analyze_model(resnet.apply, rparams, rx, BFPPolicy(),
                                  max_sites=cap)
    dev = max(abs(r.output_ex - r.output_multi) for r in rows)
    emit("table4/resnet18_worst_deviation_db", 0.0,
         f"{dev:.2f} over {len(rows)} sites (measured inheritance)")

    # aux heads need >= 64x64 inputs (4x4 pooled maps); smoke drops them
    ghw = 32 if common.SMOKE else 64
    g_apply = googlenet.apply if not common.SMOKE else \
        (lambda p, xx, pol: googlenet.apply(p, xx, pol, with_aux=False))
    gparams = googlenet.init(key, 1000, width_mult=0.125)
    gx = jax.random.normal(jax.random.PRNGKey(3), (2, ghw, ghw, 3))
    rows = analysis.analyze_model(g_apply, gparams, gx, BFPPolicy(),
                                  max_sites=cap)
    dev = max(abs(r.output_ex - r.output_multi) for r in rows)
    emit("table4/googlenet_worst_deviation_db", 0.0,
         f"{dev:.2f} over {len(rows)} sites (measured inheritance)")


if __name__ == "__main__":
    full = "--full" in sys.argv
    run(width=1.0 if full else 0.25, hw=224 if full else 64,
        layers=13 if full else 10)
