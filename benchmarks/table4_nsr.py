"""Paper Table 4 — per-layer experimental vs model SNR on VGG-16.

Full-architecture VGG-16 (ImageNet-shaped synthetic inputs, He-init
weights): the NSR theory is data-parametric, so this validates the
paper's analytical contribution without ILSVRC12 (DESIGN.md §8.1).
Reduced width keeps CPU runtime sane; --full uses width 1.0.
"""
from __future__ import annotations

import sys

import jax

from repro.core.policy import BFPPolicy
from repro.models.cnn import analysis, vgg
from benchmarks import common
from benchmarks.common import emit


def run(width: float = 0.25, hw: int = 64, layers: int = 10):
    if common.SMOKE:
        width, hw, layers = 0.125, 32, 3
    key = jax.random.PRNGKey(0)
    params = vgg.init(key, 1000, width_mult=width, input_hw=hw, fc_dim=256)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, hw, hw, 3))
    rows = analysis.analyze_vgg(params, x, BFPPolicy(), max_layers=layers)
    worst = 0.0
    for r in rows:
        dev = abs(r.output_ex - r.output_multi)
        worst = max(worst, dev)
        emit(f"table4/{r.name}", 0.0,
             f"ex={r.output_ex:.2f};single={r.output_single:.2f};"
             f"multi={r.output_multi:.2f};relu={r.relu_ex:.2f};"
             f"dev={dev:.2f}")
    emit("table4/worst_deviation_db", 0.0,
         f"{worst:.2f} (paper reports <= 8.9 dB)")


if __name__ == "__main__":
    full = "--full" in sys.argv
    run(width=1.0 if full else 0.25, hw=224 if full else 64,
        layers=13 if full else 10)
